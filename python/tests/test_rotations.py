"""Build-time rotation utilities + cross-layer (python↔rust) invariants."""

import hypothesis.strategies as st
import numpy as np
from hypothesis import given, settings

from compile.rotations import (
    hadamard_matrix,
    orthogonality_error,
    random_hadamard,
    random_orthogonal,
)
from compile.kernels.ref import kurtosis_ref

import jax.numpy as jnp

settings.register_profile("rot", deadline=None, max_examples=15, derandomize=True)
settings.load_profile("rot")


@given(logn=st.integers(1, 9))
def test_hadamard_orthogonal(logn):
    h = hadamard_matrix(2**logn)
    assert orthogonality_error(h) < 1e-5


@given(logn=st.integers(2, 8), seed=st.integers(0, 10_000))
def test_random_hadamard_orthogonal(logn, seed):
    assert orthogonality_error(random_hadamard(2**logn, seed)) < 1e-4


@given(n=st.sampled_from([4, 16, 64, 100]), seed=st.integers(0, 10_000))
def test_random_orthogonal(n, seed):
    q = random_orthogonal(n, seed)
    assert orthogonality_error(q) < 1e-4
    # determinant ±1 (orthogonal); slogdet magnitude 0
    _, logdet = np.linalg.slogdet(q.astype(np.float64))
    assert abs(logdet) < 1e-3


def test_hadamard_first_row_constant():
    h = hadamard_matrix(16)
    assert np.allclose(h[0], 1.0 / 4.0)


@given(seed=st.integers(0, 1000))
def test_rotation_gaussianizes_outlier_channels(seed):
    """The QuaRot/KurTail mechanism at the numpy level: per-token kurtosis
    of outlier-stressed data drops toward 3 (gaussian) after a random
    Hadamard — the precondition for the kurtosis objective to have slack
    left to exploit."""
    rng = np.random.default_rng(seed)
    x = rng.laplace(size=(256, 64)).astype(np.float32)
    x[:, 7] *= 25.0
    before = float(jnp.mean(kurtosis_ref(jnp.asarray(x))))
    xr = x @ random_hadamard(64, seed)
    after = float(jnp.mean(kurtosis_ref(jnp.asarray(xr))))
    assert after < before
    assert abs(after - 3.0) < 1.5
