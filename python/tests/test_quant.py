"""Quantizer semantics (ref.py is the oracle shared with Rust goldens)."""

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from compile.kernels import ref
from compile import quant as Q

settings.register_profile("quant", deadline=None, max_examples=20, derandomize=True)
settings.load_profile("quant")


@given(bits=st.sampled_from([2, 3, 4, 8]), seed=st.integers(0, 2**31 - 1))
def test_sym_roundtrip_error_bounded(bits, seed):
    """|x − fq(x)| ≤ s/2 for unclipped symmetric quantization."""
    x = np.random.default_rng(seed).normal(size=(16, 64)).astype(np.float32)
    y = np.asarray(ref.fake_quant_sym(jnp.asarray(x), bits, None))
    s = np.max(np.abs(x), axis=-1, keepdims=True) / ref.sym_qmax(bits)
    assert np.all(np.abs(x - y) <= s / 2 + 1e-6)


@given(seed=st.integers(0, 2**31 - 1))
def test_asym_roundtrip_error_bounded(seed):
    x = np.random.default_rng(seed).uniform(-3, 7, size=(8, 32)).astype(np.float32)
    y = np.asarray(ref.fake_quant_asym(jnp.asarray(x), 4))
    s = (np.max(x, -1, keepdims=True) - np.min(x, -1, keepdims=True)) / 15
    assert np.all(np.abs(x - y) <= s / 2 + 1e-5)


def test_asym_beats_sym_on_shifted_data():
    """Asymmetric quantization wins on non-centred data — why the paper
    uses it for the (post-softmax-adjacent) KV cache."""
    x = np.random.default_rng(0).uniform(4, 5, size=(64, 64)).astype(np.float32)
    xs = np.asarray(ref.fake_quant_sym(jnp.asarray(x), 4, None))
    xa = np.asarray(ref.fake_quant_asym(jnp.asarray(x), 4))
    assert np.mean((x - xa) ** 2) < np.mean((x - xs) ** 2) / 4


def test_clip_reduces_bulk_error_under_outliers():
    """The 0.98-quantile clip trades outlier fidelity for bulk precision."""
    rng = np.random.default_rng(1)
    x = rng.normal(size=(128, 256)).astype(np.float32)
    x[:, 0] *= 100.0  # one outlier channel
    y_clip = np.asarray(ref.fake_quant_sym(jnp.asarray(x), 4, 0.98))
    y_noclip = np.asarray(ref.fake_quant_sym(jnp.asarray(x), 4, None))
    bulk = np.s_[:, 1:]
    assert np.mean((x[bulk] - y_clip[bulk]) ** 2) < np.mean((x[bulk] - y_noclip[bulk]) ** 2)


def test_quantile_interpolation_matches_numpy():
    x = np.abs(np.random.default_rng(2).normal(size=(7, 129)).astype(np.float32))
    got = np.asarray(ref.row_absmax_scale(jnp.asarray(x), 4, 0.98)) * ref.sym_qmax(4)
    want = np.quantile(x, 0.98, axis=-1, keepdims=True)
    np.testing.assert_allclose(got, want, rtol=1e-4)


def test_ste_gradient_is_identity():
    q = Q.QuantConfig(use_pallas=False)

    def f(x):
        return jnp.sum(Q.act_fake_quant_ste(x, q) ** 2)

    x = jnp.asarray(np.random.default_rng(3).normal(size=(4, 32)), jnp.float32)
    g = jax.grad(f)(x)
    # STE: d/dx sum(fq(x)²) ≈ 2·fq(x) (identity backward through fq)
    want = 2 * Q.act_fake_quant(x, q)
    np.testing.assert_allclose(np.asarray(g), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_rotation_then_quant_beats_quant_on_outliers():
    """The whole point of the paper, in one assert: rotating a heavy-tailed
    activation matrix before 4-bit quantization reduces quantization MSE."""
    rng = np.random.default_rng(4)
    x = rng.normal(size=(256, 128)).astype(np.float32)
    x[:, 7] *= 30.0  # outlier channel, as in LLM residual streams
    h = np.asarray(ref.hadamard_matrix(128))
    xr = x @ h
    e_plain = np.mean((x - np.asarray(ref.fake_quant_sym(jnp.asarray(x), 4, 0.98))) ** 2)
    e_rot = np.mean((xr - np.asarray(ref.fake_quant_sym(jnp.asarray(xr), 4, 0.98))) ** 2)
    assert e_rot < e_plain / 2
