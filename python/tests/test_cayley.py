"""Cayley-Adam on the Stiefel manifold + kurtosis loss (KurTail's core)."""

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from compile import cayley
from compile.kernels.ref import fwht_ref, kurtail_loss_ref
from compile.rotations import orthogonality_error, random_orthogonal

settings.register_profile("cayley", deadline=None, max_examples=10, derandomize=True)
settings.load_profile("cayley")


def run_steps(X, d, n_steps, lr=0.1, r0=None):
    step = jax.jit(cayley.make_kurtail_step(d))
    r = jnp.eye(d) if r0 is None else jnp.asarray(r0)
    m = jnp.zeros((d, d))
    v = jnp.float32(0.0)
    losses = []
    for t in range(n_steps):
        r, m, v, loss = step(r, m, v, X, jnp.float32(lr), jnp.float32(t + 1))
        losses.append(float(loss))
    return np.asarray(r), losses


@given(d=st.sampled_from([16, 32, 64]), seed=st.integers(0, 1000))
def test_step_preserves_orthogonality(d, seed):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.laplace(size=(512, d)), jnp.float32)
    r, _ = run_steps(X, d, 20, lr=0.2, r0=random_orthogonal(d, seed))
    assert orthogonality_error(r) < 1e-4


def test_loss_decreases_on_laplace():
    X = jnp.asarray(np.random.default_rng(0).laplace(size=(2048, 64)), jnp.float32)
    _, losses = run_steps(X, 64, 60)
    assert losses[-1] < losses[0] * 0.75


def test_learned_beats_random_hadamard():
    """Paper Table 1 mechanism: KurTail's learned rotation reaches lower
    kurtosis distance than QuaRot's random Hadamard."""
    X = jnp.asarray(np.random.default_rng(1).laplace(size=(2048, 64)), jnp.float32)
    _, losses = run_steps(X, 64, 100)
    had = float(kurtail_loss_ref(fwht_ref(X)))
    assert losses[-1] < had


def test_identity_rotation_is_stationary_on_uniformish_data():
    """Already-uniform per-token data → tiny gradient, R stays near I."""
    X = jnp.asarray(np.random.default_rng(2).uniform(-1, 1, size=(2048, 64)), jnp.float32)
    r, losses = run_steps(X, 64, 10, lr=0.05)
    assert losses[0] < 0.2
    assert np.max(np.abs(r - np.eye(64))) < 0.15


def test_outlier_channel_gets_mixed_away():
    """A synthetic outlier channel (the Fig. 2 setting): after optimization
    the per-token max shrinks for almost all tokens (Table 1 success rate)."""
    rng = np.random.default_rng(3)
    X = rng.normal(size=(2048, 64)).astype(np.float32)
    X[:, 5] *= 25.0
    r, _ = run_steps(jnp.asarray(X), 64, 80)
    Xr = X @ r
    success = np.mean(np.max(np.abs(Xr), -1) < np.max(np.abs(X), -1))
    assert success > 0.95


def test_newton_schulz_restores_orthogonality():
    r = np.asarray(random_orthogonal(32, 0)) + 0.01 * np.random.default_rng(0).normal(size=(32, 32)).astype(np.float32)
    r2 = np.asarray(cayley._newton_schulz(jnp.asarray(r)))
    assert orthogonality_error(r2) < orthogonality_error(r)
