"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/seeds; assert_allclose against ref.py. This is the
core correctness signal for everything the Rust hot path executes.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import fwht, kurtosis, quant_matmul
from compile.kernels import ref

hypothesis.settings.register_profile(
    "kernels", deadline=None, max_examples=12, derandomize=True
)
hypothesis.settings.load_profile("kernels")


def rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------- quant_matmul


@given(
    m=st.sampled_from([1, 7, 32, 129]),
    k=st.sampled_from([16, 64, 96]),
    n=st.sampled_from([8, 48, 160]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quant_matmul_matches_ref(m, k, n, seed):
    r = rng(seed)
    x = r.normal(size=(m, k)).astype(np.float32)
    w = r.normal(size=(k, n)).astype(np.float32)
    got = np.asarray(quant_matmul(jnp.asarray(x), jnp.asarray(w)))
    want = np.asarray(ref.quant_matmul_ref(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@given(bits=st.sampled_from([2, 3, 4, 8]), seed=st.integers(0, 2**31 - 1))
def test_quant_matmul_bits_sweep(bits, seed):
    r = rng(seed)
    x = r.normal(size=(24, 32)).astype(np.float32)
    w = r.normal(size=(32, 16)).astype(np.float32)
    got = np.asarray(quant_matmul(jnp.asarray(x), jnp.asarray(w), bits=bits))
    want = np.asarray(ref.quant_matmul_ref(jnp.asarray(x), jnp.asarray(w), bits=bits))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_quant_matmul_no_clip():
    r = rng(0)
    x = r.normal(size=(16, 32)).astype(np.float32)
    w = r.normal(size=(32, 16)).astype(np.float32)
    got = np.asarray(quant_matmul(jnp.asarray(x), jnp.asarray(w), clip_quantile=None))
    want = np.asarray(ref.quant_matmul_ref(jnp.asarray(x), jnp.asarray(w), clip_quantile=None))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_quant_matmul_batched_input():
    r = rng(1)
    x = r.normal(size=(2, 5, 32)).astype(np.float32)
    w = r.normal(size=(32, 24)).astype(np.float32)
    got = np.asarray(quant_matmul(jnp.asarray(x), jnp.asarray(w)))
    assert got.shape == (2, 5, 24)
    want = np.asarray(ref.quant_matmul_ref(jnp.asarray(x).reshape(-1, 32), jnp.asarray(w)))
    np.testing.assert_allclose(got.reshape(-1, 24), want, rtol=2e-4, atol=2e-4)


def test_quant_matmul_outlier_row_saturates_not_explodes():
    """A row with one huge outlier must still round-trip the bulk values:
    the 0.98 quantile clip keeps the step size set by the bulk."""
    x = np.ones((1, 100), dtype=np.float32) * 0.5
    x[0, 0] = 1000.0
    w = np.eye(100, dtype=np.float32)
    y = np.asarray(quant_matmul(jnp.asarray(x), jnp.asarray(w)))
    # bulk entries recovered within one quantization step of the clipped scale
    assert abs(y[0, 50] - 0.5) < 0.15
    # outlier saturates at roughly clip-quantile * qmax steps, far below 1000
    assert y[0, 0] < 20.0


def test_quant_matmul_block_sizes_equivalent():
    r = rng(2)
    x = r.normal(size=(64, 32)).astype(np.float32)
    w = r.normal(size=(32, 64)).astype(np.float32)
    a = np.asarray(quant_matmul(jnp.asarray(x), jnp.asarray(w), block_m=16, block_n=16))
    b = np.asarray(quant_matmul(jnp.asarray(x), jnp.asarray(w), block_m=64, block_n=64))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------- fwht


@given(
    m=st.sampled_from([1, 3, 32, 100]),
    logn=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_fwht_matches_matrix(m, logn, seed):
    n = 2**logn
    x = rng(seed).normal(size=(m, n)).astype(np.float32)
    got = np.asarray(fwht(jnp.asarray(x)))
    want = np.asarray(ref.fwht_ref(jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_fwht_is_involution():
    """H/sqrt(n) is orthogonal and symmetric → applying twice is identity."""
    x = rng(3).normal(size=(17, 64)).astype(np.float32)
    y = np.asarray(fwht(fwht(jnp.asarray(x))))
    np.testing.assert_allclose(y, x, rtol=1e-4, atol=1e-4)


def test_fwht_preserves_norm():
    x = rng(4).normal(size=(9, 128)).astype(np.float32)
    y = np.asarray(fwht(jnp.asarray(x)))
    np.testing.assert_allclose(
        np.linalg.norm(y, axis=-1), np.linalg.norm(x, axis=-1), rtol=1e-4
    )


def test_fwht_rejects_non_power_of_two():
    with pytest.raises(AssertionError):
        fwht(jnp.ones((4, 12)))


def test_fwht_flattens_outlier():
    """A one-hot (extreme outlier channel) becomes perfectly flat — the
    mechanism by which Hadamard rotations kill activation outliers."""
    x = np.zeros((1, 64), dtype=np.float32)
    x[0, 17] = 8.0
    y = np.asarray(fwht(jnp.asarray(x)))
    assert np.allclose(np.abs(y), 1.0)


# ------------------------------------------------------------------ kurtosis


@given(
    m=st.sampled_from([1, 5, 64, 300]),
    d=st.sampled_from([16, 64, 257]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kurtosis_matches_ref(m, d, seed):
    x = rng(seed).normal(size=(m, d)).astype(np.float32)
    got = np.asarray(kurtosis(jnp.asarray(x)))
    want = np.asarray(ref.kurtosis_ref(jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_kurtosis_known_distributions():
    r = rng(7)
    d = 16384
    gauss = r.normal(size=(1, d)).astype(np.float32)
    unif = r.uniform(-1, 1, size=(1, d)).astype(np.float32)
    lap = r.laplace(size=(1, d)).astype(np.float32)
    kg = float(kurtosis(jnp.asarray(gauss))[0])
    ku = float(kurtosis(jnp.asarray(unif))[0])
    kl = float(kurtosis(jnp.asarray(lap))[0])
    assert abs(kg - 3.0) < 0.3
    assert abs(ku - 1.8) < 0.15
    assert abs(kl - 6.0) < 1.2
    assert ku < kg < kl  # uniform < normal < laplace ordering


def test_kurtosis_batched_shape():
    x = rng(8).normal(size=(2, 3, 32)).astype(np.float32)
    assert kurtosis(jnp.asarray(x)).shape == (2, 3)


def test_kurtail_loss_zero_only_near_uniform():
    r = rng(9)
    unif = r.uniform(-1, 1, size=(64, 4096)).astype(np.float32)
    lap = r.laplace(size=(64, 4096)).astype(np.float32)
    lu = float(ref.kurtail_loss_ref(jnp.asarray(unif)))
    ll = float(ref.kurtail_loss_ref(jnp.asarray(lap)))
    assert lu < 0.15
    assert ll > 2.0
