"""L2 model family: shapes, invariances, training, decode-cache parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import quant as Q
from compile.rotations import hadamard_matrix, random_hadamard, random_orthogonal


def toks(cfg, b=2, t=16, seed=0):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.integers(0, cfg.vocab, (b, t)), jnp.int32)


@pytest.fixture(scope="module", params=["tiny", "phi", "moe"])
def cfg_params(request):
    cfg = M.PRESETS[request.param]
    return cfg, M.init_params(cfg, 0)


def test_forward_shapes(cfg_params):
    cfg, p = cfg_params
    lg = M.forward(cfg, p, toks(cfg))
    assert lg.shape == (2, 16, cfg.vocab)
    assert np.isfinite(np.asarray(lg)).all()


def test_param_specs_cover_params(cfg_params):
    cfg, p = cfg_params
    specs = M.param_specs(cfg)
    assert set(n for n, _ in specs) == set(p.keys())
    for n, s in specs:
        assert p[n].shape == s, n


def test_quant_forward_close_to_fp(cfg_params):
    """4-bit sim perturbs but does not destroy the logits of a random-init
    model (the gap is what the pipeline measures on trained models)."""
    cfg, p = cfg_params
    t = toks(cfg)
    fp = M.forward(cfg, p, t)
    qt = M.forward(cfg, p, t, q=Q.QuantConfig(use_pallas=False))
    rel = float(jnp.mean(jnp.abs(fp - qt)) / (jnp.mean(jnp.abs(fp)) + 1e-9))
    assert rel < 1.0


def test_online_rotations_identity_noop(cfg_params):
    """Identity R3/R4/R5 must not change the quantized forward."""
    cfg, p = cfg_params
    t = toks(cfg)
    q = Q.QuantConfig(use_pallas=False)
    a = M.forward(cfg, p, t, q=q)
    b = M.forward(cfg, p, t, q=q,
                  r3=jnp.eye(cfg.d_head), r4=jnp.eye(cfg.d_head), r5=jnp.eye(cfg.d_ff))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_r3_cancels_in_fp_attention():
    """R3 rotates Q and K identically → fp logits unchanged (QᵀR3ᵀR3K = QᵀK)."""
    cfg = M.PRESETS["tiny"]
    p = M.init_params(cfg, 0)
    t = toks(cfg)
    r3 = jnp.asarray(random_hadamard(cfg.d_head, 7))
    a = M.forward(cfg, p, t)
    b = M.forward(cfg, p, t, r3=r3)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def _fold_norms(cfg, p):
    """Fold RMSNorm γ into the adjacent linears (γ → 1)."""
    q = dict(p)
    for nm, targets in (("ln1", ["wq", "wk", "wv"]), ("ln2", ["wg", "wu"] if cfg.arch == "llama" else ["wu"])):
        g = q[nm]  # (L, d)
        for t in targets:
            q[t] = q[t] * g[:, :, None]
        q[nm] = jnp.ones_like(g)
    q["head"] = q["head"] * q["lnf"][None, :]
    q["lnf"] = jnp.ones_like(q["lnf"])
    return q


def test_rmsnorm_fold_preserves_fp_forward():
    cfg = M.PRESETS["tiny"]
    p = M.init_params(cfg, 42)
    # make norms non-trivial
    p = dict(p)
    key = np.random.default_rng(1)
    p["ln1"] = jnp.asarray(1.0 + 0.3 * key.normal(size=p["ln1"].shape), jnp.float32)
    p["ln2"] = jnp.asarray(1.0 + 0.3 * key.normal(size=p["ln2"].shape), jnp.float32)
    p["lnf"] = jnp.asarray(1.0 + 0.3 * key.normal(size=p["lnf"].shape), jnp.float32)
    t = toks(cfg)
    a = M.forward(cfg, p, t)
    b = M.forward(cfg, _fold_norms(cfg, p), t)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-3)


def _fuse_r1(cfg, p, r1):
    """Fuse the residual-stream rotation R1 into a norm-folded param set.

    This mirrors rust/src/rotation/fusion.rs and is the computational-
    invariance theorem in executable form.
    """
    q = dict(p)
    q["embed"] = p["embed"] @ r1
    q["head"] = p["head"] @ r1
    for w in ("wq", "wk", "wv"):
        q[w] = jnp.einsum("ij,ljk->lik", r1.T, p[w])
    q["wo"] = jnp.einsum("lij,jk->lik", p["wo"], r1)
    if cfg.arch == "llama":
        for w in ("wg", "wu"):
            q[w] = jnp.einsum("ij,ljk->lik", r1.T, p[w])
        q["wd"] = jnp.einsum("lij,jk->lik", p["wd"], r1)
    return q


def test_r1_fusion_is_invariant_in_fp():
    """QuaRot/SliceGPT computational invariance: fp forward identical after
    fusing any orthogonal R1 (norms pre-folded, tied head absorbs R1 via
    embed)."""
    cfg = M.PRESETS["tiny"]
    p = _fold_norms(cfg, M.init_params(cfg, 3))
    r1 = jnp.asarray(random_orthogonal(cfg.d_model, 11))
    t = toks(cfg)
    a = M.forward(cfg, p, t)
    b = M.forward(cfg, _fuse_r1(cfg, p, r1), t)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-3, atol=5e-3)


def test_nll_mask_semantics():
    cfg = M.PRESETS["tiny"]
    p = M.init_params(cfg, 0)
    t = toks(cfg, 2, 12)
    full = jnp.ones((2, 12), jnp.float32)
    half = full.at[:, 6:].set(0.0)
    n_full, c_full = M.nll_per_seq(cfg, p, t, full)
    n_half, c_half = M.nll_per_seq(cfg, p, t, half)
    assert float(c_full[0]) == 11.0 and float(c_half[0]) == 5.0
    assert np.all(np.asarray(n_half) <= np.asarray(n_full) + 1e-5)


def test_train_step_learns_repetition():
    """A few Adam steps on a repetitive sequence should drop NLL sharply."""
    cfg = M.PRESETS["tiny"]
    p = M.init_params(cfg, 0)
    t = jnp.tile(jnp.asarray([[3, 7, 3, 7, 3, 7, 3, 7]], jnp.int32), (4, 4))
    m = {k: jnp.zeros_like(v) for k, v in p.items()}
    v = {k: jnp.zeros_like(x) for k, x in p.items()}
    step = jax.jit(lambda p, m, v, lr, tt: M.adam_train_step(cfg, p, m, v, t, lr, tt))
    losses = []
    for i in range(20):
        p, m, v, loss = step(p, m, v, jnp.float32(3e-3), jnp.float32(i + 1))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.5


def test_decode_matches_full_forward_fp():
    cfg = M.PRESETS["tiny"]
    p = M.init_params(cfg, 0)
    t = toks(cfg, 2, 6)
    kc = jnp.zeros((cfg.n_layers, 2, 16, cfg.n_heads, cfg.d_head))
    vc = jnp.zeros_like(kc)
    for i in range(6):
        lg, kc, vc = M.decode_step(cfg, p, kc, vc, t[:, i], jnp.int32(i))
    full = M.forward(cfg, p, t)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, -1]), rtol=1e-4, atol=1e-4)


def test_layer_fwd_cap_chains_to_full_forward():
    cfg = M.PRESETS["tiny"]
    p = M.init_params(cfg, 0)
    t = toks(cfg)
    x = M.embed_fwd(cfg, p["embed"], t)
    names = [n for n, _ in M.param_specs(cfg) if n not in M.NON_LAYER_PARAMS]
    caps = []
    for l in range(cfg.n_layers):
        lp = {n: p[n][l] for n in names}
        x, ffn_in, vh, ao, fm = M.layer_fwd_cap(cfg, lp, x)
        caps.append((ffn_in, vh, ao, fm))
    nll, cnt = M.final_nll_from_hidden(cfg, x, p["lnf"], p["head"], t, jnp.ones(t.shape, jnp.float32))
    nll2, cnt2 = M.nll_per_seq(cfg, p, t, jnp.ones(t.shape, jnp.float32))
    np.testing.assert_allclose(np.asarray(nll), np.asarray(nll2), rtol=1e-4)
    assert caps[0][1].shape == (2, 16, cfg.n_heads, cfg.d_head)


def test_moe_router_selects_top_k():
    cfg = M.PRESETS["moe"]
    p = M.init_params(cfg, 0)
    lg = M.forward(cfg, p, toks(cfg))
    assert np.isfinite(np.asarray(lg)).all()


def test_pallas_and_ref_quant_paths_agree():
    cfg = M.PRESETS["tiny"]
    p = M.init_params(cfg, 0)
    t = toks(cfg, 2, 8)
    a = M.forward(cfg, p, t, q=Q.QuantConfig(use_pallas=False))
    b = M.forward(cfg, p, t, q=Q.QuantConfig(use_pallas=True))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-3)
