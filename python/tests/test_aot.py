"""AOT artifact sanity: lowered HLO must be loadable by the Rust side.

The Rust runtime uses xla_extension 0.5.1's HLO-*text* parser, which
predates several modern HLO ops and rejects every custom-call target jax
might emit (LAPACK, Mosaic, …). These tests lower a representative set of
graphs and assert the text contains none of the known-unparseable
constructs — catching regressions at pytest time instead of deep inside a
Rust integration run.
"""

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M

# Constructs the 0.5.1 HLO text parser (or its executor) cannot handle.
FORBIDDEN = [
    " topk(",        # jax.lax.top_k → HLO topk op (attribute `largest`)
    "custom-call",   # LAPACK/Mosaic/etc custom calls don't exist in PJRT-CPU-0.5.1
    " cholesky(",    # decomposition ops lower to custom calls downstream
    " triangular-solve(",
]


def lower_text(fn, *args):
    return aot.to_hlo_text(jax.jit(fn).lower(*args))


def check(text, name):
    low = text.lower()
    for bad in FORBIDDEN:
        assert bad not in low, f"{name}: forbidden construct '{bad.strip()}'"


@pytest.mark.parametrize("cname", ["tiny", "moe", "phi"])
def test_fwd_graphs_are_parseable(cname):
    cfg = M.PRESETS[cname]
    fn, args, _ = aot.build_fwd_nll(cfg, quant=False)
    check(lower_text(fn, *[a.sds() for a in args]), f"fwd_nll_{cname}")
    fnq, argsq, _ = aot.build_fwd_nll(cfg, quant=True)
    check(lower_text(fnq, *[a.sds() for a in argsq]), f"fwd_nll_quant_{cname}")


def test_train_and_spin_graphs_are_parseable():
    cfg = M.PRESETS["moe"]  # moe is the arch that once used top_k
    fn, args, _ = aot.build_train_step(cfg)
    check(lower_text(fn, *[a.sds() for a in args]), "train_step_moe")
    fn, args, _ = aot.build_spinquant_step(cfg)
    check(lower_text(fn, *[a.sds() for a in args]), "spinquant_step_moe")


def test_kurtail_step_is_parseable():
    fn, args, _ = aot.build_kurtail_step(64)
    check(lower_text(fn, *[a.sds() for a in args]), "kurtail_step_d64")


def test_decode_step_is_parseable():
    cfg = M.PRESETS["tiny"]
    fn, args, _ = aot.build_decode_step(cfg, quant=True)
    check(lower_text(fn, *[a.sds() for a in args]), "decode_step_quant_tiny")


def test_moe_argmax_routing_matches_topk_semantics():
    """The hand-rolled top-2 must select the same experts as lax.top_k."""
    import numpy as np

    cfg = M.PRESETS["moe"]
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(3, 5, cfg.n_experts)), jnp.float32)

    # reference via top_k
    topv, topi = jax.lax.top_k(logits, cfg.top_k)
    gate_ref = jax.nn.softmax(topv, axis=-1)
    e = jnp.arange(cfg.n_experts)
    sel = (topi[..., None] == e).astype(jnp.float32)
    w_ref = jnp.einsum("btk,btke->bte", gate_ref, sel)

    # hand-rolled (same code path as model.ffn moe branch)
    masked = logits
    onehots, gates = [], []
    for _ in range(cfg.top_k):
        idx = jnp.argmax(masked, axis=-1)
        oh = jax.nn.one_hot(idx, cfg.n_experts, dtype=logits.dtype)
        onehots.append(oh)
        gates.append(jnp.sum(logits * oh, axis=-1))
        masked = masked - oh * 1e9
    gate = jax.nn.softmax(jnp.stack(gates, axis=-1), axis=-1)
    sel2 = jnp.stack(onehots, axis=2)
    w = jnp.einsum("btk,btke->bte", gate, sel2)

    np.testing.assert_allclose(np.asarray(w), np.asarray(w_ref), rtol=1e-5, atol=1e-6)


def test_manifest_matches_param_specs():
    cfg = M.PRESETS["tiny"]
    meta = aot.config_meta(cfg)
    names = [p["name"] for p in meta["param_specs"]]
    assert names == [n for n, _ in M.param_specs(cfg)]
    assert meta["d_head"] == cfg.d_head
