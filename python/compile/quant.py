"""L2 quantization simulation (fake-quant) used inside the AOT model graphs.

Paper setup (§4):
  * activations — per-token dynamic symmetric 4-bit, quantile clip 0.98
  * KV cache    — per-token asymmetric 4-bit
  * weights     — per-channel symmetric (RTN/GPTQ), done OFFLINE in Rust;
                  the graphs receive already-fake-quantized weights.

IMPORTANT CONSTRAINT for everything in this module: it must lower to plain
HLO ops (no jnp.linalg / LAPACK custom calls) so the Rust PJRT CPU client
(xla_extension 0.5.1) can execute the artifacts.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .kernels import quant_matmul as _pallas_quant_matmul
from .kernels import ref


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Static quantization configuration baked into an artifact at lowering."""

    a_bits: int = 4              # activation bits (per-token symmetric)
    kv_bits: int = 4             # KV-cache bits (per-token asymmetric)
    clip_quantile: float = 0.98  # activation dynamic-range clip
    use_pallas: bool = True      # quantized matmuls through the L1 kernel

    @property
    def enabled(self) -> bool:
        return True


#: sentinel for full-precision graphs
FP = None


def act_matmul(x: jnp.ndarray, w: jnp.ndarray, q: QuantConfig | None) -> jnp.ndarray:
    """Linear layer input-quantized matmul: fq(x) @ w (or plain x @ w)."""
    if q is None:
        return x @ w
    if q.use_pallas:
        return _pallas_quant_matmul(x, w, bits=q.a_bits, clip_quantile=q.clip_quantile)
    return ref.quant_matmul_ref(x, w, bits=q.a_bits, clip_quantile=q.clip_quantile)


def act_fake_quant(x: jnp.ndarray, q: QuantConfig | None) -> jnp.ndarray:
    """Standalone per-token symmetric activation fake-quant."""
    if q is None:
        return x
    return ref.fake_quant_sym(x, q.a_bits, q.clip_quantile)


def kv_fake_quant(x: jnp.ndarray, q: QuantConfig | None) -> jnp.ndarray:
    """Per-token asymmetric KV-cache fake-quant (last axis = head dim)."""
    if q is None:
        return x
    return ref.fake_quant_asym(x, q.kv_bits)


def ste(x: jnp.ndarray, fq_of_sg_x: jnp.ndarray) -> jnp.ndarray:
    """Straight-through estimator: forward fq(x), backward identity.

    Callers must compute ``fq_of_sg_x`` on ``stop_gradient(x)`` — this both
    implements the STE and keeps tangents out of the sort/round ops (the
    sort jvp is unavailable in this jaxlib). Used only by the
    SpinQuant-lite training step.
    """
    return x + jax.lax.stop_gradient(fq_of_sg_x) - jax.lax.stop_gradient(x)


def act_fake_quant_ste(x: jnp.ndarray, q: QuantConfig | None) -> jnp.ndarray:
    if q is None:
        return x
    sg = jax.lax.stop_gradient(x)
    return ste(x, ref.fake_quant_sym(sg, q.a_bits, q.clip_quantile))


def kv_fake_quant_ste(x: jnp.ndarray, q: QuantConfig | None) -> jnp.ndarray:
    if q is None:
        return x
    sg = jax.lax.stop_gradient(x)
    return ste(x, ref.fake_quant_asym(sg, q.kv_bits))
