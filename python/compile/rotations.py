"""Build-time rotation utilities (numpy-side; never lowered into artifacts).

The Rust coordinator owns rotation *construction and fusion* at runtime; the
functions here exist for python-side tests (rotation-invariance of the fp
model, Cayley step orthogonality) and for generating golden files the Rust
tests compare against.
"""

from __future__ import annotations

import numpy as np


def hadamard_matrix(n: int) -> np.ndarray:
    """Normalized Sylvester Hadamard matrix (n must be a power of two)."""
    assert n & (n - 1) == 0 and n > 0
    h = np.ones((1, 1), dtype=np.float64)
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    return (h / np.sqrt(n)).astype(np.float32)


def random_hadamard(n: int, seed: int) -> np.ndarray:
    """QuaRot-style random Hadamard rotation: H · diag(±1) with random signs."""
    rng = np.random.default_rng(seed)
    signs = rng.choice([-1.0, 1.0], size=n).astype(np.float32)
    return hadamard_matrix(n) * signs[None, :]


def random_orthogonal(n: int, seed: int) -> np.ndarray:
    """Haar-ish random orthogonal matrix via QR (build-time numpy only)."""
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(n, n))
    q, r = np.linalg.qr(a)
    q *= np.sign(np.diag(r))[None, :]
    return q.astype(np.float32)


def orthogonality_error(r: np.ndarray) -> float:
    """max |RᵀR − I| — used by tests to bound Cayley-retraction drift."""
    n = r.shape[0]
    return float(np.max(np.abs(r.T @ r - np.eye(n, dtype=r.dtype))))
