"""AOT lowering: JAX graphs → HLO *text* artifacts + manifest.json.

Interchange is HLO text, NOT serialized HloModuleProto: jax ≥ 0.5 emits
protos with 64-bit instruction ids which the Rust side's xla_extension
0.5.1 rejects; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Run once at build time (``make artifacts``):

    cd python && python -m compile.aot --out ../artifacts [--configs tiny,small]

Outputs one ``<name>.hlo.txt`` per artifact plus ``manifest.json`` with the
exact input/output signatures (the ABI the Rust runtime checks at load).
All graphs are lowered with ``return_tuple=True`` → every output is a tuple,
unwrapped with ``Literal::to_tuple`` on the Rust side.
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import os
import sys
import time
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import quant as Q
from .cayley import make_kurtail_step

F32, I32 = "f32", "i32"
_DT = {F32: jnp.float32, I32: jnp.int32}

KURTAIL_ROWS = 4096      # activation rows per kurtail_step batch
SPIN_BATCH = 2           # sequences per spinquant_step (end-to-end grad!)
DECODE_BATCH = 4


@dataclasses.dataclass
class Arg:
    name: str
    shape: Tuple[int, ...]
    dtype: str = F32

    def sds(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, _DT[self.dtype])

    def js(self) -> dict:
        return {"name": self.name, "shape": list(self.shape), "dtype": self.dtype}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ----------------------------------------------------------- signatures


def param_args(cfg: M.ModelConfig, prefix: str = "") -> List[Arg]:
    return [Arg(prefix + n, s) for n, s in M.param_specs(cfg)]


def layer_param_args(cfg: M.ModelConfig) -> List[Arg]:
    """Single-layer (unstacked) slices — leading L axis dropped."""
    out = []
    for n, s in M.param_specs(cfg):
        if n in M.NON_LAYER_PARAMS:
            continue
        out.append(Arg(n, tuple(s[1:])))
    return out


def _params_from_flat(cfg: M.ModelConfig, flat: Sequence[jnp.ndarray]) -> M.Params:
    names = [n for n, _ in M.param_specs(cfg)]
    return dict(zip(names, flat))


# ------------------------------------------------------- artifact builders


def build_train_step(cfg: M.ModelConfig):
    n_p = len(M.param_specs(cfg))
    b, t = cfg.train_batch, cfg.seq_len
    args = (param_args(cfg) + [Arg("m_" + a.name, a.shape) for a in param_args(cfg)]
            + [Arg("v_" + a.name, a.shape) for a in param_args(cfg)]
            + [Arg("tokens", (b, t), I32), Arg("lr", ()), Arg("step", ())])

    def fn(*flat):
        p = _params_from_flat(cfg, flat[:n_p])
        m = _params_from_flat(cfg, flat[n_p:2 * n_p])
        v = _params_from_flat(cfg, flat[2 * n_p:3 * n_p])
        tokens, lr, step = flat[3 * n_p:]
        np_, nm, nv, loss = M.adam_train_step(cfg, p, m, v, tokens, lr, step)
        names = [n for n, _ in M.param_specs(cfg)]
        return tuple([np_[k] for k in names] + [nm[k] for k in names]
                     + [nv[k] for k in names] + [loss])

    outs = ([a.js() for a in param_args(cfg)]
            + [{"name": "m_" + a.name, "shape": list(a.shape), "dtype": F32} for a in param_args(cfg)]
            + [{"name": "v_" + a.name, "shape": list(a.shape), "dtype": F32} for a in param_args(cfg)]
            + [{"name": "loss", "shape": [], "dtype": F32}])
    return fn, args, outs


def build_fwd_nll(cfg: M.ModelConfig, quant: bool):
    n_p = len(M.param_specs(cfg))
    b, t = cfg.eval_batch, cfg.seq_len
    dh, ff = cfg.d_head, cfg.d_ff
    args = param_args(cfg)
    if quant:
        args += [Arg("r3", (dh, dh)), Arg("r4", (dh, dh)), Arg("r5", (ff, ff))]
    args += [Arg("tokens", (b, t), I32), Arg("mask", (b, t))]

    def fn(*flat):
        p = _params_from_flat(cfg, flat[:n_p])
        if quant:
            r3, r4, r5, tokens, mask = flat[n_p:]
            qc = Q.QuantConfig(use_pallas=True)
            nll, cnt = M.nll_per_seq(cfg, p, tokens, mask, q=qc, r3=r3, r4=r4, r5=r5)
        else:
            tokens, mask = flat[n_p:]
            nll, cnt = M.nll_per_seq(cfg, p, tokens, mask)
        return nll, cnt

    outs = [{"name": "nll", "shape": [b], "dtype": F32},
            {"name": "cnt", "shape": [b], "dtype": F32}]
    return fn, args, outs


def build_embed(cfg: M.ModelConfig):
    b, t = cfg.cap_batch, cfg.seq_len
    args = [Arg("embed", (cfg.vocab, cfg.d_model)), Arg("tokens", (b, t), I32)]

    def fn(embed, tokens):
        return (M.embed_fwd(cfg, embed, tokens),)

    outs = [{"name": "x0", "shape": [b, t, cfg.d_model], "dtype": F32}]
    return fn, args, outs


def build_layer_fwd_cap(cfg: M.ModelConfig):
    b, t, d = cfg.cap_batch, cfg.seq_len, cfg.d_model
    largs = layer_param_args(cfg)
    args = largs + [Arg("x", (b, t, d))]
    lnames = [a.name for a in largs]

    def fn(*flat):
        lp = dict(zip(lnames, flat[:-1]))
        return M.layer_fwd_cap(cfg, lp, flat[-1])

    ffdim = cfg.d_ff * (cfg.n_experts if cfg.arch == "moe" else 1)
    outs = [
        {"name": "y", "shape": [b, t, d], "dtype": F32},
        {"name": "ffn_in", "shape": [b, t, d], "dtype": F32},
        {"name": "v_heads", "shape": [b, t, cfg.n_heads, cfg.d_head], "dtype": F32},
        {"name": "attn_out", "shape": [b, t, d], "dtype": F32},
        {"name": "ffn_mid", "shape": [b, t, ffdim], "dtype": F32},
    ]
    return fn, args, outs


def build_final_nll(cfg: M.ModelConfig):
    b, t, d = cfg.cap_batch, cfg.seq_len, cfg.d_model
    args = [Arg("x", (b, t, d)), Arg("lnf", (d,)), Arg("head", (cfg.vocab, d)),
            Arg("tokens", (b, t), I32), Arg("mask", (b, t))]

    def fn(x, lnf, head, tokens, mask):
        return M.final_nll_from_hidden(cfg, x, lnf, head, tokens, mask)

    outs = [{"name": "nll", "shape": [b], "dtype": F32},
            {"name": "cnt", "shape": [b], "dtype": F32}]
    return fn, args, outs


def build_kurtail_step(d: int):
    args = [Arg("r", (d, d)), Arg("m", (d, d)), Arg("v", ()),
            Arg("x", (KURTAIL_ROWS, d)), Arg("lr", ()), Arg("t", ())]
    step = make_kurtail_step(d)

    def fn(r, m, v, x, lr, t):
        return step(r, m, v, x, lr, t)

    outs = [{"name": "r", "shape": [d, d], "dtype": F32},
            {"name": "m", "shape": [d, d], "dtype": F32},
            {"name": "v", "shape": [], "dtype": F32},
            {"name": "loss", "shape": [], "dtype": F32}]
    return fn, args, outs


def build_spinquant_step(cfg: M.ModelConfig):
    n_p = len(M.param_specs(cfg))
    b, t, d = SPIN_BATCH, cfg.seq_len, cfg.d_model
    args = (param_args(cfg)
            + [Arg("r1", (d, d)), Arg("m", (d, d)), Arg("v", ()),
               Arg("tokens", (b, t), I32), Arg("lr", ()), Arg("t", ())])

    def fn(*flat):
        p = _params_from_flat(cfg, flat[:n_p])
        r1, m, v, tokens, lr, tt = flat[n_p:]
        return M.spinquant_step(cfg, p, r1, m, v, tokens, lr, tt)

    outs = [{"name": "r1", "shape": [d, d], "dtype": F32},
            {"name": "m", "shape": [d, d], "dtype": F32},
            {"name": "v", "shape": [], "dtype": F32},
            {"name": "loss", "shape": [], "dtype": F32}]
    return fn, args, outs


def build_decode_step(cfg: M.ModelConfig, quant: bool):
    n_p = len(M.param_specs(cfg))
    b, tmax = DECODE_BATCH, cfg.seq_len
    l, h, dh, ff = cfg.n_layers, cfg.n_heads, cfg.d_head, cfg.d_ff
    cache = (l, b, tmax, h, dh)
    args = param_args(cfg)
    if quant:
        args += [Arg("r3", (dh, dh)), Arg("r4", (dh, dh)), Arg("r5", (ff, ff))]
    args += [Arg("k_cache", cache), Arg("v_cache", cache),
             Arg("token", (b,), I32), Arg("pos", (), I32)]

    def fn(*flat):
        p = _params_from_flat(cfg, flat[:n_p])
        rest = flat[n_p:]
        if quant:
            r3, r4, r5, kc, vc, token, pos = rest
            qc = Q.QuantConfig(use_pallas=False)  # decode: tiny mats, jnp path
            return M.decode_step(cfg, p, kc, vc, token, pos, q=qc, r3=r3, r4=r4, r5=r5)
        kc, vc, token, pos = rest
        return M.decode_step(cfg, p, kc, vc, token, pos)

    outs = [{"name": "logits", "shape": [b, cfg.vocab], "dtype": F32},
            {"name": "k_cache", "shape": list(cache), "dtype": F32},
            {"name": "v_cache", "shape": list(cache), "dtype": F32}]
    return fn, args, outs


def build_kernel_bench(kind: str, m: int, k: int, n: int):
    from .kernels import fwht, kurtosis, quant_matmul

    if kind == "quant_matmul":
        args = [Arg("x", (m, k)), Arg("w", (k, n))]

        def fn(x, w):
            return (quant_matmul(x, w),)

        outs = [{"name": "y", "shape": [m, n], "dtype": F32}]
    elif kind == "hadamard":
        args = [Arg("x", (m, k))]

        def fn(x):
            return (fwht(x),)

        outs = [{"name": "y", "shape": [m, k], "dtype": F32}]
    elif kind == "kurtosis":
        args = [Arg("x", (m, k))]

        def fn(x):
            return (kurtosis(x),)

        outs = [{"name": "y", "shape": [m], "dtype": F32}]
    else:
        raise ValueError(kind)
    return fn, args, outs


# ---------------------------------------------------------------- driver


def lower_one(name: str, fn: Callable, args: List[Arg], outs: List[dict],
              out_dir: str, manifest: dict, tag: str) -> None:
    t0 = time.time()
    lowered = jax.jit(fn).lower(*[a.sds() for a in args])
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    manifest["artifacts"][name] = {
        "file": f"{name}.hlo.txt",
        "group": tag,
        "inputs": [a.js() for a in args],
        "outputs": outs,
    }
    print(f"  {name}: {len(text)/1e6:.2f} MB HLO in {time.time()-t0:.1f}s", flush=True)


def config_meta(cfg: M.ModelConfig) -> dict:
    return {
        "name": cfg.name, "vocab": cfg.vocab, "d_model": cfg.d_model,
        "n_layers": cfg.n_layers, "n_heads": cfg.n_heads, "d_head": cfg.d_head,
        "d_ff": cfg.d_ff, "seq_len": cfg.seq_len, "arch": cfg.arch,
        "n_experts": cfg.n_experts, "top_k": cfg.top_k,
        "train_batch": cfg.train_batch, "eval_batch": cfg.eval_batch,
        "cap_batch": cfg.cap_batch, "decode_batch": DECODE_BATCH,
        "spin_batch": SPIN_BATCH,
        "param_specs": [{"name": n, "shape": list(s)} for n, s in M.param_specs(cfg)],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="tiny,small,base,phi,moe")
    ap.add_argument("--skip-kernels", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    names = [c for c in args.configs.split(",") if c]
    manifest: dict = {
        "version": 1,
        "kurtail_rows": KURTAIL_ROWS,
        "configs": {},
        "artifacts": {},
    }

    kurtail_dims = set()
    for cname in names:
        cfg = M.PRESETS[cname]
        manifest["configs"][cname] = config_meta(cfg)
        kurtail_dims.add(cfg.d_model)
        kurtail_dims.add(cfg.d_head)
        print(f"[{cname}] lowering…", flush=True)
        lower_one(f"train_step_{cname}", *build_train_step(cfg), args.out, manifest, cname)
        lower_one(f"fwd_nll_{cname}", *build_fwd_nll(cfg, quant=False), args.out, manifest, cname)
        lower_one(f"fwd_nll_quant_{cname}", *build_fwd_nll(cfg, quant=True), args.out, manifest, cname)
        lower_one(f"embed_{cname}", *build_embed(cfg), args.out, manifest, cname)
        lower_one(f"layer_fwd_cap_{cname}", *build_layer_fwd_cap(cfg), args.out, manifest, cname)
        lower_one(f"final_nll_{cname}", *build_final_nll(cfg), args.out, manifest, cname)
        lower_one(f"spinquant_step_{cname}", *build_spinquant_step(cfg), args.out, manifest, cname)
        lower_one(f"decode_step_{cname}", *build_decode_step(cfg, quant=False), args.out, manifest, cname)
        lower_one(f"decode_step_quant_{cname}", *build_decode_step(cfg, quant=True), args.out, manifest, cname)

    print("[kurtail] lowering…", flush=True)
    for d in sorted(kurtail_dims):
        lower_one(f"kurtail_step_d{d}", *build_kurtail_step(d), args.out, manifest, "kurtail")

    if not args.skip_kernels:
        print("[kernels] lowering…", flush=True)
        for m, k, n in [(256, 128, 128), (512, 256, 256), (1024, 512, 512)]:
            lower_one(f"quant_matmul_{m}x{k}x{n}",
                      *build_kernel_bench("quant_matmul", m, k, n), args.out, manifest, "kernel")
        for m, k in [(1024, 64), (1024, 256), (4096, 512)]:
            lower_one(f"hadamard_{m}x{k}", *build_kernel_bench("hadamard", m, k, 0),
                      args.out, manifest, "kernel")
        for m, k in [(4096, 64), (4096, 256)]:
            lower_one(f"kurtosis_{m}x{k}", *build_kernel_bench("kurtosis", m, k, 0),
                      args.out, manifest, "kernel")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['artifacts'])} artifacts → {args.out}/manifest.json")


if __name__ == "__main__":
    main()
