"""Tiled per-row kurtosis Pallas kernel.

The KurTail objective evaluates κ(token) = m4/m2² for every token in every
Cayley-Adam step over the calibration activations — the inner loop of
rotation learning. This kernel computes the centred second and fourth
moments of each row in a single pass over a (bm, d) VMEM tile: one mean
reduction, then fused square/quartic accumulation on the VPU (no
intermediate (bm, d) temporaries written back to HBM).

Validated against ref.kurtosis_ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kurtosis_kernel(x_ref, o_ref):
    x = x_ref[...]  # (bm, d)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    c = x - mu
    c2 = c * c
    m2 = jnp.mean(c2, axis=-1)
    m4 = jnp.mean(c2 * c2, axis=-1)
    o_ref[...] = m4 / jnp.maximum(m2 * m2, 1e-12)


@functools.partial(jax.jit, static_argnames=("block_m",))
def kurtosis(x: jnp.ndarray, block_m: int = 256) -> jnp.ndarray:
    """Per-row kurtosis over the last axis; leading axes are flattened."""
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    m = x2.shape[0]
    bm = min(block_m, max(8, m))
    pad = (-m) % bm
    if pad:
        # Padding rows are constant-zero → κ = 0/ε, sliced away below.
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        _kurtosis_kernel,
        out_shape=jax.ShapeDtypeStruct((x2.shape[0],), jnp.float32),
        grid=(x2.shape[0] // bm,),
        in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        interpret=True,
    )(x2)
    return out[:m].reshape(x.shape[:-1])
