"""Pure-jnp oracles for the Pallas kernels (L1 correctness references).

Every kernel in this package has a reference implementation here written with
plain ``jax.numpy`` ops only — no pallas, no custom calls — so the pytest
suite can assert the kernels bit-match (up to float tolerance) on CPU.

These functions are also the semantic definition of the quantizers used by
the L2 model (`compile.quant` re-exports them), so the L3 Rust quantizers are
tested against the same oracle numbers via golden files.
"""

from __future__ import annotations

import jax.numpy as jnp


def sym_qmax(bits: int) -> int:
    """Integer grid half-width for symmetric k-bit quantization: 2^(k-1)-1."""
    return 2 ** (bits - 1) - 1


def row_absmax_scale(x: jnp.ndarray, bits: int, clip_quantile: float | None = None) -> jnp.ndarray:
    """Per-row (per-token) symmetric scale.

    ``clip_quantile`` < 1.0 clips the dynamic range at that quantile of |x|
    (paper setup: 0.98 for activations), which trades saturation of the few
    largest values for a finer step everywhere else.
    """
    absx = jnp.abs(x)
    if clip_quantile is not None and clip_quantile < 1.0:
        # Static-index linear interpolation over a per-row sort. Equivalent
        # to jnp.quantile(..., method="linear") but avoids gather ops whose
        # vjp this jaxlib rejects, and static indices lower to plain slices.
        k = absx.shape[-1]
        srt = jnp.sort(absx, axis=-1)
        pos = clip_quantile * (k - 1)
        lo = int(pos)
        hi = min(lo + 1, k - 1)
        frac = pos - lo
        amax = srt[..., lo:lo + 1] * (1.0 - frac) + srt[..., hi:hi + 1] * frac
    else:
        amax = jnp.max(absx, axis=-1, keepdims=True)
    return jnp.maximum(amax, 1e-8) / sym_qmax(bits)


def fake_quant_sym(x: jnp.ndarray, bits: int, clip_quantile: float | None = None,
                   axis: int = -1) -> jnp.ndarray:
    """Symmetric fake-quantization (quantize → dequantize) along ``axis``.

    axis=-1 → per-token (dynamic, activations); other axes are used for
    per-channel weight quantization by moving that axis last.
    """
    if axis != -1:
        x = jnp.moveaxis(x, axis, -1)
    s = row_absmax_scale(x, bits, clip_quantile)
    q = jnp.clip(jnp.round(x / s), -sym_qmax(bits), sym_qmax(bits))
    y = q * s
    if axis != -1:
        y = jnp.moveaxis(y, -1, axis)
    return y


def fake_quant_asym(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Asymmetric (affine) fake-quantization along the last axis.

    Used for KV-cache entries (paper §4): range [min, max] mapped onto
    [0, 2^k - 1].
    """
    lo = jnp.min(x, axis=-1, keepdims=True)
    hi = jnp.max(x, axis=-1, keepdims=True)
    s = jnp.maximum(hi - lo, 1e-8) / (2**bits - 1)
    q = jnp.clip(jnp.round((x - lo) / s), 0, 2**bits - 1)
    return q * s + lo


def quant_matmul_ref(x: jnp.ndarray, w: jnp.ndarray, bits: int = 4,
                     clip_quantile: float | None = 0.98) -> jnp.ndarray:
    """Reference for the fused per-token-quant matmul kernel.

    ``x`` is fake-quantized per row (token) symmetrically, then multiplied by
    ``w`` (which the caller has already weight-quantized offline — RTN/GPTQ
    happen in Rust; here w is used verbatim).
    """
    xq = fake_quant_sym(x, bits, clip_quantile)
    return xq @ w


def hadamard_matrix(n: int) -> jnp.ndarray:
    """Normalized Sylvester Hadamard matrix H_n / sqrt(n); n must be 2^k."""
    assert n & (n - 1) == 0 and n > 0, f"n={n} is not a power of two"
    h = jnp.ones((1, 1), dtype=jnp.float32)
    while h.shape[0] < n:
        h = jnp.block([[h, h], [h, -h]])
    return h / jnp.sqrt(jnp.float32(n))


def fwht_ref(x: jnp.ndarray) -> jnp.ndarray:
    """x @ (H_n / sqrt(n)) along the last axis via explicit matrix."""
    return x @ hadamard_matrix(x.shape[-1])


def kurtosis_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Per-row kurtosis κ = m4 / m2² (centred moments over the last axis).

    κ of N(0,1) → 3, uniform → 1.8 (= 9/5), Laplace → 6. The KurTail loss
    drives per-token activation kurtosis toward 1.8.
    """
    mu = jnp.mean(x, axis=-1, keepdims=True)
    c = x - mu
    m2 = jnp.mean(c * c, axis=-1)
    m4 = jnp.mean((c * c) * (c * c), axis=-1)
    return m4 / jnp.maximum(m2 * m2, 1e-12)


KURTOSIS_UNIFORM = 1.8  # κ_u: kurtosis of the uniform distribution (9/5)


def kurtail_loss_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Mean per-token distance |κ(row) − κ_u| — the KurTail objective."""
    return jnp.mean(jnp.abs(kurtosis_ref(x) - KURTOSIS_UNIFORM))
