"""Blocked fast Walsh–Hadamard transform (FWHT) Pallas kernel.

The online rotations R3/R4/R5 of the paper are random Hadamard transforms
applied on the inference hot path (to Q/K heads after RoPE, to attention
output heads, and to the FFN intermediate). A dense matmul by H_n costs
O(n²) per token; the butterfly FWHT costs O(n log n) and needs no matrix in
memory — this kernel is the TPU analog of QuaRot's warp-shuffle CUDA
Hadamard (DESIGN.md §Hardware-Adaptation): each program holds a (bm, n) tile
in VMEM and performs log2(n) in-register butterfly passes on the VPU.

Output equals ``x @ (H_n / sqrt(n))`` with H_n the Sylvester Hadamard
matrix (validated against ref.fwht_ref).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwht_kernel(x_ref, o_ref, *, n: int):
    x = x_ref[...]  # (bm, n)
    bm = x.shape[0]
    h = 1
    # log2(n) butterfly passes, statically unrolled (n is compile-time).
    while h < n:
        xr = x.reshape(bm, n // (2 * h), 2, h)
        a = xr[:, :, 0, :]
        b = xr[:, :, 1, :]
        x = jnp.stack([a + b, a - b], axis=2).reshape(bm, n)
        h *= 2
    o_ref[...] = x * (1.0 / jnp.sqrt(jnp.float32(n)))


@functools.partial(jax.jit, static_argnames=("block_m",))
def fwht(x: jnp.ndarray, block_m: int = 256) -> jnp.ndarray:
    """Apply the normalized Hadamard transform along the last axis.

    Last axis must be a power of two (all rotated dims in this repo are:
    d_head, d_model, d_ff are chosen as 2^k — see config presets).
    """
    n = x.shape[-1]
    assert n & (n - 1) == 0, f"FWHT dim {n} must be a power of two"
    x2 = x.reshape(-1, n)
    m = x2.shape[0]
    bm = min(block_m, max(8, m))
    pad = (-m) % bm
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_fwht_kernel, n=n),
        out_shape=jax.ShapeDtypeStruct((x2.shape[0], n), jnp.float32),
        grid=(x2.shape[0] // bm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        interpret=True,
    )(x2)
    return out[:m].reshape(x.shape)
