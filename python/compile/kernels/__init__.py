"""L1 Pallas kernels (interpret=True) + pure-jnp oracles.

Import surface used by the L2 model:

    from compile.kernels import quant_matmul, fwht, kurtosis
"""

from .hadamard import fwht
from .kurtosis import kurtosis
from .quant_matmul import quant_matmul

__all__ = ["quant_matmul", "fwht", "kurtosis"]
