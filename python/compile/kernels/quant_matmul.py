"""Fused per-token-quantize + matmul Pallas kernel (the W4A4 hot path).

This is the compute hot-spot of every rotated-and-quantized linear layer in
the paper's inference path: the activation matrix is dynamically quantized
per token (symmetric, k-bit, optional quantile clip — paper §4 uses 4 bits,
clip 0.98) and immediately multiplied by the (offline-quantized) weight.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles M×N output
blocks; each program stages an (bm, K) activation stripe and a (K, bn)
weight stripe in VMEM, computes the per-token scale as a row-local VPU
reduction, quantizes in-register, and feeds the MXU with a single
``jnp.dot``. The CUDA equivalent in QuaRot's kernels does the same staging
with threadblocks/shared memory.

Runs with ``interpret=True`` everywhere in this repo (CPU PJRT); real-TPU
lowering would emit a Mosaic custom call.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import sym_qmax


def _quant_matmul_kernel(x_ref, w_ref, o_ref, *, bits: int, clip_quantile: float | None):
    """One (bm, bn) output tile: per-row quantize x stripe, then MXU matmul."""
    x = x_ref[...]  # (bm, K) — full reduction dim so the row scale is exact
    w = w_ref[...]  # (K, bn)
    absx = jnp.abs(x)
    if clip_quantile is not None and clip_quantile < 1.0:
        # Row-quantile clip: sort each row (VPU) and linearly interpolate
        # at static indices (clip_quantile is compile-time).
        k = absx.shape[-1]
        srt = jnp.sort(absx, axis=-1)
        pos = clip_quantile * (k - 1)
        lo = int(pos)
        hi = min(lo + 1, k - 1)
        frac = pos - lo
        amax = srt[:, lo:lo + 1] * (1.0 - frac) + srt[:, hi:hi + 1] * frac
    else:
        amax = jnp.max(absx, axis=-1, keepdims=True)
    qmax = float(sym_qmax(bits))
    scale = jnp.maximum(amax, 1e-8) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    xq = q * scale
    o_ref[...] = jnp.dot(xq, w, preferred_element_type=jnp.float32)


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("bits", "clip_quantile", "block_m", "block_n"))
def quant_matmul(x: jnp.ndarray, w: jnp.ndarray, bits: int = 4,
                 clip_quantile: float | None = 0.98,
                 block_m: int = 256, block_n: int = 256) -> jnp.ndarray:
    # §Perf: 256×256 tiles quarter the grid size vs 128×128 for the
    # model's matmul shapes while staying ≪ the 16 MiB VMEM budget
    # (vmem_bytes(256,256,512) ≈ 2.6 MiB) — fewer program invocations
    # dominate interpret-mode cost and raise estimated MXU utilization.
    """``fake_quant_sym(x, bits, clip) @ w`` with per-token dynamic scales.

    Accepts ``x`` of shape (..., K) and ``w`` of shape (K, N); leading axes
    are flattened into the token axis M. Tiles are padded up to block
    multiples and the result sliced back, so any M/N work.
    """
    orig_shape = x.shape
    k = x.shape[-1]
    assert w.shape[0] == k, f"inner dims mismatch: {x.shape} @ {w.shape}"
    x2 = x.reshape(-1, k)
    m, n = x2.shape[0], w.shape[1]

    bm = min(block_m, max(8, m))
    bn = min(block_n, max(8, n))
    xp = _pad_to(x2, 0, bm)
    wp = _pad_to(w, 1, bn)
    mp, np_ = xp.shape[0], wp.shape[1]

    out = pl.pallas_call(
        functools.partial(_quant_matmul_kernel, bits=bits, clip_quantile=clip_quantile),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(xp, wp)
    return out[:m, :n].reshape(*orig_shape[:-1], n)


def vmem_bytes(block_m: int, block_n: int, k: int) -> int:
    """Estimated VMEM residency of one program instance (f32 tiles).

    x stripe (bm,K) + |x| working copy + sorted copy + w stripe (K,bn) +
    out tile (bm,bn). Used by the §Perf block-size sweep against the 16 MiB
    TPU VMEM budget.
    """
    f = 4
    return f * (3 * block_m * k + k * block_n + block_m * block_n)
