"""Build-time Python package: L2 JAX model + L1 Pallas kernels + AOT lowering.

Nothing in here runs at inference time — `compile.aot` lowers everything to
HLO text in `artifacts/`, which the Rust coordinator loads via PJRT.
"""
