"""Kurtosis loss + Cayley-Adam step on the Stiefel manifold (L2 graphs).

This is the optimization core of KurTail (paper §3 "Learning the Rotations",
"Optimization in the Orthogonal Space"): rotations are optimized with a
Cayley-transform Adam (Li et al. 2020) so every iterate stays orthogonal,
and the loss is the mean per-token distance of the activation kurtosis to
the uniform distribution's kurtosis κ_u = 1.8.

The whole step is a single AOT artifact (`kurtail_step_d{D}`): the Rust
driver owns the loop — shuffling captured activations, feeding batches,
tracking convergence — and this graph does one (loss, grad, Cayley-Adam
update) step.

Constraints: no jnp.linalg (LAPACK custom calls don't exist in the Rust
PJRT client). The Cayley retraction uses a fixed-point iteration (pure
matmuls) and orthogonality drift is killed with one Newton–Schulz pass
per step (also pure matmuls).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.ref import KURTOSIS_UNIFORM, kurtosis_ref

B1, B2, EPS = 0.9, 0.99, 1e-8  # Adam constants
CAYLEY_ITERS = 2               # fixed-point iterations of the retraction


def kurtail_loss(x: jnp.ndarray, r: jnp.ndarray) -> jnp.ndarray:
    """L = mean_tokens |κ(x_i · R) − κ_u|.

    ``x`` rows are the (already norm-and-γ-scaled) block inputs the Rust
    capture stage stored; per-token kurtosis is the quantity that matters
    for per-token dynamic quantization.
    """
    y = x @ r
    return jnp.mean(jnp.abs(kurtosis_ref(y) - KURTOSIS_UNIFORM))


def _newton_schulz(r: jnp.ndarray) -> jnp.ndarray:
    """One Newton–Schulz orthogonalization pass: R(3I − RᵀR)/2.

    Quadratically contracts ‖RᵀR − I‖ when R is already near-orthogonal —
    exactly the regime after a truncated Cayley retraction.
    """
    d = r.shape[0]
    return 0.5 * r @ (3.0 * jnp.eye(d, dtype=r.dtype) - r.T @ r)


def cayley_adam_step(loss_fn, r, m, v, lr, t):
    """One Cayley-Adam step minimizing ``loss_fn(R)`` over orthogonal R.

    Follows Li et al. 2020 in structure: Adam first moment on the euclidean
    gradient, scalar second moment (gradient norm), skew-symmetric
    projection W = ĜRᵀ − RĜᵀ, then the Cayley retraction
    R' = (I + a W)⁻¹ (I − a W) R, a = lr/2, approximated by fixed-point
    iteration  Y ← R − a·W·(R + Y).

    Args:
      loss_fn: R → scalar loss.
      r: (D, D) current rotation.  m: (D, D) first moment.  v: scalar second
      moment.  lr: scalar learning rate.  t: scalar step count (1-based).
    Returns: (r', m', v', loss).
    """
    loss, g = jax.value_and_grad(loss_fn)(r)
    m = B1 * m + (1.0 - B1) * g
    v = B2 * v + (1.0 - B2) * jnp.sum(g * g)
    mhat = m / (1.0 - B1**t)
    vhat = v / (1.0 - B2**t)
    ghat = mhat / (jnp.sqrt(vhat) + EPS)

    w = ghat @ r.T - r @ ghat.T  # skew-symmetric descent direction
    a = lr / 2.0
    y = r - (2.0 * a) * (w @ r)  # first-order init
    for _ in range(CAYLEY_ITERS):
        y = r - a * (w @ (r + y))
    r_new = _newton_schulz(y)
    return r_new, m, v, loss


def make_kurtail_step(d: int):
    """Build the jittable kurtail_step for dimension ``d``.

    Signature: (r[d,d], m[d,d], v[], x[N,d], lr[], t[]) →
               (r', m', v', loss).
    """

    def step(r, m, v, x, lr, t):
        return cayley_adam_step(lambda rr: kurtail_loss(x, rr), r, m, v, lr, t)

    return step
