"""L2 JAX model family: tiny-LLaMA / Phi-style / MoE transformers.

These are the compute graphs the Rust coordinator executes after AOT
lowering (compile/aot.py → artifacts/*.hlo.txt). Everything here must lower
to plain HLO ops — no jnp.linalg / LAPACK custom calls.

Model family (DESIGN.md §2 substitutions):
  * ``llama`` — RMSNorm, RoPE, SwiGLU, pre-norm residual, untied head.
  * ``phi``   — same attention, GELU MLP without gate (Phi-3 stand-in).
  * ``moe``   — top-2 routed expert SwiGLU FFN (Mixtral stand-in).

Parameters are stacked across layers (``wq[L,d,d]`` …) and the forward
``lax.scan``s over the stack; Rust owns per-layer slicing for rotation
fusion / GPTQ and feeds single-layer slices to the capture graph.

Rotation protocol (paper Fig. 3):
  * R1 (residual stream) and R2 (per-head V) are fused OFFLINE into the
    weights by the Rust coordinator — the graphs never see them.
  * R3/R4/R5 are ONLINE rotations passed as inputs to the quantized graphs;
    identity matrices disable them. Their inverses are pre-fused by Rust
    (R4ᵀ into Wo, R5ᵀ into Wdown; R3 self-cancels in QᵀK).
  * RMSNorm γ must be pre-folded into adjacent weights for the quantized /
    spinquant graphs (pass γ = 1) — rotation invariance of RMSNorm only
    holds for the weightless norm.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import quant as Q
from .cayley import cayley_adam_step

Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------- configs


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int = 256          # byte-level tokenizer
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 256
    seq_len: int = 128
    arch: str = "llama"       # llama | phi | moe
    n_experts: int = 1
    top_k: int = 2
    rope_base: float = 10000.0
    # artifact batch sizes (baked at lowering)
    train_batch: int = 8
    eval_batch: int = 8
    cap_batch: int = 4

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


#: All rotated dims are powers of two so online Hadamard (FWHT) applies.
PRESETS: Dict[str, ModelConfig] = {
    "tiny": ModelConfig("tiny", d_model=64, n_layers=2, n_heads=4, d_ff=128,
                        seq_len=64, train_batch=8, eval_batch=8, cap_batch=4),
    "small": ModelConfig("small", d_model=128, n_layers=4, n_heads=4, d_ff=256,
                         seq_len=128),
    "base": ModelConfig("base", d_model=256, n_layers=6, n_heads=8, d_ff=512,
                        seq_len=128),
    "phi": ModelConfig("phi", d_model=128, n_layers=4, n_heads=4, d_ff=256,
                       seq_len=128, arch="phi"),
    "moe": ModelConfig("moe", d_model=128, n_layers=4, n_heads=4, d_ff=128,
                       seq_len=128, arch="moe", n_experts=4, top_k=2),
}


def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Canonical (name, shape) order — the artifact ABI shared with Rust."""
    L, d, ff, V = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab
    specs: List[Tuple[str, Tuple[int, ...]]] = [
        ("embed", (V, d)),
        ("ln1", (L, d)),
        ("wq", (L, d, d)),
        ("wk", (L, d, d)),
        ("wv", (L, d, d)),
        ("wo", (L, d, d)),
        ("ln2", (L, d)),
    ]
    if cfg.arch == "llama":
        specs += [("wg", (L, d, ff)), ("wu", (L, d, ff)), ("wd", (L, ff, d))]
    elif cfg.arch == "phi":
        specs += [("wu", (L, d, ff)), ("wd", (L, ff, d))]
    elif cfg.arch == "moe":
        E = cfg.n_experts
        specs += [
            ("wr", (L, d, E)),
            ("wg", (L, E, d, ff)),
            ("wu", (L, E, d, ff)),
            ("wd", (L, E, ff, d)),
        ]
    else:
        raise ValueError(cfg.arch)
    specs.append(("lnf", (d,)))
    # Untied output head: required so lnf's γ and R1 can be fused into the
    # head without touching the input embedding (see rotation protocol).
    specs.append(("head", (V, d)))
    return specs


def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    """Scaled-normal init (numpy at build time; Rust mirrors this)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    p: Params = {}
    for name, shape in param_specs(cfg):
        if name.startswith("ln"):
            p[name] = jnp.ones(shape, jnp.float32)
        elif name in ("embed", "head"):
            p[name] = jnp.asarray(rng.normal(0, 0.02, shape), jnp.float32)
        else:
            fan_in = shape[-2]
            std = 1.0 / np.sqrt(fan_in)
            if name in ("wo", "wd"):  # residual-output scaling
                std /= np.sqrt(2.0 * cfg.n_layers)
            p[name] = jnp.asarray(rng.normal(0, std, shape), jnp.float32)
    return p


# ------------------------------------------------------------- primitives


def rmsnorm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gamma


def rope_tables(cfg: ModelConfig, t: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    dh = cfg.d_head
    inv = cfg.rope_base ** (-jnp.arange(0, dh, 2, dtype=jnp.float32) / dh)
    pos = jnp.arange(t, dtype=jnp.float32)
    ang = pos[:, None] * inv[None, :]          # (T, dh/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (B, T, H, dh); cos/sin: (T, dh/2)."""
    x1, x2 = x[..., 0::2], x[..., 1::2]
    c = cos[None, :, None, :]
    s = sin[None, :, None, :]
    y1 = x1 * c - x2 * s
    y2 = x1 * s + x2 * c
    return jnp.stack([y1, y2], axis=-1).reshape(x.shape)


def _silu(x):
    return x * jax.nn.sigmoid(x)


def _gelu(x):
    # tanh approximation — avoids erf availability questions in old PJRT
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608 * (x + 0.044715 * x * x * x)))


# -------------------------------------------------------------- attention


def _heads(x: jnp.ndarray, h: int) -> jnp.ndarray:
    b, t, d = x.shape
    return x.reshape(b, t, h, d // h)


def _unheads(x: jnp.ndarray) -> jnp.ndarray:
    b, t, h, dh = x.shape
    return x.reshape(b, t, h * dh)


def attention(cfg: ModelConfig, lp: Params, x: jnp.ndarray,
              q: Q.QuantConfig | None, r3, r4,
              fq_act, fq_kv) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Multi-head attention block. Returns (output, captures).

    ``fq_act``/``fq_kv`` are the fake-quant functions (STE or plain) so the
    same graph serves eval and spinquant training.
    """
    b, t, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    z = rmsnorm(x, lp["ln1"])

    wqkv = jnp.concatenate([lp["wq"], lp["wk"], lp["wv"]], axis=1)  # (d, 3d)
    if q is not None and q.use_pallas and fq_act is Q.act_fake_quant:
        qkv = Q.act_matmul(z, wqkv, q)
    else:
        qkv = fq_act(z, q) @ wqkv if q is not None else z @ wqkv
    qh = _heads(qkv[..., :d], h)
    kh = _heads(qkv[..., d:2 * d], h)
    vh = _heads(qkv[..., 2 * d:], h)

    cos, sin = rope_tables(cfg, t)
    qh = apply_rope(qh, cos, sin)
    kh = apply_rope(kh, cos, sin)
    if r3 is not None:  # online rotation; cancels in QᵀK, improves K-cache quant
        qh = qh @ r3
        kh = kh @ r3

    # KV-cache quantization (asymmetric per token/head row)
    kh = fq_kv(kh, q)
    vh = fq_kv(vh, q)
    qh = fq_act(qh, q) if q is not None else qh

    scores = jnp.einsum("bihe,bjhe->bhij", qh, kh) / jnp.sqrt(jnp.float32(dh))
    mask = jnp.tril(jnp.ones((t, t), jnp.float32))
    scores = jnp.where(mask[None, None] > 0, scores, -1e9)
    probs = jax.nn.softmax(scores, axis=-1)
    oh = jnp.einsum("bhij,bjhe->bihe", probs, vh)  # (B,T,H,dh)
    if r4 is not None:
        oh = oh @ r4
    attn_out = _unheads(oh)
    if q is not None and q.use_pallas and fq_act is Q.act_fake_quant:
        out = Q.act_matmul(attn_out, lp["wo"], q)
    else:
        out = (fq_act(attn_out, q) if q is not None else attn_out) @ lp["wo"]
    caps = {"v_heads": vh, "attn_out": attn_out}
    return out, caps


# -------------------------------------------------------------------- FFN


def ffn(cfg: ModelConfig, lp: Params, x: jnp.ndarray,
        q: Q.QuantConfig | None, r5, fq_act) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    z = rmsnorm(x, lp["ln2"])
    if cfg.arch == "llama":
        wgu = jnp.concatenate([lp["wg"], lp["wu"]], axis=1)  # (d, 2ff)
        if q is not None and q.use_pallas and fq_act is Q.act_fake_quant:
            gu = Q.act_matmul(z, wgu, q)
        else:
            gu = (fq_act(z, q) if q is not None else z) @ wgu
        g, u = gu[..., : cfg.d_ff], gu[..., cfg.d_ff:]
        mid = _silu(g) * u
        if r5 is not None:
            mid = mid @ r5
        if q is not None and q.use_pallas and fq_act is Q.act_fake_quant:
            out = Q.act_matmul(mid, lp["wd"], q)
        else:
            out = (fq_act(mid, q) if q is not None else mid) @ lp["wd"]
        return out, {"ffn_mid": mid}
    if cfg.arch == "phi":
        if q is not None and q.use_pallas and fq_act is Q.act_fake_quant:
            u = Q.act_matmul(z, lp["wu"], q)
        else:
            u = (fq_act(z, q) if q is not None else z) @ lp["wu"]
        mid = _gelu(u)
        if r5 is not None:
            mid = mid @ r5
        if q is not None and q.use_pallas and fq_act is Q.act_fake_quant:
            out = Q.act_matmul(mid, lp["wd"], q)
        else:
            out = (fq_act(mid, q) if q is not None else mid) @ lp["wd"]
        return out, {"ffn_mid": mid}
    if cfg.arch == "moe":
        # Router in fp (tiny); experts computed densely, gated top-k.
        # NOTE: no jax.lax.top_k here — it lowers to the HLO `topk` op,
        # which the Rust side's HLO-text parser (xla_extension 0.5.1)
        # rejects. Iterated argmax + one_hot lowers to plain reduces.
        logits = z @ lp["wr"]                        # (B,T,E)
        masked = logits
        onehots, gates = [], []
        for _ in range(cfg.top_k):
            idx = jnp.argmax(masked, axis=-1)
            oh = jax.nn.one_hot(idx, cfg.n_experts, dtype=logits.dtype)
            onehots.append(oh)
            gates.append(jnp.sum(logits * oh, axis=-1))
            masked = masked - oh * 1e9
        gate = jax.nn.softmax(jnp.stack(gates, axis=-1), axis=-1)  # (B,T,k)
        sel = jnp.stack(onehots, axis=2)                           # (B,T,k,E)
        weights = jnp.einsum("btk,btke->bte", gate, sel)           # (B,T,E)
        zq = fq_act(z, q) if q is not None else z
        g = jnp.einsum("btd,edf->btef", zq, lp["wg"])
        u = jnp.einsum("btd,edf->btef", zq, lp["wu"])
        mid = _silu(g) * u                           # (B,T,E,ff)
        if r5 is not None:
            mid = mid @ r5
        midq = fq_act(mid, q) if q is not None else mid
        outs = jnp.einsum("btef,efd->bted", midq, lp["wd"])
        out = jnp.einsum("bte,bted->btd", weights, outs)
        return out, {"ffn_mid": mid.reshape(*mid.shape[:2], -1)}
    raise ValueError(cfg.arch)


# ----------------------------------------------------------- full forward


NON_LAYER_PARAMS = ("embed", "lnf", "head")


def _layer_params(cfg: ModelConfig, params: Params) -> Params:
    names = [n for n, _ in param_specs(cfg) if n not in NON_LAYER_PARAMS]
    return {n: params[n] for n in names}


def forward(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
            q: Q.QuantConfig | None = None,
            r3=None, r4=None, r5=None, ste: bool = False) -> jnp.ndarray:
    """Full forward → logits (B, T, V). Tied embedding head (fp)."""
    fq_act = Q.act_fake_quant_ste if ste else Q.act_fake_quant
    fq_kv = Q.kv_fake_quant_ste if ste else Q.kv_fake_quant
    x = params["embed"][tokens]  # (B,T,d)

    layer_stack = _layer_params(cfg, params)

    def body(x, lp):
        a, _ = attention(cfg, lp, x, q, r3, r4, fq_act, fq_kv)
        xh = x + a
        f, _ = ffn(cfg, lp, xh, q, r5, fq_act)
        return xh + f, None

    x, _ = jax.lax.scan(body, x, layer_stack)
    x = rmsnorm(x, params["lnf"])
    return x @ params["head"].T


def nll_per_seq(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
                mask: jnp.ndarray, **kw) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Masked next-token NLL per sequence.

    mask[b, t] weights the prediction of tokens[b, t] from prefix < t
    (mask[:, 0] is ignored). Returns (nll_sum[B], count[B]) — perplexity is
    exp(Σnll/Σcount); option scoring compares nll sums (lm-eval semantics).
    """
    logits = forward(cfg, params, tokens, **kw)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    ll = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    m = mask[:, 1:]
    return -jnp.sum(ll * m, axis=-1), jnp.sum(m, axis=-1)


# ------------------------------------------------------------ train step


def adam_train_step(cfg: ModelConfig, params: Params, m: Params, v: Params,
                    tokens: jnp.ndarray, lr: jnp.ndarray, t: jnp.ndarray):
    """One Adam step on mean next-token NLL (fp graph, for the e2e trainer)."""

    def loss_fn(p):
        logits = forward(cfg, p, tokens)
        logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
        tgt = tokens[:, 1:]
        ll = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        return -jnp.mean(ll)

    loss, g = jax.value_and_grad(loss_fn)(params)
    b1, b2, eps = 0.9, 0.95, 1e-8
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        new_m[k] = b1 * m[k] + (1 - b1) * g[k]
        new_v[k] = b2 * v[k] + (1 - b2) * g[k] * g[k]
        mh = new_m[k] / (1 - b1**t)
        vh = new_v[k] / (1 - b2**t)
        new_p[k] = params[k] - lr * mh / (jnp.sqrt(vh) + eps)
    return new_p, new_m, new_v, loss


# --------------------------------------------------- layer-wise capture


def embed_fwd(cfg: ModelConfig, embed: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    return embed[tokens]


def layer_fwd_cap(cfg: ModelConfig, lp: Params, x: jnp.ndarray):
    """Single-layer fp forward with activation taps (layer-wise inference).

    Returns (y, ffn_in, v_heads, attn_out, ffn_mid):
      * MHSA block input is ``x`` itself (the caller already holds it).
      * ffn_in — residual stream entering the FFN block (pre-norm).
      * v_heads — V activations (B,T,H,dh) for learning R2.
      * attn_out — Wo input (for its GPTQ Hessian).
      * ffn_mid — Wdown input (for its GPTQ Hessian).
    """
    a, caps_a = attention(cfg, lp, x, None, None, None, Q.act_fake_quant, Q.kv_fake_quant)
    xh = x + a
    f, caps_f = ffn(cfg, lp, xh, None, None, Q.act_fake_quant)
    y = xh + f
    return y, xh, caps_a["v_heads"], caps_a["attn_out"], caps_f["ffn_mid"]


def final_nll_from_hidden(cfg: ModelConfig, x: jnp.ndarray, lnf: jnp.ndarray,
                          head: jnp.ndarray, tokens: jnp.ndarray, mask: jnp.ndarray):
    """NLL head for layer-wise evaluation pipelines (x = last hidden)."""
    xf = rmsnorm(x, lnf)
    logits = xf @ head.T
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    ll = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    m = mask[:, 1:]
    return -jnp.sum(ll * m, axis=-1), jnp.sum(m, axis=-1)


# ------------------------------------------------------- spinquant-lite


def spinquant_step(cfg: ModelConfig, params: Params, r1: jnp.ndarray,
                   m: jnp.ndarray, v: jnp.ndarray, tokens: jnp.ndarray,
                   lr: jnp.ndarray, t: jnp.ndarray):
    """SpinQuant-lite: one Cayley-Adam step on end-to-end CE w.r.t. R1.

    The residual stream stays unrotated; every rotated-quantized linear
    input z is replaced by STE(fq(z·R1))·R1ᵀ so quantization noise lives in
    the rotated basis while weights stay fixed. This is the end-to-end-loss
    baseline whose memory cost KurTail's layer-wise training undercuts —
    the whole model + backprop graph must be alive here (paper §3
    "Training Cost").

    Requires γ pre-folded (weightless norms): rmsnorm(x)·R1 == rmsnorm(x·R1).
    """
    q = Q.QuantConfig(use_pallas=False)

    def loss_fn(r):
        def rot_fq(z, qc):
            if qc is None:
                return z
            if z.shape[-1] != r.shape[0]:
                # head-dim / ff-dim activations are not in the R1 basis —
                # plain STE fake-quant there (R3/R4/R5 territory).
                return Q.act_fake_quant_ste(z, qc)
            zr = z @ r
            sg = jax.lax.stop_gradient(zr)
            return Q.ste(zr, Q.act_fake_quant(sg, qc)) @ r.T

        fq_kv = Q.kv_fake_quant_ste
        x = params["embed"][tokens]
        layer_stack = _layer_params(cfg, params)

        def body(x, lp):
            a, _ = attention(cfg, lp, x, q, None, None, rot_fq, fq_kv)
            xh = x + a
            f, _ = ffn(cfg, lp, xh, q, None, rot_fq)
            return xh + f, None

        x, _ = jax.lax.scan(body, x, layer_stack)
        x = rmsnorm(x, params["lnf"])
        logits = x @ params["head"].T
        logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
        tgt = tokens[:, 1:]
        ll = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        return -jnp.mean(ll)

    return cayley_adam_step(loss_fn, r1, m, v, lr, t)


# ------------------------------------------------------------ decode step


def decode_step(cfg: ModelConfig, params: Params,
                k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                token: jnp.ndarray, pos: jnp.ndarray,
                q: Q.QuantConfig | None = None, r3=None, r4=None, r5=None):
    """Single-token autoregressive step with (optionally 4-bit) KV cache.

    k_cache/v_cache: (L, B, Tmax, H, dh) — stored post-rotation, post
    fake-quant (so the cache holds exactly what a real 4-bit cache would
    dequantize to). token: (B,) int32. pos: () int32 — number of tokens
    already in the cache. Returns (logits[B,V], k_cache', v_cache').
    """
    b = token.shape[0]
    h, dh, tmax = cfg.n_heads, cfg.d_head, k_cache.shape[2]
    fq_act, fq_kv = Q.act_fake_quant, Q.kv_fake_quant

    x = params["embed"][token][:, None, :]  # (B,1,d)
    layer_stack = _layer_params(cfg, params)

    cos_t, sin_t = rope_tables(cfg, tmax)
    cos = jax.lax.dynamic_slice_in_dim(cos_t, pos, 1, axis=0)
    sin = jax.lax.dynamic_slice_in_dim(sin_t, pos, 1, axis=0)

    def body(x, scanned):
        lp, kc, vc = scanned
        z = rmsnorm(x, lp["ln1"])
        zq = fq_act(z, q) if q is not None else z
        qh = _heads(zq @ lp["wq"], h)
        kh = _heads(zq @ lp["wk"], h)
        vh = _heads(zq @ lp["wv"], h)
        qh = apply_rope(qh, cos, sin)
        kh = apply_rope(kh, cos, sin)
        if r3 is not None:
            qh, kh = qh @ r3, kh @ r3
        kh = fq_kv(kh, q)
        vh = fq_kv(vh, q)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, kh, pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, vh, pos, axis=1)
        qh = fq_act(qh, q) if q is not None else qh
        scores = jnp.einsum("bihe,bjhe->bhij", qh, kc) / jnp.sqrt(jnp.float32(dh))
        valid = (jnp.arange(tmax) <= pos).astype(jnp.float32)
        scores = jnp.where(valid[None, None, None, :] > 0, scores, -1e9)
        probs = jax.nn.softmax(scores, axis=-1)
        oh = jnp.einsum("bhij,bjhe->bihe", probs, vc)
        if r4 is not None:
            oh = oh @ r4
        ao = _unheads(oh)
        a = (fq_act(ao, q) if q is not None else ao) @ lp["wo"]
        xh = x + a
        f, _ = ffn(cfg, lp, xh, q, r5, fq_act)
        return xh + f, (kc, vc)

    x, (kc_new, vc_new) = jax.lax.scan(body, x, (layer_stack, k_cache, v_cache))
    x = rmsnorm(x, params["lnf"])
    logits = (x @ params["head"].T)[:, 0, :]
    return logits, kc_new, vc_new
