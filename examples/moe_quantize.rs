//! Mixture-of-Experts quantization (paper §5.1 / Table 4): apply one
//! rotation across all experts of a Mixtral-style model and compare RTN
//! 4-bit with and without rotations.
//!
//! ```bash
//! cargo run --release --example moe_quantize
//! ```

use std::sync::Arc;

use kurtail::config::{Method, PipelineConfig, WeightQuantizer};
use kurtail::eval::{evaluate, perplexity};
use kurtail::pipeline::Pipeline;
use kurtail::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("KURTAIL_FAST").is_ok();
    let rt = Arc::new(Runtime::new("artifacts")?);
    let pipe = Pipeline::new(rt, "moe", 0, fast, true)?;
    let meta = &pipe.fp_params.meta;
    println!(
        "[moe] {} experts, top-{} routing, {} params",
        meta.n_experts,
        meta.top_k,
        pipe.fp_params.param_count()
    );

    let n_q = if fast { 12 } else { 50 };
    let n_eval = if fast { 4 } else { 16 };
    println!("{:<12} {:>9} {:>9} {:>7}", "method", "wiki-ppl", "0-shot%", "mmlu%");
    for (method, wq) in [
        (Method::Fp16, WeightQuantizer::None),
        (Method::GptqOnly, WeightQuantizer::Rtn), // paper's "RTN" row
        (Method::QuaRot, WeightQuantizer::Rtn),
        (Method::KurTail, WeightQuantizer::Rtn),
    ] {
        let mut cfg = PipelineConfig::new("moe", method);
        cfg.weight_quantizer = wq;
        if fast {
            cfg.calib.n_samples = 64;
            cfg.calib.iters = 30;
        }
        let (pm, _) = pipe.quantize(&cfg)?;
        let s = evaluate(&pipe, &pm, n_q, n_eval)?;
        let label = if method == Method::GptqOnly { "RTN" } else { method.label() };
        println!(
            "{:<12} {:>9.3} {:>9.1} {:>7.1}",
            label,
            s.wiki_ppl,
            s.zero_shot_avg * 100.0,
            s.mmlu_avg * 100.0
        );
    }

    // sanity: the shared rotation must leave the fp model intact
    let fp = pipe.quantize(&PipelineConfig::new("moe", Method::Fp16))?.0;
    let ppl = perplexity(&pipe.rt, &fp, &pipe.bundle.test, n_eval)?;
    println!("[moe] fp reference ppl {ppl:.3}");
    Ok(())
}
