//! End-to-end driver (DESIGN.md "End-to-end validation"): trains the
//! `small` transformer from scratch for several hundred steps on the
//! synthetic corpus (loss curve logged), then runs the complete KurTail
//! pipeline and the paper's baselines, reporting the headline metrics.
//! All compute goes through the AOT artifacts — Python never runs here.
//!
//! ```bash
//! cargo run --release --example e2e_train_quantize        # full
//! KURTAIL_FAST=1 cargo run --release --example e2e_train_quantize
//! ```

use std::sync::Arc;

use kurtail::calib::DataBundle;
use kurtail::config::{Method, PipelineConfig, WeightQuantizer};
use kurtail::eval::evaluate;
use kurtail::model::{train, Params, TrainConfig};
use kurtail::pipeline::{default_train_config, Pipeline};
use kurtail::runtime::Runtime;
use kurtail::util::Rng;

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("KURTAIL_FAST").is_ok();
    let rt = Arc::new(Runtime::new("artifacts")?);
    let model = "small";
    let meta = rt.manifest.config(model)?.clone();

    // ---- stage 1: pretraining, loss curve logged ------------------------
    let (bytes, tcfg) = default_train_config(model, fast);
    let bundle = DataBundle::new(meta.seq_len, bytes, 0);
    let mut rng = Rng::new(0);
    let mut params = Params::init(&meta, &mut rng);
    println!(
        "[e2e] training {model} ({} params) for {} steps on {} KiB of synthetic corpus",
        params.param_count(),
        tcfg.steps,
        bytes / 1024
    );
    let report =
        train(&rt, &mut params, &bundle.train, &TrainConfig { log_every: 25, ..tcfg }, true)?;
    println!(
        "[e2e] loss curve: start {:.3} → min {:.3} → final {:.3} ({:.1}s, {:.1} steps/s)",
        report.losses[0],
        report.losses.iter().cloned().fold(f32::INFINITY, f32::min),
        report.losses.last().unwrap(),
        report.wall_s,
        report.losses.len() as f64 / report.wall_s
    );

    // persist so the experiment runners share this pretraining
    let snap = rt.dir.join(format!(
        "params_{model}_s{}_n{}_seed0.bin",
        report.losses.len(),
        bundle.train.n_sequences()
    ));
    params.save(&snap)?;

    // ---- stage 2: the full PTQ comparison (paper Table 2 row) -----------
    let pipe = Pipeline::new(rt, model, 0, fast, true)?;
    let n_q = if fast { 12 } else { 50 };
    let n_eval = if fast { 4 } else { 16 };
    println!("\n[e2e] W4A4KV4 with GPTQ weights:");
    println!("{:<12} {:>9} {:>9} {:>7} {:>8}", "method", "wiki-ppl", "0-shot%", "mmlu%", "cost(s)");
    for method in Method::all() {
        let mut cfg = PipelineConfig::new(model, method);
        cfg.weight_quantizer = WeightQuantizer::Gptq;
        if fast {
            cfg.calib.n_samples = 64;
            cfg.calib.iters = 30;
        }
        let (pm, cost) = pipe.quantize(&cfg)?;
        let s = evaluate(&pipe, &pm, n_q, n_eval)?;
        println!(
            "{:<12} {:>9.3} {:>9.1} {:>7.1} {:>8.2}",
            method.label(),
            s.wiki_ppl,
            s.zero_shot_avg * 100.0,
            s.mmlu_avg * 100.0,
            cost.total_s
        );
    }
    println!("\n[e2e] done — see EXPERIMENTS.md for the recorded full-scale run.");
    Ok(())
}
