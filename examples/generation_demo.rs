//! Generation demo: autoregressive sampling through the 4-bit-KV-cache
//! decode artifact — the generation-stage path the paper's KV-cache
//! quantization targets. Reports tokens/s for fp vs quantized decode.
//!
//! ```bash
//! cargo run --release --example generation_demo
//! ```

use std::sync::Arc;
use std::time::Instant;

use kurtail::config::{Method, PipelineConfig};
use kurtail::model::generate::Generator;
use kurtail::pipeline::Pipeline;
use kurtail::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("KURTAIL_FAST").is_ok();
    let rt = Arc::new(Runtime::new("artifacts")?);
    let pipe = Pipeline::new(rt, "small", 0, fast, true)?;
    let prompt = "the author of the glass river is";
    let n_tokens = 40;

    // fp decode
    let fp = pipe.quantize(&PipelineConfig::new("small", Method::Fp16))?.0;
    let gen_fp = Generator::new(&pipe.rt, fp.params.clone(), false, None)?;
    let t0 = Instant::now();
    let out_fp = gen_fp.generate(prompt, n_tokens, 0.7, 1)?;
    let fp_s = t0.elapsed().as_secs_f64();

    // KurTail-quantized decode (4-bit KV cache written every step)
    let mut cfg = PipelineConfig::new("small", Method::KurTail);
    if fast {
        cfg.calib.n_samples = 64;
        cfg.calib.iters = 30;
    }
    let (kt, _) = pipe.quantize(&cfg)?;
    let rots = (kt.rots.r3.clone(), kt.rots.r4.clone(), kt.rots.r5.clone());
    let gen_kt = Generator::new(&pipe.rt, kt.params.clone(), true, Some(rots))?;
    let t0 = Instant::now();
    let out_kt = gen_kt.generate(prompt, n_tokens, 0.7, 1)?;
    let kt_s = t0.elapsed().as_secs_f64();

    let lanes = out_fp.len() as f64;
    println!("\nprompt: {prompt:?}");
    println!("fp16 sample    : {:?}", &out_fp[0][..out_fp[0].len().min(120)]);
    println!("kurtail sample : {:?}", &out_kt[0][..out_kt[0].len().min(120)]);
    println!(
        "decode throughput: fp {:.1} tok/s · quant {:.1} tok/s (batch {lanes}, simulated quant)",
        lanes * n_tokens as f64 / fp_s,
        lanes * n_tokens as f64 / kt_s
    );
    Ok(())
}
