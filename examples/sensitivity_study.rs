//! Sensitivity study (paper Fig. 1 + Table 1 in one run): capture real
//! block inputs, learn a KurTail rotation, compare quantization
//! sensitivity and per-token-max success rates against random Hadamard.
//!
//! ```bash
//! cargo run --release --example sensitivity_study
//! ```

use kurtail::exp::{self, ExpCtx};

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("KURTAIL_FAST").is_ok();
    let ctx = ExpCtx::new("artifacts", fast, 0)?;
    exp::run(&ctx, "fig1")?;
    exp::run(&ctx, "table1")?;
    exp::run(&ctx, "fig2")?;
    println!("CSV series written to results/ — plot fig1_curves.csv to recreate the figure.");
    Ok(())
}
