//! Quickstart: quantize a tiny model with KurTail and compare against fp.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Walks the whole public API in ~40 lines: open the runtime, get a
//! pretrained model, run the KurTail pipeline, evaluate perplexity.

use std::sync::Arc;

use kurtail::config::{Method, PipelineConfig};
use kurtail::eval::perplexity;
use kurtail::pipeline::Pipeline;
use kurtail::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    // 1. Open the AOT artifacts (HLO text + manifest) on the PJRT CPU client.
    let rt = Arc::new(Runtime::new("artifacts")?);

    // 2. Pretrain (or load the cached) tiny model on the synthetic corpus.
    let fast = std::env::var("KURTAIL_FAST").is_ok();
    let pipe = Pipeline::new(rt, "tiny", /*seed=*/ 0, fast, /*verbose=*/ true)?;

    // 3. Full-precision reference.
    let fp = pipe.quantize(&PipelineConfig::new("tiny", Method::Fp16))?.0;
    let ppl_fp = perplexity(&pipe.rt, &fp, &pipe.bundle.test, 8)?;

    // 4. KurTail W4A4KV4: learn rotations by kurtosis, fuse, GPTQ weights.
    let mut cfg = PipelineConfig::new("tiny", Method::KurTail);
    if fast {
        cfg.calib.n_samples = 64;
        cfg.calib.iters = 30;
    }
    let (kt, cost) = pipe.quantize(&cfg)?;
    let ppl_kt = perplexity(&pipe.rt, &kt, &pipe.bundle.test, 8)?;

    // 5. Plain 4-bit (no rotations) for contrast.
    let mut gp = PipelineConfig::new("tiny", Method::GptqOnly);
    if fast {
        gp.calib.n_samples = 64;
    }
    let (g, _) = pipe.quantize(&gp)?;
    let ppl_g = perplexity(&pipe.rt, &g, &pipe.bundle.test, 8)?;

    println!("\n== quickstart results (held-out ppl, lower is better) ==");
    println!("  16-bit          : {ppl_fp:.3}");
    println!("  W4A4KV4 GPTQ    : {ppl_g:.3}   (no rotations)");
    println!("  W4A4KV4 KurTail : {ppl_kt:.3}   (rotation learning took {:.1}s)", cost.total_s);
    assert!(ppl_kt < ppl_g, "KurTail should beat rotation-free 4-bit");
    println!("OK: KurTail < GPTQ-only, as the paper predicts.");
    Ok(())
}
