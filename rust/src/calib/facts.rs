//! Synthetic knowledge base: the stand-in for the factual content that the
//! paper's zero-shot / MMLU / MathQA benchmarks probe (DESIGN.md §2).
//!
//! A seeded "world" assigns attributes to entities across four domains
//! mirroring the MMLU category split of paper Table 8:
//!   humanities  — authors ↔ books
//!   social      — people ↔ cities / jobs
//!   stem        — elements ↔ atomic numbers, squares
//!   other       — animals ↔ foods / colors
//!
//! `fact_sentences` feed the training corpus (so the facts are learnable);
//! `questions(domain)` produce 4-way multiple-choice items scored by the
//! eval harness exactly as lm-eval scores MMLU (per-option NLL, argmin).

use crate::util::Rng;

pub const DOMAINS: [&str; 4] = ["humanities", "other", "stem", "social"];

pub const AUTHORS: &[&str] = &[
    "alden", "briar", "corin", "darian", "elwin", "farren", "galen", "hollis",
    "imra", "jorun", "kaelis", "loreth", "mirren", "nolan", "orin", "pellan",
];
pub const BOOKS: &[&str] = &[
    "the glass river", "winter crowns", "the last orchard", "salt and cedar",
    "the iron garden", "a field of doors", "the ninth lantern", "old harbor songs",
    "the paper mountain", "a quiet armada", "the brass meadow", "night ledgers",
    "the hollow crown", "ash cartographers", "the long shore", "ember annals",
];
const PEOPLE: &[&str] = &[
    "mara", "tobin", "selka", "ivo", "petra", "ansel", "vera", "rollo",
    "edda", "sorin", "lina", "marek", "odile", "bren", "tilda", "janos",
];
const CITIES: &[&str] = &[
    "velport", "crane hill", "ostermoor", "duskvale", "harrowgate", "lindenfall",
    "redmarch", "silverquay", "thornwick", "eastmere", "goldenrow", "fennbridge",
];
const JOBS: &[&str] = &[
    "baker", "weaver", "carpenter", "fisher", "scribe", "mason", "tailor",
    "miller", "potter", "smith", "cooper", "glazier",
];
const ELEMENTS: &[&str] = &[
    "veltrium", "ossine", "drakon", "melphite", "quorine", "tessium",
    "arvolite", "zephrium", "coldane", "pyrrhite", "lumenite", "ferrowine",
];
const ANIMALS: &[&str] = &[
    "marmot", "heron", "lynx", "otter", "badger", "falcon", "tortoise",
    "weasel", "magpie", "hedgehog", "stoat", "plover",
];
const FOODS: &[&str] = &[
    "berries", "clover", "minnows", "acorns", "roots", "crickets",
    "barley", "snails", "apples", "cress", "worms", "seeds",
];
const COLORS: &[&str] = &[
    "grey", "russet", "golden", "ashen", "speckled", "dun", "silver", "umber",
];

/// A deterministic assignment of attributes to entities.
#[derive(Debug, Clone)]
pub struct World {
    pub author_of_book: Vec<usize>, // book -> author
    pub city_of_person: Vec<usize>, // person -> city
    pub job_of_person: Vec<usize>,  // person -> job
    pub number_of_element: Vec<usize>, // element -> atomic number (1..40)
    pub food_of_animal: Vec<usize>, // animal -> food
    pub color_of_animal: Vec<usize>, // animal -> color
}

impl World {
    pub fn generate(seed: u64) -> World {
        let mut rng = Rng::new(seed ^ 0xFAC75);
        World {
            author_of_book: (0..BOOKS.len()).map(|_| rng.below(AUTHORS.len())).collect(),
            city_of_person: (0..PEOPLE.len()).map(|_| rng.below(CITIES.len())).collect(),
            job_of_person: (0..PEOPLE.len()).map(|_| rng.below(JOBS.len())).collect(),
            number_of_element: (0..ELEMENTS.len()).map(|_| 1 + rng.below(39)).collect(),
            food_of_animal: (0..ANIMALS.len()).map(|_| rng.below(FOODS.len())).collect(),
            color_of_animal: (0..ANIMALS.len()).map(|_| rng.below(COLORS.len())).collect(),
        }
    }

    /// All fact sentences, in several phrasings (training signal).
    pub fn fact_sentences(&self) -> Vec<String> {
        let mut out = Vec::new();
        for (b, &a) in self.author_of_book.iter().enumerate() {
            out.push(format!("the author of {} is {}.", BOOKS[b], AUTHORS[a]));
            out.push(format!("{} wrote {}.", AUTHORS[a], BOOKS[b]));
        }
        for (p, &c) in self.city_of_person.iter().enumerate() {
            out.push(format!("{} lives in {}.", PEOPLE[p], CITIES[c]));
            out.push(format!("the home of {} is {}.", PEOPLE[p], CITIES[c]));
        }
        for (p, &j) in self.job_of_person.iter().enumerate() {
            out.push(format!("{} works as a {}.", PEOPLE[p], JOBS[j]));
        }
        for (e, &n) in self.number_of_element.iter().enumerate() {
            out.push(format!("the atomic number of {} is {}.", ELEMENTS[e], n));
            out.push(format!("{} has atomic number {}.", ELEMENTS[e], n));
        }
        for (a, &f) in self.food_of_animal.iter().enumerate() {
            out.push(format!("the {} eats {}.", ANIMALS[a], FOODS[f]));
        }
        for (a, &c) in self.color_of_animal.iter().enumerate() {
            out.push(format!("the {} is {}.", ANIMALS[a], COLORS[c]));
        }
        out
    }

    /// 4-way multiple-choice questions for one MMLU-analog domain.
    /// Returns (prompt, options, correct_index).
    pub fn questions(&self, domain: &str, n: usize, rng: &mut Rng) -> Vec<Mcq> {
        let mut out = Vec::new();
        for _ in 0..n {
            out.push(match domain {
                "humanities" => {
                    let b = rng.below(BOOKS.len());
                    let correct = self.author_of_book[b];
                    mcq(
                        format!("the author of {} is", BOOKS[b]),
                        AUTHORS, correct, rng,
                    )
                }
                "social" => {
                    let p = rng.below(PEOPLE.len());
                    if rng.below(2) == 0 {
                        mcq(format!("{} lives in", PEOPLE[p]), CITIES, self.city_of_person[p], rng)
                    } else {
                        mcq(format!("{} works as a", PEOPLE[p]), JOBS, self.job_of_person[p], rng)
                    }
                }
                "stem" => {
                    let e = rng.below(ELEMENTS.len());
                    let correct = self.number_of_element[e];
                    let mut opts = vec![correct.to_string()];
                    while opts.len() < 4 {
                        let d = 1 + rng.below(39);
                        if d != correct && !opts.contains(&d.to_string()) {
                            opts.push(d.to_string());
                        }
                    }
                    shuffle_mcq(format!("the atomic number of {} is", ELEMENTS[e]), opts, rng)
                }
                "other" => {
                    let a = rng.below(ANIMALS.len());
                    if rng.below(2) == 0 {
                        mcq(format!("the {} eats", ANIMALS[a]), FOODS, self.food_of_animal[a], rng)
                    } else {
                        mcq(format!("the {} is", ANIMALS[a]), COLORS, self.color_of_animal[a], rng)
                    }
                }
                other => panic!("unknown domain {other}"),
            });
        }
        out
    }
}

/// One multiple-choice item (lm-eval style: score `prompt + " " + option`).
#[derive(Debug, Clone)]
pub struct Mcq {
    pub prompt: String,
    pub options: Vec<String>,
    pub correct: usize,
}

fn mcq(prompt: String, pool: &[&str], correct_idx: usize, rng: &mut Rng) -> Mcq {
    let mut opts = vec![pool[correct_idx].to_string()];
    while opts.len() < 4 {
        let cand = pool[rng.below(pool.len())].to_string();
        if !opts.contains(&cand) {
            opts.push(cand);
        }
    }
    shuffle_mcq(prompt, opts, rng)
}

fn shuffle_mcq(prompt: String, mut opts: Vec<String>, rng: &mut Rng) -> Mcq {
    let correct_text = opts[0].clone();
    rng.shuffle(&mut opts);
    let correct = opts.iter().position(|o| *o == correct_text).unwrap();
    Mcq { prompt, options: opts, correct }
}

pub fn entities() -> (&'static [&'static str], &'static [&'static str], &'static [&'static str]) {
    (PEOPLE, ANIMALS, FOODS)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn world_deterministic() {
        let a = World::generate(7);
        let b = World::generate(7);
        assert_eq!(a.author_of_book, b.author_of_book);
        assert_ne!(a.author_of_book, World::generate(8).author_of_book);
    }

    #[test]
    fn facts_cover_all_domains() {
        let w = World::generate(0);
        let facts = w.fact_sentences();
        assert!(facts.len() > 100);
        assert!(facts.iter().any(|f| f.contains("author")));
        assert!(facts.iter().any(|f| f.contains("atomic number")));
        assert!(facts.iter().any(|f| f.contains("lives in")));
        assert!(facts.iter().any(|f| f.contains("eats")));
    }

    #[test]
    fn questions_are_answerable_from_facts() {
        let w = World::generate(1);
        let facts = w.fact_sentences().join(" ");
        let mut rng = Rng::new(2);
        for domain in DOMAINS {
            for q in w.questions(domain, 20, &mut rng) {
                assert_eq!(q.options.len(), 4, "{domain}");
                assert!(q.correct < 4);
                // the correct completion appears verbatim in the corpus
                let full = format!("{} {}", q.prompt, q.options[q.correct]);
                assert!(
                    facts.contains(&q.options[q.correct]) && !full.is_empty(),
                    "{domain}: {full}"
                );
                // options are distinct
                let mut o = q.options.clone();
                o.sort();
                o.dedup();
                assert_eq!(o.len(), 4);
            }
        }
    }
}
