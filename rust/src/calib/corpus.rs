//! Synthetic corpus generators — the stand-ins for the paper's calibration
//! datasets (WikiText / C4 / PTB / Alpaca, Table 6 ablation) and for the
//! training corpus of the from-scratch models.
//!
//! Each generator is deterministic from a seed and has deliberately
//! distinct surface statistics (formality, casing, punctuation, special
//! tokens), because the Table 6 experiment is exactly about whether the
//! calibration distribution matters for rotation learning.

use super::facts::World;
use crate::util::Rng;

/// Calibration corpus styles (paper Table 6 rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CorpusKind {
    Wiki,
    C4,
    Ptb,
    Alpaca,
    Combined,
}

impl CorpusKind {
    pub fn parse(s: &str) -> Option<CorpusKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "wiki" | "wikitext" | "wikitext-2" => CorpusKind::Wiki,
            "c4" => CorpusKind::C4,
            "ptb" => CorpusKind::Ptb,
            "alpaca" => CorpusKind::Alpaca,
            "combined" => CorpusKind::Combined,
            _ => return None,
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            CorpusKind::Wiki => "Wikitext-2",
            CorpusKind::C4 => "C4",
            CorpusKind::Ptb => "PTB",
            CorpusKind::Alpaca => "Alpaca",
            CorpusKind::Combined => "Combined",
        }
    }

    pub fn all() -> [CorpusKind; 5] {
        [CorpusKind::Wiki, CorpusKind::C4, CorpusKind::Ptb, CorpusKind::Alpaca, CorpusKind::Combined]
    }
}

const TOPICS: &[&str] = &[
    "the river valley", "the old harbor", "the northern railway", "the glass works",
    "the city archive", "the salt trade", "the mountain pass", "the lighthouse",
    "the printing house", "the botanical garden", "the clock tower", "the mill district",
];
const VERBS: &[&str] = &[
    "was established in", "expanded during", "declined after", "was rebuilt in",
    "supplied goods to", "connected", "served", "bordered", "influenced", "preserved",
];
const ERAS: &[&str] = &[
    "the early period", "the middle era", "the late era", "the reform years",
    "the long winter", "the second expansion", "the quiet decade",
];
const ADJS: &[&str] = &[
    "notable", "small", "prosperous", "remote", "ancient", "busy", "quiet", "famous",
];

/// Formal encyclopedic sentences (WikiText stand-in).
pub fn wiki_sentence(rng: &mut Rng) -> String {
    match rng.below(3) {
        0 => format!(
            "{} {} {}.",
            TOPICS[rng.zipf(TOPICS.len(), 1.1)],
            VERBS[rng.below(VERBS.len())],
            ERAS[rng.below(ERAS.len())]
        ),
        1 => format!(
            "{} was a {} settlement near {}.",
            TOPICS[rng.zipf(TOPICS.len(), 1.1)],
            ADJS[rng.below(ADJS.len())],
            TOPICS[rng.below(TOPICS.len())]
        ),
        _ => format!(
            "during {} , {} {} {}.",
            ERAS[rng.below(ERAS.len())],
            TOPICS[rng.zipf(TOPICS.len(), 1.1)],
            VERBS[rng.below(VERBS.len())],
            TOPICS[rng.below(TOPICS.len())]
        ),
    }
}

/// Noisy web text (C4 stand-in): casing, urls, promos.
pub fn c4_sentence(rng: &mut Rng) -> String {
    match rng.below(4) {
        0 => format!(
            "Check out {} for more info at www.{}.example!",
            TOPICS[rng.below(TOPICS.len())],
            ["shop", "news", "blog", "deals"][rng.below(4)]
        ),
        1 => format!(
            "TOP {} tips for {} - you won't believe #{}!",
            1 + rng.below(9),
            TOPICS[rng.below(TOPICS.len())],
            1 + rng.below(9)
        ),
        2 => format!(
            "I really think {} {} {} tbh.",
            TOPICS[rng.below(TOPICS.len())],
            VERBS[rng.below(VERBS.len())],
            ERAS[rng.below(ERAS.len())]
        ),
        _ => format!(
            "Subscribe now: {} news, {} updates, free shipping.",
            TOPICS[rng.below(TOPICS.len())],
            ADJS[rng.below(ADJS.len())]
        ),
    }
}

/// Financial newswire (PTB stand-in): lowercase, <unk>, N for numbers.
pub fn ptb_sentence(rng: &mut Rng) -> String {
    let co = ["acme corp", "norwood & sons", "<unk> industries", "harbor holdings"][rng.below(4)];
    match rng.below(3) {
        0 => format!(
            "{} said quarterly profit rose N % to $ N million.",
            co
        ),
        1 => format!(
            "shares of {} fell N cents in <unk> trading.",
            co
        ),
        _ => format!(
            "analysts at {} expect {} to {} next year.",
            co,
            TOPICS[rng.below(TOPICS.len())],
            ["improve", "slow", "recover", "<unk>"][rng.below(4)]
        ),
    }
}

/// Instruction-response pairs (Alpaca stand-in).
pub fn alpaca_sentence(rng: &mut Rng) -> String {
    let topic = TOPICS[rng.below(TOPICS.len())];
    match rng.below(3) {
        0 => format!(
            "### instruction: describe {}. ### response: {} was a {} place that {} {}.",
            topic, topic, ADJS[rng.below(ADJS.len())],
            VERBS[rng.below(VERBS.len())], ERAS[rng.below(ERAS.len())]
        ),
        1 => format!(
            "### instruction: list a fact about {}. ### response: it {} {}.",
            topic, VERBS[rng.below(VERBS.len())], TOPICS[rng.below(TOPICS.len())]
        ),
        _ => format!(
            "### instruction: when did {} change? ### response: during {}.",
            topic, ERAS[rng.below(ERAS.len())]
        ),
    }
}

/// Generate `n_bytes` of a given corpus style.
pub fn generate(kind: CorpusKind, n_bytes: usize, seed: u64) -> String {
    let mut rng = Rng::new(seed ^ 0xC0A9);
    let mut out = String::with_capacity(n_bytes + 128);
    while out.len() < n_bytes {
        let s = match kind {
            CorpusKind::Wiki => wiki_sentence(&mut rng),
            CorpusKind::C4 => c4_sentence(&mut rng),
            CorpusKind::Ptb => ptb_sentence(&mut rng),
            CorpusKind::Alpaca => alpaca_sentence(&mut rng),
            CorpusKind::Combined => match rng.below(4) {
                0 => wiki_sentence(&mut rng),
                1 => c4_sentence(&mut rng),
                2 => ptb_sentence(&mut rng),
                _ => alpaca_sentence(&mut rng),
            },
        };
        out.push_str(&s);
        out.push(' ');
    }
    out.truncate(n_bytes);
    out
}

/// The training corpus: wiki-style filler interleaved with the fact base
/// (repeated in shuffled order so facts are learnable) and arithmetic
/// examples (for the MathQA-analog). Returns ~`n_bytes` of text.
pub fn training_corpus(world: &World, n_bytes: usize, seed: u64) -> String {
    let mut rng = Rng::new(seed ^ 0x7EA1);
    let mut facts = world.fact_sentences();
    let mut out = String::with_capacity(n_bytes + 256);
    let mut fi = usize::MAX; // trigger reshuffle on first use
    while out.len() < n_bytes {
        match rng.below(10) {
            // 50% facts — they are the eval signal
            0..=4 => {
                if fi >= facts.len() {
                    rng.shuffle(&mut facts);
                    fi = 0;
                }
                out.push_str(&facts[fi]);
                fi += 1;
            }
            // 20% arithmetic
            5..=6 => out.push_str(&super::arithmetic::arithmetic_sentence(&mut rng)),
            // 30% wiki filler
            _ => out.push_str(&wiki_sentence(&mut rng)),
        }
        out.push(' ');
    }
    out.truncate(n_bytes);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(generate(CorpusKind::Wiki, 1000, 1), generate(CorpusKind::Wiki, 1000, 1));
        assert_ne!(generate(CorpusKind::Wiki, 1000, 1), generate(CorpusKind::Wiki, 1000, 2));
    }

    #[test]
    fn styles_are_distinct() {
        let wiki = generate(CorpusKind::Wiki, 5000, 0);
        let c4 = generate(CorpusKind::C4, 5000, 0);
        let ptb = generate(CorpusKind::Ptb, 5000, 0);
        let alp = generate(CorpusKind::Alpaca, 5000, 0);
        assert!(!wiki.contains("www.") && c4.contains("www."));
        assert!(ptb.contains("<unk>") && !wiki.contains("<unk>"));
        assert!(alp.contains("### instruction:") && !c4.contains("### instruction:"));
    }

    #[test]
    fn combined_mixes_styles() {
        let c = generate(CorpusKind::Combined, 20_000, 3);
        assert!(c.contains("www.") && c.contains("<unk>") && c.contains("### instruction:"));
    }

    #[test]
    fn training_corpus_contains_facts_and_math() {
        let w = World::generate(0);
        let t = training_corpus(&w, 50_000, 0);
        assert!(t.contains("atomic number"));
        assert!(t.contains(" eats "));
        assert!(t.contains(" plus ") || t.contains(" times ") || t.contains(" minus "));
        let first_facts = w.fact_sentences();
        // several distinct facts present
        let hits = first_facts.iter().filter(|f| t.contains(*f)).count();
        assert!(hits > first_facts.len() / 2, "{hits}/{}", first_facts.len());
    }

    #[test]
    fn exact_length() {
        for kind in CorpusKind::all() {
            assert_eq!(generate(kind, 1234, 9).len(), 1234);
        }
    }
}
