//! Calibration + training data substrate: synthetic corpora (WikiText /
//! C4 / PTB / Alpaca stand-ins), the fact world behind the zero-shot /
//! MMLU / MathQA analogs, byte tokenizer, and batch packing.

pub mod arithmetic;
pub mod corpus;
pub mod dataset;
pub mod facts;
pub mod tokenizer;

pub use corpus::CorpusKind;
pub use dataset::{DataBundle, TokenDataset};
pub use facts::{Mcq, World};
pub use tokenizer::ByteTokenizer;
