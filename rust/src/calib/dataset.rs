//! Token datasets: pack corpora into (B, T) batches for the artifact
//! graphs, with train/held-out splits and calibration sampling.

use super::corpus::{self, CorpusKind};
use super::facts::World;
use super::tokenizer::ByteTokenizer;
use crate::tensor::IntTensor;
use crate::util::Rng;

/// A tokenized corpus with sequence packing.
#[derive(Debug, Clone)]
pub struct TokenDataset {
    pub tokens: Vec<i32>,
    pub seq_len: usize,
}

impl TokenDataset {
    pub fn from_text(text: &str, seq_len: usize) -> Self {
        Self { tokens: ByteTokenizer.encode(text), seq_len }
    }

    /// Number of non-overlapping sequences available.
    pub fn n_sequences(&self) -> usize {
        self.tokens.len() / self.seq_len
    }

    /// The `i`-th non-overlapping sequence.
    pub fn sequence(&self, i: usize) -> &[i32] {
        let t = self.seq_len;
        &self.tokens[i * t..(i + 1) * t]
    }

    /// A (B, T) batch of distinct sequences, chosen by index list.
    pub fn batch(&self, idx: &[usize]) -> IntTensor {
        let t = self.seq_len;
        let mut data = Vec::with_capacity(idx.len() * t);
        for &i in idx {
            data.extend_from_slice(self.sequence(i));
        }
        IntTensor::new(data, vec![idx.len(), t])
    }

    /// A random (B, T) batch.
    pub fn random_batch(&self, b: usize, rng: &mut Rng) -> IntTensor {
        let n = self.n_sequences();
        let idx: Vec<usize> = (0..b).map(|_| rng.below(n)).collect();
        self.batch(&idx)
    }

    /// Deterministic evaluation batches covering the first `n_batches·b`
    /// sequences (held-out perplexity uses this).
    pub fn eval_batches(&self, b: usize, n_batches: usize) -> Vec<IntTensor> {
        let n = self.n_sequences();
        (0..n_batches)
            .map(|k| {
                let idx: Vec<usize> = (0..b).map(|i| (k * b + i) % n).collect();
                self.batch(&idx)
            })
            .collect()
    }
}

/// Everything data-related for one experiment run, derived from one seed.
pub struct DataBundle {
    pub world: World,
    pub train: TokenDataset,
    /// Held-out wiki-style split (the "WikiText test set" analog).
    pub test: TokenDataset,
    pub seq_len: usize,
    pub seed: u64,
}

impl DataBundle {
    /// `train_bytes` of training text + a held-out test split.
    pub fn new(seq_len: usize, train_bytes: usize, seed: u64) -> Self {
        let world = World::generate(seed);
        // Held-out data is the same *distribution* as training (the paper
        // evaluates on WikiText's test split): same generator, disjoint seed
        // stream, so sequences never coincide but statistics match.
        let train_text = corpus::training_corpus(&world, train_bytes, seed);
        let test_text = corpus::training_corpus(&world, train_bytes / 8, seed ^ 0xDEAD_BEEF);
        Self {
            world,
            train: TokenDataset::from_text(&train_text, seq_len),
            test: TokenDataset::from_text(&test_text, seq_len),
            seq_len,
            seed,
        }
    }

    /// Calibration sequences in a given corpus style (Table 6/7 knobs).
    pub fn calib_batches(
        &self,
        kind: CorpusKind,
        n_samples: usize,
        batch: usize,
        seed: u64,
    ) -> Vec<IntTensor> {
        let bytes = n_samples * self.seq_len + self.seq_len;
        let text = corpus::generate(kind, bytes, seed ^ 0xCA11B);
        let ds = TokenDataset::from_text(&text, self.seq_len);
        let mut rng = Rng::new(seed ^ 0x5A3);
        let mut idx: Vec<usize> = (0..ds.n_sequences()).collect();
        rng.shuffle(&mut idx);
        idx.truncate(n_samples);
        idx.chunks(batch)
            .filter(|c| c.len() == batch)
            .map(|c| ds.batch(c))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert};

    #[test]
    fn packing_shapes() {
        let ds = TokenDataset::from_text(&"abcdefgh".repeat(100), 16);
        assert_eq!(ds.n_sequences(), 50);
        let b = ds.batch(&[0, 1, 2]);
        assert_eq!(b.shape, vec![3, 16]);
        assert_eq!(&b.data[..8], &[97, 98, 99, 100, 101, 102, 103, 104]);
    }

    #[test]
    fn bundle_train_test_disjoint() {
        let db = DataBundle::new(64, 20_000, 0);
        assert!(db.train.n_sequences() > 100);
        assert!(db.test.n_sequences() > 10);
        // different seed stream ⇒ first sequences differ
        assert_ne!(db.train.sequence(0), db.test.sequence(0));
    }

    #[test]
    fn calib_batches_counts() {
        let db = DataBundle::new(64, 10_000, 1);
        let batches = db.calib_batches(CorpusKind::Wiki, 32, 4, 7);
        assert_eq!(batches.len(), 8);
        for b in &batches {
            assert_eq!(b.shape, vec![4, 64]);
        }
    }

    #[test]
    fn prop_eval_batches_in_vocab() {
        check(20, |rng| {
            let db = DataBundle::new(32, 5_000, rng.next_u64());
            let batches = db.test.eval_batches(2, 3);
            for b in &batches {
                for &t in &b.data {
                    prop_assert((0..256).contains(&t), "token in vocab")?;
                }
            }
            Ok(())
        });
    }
}
