//! Arithmetic text + multiple-choice generator — the MathQA stand-in
//! (paper Table 5). The training corpus contains spelled-out arithmetic
//! so a trained model can score above chance; quantization error then
//! shows up as the paper observes: math degrades more than factual recall.

use super::facts::Mcq;
use crate::util::Rng;

const OPS: [(&str, fn(i64, i64) -> i64); 3] = [
    ("plus", |a, b| a + b),
    ("minus", |a, b| a - b),
    ("times", |a, b| a * b),
];

/// One spelled-out arithmetic fact, e.g. "7 plus 12 is 19."
pub fn arithmetic_sentence(rng: &mut Rng) -> String {
    let (name, f) = OPS[rng.below(OPS.len())];
    let (a, b) = operands(name, rng);
    format!("{a} {name} {b} is {}.", f(a, b))
}

fn operands(op: &str, rng: &mut Rng) -> (i64, i64) {
    match op {
        // keep products small enough to appear repeatedly in the corpus
        "times" => (1 + rng.below(12) as i64, 1 + rng.below(12) as i64),
        _ => (rng.below(50) as i64, rng.below(50) as i64),
    }
}

/// MathQA-analog item: "a op b is" with 4 numeric options.
pub fn math_question(rng: &mut Rng) -> Mcq {
    let (name, f) = OPS[rng.below(OPS.len())];
    let (a, b) = operands(name, rng);
    let correct_val = f(a, b);
    let mut opts = vec![correct_val];
    while opts.len() < 4 {
        // plausible distractors: off-by-small and digit-swapped answers
        let cand = match rng.below(3) {
            0 => correct_val + 1 + rng.below(4) as i64,
            1 => correct_val - 1 - rng.below(4) as i64,
            _ => f(a, b + 1),
        };
        if !opts.contains(&cand) {
            opts.push(cand);
        }
    }
    let correct_val_s = correct_val.to_string();
    let mut opts: Vec<String> = opts.into_iter().map(|v| v.to_string()).collect();
    rng.shuffle(&mut opts);
    let correct = opts.iter().position(|o| *o == correct_val_s).unwrap();
    Mcq { prompt: format!("{a} {name} {b} is"), options: opts, correct }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert};

    #[test]
    fn sentences_are_correct_arithmetic() {
        let mut rng = Rng::new(0);
        for _ in 0..100 {
            let s = arithmetic_sentence(&mut rng);
            let parts: Vec<&str> = s.trim_end_matches('.').split(' ').collect();
            let (a, op, b, res) = (parts[0], parts[1], parts[2], parts[4]);
            let (a, b, res): (i64, i64, i64) =
                (a.parse().unwrap(), b.parse().unwrap(), res.parse().unwrap());
            let want = match op {
                "plus" => a + b,
                "minus" => a - b,
                "times" => a * b,
                _ => panic!("{op}"),
            };
            assert_eq!(res, want, "{s}");
        }
    }

    #[test]
    fn prop_questions_well_formed() {
        check(200, |rng| {
            let q = math_question(rng);
            prop_assert(q.options.len() == 4 && q.correct < 4, "shape")?;
            let mut o = q.options.clone();
            o.sort();
            o.dedup();
            prop_assert(o.len() == 4, "distinct options")
        });
    }
}
