//! Byte-level tokenizer (vocab = 256).
//!
//! The paper's models use BPE vocabularies; at our corpus scale a byte
//! tokenizer keeps the vocab dense (every id trainable) and makes the
//! round-trip property exact — which the proptest suite pins down.

/// Byte-level tokenizer; token id = byte value.
#[derive(Debug, Clone, Copy, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub const VOCAB: usize = 256;

    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.as_bytes().iter().map(|&b| b as i32).collect()
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        let bytes: Vec<u8> = ids.iter().map(|&i| (i.clamp(0, 255)) as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert};

    #[test]
    fn ascii_roundtrip() {
        let t = ByteTokenizer;
        let s = "The quick brown fox! 012?";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn ids_in_vocab() {
        let t = ByteTokenizer;
        for id in t.encode("any text at all") {
            assert!((0..256).contains(&id));
        }
    }

    #[test]
    fn prop_roundtrip_ascii() {
        check(200, |rng| {
            let t = ByteTokenizer;
            let len = rng.below(64);
            let s: String = (0..len).map(|_| (32 + rng.below(95)) as u8 as char).collect();
            prop_assert(t.decode(&t.encode(&s)) == s, "byte round-trip")
        });
    }
}
