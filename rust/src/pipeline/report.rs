//! Report formatting: paper-style markdown tables + JSON dumps that
//! EXPERIMENTS.md records verbatim.

use std::fmt::Write as _;

use crate::util::json::{arr, obj, s, Json};

/// A printable table with a caption (one per paper table/figure).
pub struct Table {
    pub caption: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(caption: &str, headers: &[&str]) -> Self {
        Self {
            caption: caption.to_string(),
            headers: headers.iter().map(|h| h.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "table row arity");
        self.rows.push(cells);
    }

    /// GitHub-flavoured markdown.
    pub fn markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "**{}**\n", self.caption);
        let _ = writeln!(out, "| {} |", self.headers.join(" | "));
        let _ = writeln!(out, "|{}|", self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
        for r in &self.rows {
            let _ = writeln!(out, "| {} |", r.join(" | "));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.markdown());
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("caption", s(&self.caption)),
            ("headers", arr(self.headers.iter().map(|h| s(h)).collect())),
            (
                "rows",
                arr(self
                    .rows
                    .iter()
                    .map(|r| arr(r.iter().map(|c| s(c)).collect()))
                    .collect()),
            ),
        ])
    }
}

pub fn fmt_f(v: f32, digits: usize) -> String {
    format!("{v:.digits$}")
}

pub fn fmt_pct(v: f32) -> String {
    format!("{:.1}", v * 100.0)
}

/// Append a table (markdown + JSON) to a results file under `results/`.
pub fn save_table(t: &Table, name: &str) -> anyhow::Result<std::path::PathBuf> {
    std::fs::create_dir_all("results")?;
    let md = std::path::Path::new("results").join(format!("{name}.md"));
    std::fs::write(&md, t.markdown())?;
    let js = std::path::Path::new("results").join(format!("{name}.json"));
    std::fs::write(js, t.to_json().to_string_pretty())?;
    Ok(md)
}

/// Write a CSV series (Fig 1 curves, Fig 2 histograms).
pub fn save_csv(name: &str, headers: &[&str], rows: &[Vec<f64>]) -> anyhow::Result<std::path::PathBuf> {
    std::fs::create_dir_all("results")?;
    let path = std::path::Path::new("results").join(format!("{name}.csv"));
    let mut out = String::new();
    let _ = writeln!(out, "{}", headers.join(","));
    for r in rows {
        let cells: Vec<String> = r.iter().map(|v| format!("{v}")).collect();
        let _ = writeln!(out, "{}", cells.join(","));
    }
    std::fs::write(&path, out)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("Test", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | 2 |"));
        assert!(md.contains("**Test**"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("Test", &["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
