//! The end-to-end PTQ pipeline: pretrain (or load) → fold norms → learn /
//! construct rotations → fuse → weight-quantize → evaluate. One call per
//! (model, method) cell of the paper's tables.

pub mod report;

use std::sync::Arc;

use anyhow::Result;

use crate::baselines::{quarot_rotations, spinquant_learn};
use crate::calib::{CorpusKind, DataBundle};
use crate::config::{Method, PipelineConfig, QuantScheme, WeightQuantizer};
use crate::kurtail::learn_rotations;
use crate::model::{capture_stream, train_or_load, Params, TrainConfig};
use crate::quant::{quantize_weights, HessianSet};
use crate::rotation::{fold_norms, fuse_r1, fuse_r2, fuse_r4_inverse, fuse_r5_inverse, RotationSet};
use crate::runtime::Runtime;
use crate::obs::StageTimer;
use crate::util::{timer, Rng};

/// A model ready for evaluation: fused + quantized params and the online
/// rotations the quantized graph needs.
pub struct PreparedModel {
    pub params: Params,
    pub rots: RotationSet,
    /// false → evaluate through the fp graph (the "16-bit" rows).
    pub quantized: bool,
    pub method: Method,
}

/// Cost accounting for the rotation-learning stage (paper §3 Training Cost).
#[derive(Debug, Clone, Default)]
pub struct MethodCost {
    pub capture_s: f64,
    pub optimize_s: f64,
    pub total_s: f64,
    pub peak_rss_mib: f64,
}

/// Shared per-model state: runtime, data, pretrained fp weights.
pub struct Pipeline {
    pub rt: Arc<Runtime>,
    pub bundle: DataBundle,
    pub cfg_name: String,
    pub fp_params: Params,
}

/// Pretraining sizes per config (bytes of synthetic corpus / steps).
pub fn default_train_config(cfg_name: &str, fast: bool) -> (usize, TrainConfig) {
    let (bytes, steps) = match cfg_name {
        "tiny" => (300_000, 300),
        "small" => (600_000, 500),
        "base" => (900_000, 600),
        "phi" => (600_000, 500),
        "moe" => (600_000, 500),
        _ => (300_000, 300),
    };
    let steps = if fast { steps / 5 } else { steps };
    (bytes, TrainConfig { steps, ..TrainConfig::default() })
}

impl Pipeline {
    /// Build data + pretrained weights for one model config.
    pub fn new(rt: Arc<Runtime>, cfg_name: &str, seed: u64, fast: bool, verbose: bool) -> Result<Self> {
        let meta = rt.manifest.config(cfg_name)?.clone();
        let (bytes, tcfg) = default_train_config(cfg_name, fast);
        let tcfg = TrainConfig { seed, ..tcfg };
        let bundle = DataBundle::new(meta.seq_len, bytes, seed);
        let fp_params = train_or_load(&rt, cfg_name, &bundle.train, &tcfg, verbose)?;
        Ok(Self { rt, bundle, cfg_name: cfg_name.to_string(), fp_params })
    }

    /// Produce the evaluated model for one method (one table cell).
    pub fn quantize(&self, pcfg: &PipelineConfig) -> Result<(PreparedModel, MethodCost)> {
        let rt = &self.rt;
        let meta = self.fp_params.meta.clone();
        let mut cost = MethodCost::default();
        let sw_total = StageTimer::start("method");

        if pcfg.method == Method::Fp16 {
            return Ok((
                PreparedModel {
                    params: self.fp_params.clone(),
                    rots: RotationSet::identity(meta.d_head, meta.d_ff),
                    quantized: false,
                    method: pcfg.method,
                },
                cost,
            ));
        }

        // 1. fold norms (precondition for rotations and the quant graphs)
        let mut params = self.fp_params.clone();
        fold_norms(&mut params);

        // 2. calibration data
        let kind = CorpusKind::parse(&pcfg.calib.dataset)
            .ok_or_else(|| anyhow::anyhow!("unknown calib dataset '{}'", pcfg.calib.dataset))?;
        let calib_batches =
            self.bundle.calib_batches(kind, pcfg.calib.n_samples, meta.cap_batch, pcfg.calib.seed);
        anyhow::ensure!(!calib_batches.is_empty(), "calibration produced no batches");

        // 3. rotations
        let mut rng = Rng::new(pcfg.seed ^ 0x0715);
        let mut rots = RotationSet::identity(meta.d_head, meta.d_ff);
        if pcfg.method.uses_rotations() {
            let (r3, r4, r5) = RotationSet::online_hadamard(meta.d_head, meta.d_ff, &mut rng);
            rots.r3 = r3;
            rots.r4 = r4;
            rots.r5 = r5;
        }
        match pcfg.method {
            Method::QuaRot => {
                let (r1, r2) = quarot_rotations(meta.d_model, meta.d_head, meta.n_layers, &mut rng);
                rots.r1 = Some(r1);
                rots.r2 = r2;
            }
            Method::SpinQuant => {
                let rep = spinquant_learn(
                    rt,
                    &params,
                    &calib_batches,
                    pcfg.calib.iters,
                    // CE landscape needs a gentler step than the kurtosis loss
                    pcfg.calib.lr * 0.02,
                    pcfg.seed,
                )?;
                cost.optimize_s = rep.wall_s;
                cost.peak_rss_mib = rep.peak_rss_mib;
                rots.r1 = Some(rep.r1);
                // lite variant: R2 stays random Hadamard (DESIGN.md §2)
                rots.r2 = (0..meta.n_layers)
                    .map(|_| crate::tensor::hadamard::random_hadamard(meta.d_head, &mut rng))
                    .collect();
            }
            Method::KurTail => {
                let rep = learn_rotations(rt, &params, &calib_batches, &pcfg.calib)?;
                cost.capture_s = rep.capture_s;
                cost.optimize_s = rep.optimize_s;
                cost.peak_rss_mib = rep.peak_rss_mib;
                rots.r1 = Some(rep.r1);
                rots.r2 = rep.r2;
            }
            Method::GptqOnly | Method::Fp16 => {}
        }

        // 4. GPTQ Hessians from the (folded, unrotated) model — raw grams,
        //    rotated into the fused bases inside quantize_weights.
        let hessians = if pcfg.weight_quantizer == WeightQuantizer::Gptq {
            let f_mid = meta.d_ff * if meta.arch == "moe" { meta.n_experts } else { 1 };
            let mut hs = HessianSet::new(meta.n_layers, meta.d_model, f_mid);
            let n_hess = calib_batches.len().min(8); // a few batches suffice
            capture_stream(rt, &params, &calib_batches[..n_hess], |taps| {
                hs.accumulate(taps);
                Ok(())
            })?;
            Some(hs)
        } else {
            None
        };

        // 5. fuse rotations into the weights
        if let Some(r1) = &rots.r1 {
            fuse_r1(&mut params, r1);
        }
        let r2s = rots.r2.clone();
        fuse_r2(&mut params, &r2s);
        if pcfg.method.uses_rotations() {
            fuse_r4_inverse(&mut params, &rots.r4);
            fuse_r5_inverse(&mut params, &rots.r5);
        }

        // 6. weight quantization on the fused weights
        quantize_weights(
            &mut params,
            pcfg.weight_quantizer,
            &QuantScheme::weight4(),
            hessians.as_ref(),
            &rots,
        )?;

        cost.total_s = sw_total.stop();
        if cost.peak_rss_mib == 0.0 {
            cost.peak_rss_mib = timer::peak_rss_mib();
        }
        Ok((PreparedModel { params, rots, quantized: true, method: pcfg.method }, cost))
    }

    /// Build the native serving engine for a prepared model (the `serve`
    /// pipeline entry): quantized methods get INT4-packed weights, 4-bit
    /// paged KV and the method's online rotations; fp stays dense.
    ///
    /// The pack is itself an RTN weight quantizer, so prepare the model
    /// with `WeightQuantizer::None` to make the serve grid the sole
    /// weight quantizer (RTN-prepared weights are a fixpoint; GPTQ
    /// weights get re-gridded with ≤ half-step movement).
    pub fn serve_engine(
        &self,
        pm: &PreparedModel,
        scfg: &crate::serve::ServeConfig,
    ) -> Result<crate::serve::Engine> {
        let mut scfg = scfg.clone();
        let model = self.serve_model(pm, &mut scfg)?;
        crate::serve::Engine::new(model, &scfg)
    }

    /// The model half of [`Self::serve_engine`], for callers that build
    /// their own engine wrapper (the serving daemon): packs the weights
    /// and — for the fp baseline — rewrites `scfg.kv_quant` to an fp KV
    /// cache, so pass the same `scfg` on to the engine constructor.
    pub fn serve_model(
        &self,
        pm: &PreparedModel,
        scfg: &mut crate::serve::ServeConfig,
    ) -> Result<crate::serve::ServeModel> {
        let spec = if pm.quantized {
            Some(crate::serve::ServeQuantSpec::paper_default(
                pm.rots.r3.clone(),
                pm.rots.r4.clone(),
                pm.rots.r5.clone(),
            ))
        } else {
            // fp baseline: serve it as a real fp baseline — a 4-bit KV
            // cache without R3 shaping would silently degrade it
            scfg.kv_quant = crate::config::KvQuant::Fp;
            None
        };
        crate::serve::ServeModel::from_params(&pm.params, spec)
    }
}
