//! Typed configuration for models, quantization, calibration and the
//! pipeline. Model configs mirror `python/compile/model.py::PRESETS` and
//! are cross-checked against `artifacts/manifest.json` at runtime.

pub mod quantcfg;

pub use quantcfg::{KvQuant, QuantScheme, WeightQuantizer};

/// The quantization method under evaluation (rows of paper Table 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// Full precision (the "16-bit" row).
    Fp16,
    /// Weight-only GPTQ, no rotations — collapses at W4A4 (paper row "GPTQ").
    GptqOnly,
    /// Random Hadamard R1/R2 (Ashkboos et al. 2024b).
    QuaRot,
    /// End-to-end learned R1 via CE loss (Liu et al. 2024), lite variant.
    SpinQuant,
    /// Kurtosis-learned R1/R2 — the paper's contribution.
    KurTail,
}

impl Method {
    pub fn all() -> [Method; 5] {
        [Method::Fp16, Method::GptqOnly, Method::QuaRot, Method::SpinQuant, Method::KurTail]
    }

    pub fn label(&self) -> &'static str {
        match self {
            Method::Fp16 => "16-bit",
            Method::GptqOnly => "GPTQ",
            Method::QuaRot => "QuaRot",
            Method::SpinQuant => "SpinQuant",
            Method::KurTail => "KurTail",
        }
    }

    pub fn uses_rotations(&self) -> bool {
        matches!(self, Method::QuaRot | Method::SpinQuant | Method::KurTail)
    }
}

/// Calibration settings (paper §4 Setup + §5.3 ablations).
#[derive(Debug, Clone)]
pub struct CalibConfig {
    /// Which synthetic corpus to calibrate on (Table 6 ablation).
    pub dataset: String,
    /// Number of calibration sequences (Table 7 ablation; paper: 512).
    pub n_samples: usize,
    /// Cayley-Adam iterations for rotation learning (paper: 100).
    pub iters: usize,
    /// Learning rate for rotation optimization.
    pub lr: f32,
    /// RNG seed for sampling + shuffling.
    pub seed: u64,
}

impl Default for CalibConfig {
    fn default() -> Self {
        Self { dataset: "combined".into(), n_samples: 512, iters: 100, lr: 0.05, seed: 0 }
    }
}

/// End-to-end pipeline configuration for one experiment run.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub model: String,
    pub method: Method,
    pub weight_quantizer: WeightQuantizer,
    pub calib: CalibConfig,
    /// Evaluation batches for perplexity (more = tighter estimate).
    pub eval_batches: usize,
    pub seed: u64,
}

impl PipelineConfig {
    pub fn new(model: &str, method: Method) -> Self {
        Self {
            model: model.into(),
            method,
            weight_quantizer: WeightQuantizer::Gptq,
            calib: CalibConfig::default(),
            eval_batches: 8,
            seed: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_labels_unique() {
        let labels: Vec<_> = Method::all().iter().map(|m| m.label()).collect();
        let mut dedup = labels.clone();
        dedup.dedup();
        assert_eq!(labels.len(), dedup.len());
    }

    #[test]
    fn pipeline_config_defaults() {
        let c = PipelineConfig::new("small", Method::KurTail);
        assert_eq!(c.model, "small");
        assert_eq!(c.calib.n_samples, 512);
        assert_eq!(c.calib.iters, 100);
    }
}
