//! Quantizer scheme descriptors (paper §4 Setup).

/// Weight quantization algorithm (Table 2 uses GPTQ; Tables 4/10 use RTN).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightQuantizer {
    /// Round-to-nearest, per-output-channel symmetric.
    Rtn,
    /// GPTQ (Frantar et al. 2022): Hessian-aware with error feedback.
    Gptq,
    /// Leave weights in fp (for ablations of activation-only quant).
    None,
}

impl WeightQuantizer {
    pub fn label(&self) -> &'static str {
        match self {
            WeightQuantizer::Rtn => "RTN",
            WeightQuantizer::Gptq => "GPTQ",
            WeightQuantizer::None => "none",
        }
    }
}

/// Uniform quantization scheme for a tensor group.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantScheme {
    pub bits: u32,
    pub symmetric: bool,
    /// Dynamic-range clip quantile (activations: 0.98; weights: none).
    pub clip_quantile: Option<f32>,
    /// Scale-group size along the input (row) axis for packed weight
    /// storage: `None` = one scale per output channel (the classic RTN
    /// grid), `Some(g)` = a scale per `g` consecutive input rows.
    pub group: Option<usize>,
}

impl QuantScheme {
    /// Paper default for activations: 4-bit symmetric per-token, 0.98 clip.
    pub fn act4() -> Self {
        Self { bits: 4, symmetric: true, clip_quantile: Some(0.98), group: None }
    }

    /// Paper default for weights: 4-bit symmetric per-channel.
    pub fn weight4() -> Self {
        Self { bits: 4, symmetric: true, clip_quantile: None, group: None }
    }

    /// 4-bit symmetric weights with per-`g`-row scale groups (the serving
    /// engine's packed-storage grid; `serve::Int4Weight`).
    pub fn weight4_grouped(g: usize) -> Self {
        Self { group: Some(g), ..Self::weight4() }
    }

    /// Paper default for KV cache: 4-bit asymmetric per-token.
    pub fn kv4() -> Self {
        Self { bits: 4, symmetric: false, clip_quantile: None, group: None }
    }

    /// Half of the symmetric integer grid: 2^(b-1) − 1.
    pub fn qmax(&self) -> f32 {
        ((1u32 << (self.bits - 1)) - 1) as f32
    }

    /// Full asymmetric grid size: 2^b − 1.
    pub fn levels(&self) -> f32 {
        ((1u32 << self.bits) - 1) as f32
    }
}

/// KV-cache quantization switch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvQuant {
    Fp,
    Asym4,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grids() {
        assert_eq!(QuantScheme::act4().qmax(), 7.0);
        assert_eq!(QuantScheme::kv4().levels(), 15.0);
        let s8 = QuantScheme { bits: 8, symmetric: true, clip_quantile: None, group: None };
        assert_eq!(s8.qmax(), 127.0);
    }

    #[test]
    fn grouped_scheme() {
        let g = QuantScheme::weight4_grouped(64);
        assert_eq!(g.group, Some(64));
        assert_eq!(g.bits, 4);
        assert_eq!(QuantScheme::weight4().group, None);
    }
}
