//! Weight/activation quantizers: fake-quant primitives, RTN, GPTQ, and
//! whole-model weight quantization over the stacked parameter store.

pub mod fakequant;
pub mod gptq;
pub mod rtn;
pub mod weights;

pub use fakequant::{
    fake_quant_rows, fake_quant_rows_asym, optimal_step, rotate_fake_quant_rows, row_mse_at_step,
};
pub use gptq::gptq_quantize;
pub use rtn::rtn_quantize;
pub use weights::{quantize_weights, HessianSet};
