//! Whole-model weight quantization over the stacked parameter store.
//!
//! Hessians are accumulated as raw Grams of the *unrotated* fp
//! activations during layer-wise capture (once per model), then
//! transformed per method at quantize time: if a rotation M transforms a
//! linear's input (z → z·M), its Gram transforms as G → MᵀGM. This lets
//! one capture pass serve every method row of Table 2.

use anyhow::Result;

use crate::config::{QuantScheme, WeightQuantizer};
use crate::model::{LayerTaps, Params};
use crate::rotation::{blockdiag_heads, RotationSet};
use crate::tensor::matmul::{gram_accumulate, gram_accumulate_rmsnorm, matmul};
use crate::tensor::Tensor;

use super::gptq::{gptq_quantize_with_factor, GptqFactor};
use super::rtn::rtn_quantize_stacked;

/// Raw per-layer input Grams (pre-rotation) from the capture stream.
pub struct HessianSet {
    /// gram(rmsnorm(mhsa_in)) per layer — wq/wk/wv inputs.
    pub g_attn_in: Vec<Tensor>,
    /// gram(rmsnorm(ffn_in)) per layer — wg/wu/wr inputs.
    pub g_ffn_in: Vec<Tensor>,
    /// gram(attn_out) per layer — wo input.
    pub g_attn_out: Vec<Tensor>,
    /// gram(ffn_mid) per layer — wd input (F = d_ff·E for MoE).
    pub g_ffn_mid: Vec<Tensor>,
}

impl HessianSet {
    pub fn new(n_layers: usize, d: usize, f_mid: usize) -> Self {
        Self {
            g_attn_in: (0..n_layers).map(|_| Tensor::zeros(&[d, d])).collect(),
            g_ffn_in: (0..n_layers).map(|_| Tensor::zeros(&[d, d])).collect(),
            g_attn_out: (0..n_layers).map(|_| Tensor::zeros(&[d, d])).collect(),
            g_ffn_mid: (0..n_layers).map(|_| Tensor::zeros(&[f_mid, f_mid])).collect(),
        }
    }

    /// Accumulate one batch's taps for one layer. The norm→gram path is
    /// fused (`gram_accumulate_rmsnorm`): no full normed activation copy
    /// is materialized, and the result is bitwise identical to the
    /// two-step version this replaced.
    pub fn accumulate(&mut self, taps: &LayerTaps) {
        let l = taps.layer;
        gram_accumulate_rmsnorm(&mut self.g_attn_in[l], &taps.mhsa_in);
        gram_accumulate_rmsnorm(&mut self.g_ffn_in[l], &taps.ffn_in);
        gram_accumulate(&mut self.g_attn_out[l], &taps.attn_out);
        gram_accumulate(&mut self.g_ffn_mid[l], &taps.ffn_mid);
    }
}

/// G → MᵀGM (input-rotation transform of a Gram matrix).
fn rotate_gram(g: &Tensor, m: &Tensor) -> Tensor {
    matmul(&matmul(&m.t(), g), m)
}

/// Quantize every transformer linear of `params` in place.
///
/// `params` must already be norm-folded and rotation-fused; `rots` is
/// used only to transform the Hessians into the fused bases. Embedding
/// and head stay fp (standard practice, see DESIGN.md).
pub fn quantize_weights(
    params: &mut Params,
    quantizer: WeightQuantizer,
    scheme: &QuantScheme,
    hessians: Option<&HessianSet>,
    rots: &RotationSet,
) -> Result<()> {
    if quantizer == WeightQuantizer::None {
        return Ok(());
    }
    let meta = params.meta.clone();
    let use_gptq = quantizer == WeightQuantizer::Gptq;
    anyhow::ensure!(
        !use_gptq || hessians.is_some(),
        "GPTQ weight quantization needs captured Hessians"
    );

    let attn_names: &[&str] = &["wq", "wk", "wv"];
    let ffn_in_names: &[&str] = match meta.arch.as_str() {
        "llama" => &["wg", "wu"],
        "phi" => &["wu"],
        "moe" => &["wg", "wu"],
        a => anyhow::bail!("unknown arch {a}"),
    };

    // Router: tiny output dim — RTN regardless (documented).
    if params.has("wr") {
        params.set("wr", rtn_quantize_stacked(params.get("wr"), scheme));
    }

    if !use_gptq {
        for name in attn_names.iter().chain(ffn_in_names).chain(&["wo", "wd"]) {
            params.set(name, rtn_quantize_stacked(params.get(name), scheme));
        }
        return Ok(());
    }

    let hs = hessians.unwrap();
    let d = meta.d_model;
    let eye_d = Tensor::eye(d);
    let r1 = rots.r1.as_ref().unwrap_or(&eye_d);
    let r4b = blockdiag_heads(&rots.r4, meta.n_heads);

    for l in 0..meta.n_layers {
        // wq/wk/wv: input = rmsnorm(x)·R1 (one shared factor — §Perf)
        let f_attn = GptqFactor::prepare(&rotate_gram(&hs.g_attn_in[l], r1));
        for name in attn_names {
            let w = params.get(name).index_axis0(l);
            let q = gptq_quantize_with_factor(&w, &f_attn, scheme);
            let mut stack = params.get(name).clone();
            stack.set_axis0(l, &q);
            params.set(name, stack);
        }
        // wo: input = attn_out · blockdiag(R2_l) · blockdiag(R4)
        let m_wo = if rots.r2.is_empty() {
            r4b.clone()
        } else {
            matmul(&blockdiag_heads(&rots.r2[l], meta.n_heads), &r4b)
        };
        let f_wo = GptqFactor::prepare(&rotate_gram(&hs.g_attn_out[l], &m_wo));
        let q_wo = gptq_quantize_with_factor(&params.get("wo").index_axis0(l), &f_wo, scheme);
        let mut wo = params.get("wo").clone();
        wo.set_axis0(l, &q_wo);
        params.set("wo", wo);

        // FFN input linears: input = rmsnorm(h)·R1 (one shared factor)
        let f_ffn = GptqFactor::prepare(&rotate_gram(&hs.g_ffn_in[l], r1));
        for name in ffn_in_names {
            let w = params.get(name).index_axis0(l);
            let q = if meta.arch == "moe" {
                // per-expert matrices share the same input Hessian
                let mut out = w.clone();
                for e in 0..meta.n_experts {
                    out.set_axis0(e, &gptq_quantize_with_factor(&w.index_axis0(e), &f_ffn, scheme));
                }
                out
            } else {
                gptq_quantize_with_factor(&w, &f_ffn, scheme)
            };
            let mut stack = params.get(name).clone();
            stack.set_axis0(l, &q);
            params.set(name, stack);
        }

        // wd: input = ffn_mid · R5 (per-expert diagonal block for MoE)
        let wd_l = params.get("wd").index_axis0(l);
        let q_wd = if meta.arch == "moe" {
            let ff = meta.d_ff;
            let mut out = wd_l.clone();
            for e in 0..meta.n_experts {
                let g_e = diag_block(&hs.g_ffn_mid[l], e * ff, ff);
                let f_e = GptqFactor::prepare(&rotate_gram(&g_e, &rots.r5));
                out.set_axis0(e, &gptq_quantize_with_factor(&wd_l.index_axis0(e), &f_e, scheme));
            }
            out
        } else {
            let f_wd = GptqFactor::prepare(&rotate_gram(&hs.g_ffn_mid[l], &rots.r5));
            gptq_quantize_with_factor(&wd_l, &f_wd, scheme)
        };
        let mut wd = params.get("wd").clone();
        wd.set_axis0(l, &q_wd);
        params.set("wd", wd);
    }
    Ok(())
}

/// Extract the (off, off)+(n, n) diagonal block of a square matrix.
fn diag_block(g: &Tensor, off: usize, n: usize) -> Tensor {
    let big = g.shape[0];
    let mut out = Tensor::zeros(&[n, n]);
    for i in 0..n {
        for j in 0..n {
            out.data[i * n + j] = g.data[(off + i) * big + (off + j)];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::tests_support::fake_llama_meta;
    use crate::util::Rng;

    fn fake_taps(meta: &crate::runtime::ConfigMeta, l: usize, rng: &mut Rng) -> LayerTaps {
        let (b, t, d, ff) = (2, meta.seq_len, meta.d_model, meta.d_ff);
        LayerTaps {
            layer: l,
            mhsa_in: Tensor::randn(&[b, t, d], 1.0, rng),
            ffn_in: Tensor::randn(&[b, t, d], 1.0, rng),
            v_heads: Tensor::randn(&[b, t, meta.n_heads, meta.d_head], 1.0, rng),
            attn_out: Tensor::randn(&[b, t, d], 1.0, rng),
            ffn_mid: Tensor::randn(&[b, t, ff], 1.0, rng),
        }
    }

    #[test]
    fn rtn_path_quantizes_all_linears() {
        let meta = fake_llama_meta();
        let mut rng = Rng::new(0);
        let mut p = Params::init(&meta, &mut rng);
        let orig = p.clone();
        let rots = RotationSet::identity(meta.d_head, meta.d_ff);
        quantize_weights(&mut p, WeightQuantizer::Rtn, &QuantScheme::weight4(), None, &rots)
            .unwrap();
        for name in ["wq", "wk", "wv", "wo", "wg", "wu", "wd"] {
            assert!(p.get(name).max_abs_diff(orig.get(name)) > 0.0, "{name} unchanged");
        }
        // embedding/head untouched
        assert_eq!(p.get("embed").data, orig.get("embed").data);
        assert_eq!(p.get("head").data, orig.get("head").data);
    }

    #[test]
    fn gptq_path_runs_and_stays_finite() {
        let meta = fake_llama_meta();
        let mut rng = Rng::new(1);
        let mut p = Params::init(&meta, &mut rng);
        let mut hs = HessianSet::new(meta.n_layers, meta.d_model, meta.d_ff);
        for l in 0..meta.n_layers {
            for _ in 0..4 {
                hs.accumulate(&fake_taps(&meta, l, &mut rng));
            }
        }
        let rots = RotationSet::identity(meta.d_head, meta.d_ff);
        quantize_weights(&mut p, WeightQuantizer::Gptq, &QuantScheme::weight4(), Some(&hs), &rots)
            .unwrap();
        for name in ["wq", "wo", "wd"] {
            assert!(p.get(name).all_finite(), "{name}");
        }
    }

    #[test]
    fn accumulate_fuses_norm_gram_bitwise() {
        let meta = fake_llama_meta();
        let mut rng = Rng::new(5);
        let taps = fake_taps(&meta, 0, &mut rng);
        let mut hs = HessianSet::new(meta.n_layers, meta.d_model, meta.d_ff);
        hs.accumulate(&taps);
        let mut want = Tensor::zeros(&[meta.d_model, meta.d_model]);
        gram_accumulate(&mut want, &crate::model::rmsnorm_rows(&taps.mhsa_in));
        assert_eq!(hs.g_attn_in[0].data, want.data);
        let mut want_out = Tensor::zeros(&[meta.d_model, meta.d_model]);
        gram_accumulate(&mut want_out, &taps.attn_out);
        assert_eq!(hs.g_attn_out[0].data, want_out.data);
    }

    #[test]
    fn diag_block_extracts() {
        let g = Tensor::new((0..16).map(|x| x as f32).collect(), vec![4, 4]);
        let b = diag_block(&g, 2, 2);
        assert_eq!(b.data, vec![10.0, 11.0, 14.0, 15.0]);
    }

    #[test]
    fn gptq_requires_hessians() {
        let meta = fake_llama_meta();
        let mut rng = Rng::new(2);
        let mut p = Params::init(&meta, &mut rng);
        let rots = RotationSet::identity(meta.d_head, meta.d_ff);
        assert!(quantize_weights(&mut p, WeightQuantizer::Gptq, &QuantScheme::weight4(), None, &rots)
            .is_err());
    }
}
