//! Host-side fake quantization — the Rust mirror of
//! `python/compile/kernels/ref.py` (same semantics, pinned by tests).
//! Used for weight quantization (RTN grids, GPTQ rounding) and for the
//! sensitivity / success-rate experiments that run entirely on captured
//! activations.

use crate::config::QuantScheme;
use crate::tensor::matmul::{matmul_packed_chunk, pack_b};
use crate::tensor::Tensor;
use crate::util::par::{self, num_threads};

/// Per-row symmetric scale with optional quantile clip (activations).
pub fn row_scale(row: &[f32], s: &QuantScheme) -> f32 {
    let mut buf = Vec::new();
    row_scale_buf(row, s, &mut buf)
}

/// `row_scale` with a caller-owned scratch buffer and an O(n)
/// selection instead of a full sort (§Perf: this is the inner loop of
/// every activation fake-quant on the host).
pub fn row_scale_buf(row: &[f32], s: &QuantScheme, buf: &mut Vec<f32>) -> f32 {
    let amax = match s.clip_quantile {
        Some(q) if q < 1.0 => {
            buf.clear();
            buf.extend(row.iter().map(|x| x.abs()));
            let n = buf.len();
            let pos = q.clamp(0.0, 1.0) * (n - 1) as f32;
            let lo = pos.floor() as usize;
            let frac = pos - lo as f32;
            let (_, v_lo, rest) =
                buf.select_nth_unstable_by(lo, |a, b| a.partial_cmp(b).unwrap());
            let v_lo = *v_lo;
            if frac == 0.0 || rest.is_empty() {
                v_lo
            } else {
                let v_hi = rest.iter().cloned().fold(f32::INFINITY, f32::min);
                v_lo * (1.0 - frac) + v_hi * frac
            }
        }
        _ => row.iter().fold(0.0f32, |a, &x| a.max(x.abs())),
    };
    amax.max(1e-8) / s.qmax()
}

/// Symmetric fake-quant of one row given its scale.
pub fn fq_row_sym(row: &mut [f32], scale: f32, s: &QuantScheme) {
    let qmax = s.qmax();
    for v in row.iter_mut() {
        let q = (*v / scale).round().clamp(-qmax, qmax);
        *v = q * scale;
    }
}

/// Per-token (row) symmetric fake-quant of a (…, d) tensor.
/// Row-parallel; per-row math identical to [`fake_quant_rows_ref`].
pub fn fake_quant_rows(x: &Tensor, s: &QuantScheme) -> Tensor {
    rotate_fake_quant_rows(x, None, s)
}

/// [`fake_quant_rows`] with an explicit thread budget (tests / tuning).
pub fn fake_quant_rows_with_threads(x: &Tensor, s: &QuantScheme, threads: usize) -> Tensor {
    rotate_fake_quant_threads(x, None, s, threads)
}

/// Fused rotate→fake-quant: `fq(x·R)` without materializing the rotated
/// intermediate — each thread rotates its row-chunk straight into the
/// output buffer (packed microkernel) and quantizes it in place. This is
/// the online-quantization semantic of the paper (rotate activations,
/// then quantize) and the backing kernel of [`fake_quant_rows`]
/// (`rot = None` skips the rotation).
pub fn rotate_fake_quant_rows(x: &Tensor, rot: Option<&Tensor>, s: &QuantScheme) -> Tensor {
    rotate_fake_quant_threads(x, rot, s, num_threads())
}

fn rotate_fake_quant_threads(
    x: &Tensor,
    rot: Option<&Tensor>,
    s: &QuantScheme,
    threads: usize,
) -> Tensor {
    let (r, c) = x.as_2d();
    let mut out = Tensor::zeros(&x.shape);
    if r == 0 || c == 0 {
        return out;
    }
    if let Some(rm) = rot {
        assert_eq!(rm.shape, vec![c, c], "rotation must be ({c},{c})");
    }
    let packed = rot.map(|rm| pack_b(&rm.data, c, c, threads));
    par::par_row_chunks_mut(&mut out.data, c, 16, threads, |r0, ochunk| {
        let rows = ochunk.len() / c;
        match &packed {
            Some(p) => {
                // ochunk is zeroed, so += accumulates a plain product
                matmul_packed_chunk(&x.data[r0 * c..(r0 + rows) * c], p, ochunk, rows, c, c);
            }
            None => ochunk.copy_from_slice(&x.data[r0 * c..(r0 + rows) * c]),
        }
        let mut buf = Vec::with_capacity(c);
        for row in ochunk.chunks_exact_mut(c) {
            let scale = row_scale_buf(row, s, &mut buf);
            fq_row_sym(row, scale, s);
        }
    });
    out
}

/// Scalar reference fake-quant (original sequential loop; bench baseline).
pub fn fake_quant_rows_ref(x: &Tensor, s: &QuantScheme) -> Tensor {
    let (r, c) = x.as_2d();
    let mut out = x.clone();
    let mut buf = Vec::with_capacity(c);
    for i in 0..r {
        let row = &mut out.data[i * c..(i + 1) * c];
        let scale = row_scale_buf(row, s, &mut buf);
        fq_row_sym(row, scale, s);
    }
    out
}

/// Per-token asymmetric fake-quant (KV cache semantics), row-parallel.
pub fn fake_quant_rows_asym(x: &Tensor, s: &QuantScheme) -> Tensor {
    let (r, c) = x.as_2d();
    let levels = s.levels();
    let mut out = x.clone();
    if r == 0 || c == 0 {
        return out;
    }
    par::par_row_chunks_mut(&mut out.data, c, 16, num_threads(), |_r0, chunk| {
        for row in chunk.chunks_exact_mut(c) {
            let lo = row.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let scale = ((hi - lo).max(1e-8)) / levels;
            for v in row.iter_mut() {
                let q = ((*v - lo) / scale).round().clamp(0.0, levels);
                *v = q * scale + lo;
            }
        }
    });
    out
}

/// Quantization MSE of a row at a given step size (symmetric grid) —
/// the Γ(x, ε) sensitivity primitive (Chmiel et al. 2020, paper Fig. 1).
pub fn row_mse_at_step(row: &[f32], step: f32, s: &QuantScheme) -> f32 {
    let qmax = s.qmax();
    let mut mse = 0.0f64;
    for &v in row {
        let q = (v / step).round().clamp(-qmax, qmax);
        let e = (v - q * step) as f64;
        mse += e * e;
    }
    (mse / row.len() as f64) as f32
}

/// Grid-search the MSE-optimal symmetric step size for a row.
pub fn optimal_step(row: &[f32], s: &QuantScheme) -> f32 {
    let absmax = row.iter().fold(0.0f32, |a, &x| a.max(x.abs())).max(1e-8);
    let hi = absmax / s.qmax();
    let mut best = (f32::INFINITY, hi);
    // 64-point geometric sweep from hi/16 to hi covers the optimum for
    // everything from uniform to heavy-tailed rows
    for i in 0..64 {
        let step = hi * (16.0f32).powf(-(1.0 - i as f32 / 63.0));
        let mse = row_mse_at_step(row, step, s);
        if mse < best.0 {
            best = (mse, step);
        }
    }
    best.1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert};
    use crate::util::Rng;

    fn act4() -> QuantScheme {
        QuantScheme::act4()
    }

    #[test]
    fn roundtrip_bounded_by_half_step() {
        let mut rng = Rng::new(0);
        let s = QuantScheme { clip_quantile: None, ..act4() };
        let x = Tensor::randn(&[16, 64], 1.0, &mut rng);
        let y = fake_quant_rows(&x, &s);
        for i in 0..16 {
            let scale = row_scale(x.row(i), &s);
            for (a, b) in x.row(i).iter().zip(y.row(i)) {
                assert!((a - b).abs() <= scale / 2.0 + 1e-6);
            }
        }
    }

    #[test]
    fn asym_beats_sym_on_shifted() {
        let mut rng = Rng::new(1);
        let x = Tensor::new((0..64 * 64).map(|_| 4.0 + rng.uniform()).collect(), vec![64, 64]);
        let sym = fake_quant_rows(&x, &QuantScheme { clip_quantile: None, ..act4() });
        let asym = fake_quant_rows_asym(&x, &QuantScheme::kv4());
        let mse_s = x.sub(&sym).frob_norm();
        let mse_a = x.sub(&asym).frob_norm();
        assert!(mse_a < mse_s / 2.0, "{mse_a} vs {mse_s}");
    }

    #[test]
    fn clip_helps_with_outliers() {
        let mut rng = Rng::new(2);
        let mut x = Tensor::randn(&[32, 256], 1.0, &mut rng);
        for i in 0..32 {
            x.row_mut(i)[0] *= 100.0;
        }
        let clipped = fake_quant_rows(&x, &act4());
        let unclipped = fake_quant_rows(&x, &QuantScheme { clip_quantile: None, ..act4() });
        // compare error on the bulk (excluding the outlier channel)
        let mut e_clip = 0.0;
        let mut e_no = 0.0;
        for i in 0..32 {
            for j in 1..256 {
                e_clip += (x.row(i)[j] - clipped.row(i)[j]).powi(2);
                e_no += (x.row(i)[j] - unclipped.row(i)[j]).powi(2);
            }
        }
        assert!(e_clip < e_no / 4.0, "{e_clip} vs {e_no}");
    }

    #[test]
    fn optimal_step_beats_absmax_on_gaussian() {
        let mut rng = Rng::new(3);
        let s = QuantScheme { clip_quantile: None, ..act4() };
        let row: Vec<f32> = (0..4096).map(|_| rng.normal()).collect();
        let naive = row_scale(&row, &s);
        let opt = optimal_step(&row, &s);
        assert!(row_mse_at_step(&row, opt, &s) <= row_mse_at_step(&row, naive, &s));
        // for gaussians the optimum is well below absmax/qmax
        assert!(opt < naive);
    }

    #[test]
    fn parallel_matches_ref_exactly() {
        let mut rng = Rng::new(21);
        let x = Tensor::randn(&[67, 96], 1.5, &mut rng);
        let s = act4();
        let want = fake_quant_rows_ref(&x, &s);
        for threads in [1usize, 2, 8] {
            let got = fake_quant_rows_with_threads(&x, &s, threads);
            assert_eq!(got.data, want.data, "t={threads}");
        }
    }

    #[test]
    fn fused_rotate_fq_matches_two_step() {
        use crate::tensor::hadamard::random_hadamard;
        use crate::tensor::matmul::rows_matmul;
        let mut rng = Rng::new(22);
        let x = Tensor::randn(&[83, 64], 1.0, &mut rng);
        let r = random_hadamard(64, &mut rng);
        let s = act4();
        let two_step = fake_quant_rows(&rows_matmul(&x, &r), &s);
        let fused = rotate_fake_quant_rows(&x, Some(&r), &s);
        // same grids, same rounding — only the rotation's fp summation
        // order could differ, and it doesn't (same kernel)
        assert!(fused.max_abs_diff(&two_step) < 1e-5);
    }

    #[test]
    fn prop_fq_idempotent() {
        check(50, |rng| {
            let s = QuantScheme { clip_quantile: None, ..QuantScheme::act4() };
            let x = Tensor::randn(&[4, 32], 1.0 + rng.uniform(), rng);
            let y = fake_quant_rows(&x, &s);
            let z = fake_quant_rows(&y, &s);
            prop_assert(y.max_abs_diff(&z) < 1e-5, "fq(fq(x)) == fq(x)")
        });
    }
}
