//! RTN weight quantization: round-to-nearest on a per-output-channel
//! symmetric grid (paper §4: "per-column (or per-channel) symmetric").
//!
//! Weights here are stored (in, out) — an output channel is a *column*.

use crate::config::QuantScheme;
use crate::tensor::Tensor;

/// Per-output-channel scales for a (k_in, n_out) weight matrix.
pub fn channel_scales(w: &Tensor, s: &QuantScheme) -> Vec<f32> {
    assert_eq!(w.rank(), 2);
    let (k, n) = (w.shape[0], w.shape[1]);
    let mut scales = vec![0.0f32; n];
    for i in 0..k {
        for j in 0..n {
            scales[j] = scales[j].max(w.data[i * n + j].abs());
        }
    }
    scales.iter().map(|&a| a.max(1e-8) / s.qmax()).collect()
}

/// RTN fake-quant of a 2-D weight (in, out) on per-column grids.
pub fn rtn_quantize(w: &Tensor, s: &QuantScheme) -> Tensor {
    let scales = channel_scales(w, s);
    let (k, n) = (w.shape[0], w.shape[1]);
    let qmax = s.qmax();
    let mut out = w.clone();
    for i in 0..k {
        for j in 0..n {
            let v = &mut out.data[i * n + j];
            let q = (*v / scales[j]).round().clamp(-qmax, qmax);
            *v = q * scales[j];
        }
    }
    out
}

/// RTN over a stacked weight (L, …, k, n): quantize each trailing 2-D
/// matrix independently (layers / experts get their own grids).
pub fn rtn_quantize_stacked(w: &Tensor, s: &QuantScheme) -> Tensor {
    if w.rank() == 2 {
        return rtn_quantize(w, s);
    }
    let mat = w.shape[w.rank() - 2] * w.shape[w.rank() - 1];
    let count = w.numel() / mat;
    let sub_shape = vec![w.shape[w.rank() - 2], w.shape[w.rank() - 1]];
    let mut out = w.clone();
    for i in 0..count {
        let sub = Tensor::new(w.data[i * mat..(i + 1) * mat].to_vec(), sub_shape.clone());
        let q = rtn_quantize(&sub, s);
        out.data[i * mat..(i + 1) * mat].copy_from_slice(&q.data);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert};
    use crate::util::Rng;

    #[test]
    fn error_bounded_by_half_step_per_channel() {
        let mut rng = Rng::new(0);
        let w = Tensor::randn(&[32, 16], 0.1, &mut rng);
        let s = QuantScheme::weight4();
        let q = rtn_quantize(&w, &s);
        let scales = channel_scales(&w, &s);
        for i in 0..32 {
            for j in 0..16 {
                assert!((w.data[i * 16 + j] - q.data[i * 16 + j]).abs() <= scales[j] / 2.0 + 1e-7);
            }
        }
    }

    #[test]
    fn stacked_matches_per_layer() {
        let mut rng = Rng::new(1);
        let w = Tensor::randn(&[3, 8, 4], 0.2, &mut rng);
        let s = QuantScheme::weight4();
        let q = rtn_quantize_stacked(&w, &s);
        for l in 0..3 {
            let ql = rtn_quantize(&w.index_axis0(l), &s);
            assert_eq!(q.index_axis0(l).data, ql.data);
        }
    }

    #[test]
    fn prop_grid_has_at_most_2b_levels() {
        check(30, |rng| {
            let s = QuantScheme::weight4();
            let w = Tensor::randn(&[16, 4], 0.3, rng);
            let q = rtn_quantize(&w, &s);
            let scales = channel_scales(&w, &s);
            for j in 0..4 {
                let mut vals: Vec<i64> = (0..16)
                    .map(|i| (q.data[i * 4 + j] / scales[j]).round() as i64)
                    .collect();
                vals.sort();
                vals.dedup();
                prop_assert(vals.len() <= 15, "≤ 2^4−1 distinct levels")?;
                prop_assert(
                    vals.iter().all(|&v| (-7..=7).contains(&v)),
                    "levels within symmetric grid",
                )?;
            }
            Ok(())
        });
    }
}
