//! GPTQ (Frantar et al. 2022) — Hessian-aware weight quantization with
//! error feedback, built entirely on the in-tree Cholesky (no LAPACK).
//!
//! For a linear y = x·W (W: k_in × n_out) with input Hessian H = XᵀX
//! accumulated from calibration activations, columns of Wᵀ (i.e. input
//! dims) are quantized one at a time; the rounding error of input-dim i
//! is propagated into the not-yet-quantized dims j > i weighted by
//! U[i,j]/U[i,i], where U = chol(H⁻¹) upper. This is the exact algorithm
//! of the reference implementation (act-order disabled, percdamp = 0.01).

use crate::config::QuantScheme;
use crate::tensor::linalg::{cholesky_upper, dampen, spd_inverse};
use crate::tensor::Tensor;

use super::rtn::channel_scales;

/// Precomputed GPTQ factor: U = chol(H⁻¹) upper for one Hessian.
/// Computing it costs O(k³); sharing it across the linears that see the
/// same input (wq/wk/wv; wg/wu; all MoE experts) is a §Perf win.
pub struct GptqFactor {
    u: Option<Tensor>, // None ⇒ Hessian unusable, fall back to RTN
    k: usize,
}

impl GptqFactor {
    pub fn prepare(h: &Tensor) -> GptqFactor {
        let k = h.shape[0];
        if !h.all_finite() {
            return GptqFactor { u: None, k };
        }
        let mut hd = h.clone();
        dampen(&mut hd, 0.01);
        let u = spd_inverse(&hd).and_then(|hi| cholesky_upper(&hi));
        GptqFactor { u, k }
    }
}

/// GPTQ-quantize W (k_in × n_out) against Hessian H (k × k).
/// Falls back to RTN if H is numerically unusable.
pub fn gptq_quantize(w: &Tensor, h: &Tensor, s: &QuantScheme) -> Tensor {
    gptq_quantize_with_factor(w, &GptqFactor::prepare(h), s)
}

/// GPTQ with a precomputed factor (shared across same-input linears).
pub fn gptq_quantize_with_factor(w: &Tensor, f: &GptqFactor, s: &QuantScheme) -> Tensor {
    assert_eq!(w.rank(), 2);
    let (k, n) = (w.shape[0], w.shape[1]);
    assert_eq!(f.k, k, "factor dim");
    let u = match &f.u {
        Some(u) => u,
        None => return super::rtn::rtn_quantize(w, s),
    };

    // per-output-channel grids fixed from the original weights (as GPTQ does)
    let scales = channel_scales(w, s);
    let qmax = s.qmax();

    // §Perf: work on Wᵀ (n_out, k_in) so the error propagation over the
    // remaining input dims is a contiguous AXPY against a contiguous row
    // of U — the naive (k, n) layout strides by n and was ~7× slower.
    //
    // The error feedback of output channel j only ever touches row j of
    // Wᵀ (U is read-only), so the channels split across threads with the
    // per-channel i-recursion untouched: bitwise-identical results at
    // every thread count.
    //
    // Per-channel cost is heavily *skewed* — a channel whose rounding
    // errors are zero (already-on-grid weights, pruned channels) skips
    // the O(k) AXPY at every step — which is exactly the case the
    // work-stealing `util::par` backend rebalances: the fixed channel
    // grid is finer than the worker count and idle workers pick up the
    // expensive chunks (`benches/kernels.rs` measures this as
    // `gptq_skewed_steal`). Chunk partition never affects results.
    let mut wt = w.t(); // (n, k), mutated with error feedback
    crate::util::par::par_row_chunks_mut(
        &mut wt.data,
        k,
        4,
        crate::util::par::num_threads(),
        |j0, chunk| {
            for (jr, row) in chunk.chunks_exact_mut(k).enumerate() {
                let scale = scales[j0 + jr];
                for i in 0..k {
                    let d = u.data[i * k + i].max(1e-10);
                    let u_row = &u.data[i * k + (i + 1)..(i + 1) * k]; // U[i, i+1..]
                    let v = row[i];
                    let q = (v / scale).round().clamp(-qmax, qmax) * scale;
                    row[i] = q;
                    let err = (v - q) / d;
                    if err != 0.0 {
                        for (dst, &uij) in row[i + 1..].iter_mut().zip(u_row) {
                            *dst -= err * uij;
                        }
                    }
                }
            }
        },
    );
    wt.t()
}

/// Hessian-weighted reconstruction error tr((W−Q)ᵀ H (W−Q)) / numel —
/// the quantity GPTQ minimizes; used by tests and the bench.
pub fn hessian_error(w: &Tensor, q: &Tensor, h: &Tensor) -> f32 {
    let diff = w.sub(q);
    let hd = crate::tensor::matmul::matmul(h, &diff);
    let mut tr = 0.0f64;
    let (k, n) = (diff.shape[0], diff.shape[1]);
    for i in 0..k {
        for j in 0..n {
            tr += (diff.data[i * n + j] * hd.data[i * n + j]) as f64;
        }
    }
    (tr / (k * n) as f64) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::rtn_quantize;
    use crate::tensor::matmul::gram;
    use crate::util::Rng;

    fn setup(k: usize, n: usize, seed: u64) -> (Tensor, Tensor) {
        let mut rng = Rng::new(seed);
        let w = Tensor::randn(&[k, n], 0.3, &mut rng);
        // correlated inputs make the Hessian non-diagonal (where GPTQ wins)
        let base = Tensor::randn(&[256, k], 1.0, &mut rng);
        let mix = Tensor::randn(&[k, k], 0.4, &mut rng).add(&Tensor::eye(k));
        let x = crate::tensor::matmul::matmul(&base, &mix);
        (w, gram(&x))
    }

    #[test]
    fn gptq_beats_rtn_on_hessian_error() {
        for seed in 0..3 {
            let (w, h) = setup(24, 12, seed);
            let s = QuantScheme::weight4();
            let g = gptq_quantize(&w, &h, &s);
            let r = rtn_quantize(&w, &s);
            let eg = hessian_error(&w, &g, &h);
            let er = hessian_error(&w, &r, &h);
            assert!(eg < er, "seed {seed}: gptq {eg} !< rtn {er}");
        }
    }

    #[test]
    fn gptq_stays_on_grid() {
        let (w, h) = setup(16, 8, 7);
        let s = QuantScheme::weight4();
        let g = gptq_quantize(&w, &h, &s);
        let scales = channel_scales(&w, &s);
        for i in 0..16 {
            for j in 0..8 {
                let q = g.data[i * 8 + j] / scales[j];
                assert!((q - q.round()).abs() < 1e-4, "off grid: {q}");
                assert!(q.round().abs() <= 7.0);
            }
        }
    }

    #[test]
    fn identity_hessian_reduces_to_rtn() {
        let mut rng = Rng::new(9);
        let w = Tensor::randn(&[12, 6], 0.3, &mut rng);
        let h = Tensor::eye(12).scale(100.0);
        let s = QuantScheme::weight4();
        let g = gptq_quantize(&w, &h, &s);
        let r = rtn_quantize(&w, &s);
        assert!(g.max_abs_diff(&r) < 1e-5);
    }

    #[test]
    fn degenerate_hessian_falls_back() {
        let mut rng = Rng::new(10);
        let w = Tensor::randn(&[8, 4], 0.3, &mut rng);
        let h = Tensor::zeros(&[8, 8]); // rank-0: damping saves it, but make it NaN to force fallback
        let mut h_bad = h.clone();
        h_bad.data[0] = f32::NAN;
        let s = QuantScheme::weight4();
        let g = gptq_quantize(&w, &h_bad, &s);
        assert!(g.all_finite());
    }
}
