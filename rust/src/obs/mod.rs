//! Observability: zero-dependency telemetry for the serving stack.
//!
//! Three pieces, threaded through engine → scheduler → KV pool → daemon:
//!
//! * [`metrics`] — lock-free counters/gauges, log2-bucket latency
//!   histograms (mergeable, snapshot-able, p50/p90/p99 derivable), and a
//!   registry that renders Prometheus text exposition for `GET /metrics`.
//! * [`log`] — structured, leveled log lines (`KURTAIL_LOG=json|text|off`),
//!   one per request lifecycle event, emitted by the daemon.
//! * [`EngineObs`] / [`RequestSpan`] — the engine's metric bundle and the
//!   per-request trace span (queue-wait / prefill / decode) attached to
//!   every completion.
//!
//! ## Knobs
//!
//! * `KURTAIL_OBS` — unset or any value but `0` → instrumentation on
//!   (default); `0` → the engine skips all timing and recording, for A/B
//!   overhead measurement (`benches/serve.rs` gates the difference ≤ 2%).
//!   `ServeConfig::obs` overrides the env per engine.
//! * `KURTAIL_LOG` — log line format (`text` default, `json`, `off`).
//!
//! ## Hot-path contract
//!
//! Recording is `Instant::now()` reads plus relaxed atomic adds on
//! pre-registered handles — no locks, no allocation — so the zero-alloc
//! steady-state decode test holds with observability enabled, and no
//! instrumentation touches the math: token streams are bitwise identical
//! with `KURTAIL_OBS=0` and `=1`.

pub mod log;
pub mod metrics;

use std::sync::Arc;

pub use log::{log_event, LogFormat, LogLevel, LogValue};
pub use metrics::{
    global, Counter, Gauge, HistSnapshot, Histogram, Registry, StageTimer, HIST_BUCKETS,
};

/// Decode phase indices into [`EngineObs::phases`] (histogram per phase,
/// labeled `phase="..."` on the `kurtail_decode_phase_seconds` family).
pub const PHASE_ACT_QUANT: usize = 0;
pub const PHASE_GEMM: usize = 1;
pub const PHASE_ATTENTION: usize = 2;
pub const PHASE_EPILOGUE: usize = 3;
pub const PHASE_SAMPLING: usize = 4;
pub const N_PHASES: usize = 5;

/// Phase label values, indexed by the `PHASE_*` constants.
pub const PHASE_NAMES: [&str; N_PHASES] =
    ["act_quant", "gemm", "attention", "epilogue", "sampling"];

/// Parse rule for `KURTAIL_OBS`: unset → on, `0` → off, anything else →
/// on (same rule as the engine's other feature flags).
fn obs_flag(var: Option<&str>) -> bool {
    var.map(|v| v.trim() != "0").unwrap_or(true)
}

/// Whether instrumentation is enabled for this process (`KURTAIL_OBS`).
pub fn obs_enabled() -> bool {
    obs_flag(std::env::var("KURTAIL_OBS").ok().as_deref())
}

/// Per-request trace span: where a request spent its life, in ns.
/// Filled by the engine at retirement and carried on every `Completion`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RequestSpan {
    /// Submit → admission (time spent queued).
    pub queue_wait_ns: u64,
    /// Prefill forward + first sampled token.
    pub prefill_ns: u64,
    /// Admission → retirement, minus prefill (decode steps + co-batching
    /// waits).
    pub decode_ns: u64,
    /// Tokens generated (including the prefill-sampled first token).
    pub new_tokens: u64,
}

/// The serving engine's metric bundle: every series the engine records,
/// registered once at construction against the engine's own registry.
///
/// Each engine owns a fresh [`Registry`] (parallel engines/tests must
/// not share series); the daemon exposes its engine's registry on
/// `GET /metrics`. All fields are `Arc`s, so the bundle is `Clone` and
/// handles can be read from other threads while the engine records.
#[derive(Clone)]
pub struct EngineObs {
    /// Master switch (`KURTAIL_OBS` / `ServeConfig::obs`): when false the
    /// engine skips every clock read and record call.
    pub enabled: bool,
    pub registry: Arc<Registry>,
    /// Submit → admission wait (also drives the daemon's `Retry-After`).
    pub queue_wait: Arc<Histogram>,
    /// Submit → first token.
    pub ttft: Arc<Histogram>,
    /// Prefill duration per admitted request.
    pub prefill: Arc<Histogram>,
    /// One batched decode step (all lanes), including sampling.
    pub decode_step: Arc<Histogram>,
    /// Per-phase time per forward pass, indexed by `PHASE_*`.
    pub phases: [Arc<Histogram>; N_PHASES],
    pub kv_free_blocks: Arc<Gauge>,
    pub kv_used_blocks: Arc<Gauge>,
    pub kv_withheld_blocks: Arc<Gauge>,
    /// Σ(refs − 1) over pool blocks — KV blocks lanes hold without
    /// owning storage (the prefix-sharing memory win).
    pub kv_shared_block_refs: Arc<Gauge>,
    pub live_lanes: Arc<Gauge>,
    pub queued_requests: Arc<Gauge>,
    pub prefill_tokens: Arc<Counter>,
    /// Prompt positions served from shared blocks instead of compute.
    pub prefix_shared_tokens: Arc<Counter>,
    /// Bounded prefill forwards run (chunked-prefill cadence).
    pub prefill_chunks: Arc<Counter>,
    pub decode_tokens: Arc<Counter>,
    pub requests_admitted: Arc<Counter>,
    pub requests_retired: Arc<Counter>,
    pub requests_shed: Arc<Counter>,
    pub requests_canceled: Arc<Counter>,
    /// Lanes snapshotted and requeued under KV pressure (not failures;
    /// each stream resumes byte-identically).
    pub requests_preempted: Arc<Counter>,
    /// Preempted or restart-orphaned lanes re-admitted and continued.
    pub requests_resumed: Arc<Counter>,
    /// Positions re-run through prefill on resume (the recompute cost
    /// of transparent degradation).
    pub resume_recompute_tokens: Arc<Counter>,
    /// Request retirements per second × 1000 (EWMA, maintained by the
    /// daemon host loop) — drives the block-free-time `Retry-After`
    /// fallback when the queue-wait histogram is still empty.
    pub retire_rate_milli: Arc<Gauge>,
}

impl EngineObs {
    /// Build the bundle against a fresh registry.
    pub fn new(enabled: bool) -> Self {
        Self::with_registry(enabled, Arc::new(Registry::new()))
    }

    pub fn with_registry(enabled: bool, registry: Arc<Registry>) -> Self {
        let r = &registry;
        let phases = PHASE_NAMES.map(|p| {
            r.histogram(
                "kurtail_decode_phase_seconds",
                "Per-phase wall-clock of one forward pass",
                &[("phase", p)],
            )
        });
        Self {
            enabled,
            queue_wait: r.histogram(
                "kurtail_queue_wait_seconds",
                "Request wait from submit to admission",
                &[],
            ),
            ttft: r.histogram(
                "kurtail_ttft_seconds",
                "Time from submit to first generated token",
                &[],
            ),
            prefill: r.histogram(
                "kurtail_prefill_seconds",
                "Prefill duration per admitted request",
                &[],
            ),
            decode_step: r.histogram(
                "kurtail_decode_step_seconds",
                "One batched decode step across all live lanes",
                &[],
            ),
            phases,
            kv_free_blocks: r.gauge("kurtail_kv_free_blocks", "KV pool blocks on the free list", &[]),
            kv_used_blocks: r.gauge("kurtail_kv_used_blocks", "KV pool blocks held by lanes", &[]),
            kv_withheld_blocks: r.gauge(
                "kurtail_kv_withheld_blocks",
                "KV pool blocks withheld by fault injection",
                &[],
            ),
            kv_shared_block_refs: r.gauge(
                "kurtail_kv_shared_block_refs",
                "KV pool blocks held by more than one lane (sum of refs minus one)",
                &[],
            ),
            live_lanes: r.gauge("kurtail_live_lanes", "Lanes currently decoding", &[]),
            queued_requests: r.gauge("kurtail_queued_requests", "Requests waiting for admission", &[]),
            prefill_tokens: r.counter("kurtail_prefill_tokens_total", "Prompt tokens prefilled (computed positions only)", &[]),
            prefix_shared_tokens: r.counter(
                "kurtail_prefix_shared_tokens_total",
                "Prompt tokens served from shared KV blocks instead of compute",
                &[],
            ),
            prefill_chunks: r.counter(
                "kurtail_prefill_chunks_total",
                "Bounded prefill forwards run",
                &[],
            ),
            decode_tokens: r.counter("kurtail_decode_tokens_total", "Tokens generated", &[]),
            requests_admitted: r.counter("kurtail_requests_admitted_total", "Requests admitted to a lane", &[]),
            requests_retired: r.counter("kurtail_requests_retired_total", "Requests retired (completed)", &[]),
            requests_shed: r.counter("kurtail_requests_shed_total", "Requests shed (queue full, too large, draining)", &[]),
            requests_canceled: r.counter("kurtail_requests_canceled_total", "Requests canceled (client or deadline)", &[]),
            requests_preempted: r.counter(
                "kurtail_requests_preempted_total",
                "Live lanes snapshotted and requeued under KV pressure",
                &[],
            ),
            requests_resumed: r.counter(
                "kurtail_requests_resumed_total",
                "Preempted or restart-orphaned lanes re-admitted and continued",
                &[],
            ),
            resume_recompute_tokens: r.counter(
                "kurtail_resume_recompute_tokens_total",
                "Positions re-run through prefill when resuming a lane",
                &[],
            ),
            retire_rate_milli: r.gauge(
                "kurtail_retire_rate_milli",
                "Request retirements per second x1000 (EWMA)",
                &[],
            ),
            registry,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn obs_flag_parse_rule() {
        assert!(obs_flag(None));
        assert!(obs_flag(Some("1")));
        assert!(obs_flag(Some("yes")));
        assert!(!obs_flag(Some("0")));
        assert!(!obs_flag(Some(" 0 ")));
    }

    #[test]
    fn engine_obs_registers_every_series_once() {
        let obs = EngineObs::new(true);
        obs.requests_admitted.inc();
        obs.queue_wait.record_ns(1_000);
        obs.phases[PHASE_GEMM].record_ns(500);
        let text = obs.registry.render_prometheus();
        for name in [
            "kurtail_queue_wait_seconds",
            "kurtail_ttft_seconds",
            "kurtail_prefill_seconds",
            "kurtail_decode_step_seconds",
            "kurtail_decode_phase_seconds",
            "kurtail_kv_free_blocks",
            "kurtail_kv_used_blocks",
            "kurtail_kv_withheld_blocks",
            "kurtail_kv_shared_block_refs",
            "kurtail_live_lanes",
            "kurtail_queued_requests",
            "kurtail_prefill_tokens_total",
            "kurtail_prefix_shared_tokens_total",
            "kurtail_prefill_chunks_total",
            "kurtail_decode_tokens_total",
            "kurtail_requests_admitted_total",
            "kurtail_requests_retired_total",
            "kurtail_requests_shed_total",
            "kurtail_requests_canceled_total",
            "kurtail_requests_preempted_total",
            "kurtail_requests_resumed_total",
            "kurtail_resume_recompute_tokens_total",
            "kurtail_retire_rate_milli",
        ] {
            assert!(text.contains(name), "{name} missing from exposition:\n{text}");
            let type_lines =
                text.lines().filter(|l| l.starts_with(&format!("# TYPE {name} "))).count();
            assert_eq!(type_lines, 1, "{name}: exactly one TYPE line");
        }
        for p in PHASE_NAMES {
            assert!(text.contains(&format!("phase=\"{p}\"")), "phase {p} series");
        }
        assert!(text.contains("kurtail_requests_admitted_total 1"));
    }
}
