//! Metrics core: lock-free counters/gauges, fixed-log2-bucket latency
//! histograms, and a registry that renders Prometheus text exposition.
//!
//! Everything on the record path is a handful of relaxed atomic RMWs on
//! pre-registered `Arc`s — no locks, no allocation — so the serving
//! engine can record from the decode hot path without violating the
//! zero-alloc steady-state contract (`tests/serve_scratch.rs`).
//!
//! ## Histogram layout
//!
//! Durations are recorded in nanoseconds into power-of-two buckets:
//! bucket 0 holds the value 0, bucket `i` (1 ≤ i < 43) holds
//! `[2^(i-1), 2^i - 1]`, and the last bucket is the `+Inf` overflow for
//! anything ≥ 2^42 ns (~73 min). A quantile estimate returns the upper
//! bound of the bucket containing the requested rank, so it is always
//! ≥ the true order statistic and < 2× it — a bound the property tests
//! in `tests/props.rs` hold against a sorted reference.
//!
//! Snapshots are plain `u64` arrays: mergeable (element-wise add, hence
//! associative), serializable, and safe to ship across threads.
//!
//! ## Registry scope
//!
//! `Registry::new()` makes an isolated registry; each `serve::Engine`
//! owns one so parallel tests (and future multi-engine processes) never
//! cross-contaminate. [`global()`] is the process-wide default used by
//! offline pipeline stage timers ([`StageTimer`]); the daemon's
//! `GET /metrics` serves its engine's registry.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Number of histogram buckets, including the value-0 bucket and the
/// trailing `+Inf` overflow bucket.
pub const HIST_BUCKETS: usize = 44;

/// Monotonic counter (`_total` series).
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Self(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge (occupancy, lane counts, queue depth).
#[derive(Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn new() -> Self {
        Self(AtomicU64::new(0))
    }

    #[inline]
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Bucket index for a nanosecond value: 0 for 0, else `64 - lz`,
/// clamped into the overflow bucket.
#[inline]
fn bucket_idx(ns: u64) -> usize {
    let idx = (64 - ns.leading_zeros()) as usize;
    idx.min(HIST_BUCKETS - 1)
}

/// Upper bound (ns) of bucket `i`; the overflow bucket reports its lower
/// bound (there is no finite upper bound to return).
#[inline]
fn bucket_upper_ns(i: usize) -> u64 {
    match i {
        0 => 0,
        i if i < HIST_BUCKETS - 1 => (1u64 << i) - 1,
        _ => 1u64 << (HIST_BUCKETS - 2),
    }
}

/// Lock-free log2-bucket latency histogram. Record with [`record_ns`]
/// (3 relaxed `fetch_add`s); read with [`snapshot`].
///
/// [`record_ns`]: Histogram::record_ns
/// [`snapshot`]: Histogram::snapshot
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn record_ns(&self, ns: u64) {
        self.buckets[bucket_idx(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    #[inline]
    pub fn record_duration(&self, d: Duration) {
        self.record_ns(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
            count: self.count.load(Ordering::Relaxed),
            sum_ns: self.sum_ns.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of a [`Histogram`]: mergeable and quantile-able.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HistSnapshot {
    pub buckets: [u64; HIST_BUCKETS],
    pub count: u64,
    pub sum_ns: u64,
}

impl Default for HistSnapshot {
    fn default() -> Self {
        Self { buckets: [0; HIST_BUCKETS], count: 0, sum_ns: 0 }
    }
}

impl HistSnapshot {
    /// Element-wise accumulate `other` into `self` (associative and
    /// commutative, so shard merges are order-independent).
    pub fn merge(&mut self, other: &HistSnapshot) {
        for (b, o) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *b += o;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
    }

    /// Estimated `q`-quantile in ns: the upper bound of the bucket that
    /// contains rank `ceil(q * count)`. Always ≥ the true order
    /// statistic and < 2× it. `None` when empty.
    pub fn quantile_ns(&self, q: f64) -> Option<u64> {
        if self.count == 0 {
            return None;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return Some(bucket_upper_ns(i));
            }
        }
        Some(bucket_upper_ns(HIST_BUCKETS - 1))
    }

    /// Mean observed value in ns (`None` when empty).
    pub fn mean_ns(&self) -> Option<f64> {
        (self.count > 0).then(|| self.sum_ns as f64 / self.count as f64)
    }
}

/// A registered metric: the shared handle plus exposition metadata.
#[derive(Clone)]
enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

impl Metric {
    fn kind(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Histogram(_) => "histogram",
        }
    }
}

#[derive(Clone)]
struct Entry {
    name: &'static str,
    help: &'static str,
    labels: Vec<(&'static str, String)>,
    metric: Metric,
}

/// Metric registry: registration is idempotent on `(name, labels)` — a
/// second registration returns the existing handle — so callers may
/// re-derive handles freely. Registration takes a lock; recording never
/// does.
#[derive(Default)]
pub struct Registry {
    entries: Mutex<Vec<Entry>>,
}

impl Registry {
    pub fn new() -> Self {
        Self { entries: Mutex::new(Vec::new()) }
    }

    fn register<T>(
        &self,
        name: &'static str,
        help: &'static str,
        labels: &[(&'static str, &str)],
        make: impl FnOnce() -> Metric,
        pick: impl Fn(&Metric) -> Option<Arc<T>>,
    ) -> Arc<T> {
        let labels: Vec<(&'static str, String)> =
            labels.iter().map(|&(k, v)| (k, v.to_string())).collect();
        let mut entries = self.entries.lock().unwrap();
        if let Some(e) = entries.iter().find(|e| e.name == name && e.labels == labels) {
            return pick(&e.metric).unwrap_or_else(|| {
                panic!("metric {name} re-registered as a different kind ({})", e.metric.kind())
            });
        }
        let metric = make();
        let handle = pick(&metric).expect("freshly made metric matches its own kind");
        entries.push(Entry { name, help, labels, metric });
        handle
    }

    pub fn counter(
        &self,
        name: &'static str,
        help: &'static str,
        labels: &[(&'static str, &str)],
    ) -> Arc<Counter> {
        self.register(name, help, labels, || Metric::Counter(Arc::new(Counter::new())), |m| {
            match m {
                Metric::Counter(c) => Some(c.clone()),
                _ => None,
            }
        })
    }

    pub fn gauge(
        &self,
        name: &'static str,
        help: &'static str,
        labels: &[(&'static str, &str)],
    ) -> Arc<Gauge> {
        self.register(name, help, labels, || Metric::Gauge(Arc::new(Gauge::new())), |m| match m {
            Metric::Gauge(g) => Some(g.clone()),
            _ => None,
        })
    }

    pub fn histogram(
        &self,
        name: &'static str,
        help: &'static str,
        labels: &[(&'static str, &str)],
    ) -> Arc<Histogram> {
        self.register(
            name,
            help,
            labels,
            || Metric::Histogram(Arc::new(Histogram::new())),
            |m| match m {
                Metric::Histogram(h) => Some(h.clone()),
                _ => None,
            },
        )
    }

    /// Render every registered series as Prometheus text exposition
    /// (format 0.0.4): one `# HELP`/`# TYPE` pair per metric name,
    /// cumulative `le` buckets in seconds, deterministic ordering.
    pub fn render_prometheus(&self) -> String {
        let mut entries: Vec<Entry> = self.entries.lock().unwrap().clone();
        entries.sort_by(|a, b| (a.name, &a.labels).cmp(&(b.name, &b.labels)));
        let mut out = String::with_capacity(1024);
        let mut last_name = "";
        for e in &entries {
            if e.name != last_name {
                out.push_str(&format!("# HELP {} {}\n", e.name, e.help));
                out.push_str(&format!("# TYPE {} {}\n", e.name, e.metric.kind()));
                last_name = e.name;
            }
            match &e.metric {
                Metric::Counter(c) => {
                    out.push_str(&format!(
                        "{}{} {}\n",
                        e.name,
                        label_block(&e.labels, None),
                        c.get()
                    ));
                }
                Metric::Gauge(g) => {
                    out.push_str(&format!(
                        "{}{} {}\n",
                        e.name,
                        label_block(&e.labels, None),
                        g.get()
                    ));
                }
                Metric::Histogram(h) => {
                    let snap = h.snapshot();
                    let mut cum = 0u64;
                    for (i, &c) in snap.buckets.iter().enumerate() {
                        cum += c;
                        // keep the exposition compact: only bounds with
                        // observations, plus the mandatory +Inf
                        if i == HIST_BUCKETS - 1 {
                            out.push_str(&format!(
                                "{}_bucket{} {}\n",
                                e.name,
                                label_block(&e.labels, Some("+Inf")),
                                cum
                            ));
                        } else if c > 0 {
                            let le = format!("{:e}", bucket_upper_ns(i) as f64 * 1e-9);
                            out.push_str(&format!(
                                "{}_bucket{} {}\n",
                                e.name,
                                label_block(&e.labels, Some(&le)),
                                cum
                            ));
                        }
                    }
                    // seconds, matching the `le` bounds
                    out.push_str(&format!(
                        "{}_sum{} {:e}\n",
                        e.name,
                        label_block(&e.labels, None),
                        snap.sum_ns as f64 * 1e-9
                    ));
                    out.push_str(&format!(
                        "{}_count{} {}\n",
                        e.name,
                        label_block(&e.labels, None),
                        snap.count
                    ));
                }
            }
        }
        out
    }
}

/// `{k="v",...}` with exposition-format escaping; empty string when
/// there are no labels. `le` is appended last when given.
fn label_block(labels: &[(&'static str, String)], le: Option<&str>) -> String {
    if labels.is_empty() && le.is_none() {
        return String::new();
    }
    let mut parts: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{}\"", escape_label(v))).collect();
    if let Some(le) = le {
        parts.push(format!("le=\"{le}\""));
    }
    format!("{{{}}}", parts.join(","))
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Process-global registry (offline pipeline stage timers; anything not
/// owned by a specific engine).
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

/// Wall-clock stage timer: the successor to the retired
/// `util::timer::Stopwatch` label printer. `stop()` records the elapsed
/// time into the `kurtail_stage_seconds{stage=...}` histogram in the
/// global registry and returns the elapsed seconds.
pub struct StageTimer {
    start: Instant,
    hist: Arc<Histogram>,
}

impl StageTimer {
    pub fn start(stage: &'static str) -> Self {
        let hist = global().histogram(
            "kurtail_stage_seconds",
            "Wall-clock of coarse offline pipeline stages",
            &[("stage", stage)],
        );
        Self { start: Instant::now(), hist }
    }

    /// Seconds since `start()` without recording (for mid-stage peeks).
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Record the stage duration into the histogram; returns seconds.
    pub fn stop(self) -> f64 {
        let d = self.start.elapsed();
        self.hist.record_duration(d);
        d.as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_idx_covers_powers_of_two() {
        assert_eq!(bucket_idx(0), 0);
        assert_eq!(bucket_idx(1), 1);
        assert_eq!(bucket_idx(2), 2);
        assert_eq!(bucket_idx(3), 2);
        assert_eq!(bucket_idx(4), 3);
        assert_eq!(bucket_idx(7), 3);
        assert_eq!(bucket_idx(8), 4);
        assert_eq!(bucket_idx(u64::MAX), HIST_BUCKETS - 1);
        // every value sits at or below its bucket's upper bound
        for v in [0u64, 1, 2, 3, 5, 100, 1_000_000, 123_456_789_000] {
            let i = bucket_idx(v);
            assert!(v <= bucket_upper_ns(i), "v={v} bucket={i}");
            if i > 0 && i < HIST_BUCKETS - 1 {
                assert!(bucket_upper_ns(i) < 2 * v.max(1), "bound within 2x: v={v}");
            }
        }
    }

    #[test]
    fn histogram_quantiles_and_mean() {
        let h = Histogram::new();
        assert_eq!(h.snapshot().quantile_ns(0.5), None);
        for ns in [100u64, 200, 400, 800, 1600] {
            h.record_ns(ns);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 5);
        assert_eq!(s.sum_ns, 3100);
        let p50 = s.quantile_ns(0.5).unwrap();
        assert!((400..800).contains(&p50), "p50 {p50}");
        let p99 = s.quantile_ns(0.99).unwrap();
        assert!((1600..3200).contains(&p99), "p99 {p99}");
        assert!((s.mean_ns().unwrap() - 620.0).abs() < 1e-9);
    }

    #[test]
    fn snapshot_merge_accumulates() {
        let (a, b) = (Histogram::new(), Histogram::new());
        a.record_ns(10);
        a.record_ns(20);
        b.record_ns(1000);
        let mut m = a.snapshot();
        m.merge(&b.snapshot());
        assert_eq!(m.count, 3);
        assert_eq!(m.sum_ns, 1030);
        assert_eq!(m.quantile_ns(1.0), b.snapshot().quantile_ns(1.0));
    }

    #[test]
    fn registry_registration_is_idempotent() {
        let reg = Registry::new();
        let a = reg.counter("t_total", "t", &[("k", "v")]);
        let b = reg.counter("t_total", "t", &[("k", "v")]);
        a.inc();
        b.inc();
        assert_eq!(a.get(), 2, "same (name, labels) shares one counter");
        let other = reg.counter("t_total", "t", &[("k", "w")]);
        other.inc();
        assert_eq!(other.get(), 1, "distinct labels are a distinct series");
    }

    #[test]
    #[should_panic(expected = "different kind")]
    fn registry_rejects_kind_clash() {
        let reg = Registry::new();
        let _ = reg.counter("clash", "t", &[]);
        let _ = reg.gauge("clash", "t", &[]);
    }

    /// Exposition-format conformance: parse back every rendered line,
    /// assert no duplicate series, cumulative bucket monotonicity, and
    /// +Inf == _count.
    #[test]
    fn prometheus_exposition_parses_back() {
        let reg = Registry::new();
        reg.counter("kurtail_req_total", "requests", &[("tenant", "a\"b")]).add(3);
        reg.gauge("kurtail_depth", "queue depth", &[]).set(7);
        let h = reg.histogram("kurtail_lat_seconds", "latency", &[("phase", "gemm")]);
        for ns in [50u64, 900, 900, 15_000, 2_000_000] {
            h.record_ns(ns);
        }
        let text = reg.render_prometheus();

        let mut seen = std::collections::HashSet::new();
        let mut hist_cum: Vec<(f64, f64)> = Vec::new(); // (le, cum)
        let (mut hist_sum, mut hist_count) = (None, None);
        for line in text.lines() {
            if line.starts_with('#') {
                assert!(
                    line.starts_with("# HELP ") || line.starts_with("# TYPE "),
                    "bad comment line: {line}"
                );
                continue;
            }
            let (series, value) = line.rsplit_once(' ').expect("name value");
            let value: f64 = value.parse().unwrap_or_else(|_| panic!("bad value in {line}"));
            assert!(seen.insert(series.to_string()), "duplicate series {series}");
            if let Some(rest) = series.strip_prefix("kurtail_lat_seconds_bucket") {
                let le = rest.split("le=\"").nth(1).unwrap().trim_end_matches("\"}");
                let le = if le == "+Inf" { f64::INFINITY } else { le.parse().unwrap() };
                hist_cum.push((le, value));
            } else if series.starts_with("kurtail_lat_seconds_sum") {
                hist_sum = Some(value);
            } else if series.starts_with("kurtail_lat_seconds_count") {
                hist_count = Some(value);
            }
        }
        assert!(seen.iter().any(|s| s.contains("tenant=\"a\\\"b\"")), "label escaping");
        for w in hist_cum.windows(2) {
            assert!(w[0].0 < w[1].0, "le bounds ascending");
            assert!(w[0].1 <= w[1].1, "cumulative counts nondecreasing");
        }
        let inf = hist_cum.last().expect("+Inf bucket present");
        assert!(inf.0.is_infinite());
        assert_eq!(inf.1, hist_count.expect("_count emitted"));
        assert_eq!(hist_count, Some(5.0));
        let want_sum = (50.0 + 900.0 + 900.0 + 15_000.0 + 2_000_000.0) * 1e-9;
        assert!((hist_sum.expect("_sum emitted") - want_sum).abs() < 1e-12);
        // every bucket's cumulative count is consistent with the raw data
        for &(le, cum) in &hist_cum {
            let truth = [50u64, 900, 900, 15_000, 2_000_000]
                .iter()
                .filter(|&&ns| (ns as f64 * 1e-9) <= le)
                .count() as f64;
            assert!(cum >= truth, "le={le}: cum {cum} >= {truth} (upper-bound buckets)");
        }
    }

    #[test]
    fn stage_timer_records_into_global_registry() {
        let sw = StageTimer::start("unit_test_stage");
        let s = sw.stop();
        assert!(s >= 0.0);
        let text = global().render_prometheus();
        assert!(
            text.contains("kurtail_stage_seconds_count{stage=\"unit_test_stage\"} 1"),
            "stage series rendered:\n{text}"
        );
    }
}
