//! Structured, leveled log lines for request lifecycle events.
//!
//! One line per event on stderr, formatted per `KURTAIL_LOG`:
//!
//! * `text` (default) — `ts=1754640000.123 level=info event=request_done
//!   id=3 tenant="alice" ...` (logfmt-style, greppable)
//! * `json` — the same fields as one JSON object per line, for log
//!   shippers
//! * `off` — suppress everything
//!
//! The format is resolved once per process and cached. Logging happens
//! only at request lifecycle boundaries (accept / shed / done / failed)
//! and daemon lifecycle events — never on the per-step decode hot path —
//! so the allocation it does is irrelevant to the zero-alloc contract.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LogFormat {
    Text,
    Json,
    Off,
}

impl LogFormat {
    /// Strict config-file spelling (`"text"`, `"json"`, `"off"`). The
    /// env var keeps its lenient fallback-to-text rule; a config file
    /// must not silently typo into `text`.
    pub fn parse(s: &str) -> Option<LogFormat> {
        match s.trim() {
            "text" => Some(LogFormat::Text),
            "json" => Some(LogFormat::Json),
            "off" | "0" => Some(LogFormat::Off),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            LogFormat::Text => "text",
            LogFormat::Json => "json",
            LogFormat::Off => "off",
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LogLevel {
    Info,
    Warn,
    Error,
}

impl LogLevel {
    fn as_str(self) -> &'static str {
        match self {
            LogLevel::Info => "info",
            LogLevel::Warn => "warn",
            LogLevel::Error => "error",
        }
    }
}

/// A borrowed field value — callers build `&[(&str, LogValue)]` on the
/// stack; nothing is allocated until a line is actually emitted.
#[derive(Clone, Copy, Debug)]
pub enum LogValue<'a> {
    U64(u64),
    F64(f64),
    Str(&'a str),
    Bool(bool),
}

/// Parse rule for `KURTAIL_LOG`: unset/`text` → text, `json` → json,
/// `off`/`0` → off; anything unrecognized falls back to text.
fn log_format_flag(var: Option<&str>) -> LogFormat {
    match var.map(str::trim) {
        Some("json") => LogFormat::Json,
        Some("off") | Some("0") => LogFormat::Off,
        _ => LogFormat::Text,
    }
}

/// Runtime override installed by the daemon's live config reload:
/// 0 = unset (fall through to the `KURTAIL_LOG` default), 1 = text,
/// 2 = json, 3 = off.
static FORMAT_OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// Override the process log format at runtime (live config reload).
/// `None` clears the override back to the `KURTAIL_LOG` default.
pub fn set_log_format(fmt: Option<LogFormat>) {
    let v = match fmt {
        None => 0,
        Some(LogFormat::Text) => 1,
        Some(LogFormat::Json) => 2,
        Some(LogFormat::Off) => 3,
    };
    FORMAT_OVERRIDE.store(v, Ordering::Relaxed);
}

/// The process's log format: a live-reload override if one is
/// installed, else `KURTAIL_LOG` (resolved once).
pub fn log_format() -> LogFormat {
    match FORMAT_OVERRIDE.load(Ordering::Relaxed) {
        1 => return LogFormat::Text,
        2 => return LogFormat::Json,
        3 => return LogFormat::Off,
        _ => {}
    }
    static FORMAT: OnceLock<LogFormat> = OnceLock::new();
    *FORMAT.get_or_init(|| log_format_flag(std::env::var("KURTAIL_LOG").ok().as_deref()))
}

/// Emit one structured log line to stderr (format per `KURTAIL_LOG`).
pub fn log_event(level: LogLevel, event: &str, fields: &[(&str, LogValue)]) {
    let fmt = log_format();
    if fmt == LogFormat::Off {
        return;
    }
    let ts = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0);
    let line = match fmt {
        LogFormat::Json => render_json(ts, level, event, fields),
        _ => render_text(ts, level, event, fields),
    };
    // single write so concurrent threads' lines never interleave
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "{line}");
}

pub fn info(event: &str, fields: &[(&str, LogValue)]) {
    log_event(LogLevel::Info, event, fields);
}

pub fn warn(event: &str, fields: &[(&str, LogValue)]) {
    log_event(LogLevel::Warn, event, fields);
}

pub fn error(event: &str, fields: &[(&str, LogValue)]) {
    log_event(LogLevel::Error, event, fields);
}

fn render_text(ts: f64, level: LogLevel, event: &str, fields: &[(&str, LogValue)]) -> String {
    let mut s = format!("ts={ts:.3} level={} event={event}", level.as_str());
    for (k, v) in fields {
        match v {
            LogValue::U64(n) => s.push_str(&format!(" {k}={n}")),
            LogValue::F64(x) => s.push_str(&format!(" {k}={x:.3}")),
            LogValue::Bool(b) => s.push_str(&format!(" {k}={b}")),
            LogValue::Str(t) => s.push_str(&format!(" {k}={}", quote_json(t))),
        }
    }
    s
}

fn render_json(ts: f64, level: LogLevel, event: &str, fields: &[(&str, LogValue)]) -> String {
    let mut s = format!(
        "{{\"ts\": {ts:.3}, \"level\": {}, \"event\": {}",
        quote_json(level.as_str()),
        quote_json(event)
    );
    for (k, v) in fields {
        s.push_str(&format!(", {}: ", quote_json(k)));
        match v {
            LogValue::U64(n) => s.push_str(&n.to_string()),
            LogValue::F64(x) => s.push_str(&format!("{x:.3}")),
            LogValue::Bool(b) => s.push_str(&b.to_string()),
            LogValue::Str(t) => s.push_str(&quote_json(t)),
        }
    }
    s.push('}');
    s
}

fn quote_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_format_parse_rule() {
        assert_eq!(log_format_flag(None), LogFormat::Text);
        assert_eq!(log_format_flag(Some("text")), LogFormat::Text);
        assert_eq!(log_format_flag(Some("json")), LogFormat::Json);
        assert_eq!(log_format_flag(Some(" json ")), LogFormat::Json);
        assert_eq!(log_format_flag(Some("off")), LogFormat::Off);
        assert_eq!(log_format_flag(Some("0")), LogFormat::Off);
        assert_eq!(log_format_flag(Some("verbose")), LogFormat::Text);
        // the config-file rule is strict where the env rule is lenient
        assert_eq!(LogFormat::parse("json"), Some(LogFormat::Json));
        assert_eq!(LogFormat::parse(" off "), Some(LogFormat::Off));
        assert_eq!(LogFormat::parse("verbose"), None);
        assert_eq!(LogFormat::parse(LogFormat::Text.as_str()), Some(LogFormat::Text));
    }

    #[test]
    fn json_lines_are_valid_json() {
        let line = render_json(
            1.5,
            LogLevel::Warn,
            "request_shed",
            &[
                ("id", LogValue::U64(7)),
                ("tenant", LogValue::Str("a\"b")),
                ("retryable", LogValue::Bool(true)),
                ("wait_ms", LogValue::F64(12.25)),
            ],
        );
        let parsed = crate::util::Json::parse(&line).expect("line parses");
        assert_eq!(parsed.get("event").unwrap().as_str().unwrap(), "request_shed");
        assert_eq!(parsed.get("tenant").unwrap().as_str().unwrap(), "a\"b");
        assert_eq!(parsed.get("id").unwrap().as_f64().unwrap(), 7.0);
    }

    #[test]
    fn text_lines_are_single_line() {
        let line = render_text(
            1.0,
            LogLevel::Info,
            "e",
            &[("msg", LogValue::Str("two\nlines"))],
        );
        assert!(!line.contains('\n'), "newline escaped: {line}");
        assert!(line.starts_with("ts=1.000 level=info event=e"));
    }
}
