//! KurTail rotation learning — the paper's contribution (§3).
//!
//! The Rust side owns exactly what the paper describes: layer-wise
//! inference to capture block inputs, shuffling the activations of *all*
//! layers and blocks together, and a 100-iteration Cayley-Adam loop on
//! the kurtosis loss — executed step-by-step through the AOT
//! `kurtail_step_d{D}` artifact. Peak memory is one layer's activations
//! plus a bounded row reservoir (vs. SpinQuant's full-model autograd).

pub mod optimizer;

pub use optimizer::{learn_rotations, CayleyOutcome, KurtailReport};
