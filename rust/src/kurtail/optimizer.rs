//! Cayley-Adam driver over the `kurtail_step_d{D}` artifacts.

use anyhow::Result;

use crate::config::CalibConfig;
use crate::model::{capture_stream, Params, RowReservoir};
use crate::runtime::{Runtime, Value};
use crate::tensor::{hadamard::orthogonality_error, Tensor};
use crate::obs::StageTimer;
use crate::util::{timer, Rng};

/// Result of one Cayley-Adam run.
pub struct CayleyOutcome {
    pub rotation: Tensor,
    pub losses: Vec<f32>,
    pub orth_err: f32,
}

/// Full KurTail learning report (feeds the training-cost experiment).
pub struct KurtailReport {
    pub r1: Tensor,
    pub r2: Vec<Tensor>,
    pub r1_losses: Vec<f32>,
    pub r2_final_losses: Vec<f32>,
    pub capture_s: f64,
    pub optimize_s: f64,
    pub peak_rss_mib: f64,
}

/// Drive `iters` Cayley-Adam steps on one rotation of dimension `d`,
/// sampling `rows_per_step` rows from the reservoir each iteration.
pub fn cayley_run(
    rt: &Runtime,
    d: usize,
    pool: &mut RowReservoir,
    iters: usize,
    lr: f32,
) -> Result<CayleyOutcome> {
    anyhow::ensure!(!pool.is_empty(), "empty activation pool for d={d}");
    let art = rt.load(&format!("kurtail_step_d{d}"))?;
    let rows = rt.manifest.kurtail_rows;

    // Initialize at a random Hadamard rotation (as SpinQuant does): the
    // optimizer then only has to *improve on* QuaRot's solution, instead
    // of having to discover channel mixing from the identity.
    let mut seed_rng = Rng::new(0xD00D ^ d as u64);
    let mut r = crate::tensor::hadamard::random_hadamard(d, &mut seed_rng);
    let mut m = Tensor::zeros(&[d, d]);
    let mut v = 0.0f32;
    let mut losses = Vec::with_capacity(iters);
    for t in 1..=iters {
        let x = pool.sample(rows);
        let out = art.run(&[
            Value::F32(r),
            Value::F32(m),
            Value::from(v),
            Value::F32(x),
            Value::from(lr),
            Value::from(t as f32),
        ])?;
        r = out[0].as_f32()?.clone();
        m = out[1].as_f32()?.clone();
        v = out[2].scalar_f32()?;
        losses.push(out[3].scalar_f32()?);
    }
    let orth_err = orthogonality_error(&r);
    anyhow::ensure!(orth_err < 1e-2, "rotation left the Stiefel manifold: {orth_err}");
    Ok(CayleyOutcome { rotation: r, losses, orth_err })
}

/// Learn R1 (residual stream) and per-layer R2 (V heads) with kurtosis
/// loss from layer-wise captured activations (paper §3).
pub fn learn_rotations(
    rt: &Runtime,
    params: &Params,
    calib_batches: &[crate::tensor::IntTensor],
    calib: &CalibConfig,
) -> Result<KurtailReport> {
    let meta = params.meta.clone();
    let d = meta.d_model;
    let dh = meta.d_head;
    let mut rng = Rng::new(calib.seed ^ 0x6A11);

    // --- capture phase (layer-wise; bounded memory) ---------------------
    let sw = StageTimer::start("capture");
    // R1 pool: MHSA+FFN block inputs of ALL layers, normed, shuffled —
    // "we shuffle the stored input data from all transformer layers and
    //  both blocks" (paper §3).
    let mut r1_pool = RowReservoir::new(d, 262_144.min(400 * rt.manifest.kurtail_rows), rng.next_u64());
    // R2 pools: per layer, V head rows.
    let mut r2_pools: Vec<RowReservoir> =
        (0..meta.n_layers).map(|_| RowReservoir::new(dh, 65_536, rng.next_u64())).collect();

    capture_stream(rt, params, calib_batches, |taps| {
        // fused norm→offer: no normed activation tensor is materialized,
        // keeping peak RSS at one layer's taps (the paper's §3 argument)
        r1_pool.offer_rmsnorm(&taps.mhsa_in);
        r1_pool.offer_rmsnorm(&taps.ffn_in);
        r2_pools[taps.layer].offer(&taps.v_heads);
        Ok(())
    })?;
    let capture_s = sw.stop();

    // --- optimization phase ---------------------------------------------
    let sw = StageTimer::start("optimize");
    let r1_run = cayley_run(rt, d, &mut r1_pool, calib.iters, calib.lr)?;
    let mut r2 = Vec::with_capacity(meta.n_layers);
    let mut r2_final_losses = Vec::with_capacity(meta.n_layers);
    for pool in r2_pools.iter_mut() {
        // R2 is a much smaller problem (d_head); half the iterations suffice
        let run = cayley_run(rt, dh, pool, (calib.iters / 2).max(10), calib.lr)?;
        r2_final_losses.push(*run.losses.last().unwrap());
        r2.push(run.rotation);
    }
    let optimize_s = sw.stop();

    Ok(KurtailReport {
        r1: r1_run.rotation,
        r2,
        r1_losses: r1_run.losses,
        r2_final_losses,
        capture_s,
        optimize_s,
        peak_rss_mib: timer::peak_rss_mib(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    // cayley_run against the real artifact is covered by the integration
    // tests; here we pin the pure-host pieces.
    #[test]
    fn reservoir_sizes_are_bounded() {
        let pool = RowReservoir::new(64, 1000, 0);
        assert_eq!(pool.len(), 0);
        assert!(pool.is_empty());
    }
}
