//! Rotation construction + offline fusion (paper Fig. 3).
//!
//! R1 (d_model, global) and R2 (d_head, per layer) fuse into the weights —
//! zero inference cost. R3/R4/R5 stay online (random Hadamard, passed to
//! the quantized graphs as inputs); their inverses are pre-fused here
//! (R4ᵀ into Wo, R5ᵀ into Wdown; R3 self-cancels in QᵀK).

pub mod fusion;

pub use fusion::{fold_norms, fuse_r1, fuse_r2, fuse_r4_inverse, fuse_r5_inverse};

use crate::tensor::{hadamard::random_hadamard, Tensor};
use crate::util::Rng;

/// The full rotation assignment for one quantized model.
#[derive(Clone)]
pub struct RotationSet {
    /// Residual-stream rotation (None = identity, e.g. GPTQ-only).
    pub r1: Option<Tensor>,
    /// Per-layer V/KV rotation (d_head × d_head), empty = identity.
    pub r2: Vec<Tensor>,
    /// Online rotations (identity when rotations are disabled).
    pub r3: Tensor,
    pub r4: Tensor,
    pub r5: Tensor,
}

impl RotationSet {
    /// No rotations at all (Fp16 / GPTQ-only rows).
    pub fn identity(d_head: usize, d_ff: usize) -> Self {
        Self {
            r1: None,
            r2: Vec::new(),
            r3: Tensor::eye(d_head),
            r4: Tensor::eye(d_head),
            r5: Tensor::eye(d_ff),
        }
    }

    /// Random-Hadamard online rotations (shared by all rotation methods).
    pub fn online_hadamard(d_head: usize, d_ff: usize, rng: &mut Rng) -> (Tensor, Tensor, Tensor) {
        (
            random_hadamard(d_head, rng),
            random_hadamard(d_head, rng),
            random_hadamard(d_ff, rng),
        )
    }
}

/// Expand a per-head rotation (dh × dh) to the block-diagonal (d × d)
/// acting identically on every head.
pub fn blockdiag_heads(r: &Tensor, n_heads: usize) -> Tensor {
    let dh = r.shape[0];
    assert_eq!(r.shape, vec![dh, dh]);
    let d = dh * n_heads;
    let mut out = Tensor::zeros(&[d, d]);
    for h in 0..n_heads {
        for i in 0..dh {
            for j in 0..dh {
                out.data[(h * dh + i) * d + (h * dh + j)] = r.data[i * dh + j];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::hadamard::orthogonality_error;

    #[test]
    fn blockdiag_is_orthogonal() {
        let mut rng = Rng::new(0);
        let r = random_hadamard(16, &mut rng);
        let b = blockdiag_heads(&r, 4);
        assert_eq!(b.shape, vec![64, 64]);
        assert!(orthogonality_error(&b) < 1e-4);
    }

    #[test]
    fn blockdiag_acts_per_head() {
        let mut rng = Rng::new(1);
        let r = random_hadamard(4, &mut rng);
        let b = blockdiag_heads(&r, 2);
        let x = Tensor::randn(&[1, 8], 1.0, &mut rng);
        let y = crate::tensor::matmul::matmul(&x, &b);
        let x0 = Tensor::new(x.data[..4].to_vec(), vec![1, 4]);
        let y0 = crate::tensor::matmul::matmul(&x0, &r);
        for j in 0..4 {
            assert!((y.data[j] - y0.data[j]).abs() < 1e-5);
        }
    }
}
