//! Offline weight transformations: RMSNorm folding and rotation fusion.
//!
//! These implement the computational-invariance theorem (Ashkboos et al.
//! 2024a) on the stacked parameter store. The python test
//! `test_model.py::test_r1_fusion_is_invariant_in_fp` pins the same math
//! on the JAX side; the Rust integration test checks invariance through
//! the actual artifacts.

use crate::model::Params;
use crate::tensor::{
    matmul::{matmul, matmul_into},
    Tensor,
};

use super::blockdiag_heads;

/// Apply `f` to every trailing 2-D matrix of a stacked (…, k, n) tensor.
fn map_matrices(w: &Tensor, f: impl Fn(&Tensor) -> Tensor) -> Tensor {
    let r = w.rank();
    assert!(r >= 2);
    if r == 2 {
        return f(w);
    }
    let (k, n) = (w.shape[r - 2], w.shape[r - 1]);
    let mat = k * n;
    let count = w.numel() / mat;
    let mut out = w.clone();
    for i in 0..count {
        let sub = Tensor::new(w.data[i * mat..(i + 1) * mat].to_vec(), vec![k, n]);
        let g = f(&sub);
        assert_eq!(g.shape, vec![k, n]);
        out.data[i * mat..(i + 1) * mat].copy_from_slice(&g.data);
    }
    out
}

/// Left-multiply every matrix of a stack by `m`ᵀ (input-side transform):
/// out_i = mᵀ @ w_i, computed straight into the output stack — no
/// per-matrix sub/result tensors; `m` is transposed once and each slice
/// product runs on the packed parallel kernel.
fn left_t(w: &Tensor, m: &Tensor) -> Tensor {
    let r = w.rank();
    assert!(r >= 2);
    let (k, n) = (w.shape[r - 2], w.shape[r - 1]);
    assert_eq!(m.shape, vec![k, k], "left transform must be ({k},{k})");
    let mt = m.t();
    let mat = k * n;
    let count = w.numel() / mat;
    let mut out = Tensor::zeros(&w.shape);
    for i in 0..count {
        matmul_into(&mt.data, &w.data[i * mat..(i + 1) * mat], &mut out.data[i * mat..(i + 1) * mat], k, k, n);
    }
    out
}

/// Right-multiply every matrix of a stack by `m` (output-side transform):
/// out_i = w_i @ m, straight into the output stack.
fn right(w: &Tensor, m: &Tensor) -> Tensor {
    let r = w.rank();
    assert!(r >= 2);
    let (k, n) = (w.shape[r - 2], w.shape[r - 1]);
    assert_eq!(m.shape, vec![n, n], "right transform must be ({n},{n})");
    let mat = k * n;
    let count = w.numel() / mat;
    let mut out = Tensor::zeros(&w.shape);
    for i in 0..count {
        matmul_into(&w.data[i * mat..(i + 1) * mat], &m.data, &mut out.data[i * mat..(i + 1) * mat], k, n, n);
    }
    out
}

/// Fold RMSNorm γ into the adjacent linears; all norms become weightless.
/// Precondition for every rotation (RMSNorm is rotation-invariant only
/// without per-channel weights).
pub fn fold_norms(p: &mut Params) {
    let meta = p.meta.clone();
    let l = meta.n_layers;
    // ln1 → wq, wk, wv
    let ln1 = p.get("ln1").clone();
    for name in ["wq", "wk", "wv"] {
        let w = p.get(name).clone();
        let mut out = w.clone();
        let d = meta.d_model;
        for layer in 0..l {
            let g = &ln1.data[layer * d..(layer + 1) * d];
            let sub = w.index_axis0(layer).scale_rows(g);
            out.set_axis0(layer, &sub);
        }
        p.set(name, out);
    }
    p.set("ln1", Tensor::ones(&[l, meta.d_model]));

    // ln2 → FFN input linears (arch-dependent)
    let ln2 = p.get("ln2").clone();
    let targets: &[&str] = match meta.arch.as_str() {
        "llama" => &["wg", "wu"],
        "phi" => &["wu"],
        "moe" => &["wr", "wg", "wu"],
        a => panic!("unknown arch {a}"),
    };
    for name in targets {
        let w = p.get(name).clone();
        let mut out = w.clone();
        let d = meta.d_model;
        for layer in 0..l {
            let g = &ln2.data[layer * d..(layer + 1) * d];
            let scaled = map_matrices(&w.index_axis0(layer), |sub| sub.scale_rows(g));
            out.set_axis0(layer, &scaled);
        }
        p.set(name, out);
    }
    p.set("ln2", Tensor::ones(&[l, meta.d_model]));

    // lnf → head (head is (V, d): logits = x ⊙ γ @ headᵀ ⇒ head[:,j] *= γ[j])
    let lnf = p.get("lnf").clone();
    p.set("head", p.get("head").scale_cols(&lnf.data));
    p.set("lnf", Tensor::ones(&[meta.d_model]));
}

/// Fuse the residual-stream rotation R1 (requires folded norms).
pub fn fuse_r1(p: &mut Params, r1: &Tensor) {
    let meta = p.meta.clone();
    assert_eq!(r1.shape, vec![meta.d_model, meta.d_model]);
    p.set("embed", matmul(p.get("embed"), r1));
    p.set("head", matmul(p.get("head"), r1));
    for name in ["wq", "wk", "wv"] {
        p.set(name, left_t(p.get(name), r1));
    }
    p.set("wo", right(p.get("wo"), r1));
    match meta.arch.as_str() {
        "llama" => {
            p.set("wg", left_t(p.get("wg"), r1));
            p.set("wu", left_t(p.get("wu"), r1));
            p.set("wd", right(p.get("wd"), r1));
        }
        "phi" => {
            p.set("wu", left_t(p.get("wu"), r1));
            p.set("wd", right(p.get("wd"), r1));
        }
        "moe" => {
            p.set("wr", left_t(p.get("wr"), r1));
            p.set("wg", left_t(p.get("wg"), r1));
            p.set("wu", left_t(p.get("wu"), r1));
            p.set("wd", right(p.get("wd"), r1));
        }
        a => panic!("unknown arch {a}"),
    }
}

/// Fuse per-layer R2 (d_head) into Wv (right) and Wo (left-inverse).
pub fn fuse_r2(p: &mut Params, r2s: &[Tensor]) {
    let meta = p.meta.clone();
    if r2s.is_empty() {
        return;
    }
    assert_eq!(r2s.len(), meta.n_layers);
    let mut wv = p.get("wv").clone();
    let mut wo = p.get("wo").clone();
    for (l, r2) in r2s.iter().enumerate() {
        let b = blockdiag_heads(r2, meta.n_heads);
        wv.set_axis0(l, &matmul(&wv.index_axis0(l), &b));
        wo.set_axis0(l, &matmul(&b.t(), &wo.index_axis0(l)));
    }
    p.set("wv", wv);
    p.set("wo", wo);
}

/// Fuse the inverse of the online head rotation R4 into Wo.
pub fn fuse_r4_inverse(p: &mut Params, r4: &Tensor) {
    let meta = p.meta.clone();
    let b = blockdiag_heads(r4, meta.n_heads);
    p.set("wo", left_t(p.get("wo"), &b));
}

/// Fuse the inverse of the online FFN rotation R5 into Wdown.
pub fn fuse_r5_inverse(p: &mut Params, r5: &Tensor) {
    p.set("wd", left_t(p.get("wd"), r5));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::tests_support::fake_llama_meta;
    use crate::tensor::hadamard::random_hadamard;
    use crate::util::Rng;

    #[test]
    fn fold_norms_makes_norms_one() {
        let meta = fake_llama_meta();
        let mut rng = Rng::new(0);
        let mut p = Params::init(&meta, &mut rng);
        // randomize norms first
        p.set("ln1", Tensor::randn(&[meta.n_layers, meta.d_model], 0.2, &mut rng).map(|x| 1.0 + x));
        p.set("lnf", Tensor::randn(&[meta.d_model], 0.2, &mut rng).map(|x| 1.0 + x));
        fold_norms(&mut p);
        assert!(p.get("ln1").data.iter().all(|&v| v == 1.0));
        assert!(p.get("lnf").data.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn fuse_r1_then_inverse_restores() {
        let meta = fake_llama_meta();
        let mut rng = Rng::new(1);
        let mut p = Params::init(&meta, &mut rng);
        fold_norms(&mut p);
        let orig = p.clone();
        let r1 = random_hadamard(meta.d_model, &mut rng);
        fuse_r1(&mut p, &r1);
        assert!(p.get("wq").max_abs_diff(orig.get("wq")) > 1e-3); // actually rotated
        fuse_r1(&mut p, &r1.t()); // rotate back
        for name in ["embed", "head", "wq", "wo", "wg", "wd"] {
            assert!(
                p.get(name).max_abs_diff(orig.get(name)) < 1e-4,
                "{name} not restored"
            );
        }
    }

    #[test]
    fn fuse_r2_roundtrip() {
        let meta = fake_llama_meta();
        let mut rng = Rng::new(2);
        let mut p = Params::init(&meta, &mut rng);
        let orig = p.clone();
        let r2s: Vec<Tensor> =
            (0..meta.n_layers).map(|_| random_hadamard(meta.d_head, &mut rng)).collect();
        fuse_r2(&mut p, &r2s);
        assert!(p.get("wv").max_abs_diff(orig.get("wv")) > 1e-3);
        let inv: Vec<Tensor> = r2s.iter().map(|r| r.t()).collect();
        fuse_r2(&mut p, &inv);
        assert!(p.get("wv").max_abs_diff(orig.get("wv")) < 1e-4);
        assert!(p.get("wo").max_abs_diff(orig.get("wo")) < 1e-4);
    }

    #[test]
    fn r4_r5_inverses_roundtrip() {
        let meta = fake_llama_meta();
        let mut rng = Rng::new(3);
        let mut p = Params::init(&meta, &mut rng);
        let orig = p.clone();
        let r4 = random_hadamard(meta.d_head, &mut rng);
        let r5 = random_hadamard(meta.d_ff, &mut rng);
        fuse_r4_inverse(&mut p, &r4);
        fuse_r5_inverse(&mut p, &r5);
        fuse_r4_inverse(&mut p, &r4.t());
        fuse_r5_inverse(&mut p, &r5.t());
        assert!(p.get("wo").max_abs_diff(orig.get("wo")) < 1e-4);
        assert!(p.get("wd").max_abs_diff(orig.get("wd")) < 1e-4);
    }
}
