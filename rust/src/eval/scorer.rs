//! lm-eval-style multiple-choice scoring: for each option, compute the
//! NLL of the option tokens given the prompt, normalized by option length
//! (lm-evaluation-harness's `acc_norm` — the variant robust to options of
//! different byte lengths, which our numeric answers are); predict the
//! argmin, run through the fp or quantized NLL graphs.

use anyhow::Result;

use crate::calib::{ByteTokenizer, Mcq};
use crate::eval::perplexity::run_nll;
use crate::pipeline::PreparedModel;
use crate::runtime::Runtime;
use crate::tensor::{IntTensor, Tensor};

#[derive(Debug, Clone)]
pub struct McqScore {
    pub accuracy: f32,
    pub n: usize,
    pub predictions: Vec<usize>,
}

/// Pack one (prompt, option) pair into a fixed-length row + option mask.
/// Returns None if the pair does not fit the sequence length.
fn pack(prompt: &str, option: &str, seq_len: usize) -> Option<(Vec<i32>, Vec<f32>)> {
    let tok = ByteTokenizer;
    let p = tok.encode(&format!("{prompt} "));
    let o = tok.encode(option);
    if p.len() + o.len() > seq_len {
        return None;
    }
    let mut ids = Vec::with_capacity(seq_len);
    let mut mask = vec![0.0f32; seq_len];
    ids.extend_from_slice(&p);
    for (k, &t) in o.iter().enumerate() {
        mask[p.len() + k] = 1.0; // score exactly the option tokens
        ids.push(t);
    }
    ids.resize(seq_len, b' ' as i32); // pad (masked out)
    Some((ids, mask))
}

/// Score a set of MCQs; batches (question, option) rows through the model.
pub fn score_mcqs(rt: &Runtime, pm: &PreparedModel, qs: &[Mcq]) -> Result<McqScore> {
    anyhow::ensure!(!qs.is_empty(), "no questions");
    let meta = &pm.params.meta;
    let (b, t) = (meta.eval_batch, meta.seq_len);

    // flatten to rows
    let mut rows: Vec<(usize, usize, Vec<i32>, Vec<f32>)> = Vec::new(); // (q, opt, ids, mask)
    for (qi, q) in qs.iter().enumerate() {
        for (oi, opt) in q.options.iter().enumerate() {
            let (ids, mask) = pack(&q.prompt, opt, t)
                .ok_or_else(|| anyhow::anyhow!("question too long for seq_len {t}"))?;
            rows.push((qi, oi, ids, mask));
        }
    }

    // batched NLL
    let mut scores = vec![vec![f32::INFINITY; 4]; qs.len()];
    for chunk in rows.chunks(b) {
        let mut ids = Vec::with_capacity(b * t);
        let mut mask = Vec::with_capacity(b * t);
        for (_, _, i, m) in chunk {
            ids.extend_from_slice(i);
            mask.extend_from_slice(m);
        }
        // pad the last partial batch with copies of row 0
        for _ in chunk.len()..b {
            ids.extend_from_slice(&chunk[0].2);
            mask.extend_from_slice(&chunk[0].3);
        }
        let (nll, cnt) = run_nll(
            rt,
            pm,
            &IntTensor::new(ids, vec![b, t]),
            &Tensor::new(mask, vec![b, t]),
        )?;
        for (k, (qi, oi, _, _)) in chunk.iter().enumerate() {
            // length-normalized (acc_norm): mean NLL per option token
            scores[*qi][*oi] = nll.data[k] / cnt.data[k].max(1.0);
        }
    }

    let mut correct = 0usize;
    let mut predictions = Vec::with_capacity(qs.len());
    for (qi, q) in qs.iter().enumerate() {
        let pred = scores[qi]
            .iter()
            .take(q.options.len())
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        predictions.push(pred);
        if pred == q.correct {
            correct += 1;
        }
    }
    Ok(McqScore { accuracy: correct as f32 / qs.len() as f32, n: qs.len(), predictions })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_masks_only_option() {
        let (ids, mask) = pack("the answer is", "yes", 32).unwrap();
        assert_eq!(ids.len(), 32);
        let prompt_len = "the answer is ".len();
        assert!(mask[..prompt_len].iter().all(|&m| m == 0.0));
        assert!(mask[prompt_len..prompt_len + 3].iter().all(|&m| m == 1.0));
        assert!(mask[prompt_len + 3..].iter().all(|&m| m == 0.0));
    }

    #[test]
    fn pack_rejects_overflow() {
        assert!(pack(&"x".repeat(60), "yes", 32).is_none());
    }
}
