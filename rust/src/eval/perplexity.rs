//! Held-out perplexity through the fp / quantized NLL graphs
//! (the "Wiki (↓)" column of every paper table).

use anyhow::Result;

use crate::calib::TokenDataset;
use crate::pipeline::PreparedModel;
use crate::runtime::{Runtime, Value};
use crate::tensor::{IntTensor, Tensor};

/// exp(Σ nll / Σ count) over `n_batches` deterministic eval batches.
pub fn perplexity(
    rt: &Runtime,
    pm: &PreparedModel,
    data: &TokenDataset,
    n_batches: usize,
) -> Result<f32> {
    let meta = &pm.params.meta;
    let batches = data.eval_batches(meta.eval_batch, n_batches);
    let (mut nll_sum, mut cnt_sum) = (0.0f64, 0.0f64);
    // eval batches share a shape; build the all-ones mask once and only
    // rebuild if a ragged final batch shows up
    let mut mask = Tensor::zeros(&[0]);
    for b in &batches {
        if mask.shape != b.shape {
            mask = Tensor::ones(&b.shape);
        }
        let (nll, cnt) = run_nll(rt, pm, b, &mask)?;
        nll_sum += nll.data.iter().map(|&x| x as f64).sum::<f64>();
        cnt_sum += cnt.data.iter().map(|&x| x as f64).sum::<f64>();
    }
    Ok(((nll_sum / cnt_sum.max(1.0)).exp()) as f32)
}

/// One masked-NLL artifact call on the right graph for this model.
pub fn run_nll(
    rt: &Runtime,
    pm: &PreparedModel,
    tokens: &IntTensor,
    mask: &Tensor,
) -> Result<(Tensor, Tensor)> {
    let meta = &pm.params.meta;
    let mut inputs = pm.params.as_values();
    let name = if pm.quantized {
        inputs.push(Value::F32(pm.rots.r3.clone()));
        inputs.push(Value::F32(pm.rots.r4.clone()));
        inputs.push(Value::F32(pm.rots.r5.clone()));
        format!("fwd_nll_quant_{}", meta.name)
    } else {
        format!("fwd_nll_{}", meta.name)
    };
    inputs.push(Value::I32(tokens.clone()));
    inputs.push(Value::F32(mask.clone()));
    let art = rt.load(&name)?;
    let mut out = art.run(&inputs)?;
    let cnt = out.remove(1).into_f32()?;
    let nll = out.remove(0).into_f32()?;
    Ok((nll, cnt))
}
