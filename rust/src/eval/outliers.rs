//! Outlier / distribution statistics — paper Fig. 2 (numeric form).
//!
//! The paper visualizes MHSA/FFN input distributions and per-token max
//! surfaces before/after rotation. We emit the same content as numbers:
//! per-token max series, value histograms, and channel-absmax profiles,
//! written to results/*.csv by the fig2 runner.

use crate::tensor::{stats, Tensor};

#[derive(Debug, Clone)]
pub struct DistStats {
    pub mean_token_max: f32,
    pub p99_token_max: f32,
    pub max_channel_absmax: f32,
    pub median_channel_absmax: f32,
    pub mean_token_kurtosis: f32,
    /// #channels whose absmax exceeds 5× the median (the "outlier channels")
    pub outlier_channels: usize,
}

pub fn dist_stats(rows: &Tensor) -> DistStats {
    let (_r, c) = rows.as_2d();
    let token_max = stats::row_absmax(rows);
    let mut channel_absmax = vec![0.0f32; c];
    let (r, _) = rows.as_2d();
    for i in 0..r {
        for (j, v) in rows.row(i).iter().enumerate() {
            channel_absmax[j] = channel_absmax[j].max(v.abs());
        }
    }
    let median = stats::quantile(&channel_absmax, 0.5);
    let kurt = stats::kurtosis_rows(rows);
    DistStats {
        mean_token_max: token_max.iter().sum::<f32>() / token_max.len() as f32,
        p99_token_max: stats::quantile(&token_max, 0.99),
        max_channel_absmax: channel_absmax.iter().cloned().fold(0.0, f32::max),
        median_channel_absmax: median,
        mean_token_kurtosis: kurt.iter().sum::<f32>() / kurt.len() as f32,
        outlier_channels: channel_absmax.iter().filter(|&&a| a > 5.0 * median.max(1e-8)).count(),
    }
}

/// Histogram of all values (Fig. 2's density panel, as counts).
pub fn value_histogram(rows: &Tensor, bins: usize) -> (f32, f32, Vec<usize>) {
    let lo = rows.data.iter().cloned().fold(f32::INFINITY, f32::min);
    let hi = rows.data.iter().cloned().fold(f32::NEG_INFINITY, f32::max) + 1e-6;
    (lo, hi, stats::histogram(&rows.data, lo, hi, bins))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::hadamard::random_hadamard;
    use crate::tensor::matmul::rows_matmul;
    use crate::util::Rng;

    #[test]
    fn rotation_shrinks_outlier_stats() {
        let mut rng = Rng::new(0);
        let mut x = Tensor::randn(&[256, 64], 1.0, &mut rng);
        for i in 0..256 {
            x.row_mut(i)[7] *= 25.0;
        }
        let before = dist_stats(&x);
        let rot = rows_matmul(&x, &random_hadamard(64, &mut rng));
        let after = dist_stats(&rot);
        assert!(before.outlier_channels >= 1);
        assert!(after.outlier_channels < before.outlier_channels);
        assert!(after.mean_token_max < before.mean_token_max);
        assert!(after.mean_token_kurtosis < before.mean_token_kurtosis);
    }

    #[test]
    fn histogram_total_matches() {
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&[16, 16], 1.0, &mut rng);
        let (_, _, h) = value_histogram(&x, 10);
        assert_eq!(h.iter().sum::<usize>(), 256);
    }
}
