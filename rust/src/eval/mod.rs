//! Evaluation harness: perplexity, lm-eval-style option scoring, the 8
//! zero-shot task analogs, MMLU/MathQA analogs, and the paper's analysis
//! experiments (sensitivity Fig. 1, outliers Fig. 2, success rate Table 1).

pub mod outliers;
pub mod perplexity;
pub mod scorer;
pub mod sensitivity;
pub mod success;
pub mod tasks;

pub use perplexity::perplexity;
pub use scorer::{score_mcqs, McqScore};
pub use tasks::{mathqa_suite, mmlu_suite, zero_shot_suite, TaskSet};

use anyhow::Result;

use crate::pipeline::{Pipeline, PreparedModel};

/// Everything the paper's main tables report for one (model, method) cell.
#[derive(Debug, Clone)]
pub struct EvalSummary {
    pub wiki_ppl: f32,
    pub zero_shot_avg: f32,
    pub per_task: Vec<(String, f32)>,
    pub mmlu_avg: f32,
    pub per_domain: Vec<(String, f32)>,
    pub mathqa: f32,
}

/// Full evaluation of a prepared model (ppl + all accuracy suites).
pub fn evaluate(
    pipe: &Pipeline,
    pm: &PreparedModel,
    n_questions: usize,
    eval_batches: usize,
) -> Result<EvalSummary> {
    let rt = &pipe.rt;
    let wiki_ppl = perplexity(rt, pm, &pipe.bundle.test, eval_batches)?;

    let zs = zero_shot_suite(&pipe.bundle.world, n_questions, pipe.bundle.seed ^ 0x25);
    let mut per_task = Vec::new();
    let mut zs_sum = 0.0;
    for set in &zs {
        let acc = score_mcqs(rt, pm, &set.questions)?.accuracy;
        zs_sum += acc;
        per_task.push((set.name.clone(), acc));
    }
    let zero_shot_avg = zs_sum / zs.len() as f32;

    let mmlu = mmlu_suite(&pipe.bundle.world, n_questions, pipe.bundle.seed ^ 0x26);
    let mut per_domain = Vec::new();
    let mut mmlu_sum = 0.0;
    for set in &mmlu {
        let acc = score_mcqs(rt, pm, &set.questions)?.accuracy;
        mmlu_sum += acc;
        per_domain.push((set.name.clone(), acc));
    }
    let mmlu_avg = mmlu_sum / mmlu.len() as f32;

    let mq = mathqa_suite(n_questions, pipe.bundle.seed ^ 0x27);
    let mathqa = score_mcqs(rt, pm, &mq.questions)?.accuracy;

    Ok(EvalSummary { wiki_ppl, zero_shot_avg, per_task, mmlu_avg, per_domain, mathqa })
}
