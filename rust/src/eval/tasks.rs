//! Task suites: the 8 zero-shot analogs (paper Tables 9/10 columns), the
//! 4-domain MMLU analog (Table 8), and the MathQA analog (Table 5).
//!
//! Analog mapping (DESIGN.md §2): each paper task is replaced by a
//! synthetic MCQ family probing the same *kind* of capability, with the
//! ground truth present in the training corpus so a trained tiny model
//! scores above chance and quantization damage is measurable.

use crate::calib::arithmetic::math_question;
use crate::calib::facts::{Mcq, World, AUTHORS, BOOKS, DOMAINS};
use crate::util::Rng;

pub struct TaskSet {
    pub name: String,
    pub questions: Vec<Mcq>,
}

/// The 8 zero-shot task analogs (names follow the paper's columns).
pub fn zero_shot_suite(world: &World, n_per_task: usize, seed: u64) -> Vec<TaskSet> {
    let mut rng = Rng::new(seed ^ 0x87A5);
    vec![
        // ARC-E analog: easy sums (science-exam-easy → small arithmetic)
        TaskSet {
            name: "ARC-E".into(),
            questions: (0..n_per_task).map(|_| easy_sum(&mut rng)).collect(),
        },
        // ARC-C analog: harder arithmetic (products / subtraction)
        TaskSet {
            name: "ARC-C".into(),
            questions: (0..n_per_task).map(|_| math_question(&mut rng)).collect(),
        },
        // BoolQ analog: yes/no fact verification
        TaskSet {
            name: "BoolQ".into(),
            questions: (0..n_per_task).map(|_| boolq(world, &mut rng)).collect(),
        },
        // HellaSwag analog: continuation ("X wrote" → book title)
        TaskSet {
            name: "HellaSwag".into(),
            questions: continuation_set(world, n_per_task, &mut rng),
        },
        // OBQA analog: element → atomic number recall
        TaskSet { name: "OBQA".into(), questions: world.questions("stem", n_per_task, &mut rng) },
        // PIQA analog: perceptual attribute (animal → color/food)
        TaskSet { name: "PIQA".into(), questions: world.questions("other", n_per_task, &mut rng) },
        // SIQA analog: social attribute (person → job/city)
        TaskSet { name: "SIQA".into(), questions: world.questions("social", n_per_task, &mut rng) },
        // WinoGrande analog: referent binding (book → author)
        TaskSet {
            name: "WinoGrande".into(),
            questions: world.questions("humanities", n_per_task, &mut rng),
        },
    ]
}

/// The 4-domain MMLU analog (Table 8 rows: Human/Other/STEM/S-Sci).
pub fn mmlu_suite(world: &World, n_per_domain: usize, seed: u64) -> Vec<TaskSet> {
    let mut rng = Rng::new(seed ^ 0x3317);
    DOMAINS
        .iter()
        .map(|d| TaskSet {
            name: d.to_string(),
            questions: world.questions(d, n_per_domain, &mut rng),
        })
        .collect()
}

/// MathQA analog (Table 5).
pub fn mathqa_suite(n: usize, seed: u64) -> TaskSet {
    let mut rng = Rng::new(seed ^ 0x3A7B);
    TaskSet { name: "MathQA".into(), questions: (0..n).map(|_| math_question(&mut rng)).collect() }
}

// ------------------------------------------------------------ helpers

fn easy_sum(rng: &mut Rng) -> Mcq {
    let a = rng.below(20) as i64;
    let b = rng.below(20) as i64;
    let correct_val = a + b;
    let mut opts = vec![correct_val];
    while opts.len() < 4 {
        let sign = if rng.below(2) == 0 { 1 } else { -1 };
        let c = (correct_val + sign * (1 + rng.below(6) as i64)).max(0);
        if !opts.contains(&c) {
            opts.push(c);
        }
    }
    let target = correct_val.to_string();
    let mut opts: Vec<String> = opts.into_iter().map(|v| v.to_string()).collect();
    rng.shuffle(&mut opts);
    let correct = opts.iter().position(|o| *o == target).unwrap();
    Mcq { prompt: format!("{a} plus {b} is"), options: opts, correct }
}

fn boolq(world: &World, rng: &mut Rng) -> Mcq {
    let (_, animals, foods) = crate::calib::facts::entities();
    let a = rng.below(animals.len());
    let truth = rng.below(2) == 0;
    let food_idx = if truth {
        world.food_of_animal[a]
    } else {
        let mut f = rng.below(foods.len());
        while f == world.food_of_animal[a] {
            f = rng.below(foods.len());
        }
        f
    };
    Mcq {
        prompt: format!("question: the {} eats {}. answer:", animals[a], foods[food_idx]),
        options: vec!["yes".into(), "no".into()],
        correct: if truth { 0 } else { 1 },
    }
}

fn continuation_set(world: &World, n: usize, rng: &mut Rng) -> Vec<Mcq> {
    // "X wrote" → book title (reverse direction of the author question)
    (0..n)
        .map(|_| {
            let b = rng.below(world.author_of_book.len());
            let author = world.author_of_book[b];
            let mut opts = vec![b];
            while opts.len() < 4 {
                let cand = rng.below(world.author_of_book.len());
                if world.author_of_book[cand] != author && !opts.contains(&cand) {
                    opts.push(cand);
                }
            }
            let target = BOOKS[b].to_string();
            let mut options: Vec<String> = opts.iter().map(|&i| BOOKS[i].to_string()).collect();
            rng.shuffle(&mut options);
            let correct = options.iter().position(|o| *o == target).unwrap();
            Mcq { prompt: format!("{} wrote", AUTHORS[author]), options, correct }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eight_tasks_with_questions() {
        let w = World::generate(0);
        let sets = zero_shot_suite(&w, 10, 1);
        assert_eq!(sets.len(), 8);
        let names: Vec<_> = sets.iter().map(|s| s.name.as_str()).collect();
        assert!(names.contains(&"BoolQ") && names.contains(&"WinoGrande"));
        for s in &sets {
            assert_eq!(s.questions.len(), 10, "{}", s.name);
            for q in &s.questions {
                assert!(q.correct < q.options.len());
            }
        }
    }

    #[test]
    fn mmlu_has_four_domains() {
        let w = World::generate(0);
        let sets = mmlu_suite(&w, 5, 2);
        assert_eq!(sets.len(), 4);
    }

    #[test]
    fn boolq_truth_balance() {
        let w = World::generate(0);
        let mut rng = Rng::new(3);
        let qs: Vec<Mcq> = (0..200).map(|_| boolq(&w, &mut rng)).collect();
        let yes = qs.iter().filter(|q| q.correct == 0).count();
        assert!(yes > 60 && yes < 140, "yes={yes}");
    }

    #[test]
    fn easy_sums_are_correct() {
        let mut rng = Rng::new(4);
        for _ in 0..50 {
            let q = easy_sum(&mut rng);
            let parts: Vec<&str> = q.prompt.split(' ').collect();
            let a: i64 = parts[0].parse().unwrap();
            let b: i64 = parts[2].parse().unwrap();
            assert_eq!(q.options[q.correct], (a + b).to_string());
        }
    }

    #[test]
    fn continuation_correct_is_the_right_book() {
        let w = World::generate(0);
        let mut rng = Rng::new(5);
        for q in continuation_set(&w, 20, &mut rng) {
            let author_idx = AUTHORS.iter().position(|a| q.prompt.starts_with(a)).unwrap();
            let book_idx = BOOKS.iter().position(|b| *b == q.options[q.correct]).unwrap();
            assert_eq!(w.author_of_book[book_idx], author_idx);
        }
    }

    #[test]
    fn suites_are_deterministic() {
        let w = World::generate(0);
        let a = mathqa_suite(10, 7);
        let b = mathqa_suite(10, 7);
        for (x, y) in a.questions.iter().zip(&b.questions) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.options, y.options);
        }
        let _ = w;
    }
}
