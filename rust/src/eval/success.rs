//! Per-token max success rate — paper Table 1.
//!
//! "Success" = the rotated version of a token's activation vector has a
//! smaller max |value| than the baseline version. Larger per-token max ⇒
//! coarser dynamic quantization step ⇒ more error, so driving the max
//! down is the mechanism by which rotations help (paper §2).

use crate::tensor::{fused::rotate_row_absmax, Tensor};

/// Fraction of rows where `benchmark`-rotated max < `baseline`-rotated max.
/// `None` rotation = vanilla (identity).
///
/// Both absmax series run on the fused rotate→reduce kernel: the rotated
/// activation tensors are never materialized (the Table-1 sweeps feed
/// this hundreds of thousands of captured rows per cell).
pub fn success_rate(rows: &Tensor, baseline: Option<&Tensor>, benchmark: &Tensor) -> f32 {
    let base_max = rotate_row_absmax(rows, baseline);
    let bench_max = rotate_row_absmax(rows, Some(benchmark));
    let wins = base_max.iter().zip(&bench_max).filter(|(b, q)| q < b).count();
    wins as f32 / base_max.len() as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::hadamard::random_hadamard;
    use crate::util::Rng;

    #[test]
    fn hadamard_beats_vanilla_on_outlier_data() {
        let mut rng = Rng::new(0);
        let mut x = Tensor::randn(&[512, 64], 1.0, &mut rng);
        for i in 0..512 {
            x.row_mut(i)[5] *= 30.0; // outlier channel
        }
        let h = random_hadamard(64, &mut rng);
        let sr = success_rate(&x, None, &h);
        assert!(sr > 0.95, "sr={sr}");
    }

    #[test]
    fn identity_never_beats_itself() {
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&[64, 32], 1.0, &mut rng);
        let eye = Tensor::eye(32);
        assert_eq!(success_rate(&x, None, &eye), 0.0);
    }
}
