//! Quantization sensitivity Γ(x, ε) — paper Fig. 1 (Chmiel et al. 2020).
//!
//! For captured activation rows, find the MSE-optimal symmetric 4-bit step
//! size s̃ per row, then measure how much the MSE rises when the step is
//! perturbed to α·s̃. Distributions closer to uniform are flatter in α —
//! the paper's evidence that KurTail's rotation beats random Hadamard.
//!
//! The `_rotated` entry points run fused: rows are rotated a bounded
//! chunk at a time (`tensor::fused`) and consumed immediately, so the
//! sweep never materializes a rotated copy of the activation pool, and
//! the per-chunk partial curves accumulate in parallel on a fixed chunk
//! grid (deterministic reduction order at any thread count).

use crate::config::QuantScheme;
use crate::quant::fakequant::{optimal_step, row_mse_at_step};
use crate::tensor::fused::{map_rotated_chunks, FUSE_CHUNK_ROWS};
use crate::tensor::Tensor;

/// One sensitivity curve: mean over rows of MSE(α·s̃) − MSE(s̃).
pub fn sensitivity_curve(rows: &Tensor, alphas: &[f32], scheme: &QuantScheme) -> Vec<f32> {
    sensitivity_curve_rotated(rows, None, alphas, scheme)
}

/// [`sensitivity_curve`] of `rows·R`, computed without materializing the
/// rotated tensor (`rot = None` is the vanilla path).
pub fn sensitivity_curve_rotated(
    rows: &Tensor,
    rot: Option<&Tensor>,
    alphas: &[f32],
    scheme: &QuantScheme,
) -> Vec<f32> {
    curve_rotated(rows, rot, alphas, scheme, false)
}

/// Normalized sensitivity (relative to the optimal-step MSE) — what the
/// paper's y-axis effectively shows; robust to overall scale differences
/// between rotation bases.
pub fn sensitivity_curve_normalized(rows: &Tensor, alphas: &[f32], scheme: &QuantScheme) -> Vec<f32> {
    sensitivity_curve_normalized_rotated(rows, None, alphas, scheme)
}

/// [`sensitivity_curve_normalized`] of `rows·R`, fused like
/// [`sensitivity_curve_rotated`].
pub fn sensitivity_curve_normalized_rotated(
    rows: &Tensor,
    rot: Option<&Tensor>,
    alphas: &[f32],
    scheme: &QuantScheme,
) -> Vec<f32> {
    curve_rotated(rows, rot, alphas, scheme, true)
}

fn curve_rotated(
    rows: &Tensor,
    rot: Option<&Tensor>,
    alphas: &[f32],
    scheme: &QuantScheme,
    normalized: bool,
) -> Vec<f32> {
    let (r, _c) = rows.as_2d();
    let width = alphas.len();
    let n_chunks = (r + FUSE_CHUNK_ROWS - 1) / FUSE_CHUNK_ROWS;
    let mut partials = vec![0.0f64; n_chunks * width];
    map_rotated_chunks(rows, rot, &mut partials, width, |_r0, data, n_rows, pcurve| {
        let c = data.len() / n_rows;
        for i in 0..n_rows {
            let row = &data[i * c..(i + 1) * c];
            let s_opt = optimal_step(row, scheme);
            let base = row_mse_at_step(row, s_opt, scheme) as f64;
            let denom = if normalized { base.max(1e-12) } else { 1.0 };
            for (k, &a) in alphas.iter().enumerate() {
                let m = row_mse_at_step(row, a * s_opt, scheme) as f64;
                pcurve[k] += ((m - base) / denom).abs();
            }
        }
    });
    // fixed chunk-order reduction, then the mean over rows
    let mut curve = vec![0.0f64; width];
    for chunk in partials.chunks_exact(width) {
        for (acc, v) in curve.iter_mut().zip(chunk) {
            *acc += v;
        }
    }
    curve.iter().map(|&v| (v / (r.max(1)) as f64) as f32).collect()
}

/// The α grid used by the figure.
pub fn alpha_grid() -> Vec<f32> {
    (0..=20).map(|i| 0.5 + i as f32 * 0.05).collect() // 0.5 .. 1.5
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::hadamard::random_hadamard;
    use crate::tensor::matmul::rows_matmul;
    use crate::util::Rng;

    fn gen_rows(rng: &mut Rng, heavy: bool) -> Tensor {
        let (r, c) = (64, 128);
        let mut t = Tensor::zeros(&[r, c]);
        for v in &mut t.data {
            *v = if heavy { rng.laplace(1.0) } else { rng.range(-1.0, 1.0) };
        }
        t
    }

    #[test]
    fn curve_is_zero_at_alpha_one() {
        let mut rng = Rng::new(0);
        let rows = gen_rows(&mut rng, true);
        let curve = sensitivity_curve(&rows, &[1.0], &QuantScheme::act4());
        assert!(curve[0].abs() < 1e-9);
    }

    #[test]
    fn uniform_rows_less_sensitive_than_laplace() {
        // Theorem 2.2 of the paper (Chmiel et al. 2020), empirically —
        // on variance-matched rows so the raw MSE scales are comparable.
        let mut rng = Rng::new(1);
        let unif = gen_rows(&mut rng, false).scale(3f32.sqrt()); // var → 1
        let lap = gen_rows(&mut rng, true).scale(1.0 / 2f32.sqrt()); // var → 1
        let s = QuantScheme { clip_quantile: None, ..QuantScheme::act4() };
        // α > 1 (step over-estimation): the regime where the theorem's
        // no-saturation analysis applies. α < 1 is dominated by the
        // clipping cliff, which hits the uniform's hard range first.
        let alphas = [1.1, 1.2, 1.3, 1.5];
        let cu = sensitivity_curve(&unif, &alphas, &s);
        let cl = sensitivity_curve(&lap, &alphas, &s);
        let su: f32 = cu.iter().sum();
        let sl: f32 = cl.iter().sum();
        assert!(su < sl, "uniform {su} !< laplace {sl}");
    }

    #[test]
    fn fused_rotated_curve_matches_materialized() {
        let mut rng = Rng::new(4);
        let rows = gen_rows(&mut rng, true);
        let r = random_hadamard(128, &mut rng);
        let s = QuantScheme::act4();
        let alphas = [0.6, 0.9, 1.0, 1.2];
        let fused = sensitivity_curve_rotated(&rows, Some(&r), &alphas, &s);
        let materialized = sensitivity_curve(&rows_matmul(&rows, &r), &alphas, &s);
        for (f, m) in fused.iter().zip(&materialized) {
            assert!((f - m).abs() < 1e-5, "{f} vs {m}");
        }
        let fused_n = sensitivity_curve_normalized_rotated(&rows, Some(&r), &alphas, &s);
        let mat_n = sensitivity_curve_normalized(&rows_matmul(&rows, &r), &alphas, &s);
        for (f, m) in fused_n.iter().zip(&mat_n) {
            assert!((f - m).abs() < 1e-4, "norm {f} vs {m}");
        }
    }

    #[test]
    fn grid_covers_half_to_one_and_half() {
        let g = alpha_grid();
        assert!((g[0] - 0.5).abs() < 1e-6);
        assert!((g.last().unwrap() - 1.5).abs() < 1e-5);
    }
}
