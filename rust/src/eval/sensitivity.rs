//! Quantization sensitivity Γ(x, ε) — paper Fig. 1 (Chmiel et al. 2020).
//!
//! For captured activation rows, find the MSE-optimal symmetric 4-bit step
//! size s̃ per row, then measure how much the MSE rises when the step is
//! perturbed to α·s̃. Distributions closer to uniform are flatter in α —
//! the paper's evidence that KurTail's rotation beats random Hadamard.

use crate::config::QuantScheme;
use crate::quant::fakequant::{optimal_step, row_mse_at_step};
use crate::tensor::Tensor;

/// One sensitivity curve: mean over rows of MSE(α·s̃) − MSE(s̃).
pub fn sensitivity_curve(rows: &Tensor, alphas: &[f32], scheme: &QuantScheme) -> Vec<f32> {
    let (r, c) = rows.as_2d();
    let mut curve = vec![0.0f64; alphas.len()];
    for i in 0..r {
        let row = &rows.data[i * c..(i + 1) * c];
        let s_opt = optimal_step(row, scheme);
        let base = row_mse_at_step(row, s_opt, scheme) as f64;
        for (k, &a) in alphas.iter().enumerate() {
            let m = row_mse_at_step(row, a * s_opt, scheme) as f64;
            curve[k] += (m - base).abs();
        }
    }
    curve.iter().map(|&v| (v / r as f64) as f32).collect()
}

/// Normalized sensitivity (relative to the optimal-step MSE) — what the
/// paper's y-axis effectively shows; robust to overall scale differences
/// between rotation bases.
pub fn sensitivity_curve_normalized(rows: &Tensor, alphas: &[f32], scheme: &QuantScheme) -> Vec<f32> {
    let (r, c) = rows.as_2d();
    let mut curve = vec![0.0f64; alphas.len()];
    for i in 0..r {
        let row = &rows.data[i * c..(i + 1) * c];
        let s_opt = optimal_step(row, scheme);
        let base = (row_mse_at_step(row, s_opt, scheme) as f64).max(1e-12);
        for (k, &a) in alphas.iter().enumerate() {
            let m = row_mse_at_step(row, a * s_opt, scheme) as f64;
            curve[k] += ((m - base) / base).abs();
        }
    }
    curve.iter().map(|&v| (v / r as f64) as f32).collect()
}

/// The α grid used by the figure.
pub fn alpha_grid() -> Vec<f32> {
    (0..=20).map(|i| 0.5 + i as f32 * 0.05).collect() // 0.5 .. 1.5
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn gen_rows(rng: &mut Rng, heavy: bool) -> Tensor {
        let (r, c) = (64, 128);
        let mut t = Tensor::zeros(&[r, c]);
        for v in &mut t.data {
            *v = if heavy { rng.laplace(1.0) } else { rng.range(-1.0, 1.0) };
        }
        t
    }

    #[test]
    fn curve_is_zero_at_alpha_one() {
        let mut rng = Rng::new(0);
        let rows = gen_rows(&mut rng, true);
        let curve = sensitivity_curve(&rows, &[1.0], &QuantScheme::act4());
        assert!(curve[0].abs() < 1e-9);
    }

    #[test]
    fn uniform_rows_less_sensitive_than_laplace() {
        // Theorem 2.2 of the paper (Chmiel et al. 2020), empirically —
        // on variance-matched rows so the raw MSE scales are comparable.
        let mut rng = Rng::new(1);
        let unif = gen_rows(&mut rng, false).scale(3f32.sqrt()); // var → 1
        let lap = gen_rows(&mut rng, true).scale(1.0 / 2f32.sqrt()); // var → 1
        let s = QuantScheme { clip_quantile: None, ..QuantScheme::act4() };
        // α > 1 (step over-estimation): the regime where the theorem's
        // no-saturation analysis applies. α < 1 is dominated by the
        // clipping cliff, which hits the uniform's hard range first.
        let alphas = [1.1, 1.2, 1.3, 1.5];
        let cu = sensitivity_curve(&unif, &alphas, &s);
        let cl = sensitivity_curve(&lap, &alphas, &s);
        let su: f32 = cu.iter().sum();
        let sl: f32 = cl.iter().sum();
        assert!(su < sl, "uniform {su} !< laplace {sl}");
    }

    #[test]
    fn grid_covers_half_to_one_and_half() {
        let g = alpha_grid();
        assert!((g[0] - 0.5).abs() < 1e-6);
        assert!((g.last().unwrap() - 1.5).abs() < 1e-5);
    }
}
