//! KurTail CLI — the L3 leader entrypoint.
//!
//! ```text
//! kurtail exp <id>           run a paper experiment (fig1, fig2, table1..10, cost, all)
//! kurtail train <model>      pretrain a tiny model and report the loss curve
//! kurtail quantize <model>   run the full PTQ pipeline for one method
//! kurtail generate <model>   sample text through the (quantized) decode path
//! kurtail serve <model>      continuous-batching INT4 serving over N requests
//! kurtail daemon [<model>]   long-running HTTP serving daemon (drains on SIGTERM)
//! kurtail list               show artifacts + model configs
//! ```
//!
//! Global flags: --artifacts <dir> (default ./artifacts), --fast, --seed <n>.
//! Arg parsing is hand-rolled (offline build — no clap).

use std::process::ExitCode;

use kurtail::config::{Method, PipelineConfig, WeightQuantizer};
use kurtail::eval::evaluate;
use kurtail::exp::{self, ExpCtx};
use kurtail::model::generate::Generator;
use kurtail::runtime::Runtime;
use kurtail::serve::daemon::{fault::FaultSpec, signal, synthetic_model};
use kurtail::serve::{Daemon, DaemonConfig, ParBackend, ServeConfig};

struct Args {
    cmd: String,
    positional: Vec<String>,
    artifacts: String,
    fast: bool,
    seed: u64,
    method: Method,
    weights: WeightQuantizer,
    prompt: String,
    tokens: usize,
    lanes: usize,
    requests: usize,
    /// `serve`: parallel-runtime backend (None follows `KURTAIL_PAR`).
    par_backend: Option<ParBackend>,
    /// `serve`: arena decay idle-step count (None follows
    /// `KURTAIL_SCRATCH_DECAY`; 0 disables).
    scratch_decay: Option<usize>,
    /// `daemon`: bind address.
    addr: String,
    /// `daemon`: serve a self-contained random-init model (no
    /// artifacts, no calibration) — smoke tests and load generators.
    synthetic: bool,
    /// `daemon`: admission-queue bound (0 = unbounded).
    queue_cap: usize,
    /// `daemon`: per-tenant in-flight cap (0 = unbounded).
    tenant_cap: usize,
    /// `daemon`: default request deadline in ms (0 = none).
    deadline_ms: u64,
    /// `daemon`: runtime-config file, live-reloaded on SIGHUP / edit.
    config: Option<std::path::PathBuf>,
}

fn parse_args() -> Result<Args, String> {
    let mut a = Args {
        cmd: String::new(),
        positional: Vec::new(),
        artifacts: "artifacts".into(),
        fast: std::env::var("KURTAIL_FAST").is_ok(),
        seed: 0,
        method: Method::KurTail,
        weights: WeightQuantizer::Gptq,
        prompt: "the author of ".into(),
        tokens: 48,
        lanes: 4,
        requests: 8,
        par_backend: None,
        scratch_decay: None,
        addr: "127.0.0.1:8080".into(),
        synthetic: false,
        queue_cap: 64,
        tenant_cap: 0,
        deadline_ms: 0,
        config: None,
    };
    let mut it = std::env::args().skip(1);
    while let Some(arg) = it.next() {
        let mut take = |name: &str| -> Result<String, String> {
            it.next().ok_or_else(|| format!("{name} needs a value"))
        };
        match arg.as_str() {
            "--artifacts" => a.artifacts = take("--artifacts")?,
            "--fast" => a.fast = true,
            "--seed" => a.seed = take("--seed")?.parse().map_err(|e| format!("--seed: {e}"))?,
            "--method" => {
                a.method = match take("--method")?.to_ascii_lowercase().as_str() {
                    "fp16" | "16bit" => Method::Fp16,
                    "gptq" => Method::GptqOnly,
                    "quarot" => Method::QuaRot,
                    "spinquant" => Method::SpinQuant,
                    "kurtail" => Method::KurTail,
                    m => return Err(format!("unknown method '{m}'")),
                }
            }
            "--weights" => {
                a.weights = match take("--weights")?.to_ascii_lowercase().as_str() {
                    "rtn" => WeightQuantizer::Rtn,
                    "gptq" => WeightQuantizer::Gptq,
                    "none" => WeightQuantizer::None,
                    w => return Err(format!("unknown weight quantizer '{w}'")),
                }
            }
            "--prompt" => a.prompt = take("--prompt")?,
            "--tokens" => {
                a.tokens = take("--tokens")?.parse().map_err(|e| format!("--tokens: {e}"))?
            }
            "--lanes" => {
                a.lanes = take("--lanes")?.parse().map_err(|e| format!("--lanes: {e}"))?
            }
            "--requests" => {
                a.requests = take("--requests")?.parse().map_err(|e| format!("--requests: {e}"))?
            }
            "--par-backend" => {
                a.par_backend = Some(match take("--par-backend")?.to_ascii_lowercase().as_str() {
                    "static" => ParBackend::Static,
                    "steal" => ParBackend::Steal,
                    b => return Err(format!("unknown parallel backend '{b}' (static|steal)")),
                })
            }
            "--scratch-decay" => {
                a.scratch_decay =
                    Some(take("--scratch-decay")?.parse().map_err(|e| format!("--scratch-decay: {e}"))?)
            }
            "--addr" => a.addr = take("--addr")?,
            "--synthetic" => a.synthetic = true,
            "--queue-cap" => {
                a.queue_cap = take("--queue-cap")?.parse().map_err(|e| format!("--queue-cap: {e}"))?
            }
            "--tenant-cap" => {
                a.tenant_cap = take("--tenant-cap")?.parse().map_err(|e| format!("--tenant-cap: {e}"))?
            }
            "--deadline-ms" => {
                a.deadline_ms = take("--deadline-ms")?.parse().map_err(|e| format!("--deadline-ms: {e}"))?
            }
            "--config" => a.config = Some(take("--config")?.into()),
            flag if flag.starts_with("--") => return Err(format!("unknown flag '{flag}'")),
            pos => {
                if a.cmd.is_empty() {
                    a.cmd = pos.to_string();
                } else {
                    a.positional.push(pos.to_string());
                }
            }
        }
    }
    Ok(a)
}

fn usage() {
    eprintln!(
        "usage: kurtail <command> [args] [--artifacts DIR] [--fast] [--seed N]\n\
         commands:\n\
         \x20 exp <id>                         fig1|fig2|table1..table10|cost|all\n\
         \x20 train <model>                    pretrain (tiny|small|base|phi|moe)\n\
         \x20 quantize <model> [--method M] [--weights W]   full PTQ pipeline + eval\n\
         \x20 generate <model> [--method M] [--prompt P] [--tokens N]\n\
         \x20 serve <model> [--method M] [--lanes N] [--requests N] [--prompt P] [--tokens N]\n\
         \x20       [--par-backend static|steal] [--scratch-decay N]\n\
         \x20 daemon [<model>|--synthetic] [--addr HOST:PORT] [--lanes N] [--queue-cap N]\n\
         \x20       [--tenant-cap N] [--deadline-ms N] [--config FILE]\n\
         \x20       (KURTAIL_FAULT arms fault injection; SIGHUP reloads --config)\n\
         \x20 list                             artifacts + configs"
    );
}

fn main() -> ExitCode {
    let args = match parse_args() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            usage();
            return ExitCode::FAILURE;
        }
    };
    if args.cmd.is_empty() {
        usage();
        return ExitCode::FAILURE;
    }
    match dispatch(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn dispatch(args: &Args) -> anyhow::Result<()> {
    match args.cmd.as_str() {
        "exp" => {
            let id = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
            let ctx = ExpCtx::new(&args.artifacts, args.fast, args.seed)?;
            exp::run(&ctx, id)
        }
        "train" => {
            let model = args.positional.first().map(|s| s.as_str()).unwrap_or("tiny");
            let ctx = ExpCtx::new(&args.artifacts, args.fast, args.seed)?;
            let pipe = ctx.pipeline(model)?;
            println!(
                "model {model}: {} params, train corpus {} sequences",
                pipe.fp_params.param_count(),
                pipe.bundle.train.n_sequences()
            );
            Ok(())
        }
        "quantize" => {
            let model = args.positional.first().map(|s| s.as_str()).unwrap_or("small");
            let ctx = ExpCtx::new(&args.artifacts, args.fast, args.seed)?;
            let pipe = ctx.pipeline(model)?;
            let mut pcfg = PipelineConfig::new(model, args.method);
            pcfg.weight_quantizer = args.weights;
            pcfg.seed = args.seed;
            pcfg.calib.seed = args.seed;
            if args.fast {
                pcfg.calib.n_samples = 64;
                pcfg.calib.iters = 30;
            }
            let (pm, cost) = pipe.quantize(&pcfg)?;
            let s = evaluate(&pipe, &pm, ctx.n_questions(), ctx.eval_batches())?;
            println!("\nmethod       : {}", args.method.label());
            println!("weights      : {}", args.weights.label());
            println!(
                "rotation cost: {:.2}s (capture {:.2}s, optimize {:.2}s)",
                cost.total_s, cost.capture_s, cost.optimize_s
            );
            println!("wiki ppl     : {:.3}", s.wiki_ppl);
            println!("0-shot avg   : {:.1}%", s.zero_shot_avg * 100.0);
            println!("mmlu avg     : {:.1}%", s.mmlu_avg * 100.0);
            println!("mathqa       : {:.1}%", s.mathqa * 100.0);
            Ok(())
        }
        "generate" => {
            let model = args.positional.first().map(|s| s.as_str()).unwrap_or("small");
            let ctx = ExpCtx::new(&args.artifacts, args.fast, args.seed)?;
            let pipe = ctx.pipeline(model)?;
            let mut pcfg = PipelineConfig::new(model, args.method);
            // generation is served natively (INT4-packed weights); RTN
            // grids round-trip the pack exactly, whereas GPTQ's
            // Hessian-optimized rounding would be silently re-gridded
            pcfg.weight_quantizer = WeightQuantizer::Rtn;
            pcfg.seed = args.seed;
            pcfg.calib.seed = args.seed;
            if args.fast {
                pcfg.calib.n_samples = 64;
                pcfg.calib.iters = 30;
            }
            let (pm, _) = pipe.quantize(&pcfg)?;
            let rots = (pm.rots.r3.clone(), pm.rots.r4.clone(), pm.rots.r5.clone());
            let gen = Generator::new(&pipe.rt, pm.params.clone(), pm.quantized, Some(rots))?;
            for (i, text) in
                gen.generate(&args.prompt, args.tokens, 0.8, args.seed)?.iter().enumerate()
            {
                println!("[{i}] {text}");
            }
            Ok(())
        }
        "serve" => {
            let model = args.positional.first().map(|s| s.as_str()).unwrap_or("small");
            let ctx = ExpCtx::new(&args.artifacts, args.fast, args.seed)?;
            let pipe = ctx.pipeline(model)?;
            let mut pcfg = PipelineConfig::new(model, args.method);
            // the serve engine packs real INT4 itself — keep the fused
            // weights un-fake-quantized and let the pack be the grid
            pcfg.weight_quantizer = WeightQuantizer::None;
            pcfg.seed = args.seed;
            pcfg.calib.seed = args.seed;
            if args.fast {
                pcfg.calib.n_samples = 64;
                pcfg.calib.iters = 30;
            }
            let (pm, _) = pipe.quantize(&pcfg)?;
            // A/B knobs surfaced as flags so runs don't need env vars
            let scfg = ServeConfig {
                max_lanes: args.lanes,
                par_backend: args.par_backend,
                scratch_decay: args.scratch_decay,
                ..ServeConfig::default()
            };
            let mut eng = pipe.serve_engine(&pm, &scfg)?;
            for i in 0..args.requests {
                eng.submit(&args.prompt, args.tokens, 0.8, args.seed.wrapping_add(i as u64))?;
            }
            let t0 = std::time::Instant::now();
            let done = eng.run()?;
            let wall = t0.elapsed().as_secs_f64();
            for c in &done {
                println!("[{}] {}", c.id, c.text);
            }
            let total_tokens: usize = done.iter().map(|c| c.tokens.len()).sum();
            println!("\nmethod         : {}", args.method.label());
            println!("requests       : {} × {} new tokens, {} lanes", done.len(), args.tokens, args.lanes);
            println!("throughput     : {:.1} tok/s ({total_tokens} tokens in {wall:.2}s)", total_tokens as f64 / wall);
            println!(
                "kv bytes/token : {} (dense f32 cache: {}, {:.1}x)",
                eng.kv_bytes_per_token(),
                eng.dense_kv_bytes_per_token(),
                eng.dense_kv_bytes_per_token() as f64 / eng.kv_bytes_per_token() as f64
            );
            println!(
                "weight bytes   : {} (dense f32: {}, {:.1}x)",
                eng.model().weight_bytes(),
                eng.model().dense_weight_bytes(),
                eng.model().dense_weight_bytes() as f64 / eng.model().weight_bytes() as f64
            );
            Ok(())
        }
        "daemon" => {
            let fault = FaultSpec::from_env().map_err(|e| anyhow::anyhow!("KURTAIL_FAULT: {e}"))?;
            let mut scfg = ServeConfig {
                max_lanes: args.lanes,
                par_backend: args.par_backend,
                scratch_decay: args.scratch_decay,
                ..ServeConfig::default()
            };
            let model = if args.synthetic {
                synthetic_model(args.seed)?
            } else {
                let model = args.positional.first().map(|s| s.as_str()).unwrap_or("small");
                let ctx = ExpCtx::new(&args.artifacts, args.fast, args.seed)?;
                let pipe = ctx.pipeline(model)?;
                let mut pcfg = PipelineConfig::new(model, args.method);
                // same pack policy as `serve`: the engine's INT4 pack is
                // the weight grid
                pcfg.weight_quantizer = WeightQuantizer::None;
                pcfg.seed = args.seed;
                pcfg.calib.seed = args.seed;
                if args.fast {
                    pcfg.calib.n_samples = 64;
                    pcfg.calib.iters = 30;
                }
                let (pm, _) = pipe.quantize(&pcfg)?;
                pipe.serve_model(&pm, &mut scfg)?
            };
            let dcfg = DaemonConfig {
                addr: args.addr.clone(),
                queue_cap: args.queue_cap,
                per_tenant_cap: args.tenant_cap,
                default_deadline_ms: args.deadline_ms,
                serve: scfg,
                fault,
                config_path: args.config.clone(),
                ..DaemonConfig::default()
            };
            // install before spawn so a SIGTERM racing startup still
            // lands a drain instead of the default kill
            let stop = signal::install();
            let daemon = Daemon::spawn(model, &dcfg)?;
            println!("kurtail daemon listening on http://{}", daemon.addr());
            println!("  POST /v1/generate | GET /stats | GET /metrics | GET /healthz | POST /admin/drain");
            if !dcfg.fault.is_none() {
                println!("  fault injection armed: {:?}", dcfg.fault);
            }
            daemon.run_until(stop)?;
            println!("drained clean");
            Ok(())
        }
        "list" => {
            let rt = Runtime::new(&args.artifacts)?;
            println!("configs:");
            for (name, c) in &rt.manifest.configs {
                println!(
                    "  {name:<8} {}  d={} L={} H={} ff={} seq={} params≈{}",
                    c.arch, c.d_model, c.n_layers, c.n_heads, c.d_ff, c.seq_len, c.param_count()
                );
            }
            println!("artifacts: {}", rt.manifest.artifacts.len());
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}'"),
    }
}
