//! Model-side coordinator machinery: parameter store, training driver,
//! layer-wise capture, generation.

pub mod capture;
pub mod generate;
pub mod params;
pub mod trainer;

pub use capture::{capture_stream, rmsnorm_rows, LayerTaps, RowReservoir};
pub use params::Params;
pub use trainer::{train, train_or_load, TrainConfig};
