//! Layer-wise activation capture — the memory-efficiency centerpiece of
//! KurTail (paper §3 "Training Cost"): instead of an end-to-end forward
//! holding the whole model + autograd graph, we run `embed` then one
//! `layer_fwd_cap` at a time, stream each layer's taps to consumers, and
//! drop them. Peak memory is one layer's activations, not the model's.

use anyhow::Result;

use super::Params;
use crate::runtime::{Runtime, Value};
use crate::tensor::{IntTensor, Tensor};

/// One layer's activation taps for one batch.
pub struct LayerTaps {
    pub layer: usize,
    /// Residual-stream input of the MHSA block (pre-norm).
    pub mhsa_in: Tensor,
    /// Residual-stream input of the FFN block (pre-norm).
    pub ffn_in: Tensor,
    /// V activations (B, T, H, dh) — the R2 training signal.
    pub v_heads: Tensor,
    /// Wo input (B, T, d) — its GPTQ Hessian source.
    pub attn_out: Tensor,
    /// Wdown input (B, T, ff·E) — its GPTQ Hessian source.
    pub ffn_mid: Tensor,
}

/// Stream taps for every (batch, layer) to `consume`; also returns the
/// final hidden states per batch (for layer-wise NLL evaluation).
pub fn capture_stream(
    rt: &Runtime,
    params: &Params,
    batches: &[IntTensor],
    mut consume: impl FnMut(&LayerTaps) -> Result<()>,
) -> Result<Vec<Tensor>> {
    let meta = &params.meta;
    let embed_art = rt.load(&format!("embed_{}", meta.name))?;
    let layer_art = rt.load(&format!("layer_fwd_cap_{}", meta.name))?;
    // Pre-slice per-layer params once (reused across batches).
    let layer_inputs: Vec<Vec<Value>> =
        (0..meta.n_layers).map(|l| params.layer_values(l)).collect();

    let mut finals = Vec::with_capacity(batches.len());
    for batch in batches {
        let x0 = embed_art
            .run(&[Value::F32(params.get("embed").clone()), Value::I32(batch.clone())])?
            .remove(0)
            .into_f32()?;
        let mut x = x0;
        for l in 0..meta.n_layers {
            let mut inputs = layer_inputs[l].clone();
            inputs.push(Value::F32(x.clone()));
            let mut out = layer_art.run(&inputs)?;
            // outputs: y, ffn_in, v_heads, attn_out, ffn_mid
            let ffn_mid = out.remove(4).into_f32()?;
            let attn_out = out.remove(3).into_f32()?;
            let v_heads = out.remove(2).into_f32()?;
            let ffn_in = out.remove(1).into_f32()?;
            let y = out.remove(0).into_f32()?;
            consume(&LayerTaps { layer: l, mhsa_in: x, ffn_in, v_heads, attn_out, ffn_mid })?;
            x = y;
        }
        finals.push(x);
    }
    Ok(finals)
}

/// Weightless RMSNorm over the last axis — what the quantized linears see
/// after γ has been folded into the weights. Row-parallel.
pub fn rmsnorm_rows(x: &Tensor) -> Tensor {
    let (r, c) = x.as_2d();
    let mut out = x.clone();
    if r == 0 || c == 0 {
        return out;
    }
    crate::util::par::par_row_chunks_mut(
        &mut out.data,
        c,
        32,
        crate::util::par::num_threads(),
        |_r0, chunk| {
            for row in chunk.chunks_exact_mut(c) {
                rmsnorm_row(row);
            }
        },
    );
    out
}

/// Normalize one row in place (shared by the batch and streaming paths).
#[inline]
fn rmsnorm_row(row: &mut [f32]) {
    let ms = row.iter().map(|v| v * v).sum::<f32>() / row.len() as f32;
    let inv = 1.0 / (ms + 1e-5).sqrt();
    for v in row.iter_mut() {
        *v *= inv;
    }
}

/// Reservoir row sampler: keeps a bounded uniform sample of rows from a
/// stream of (N, d) tensors — the kurtail trainer's data pool.
pub struct RowReservoir {
    pub dim: usize,
    cap: usize,
    pub rows: Vec<f32>, // cap × dim, filled prefix
    seen: u64,
    rng: crate::util::Rng,
}

impl RowReservoir {
    pub fn new(dim: usize, cap: usize, seed: u64) -> Self {
        Self { dim, cap, rows: Vec::with_capacity(cap * dim), seen: 0, rng: crate::util::Rng::new(seed) }
    }

    pub fn len(&self) -> usize {
        self.rows.len() / self.dim
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Offer all rows of a (…, dim) tensor.
    pub fn offer(&mut self, x: &Tensor) {
        let (r, c) = x.as_2d();
        assert_eq!(c, self.dim, "reservoir dim mismatch");
        for i in 0..r {
            self.offer_row(&x.data[i * c..(i + 1) * c]);
        }
    }

    /// Offer the RMSNorm'd rows of a (…, dim) tensor without
    /// materializing the normed tensor: one row buffer instead of a full
    /// activation-sized copy per tap (the kurtail R1 pool feeds on every
    /// block input of every layer, so this is the peak-RSS hot spot).
    pub fn offer_rmsnorm(&mut self, x: &Tensor) {
        let (r, c) = x.as_2d();
        assert_eq!(c, self.dim, "reservoir dim mismatch");
        let mut buf = vec![0.0f32; c];
        for i in 0..r {
            buf.copy_from_slice(&x.data[i * c..(i + 1) * c]);
            rmsnorm_row(&mut buf);
            self.offer_row(&buf);
        }
    }

    /// Classic reservoir step for one row.
    fn offer_row(&mut self, row: &[f32]) {
        let c = self.dim;
        self.seen += 1;
        if self.len() < self.cap {
            self.rows.extend_from_slice(row);
        } else {
            let j = (self.rng.next_u64() % self.seen) as usize;
            if j < self.cap {
                self.rows[j * c..(j + 1) * c].copy_from_slice(row);
            }
        }
    }

    /// A shuffled (n, dim) batch sampled with replacement.
    pub fn sample(&mut self, n: usize) -> Tensor {
        assert!(!self.is_empty(), "empty reservoir");
        let rows = self.len();
        let mut data = Vec::with_capacity(n * self.dim);
        for _ in 0..n {
            let i = self.rng.below(rows);
            data.extend_from_slice(&self.rows[i * self.dim..(i + 1) * self.dim]);
        }
        Tensor::new(data, vec![n, self.dim])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn rmsnorm_unit_rms() {
        let mut rng = Rng::new(0);
        let x = Tensor::randn(&[5, 32], 3.0, &mut rng);
        let y = rmsnorm_rows(&x);
        for i in 0..5 {
            let ms: f32 = y.row(i).iter().map(|v| v * v).sum::<f32>() / 32.0;
            assert!((ms - 1.0).abs() < 1e-3, "{ms}");
        }
    }

    #[test]
    fn reservoir_caps_and_samples() {
        let mut rng = Rng::new(1);
        let mut res = RowReservoir::new(8, 100, 0);
        for _ in 0..50 {
            res.offer(&Tensor::randn(&[10, 8], 1.0, &mut rng));
        }
        assert_eq!(res.len(), 100);
        let s = res.sample(32);
        assert_eq!(s.shape, vec![32, 8]);
        assert!(s.all_finite());
    }

    #[test]
    fn offer_rmsnorm_matches_two_step() {
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&[20, 8], 2.0, &mut rng);
        let mut two_step = RowReservoir::new(8, 1000, 7);
        two_step.offer(&rmsnorm_rows(&x));
        let mut fused = RowReservoir::new(8, 1000, 7);
        fused.offer_rmsnorm(&x);
        assert_eq!(two_step.rows, fused.rows);
    }

    #[test]
    fn reservoir_is_uniformish() {
        // offer rows with a marker value; the kept fraction should track
        // the stream fraction
        let mut res = RowReservoir::new(1, 200, 2);
        let a = Tensor::new(vec![1.0; 500], vec![500, 1]);
        let b = Tensor::new(vec![2.0; 500], vec![500, 1]);
        res.offer(&a);
        res.offer(&b);
        let twos = res.rows.iter().filter(|&&v| v == 2.0).count();
        assert!(twos > 60 && twos < 140, "twos={twos}");
    }
}
