//! Parameter store: the model's weights in manifest order, with named
//! access, per-layer slicing (for layer-wise inference), and a simple
//! binary snapshot format so training runs are cached across experiments.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::runtime::{ConfigMeta, Value};
use crate::tensor::Tensor;
use crate::util::Rng;

/// All weights of one model, in the canonical (manifest) order.
#[derive(Clone)]
pub struct Params {
    pub meta: ConfigMeta,
    values: Vec<Tensor>,
    index: BTreeMap<String, usize>,
}

impl Params {
    pub fn from_tensors(meta: &ConfigMeta, values: Vec<Tensor>) -> Result<Self> {
        anyhow::ensure!(values.len() == meta.n_params(), "param count mismatch");
        for (t, spec) in values.iter().zip(&meta.param_specs) {
            anyhow::ensure!(
                t.shape == spec.shape,
                "param '{}': shape {:?} != spec {:?}",
                spec.name, t.shape, spec.shape
            );
        }
        let index =
            meta.param_specs.iter().enumerate().map(|(i, p)| (p.name.clone(), i)).collect();
        Ok(Self { meta: meta.clone(), values, index })
    }

    /// Scaled-normal init mirroring `compile.model.init_params`.
    pub fn init(meta: &ConfigMeta, rng: &mut Rng) -> Self {
        let n_layers = meta.n_layers as f32;
        let values = meta
            .param_specs
            .iter()
            .map(|p| {
                if p.name.starts_with("ln") {
                    Tensor::ones(&p.shape)
                } else if p.name == "embed" || p.name == "head" {
                    Tensor::randn(&p.shape, 0.02, rng)
                } else {
                    let fan_in = p.shape[p.shape.len() - 2] as f32;
                    let mut std = 1.0 / fan_in.sqrt();
                    if p.name == "wo" || p.name == "wd" {
                        std /= (2.0 * n_layers).sqrt();
                    }
                    Tensor::randn(&p.shape, std, rng)
                }
            })
            .collect();
        Self::from_tensors(meta, values).expect("init shapes match specs")
    }

    pub fn get(&self, name: &str) -> &Tensor {
        &self.values[*self.index.get(name).unwrap_or_else(|| panic!("no param '{name}'"))]
    }

    pub fn get_mut(&mut self, name: &str) -> &mut Tensor {
        let i = *self.index.get(name).unwrap_or_else(|| panic!("no param '{name}'"));
        &mut self.values[i]
    }

    pub fn has(&self, name: &str) -> bool {
        self.index.contains_key(name)
    }

    pub fn set(&mut self, name: &str, t: Tensor) {
        let i = *self.index.get(name).unwrap_or_else(|| panic!("no param '{name}'"));
        assert_eq!(t.shape, self.values[i].shape, "set '{name}'");
        self.values[i] = t;
    }

    /// All tensors in manifest order as artifact inputs.
    pub fn as_values(&self) -> Vec<Value> {
        self.values.iter().map(|t| Value::F32(t.clone())).collect()
    }

    /// Replace all values from artifact outputs (e.g. after a train step).
    pub fn update_from_values(&mut self, vals: &[Value]) -> Result<()> {
        anyhow::ensure!(vals.len() == self.values.len(), "value count mismatch");
        for (slot, v) in self.values.iter_mut().zip(vals) {
            *slot = v.as_f32()?.clone();
        }
        Ok(())
    }

    /// Zero tensors shaped like the params (Adam moment buffers).
    pub fn zeros_like(&self) -> Vec<Value> {
        self.values.iter().map(|t| Value::F32(Tensor::zeros(&t.shape))).collect()
    }

    /// Single-layer parameter slices in `layer_fwd_cap` input order
    /// (= manifest order minus embed/lnf/head, leading L axis indexed).
    pub fn layer_values(&self, layer: usize) -> Vec<Value> {
        self.meta
            .param_specs
            .iter()
            .filter(|p| !matches!(p.name.as_str(), "embed" | "lnf" | "head"))
            .map(|p| Value::F32(self.get(&p.name).index_axis0(layer)))
            .collect()
    }

    pub fn param_count(&self) -> usize {
        self.values.iter().map(|t| t.numel()).sum()
    }

    // ---- binary snapshots (cache trained models across experiments) -----

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
        f.write_all(b"KTP1")?;
        f.write_all(&(self.values.len() as u32).to_le_bytes())?;
        for (t, spec) in self.values.iter().zip(&self.meta.param_specs) {
            let name = spec.name.as_bytes();
            f.write_all(&(name.len() as u32).to_le_bytes())?;
            f.write_all(name)?;
            f.write_all(&(t.shape.len() as u32).to_le_bytes())?;
            for &d in &t.shape {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            for &x in &t.data {
                f.write_all(&x.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(meta: &ConfigMeta, path: &Path) -> Result<Self> {
        let mut f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == b"KTP1", "bad snapshot magic");
        let n = read_u32(&mut f)? as usize;
        anyhow::ensure!(n == meta.n_params(), "snapshot param count {n} != {}", meta.n_params());
        let mut values = Vec::with_capacity(n);
        for spec in &meta.param_specs {
            let name_len = read_u32(&mut f)? as usize;
            let mut name = vec![0u8; name_len];
            f.read_exact(&mut name)?;
            anyhow::ensure!(
                name == spec.name.as_bytes(),
                "snapshot param order mismatch at '{}'",
                spec.name
            );
            let rank = read_u32(&mut f)? as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                let mut b = [0u8; 8];
                f.read_exact(&mut b)?;
                shape.push(u64::from_le_bytes(b) as usize);
            }
            anyhow::ensure!(shape == spec.shape, "snapshot shape mismatch for '{}'", spec.name);
            let numel: usize = shape.iter().product();
            let mut buf = vec![0u8; numel * 4];
            f.read_exact(&mut buf)?;
            let data = buf.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect();
            values.push(Tensor::new(data, shape));
        }
        Self::from_tensors(meta, values)
    }
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Hand-built `ConfigMeta`s for unit tests that don't need artifacts.
#[cfg(test)]
pub mod tests_support {
    use crate::runtime::manifest::{ConfigMeta, ParamSpec};

    /// A complete 2-layer llama-arch meta (all weights present).
    pub fn fake_llama_meta() -> ConfigMeta {
        let (l, d, ff, v) = (2usize, 8usize, 16usize, 12usize);
        let spec = |name: &str, shape: Vec<usize>| ParamSpec { name: name.into(), shape };
        ConfigMeta {
            name: "fakellama".into(),
            vocab: v,
            d_model: d,
            n_layers: l,
            n_heads: 2,
            d_head: d / 2,
            d_ff: ff,
            seq_len: 8,
            arch: "llama".into(),
            n_experts: 1,
            top_k: 2,
            train_batch: 2,
            eval_batch: 2,
            cap_batch: 2,
            decode_batch: 2,
            spin_batch: 2,
            param_specs: vec![
                spec("embed", vec![v, d]),
                spec("ln1", vec![l, d]),
                spec("wq", vec![l, d, d]),
                spec("wk", vec![l, d, d]),
                spec("wv", vec![l, d, d]),
                spec("wo", vec![l, d, d]),
                spec("ln2", vec![l, d]),
                spec("wg", vec![l, d, ff]),
                spec("wu", vec![l, d, ff]),
                spec("wd", vec![l, ff, d]),
                spec("lnf", vec![d]),
                spec("head", vec![v, d]),
            ],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ParamSpec;

    pub(crate) fn fake_meta() -> ConfigMeta {
        ConfigMeta {
            name: "fake".into(),
            vocab: 8,
            d_model: 4,
            n_layers: 2,
            n_heads: 2,
            d_head: 2,
            d_ff: 8,
            seq_len: 8,
            arch: "llama".into(),
            n_experts: 1,
            top_k: 2,
            train_batch: 2,
            eval_batch: 2,
            cap_batch: 2,
            decode_batch: 2,
            spin_batch: 2,
            param_specs: vec![
                ParamSpec { name: "embed".into(), shape: vec![8, 4] },
                ParamSpec { name: "ln1".into(), shape: vec![2, 4] },
                ParamSpec { name: "wq".into(), shape: vec![2, 4, 4] },
                ParamSpec { name: "lnf".into(), shape: vec![4] },
                ParamSpec { name: "head".into(), shape: vec![8, 4] },
            ],
        }
    }

    #[test]
    fn init_and_access() {
        let meta = fake_meta();
        let mut rng = Rng::new(0);
        let p = Params::init(&meta, &mut rng);
        assert_eq!(p.get("embed").shape, vec![8, 4]);
        assert_eq!(p.get("ln1").data, vec![1.0; 8]);
        assert_eq!(p.param_count(), 8 * 4 + 2 * 4 + 2 * 16 + 4 + 32);
    }

    #[test]
    fn layer_values_slices() {
        let meta = fake_meta();
        let mut rng = Rng::new(1);
        let p = Params::init(&meta, &mut rng);
        let lv = p.layer_values(1);
        assert_eq!(lv.len(), 2); // ln1, wq
        assert_eq!(lv[0].shape(), &[4]);
        assert_eq!(lv[1].shape(), &[4, 4]);
        assert_eq!(lv[1].as_f32().unwrap().data, p.get("wq").index_axis0(1).data);
    }

    #[test]
    fn snapshot_roundtrip() {
        let meta = fake_meta();
        let mut rng = Rng::new(2);
        let p = Params::init(&meta, &mut rng);
        let dir = std::env::temp_dir().join("kurtail_test_params.bin");
        p.save(&dir).unwrap();
        let q = Params::load(&meta, &dir).unwrap();
        for spec in &meta.param_specs {
            assert_eq!(p.get(&spec.name).data, q.get(&spec.name).data, "{}", spec.name);
        }
        std::fs::remove_file(dir).ok();
    }
}
