//! Training driver: the Rust loop around the AOT `train_step_{cfg}`
//! artifact. The e2e example uses this to pretrain the tiny model family
//! from scratch on the synthetic corpus (the substitution for downloading
//! LLaMA checkpoints — DESIGN.md §2), logging the loss curve.

use anyhow::Result;

use super::Params;
use crate::calib::TokenDataset;
use crate::runtime::{Runtime, Value};
use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f32,
    /// Linear warmup steps, then cosine decay to lr/10.
    pub warmup: usize,
    pub log_every: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self { steps: 300, lr: 3e-3, warmup: 20, log_every: 50, seed: 0 }
    }
}

pub struct TrainReport {
    pub losses: Vec<f32>,
    pub wall_s: f64,
}

/// Run `cfg.steps` Adam steps; mutates `params` in place.
pub fn train(
    rt: &Runtime,
    params: &mut Params,
    data: &TokenDataset,
    cfg: &TrainConfig,
    verbose: bool,
) -> Result<TrainReport> {
    let meta = params.meta.clone();
    let art = rt.load(&format!("train_step_{}", meta.name))?;
    let mut rng = Rng::new(cfg.seed ^ 0x7124);
    let n = meta.n_params();
    let mut m = params.zeros_like();
    let mut v = params.zeros_like();
    let mut losses = Vec::with_capacity(cfg.steps);
    let t0 = std::time::Instant::now();

    for step in 1..=cfg.steps {
        let lr = schedule(cfg, step);
        let batch = data.random_batch(meta.train_batch, &mut rng);
        let mut inputs: Vec<Value> = Vec::with_capacity(3 * n + 3);
        inputs.extend(params.as_values());
        inputs.extend(m.iter().cloned());
        inputs.extend(v.iter().cloned());
        inputs.push(batch.into());
        inputs.push(Value::from(lr));
        inputs.push(Value::from(step as f32));
        let out = art.run(&inputs)?;
        params.update_from_values(&out[..n])?;
        m = out[n..2 * n].to_vec();
        v = out[2 * n..3 * n].to_vec();
        let loss = out[3 * n].scalar_f32()?;
        losses.push(loss);
        if verbose && (step % cfg.log_every == 0 || step == 1) {
            println!("  step {step:>5}  lr {lr:.2e}  loss {loss:.4}");
        }
    }
    Ok(TrainReport { losses, wall_s: t0.elapsed().as_secs_f64() })
}

fn schedule(cfg: &TrainConfig, step: usize) -> f32 {
    if step <= cfg.warmup {
        return cfg.lr * step as f32 / cfg.warmup as f32;
    }
    let p = (step - cfg.warmup) as f32 / (cfg.steps - cfg.warmup).max(1) as f32;
    let min_lr = cfg.lr / 10.0;
    min_lr + 0.5 * (cfg.lr - min_lr) * (1.0 + (std::f32::consts::PI * p).cos())
}

/// Train-or-load: snapshots trained weights next to the artifacts so the
/// (deterministic) pretraining is shared by every experiment on a config.
pub fn train_or_load(
    rt: &Runtime,
    cfg_name: &str,
    data: &TokenDataset,
    tcfg: &TrainConfig,
    verbose: bool,
) -> Result<Params> {
    let meta = rt.manifest.config(cfg_name)?.clone();
    let snap = rt.dir.join(format!(
        "params_{cfg_name}_s{}_n{}_seed{}.bin",
        tcfg.steps, data.n_sequences(), tcfg.seed
    ));
    if snap.exists() {
        if verbose {
            println!("  loading cached weights {snap:?}");
        }
        return Params::load(&meta, &snap);
    }
    let mut rng = Rng::new(tcfg.seed);
    let mut params = Params::init(&meta, &mut rng);
    if verbose {
        println!(
            "  pretraining {cfg_name} ({} params, {} steps)…",
            params.param_count(),
            tcfg.steps
        );
    }
    let report = train(rt, &mut params, data, tcfg, verbose)?;
    if verbose {
        let first = report.losses.first().unwrap();
        let last = report.losses.last().unwrap();
        println!("  trained: loss {first:.3} → {last:.3} in {:.1}s", report.wall_s);
    }
    params.save(&snap)?;
    Ok(params)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_shape() {
        let cfg = TrainConfig { steps: 100, lr: 1e-2, warmup: 10, ..Default::default() };
        assert!(schedule(&cfg, 1) < schedule(&cfg, 10));
        assert!((schedule(&cfg, 10) - 1e-2).abs() < 1e-6);
        assert!(schedule(&cfg, 100) < 2e-3);
    }
}
