//! Autoregressive generation. [`Generator::generate`] is a thin client
//! of the native serving engine ([`crate::serve`]): packed INT4 weights,
//! paged 4-bit KV cache, batched prefill. The original artifact-driven
//! decode loop survives as [`Generator::generate_artifact`] — it
//! exercises the AOT `decode_step_{cfg}` graphs (dense f32 caches) and
//! anchors the serve engine's parity test.

use anyhow::Result;

use super::Params;
use crate::calib::ByteTokenizer;
use crate::config::KvQuant;
use crate::runtime::{Runtime, Value};
use crate::serve::{sample_token, Engine, ServeConfig, ServeModel, ServeQuantSpec};
use crate::tensor::{IntTensor, Tensor};
use crate::util::Rng;

pub struct Generator {
    art: std::sync::Arc<crate::runtime::Artifact>,
    params: Params,
    quant: bool,
    rots: Option<(Tensor, Tensor, Tensor)>, // r3, r4, r5
    pub batch: usize,
    pub tmax: usize,
}

impl Generator {
    /// `rots`: online rotations for the quantized decode graph (ignored in fp).
    pub fn new(
        rt: &Runtime,
        params: Params,
        quant: bool,
        rots: Option<(Tensor, Tensor, Tensor)>,
    ) -> Result<Self> {
        let meta = &params.meta;
        let name = if quant {
            format!("decode_step_quant_{}", meta.name)
        } else {
            format!("decode_step_{}", meta.name)
        };
        let art = rt.load(&name)?;
        anyhow::ensure!(!quant || rots.is_some(), "quant decode needs online rotations");
        Ok(Self {
            art,
            batch: meta.decode_batch,
            tmax: meta.seq_len,
            params,
            quant,
            rots,
        })
    }

    /// Greedy-or-sampled continuation of `prompt` for all batch lanes,
    /// served natively (INT4 weights + paged 4-bit KV + batched prefill).
    /// Returns decoded strings (including the prompt). Lanes sample from
    /// independent per-request streams seeded off `seed`. Unsupported
    /// archs (moe) fall back to the artifact decode loop.
    ///
    /// Weight caveat: the quant path packs `params` onto the serve RTN
    /// grid. RTN-quantized (or unquantized) weights round-trip exactly;
    /// GPTQ-prepared weights get re-gridded (≤ half-step movement) —
    /// use [`Self::generate_artifact`] to decode a GPTQ model verbatim.
    pub fn generate(&self, prompt: &str, n_tokens: usize, temp: f32, seed: u64) -> Result<Vec<String>> {
        if !matches!(self.params.meta.arch.as_str(), "llama" | "phi") {
            return self.generate_artifact(prompt, n_tokens, temp, seed);
        }
        if n_tokens == 0 {
            return Ok(vec![prompt.to_string(); self.batch.max(1)]);
        }
        let (spec, kv) = if self.quant {
            let (r3, r4, r5) =
                self.rots.clone().expect("quant decode needs online rotations");
            (Some(ServeQuantSpec::paper_default(r3, r4, r5)), KvQuant::Asym4)
        } else {
            (None, KvQuant::Fp)
        };
        let model = ServeModel::from_params(&self.params, spec)?;
        let cfg = ServeConfig { max_lanes: self.batch.max(1), kv_quant: kv, ..ServeConfig::default() };
        let mut eng = Engine::new(model, &cfg)?;
        for lane in 0..self.batch.max(1) {
            eng.submit(prompt, n_tokens, temp, seed.wrapping_add(lane as u64))?;
        }
        Ok(eng.run()?.into_iter().map(|c| c.text).collect())
    }

    /// The original decode path through the `decode_step_{cfg}` artifact
    /// (dense f32 KV caches). Parameter values and the online rotations
    /// are built **once** and reused across the token loop — only the
    /// cache/token/pos slots change per step.
    pub fn generate_artifact(&self, prompt: &str, n_tokens: usize, temp: f32, seed: u64) -> Result<Vec<String>> {
        let meta = &self.params.meta;
        let tok = ByteTokenizer;
        let prompt_ids = tok.encode(prompt);
        anyhow::ensure!(!prompt_ids.is_empty(), "empty prompt");
        anyhow::ensure!(
            prompt_ids.len() + n_tokens <= self.tmax,
            "prompt+generation exceeds cache size {}",
            self.tmax
        );
        let (l, b, h, dh) = (meta.n_layers, self.batch, meta.n_heads, meta.d_head);
        let cache_shape = vec![l, b, self.tmax, h, dh];
        let mut rng = Rng::new(seed);

        let mut seqs: Vec<Vec<i32>> = vec![prompt_ids.clone(); b];
        // static inputs hoisted out of the token loop: weights (+ online
        // rotations for the quant graph) are cloned exactly once per call
        let mut inputs = self.params.as_values();
        if self.quant {
            let (r3, r4, r5) = self.rots.as_ref().unwrap();
            inputs.push(Value::F32(r3.clone()));
            inputs.push(Value::F32(r4.clone()));
            inputs.push(Value::F32(r5.clone()));
        }
        let base = inputs.len();
        inputs.push(Value::F32(Tensor::zeros(&cache_shape))); // k cache
        inputs.push(Value::F32(Tensor::zeros(&cache_shape))); // v cache
        inputs.push(Value::I32(IntTensor::zeros(&[b]))); // token slot
        inputs.push(Value::from(0i32)); // pos slot

        for pos in 0..prompt_ids.len() + n_tokens - 1 {
            let token: Vec<i32> = seqs
                .iter()
                .map(|s| *s.get(pos).unwrap_or(s.last().unwrap()))
                .collect();
            inputs[base + 2] = Value::I32(IntTensor::new(token, vec![b]));
            inputs[base + 3] = Value::from(pos as i32);
            let mut out = self.art.run(&inputs)?;
            // thread the updated caches straight back into the input slots
            inputs[base + 1] = out.remove(2);
            inputs[base] = out.remove(1);
            let logits = out.remove(0).into_f32()?;
            if pos + 1 >= prompt_ids.len() {
                for lane in 0..b {
                    let next = sample_token(logits.row(lane), temp, &mut rng);
                    seqs[lane].push(next);
                }
            }
        }
        Ok(seqs.iter().map(|s| tok.decode(s)).collect())
    }
}

#[cfg(test)]
mod tests {
    use crate::serve::{argmax, sample_token};
    use crate::util::Rng;

    #[test]
    fn argmax_and_greedy() {
        let logits = vec![0.0, 3.0, 1.0];
        assert_eq!(argmax(&logits), 1);
        let mut rng = Rng::new(0);
        assert_eq!(sample_token(&logits, 0.0, &mut rng), 1);
    }

    #[test]
    fn sampling_respects_distribution() {
        let logits = vec![0.0, 10.0];
        let mut rng = Rng::new(1);
        let picks: Vec<i32> = (0..50).map(|_| sample_token(&logits, 1.0, &mut rng)).collect();
        assert!(picks.iter().filter(|&&p| p == 1).count() > 45);
    }
}
