//! Autoregressive generation through the `decode_step_{cfg}` artifacts —
//! the serving-flavoured path that exercises 4-bit KV-cache quantization
//! token by token (what the paper's generation-stage analysis is about).

use anyhow::Result;

use super::Params;
use crate::calib::ByteTokenizer;
use crate::runtime::{Runtime, Value};
use crate::tensor::{IntTensor, Tensor};
use crate::util::Rng;

pub struct Generator {
    art: std::sync::Arc<crate::runtime::Artifact>,
    params: Params,
    quant: bool,
    rots: Option<(Tensor, Tensor, Tensor)>, // r3, r4, r5
    pub batch: usize,
    pub tmax: usize,
}

impl Generator {
    /// `rots`: online rotations for the quantized decode graph (ignored in fp).
    pub fn new(
        rt: &Runtime,
        params: Params,
        quant: bool,
        rots: Option<(Tensor, Tensor, Tensor)>,
    ) -> Result<Self> {
        let meta = &params.meta;
        let name = if quant {
            format!("decode_step_quant_{}", meta.name)
        } else {
            format!("decode_step_{}", meta.name)
        };
        let art = rt.load(&name)?;
        anyhow::ensure!(!quant || rots.is_some(), "quant decode needs online rotations");
        Ok(Self {
            art,
            batch: meta.decode_batch,
            tmax: meta.seq_len,
            params,
            quant,
            rots,
        })
    }

    /// Greedy-or-sampled continuation of `prompt` for all batch lanes.
    /// Returns decoded strings (including the prompt).
    pub fn generate(&self, prompt: &str, n_tokens: usize, temp: f32, seed: u64) -> Result<Vec<String>> {
        let meta = &self.params.meta;
        let tok = ByteTokenizer;
        let prompt_ids = tok.encode(prompt);
        anyhow::ensure!(!prompt_ids.is_empty(), "empty prompt");
        anyhow::ensure!(
            prompt_ids.len() + n_tokens <= self.tmax,
            "prompt+generation exceeds cache size {}",
            self.tmax
        );
        let (l, b, h, dh) = (meta.n_layers, self.batch, meta.n_heads, meta.d_head);
        let cache_shape = vec![l, b, self.tmax, h, dh];
        let mut kc = Tensor::zeros(&cache_shape);
        let mut vc = Tensor::zeros(&cache_shape);
        let mut rng = Rng::new(seed);

        let mut seqs: Vec<Vec<i32>> = vec![prompt_ids.clone(); b];
        let mut logits = Tensor::zeros(&[b, meta.vocab]);
        // prefill token by token (decode-path prefill; fine at these sizes)
        for pos in 0..prompt_ids.len() + n_tokens - 1 {
            let token: Vec<i32> = seqs
                .iter()
                .map(|s| *s.get(pos).unwrap_or(s.last().unwrap()))
                .collect();
            let mut inputs = self.params.as_values();
            if self.quant {
                let (r3, r4, r5) = self.rots.as_ref().unwrap();
                inputs.push(Value::F32(r3.clone()));
                inputs.push(Value::F32(r4.clone()));
                inputs.push(Value::F32(r5.clone()));
            }
            inputs.push(Value::F32(kc));
            inputs.push(Value::F32(vc));
            inputs.push(Value::I32(IntTensor::new(token, vec![b])));
            inputs.push(Value::from(pos as i32));
            let mut out = self.art.run(&inputs)?;
            vc = out.remove(2).into_f32()?;
            kc = out.remove(1).into_f32()?;
            logits = out.remove(0).into_f32()?;
            if pos + 1 >= prompt_ids.len() {
                for lane in 0..b {
                    let next = sample_token(logits.row(lane), temp, &mut rng);
                    seqs[lane].push(next);
                }
            }
        }
        let _ = logits;
        Ok(seqs.iter().map(|s| tok.decode(s)).collect())
    }
}

fn sample_token(logits: &[f32], temp: f32, rng: &mut Rng) -> i32 {
    if temp <= 0.0 {
        return argmax(logits) as i32;
    }
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&l| ((l - max) / temp).exp()).collect();
    let sum: f32 = exps.iter().sum();
    let mut u = rng.uniform() * sum;
    for (i, e) in exps.iter().enumerate() {
        u -= e;
        if u <= 0.0 {
            return i as i32;
        }
    }
    (exps.len() - 1) as i32
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).map(|(i, _)| i).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_and_greedy() {
        let logits = vec![0.0, 3.0, 1.0];
        assert_eq!(argmax(&logits), 1);
        let mut rng = Rng::new(0);
        assert_eq!(sample_token(&logits, 0.0, &mut rng), 1);
    }

    #[test]
    fn sampling_respects_distribution() {
        let logits = vec![0.0, 10.0];
        let mut rng = Rng::new(1);
        let picks: Vec<i32> = (0..50).map(|_| sample_token(&logits, 1.0, &mut rng)).collect();
        assert!(picks.iter().filter(|&&p| p == 1).count() > 45);
    }
}
