//! Debug counting allocator: a [`GlobalAlloc`] wrapper over the system
//! allocator that counts allocation events, so tests can assert that a
//! code region performs **zero heap allocations**.
//!
//! Install it per test binary:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: kurtail::util::alloc::CountingAlloc =
//!     kurtail::util::alloc::CountingAlloc::new();
//! ```
//!
//! then snapshot [`CountingAlloc::allocations`] around the region under
//! test (`tests/serve_scratch.rs` pins the serve engine's steady-state
//! decode this way). `alloc`, `alloc_zeroed`, and `realloc` each count
//! as one event — a `Vec` growing in place via `realloc` is still a
//! heap round-trip the hot path must not take. `dealloc` is not
//! counted: dropping is fine to observe, acquiring is not.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// Allocation-counting wrapper over [`System`].
#[derive(Default)]
pub struct CountingAlloc {
    events: AtomicU64,
}

impl CountingAlloc {
    pub const fn new() -> Self {
        Self { events: AtomicU64::new(0) }
    }

    /// Allocation events (alloc + alloc_zeroed + realloc) so far.
    pub fn allocations(&self) -> u64 {
        self.events.load(Ordering::SeqCst)
    }
}

// SAFETY: delegates every operation to `System` unchanged; the only
// addition is a relaxed-enough atomic counter bump, which allocates
// nothing and is reentrancy-safe.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        self.events.fetch_add(1, Ordering::SeqCst);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        self.events.fetch_add(1, Ordering::SeqCst);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        self.events.fetch_add(1, Ordering::SeqCst);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}
