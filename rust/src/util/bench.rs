//! Micro-benchmark harness (criterion stand-in for the offline build).
//!
//! `cargo bench` targets use this: warmup, adaptive iteration count,
//! mean/σ/min reporting, and machine-readable lines (`BENCH\t<name>\t<ns>`)
//! that EXPERIMENTS.md §Perf scrapes. [`Bench::write_json`] additionally
//! dumps every recorded stat as JSON — `benches/kernels.rs` uses it to
//! emit `BENCH_kernels.json`, the scalar-vs-packed perf trajectory that
//! `scripts/bench.sh` tracks PR-over-PR.

use std::time::Instant;

use crate::util::json::{arr, num, obj, s, Json};

pub struct Bench {
    /// Minimum sampling time per benchmark (seconds).
    pub min_time_s: f64,
    pub warmup_s: f64,
    /// Minimum number of samples regardless of elapsed time (≥ 2 always
    /// enforced); lets multi-second kernels cap their iteration count.
    pub min_samples: usize,
    results: Vec<(String, Stats)>,
}

#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: u64,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
}

impl Default for Bench {
    fn default() -> Self {
        Self { min_time_s: 1.0, warmup_s: 0.2, min_samples: 5, results: Vec::new() }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn quick() -> Self {
        Self { min_time_s: 0.3, warmup_s: 0.05, ..Self::default() }
    }

    /// Run one benchmark; `f` is invoked repeatedly, timed per call.
    pub fn run<R>(&mut self, name: &str, mut f: impl FnMut() -> R) -> Stats {
        // Warmup
        let t0 = Instant::now();
        while t0.elapsed().as_secs_f64() < self.warmup_s {
            std::hint::black_box(f());
        }
        // Sample
        let min_samples = self.min_samples.max(2);
        let mut samples = Vec::new();
        let t1 = Instant::now();
        while t1.elapsed().as_secs_f64() < self.min_time_s || samples.len() < min_samples {
            let s = Instant::now();
            std::hint::black_box(f());
            samples.push(s.elapsed().as_nanos() as f64);
            if samples.len() > 100_000 {
                break;
            }
        }
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n;
        let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
        let st = Stats { iters: samples.len() as u64, mean_ns: mean, std_ns: var.sqrt(), min_ns: min };
        println!(
            "{name:<48} {:>12}/iter  (σ {:>10}, min {:>10}, n={})",
            fmt_ns(st.mean_ns),
            fmt_ns(st.std_ns),
            fmt_ns(st.min_ns),
            st.iters
        );
        println!("BENCH\t{name}\t{:.1}", st.mean_ns);
        self.results.push((name.to_string(), st));
        st
    }

    pub fn results(&self) -> &[(String, Stats)] {
        &self.results
    }

    /// Look up a recorded stat by exact name.
    pub fn stat(&self, name: &str) -> Option<Stats> {
        self.results.iter().find(|(n, _)| n == name).map(|(_, s)| *s)
    }

    /// All recorded stats as a JSON array (name, mean/σ/min ns, iters).
    pub fn results_json(&self) -> Json {
        arr(self
            .results
            .iter()
            .map(|(name, st)| {
                obj(vec![
                    ("name", s(name)),
                    ("mean_ns", num(st.mean_ns)),
                    ("std_ns", num(st.std_ns)),
                    ("min_ns", num(st.min_ns)),
                    ("iters", num(st.iters as f64)),
                ])
            })
            .collect())
    }

    /// Write `extra` top-level fields + `"results"` to `path` as JSON.
    pub fn write_json(&self, path: &str, extra: Vec<(&str, Json)>) -> std::io::Result<()> {
        let mut fields = extra;
        fields.push(("results", self.results_json()));
        std::fs::write(path, obj(fields).to_string_pretty())
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench { min_time_s: 0.02, warmup_s: 0.0, ..Bench::new() };
        let st = b.run("spin", || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(st.mean_ns > 0.0 && st.iters >= 5);
        assert!(b.stat("spin").is_some());
        assert!(b.stat("nope").is_none());
    }

    #[test]
    fn min_samples_caps_iterations() {
        let mut b = Bench { min_time_s: 0.0, warmup_s: 0.0, min_samples: 2, results: vec![] };
        let st = b.run("two", || std::thread::sleep(std::time::Duration::from_millis(1)));
        assert_eq!(st.iters, 2);
    }

    #[test]
    fn json_roundtrips() {
        let mut b = Bench { min_time_s: 0.0, warmup_s: 0.0, min_samples: 2, results: vec![] };
        b.run("k", || 1 + 1);
        let j = b.results_json();
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        let first = &parsed.as_arr().unwrap()[0];
        assert_eq!(first.get("name").unwrap().as_str().unwrap(), "k");
        assert!(first.get("mean_ns").unwrap().as_f64().unwrap() >= 0.0);
    }
}
