//! Minimal JSON parser + writer (the offline build has no serde).
//!
//! Parses the subset of JSON that `artifacts/manifest.json` and the
//! experiment reports use: objects, arrays, strings, f64 numbers, bools,
//! null. Strings support the standard escapes. This is a substrate module
//! with its own tests — not a speed-critical path.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow::anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- writer ----------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    /// Single-line form (ndjson stream lines, log records).
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write_compact(&mut s);
        s
    }

    fn write_compact(&self, out: &mut String) {
        match self {
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write_compact(out);
                }
                out.push('}');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    x.write_compact(out);
                }
                out.push(']');
            }
            // scalars never contain newlines (write_escaped covers Str)
            leaf => leaf.write(out, 0),
        }
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    x.write(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push_str("{\n");
                let pad = "  ".repeat(indent + 1);
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow::anyhow!("unexpected end of JSON"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, got '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(key, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, got '{}'", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, got '{}'", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // raw UTF-8 passthrough: collect continuation bytes
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        self.i = start + len;
                        s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse()?))
    }
}

/// Builder helpers for report writing.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let text = r#"{"a": 1, "b": [1.5, "x", true, null], "c": {"d": -2e3}}"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("a").unwrap().as_usize().unwrap(), 1);
        assert_eq!(j.get("b").unwrap().as_arr().unwrap().len(), 4);
        assert_eq!(j.get("c").unwrap().get("d").unwrap().as_f64().unwrap(), -2000.0);
        let re = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, re);
        let compact = j.to_string_compact();
        assert!(!compact.contains('\n'), "compact form is one line: {compact}");
        assert_eq!(Json::parse(&compact).unwrap(), j);
    }

    #[test]
    fn escapes() {
        let j = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\nb\t\"q\" A");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}x").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"κ→3 ✓\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "κ→3 ✓");
    }
}
