//! Wall-clock + peak-RSS instrumentation for the training-cost experiment
//! (paper §3: SpinQuant needs 4×H100, KurTail one GPU — here the analogous
//! asymmetry is peak memory + wall-clock of rotation learning).

use std::time::Instant;

pub struct Stopwatch {
    start: Instant,
    label: String,
}

impl Stopwatch {
    pub fn start(label: &str) -> Self {
        Self { start: Instant::now(), label: label.to_string() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn report(&self) -> String {
        format!("{}: {:.2}s", self.label, self.elapsed_s())
    }
}

/// Current process peak RSS in MiB (from /proc/self/status; Linux only).
pub fn peak_rss_mib() -> f64 {
    if let Ok(s) = std::fs::read_to_string("/proc/self/status") {
        for line in s.lines() {
            if let Some(rest) = line.strip_prefix("VmHWM:") {
                let kb: f64 = rest.trim().trim_end_matches(" kB").trim().parse().unwrap_or(0.0);
                return kb / 1024.0;
            }
        }
    }
    0.0
}

/// Current RSS in MiB.
pub fn rss_mib() -> f64 {
    if let Ok(s) = std::fs::read_to_string("/proc/self/status") {
        for line in s.lines() {
            if let Some(rest) = line.strip_prefix("VmRSS:") {
                let kb: f64 = rest.trim().trim_end_matches(" kB").trim().parse().unwrap_or(0.0);
                return kb / 1024.0;
            }
        }
    }
    0.0
}

#[cfg(test)]
mod tests {
    #[test]
    fn rss_readable() {
        assert!(super::rss_mib() > 0.0);
        assert!(super::peak_rss_mib() >= super::rss_mib() * 0.5);
    }
}
