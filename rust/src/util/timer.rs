//! Peak-RSS instrumentation for the training-cost experiment (paper §3:
//! SpinQuant needs 4×H100, KurTail one GPU — here the analogous
//! asymmetry is peak memory + wall-clock of rotation learning).
//!
//! Wall-clock stage timing lives in [`crate::obs::StageTimer`], which
//! replaced the old `Stopwatch` label printer: the same `start()` /
//! `stop() -> f64` shape, but every stage duration also lands in the
//! `kurtail_stage_seconds{stage=...}` histogram of the global metric
//! registry instead of vanishing into a formatted string.

/// Current process peak RSS in MiB (from /proc/self/status; Linux only).
pub fn peak_rss_mib() -> f64 {
    if let Ok(s) = std::fs::read_to_string("/proc/self/status") {
        for line in s.lines() {
            if let Some(rest) = line.strip_prefix("VmHWM:") {
                let kb: f64 = rest.trim().trim_end_matches(" kB").trim().parse().unwrap_or(0.0);
                return kb / 1024.0;
            }
        }
    }
    0.0
}

/// Current RSS in MiB.
pub fn rss_mib() -> f64 {
    if let Ok(s) = std::fs::read_to_string("/proc/self/status") {
        for line in s.lines() {
            if let Some(rest) = line.strip_prefix("VmRSS:") {
                let kb: f64 = rest.trim().trim_end_matches(" kB").trim().parse().unwrap_or(0.0);
                return kb / 1024.0;
            }
        }
    }
    0.0
}

#[cfg(test)]
mod tests {
    #[test]
    fn rss_readable() {
        assert!(super::rss_mib() > 0.0);
        assert!(super::peak_rss_mib() >= super::rss_mib() * 0.5);
    }
}
