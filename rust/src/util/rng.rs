//! Deterministic, dependency-free RNG (SplitMix64 core).
//!
//! Everything stochastic in the coordinator — corpus generation, data
//! shuffling, random Hadamard signs, weight init — flows through this so
//! experiments are exactly reproducible from a seed recorded in the report.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Derive an independent stream (for parallel workers / substages).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xBF58476D1CE4E5B9))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Laplace(0, b): the distribution Banner et al. fit to DNN activations.
    pub fn laplace(&mut self, b: f32) -> f32 {
        let u = self.uniform() - 0.5;
        -b * u.signum() * (1.0 - 2.0 * u.abs()).max(1e-12).ln()
    }

    /// Random sign ±1.
    pub fn sign(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k ≤ n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Zipf-ish rank sampler over [0, n): P(r) ∝ 1/(r+1)^s, via inverse CDF
    /// on a precomputed table is overkill — rejection on the harmonic bound
    /// is fine at our scales.
    pub fn zipf(&mut self, n: usize, s: f32) -> usize {
        // inverse-transform on the continuous approximation
        let hmax = ((n as f32) + 0.5).powf(1.0 - s);
        let hmin = 0.5f32.powf(1.0 - s);
        loop {
            let u = self.uniform();
            let h = hmin + u * (hmax - hmin);
            let r = h.powf(1.0 / (1.0 - s)) - 0.5;
            let k = r.floor() as usize;
            if k < n {
                return k;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 100_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn laplace_kurtosis_heavy() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let xs: Vec<f32> = (0..n).map(|_| r.laplace(1.0)).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let m2 = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / n as f32;
        let m4 = xs.iter().map(|x| (x - mean).powi(4)).sum::<f32>() / n as f32;
        let k = m4 / (m2 * m2);
        assert!((k - 6.0).abs() < 0.5, "kurtosis={k}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_is_skewed() {
        let mut r = Rng::new(5);
        let mut counts = vec![0usize; 100];
        for _ in 0..50_000 {
            counts[r.zipf(100, 1.1)] += 1;
        }
        assert!(counts[0] > counts[10] && counts[10] > counts[60]);
    }
}
