//! Small shared utilities: deterministic RNG, timing, JSON, bench harness,
//! property-testing helpers. All dependency-free (offline build).

pub mod alloc;
pub mod bench;
pub mod json;
pub mod par;
pub mod proptest;
pub mod rng;
pub mod timer;

pub use json::Json;
pub use rng::Rng;
