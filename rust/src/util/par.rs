//! Work partitioning for the coordinator's parallel host kernels.
//!
//! Every parallel kernel in `tensor/`, `quant/` and `serve/` funnels
//! through [`par_row_chunks_mut`] (or its scratch-slot sibling): the
//! output (or the in-place operand) is split into contiguous, disjoint
//! row-chunks and each chunk is processed by one worker. Two properties
//! matter more than raw speed here:
//!
//! * **Determinism across thread counts, backends and partitions.**
//!   Chunks only partition *which* rows a worker owns — never the
//!   per-row accumulation order — and every kernel built on this module
//!   computes each row as a pure function of `(first_row_index, input)`.
//!   So results are bitwise identical for `KURTAIL_THREADS=1` and
//!   `KURTAIL_THREADS=64`, and for `KURTAIL_PAR=static` vs the
//!   work-stealing default, even though the two backends produce
//!   different (both fixed, both contiguous) chunk grids. Pinned by
//!   `tests/props.rs::prop_kernels_deterministic_across_threads` and the
//!   backend-invariance properties.
//! * **Bounded, caller-owned scratch.** Per-worker work buffers are
//!   handed out from a caller-provided slot pool
//!   ([`par_row_chunks_scratch_mut`]) so the serving hot loop reuses
//!   engine-owned arenas instead of allocating inside chunk closures.
//!
//! ## Backends (`KURTAIL_PAR`)
//!
//! * **`steal` (default).** The row range is pre-partitioned into a
//!   *fixed* grid of up to [`STEAL_OVERSUB`]`×threads` chunks; `threads`
//!   worker tasks (spread over a rayon join-tree so idle pool threads
//!   steal them) claim grid chunks from a shared atomic counter. Skewed
//!   per-chunk cost — GPTQ channels with many zero errors, mixed
//!   prefill/decode rows — no longer leaves workers idle: whoever
//!   finishes early claims the next chunk. Only the *assignment* of
//!   chunks to workers is dynamic; the grid itself, and therefore every
//!   `(first_row, rows)` pair a callback observes, is a pure function of
//!   `(rows, min_rows, threads)`.
//! * **`static` (`KURTAIL_PAR=static`).** The original scoped-thread
//!   backend: at most `threads` equal row-count chunks, one scoped
//!   thread each, no pool and no runtime state. Kept for A/B runs and as
//!   the zero-dependency fallback.
//!
//! ## Scratch slots are worker-keyed, not chunk-keyed
//!
//! [`par_row_chunks_scratch_mut`] hands each **worker** (not each chunk)
//! exclusive `&mut` access to one slot for the duration of the call; a
//! worker that processes several chunks reuses its slot across them.
//! `threads` slots therefore always suffice for both backends (the
//! steal backend runs at most `threads` workers no matter how fine its
//! chunk grid is). Slot *contents* must never affect results — only
//! capacity is reused — so slot→chunk assignment being nondeterministic
//! under stealing is invisible in the output.
//!
//! The thread budget comes from `KURTAIL_THREADS` when set (≥ 1), else
//! from `std::thread::available_parallelism()`. The steal backend runs
//! on rayon's global pool but bounds its own concurrency at `threads`
//! worker tasks, so the budget caps CPU use on either backend.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Thread budget for parallel kernels: `KURTAIL_THREADS` env override
/// (any integer ≥ 1), falling back to the host's available parallelism.
/// Read per call so tests and operators can retune without restarting.
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("KURTAIL_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Parallel execution backend (see the module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParBackend {
    /// Scoped threads, one equal-rows chunk per thread (the PR-1 chunker).
    Static,
    /// Fixed oversubscribed chunk grid + atomic claiming by `threads`
    /// worker tasks on the rayon pool (the default).
    Steal,
}

/// Backend selection: `KURTAIL_PAR=static` restores the scoped-thread
/// chunker; unset or anything else runs the work-stealing backend. Read
/// per call so A/B runs can flip it without restarting.
pub fn backend() -> ParBackend {
    backend_flag(std::env::var("KURTAIL_PAR").ok().as_deref())
}

/// Parse rule behind [`backend`], split out so it is testable: only the
/// literal `static` (case-insensitive, trimmed) opts out of stealing.
fn backend_flag(var: Option<&str>) -> ParBackend {
    match var {
        Some(v) if v.trim().eq_ignore_ascii_case("static") => ParBackend::Static,
        _ => ParBackend::Steal,
    }
}

/// Steal-backend chunk grid granularity: up to this many chunks per
/// worker. Finer chunks → better rebalancing under skew, more claim
/// traffic; 4 keeps claim overhead ≪ 1% for the ms-scale kernels that
/// opt into parallelism.
const STEAL_OVERSUB: usize = 4;

/// Split `data` (a dense row-major block of rows of `width` elements)
/// into contiguous chunks of at least `min_rows` rows and run
/// `f(first_row_index, chunk)` on each, in parallel on the env-selected
/// backend ([`backend`]).
///
/// The chunks are mutually disjoint `&mut` slices, so `f` may freely
/// write its chunk; anything else it touches is captured by shared
/// reference and must be read-only. With one chunk (or `threads == 1`)
/// no worker is spawned and `f` runs on the caller's stack. `f` must not
/// re-enter this module (kernel chunk bodies are leaf computations).
pub fn par_row_chunks_mut<T, F>(data: &mut [T], width: usize, min_rows: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    par_row_chunks_mut_on(backend(), data, width, min_rows, threads, f);
}

/// [`par_row_chunks_mut`] on an explicit backend (engine-pinned runs,
/// A/B tests).
pub fn par_row_chunks_mut_on<T, F>(backend: ParBackend, data: &mut [T], width: usize, min_rows: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    // unit scratch: a Vec of ZSTs never touches the heap
    let mut units = vec![(); threads.max(1)];
    par_row_chunks_scratch_mut_on(backend, data, width, min_rows, threads, &mut units, |r0, chunk, _| f(r0, chunk));
}

/// [`par_row_chunks_mut`] with caller-owned scratch slots: each worker
/// gets exclusive `&mut` access to one slot of `scratch` for the whole
/// call and reuses it across every chunk it claims.
///
/// This is how the serving hot loop keeps per-worker work buffers
/// (fake-quant selection scratch, attention score rows, nibble-unpack
/// tiles) out of the steady-state allocation count: the buffers live in
/// an engine-owned arena and are *re-lent* to the kernels on every call
/// instead of being reallocated inside each chunk closure. `scratch`
/// must provide at least as many slots as the call runs workers —
/// `threads` slots always suffice on both backends. Scratch contents
/// must never affect results — only capacity is reused — so the
/// determinism contract of [`par_row_chunks_mut`] carries over
/// unchanged even though slot→chunk assignment is nondeterministic
/// under stealing.
pub fn par_row_chunks_scratch_mut<T, S, F>(
    data: &mut [T],
    width: usize,
    min_rows: usize,
    threads: usize,
    scratch: &mut [S],
    f: F,
) where
    T: Send,
    S: Send,
    F: Fn(usize, &mut [T], &mut S) + Sync,
{
    par_row_chunks_scratch_mut_on(backend(), data, width, min_rows, threads, scratch, f);
}

/// [`par_row_chunks_scratch_mut`] on an explicit backend.
pub fn par_row_chunks_scratch_mut_on<T, S, F>(
    backend: ParBackend,
    data: &mut [T],
    width: usize,
    min_rows: usize,
    threads: usize,
    scratch: &mut [S],
    f: F,
) where
    T: Send,
    S: Send,
    F: Fn(usize, &mut [T], &mut S) + Sync,
{
    assert!(width > 0, "par_row_chunks_mut: zero row width");
    assert_eq!(data.len() % width, 0, "par_row_chunks_mut: ragged rows");
    let rows = data.len() / width;
    if rows == 0 {
        return;
    }
    let max_chunks = (rows / min_rows.max(1)).max(1);
    match backend {
        ParBackend::Static => {
            let n_chunks = threads.max(1).min(max_chunks);
            assert!(
                scratch.len() >= n_chunks,
                "par_row_chunks_scratch_mut: {} scratch slots for {n_chunks} chunks",
                scratch.len()
            );
            if n_chunks == 1 {
                f(0, data, &mut scratch[0]);
                return;
            }
            static_exec(data, width, rows, n_chunks, scratch, &f);
        }
        ParBackend::Steal => {
            // threads == 1 never touches the pool: the whole range runs
            // inline (this is what keeps the zero-allocation decode pin
            // valid on the steal backend too)
            let n_chunks = if threads <= 1 { 1 } else { (threads * STEAL_OVERSUB).min(max_chunks) };
            let workers = threads.max(1).min(n_chunks);
            assert!(
                scratch.len() >= workers,
                "par_row_chunks_scratch_mut: {} scratch slots for {workers} workers",
                scratch.len()
            );
            if n_chunks == 1 {
                f(0, data, &mut scratch[0]);
                return;
            }
            steal_exec(data, width, rows, n_chunks, &mut scratch[..workers], &f);
        }
    }
}

/// Static backend: equal row-count chunks on scoped threads (chunk `i`
/// gets `scratch[i]`; the first chunk runs on the calling thread).
fn static_exec<T, S, F>(data: &mut [T], width: usize, rows: usize, n_chunks: usize, scratch: &mut [S], f: &F)
where
    T: Send,
    S: Send,
    F: Fn(usize, &mut [T], &mut S) + Sync,
{
    let rows_per = (rows + n_chunks - 1) / n_chunks;
    let (first, mut rest) = data.split_at_mut(rows_per.min(rows) * width);
    let (s_first, mut s_rest) = scratch.split_first_mut().expect("scratch slot for chunk 0");
    std::thread::scope(|scope| {
        let mut row0 = rows_per.min(rows);
        while !rest.is_empty() {
            let take = rows_per.min(rest.len() / width);
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(take * width);
            rest = tail;
            let (slot, s_tail) = std::mem::take(&mut s_rest).split_first_mut().expect("scratch slot for chunk");
            s_rest = s_tail;
            let r0 = row0;
            row0 += take;
            scope.spawn(move || f(r0, head, slot));
        }
        // the first chunk runs on the calling thread while the rest work
        f(0, first, s_first);
    });
}

/// Shared view of the fixed chunk grid for the steal backend. Chunk `c`
/// covers rows `[c·rows_per, min((c+1)·rows_per, rows))`; handing each
/// index out exactly once (the atomic counter in [`steal_exec`]) makes
/// the produced `&mut` chunk slices disjoint.
struct ChunkGrid<T> {
    data: *mut T,
    width: usize,
    rows: usize,
    rows_per: usize,
}

// SAFETY: the grid is only a sized pointer; disjointness of the chunks
// produced from it is guaranteed by unique chunk-index claims, and the
// row payload crosses threads, hence T: Send.
unsafe impl<T: Send> Sync for ChunkGrid<T> {}

/// Steal backend: `slots.len()` worker tasks spread over a rayon
/// join-tree (so idle pool threads steal whole workers), each claiming
/// grid chunks from a shared counter until the grid is drained. Each
/// worker keeps its one scratch slot across every chunk it runs.
fn steal_exec<T, S, F>(data: &mut [T], width: usize, rows: usize, n_chunks: usize, slots: &mut [S], f: &F)
where
    T: Send,
    S: Send,
    F: Fn(usize, &mut [T], &mut S) + Sync,
{
    let rows_per = (rows + n_chunks - 1) / n_chunks;
    let grid = ChunkGrid { data: data.as_mut_ptr(), width, rows, rows_per };
    let next = AtomicUsize::new(0);
    let grid = &grid;
    let next = &next;
    let run = move |slot: &mut S| loop {
        let c = next.fetch_add(1, Ordering::Relaxed);
        let r0 = c * grid.rows_per;
        if r0 >= grid.rows {
            break;
        }
        let r1 = (r0 + grid.rows_per).min(grid.rows);
        // SAFETY: `fetch_add` hands each chunk index to exactly one
        // worker, chunk row ranges are disjoint by construction, and the
        // borrow of `data` is held for the whole call — so this slice is
        // the only live reference to its rows.
        let chunk = unsafe { std::slice::from_raw_parts_mut(grid.data.add(r0 * grid.width), (r1 - r0) * grid.width) };
        f(r0, chunk, slot);
    };
    join_slots(slots, &run);
}

/// Recursively split the worker slots across `rayon::join` so each leaf
/// owns exactly one `&mut` slot. join is stack-allocated in rayon, so a
/// steady-state call adds no per-chunk heap traffic of its own.
fn join_slots<S: Send>(slots: &mut [S], run: &(impl Fn(&mut S) + Sync)) {
    match slots {
        [] => {}
        [one] => run(one),
        many => {
            let mid = many.len() / 2;
            let (l, r) = many.split_at_mut(mid);
            rayon::join(|| join_slots(l, run), || join_slots(r, run));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const BACKENDS: [ParBackend; 2] = [ParBackend::Static, ParBackend::Steal];

    #[test]
    fn thread_budget_is_positive() {
        assert!(num_threads() >= 1);
    }

    #[test]
    fn backend_flag_parse_rule() {
        assert_eq!(backend_flag(None), ParBackend::Steal, "unset defaults to stealing");
        assert_eq!(backend_flag(Some("static")), ParBackend::Static);
        assert_eq!(backend_flag(Some(" STATIC ")), ParBackend::Static);
        assert_eq!(backend_flag(Some("steal")), ParBackend::Steal);
        assert_eq!(backend_flag(Some("")), ParBackend::Steal);
        assert_eq!(backend_flag(Some("nonsense")), ParBackend::Steal, "only literal 'static' opts out");
    }

    #[test]
    fn chunks_cover_every_row_exactly_once() {
        for backend in BACKENDS {
            for rows in [0usize, 1, 7, 16, 17, 1000] {
                for threads in [1usize, 2, 3, 8] {
                    let mut data = vec![0u32; rows * 4];
                    par_row_chunks_mut_on(backend, &mut data, 4, 1, threads, |r0, chunk| {
                        for (i, row) in chunk.chunks_exact_mut(4).enumerate() {
                            for v in row.iter_mut() {
                                *v += (r0 + i) as u32 + 1; // +1 so row 0 counts
                            }
                        }
                    });
                    for (i, v) in data.iter().enumerate() {
                        assert_eq!(*v, (i / 4) as u32 + 1, "{backend:?} row {} touched wrong", i / 4);
                    }
                }
            }
        }
    }

    #[test]
    fn backends_produce_identical_results() {
        // a row kernel that is a pure function of (row index, input)
        // must agree bitwise across backends and thread budgets even
        // though their chunk grids differ
        let run = |backend: ParBackend, threads: usize| -> Vec<f32> {
            let mut data: Vec<f32> = vec![0.0; 103 * 3];
            par_row_chunks_mut_on(backend, &mut data, 3, 1, threads, |r0, chunk| {
                for (i, row) in chunk.chunks_exact_mut(3).enumerate() {
                    let r = (r0 + i) as f32;
                    for (j, v) in row.iter_mut().enumerate() {
                        *v = (r * 1.7 + j as f32).sin();
                    }
                }
            });
            data
        };
        let want = run(ParBackend::Static, 1);
        for backend in BACKENDS {
            for threads in [1usize, 2, 4, 8] {
                assert_eq!(run(backend, threads), want, "{backend:?} t={threads}");
            }
        }
    }

    #[test]
    fn min_rows_limits_chunk_count() {
        // 10 rows with min 8 → a single chunk even with many threads
        for backend in BACKENDS {
            let mut data = vec![0u8; 10];
            let hits = std::sync::atomic::AtomicUsize::new(0);
            par_row_chunks_mut_on(backend, &mut data, 1, 8, 16, |_, _| {
                hits.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            });
            assert_eq!(hits.load(std::sync::atomic::Ordering::SeqCst), 1, "{backend:?}");
        }
    }

    #[test]
    fn static_scratch_slots_are_per_chunk_and_reused() {
        // static backend: every chunk sees exactly one scratch slot;
        // slot contents from a prior call survive (capacity reuse is the
        // whole point)
        let mut data = vec![0u32; 64];
        let mut bufs: Vec<Vec<u32>> = (0..4).map(|_| Vec::with_capacity(8)).collect();
        for pass in 0..2u32 {
            par_row_chunks_scratch_mut_on(ParBackend::Static, &mut data, 4, 1, 4, &mut bufs, |r0, chunk, buf| {
                buf.push(pass);
                for (i, row) in chunk.chunks_exact_mut(4).enumerate() {
                    row.fill((r0 + i) as u32 + pass);
                }
            });
        }
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, (i / 4) as u32 + 1);
        }
        // each used slot accumulated one entry per pass, untouched between
        let used: Vec<_> = bufs.iter().filter(|b| !b.is_empty()).collect();
        assert!(!used.is_empty());
        for b in used {
            assert_eq!(b.as_slice(), &[0, 1]);
        }
    }

    #[test]
    fn steal_slots_are_worker_keyed() {
        // the steal grid is finer than the worker count, so a worker
        // reuses its slot across the chunks it claims: the per-slot chunk
        // tallies must sum to the grid size, nothing may run on a slot
        // index ≥ threads, and every row is still touched exactly once
        let (rows, threads) = (64usize, 4usize);
        let mut data = vec![0u32; rows];
        let mut tallies = vec![0usize; threads];
        par_row_chunks_scratch_mut_on(ParBackend::Steal, &mut data, 1, 1, threads, &mut tallies, |r0, chunk, tally| {
            *tally += 1;
            for (i, v) in chunk.iter_mut().enumerate() {
                *v += (r0 + i) as u32 + 1;
            }
        });
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i as u32 + 1, "row {i} touched wrong");
        }
        let total: usize = tallies.iter().sum();
        assert_eq!(total, threads * STEAL_OVERSUB, "every grid chunk claimed exactly once");
    }

    #[test]
    #[should_panic(expected = "scratch slots")]
    fn scratch_shortfall_panics() {
        let mut data = vec![0u8; 32];
        let mut bufs = [0u8; 1];
        par_row_chunks_scratch_mut_on(ParBackend::Steal, &mut data, 1, 1, 8, &mut bufs, |_, _, _| {});
    }

    #[test]
    #[should_panic(expected = "scratch slots")]
    fn static_scratch_shortfall_panics() {
        let mut data = vec![0u8; 32];
        let mut bufs = [0u8; 1];
        par_row_chunks_scratch_mut_on(ParBackend::Static, &mut data, 1, 1, 8, &mut bufs, |_, _, _| {});
    }

    #[test]
    fn first_row_indices_are_consistent() {
        for backend in BACKENDS {
            let mut data: Vec<usize> = vec![0; 103];
            par_row_chunks_mut_on(backend, &mut data, 1, 1, 8, |r0, chunk| {
                for (i, v) in chunk.iter_mut().enumerate() {
                    *v = r0 + i;
                }
            });
            for (i, v) in data.iter().enumerate() {
                assert_eq!(*v, i, "{backend:?}");
            }
        }
    }
}
