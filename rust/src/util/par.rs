//! Work partitioning for the coordinator's parallel host kernels.
//!
//! Every parallel kernel in `tensor/` and `quant/` funnels through
//! [`par_row_chunks_mut`]: the output (or the in-place operand) is split
//! into contiguous, disjoint row-chunks and each chunk is processed on a
//! scoped thread. Two properties matter more than raw speed here:
//!
//! * **Determinism across thread counts.** Chunks only partition *which*
//!   rows a thread owns — never the per-row accumulation order — so every
//!   kernel built on this module produces bitwise-identical results for
//!   `KURTAIL_THREADS=1` and `KURTAIL_THREADS=64` (pinned by
//!   `tests/props.rs::prop_kernels_deterministic_across_threads`).
//! * **No pool, no globals.** Scoped threads borrow the caller's slices
//!   directly; there is no runtime state to poison and nothing to shut
//!   down. Thread spawn costs ~10µs, which is noise for the ms-scale
//!   kernels that opt into parallelism (tiny inputs take the sequential
//!   path before ever reaching a spawn).
//!
//! The thread budget comes from `KURTAIL_THREADS` when set (≥ 1), else
//! from `std::thread::available_parallelism()`.

/// Thread budget for parallel kernels: `KURTAIL_THREADS` env override
/// (any integer ≥ 1), falling back to the host's available parallelism.
/// Read per call so tests and operators can retune without restarting.
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("KURTAIL_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Split `data` (a dense row-major block of rows of `width` elements)
/// into at most `threads` contiguous chunks of at least `min_rows` rows
/// and run `f(first_row_index, chunk)` on each, in parallel.
///
/// The chunks are mutually disjoint `&mut` slices, so `f` may freely
/// write its chunk; anything else it touches is captured by shared
/// reference and must be read-only. With one chunk (or `threads == 1`)
/// no thread is spawned and `f` runs on the caller's stack.
pub fn par_row_chunks_mut<T, F>(data: &mut [T], width: usize, min_rows: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    // unit scratch: a Vec of ZSTs never touches the heap
    let mut units = vec![(); threads.max(1)];
    par_row_chunks_scratch_mut(data, width, min_rows, threads, &mut units, |r0, chunk, _| {
        f(r0, chunk)
    });
}

/// [`par_row_chunks_mut`] with one caller-owned scratch slot handed to
/// each chunk: chunk `i` (in partition order) gets exclusive `&mut`
/// access to `scratch[i]` for the duration of its callback.
///
/// This is how the serving hot loop keeps per-thread work buffers
/// (fake-quant selection scratch, attention score rows, nibble-unpack
/// tiles) out of the steady-state allocation count: the buffers live in
/// an engine-owned arena and are *re-lent* to the kernels on every call
/// instead of being reallocated inside each chunk closure. `scratch`
/// must provide at least as many slots as the partition produces chunks
/// (`threads` slots always suffice). Scratch contents must never affect
/// results — only capacity is reused — so the determinism contract of
/// [`par_row_chunks_mut`] carries over unchanged.
pub fn par_row_chunks_scratch_mut<T, S, F>(
    data: &mut [T],
    width: usize,
    min_rows: usize,
    threads: usize,
    scratch: &mut [S],
    f: F,
) where
    T: Send,
    S: Send,
    F: Fn(usize, &mut [T], &mut S) + Sync,
{
    assert!(width > 0, "par_row_chunks_mut: zero row width");
    assert_eq!(data.len() % width, 0, "par_row_chunks_mut: ragged rows");
    let rows = data.len() / width;
    if rows == 0 {
        return;
    }
    let n_chunks = threads.max(1).min((rows / min_rows.max(1)).max(1));
    assert!(
        scratch.len() >= n_chunks,
        "par_row_chunks_scratch_mut: {} scratch slots for {n_chunks} chunks",
        scratch.len()
    );
    if n_chunks == 1 {
        f(0, data, &mut scratch[0]);
        return;
    }
    let rows_per = (rows + n_chunks - 1) / n_chunks;
    let (first, mut rest) = data.split_at_mut(rows_per.min(rows) * width);
    let (s_first, mut s_rest) = scratch.split_first_mut().expect("scratch slot for chunk 0");
    std::thread::scope(|scope| {
        let f = &f;
        let mut row0 = rows_per.min(rows);
        while !rest.is_empty() {
            let take = rows_per.min(rest.len() / width);
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(take * width);
            rest = tail;
            let (slot, s_tail) =
                std::mem::take(&mut s_rest).split_first_mut().expect("scratch slot for chunk");
            s_rest = s_tail;
            let r0 = row0;
            row0 += take;
            scope.spawn(move || f(r0, head, slot));
        }
        // the first chunk runs on the calling thread while the rest work
        f(0, first, s_first);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_budget_is_positive() {
        assert!(num_threads() >= 1);
    }

    #[test]
    fn chunks_cover_every_row_exactly_once() {
        for rows in [0usize, 1, 7, 16, 17, 1000] {
            for threads in [1usize, 2, 3, 8] {
                let mut data = vec![0u32; rows * 4];
                par_row_chunks_mut(&mut data, 4, 1, threads, |r0, chunk| {
                    for (i, row) in chunk.chunks_exact_mut(4).enumerate() {
                        for v in row.iter_mut() {
                            *v += (r0 + i) as u32 + 1; // +1 so row 0 counts
                        }
                    }
                });
                for (i, v) in data.iter().enumerate() {
                    assert_eq!(*v, (i / 4) as u32 + 1, "row {} touched wrong", i / 4);
                }
            }
        }
    }

    #[test]
    fn min_rows_limits_chunk_count() {
        // 10 rows with min 8 → a single chunk even with many threads
        let mut data = vec![0u8; 10];
        let hits = std::sync::atomic::AtomicUsize::new(0);
        par_row_chunks_mut(&mut data, 1, 8, 16, |_, _| {
            hits.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        });
        assert_eq!(hits.load(std::sync::atomic::Ordering::SeqCst), 1);
    }

    #[test]
    fn scratch_slots_are_per_chunk_and_reused() {
        // every chunk sees exactly one scratch slot; slot contents from a
        // prior call survive (capacity reuse is the whole point)
        let mut data = vec![0u32; 64];
        let mut bufs: Vec<Vec<u32>> = (0..4).map(|_| Vec::with_capacity(8)).collect();
        for pass in 0..2u32 {
            par_row_chunks_scratch_mut(&mut data, 4, 1, 4, &mut bufs, |r0, chunk, buf| {
                buf.push(pass);
                for (i, row) in chunk.chunks_exact_mut(4).enumerate() {
                    row.fill((r0 + i) as u32 + pass);
                }
            });
        }
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, (i / 4) as u32 + 1);
        }
        // each used slot accumulated one entry per pass, untouched between
        let used: Vec<_> = bufs.iter().filter(|b| !b.is_empty()).collect();
        assert!(!used.is_empty());
        for b in used {
            assert_eq!(b.as_slice(), &[0, 1]);
        }
    }

    #[test]
    #[should_panic(expected = "scratch slots")]
    fn scratch_shortfall_panics() {
        let mut data = vec![0u8; 32];
        let mut bufs = [0u8; 1];
        par_row_chunks_scratch_mut(&mut data, 1, 1, 8, &mut bufs, |_, _, _| {});
    }

    #[test]
    fn first_row_indices_are_consistent() {
        let mut data: Vec<usize> = vec![0; 103];
        par_row_chunks_mut(&mut data, 1, 1, 8, |r0, chunk| {
            for (i, v) in chunk.iter_mut().enumerate() {
                *v = r0 + i;
            }
        });
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i);
        }
    }
}
