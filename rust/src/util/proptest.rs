//! Tiny property-testing helper (proptest stand-in for the offline build).
//!
//! Runs a property over `n` seeded random cases; on failure reports the
//! seed so the case replays deterministically:
//!
//! ```ignore
//! check(100, |rng| {
//!     let n = 1 << (1 + rng.below(6));
//!     let h = hadamard_matrix(n);
//!     prop_assert(orthogonality_error(&h) < 1e-4, "H orthogonal")
//! });
//! ```

use crate::util::Rng;

pub type PropResult = Result<(), String>;

pub fn prop_assert(cond: bool, msg: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

pub fn prop_close(a: f32, b: f32, tol: f32, msg: &str) -> PropResult {
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("{msg}: {a} vs {b} (tol {tol})"))
    }
}

/// Run `prop` over `cases` seeded RNGs; panics with the failing seed.
pub fn check(cases: u64, prop: impl Fn(&mut Rng) -> PropResult) {
    let base = std::env::var("KURTAIL_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEEu64);
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially() {
        check(10, |rng| prop_assert(rng.uniform() < 1.0, "uniform < 1"));
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_loudly() {
        check(10, |rng| prop_assert(rng.uniform() < 0.0001, "rarely true"));
    }
}
