//! # KurTail — kurtosis-based LLM quantization (EMNLP 2025), reproduced
//!
//! Three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the post-training-quantization coordinator:
//!   corpora, tokenizer, trainer driver, layer-wise activation capture,
//!   rotation learning (Cayley-Adam over kurtosis loss), rotation fusion,
//!   RTN/GPTQ weight quantization, baselines (QuaRot, SpinQuant-lite), the
//!   evaluation harness, one experiment runner per paper table/figure, and
//!   the native INT4 serving engine ([`serve`]: packed 4-bit weights,
//!   paged 4-bit KV cache, continuous-batching decode) with its
//!   telemetry layer ([`obs`]: histograms, spans, Prometheus exposition).
//! * **L2/L1 (python/compile, build-time only)** — JAX model graphs and
//!   Pallas kernels, AOT-lowered to `artifacts/*.hlo.txt`, executed here
//!   through PJRT ([`runtime`]).
//!
//! See DESIGN.md for the full system inventory and experiment index.

pub mod baselines;
pub mod calib;
pub mod config;
pub mod eval;
pub mod exp;
pub mod kurtail;
pub mod model;
pub mod obs;
pub mod pipeline;
pub mod quant;
pub mod rotation;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod util;
