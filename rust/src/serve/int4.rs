//! Packed INT4 weight storage + fused dequant-GEMV/GEMM.
//!
//! A weight matrix is stored `(k_in, n_out)` like everywhere else in the
//! coordinator, but here the 4-bit codes are *materialized*: two codes
//! per byte, column-major (one output channel's `⌈k/2⌉` bytes are
//! contiguous), with one f32 scale per `(column, row-group)`. With
//! `group = None` in the [`QuantScheme`] the grid is exactly the RTN
//! per-output-channel grid of [`crate::quant::rtn::rtn_quantize`] —
//! `pack → unpack` reproduces its output bitwise (pinned by tests and
//! `tests/props.rs`).
//!
//! The matmul kernel never materializes the dequantized matrix: each
//! thread owns a contiguous range of output *columns*
//! ([`crate::util::par::par_row_chunks_mut`] over the transposed output),
//! reads one column's codes as a contiguous i8 tile — from the optional
//! **panel cache** when built, otherwise unpacked from nibbles into a
//! small scratch buffer — and accumulates
//! `Σ_g scale_g · Σ_{i∈g} x_i·q_i` per lane. Per output element the
//! accumulation order is fixed (ascending rows within ascending groups),
//! so results are bitwise identical across thread counts *and* across
//! batch sizes (lane `i` of a 16-lane GEMM equals the 1-lane GEMV on the
//! same row) — the same determinism contract as the PR-1 kernels.
//!
//! **Panel cache.** [`Int4Weight::build_panels`] unpacks every column's
//! nibbles *once* into a column-major i8 panel (`n × k` bytes, i.e. 2×
//! the packed codes), so steady-state GEMMs stream contiguous i8
//! instead of re-unpacking per call. The panel holds exactly the codes
//! [`unpack_col`] produces, so cached and uncached results are bitwise
//! identical. The serve engine bounds total panel bytes with a budget
//! (`ServeConfig::panel_cache`, falling back to the
//! [`panel_cache_budget`] env rule for `KURTAIL_PANEL_CACHE`).
//!
//! **Scratch-fed GEMMs.** The `*_scratch` entry points take a
//! caller-owned [`GemmScratch`] (transposed-output buffer + one
//! nibble-unpack tile per parallel worker) so the decode hot loop
//! performs zero heap allocations; the original entry points remain as
//! convenience wrappers that allocate a fresh scratch per call.
//!
//! **Output layouts & epilogues.** Both GEMMs compute natively
//! **column-major** — threads own output columns, so the staging buffer
//! is the `(n × m)` transpose of the row-major result. Three epilogues
//! expose it (see `rust/README.md` §Output layouts):
//!
//! * `*_colmajor_scratch` — hand the `(n × m)` block to the caller
//!   as-is; the serve engine's fused consumers (residual add, silu-mul,
//!   logits argmax/sampling) traverse it without any transpose.
//! * `*_scratch` / `*_scratch_on` — flip into row-major with the
//!   **parallel blocked transpose** ([`transpose_into_on`]) for
//!   consumers that need row layout (RoPE, KV append, rotation lhs).
//! * `*_scratch_serial` / the allocating wrappers — the PR-4
//!   single-threaded scalar flip, kept verbatim as the bench A/B
//!   baseline (`epilogue_fused_speedup`) and the legacy
//!   (`KURTAIL_ARENA=0`) profile.
//!
//! All three write bitwise-identical values per element (the core is
//! shared; epilogues only move bytes), pinned by unit tests here and
//! the engine-level layout-invariance tests.

use crate::config::QuantScheme;
use crate::tensor::matmul::{dot_i8_grouped, transpose_into_on};
use crate::tensor::Tensor;
use crate::util::par::{self, num_threads, ParBackend};

use super::qact::{quantize_rows_into, QuantActs};

/// `KURTAIL_PANEL_CACHE` budget rule: unset or empty → unbounded cache
/// (`usize::MAX`), `0` → cache off, any other integer → that many bytes
/// of i8 panels. An unparseable value (e.g. `512M` — suffixes are not
/// supported) disables the cache: the variable exists to cap memory, so
/// a garbled cap must fail *closed*, not open. Read per engine build,
/// like `KURTAIL_INT_GEMM`.
pub fn panel_cache_budget() -> usize {
    panel_budget_flag(std::env::var("KURTAIL_PANEL_CACHE").ok().as_deref())
}

/// Parse rule behind [`panel_cache_budget`], split out for tests.
fn panel_budget_flag(var: Option<&str>) -> usize {
    match var {
        None => usize::MAX,
        Some(v) => {
            let t = v.trim();
            if t.is_empty() {
                usize::MAX
            } else {
                t.parse::<usize>().unwrap_or(0)
            }
        }
    }
}

/// Caller-owned scratch for the packed GEMMs: the transposed-output
/// staging buffer plus one nibble-unpack tile per parallel worker.
/// Reused across calls (the serve arena owns one), capacities only ever
/// grow — contents never influence results.
#[derive(Clone, Debug, Default)]
pub struct GemmScratch {
    /// `(n × m)` transposed output staging (row-major epilogues, `m > 1`).
    pub out_t: Vec<f32>,
    /// Per-worker i8 column tiles (unused when the panel cache is built).
    pub qbufs: Vec<Vec<i8>>,
}

impl GemmScratch {
    /// Scratch with one unpack tile per potential parallel worker.
    pub fn with_threads(threads: usize) -> Self {
        Self { out_t: Vec::new(), qbufs: (0..threads.max(1)).map(|_| Vec::new()).collect() }
    }

    /// Pre-size every buffer so subsequent GEMMs up to `max_out` staged
    /// floats and `max_k` input rows never allocate *and never fill*:
    /// `out_t` is brought to its full length here, once, off the decode
    /// loop — PR-4 instead `Vec::resize`d it inside the GEMM, zeroing
    /// memory the epilogue was about to fully overwrite anyway. The
    /// in-GEMM growth branch ([`grow_for_overwrite`]) survives only for
    /// cold callers that skipped this (allocating wrappers, bare
    /// scratch), where one fill is noise next to the fresh allocation.
    pub fn reserve(&mut self, max_out: usize, max_k: usize) {
        if self.out_t.len() < max_out {
            self.out_t.resize(max_out, 0.0);
        }
        for q in &mut self.qbufs {
            q.reserve(max_k.saturating_sub(q.len()));
        }
    }

    /// Shrink the staging buffer to `max_out` floats, releasing the
    /// excess to the allocator (the `DecodeScratch` high-water decay).
    pub fn shrink(&mut self, max_out: usize) {
        if self.out_t.len() > max_out {
            self.out_t.truncate(max_out);
            self.out_t.shrink_to_fit();
        }
    }
}

/// Grow `v` to `len` elements ahead of a full overwrite.
///
/// Invariant this relies on: every GEMM epilogue writes each element of
/// the slice it takes — the column loops cover `[0, n·m)` exactly once
/// per call — before anything reads it, so the zero-fill below is pure
/// insurance (Vec's initialization invariant must hold for the safe
/// `len`, so an uninitialized fast path would be unsound — it was
/// rejected in review). The serving hot loop never reaches this branch:
/// [`GemmScratch::reserve`] (called by `DecodeScratch::ensure` at
/// engine build / admission) pre-sizes `out_t` to the peak, which is
/// where the PR-4 per-growth fill actually moved.
fn grow_for_overwrite(v: &mut Vec<f32>, len: usize) {
    if v.len() < len {
        v.resize(len, 0.0);
    }
}

/// One column's signed levels: the cached panel slice when built, else
/// a fresh unpack into the chunk's scratch tile. Implicit reborrow of
/// `qbuf` keeps the returned slice scoped to one loop iteration.
#[inline]
fn col_codes<'a>(
    panels: Option<&'a [i8]>,
    packed: &[u8],
    j: usize,
    k: usize,
    bpc: usize,
    qbuf: &'a mut Vec<i8>,
) -> &'a [i8] {
    match panels {
        Some(p) => &p[j * k..(j + 1) * k],
        None => {
            if qbuf.len() < k {
                qbuf.resize(k, 0);
            }
            unpack_col(&packed[j * bpc..(j + 1) * bpc], k, &mut qbuf[..k]);
            &qbuf[..k]
        }
    }
}

/// Nibble-packed INT4 weight `(k, n)` with per-(column, group) scales.
#[derive(Clone, Debug)]
pub struct Int4Weight {
    pub k: usize,
    pub n: usize,
    /// Input rows per scale group (== `k` when the scheme has no groups).
    pub group: usize,
    /// `⌈k / group⌉` scale groups per column.
    pub n_groups: usize,
    /// `n × ⌈k/2⌉` bytes, column-major; even row = low nibble. A code
    /// nibble is the signed level plus 8 (levels live in [-7, 7]).
    packed: Vec<u8>,
    /// `n × n_groups` scales, column-major (`scales[j·n_groups + g]`).
    scales: Vec<f32>,
    /// Optional i8 panel cache: `n × k` signed levels, column-major —
    /// exactly what [`unpack_col`] yields per column, materialized once
    /// by [`Self::build_panels`] so GEMMs skip the per-call unpack.
    panels: Option<Vec<i8>>,
}

impl Int4Weight {
    /// Quantize + pack a 2-D `(k, n)` weight on the scheme's grid. This
    /// *is* the RTN weight quantizer (absmax grid, round-to-nearest) —
    /// packing already-RTN-quantized weights is a fixpoint.
    pub fn pack(w: &Tensor, s: &QuantScheme) -> Int4Weight {
        assert_eq!(w.rank(), 2, "Int4Weight::pack needs a 2-D weight");
        assert_eq!(s.bits, 4, "Int4Weight stores 4-bit codes");
        assert!(s.symmetric, "Int4Weight uses the symmetric grid");
        let (k, n) = (w.shape[0], w.shape[1]);
        assert!(k > 0 && n > 0, "empty weight");
        let group = s.group.unwrap_or(k).max(1).min(k);
        let n_groups = (k + group - 1) / group;
        let bpc = (k + 1) / 2;
        let qmax = s.qmax();
        // pass 1: per-(column, group) absmax scales, parallel over columns
        let mut scales = vec![0.0f32; n * n_groups];
        par::par_row_chunks_mut(&mut scales, n_groups, 16, num_threads(), |j0, chunk| {
            for (jj, srow) in chunk.chunks_exact_mut(n_groups).enumerate() {
                let j = j0 + jj;
                for (g, sc) in srow.iter_mut().enumerate() {
                    let i0 = g * group;
                    let i1 = (i0 + group).min(k);
                    let mut amax = 0.0f32;
                    for i in i0..i1 {
                        amax = amax.max(w.data[i * n + j].abs());
                    }
                    *sc = amax.max(1e-8) / qmax;
                }
            }
        });
        // pass 2: quantize + pack on those grids, parallel over columns
        let mut packed = vec![0u8; n * bpc];
        par::par_row_chunks_mut(&mut packed, bpc, 8, num_threads(), |j0, chunk| {
            for (jj, col) in chunk.chunks_exact_mut(bpc).enumerate() {
                let j = j0 + jj;
                for g in 0..n_groups {
                    let scale = scales[j * n_groups + g];
                    let i0 = g * group;
                    let i1 = (i0 + group).min(k);
                    for i in i0..i1 {
                        let q = (w.data[i * n + j] / scale).round().clamp(-qmax, qmax);
                        let nib = (q as i32 + 8) as u8;
                        if i % 2 == 0 {
                            col[i / 2] = (col[i / 2] & 0xF0) | nib;
                        } else {
                            col[i / 2] = (col[i / 2] & 0x0F) | (nib << 4);
                        }
                    }
                }
            }
        });
        Int4Weight { k, n, group, n_groups, packed, scales, panels: None }
    }

    /// Materialize the i8 panel cache (idempotent): every column's
    /// nibbles unpacked once into a contiguous `n × k` column-major
    /// panel. Costs [`Self::panel_bytes`] of memory — 2× the packed
    /// codes — and makes every subsequent GEMM read contiguous i8.
    pub fn build_panels(&mut self) {
        if self.panels.is_some() {
            return;
        }
        let (k, n) = (self.k, self.n);
        let bpc = (k + 1) / 2;
        let mut panels = vec![0i8; n * k];
        let packed = &self.packed;
        par::par_row_chunks_mut(&mut panels, k, 8, num_threads(), |j0, chunk| {
            for (jj, col) in chunk.chunks_exact_mut(k).enumerate() {
                unpack_col(&packed[(j0 + jj) * bpc..(j0 + jj + 1) * bpc], k, col);
            }
        });
        self.panels = Some(panels);
    }

    /// Drop the panel cache, returning to per-call nibble unpack.
    pub fn drop_panels(&mut self) {
        self.panels = None;
    }

    pub fn has_panels(&self) -> bool {
        self.panels.is_some()
    }

    /// Bytes a built panel cache costs for this weight (`k · n` i8s).
    pub fn panel_bytes(&self) -> usize {
        self.k * self.n
    }

    /// Signed level of element `(i, j)`.
    #[inline]
    fn code(&self, i: usize, j: usize) -> i32 {
        let b = self.packed[j * ((self.k + 1) / 2) + i / 2];
        let nib = if i % 2 == 0 { b & 0x0F } else { b >> 4 };
        nib as i32 - 8
    }

    /// Dequantize back to a dense `(k, n)` tensor (tests / fallbacks).
    pub fn unpack(&self) -> Tensor {
        let mut out = Tensor::zeros(&[self.k, self.n]);
        for j in 0..self.n {
            for i in 0..self.k {
                let scale = self.scales[j * self.n_groups + i / self.group];
                out.data[i * self.n + j] = self.code(i, j) as f32 * scale;
            }
        }
        out
    }

    /// Packed storage footprint (codes + scales), in bytes. This is the
    /// *format* size — the compression-ratio numerator — and deliberately
    /// excludes the optional i8 panel cache, which is derived runtime
    /// state reported separately ([`Self::panel_bytes`] when
    /// [`Self::has_panels`]; `weights.panel_cache_bytes` in
    /// `BENCH_serve.json`).
    pub fn bytes(&self) -> usize {
        self.packed.len() + self.scales.len() * 4
    }

    /// Dense f32 footprint of the same matrix, in bytes.
    pub fn dense_bytes(&self) -> usize {
        self.k * self.n * 4
    }

    /// Column-major core of the f32 dequant GEMM: output columns split
    /// across workers, one i8 column tile (cached panel or fresh unpack)
    /// consumed by every lane while hot. `m == 1` (GEMV) and `m > 1`
    /// share this — a single row is the same byte sequence in either
    /// layout.
    fn gemm_colmajor_core(
        &self,
        x: &[f32],
        m: usize,
        out_t: &mut [f32],
        threads: usize,
        backend: ParBackend,
        qbufs: &mut [Vec<i8>],
    ) {
        let (k, group, ng) = (self.k, self.group, self.n_groups);
        let bpc = (k + 1) / 2;
        let panels = self.panels.as_deref();
        let min_rows = if m == 1 { 32 } else { 8 };
        par::par_row_chunks_scratch_mut_on(backend, out_t, m, min_rows, threads, qbufs, |j0, chunk, qbuf| {
            for (jj, orow) in chunk.chunks_exact_mut(m).enumerate() {
                let j = j0 + jj;
                let col = col_codes(panels, &self.packed, j, k, bpc, qbuf);
                let scales = &self.scales[j * ng..(j + 1) * ng];
                for (lane, o) in orow.iter_mut().enumerate() {
                    *o = dot_col(&x[lane * k..(lane + 1) * k], col, scales, group);
                }
            }
        });
    }

    /// Fused dequant-GEMM: `out = x @ W̃` for `x` of `m` rows of `k`
    /// f32s. **Overwrites** `out` (`m × n`) — unlike
    /// [`crate::tensor::matmul::matmul_into`], which accumulates.
    /// Allocates a fresh [`GemmScratch`] per call and keeps the PR-4
    /// serial-flip epilogue — this is the legacy (`KURTAIL_ARENA=0`)
    /// profile the serve bench A/Bs against; the serve hot loop uses
    /// [`Self::matmul_into_scratch`] / [`Self::matmul_colmajor_scratch`].
    pub fn matmul_into(&self, x: &[f32], m: usize, out: &mut [f32], threads: usize) {
        let mut scratch = GemmScratch::with_threads(threads);
        self.matmul_into_scratch_serial(x, m, out, threads, par::backend(), &mut scratch);
    }

    /// `out_t = (x @ W̃)ᵀ` (`n × m` column-major, **overwrites**): the
    /// no-flip epilogue for fused consumers. Bitwise: `out_t[j·m + i]`
    /// equals `out[i·n + j]` of [`Self::matmul_into`] — same core, no
    /// epilogue arithmetic at all.
    pub fn matmul_colmajor_scratch(
        &self,
        x: &[f32],
        m: usize,
        out_t: &mut [f32],
        threads: usize,
        backend: ParBackend,
        scratch: &mut GemmScratch,
    ) {
        assert_eq!(x.len(), m * self.k, "int4 matmul: lhs size");
        assert_eq!(out_t.len(), m * self.n, "int4 matmul: out size");
        if m == 0 {
            return;
        }
        self.gemm_colmajor_core(x, m, out_t, threads, backend, &mut scratch.qbufs);
    }

    /// Allocating wrapper over [`Self::matmul_colmajor_scratch`].
    pub fn matmul_colmajor_into(&self, x: &[f32], m: usize, out_t: &mut [f32], threads: usize) {
        let mut scratch = GemmScratch::with_threads(threads);
        self.matmul_colmajor_scratch(x, m, out_t, threads, par::backend(), &mut scratch);
    }

    /// [`Self::matmul_into`] on caller-owned scratch: zero allocations
    /// once `scratch` has warmed to this problem size, row-major output
    /// via the **parallel blocked transpose** epilogue. Bitwise
    /// identical to the allocating entry (scratch contents never affect
    /// results; the flip moves the same bytes).
    pub fn matmul_into_scratch(
        &self,
        x: &[f32],
        m: usize,
        out: &mut [f32],
        threads: usize,
        scratch: &mut GemmScratch,
    ) {
        self.matmul_into_scratch_on(x, m, out, threads, par::backend(), scratch);
    }

    /// [`Self::matmul_into_scratch`] on an explicit parallel backend.
    pub fn matmul_into_scratch_on(
        &self,
        x: &[f32],
        m: usize,
        out: &mut [f32],
        threads: usize,
        backend: ParBackend,
        scratch: &mut GemmScratch,
    ) {
        assert_eq!(x.len(), m * self.k, "int4 matmul: lhs size");
        assert_eq!(out.len(), m * self.n, "int4 matmul: out size");
        if m == 0 {
            return;
        }
        if m == 1 {
            // GEMV: the output row *is* the column axis — no transpose
            return self.gemm_colmajor_core(x, 1, out, threads, backend, &mut scratch.qbufs);
        }
        let n = self.n;
        let GemmScratch { out_t, qbufs } = scratch;
        grow_for_overwrite(out_t, n * m);
        let out_t = &mut out_t[..n * m];
        self.gemm_colmajor_core(x, m, out_t, threads, backend, qbufs);
        transpose_into_on(backend, out_t, n, m, out, threads);
    }

    /// [`Self::matmul_into_scratch`] with the PR-4 **serial** scalar
    /// flip epilogue, kept verbatim so `benches/serve.rs` can isolate
    /// the fused/parallel epilogue win (`epilogue_fused_speedup`) and so
    /// `ServeConfig::fused_epilogue = Some(false)` reproduces the PR-4
    /// decode profile exactly.
    pub fn matmul_into_scratch_serial(
        &self,
        x: &[f32],
        m: usize,
        out: &mut [f32],
        threads: usize,
        backend: ParBackend,
        scratch: &mut GemmScratch,
    ) {
        assert_eq!(x.len(), m * self.k, "int4 matmul: lhs size");
        assert_eq!(out.len(), m * self.n, "int4 matmul: out size");
        if m == 0 {
            return;
        }
        if m == 1 {
            return self.gemm_colmajor_core(x, 1, out, threads, backend, &mut scratch.qbufs);
        }
        let n = self.n;
        let GemmScratch { out_t, qbufs } = scratch;
        grow_for_overwrite(out_t, n * m);
        let out_t = &mut out_t[..n * m];
        self.gemm_colmajor_core(x, m, out_t, threads, backend, qbufs);
        for i in 0..m {
            for j in 0..n {
                out[i * n + j] = out_t[j * m + i];
            }
        }
    }

    /// Integer-accumulator GEMM: `out = deq(codes) @ W̃` for `m` rows of
    /// int8 activation codes with per-row scales (the
    /// [`QuantActs`] layout). **Overwrites** `out` (`m × n`).
    ///
    /// Per output element the work is
    /// `Σ_g (act_scale·wscale_g) · Σ_{i∈g} xq_i·wq_i` — the inner sums
    /// run exactly in i32 ([`dot_i8_grouped`]), the scale product folds
    /// once per (row, group), and groups accumulate ascending in f32.
    /// Same parallel shape as [`Self::matmul_into`] (threads own output
    /// columns, one nibble unpack per column amortized over all lanes),
    /// so results are bitwise identical across thread counts and batch
    /// sizes. Versus the f32 dequant path the quantized codes are
    /// identical and only the in-group f32 summation order differs
    /// (bounded; pinned by `tests/props.rs`).
    pub fn matmul_i8_into(
        &self,
        codes: &[i8],
        act_scales: &[f32],
        m: usize,
        out: &mut [f32],
        threads: usize,
    ) {
        let mut scratch = GemmScratch::with_threads(threads);
        self.matmul_i8_scratch_serial(codes, act_scales, m, out, threads, par::backend(), &mut scratch);
    }

    /// Column-major core of the integer GEMM (see
    /// [`Self::gemm_colmajor_core`] for the parallel shape; the math is
    /// [`dot_i8_grouped`] per (lane, column)).
    fn gemm_i8_colmajor_core(
        &self,
        codes: &[i8],
        act_scales: &[f32],
        m: usize,
        out_t: &mut [f32],
        threads: usize,
        backend: ParBackend,
        qbufs: &mut [Vec<i8>],
    ) {
        let (k, group, ng) = (self.k, self.group, self.n_groups);
        let bpc = (k + 1) / 2;
        let panels = self.panels.as_deref();
        let min_rows = if m == 1 { 32 } else { 8 };
        par::par_row_chunks_scratch_mut_on(backend, out_t, m, min_rows, threads, qbufs, |j0, chunk, qbuf| {
            for (jj, orow) in chunk.chunks_exact_mut(m).enumerate() {
                let j = j0 + jj;
                let col = col_codes(panels, &self.packed, j, k, bpc, qbuf);
                let wscales = &self.scales[j * ng..(j + 1) * ng];
                for (lane, o) in orow.iter_mut().enumerate() {
                    let xq = &codes[lane * k..(lane + 1) * k];
                    *o = dot_i8_grouped(xq, col, wscales, group, act_scales[lane]);
                }
            }
        });
    }

    /// `out_t = (deq(codes) @ W̃)ᵀ` (`n × m` column-major,
    /// **overwrites**): the no-flip integer-GEMM epilogue for fused
    /// consumers.
    pub fn matmul_i8_colmajor_scratch(
        &self,
        codes: &[i8],
        act_scales: &[f32],
        m: usize,
        out_t: &mut [f32],
        threads: usize,
        backend: ParBackend,
        scratch: &mut GemmScratch,
    ) {
        assert!(codes.len() >= m * self.k, "int gemm: codes size");
        assert!(act_scales.len() >= m, "int gemm: scales size");
        assert_eq!(out_t.len(), m * self.n, "int gemm: out size");
        if m == 0 {
            return;
        }
        self.gemm_i8_colmajor_core(codes, act_scales, m, out_t, threads, backend, &mut scratch.qbufs);
    }

    /// [`Self::matmul_i8_into`] on caller-owned scratch: zero
    /// allocations once `scratch` has warmed to this problem size,
    /// row-major output via the parallel blocked transpose. Bitwise
    /// identical to the allocating entry.
    pub fn matmul_i8_scratch(
        &self,
        codes: &[i8],
        act_scales: &[f32],
        m: usize,
        out: &mut [f32],
        threads: usize,
        scratch: &mut GemmScratch,
    ) {
        self.matmul_i8_scratch_on(codes, act_scales, m, out, threads, par::backend(), scratch);
    }

    /// [`Self::matmul_i8_scratch`] on an explicit parallel backend.
    pub fn matmul_i8_scratch_on(
        &self,
        codes: &[i8],
        act_scales: &[f32],
        m: usize,
        out: &mut [f32],
        threads: usize,
        backend: ParBackend,
        scratch: &mut GemmScratch,
    ) {
        assert!(codes.len() >= m * self.k, "int gemm: codes size");
        assert!(act_scales.len() >= m, "int gemm: scales size");
        assert_eq!(out.len(), m * self.n, "int gemm: out size");
        if m == 0 {
            return;
        }
        if m == 1 {
            return self.gemm_i8_colmajor_core(codes, act_scales, 1, out, threads, backend, &mut scratch.qbufs);
        }
        let n = self.n;
        let GemmScratch { out_t, qbufs } = scratch;
        grow_for_overwrite(out_t, n * m);
        let out_t = &mut out_t[..n * m];
        self.gemm_i8_colmajor_core(codes, act_scales, m, out_t, threads, backend, qbufs);
        transpose_into_on(backend, out_t, n, m, out, threads);
    }

    /// [`Self::matmul_i8_scratch`] with the PR-4 serial flip epilogue
    /// (see [`Self::matmul_into_scratch_serial`]).
    pub fn matmul_i8_scratch_serial(
        &self,
        codes: &[i8],
        act_scales: &[f32],
        m: usize,
        out: &mut [f32],
        threads: usize,
        backend: ParBackend,
        scratch: &mut GemmScratch,
    ) {
        assert!(codes.len() >= m * self.k, "int gemm: codes size");
        assert!(act_scales.len() >= m, "int gemm: scales size");
        assert_eq!(out.len(), m * self.n, "int gemm: out size");
        if m == 0 {
            return;
        }
        if m == 1 {
            return self.gemm_i8_colmajor_core(codes, act_scales, 1, out, threads, backend, &mut scratch.qbufs);
        }
        let n = self.n;
        let GemmScratch { out_t, qbufs } = scratch;
        grow_for_overwrite(out_t, n * m);
        let out_t = &mut out_t[..n * m];
        self.gemm_i8_colmajor_core(codes, act_scales, m, out_t, threads, backend, qbufs);
        for i in 0..m {
            for j in 0..n {
                out[i * n + j] = out_t[j * m + i];
            }
        }
    }

    /// Fused quantize → integer GEMM → fold: quantizes `m` rows of `x`
    /// to int8 codes on the `act` grid (`serve::qact`) and runs
    /// [`Self::matmul_i8_into`]. **Overwrites** `out`.
    pub fn quant_matmul_into(
        &self,
        x: &[f32],
        m: usize,
        act: &QuantScheme,
        out: &mut [f32],
        threads: usize,
    ) {
        assert_eq!(x.len(), m * self.k, "quant matmul: lhs size");
        let mut codes = vec![0i8; m * self.k];
        let mut scales = vec![0.0f32; m];
        quantize_rows_into(x, self.k, act, &mut codes, &mut scales, threads);
        self.matmul_i8_into(&codes, &scales, m, out, threads);
    }

    /// Column-major twin of [`Self::quant_matmul_into`]: quantize `m`
    /// rows of `x` to int8 codes and leave `(deq(codes) @ W̃)ᵀ` in
    /// `out_t` (`n × m`, **overwrites**) — no flip anywhere.
    pub fn quant_matmul_colmajor_into(
        &self,
        x: &[f32],
        m: usize,
        act: &QuantScheme,
        out_t: &mut [f32],
        threads: usize,
    ) {
        assert_eq!(x.len(), m * self.k, "quant matmul: lhs size");
        let mut codes = vec![0i8; m * self.k];
        let mut scales = vec![0.0f32; m];
        quantize_rows_into(x, self.k, act, &mut codes, &mut scales, threads);
        let mut scratch = GemmScratch::with_threads(threads);
        self.matmul_i8_colmajor_scratch(&codes, &scales, m, out_t, threads, par::backend(), &mut scratch);
    }

    /// Tensor wrapper over [`Self::quant_matmul_into`] (keeps leading
    /// shape) — the int-path equivalent of `fake_quant_rows(x) @ W̃`.
    pub fn quant_matmul(&self, x: &Tensor, act: &QuantScheme) -> Tensor {
        self.quant_matmul_with_threads(x, act, num_threads())
    }

    /// [`Self::quant_matmul`] with an explicit thread budget.
    pub fn quant_matmul_with_threads(&self, x: &Tensor, act: &QuantScheme, threads: usize) -> Tensor {
        let (m, kx) = x.as_2d();
        assert_eq!(kx, self.k, "quant matmul inner dim: {kx} vs {}", self.k);
        let mut out = Tensor::zeros(&[m, self.n]);
        self.quant_matmul_into(&x.data, m, act, &mut out.data, threads);
        let mut shape = x.shape.clone();
        *shape.last_mut().unwrap() = self.n;
        out.reshape(&shape)
    }

    /// Integer GEMM on a pre-quantized activation block.
    pub fn matmul_quant_acts(&self, qa: &QuantActs, threads: usize) -> Tensor {
        assert_eq!(qa.k, self.k, "quant acts inner dim: {} vs {}", qa.k, self.k);
        let mut out = Tensor::zeros(&[qa.m, self.n]);
        self.matmul_i8_into(&qa.codes, &qa.scales, qa.m, &mut out.data, threads);
        out
    }

    /// Tensor wrapper over [`Self::matmul_into`] (keeps leading shape).
    pub fn matmul(&self, x: &Tensor) -> Tensor {
        self.matmul_with_threads(x, num_threads())
    }

    /// [`Self::matmul`] with an explicit thread budget (tests / engine).
    pub fn matmul_with_threads(&self, x: &Tensor, threads: usize) -> Tensor {
        let (m, kx) = x.as_2d();
        assert_eq!(kx, self.k, "int4 matmul inner dim: {kx} vs {}", self.k);
        let mut out = Tensor::zeros(&[m, self.n]);
        self.matmul_into(&x.data, m, &mut out.data, threads);
        let mut shape = x.shape.clone();
        *shape.last_mut().unwrap() = self.n;
        out.reshape(&shape)
    }
}

/// Unpack one column's nibbles into signed levels.
#[inline]
fn unpack_col(col: &[u8], k: usize, qbuf: &mut [i8]) {
    for i in 0..k {
        let b = col[i / 2];
        let nib = if i % 2 == 0 { b & 0x0F } else { b >> 4 };
        qbuf[i] = nib as i8 - 8;
    }
}

/// `Σ_g scale_g · Σ_{i∈g} x_i·q_i` with a fixed ascending order.
#[inline]
fn dot_col(x: &[f32], qbuf: &[i8], scales: &[f32], group: usize) -> f32 {
    let k = x.len();
    let mut acc = 0.0f32;
    for (g, &scale) in scales.iter().enumerate() {
        let i0 = g * group;
        let i1 = (i0 + group).min(k);
        let mut part = 0.0f32;
        for i in i0..i1 {
            part += x[i] * qbuf[i] as f32;
        }
        acc += scale * part;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::rtn_quantize;
    use crate::tensor::matmul::rows_matmul;
    use crate::util::Rng;

    #[test]
    fn roundtrip_matches_rtn_bitwise() {
        let mut rng = Rng::new(0);
        for (k, n) in [(7, 3), (16, 5), (33, 4), (1, 1), (64, 48)] {
            let w = Tensor::randn(&[k, n], 0.3, &mut rng);
            let s = QuantScheme::weight4();
            let got = Int4Weight::pack(&w, &s).unpack();
            let want = rtn_quantize(&w, &s);
            assert_eq!(got.data, want.data, "{k}x{n}");
        }
    }

    #[test]
    fn grouped_error_bounded_per_group() {
        let mut rng = Rng::new(1);
        let (k, n, g) = (33, 6, 8);
        let w = Tensor::randn(&[k, n], 0.3, &mut rng);
        let iw = Int4Weight::pack(&w, &QuantScheme::weight4_grouped(g));
        assert_eq!(iw.n_groups, 5); // ceil(33/8)
        let deq = iw.unpack();
        for j in 0..n {
            for gi in 0..iw.n_groups {
                let i0 = gi * g;
                let i1 = (i0 + g).min(k);
                let amax =
                    (i0..i1).fold(0.0f32, |a, i| a.max(w.data[i * n + j].abs()));
                let step = amax.max(1e-8) / 7.0;
                for i in i0..i1 {
                    let e = (deq.data[i * n + j] - w.data[i * n + j]).abs();
                    assert!(e <= step / 2.0 + 1e-6, "({i},{j})");
                }
            }
        }
    }

    #[test]
    fn matmul_matches_dense_on_unpacked() {
        let mut rng = Rng::new(2);
        for (m, k, n, g) in [(1, 33, 7, Some(8)), (5, 16, 9, None), (16, 40, 12, Some(16))] {
            let w = Tensor::randn(&[k, n], 0.3, &mut rng);
            let s = QuantScheme { group: g, ..QuantScheme::weight4() };
            let iw = Int4Weight::pack(&w, &s);
            let x = Tensor::randn(&[m, k], 1.0, &mut rng);
            let got = iw.matmul(&x);
            let want = rows_matmul(&x, &iw.unpack());
            assert!(got.max_abs_diff(&want) < 1e-3, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn matmul_bitwise_across_threads_and_batch() {
        let mut rng = Rng::new(3);
        let w = Tensor::randn(&[33, 17], 0.3, &mut rng);
        let iw = Int4Weight::pack(&w, &QuantScheme::weight4_grouped(8));
        let x = Tensor::randn(&[9, 33], 1.0, &mut rng);
        let batched = iw.matmul_with_threads(&x, 1);
        for threads in [2usize, 8] {
            assert_eq!(iw.matmul_with_threads(&x, threads).data, batched.data, "t={threads}");
        }
        // lane i of the batch == the single-row GEMV on the same row
        for i in 0..9 {
            let row = Tensor::new(x.row(i).to_vec(), vec![1, 33]);
            let one = iw.matmul_with_threads(&row, 4);
            assert_eq!(one.data, batched.row(i), "lane {i}");
        }
    }

    #[test]
    fn int_gemm_close_to_f32_dequant_path() {
        // identical quantized codes; only the in-group f32 summation
        // order differs between the two paths, so outputs stay within
        // a few ulps of each other at these magnitudes
        let mut rng = Rng::new(5);
        let act = QuantScheme::act4();
        for (m, k, n, g) in [(1usize, 33, 7, Some(8)), (5, 16, 9, None), (16, 64, 12, Some(16))] {
            let w = Tensor::randn(&[k, n], 0.3, &mut rng);
            let s = QuantScheme { group: g, ..QuantScheme::weight4() };
            let iw = Int4Weight::pack(&w, &s);
            let x = Tensor::randn(&[m, k], 1.0, &mut rng);
            let int = iw.quant_matmul(&x, &act);
            let f32_path = iw.matmul(&crate::quant::fakequant::fake_quant_rows(&x, &act));
            assert!(int.max_abs_diff(&f32_path) < 1e-4, "{m}x{k}x{n}: int vs f32 path");
            assert_eq!(int.shape, f32_path.shape);
        }
    }

    #[test]
    fn int_gemm_bitwise_across_threads_and_batch() {
        let mut rng = Rng::new(6);
        let act = QuantScheme::act4();
        let w = Tensor::randn(&[33, 17], 0.3, &mut rng);
        let iw = Int4Weight::pack(&w, &QuantScheme::weight4_grouped(8));
        let x = Tensor::randn(&[9, 33], 1.0, &mut rng);
        let batched = iw.quant_matmul_with_threads(&x, &act, 1);
        for threads in [2usize, 8] {
            let got = iw.quant_matmul_with_threads(&x, &act, threads);
            assert_eq!(got.data, batched.data, "t={threads}");
        }
        // lane i of the batch == the single-row integer GEMV on its row
        for i in 0..9 {
            let row = Tensor::new(x.row(i).to_vec(), vec![1, 33]);
            let one = iw.quant_matmul_with_threads(&row, &act, 4);
            assert_eq!(one.data, batched.row(i), "lane {i}");
        }
    }

    #[test]
    fn int_gemm_consumes_prequantized_acts() {
        use super::super::qact::QuantActs;
        let mut rng = Rng::new(7);
        let act = QuantScheme::act4();
        let w = Tensor::randn(&[40, 11], 0.3, &mut rng);
        let iw = Int4Weight::pack(&w, &QuantScheme::weight4_grouped(16));
        let x = Tensor::randn(&[6, 40], 1.0, &mut rng);
        let qa = QuantActs::quantize_with_threads(&x, &act, 2);
        let via_acts = iw.matmul_quant_acts(&qa, 4);
        let fused = iw.quant_matmul_with_threads(&x, &act, 4);
        assert_eq!(via_acts.data, fused.data, "shared quantized acts == fused path");
    }

    #[test]
    fn panel_cache_is_bitwise_transparent() {
        // cached panels hold exactly the unpack_col codes, so every GEMM
        // entry (f32 dequant + integer, GEMV + batched, via scratch or
        // allocating wrapper) must be bitwise unchanged by the cache
        let mut rng = Rng::new(8);
        let act = QuantScheme::act4();
        for (m, k, n, g) in [(1usize, 33, 7, Some(8)), (6, 40, 11, Some(16)), (5, 16, 9, None)] {
            let w = Tensor::randn(&[k, n], 0.3, &mut rng);
            let s = QuantScheme { group: g, ..QuantScheme::weight4() };
            let cold = Int4Weight::pack(&w, &s);
            let mut hot = cold.clone();
            hot.build_panels();
            assert!(hot.has_panels() && !cold.has_panels());
            assert_eq!(hot.panel_bytes(), k * n);
            assert_eq!(hot.unpack().data, cold.unpack().data);
            let x = Tensor::randn(&[m, k], 1.0, &mut rng);
            for threads in [1usize, 4] {
                assert_eq!(
                    hot.matmul_with_threads(&x, threads).data,
                    cold.matmul_with_threads(&x, threads).data,
                    "f32 path {m}x{k}x{n} t={threads}"
                );
                assert_eq!(
                    hot.quant_matmul_with_threads(&x, &act, threads).data,
                    cold.quant_matmul_with_threads(&x, &act, threads).data,
                    "int path {m}x{k}x{n} t={threads}"
                );
            }
            // scratch reuse across differently-sized calls stays correct
            let mut scratch = GemmScratch::with_threads(4);
            let mut a = vec![0.0f32; m * n];
            let mut b = vec![0.0f32; m * n];
            hot.matmul_into_scratch(&x.data, m, &mut a, 4, &mut scratch);
            hot.matmul_into_scratch(&x.data, m, &mut b, 4, &mut scratch);
            assert_eq!(a, b, "warm scratch must not drift");
            hot.drop_panels();
            assert!(!hot.has_panels());
            let mut c = vec![0.0f32; m * n];
            hot.matmul_into_scratch(&x.data, m, &mut c, 4, &mut scratch);
            assert_eq!(a, c, "dropping panels must not change results");
        }
    }

    #[test]
    fn epilogues_agree_bitwise() {
        // the three epilogues (colmajor, parallel transpose, PR-4 serial
        // flip) share one core: per element they must produce identical
        // bits on both GEMM paths, with and without the panel cache, at
        // every thread budget and parallel backend, m == 1 included
        let mut rng = Rng::new(31);
        let act = QuantScheme::act4();
        for (m, k, n, g) in [(1usize, 33, 7, Some(8)), (6, 40, 11, Some(16)), (16, 64, 12, None)] {
            let w = Tensor::randn(&[k, n], 0.3, &mut rng);
            let s = QuantScheme { group: g, ..QuantScheme::weight4() };
            let mut iw = Int4Weight::pack(&w, &s);
            let x = Tensor::randn(&[m, k], 1.0, &mut rng);
            let qa = super::super::qact::QuantActs::quantize_with_threads(&x, &act, 2);
            for panels in [false, true] {
                if panels {
                    iw.build_panels();
                }
                for threads in [1usize, 4] {
                    for backend in [ParBackend::Static, ParBackend::Steal] {
                        let mut scratch = GemmScratch::with_threads(threads);
                        // f32 dequant path
                        let mut row = vec![0.0f32; m * n];
                        iw.matmul_into(&x.data, m, &mut row, threads);
                        let mut par_row = vec![0.0f32; m * n];
                        iw.matmul_into_scratch_on(&x.data, m, &mut par_row, threads, backend, &mut scratch);
                        assert_eq!(par_row, row, "f32 parallel-flip {m}x{k}x{n} t={threads} {backend:?}");
                        let mut ser_row = vec![0.0f32; m * n];
                        iw.matmul_into_scratch_serial(&x.data, m, &mut ser_row, threads, backend, &mut scratch);
                        assert_eq!(ser_row, row, "f32 serial {m}x{k}x{n} t={threads} {backend:?}");
                        let mut col = vec![f32::NAN; m * n];
                        iw.matmul_colmajor_scratch(&x.data, m, &mut col, threads, backend, &mut scratch);
                        for i in 0..m {
                            for j in 0..n {
                                assert_eq!(col[j * m + i], row[i * n + j], "f32 colmajor ({i},{j})");
                            }
                        }
                        // integer path
                        let mut irow = vec![0.0f32; m * n];
                        iw.matmul_i8_into(&qa.codes, &qa.scales, m, &mut irow, threads);
                        let mut ipar = vec![0.0f32; m * n];
                        iw.matmul_i8_scratch_on(&qa.codes, &qa.scales, m, &mut ipar, threads, backend, &mut scratch);
                        assert_eq!(ipar, irow, "i8 parallel-flip {m}x{k}x{n} t={threads} {backend:?}");
                        let mut icol = vec![f32::NAN; m * n];
                        iw.matmul_i8_colmajor_scratch(&qa.codes, &qa.scales, m, &mut icol, threads, backend, &mut scratch);
                        for i in 0..m {
                            for j in 0..n {
                                assert_eq!(icol[j * m + i], irow[i * n + j], "i8 colmajor ({i},{j})");
                            }
                        }
                        // fused quantize→colmajor wrapper
                        let mut qcol = vec![f32::NAN; m * n];
                        iw.quant_matmul_colmajor_into(&x.data, m, &act, &mut qcol, threads);
                        assert_eq!(qcol, icol, "quant_matmul_colmajor {m}x{k}x{n}");
                    }
                }
            }
        }
    }

    #[test]
    fn panel_budget_flag_parse_rule() {
        assert_eq!(panel_budget_flag(None), usize::MAX, "unset defaults to unbounded");
        assert_eq!(panel_budget_flag(Some("0")), 0, "literal 0 disables");
        assert_eq!(panel_budget_flag(Some(" 4096 ")), 4096);
        assert_eq!(panel_budget_flag(Some("")), usize::MAX);
        // a memory *cap* must fail closed on garbage, not open
        assert_eq!(panel_budget_flag(Some("512M")), 0, "unparseable cap disables the cache");
        assert_eq!(panel_budget_flag(Some("lots")), 0);
    }

    #[test]
    fn bytes_accounting() {
        let mut rng = Rng::new(4);
        let w = Tensor::randn(&[64, 32], 0.3, &mut rng);
        let iw = Int4Weight::pack(&w, &QuantScheme::weight4());
        assert_eq!(iw.bytes(), 32 * 32 + 32 * 4); // nibbles + 1 scale/col
        assert_eq!(iw.dense_bytes(), 64 * 32 * 4);
        // odd k pads the last nibble
        let w2 = Tensor::randn(&[7, 2], 0.3, &mut rng);
        let iw2 = Int4Weight::pack(&w2, &QuantScheme::weight4());
        assert_eq!(iw2.bytes(), 2 * 4 + 2 * 4);
    }
}
