//! Activation quantization for the integer serving GEMM: int8 codes on
//! the **exact** `fake_quant_rows` grid.
//!
//! The serve forward fake-quantizes every activation block before its
//! packed-weight GEMM. The f32 path materializes the fake-quantized
//! *values* (`code · scale` per element) and dots them against
//! dequantized weights; this module materializes the *codes* instead —
//! one `i8` per element plus one f32 scale per row — so the GEMM can run
//! on integers and fold the scales once per (row, group).
//!
//! The grid is shared with [`crate::quant::fakequant`]: the scale is
//! [`row_scale_buf`] (absmax or clip-quantile over the row, divided by
//! `qmax`) and the code is `round(v / scale)` clamped to `±qmax` — the
//! same two expressions `fq_row_sym` evaluates. Therefore
//! `code as f32 * scale` reproduces the fake-quant output **bitwise**
//! (pinned by `tests/props.rs::prop_qact_codes_match_fake_quant_grid`),
//! which is what keeps the integer GEMM explainable against the
//! simulated-quantization path.
//!
//! Codes fit i8 for every scheme with `bits ≤ 8`; the serving default is
//! 4-bit (`qmax = 7`), leaving |code·wcode| ≤ 7·8 — small enough that an
//! i32 accumulator is exact for any realistic row width (see
//! [`crate::tensor::matmul::dot_i8_i32`]).

use crate::config::QuantScheme;
use crate::quant::fakequant::row_scale_buf;
use crate::tensor::Tensor;
use crate::util::par::{self, num_threads, ParBackend};

/// `KURTAIL_INT_GEMM` escape hatch: the integer-accumulator serving GEMM
/// is on by default; set `KURTAIL_INT_GEMM=0` to route quantized serving
/// through the f32 dequant GEMM instead (A/B debugging, perf bisection).
/// Read per call so tests and operators can flip it without restarting.
pub fn int_gemm_enabled() -> bool {
    int_gemm_flag(std::env::var("KURTAIL_INT_GEMM").ok().as_deref())
}

/// Parse rule behind [`int_gemm_enabled`]: unset → on, `0` → off,
/// anything else → on. Split out so the rule itself is testable.
fn int_gemm_flag(var: Option<&str>) -> bool {
    var.map(|v| v.trim() != "0").unwrap_or(true)
}

/// Whether a scheme's codes fit the int8 activation path: the per-row
/// grid must be symmetric (codes are signed levels) and ≤ 8 bits. The
/// engine falls back to the f32 dequant GEMM for anything else.
pub fn scheme_fits_i8(s: &QuantScheme) -> bool {
    s.symmetric && s.bits <= 8
}

/// A block of activation rows quantized to int8 codes, one scale per
/// row. `codes[r·k + i] as f32 * scales[r]` is bitwise the fake-quant
/// value of element `(r, i)`.
#[derive(Clone, Debug)]
pub struct QuantActs {
    pub m: usize,
    pub k: usize,
    /// `m × k` signed levels, row-major, each in `[-qmax, qmax]`.
    pub codes: Vec<i8>,
    /// One symmetric scale per row (the `row_scale_buf` grid).
    pub scales: Vec<f32>,
}

impl QuantActs {
    /// Quantize a `(…, k)` tensor row-wise on scheme `s`.
    pub fn quantize(x: &Tensor, s: &QuantScheme) -> QuantActs {
        Self::quantize_with_threads(x, s, num_threads())
    }

    /// [`Self::quantize`] with an explicit thread budget.
    pub fn quantize_with_threads(x: &Tensor, s: &QuantScheme, threads: usize) -> QuantActs {
        let (m, k) = x.as_2d();
        let mut codes = vec![0i8; m * k];
        let mut scales = vec![0.0f32; m];
        quantize_rows_into(&x.data, k, s, &mut codes, &mut scales, threads);
        QuantActs { m, k, codes, scales }
    }

    /// Dequantize back to the fake-quant tensor (tests / debugging):
    /// bitwise equal to `fake_quant_rows(x, s)` on the source rows.
    pub fn dequant(&self) -> Tensor {
        let mut out = Tensor::zeros(&[self.m, self.k]);
        for r in 0..self.m {
            let s = self.scales[r];
            for i in 0..self.k {
                out.data[r * self.k + i] = self.codes[r * self.k + i] as f32 * s;
            }
        }
        out
    }
}

/// Quantize `m = x.len()/width` rows of `width` f32s into caller-owned
/// `codes` (`m × width`) and `scales` (`m`) buffers. Two row-parallel
/// passes (scales, then codes), mirroring `Int4Weight::pack`; per-row
/// math is exactly the `row_scale_buf` → `round(v/scale).clamp(±qmax)`
/// pair of `fq_row_sym`, so the codes sit on the fake-quant grid.
pub fn quantize_rows_into(
    x: &[f32],
    width: usize,
    s: &QuantScheme,
    codes: &mut [i8],
    scales: &mut [f32],
    threads: usize,
) {
    let mut bufs: Vec<Vec<f32>> = (0..threads.max(1)).map(|_| Vec::new()).collect();
    quantize_rows_scratch(x, width, s, codes, scales, threads, &mut bufs);
}

/// [`quantize_rows_into`] with caller-owned per-chunk selection scratch
/// (the `row_scale_buf` clip-quantile workspace): the serve arena lends
/// one warm buffer per thread chunk so steady-state decode quantization
/// performs zero heap allocations. `bufs` needs at least as many slots
/// as the row partition produces chunks (`threads` always suffices);
/// contents never affect results.
pub fn quantize_rows_scratch(
    x: &[f32],
    width: usize,
    s: &QuantScheme,
    codes: &mut [i8],
    scales: &mut [f32],
    threads: usize,
    bufs: &mut [Vec<f32>],
) {
    quantize_rows_scratch_on(par::backend(), x, width, s, codes, scales, threads, bufs);
}

/// [`quantize_rows_scratch`] on an explicit parallel backend (the serve
/// engine pins one per `ServeConfig::par_backend`).
#[allow(clippy::too_many_arguments)]
pub fn quantize_rows_scratch_on(
    backend: ParBackend,
    x: &[f32],
    width: usize,
    s: &QuantScheme,
    codes: &mut [i8],
    scales: &mut [f32],
    threads: usize,
    bufs: &mut [Vec<f32>],
) {
    assert!(width > 0, "qact: zero row width");
    assert_eq!(x.len() % width, 0, "qact: ragged rows");
    let m = x.len() / width;
    assert!(codes.len() >= m * width, "qact: codes buffer too small");
    assert!(scales.len() >= m, "qact: scales buffer too small");
    assert!(s.bits <= 8, "qact codes are i8 (bits ≤ 8), got {}", s.bits);
    assert!(s.symmetric, "qact uses the symmetric per-row grid");
    if m == 0 {
        return;
    }
    par::par_row_chunks_scratch_mut_on(backend, &mut scales[..m], 1, 64, threads, bufs, |r0, chunk, buf| {
        for (i, sc) in chunk.iter_mut().enumerate() {
            let row = &x[(r0 + i) * width..(r0 + i + 1) * width];
            *sc = row_scale_buf(row, s, buf);
        }
    });
    let qmax = s.qmax();
    let scales_ref: &[f32] = &scales[..m];
    par::par_row_chunks_mut_on(backend, &mut codes[..m * width], width, 16, threads, |r0, chunk| {
        for (i, crow) in chunk.chunks_exact_mut(width).enumerate() {
            let scale = scales_ref[r0 + i];
            let row = &x[(r0 + i) * width..(r0 + i + 1) * width];
            for (c, &v) in crow.iter_mut().zip(row) {
                *c = (v / scale).round().clamp(-qmax, qmax) as i8;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::fakequant::{fake_quant_rows, row_scale};
    use crate::util::Rng;

    #[test]
    fn codes_reproduce_fake_quant_bitwise() {
        let mut rng = Rng::new(0);
        for (m, k) in [(1usize, 7usize), (5, 33), (16, 64), (3, 1)] {
            let x = Tensor::randn(&[m, k], 1.2, &mut rng);
            for s in [QuantScheme::act4(), QuantScheme { clip_quantile: None, ..QuantScheme::act4() }] {
                let qa = QuantActs::quantize_with_threads(&x, &s, 3);
                let want = fake_quant_rows(&x, &s);
                assert_eq!(qa.dequant().data, want.data, "{m}x{k}");
                for r in 0..m {
                    assert_eq!(qa.scales[r], row_scale(x.row(r), &s), "scale row {r}");
                }
            }
        }
    }

    #[test]
    fn codes_stay_on_the_integer_grid() {
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&[9, 41], 2.0, &mut rng);
        let s = QuantScheme::act4();
        let qa = QuantActs::quantize(&x, &s);
        let qmax = s.qmax() as i32;
        assert!(qa.codes.iter().all(|&c| (c as i32).abs() <= qmax));
        // clip quantile means some codes saturate at ±qmax on wide rows
        assert!(qa.codes.iter().any(|&c| (c as i32).abs() == qmax));
    }

    #[test]
    fn bitwise_across_thread_budgets() {
        let mut rng = Rng::new(2);
        let x = Tensor::randn(&[67, 96], 1.0, &mut rng);
        let s = QuantScheme::act4();
        let base = QuantActs::quantize_with_threads(&x, &s, 1);
        for threads in [2usize, 8] {
            let got = QuantActs::quantize_with_threads(&x, &s, threads);
            assert_eq!(got.codes, base.codes, "t={threads}");
            assert_eq!(got.scales, base.scales, "t={threads}");
        }
    }

    #[test]
    fn int_gemm_flag_parse_rule() {
        // the escape hatch: unset defaults ON, exactly "0" turns it off
        assert!(int_gemm_flag(None), "unset must default to the int path");
        assert!(!int_gemm_flag(Some("0")));
        assert!(!int_gemm_flag(Some(" 0 ")));
        assert!(int_gemm_flag(Some("1")));
        assert!(int_gemm_flag(Some("")));
        assert!(int_gemm_flag(Some("false")), "only literal 0 disables");
    }

    #[test]
    fn scheme_i8_compatibility() {
        assert!(scheme_fits_i8(&QuantScheme::act4()));
        assert!(!scheme_fits_i8(&QuantScheme::kv4()), "asymmetric grids need the f32 path");
        let s16 = QuantScheme { bits: 16, ..QuantScheme::act4() };
        assert!(!scheme_fits_i8(&s16), ">8-bit codes don't fit i8");
    }
}
