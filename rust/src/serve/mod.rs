//! Native INT4 serving subsystem: packed 4-bit weight storage, a paged
//! 4-bit KV-cache block pool, and a continuous-batching decode engine.
//!
//! Three pillars (see `rust/README.md` §Serving engine for the full
//! design, scale layouts and scheduler policy):
//!
//! * [`Int4Weight`] — nibble-packed weights on the RTN grid with
//!   per-(channel, group) scales and a fused dequant-GEMV/GEMM.
//! * [`KvPool`] / [`SeqKv`] — a reference-counted block-pool allocator
//!   storing K/V as 4-bit codes with per-token per-head asymmetric
//!   scales, append-quantize on write and fused dequant-attention on
//!   read; [`PrefixIndex`] maps identical prompt prefixes onto the same
//!   blocks (full blocks by refcount bump, partial tails copy-on-write).
//! * [`Engine`] + [`Scheduler`] — admit N concurrent sequences against
//!   the shared pool, batch prompt prefill, step every live lane per
//!   decode iteration, and retire/admit without draining the batch.
//! * [`QuantActs`] (`serve/qact.rs`) — activations quantized to int8
//!   codes + per-row scales on the exact `fake_quant_rows` grid, feeding
//!   the i32-accumulator GEMM (`Int4Weight::matmul_i8_into`) so the
//!   quantized decode path runs on integers end to end
//!   (`KURTAIL_INT_GEMM=0` routes back through the f32 dequant GEMM).
//! * [`DecodeScratch`] (`serve/scratch.rs`) — the engine-owned arena
//!   holding every per-iteration buffer, plus the i8 weight panel cache
//!   on [`Int4Weight`]: steady-state decode performs zero heap
//!   allocations and is bitwise identical to the fresh-alloc path
//!   (`KURTAIL_ARENA=0` / `KURTAIL_PANEL_CACHE=0` restore it).
//! * [`Daemon`] (`serve/daemon/`) — the long-running fault-tolerant
//!   HTTP front-end: every recoverable failure is a typed
//!   [`ServeError`] (`serve/error.rs`), admission is bounded with
//!   explicit load shedding, requests carry deadlines and can be
//!   canceled mid-flight with full KV-block reclaim, SIGTERM drains
//!   gracefully, and a seeded fault-injection layer (`KURTAIL_FAULT`)
//!   makes the failure paths testable (`rust/README.md` §Serving
//!   daemon). Under KV pressure the engine preempts the
//!   lowest-class/newest lane ([`LaneSnapshot`]) and later resumes it
//!   byte-identically via recompute; the daemon's supervisor replays
//!   host-side snapshots across engine restarts so clients see a
//!   pause, not a 503 (`rust/README.md` §Preemption & resume).
//! * Telemetry ([`crate::obs`]) — every engine owns an
//!   [`crate::obs::EngineObs`] bundle (queue-wait/TTFT/prefill/decode
//!   and per-phase histograms, KV-occupancy gauges, request counters)
//!   against its own metric registry; the daemon renders that registry
//!   as Prometheus text on `GET /metrics`, folds quantiles into
//!   `/stats`, and emits one structured log line per request lifecycle
//!   event (`KURTAIL_LOG`). Recording is atomics-only on the decode hot
//!   path and `KURTAIL_OBS=0` / `ServeConfig::obs` turns it off without
//!   changing a single emitted token (`rust/README.md` §Observability).
//!
//! Everything here runs on the host kernel layer (`util::par`
//! row-chunking, work-stealing by default with `KURTAIL_PAR=static` /
//! `ServeConfig::par_backend` for A/B) with the repo-wide determinism
//! contract: results are bitwise identical across `KURTAIL_THREADS`
//! settings, parallel backends and GEMM output layouts
//! (`ServeConfig::fused_epilogue`), and a lane's token stream does not
//! depend on which other lanes share its batch.

pub mod daemon;
pub mod engine;
pub mod error;
pub mod int4;
pub mod kvcache;
pub mod qact;
pub mod scheduler;
pub mod scratch;

pub use daemon::config::{ConfigCell, RuntimeConfig, TenantPolicy};
pub use daemon::ratelimit::TokenBucket;
pub use daemon::{Daemon, DaemonConfig, Host, HostConfig};
pub use engine::{
    argmax, fused_epilogue_enabled, kv_high_water_default, preempt_enabled, prefill_chunk_default,
    prefix_share_enabled, sample_token, sample_token_buf, Completion, Engine, EngineStats,
    ServeConfig, ServeModel, ServeQuantSpec, DEFAULT_KV_HIGH_WATER, DEFAULT_PREFILL_CHUNK,
};
pub use error::ServeError;
pub use int4::{panel_cache_budget, GemmScratch, Int4Weight};
pub use kvcache::{KvPool, PrefixIndex, SeqKv};
pub use qact::{int_gemm_enabled, QuantActs};
pub use scheduler::{LaneSnapshot, Priority, QueuedRequest, Scheduler};
pub use scratch::{arena_enabled, scratch_decay_default, DecodeScratch, DEFAULT_DECAY_STEPS};

pub use crate::util::par::ParBackend;
