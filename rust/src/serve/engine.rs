//! The native INT4 serving engine: continuous-batching autoregressive
//! decode over packed INT4 weights ([`Int4Weight`]) and the paged 4-bit
//! KV pool ([`KvPool`]), entirely on the host kernel layer — no PJRT
//! artifacts at request time.
//!
//! The per-token math mirrors `python/compile/model.py::decode_step`
//! (same op order: norm → act-fake-quant → QKV → RoPE → R3 →
//! KV-quantize-on-append → fused attention → R4 → Wo → FFN with R5),
//! so with 4-bit KV the dequantized cache holds exactly what the quant
//! decode artifact's dense cache holds, and at `temp = 0` the engine
//! reproduces the artifact `Generator` greedy stream modulo f32
//! summation order (pinned by the artifact-parity integration test).
//!
//! **Batching model.** Each decode iteration stacks every live lane's
//! current token into one `(N, d)` activation block so the weight
//! matrices are traversed once per *iteration*, not once per lane —
//! that's the continuous-batching win the serve bench measures. Prompt
//! prefill runs the same forward with one row per prompt position
//! (closing the ROADMAP prefill-batching item). All row-level kernels
//! (norm, fake-quant, GEMM, attention) are per-row independent with
//! fixed accumulation order, so **a lane's token stream is bitwise
//! independent of which other lanes happen to share its batch** — a
//! 1-lane engine and a 16-lane engine produce identical completions
//! (pinned by tests) — and independent of `KURTAIL_THREADS`.
//!
//! **Integer GEMM path.** For quantized models the activation
//! fake-quant before each packed GEMM produces int8 *codes* + per-row
//! scales (`serve/qact.rs`) instead of fake-quantized f32 values, and
//! the GEMM accumulates in i32 (`Int4Weight::matmul_i8_into`), folding
//! `act_scale · weight_group_scale` once per (row, group). Codes are
//! identical to the fake-quant grid, so only in-group f32 summation
//! order distinguishes the paths; both keep the batching/threading
//! invariants above. `KURTAIL_INT_GEMM=0` (or
//! `ServeConfig::int_gemm = Some(false)`) restores the f32 dequant GEMM.

use anyhow::Result;

use crate::calib::ByteTokenizer;
use crate::config::{KvQuant, QuantScheme};
use crate::model::Params;
use crate::quant::fakequant::{fq_row_sym, row_scale_buf};
use crate::runtime::ConfigMeta;
use crate::tensor::matmul::matmul_into_threads;
use crate::tensor::Tensor;
use crate::util::par::{self, num_threads};
use crate::util::Rng;

use super::int4::Int4Weight;
use super::kvcache::{KvPool, SeqKv};
use super::qact::{int_gemm_enabled, quantize_rows_into, scheme_fits_i8};
use super::scheduler::{QueuedRequest, Scheduler};

/// RoPE base shared by every preset (`ModelConfig.rope_base`); the
/// manifest does not carry it because no config overrides it.
const ROPE_BASE: f32 = 10000.0;

// ------------------------------------------------------------- model

/// Online quantization spec for a quantized serving model: the weight
/// grid used at pack time, the activation fake-quant scheme, and the
/// online rotations (R3/R4/R5) the quant decode graph applies.
#[derive(Clone)]
pub struct ServeQuantSpec {
    pub weight: QuantScheme,
    pub act: QuantScheme,
    pub r3: Tensor,
    pub r4: Tensor,
    pub r5: Tensor,
}

impl ServeQuantSpec {
    /// Paper-default W4/A4 spec with the given online rotations.
    pub fn paper_default(r3: Tensor, r4: Tensor, r5: Tensor) -> Self {
        Self { weight: QuantScheme::weight4(), act: QuantScheme::act4(), r3, r4, r5 }
    }
}

/// One linear's serving-time storage.
#[derive(Clone)]
enum LinW {
    F32(Tensor),
    Int4(Int4Weight),
}

impl LinW {
    fn bytes(&self) -> usize {
        match self {
            LinW::F32(t) => t.numel() * 4,
            LinW::Int4(w) => w.bytes(),
        }
    }

    fn dense_bytes(&self) -> usize {
        match self {
            LinW::F32(t) => t.numel() * 4,
            LinW::Int4(w) => w.dense_bytes(),
        }
    }

    /// `out = x @ W` (overwrites `out`).
    fn matmul_into(&self, x: &[f32], m: usize, out: &mut [f32], threads: usize) {
        match self {
            LinW::F32(t) => {
                out.fill(0.0);
                matmul_into_threads(x, &t.data, out, m, t.shape[0], t.shape[1], threads);
            }
            LinW::Int4(w) => w.matmul_into(x, m, out, threads),
        }
    }

    /// Integer-accumulator GEMM on pre-quantized activation codes
    /// (overwrites `out`). Only the quantized (packed) serving path
    /// takes this; fp models never quantize activations.
    fn matmul_i8_into(&self, codes: &[i8], scales: &[f32], m: usize, out: &mut [f32], threads: usize) {
        match self {
            LinW::Int4(w) => w.matmul_i8_into(codes, scales, m, out, threads),
            LinW::F32(_) => unreachable!("integer GEMM requires packed int4 weights"),
        }
    }
}

/// One serving projection: the integer path consumes the block's shared
/// int8 codes + per-row scales; the f32 path the (already fake-quantized)
/// dense activations. Split out so every GEMM site in `forward` stays a
/// one-liner per weight.
fn project(
    w: &LinW,
    use_int: bool,
    z: &[f32],
    codes: &[i8],
    scales: &[f32],
    m: usize,
    out: &mut [f32],
    threads: usize,
) {
    if use_int {
        w.matmul_i8_into(codes, scales, m, out, threads);
    } else {
        w.matmul_into(z, m, out, threads);
    }
}

/// Activation quantization for one GEMM site: the integer path reads
/// `data` into int8 codes + per-row scales (leaving `data` untouched),
/// the f32 path fake-quantizes `data` in place — the single spot where
/// the two paths' pre-GEMM step lives, so every site in `forward` stays
/// in lockstep.
fn quantize_site(
    data: &mut [f32],
    width: usize,
    act: &QuantScheme,
    use_int: bool,
    codes: &mut [i8],
    scales: &mut [f32],
    threads: usize,
) {
    if use_int {
        quantize_rows_into(data, width, act, codes, scales, threads);
    } else {
        fq_rows(data, width, act, threads);
    }
}

#[derive(Clone)]
struct LayerW {
    ln1: Vec<f32>,
    ln2: Vec<f32>,
    wq: LinW,
    wk: LinW,
    wv: LinW,
    wo: LinW,
    /// `None` for the phi arch (single-branch FFN).
    wg: Option<LinW>,
    wu: LinW,
    wd: LinW,
}

/// A model prepared for serving: embedding/head in f32, transformer
/// linears packed INT4 (quant) or dense f32 (fp), RoPE tables
/// precomputed to `max_pos`.
#[derive(Clone)]
pub struct ServeModel {
    pub meta: ConfigMeta,
    embed: Tensor,
    head_t: Tensor,
    lnf: Vec<f32>,
    layers: Vec<LayerW>,
    quant: Option<ServeQuantSpec>,
    rope_cos: Vec<f32>,
    rope_sin: Vec<f32>,
    /// Maximum cache position + 1 a request may reach.
    pub max_pos: usize,
}

impl ServeModel {
    /// Build a serving model from a parameter store. `quant = Some(_)`
    /// packs every transformer linear to INT4 on the spec's weight grid
    /// (this *is* the serving-side weight quantizer — hand it the fused,
    /// un-fake-quantized weights; RTN-quantized weights are a fixpoint).
    /// Embedding and head stay f32 (standard practice).
    pub fn from_params(params: &Params, quant: Option<ServeQuantSpec>) -> Result<Self> {
        let meta = params.meta.clone();
        anyhow::ensure!(
            matches!(meta.arch.as_str(), "llama" | "phi"),
            "serve engine supports llama/phi archs, not '{}'",
            meta.arch
        );
        let (d, h, dh) = (meta.d_model, meta.n_heads, meta.d_head);
        anyhow::ensure!(d == h * dh, "d_model {d} != n_heads*d_head");
        anyhow::ensure!(dh % 2 == 0, "RoPE needs an even d_head, got {dh}");
        if let Some(q) = &quant {
            anyhow::ensure!(q.r3.shape == vec![dh, dh], "r3 must be ({dh},{dh})");
            anyhow::ensure!(q.r4.shape == vec![dh, dh], "r4 must be ({dh},{dh})");
            anyhow::ensure!(
                q.r5.shape == vec![meta.d_ff, meta.d_ff],
                "r5 must be ({0},{0})",
                meta.d_ff
            );
        }
        let pack = |w: Tensor| -> LinW {
            match &quant {
                Some(q) => LinW::Int4(Int4Weight::pack(&w, &q.weight)),
                None => LinW::F32(w),
            }
        };
        let mut layers = Vec::with_capacity(meta.n_layers);
        for l in 0..meta.n_layers {
            layers.push(LayerW {
                ln1: params.get("ln1").index_axis0(l).data,
                ln2: params.get("ln2").index_axis0(l).data,
                wq: pack(params.get("wq").index_axis0(l)),
                wk: pack(params.get("wk").index_axis0(l)),
                wv: pack(params.get("wv").index_axis0(l)),
                wo: pack(params.get("wo").index_axis0(l)),
                wg: if params.has("wg") { Some(pack(params.get("wg").index_axis0(l))) } else { None },
                wu: pack(params.get("wu").index_axis0(l)),
                wd: pack(params.get("wd").index_axis0(l)),
            });
        }
        let max_pos = meta.seq_len;
        // rope_tables(): inv_i = base^(-2i/dh), ang = pos · inv
        let dh2 = dh / 2;
        let inv: Vec<f32> =
            (0..dh2).map(|i2| ROPE_BASE.powf(-((2 * i2) as f32) / dh as f32)).collect();
        let mut rope_cos = vec![0.0f32; max_pos * dh2];
        let mut rope_sin = vec![0.0f32; max_pos * dh2];
        for p in 0..max_pos {
            for (i2, &iv) in inv.iter().enumerate() {
                let ang = p as f32 * iv;
                rope_cos[p * dh2 + i2] = ang.cos();
                rope_sin[p * dh2 + i2] = ang.sin();
            }
        }
        Ok(Self {
            embed: params.get("embed").clone(),
            head_t: params.get("head").t(),
            lnf: params.get("lnf").data.clone(),
            meta,
            layers,
            quant,
            rope_cos,
            rope_sin,
            max_pos,
        })
    }

    pub fn is_quantized(&self) -> bool {
        self.quant.is_some()
    }

    /// Serving-time bytes of the transformer linears (packed or dense).
    pub fn weight_bytes(&self) -> usize {
        self.layers.iter().map(layer_bytes).sum()
    }

    /// Dense-f32 bytes of the same linears (the compression baseline).
    pub fn dense_weight_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| {
                [Some(&l.wq), Some(&l.wk), Some(&l.wv), Some(&l.wo), l.wg.as_ref(), Some(&l.wu), Some(&l.wd)]
                    .into_iter()
                    .flatten()
                    .map(|w| w.dense_bytes())
                    .sum::<usize>()
            })
            .sum()
    }
}

fn layer_bytes(l: &LayerW) -> usize {
    [Some(&l.wq), Some(&l.wk), Some(&l.wv), Some(&l.wo), l.wg.as_ref(), Some(&l.wu), Some(&l.wd)]
        .into_iter()
        .flatten()
        .map(|w| w.bytes())
        .sum()
}

// ------------------------------------------------------------- engine

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Maximum concurrently decoding sequences.
    pub max_lanes: usize,
    /// Tokens per KV block.
    pub block_tokens: usize,
    /// KV pool capacity in blocks; `0` sizes the pool so `max_lanes`
    /// full-length sequences always fit.
    pub max_blocks: usize,
    pub kv_quant: KvQuant,
    /// Thread budget override (`None` = `KURTAIL_THREADS` / host cores).
    pub threads: Option<usize>,
    /// Integer-accumulator GEMM for quantized models: `None` follows the
    /// `KURTAIL_INT_GEMM` env escape hatch (on unless set to `0`),
    /// `Some(_)` pins it (benches A/B the two paths this way). Ignored
    /// for fp models (which never quantize activations) and for act
    /// schemes whose codes don't fit i8 (asymmetric or > 8 bits — those
    /// fall back to the f32 dequant GEMM).
    pub int_gemm: Option<bool>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            max_lanes: 4,
            block_tokens: 16,
            max_blocks: 0,
            kv_quant: KvQuant::Asym4,
            threads: None,
            int_gemm: None,
        }
    }
}

/// A finished request: the full token stream (prompt included) and its
/// byte-decoded text.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: usize,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    pub text: String,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    pub steps: u64,
    pub prefill_tokens: u64,
    pub decode_tokens: u64,
    pub admitted: u64,
    pub retired: u64,
    pub peak_lanes: usize,
}

struct Lane {
    id: usize,
    tokens: Vec<i32>,
    prompt_len: usize,
    n_new: usize,
    produced: usize,
    temp: f32,
    rng: Rng,
    seq: SeqKv,
    /// Tokens already written to the KV cache.
    pos: usize,
    reserved_blocks: usize,
}

/// The continuous-batching serving engine.
pub struct Engine {
    model: ServeModel,
    pool: KvPool,
    sched: Scheduler,
    lanes: Vec<Option<Lane>>,
    done: Vec<Completion>,
    next_id: usize,
    committed_blocks: usize,
    threads: usize,
    int_gemm: bool,
    pub stats: EngineStats,
}

impl Engine {
    pub fn new(model: ServeModel, cfg: &ServeConfig) -> Result<Self> {
        anyhow::ensure!(cfg.max_lanes >= 1, "need at least one lane");
        let meta = &model.meta;
        let threads = cfg.threads.unwrap_or_else(num_threads).max(1);
        let per_seq = meta.n_layers
            * 2
            * ((model.max_pos + cfg.block_tokens - 1) / cfg.block_tokens);
        let max_blocks = if cfg.max_blocks > 0 { cfg.max_blocks } else { cfg.max_lanes * per_seq };
        let pool = KvPool::new(cfg.kv_quant, meta.n_heads, meta.d_head, cfg.block_tokens, max_blocks);
        // the integer path needs i8-representable activation codes
        // (symmetric, ≤ 8 bits); anything else — reachable through the
        // public ServeQuantSpec fields — silently keeps the f32 dequant
        // GEMM, which every spec supports
        let int_gemm = cfg.int_gemm.unwrap_or_else(int_gemm_enabled)
            && model.quant.as_ref().is_none_or(|q| scheme_fits_i8(&q.act));
        Ok(Self {
            lanes: (0..cfg.max_lanes).map(|_| None).collect(),
            model,
            pool,
            sched: Scheduler::new(),
            done: Vec::new(),
            next_id: 0,
            committed_blocks: 0,
            threads,
            int_gemm,
            stats: EngineStats::default(),
        })
    }

    /// Whether quantized GEMMs run on the integer-accumulator path
    /// (`ServeConfig::int_gemm`, falling back to `KURTAIL_INT_GEMM`).
    pub fn int_gemm(&self) -> bool {
        self.int_gemm
    }

    /// Queue a text prompt (byte-tokenized). Returns the request id.
    pub fn submit(&mut self, prompt: &str, n_tokens: usize, temp: f32, seed: u64) -> Result<usize> {
        self.submit_tokens(ByteTokenizer.encode(prompt), n_tokens, temp, seed)
    }

    /// Queue a pre-tokenized prompt. Returns the request id.
    pub fn submit_tokens(&mut self, tokens: Vec<i32>, n_tokens: usize, temp: f32, seed: u64) -> Result<usize> {
        anyhow::ensure!(!tokens.is_empty(), "empty prompt");
        anyhow::ensure!(n_tokens >= 1, "need at least one generated token");
        let vocab = self.model.meta.vocab as i32;
        anyhow::ensure!(
            tokens.iter().all(|&t| t >= 0 && t < vocab),
            "prompt token out of vocab range 0..{vocab}"
        );
        let total = tokens.len() + n_tokens;
        anyhow::ensure!(
            total <= self.model.max_pos,
            "prompt+generation ({total}) exceeds cache size {}",
            self.model.max_pos
        );
        let needed = self.pool.blocks_needed(self.model.meta.n_layers, total);
        anyhow::ensure!(
            needed <= self.pool.max_blocks,
            "request needs {needed} KV blocks but the pool only has {}",
            self.pool.max_blocks
        );
        let id = self.next_id;
        self.next_id += 1;
        self.sched.push(QueuedRequest { id, tokens, n_new: n_tokens, temp, seed });
        Ok(id)
    }

    /// Blocks the pool can still promise to new admissions.
    fn uncommitted_blocks(&self) -> usize {
        self.pool.max_blocks - self.committed_blocks
    }

    /// One engine iteration: retire finished lanes, admit + prefill
    /// queued requests into free lanes, then decode one token on every
    /// other live lane. Returns `false` once no work remains.
    pub fn step(&mut self) -> Result<bool> {
        self.retire_finished();

        // admit into free lanes (FCFS, reservation-checked); freshly
        // admitted lanes already produce their first token via prefill,
        // so they sit out this iteration's decode batch
        let mut admitted_now: Vec<usize> = Vec::new();
        for slot in 0..self.lanes.len() {
            if self.lanes[slot].is_some() {
                continue;
            }
            let budget = self.uncommitted_blocks();
            let (pool, meta) = (&self.pool, &self.model.meta);
            let Some(req) = self
                .sched
                .pop_if(|r| pool.blocks_needed(meta.n_layers, r.total_tokens()) <= budget)
            else {
                break;
            };
            let reserved = self.pool.blocks_needed(self.model.meta.n_layers, req.total_tokens());
            self.committed_blocks += reserved;
            let lane = Lane {
                id: req.id,
                prompt_len: req.tokens.len(),
                n_new: req.n_new,
                produced: 0,
                temp: req.temp,
                rng: req.rng(),
                seq: SeqKv::new(self.model.meta.n_layers),
                pos: 0,
                reserved_blocks: reserved,
                tokens: req.tokens,
            };
            self.lanes[slot] = Some(lane);
            self.prefill(slot)?;
            admitted_now.push(slot);
            self.stats.admitted += 1;
        }

        // one decode token for every live lane not admitted this step
        let decode_slots: Vec<usize> = (0..self.lanes.len())
            .filter(|&s| {
                self.lanes[s].as_ref().map_or(false, |l| l.produced < l.n_new)
                    && !admitted_now.contains(&s)
            })
            .collect();
        if !decode_slots.is_empty() {
            self.decode_batch(&decode_slots)?;
        }

        let live = self.lanes.iter().filter(|l| l.is_some()).count();
        self.stats.peak_lanes = self.stats.peak_lanes.max(live);
        self.stats.steps += 1;
        self.retire_finished();
        Ok(self.lanes.iter().any(|l| l.is_some()) || !self.sched.is_empty())
    }

    /// Run to completion; completions are returned in submission order.
    pub fn run(&mut self) -> Result<Vec<Completion>> {
        while self.step()? {}
        let mut out = std::mem::take(&mut self.done);
        out.sort_by_key(|c| c.id);
        Ok(out)
    }

    fn retire_finished(&mut self) {
        for slot in 0..self.lanes.len() {
            let finished = self.lanes[slot].as_ref().map_or(false, |l| l.produced >= l.n_new);
            if !finished {
                continue;
            }
            let mut lane = self.lanes[slot].take().unwrap();
            self.pool.release(&mut lane.seq);
            self.committed_blocks -= lane.reserved_blocks;
            self.stats.retired += 1;
            self.done.push(Completion {
                id: lane.id,
                prompt_len: lane.prompt_len,
                text: ByteTokenizer.decode(&lane.tokens),
                tokens: lane.tokens,
            });
        }
    }

    /// Batched prompt prefill for one freshly admitted lane: all prompt
    /// positions run through the forward as one `(T, d)` block, then the
    /// last position's logits seed the first generated token.
    fn prefill(&mut self, slot: usize) -> Result<()> {
        let (rows, x) = {
            let lane = self.lanes[slot].as_ref().unwrap();
            let p = lane.prompt_len;
            let rows: Vec<(usize, usize)> = (0..p).map(|t| (slot, t)).collect();
            (rows, self.embed_rows(&lane.tokens[..p]))
        };
        let n = rows.len();
        let logits = self.forward(&rows, x)?;
        let vocab = self.model.meta.vocab;
        let lane = self.lanes[slot].as_mut().unwrap();
        lane.pos = lane.prompt_len;
        let next = sample_token(&logits[(n - 1) * vocab..n * vocab], lane.temp, &mut lane.rng);
        lane.tokens.push(next);
        lane.produced = 1;
        self.stats.prefill_tokens += n as u64;
        self.stats.decode_tokens += 1;
        Ok(())
    }

    /// One decode token for every slot in `slots`, batched `(N, d)`.
    fn decode_batch(&mut self, slots: &[usize]) -> Result<()> {
        let mut rows = Vec::with_capacity(slots.len());
        let mut toks = Vec::with_capacity(slots.len());
        for &s in slots {
            let lane = self.lanes[s].as_ref().unwrap();
            rows.push((s, lane.pos));
            toks.push(lane.tokens[lane.pos]);
        }
        let x = self.embed_rows(&toks);
        let logits = self.forward(&rows, x)?;
        let vocab = self.model.meta.vocab;
        for (i, &s) in slots.iter().enumerate() {
            let lane = self.lanes[s].as_mut().unwrap();
            let next = sample_token(&logits[i * vocab..(i + 1) * vocab], lane.temp, &mut lane.rng);
            lane.pos += 1;
            lane.tokens.push(next);
            lane.produced += 1;
            self.stats.decode_tokens += 1;
        }
        Ok(())
    }

    fn embed_rows(&self, tokens: &[i32]) -> Vec<f32> {
        let d = self.model.meta.d_model;
        let mut x = Vec::with_capacity(tokens.len() * d);
        for &t in tokens {
            x.extend_from_slice(self.model.embed.row(t as usize));
        }
        x
    }

    /// The batched transformer forward for `rows` = `(lane_slot, pos)`
    /// pairs with activations `x` (`N × d`, row i belongs to `rows[i]`).
    /// Appends this token's K/V to each row's paged cache and returns
    /// logits (`N × vocab`). Mirrors `decode_step` op-for-op.
    fn forward(&mut self, rows: &[(usize, usize)], mut x: Vec<f32>) -> Result<Vec<f32>> {
        let model = &self.model;
        let pool = &mut self.pool;
        let lanes = &mut self.lanes;
        let threads = self.threads;
        let meta = &model.meta;
        let (d, h, dh, ff) = (meta.d_model, meta.n_heads, meta.d_head, meta.d_ff);
        let dh2 = dh / 2;
        let n = rows.len();
        assert_eq!(x.len(), n * d);
        let quant = model.quant.as_ref();
        // integer GEMM path: quantize each activation block to int8
        // codes once and feed every consuming linear; the f32 path
        // fake-quantizes in place instead. Both sit on the same grid
        // (identical codes), so the paths differ only in f32 summation
        // order inside a scale group (see serve/qact.rs).
        let use_int = self.int_gemm && quant.is_some();
        let (mut qcodes, mut qscales) = if use_int {
            (vec![0i8; n * d.max(ff)], vec![0.0f32; n])
        } else {
            (Vec::new(), Vec::new())
        };

        let mut z = vec![0.0f32; n * d];
        let mut qx = vec![0.0f32; n * d];
        let mut kx = vec![0.0f32; n * d];
        let mut vx = vec![0.0f32; n * d];
        let mut attn = vec![0.0f32; n * d];
        let mut rot = vec![0.0f32; n * d];
        let mut mid = vec![0.0f32; n * ff];
        let mut gate = vec![0.0f32; n * ff];

        for (l, lw) in model.layers.iter().enumerate() {
            // z = act_fq(rmsnorm(x, ln1)) — shared by wq/wk/wv
            rmsnorm_gamma_rows(&x, &lw.ln1, &mut z, d, threads);
            if let Some(q) = quant {
                quantize_site(&mut z, d, &q.act, use_int, &mut qcodes, &mut qscales, threads);
            }
            project(&lw.wq, use_int, &z, &qcodes, &qscales, n, &mut qx, threads);
            project(&lw.wk, use_int, &z, &qcodes, &qscales, n, &mut kx, threads);
            project(&lw.wv, use_int, &z, &qcodes, &qscales, n, &mut vx, threads);

            // RoPE at each row's position, per head
            for (i, &(_, pos)) in rows.iter().enumerate() {
                let (cos, sin) =
                    (&model.rope_cos[pos * dh2..(pos + 1) * dh2], &model.rope_sin[pos * dh2..(pos + 1) * dh2]);
                for head in 0..h {
                    let o = i * d + head * dh;
                    apply_rope_row(&mut qx[o..o + dh], cos, sin);
                    apply_rope_row(&mut kx[o..o + dh], cos, sin);
                }
            }
            // online R3 (cancels in QᵀK, shapes the K cache distribution)
            if let Some(q) = quant {
                head_rotate(&mut qx, &mut rot, &q.r3, n * h, dh, threads);
                head_rotate(&mut kx, &mut rot, &q.r3, n * h, dh, threads);
            }
            // append-quantize this token's K/V into the paged pool
            for (i, &(slot, pos)) in rows.iter().enumerate() {
                let lane = lanes[slot].as_mut().unwrap();
                pool.append(&mut lane.seq, l, pos, &kx[i * d..(i + 1) * d], &vx[i * d..(i + 1) * d])?;
            }
            // Q activation quant happens after R3 (decode_step order)
            if let Some(q) = quant {
                fq_rows(&mut qx, dh, &q.act, threads);
            }
            // fused dequant-attention per row (rows own disjoint caches
            // or, within a prefill, disjoint causal prefixes)
            {
                let pool_ref: &KvPool = pool;
                let lanes_ref: &Vec<Option<Lane>> = lanes;
                par::par_row_chunks_mut(&mut attn, d, 1, threads, |r0, chunk| {
                    let mut scores = Vec::new();
                    for (i, orow) in chunk.chunks_exact_mut(d).enumerate() {
                        let (slot, pos) = rows[r0 + i];
                        let seq = &lanes_ref[slot].as_ref().unwrap().seq;
                        pool_ref.attend(seq, l, pos + 1, &qx[(r0 + i) * d..(r0 + i + 1) * d], orow, &mut scores);
                    }
                });
            }
            if let Some(q) = quant {
                head_rotate(&mut attn, &mut rot, &q.r4, n * h, dh, threads);
                quantize_site(&mut attn, d, &q.act, use_int, &mut qcodes, &mut qscales, threads);
            }
            project(&lw.wo, use_int, &attn, &qcodes, &qscales, n, &mut z, threads);
            add_assign(&mut x, &z);

            // FFN
            rmsnorm_gamma_rows(&x, &lw.ln2, &mut z, d, threads);
            if let Some(q) = quant {
                quantize_site(&mut z, d, &q.act, use_int, &mut qcodes, &mut qscales, threads);
            }
            match &lw.wg {
                Some(wg) => {
                    // llama: silu(z·Wg) ⊙ (z·Wu)
                    project(wg, use_int, &z, &qcodes, &qscales, n, &mut gate, threads);
                    project(&lw.wu, use_int, &z, &qcodes, &qscales, n, &mut mid, threads);
                    for (m, &gv) in mid.iter_mut().zip(&gate) {
                        *m = silu(gv) * *m;
                    }
                }
                None => {
                    // phi: gelu(z·Wu)
                    project(&lw.wu, use_int, &z, &qcodes, &qscales, n, &mut mid, threads);
                    for m in mid.iter_mut() {
                        *m = gelu(*m);
                    }
                }
            }
            if let Some(q) = quant {
                matmul_into_buf(&mid, &q.r5.data, &mut rot, n, ff, threads);
                mid[..n * ff].copy_from_slice(&rot[..n * ff]);
                quantize_site(&mut mid, ff, &q.act, use_int, &mut qcodes, &mut qscales, threads);
            }
            project(&lw.wd, use_int, &mid, &qcodes, &qscales, n, &mut z, threads);
            add_assign(&mut x, &z);
        }

        // final norm + fp head
        rmsnorm_gamma_rows(&x, &model.lnf, &mut z, d, threads);
        let vocab = meta.vocab;
        let mut logits = vec![0.0f32; n * vocab];
        matmul_into_threads(&z, &model.head_t.data, &mut logits, n, d, vocab, threads);
        Ok(logits)
    }

    /// Pool bytes per stored token across all layers (K+V, scales
    /// included) — the serve-side KV memory/token number.
    pub fn kv_bytes_per_token(&self) -> usize {
        self.model.meta.n_layers * self.pool.bytes_per_token_layer()
    }

    /// Dense f32 cache bytes per stored token (`2·L·h·dh·4`) — what the
    /// artifact decode path keeps per token.
    pub fn dense_kv_bytes_per_token(&self) -> usize {
        let m = &self.model.meta;
        2 * m.n_layers * m.n_heads * m.d_head * 4
    }

    pub fn model(&self) -> &ServeModel {
        &self.model
    }

    pub fn pool(&self) -> &KvPool {
        &self.pool
    }

    pub fn queued(&self) -> usize {
        self.sched.len()
    }
}

// ---------------------------------------------------------- primitives

/// Greedy (temp ≤ 0) or temperature sampling over one logit row.
pub fn sample_token(logits: &[f32], temp: f32, rng: &mut Rng) -> i32 {
    if temp <= 0.0 {
        return argmax(logits) as i32;
    }
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&l| ((l - max) / temp).exp()).collect();
    let sum: f32 = exps.iter().sum();
    let mut u = rng.uniform() * sum;
    for (i, e) in exps.iter().enumerate() {
        u -= e;
        if u <= 0.0 {
            return i as i32;
        }
    }
    (exps.len() - 1) as i32
}

pub fn argmax(xs: &[f32]) -> usize {
    xs.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).map(|(i, _)| i).unwrap_or(0)
}

/// `out = rmsnorm(x) · γ` per `width`-row (eps 1e-5, matching both
/// `model.py::rmsnorm` and the host `rmsnorm_rows`).
fn rmsnorm_gamma_rows(x: &[f32], gamma: &[f32], out: &mut [f32], width: usize, threads: usize) {
    assert_eq!(gamma.len(), width);
    assert_eq!(x.len(), out.len());
    par::par_row_chunks_mut(out, width, 16, threads, |r0, chunk| {
        for (i, orow) in chunk.chunks_exact_mut(width).enumerate() {
            let row = &x[(r0 + i) * width..(r0 + i + 1) * width];
            let ms = row.iter().map(|v| v * v).sum::<f32>() / width as f32;
            let inv = 1.0 / (ms + 1e-5).sqrt();
            for ((o, &v), &g) in orow.iter_mut().zip(row).zip(gamma) {
                *o = v * inv * g;
            }
        }
    });
}

/// RoPE on one head row at a fixed position: each even/odd pair
/// `(x[2i], x[2i+1])` rotates by angle `pos·base^(-2i/dh)` — the exact
/// interleaving of `model.py::apply_rope`.
#[inline]
fn apply_rope_row(row: &mut [f32], cos: &[f32], sin: &[f32]) {
    debug_assert_eq!(row.len(), 2 * cos.len());
    for i2 in 0..cos.len() {
        let (c, s) = (cos[i2], sin[i2]);
        let x1 = row[2 * i2];
        let x2 = row[2 * i2 + 1];
        row[2 * i2] = x1 * c - x2 * s;
        row[2 * i2 + 1] = x1 * s + x2 * c;
    }
}

/// In-place per-row symmetric fake-quant (`fake_quant_rows` math).
fn fq_rows(data: &mut [f32], width: usize, s: &QuantScheme, threads: usize) {
    par::par_row_chunks_mut(data, width, 16, threads, |_r0, chunk| {
        let mut buf = Vec::with_capacity(width);
        for row in chunk.chunks_exact_mut(width) {
            let scale = row_scale_buf(row, s, &mut buf);
            fq_row_sym(row, scale, s);
        }
    });
}

/// Rotate `rows` rows of `dh` in place: `x ← x · R` (via scratch).
fn head_rotate(x: &mut Vec<f32>, scratch: &mut Vec<f32>, r: &Tensor, rows: usize, dh: usize, threads: usize) {
    matmul_into_buf(&x[..rows * dh], &r.data, scratch, rows, dh, threads);
    x[..rows * dh].copy_from_slice(&scratch[..rows * dh]);
}

/// `scratch[..m*k] = x @ R` for a square `k×k` rotation (overwrites).
fn matmul_into_buf(x: &[f32], r: &[f32], scratch: &mut Vec<f32>, m: usize, k: usize, threads: usize) {
    if scratch.len() < m * k {
        scratch.resize(m * k, 0.0);
    }
    scratch[..m * k].fill(0.0);
    matmul_into_threads(x, r, &mut scratch[..m * k], m, k, k, threads);
}

fn add_assign(x: &mut [f32], y: &[f32]) {
    for (a, b) in x.iter_mut().zip(y) {
        *a += b;
    }
}

#[inline]
fn silu(v: f32) -> f32 {
    v / (1.0 + (-v).exp())
}

#[inline]
fn gelu(v: f32) -> f32 {
    // tanh approximation, matching model.py::_gelu
    0.5 * v * (1.0 + (0.7978845608 * (v + 0.044715 * v * v * v)).tanh())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::tests_support::fake_llama_meta;
    use crate::tensor::hadamard::random_hadamard;

    fn fp_model() -> ServeModel {
        let meta = fake_llama_meta();
        let mut rng = Rng::new(0);
        let params = Params::init(&meta, &mut rng);
        ServeModel::from_params(&params, None).unwrap()
    }

    fn quant_model() -> ServeModel {
        let meta = fake_llama_meta();
        let mut rng = Rng::new(0);
        let params = Params::init(&meta, &mut rng);
        let spec = ServeQuantSpec::paper_default(
            random_hadamard(meta.d_head, &mut rng),
            random_hadamard(meta.d_head, &mut rng),
            random_hadamard(meta.d_ff, &mut rng),
        );
        ServeModel::from_params(&params, Some(spec)).unwrap()
    }

    fn requests() -> Vec<(Vec<i32>, usize)> {
        vec![
            (vec![1, 2, 3], 4),
            (vec![7], 5),
            (vec![4, 5], 3),
            (vec![9, 1, 0, 2], 2),
        ]
    }

    fn run_with(model: &ServeModel, kv: KvQuant, lanes: usize, threads: usize) -> Vec<Completion> {
        run_with_int(model, kv, lanes, threads, None)
    }

    fn run_with_int(
        model: &ServeModel,
        kv: KvQuant,
        lanes: usize,
        threads: usize,
        int_gemm: Option<bool>,
    ) -> Vec<Completion> {
        let cfg = ServeConfig {
            max_lanes: lanes,
            block_tokens: 4,
            kv_quant: kv,
            threads: Some(threads),
            int_gemm,
            ..ServeConfig::default()
        };
        let mut eng = Engine::new(model.clone(), &cfg).unwrap();
        for (toks, n) in requests() {
            eng.submit_tokens(toks, n, 0.0, 7).unwrap();
        }
        eng.run().unwrap()
    }

    #[test]
    fn fp_engine_completes_all_requests() {
        let model = fp_model();
        let done = run_with(&model, KvQuant::Fp, 2, 2);
        assert_eq!(done.len(), 4);
        for (c, (toks, n)) in done.iter().zip(requests()) {
            assert_eq!(c.prompt_len, toks.len());
            assert_eq!(c.tokens.len(), toks.len() + n);
            assert_eq!(&c.tokens[..toks.len()], &toks[..]);
            let vocab = model.meta.vocab as i32;
            assert!(c.tokens.iter().all(|&t| t >= 0 && t < vocab));
        }
    }

    #[test]
    fn streams_invariant_to_lanes_and_threads() {
        for model in [fp_model(), quant_model()] {
            let kv = if model.is_quantized() { KvQuant::Asym4 } else { KvQuant::Fp };
            // both GEMM paths must hold the invariance independently
            for int_gemm in [Some(true), Some(false)] {
                let base = run_with_int(&model, kv, 1, 1, int_gemm);
                for (lanes, threads) in [(2usize, 1usize), (4, 4), (3, 8)] {
                    let got = run_with_int(&model, kv, lanes, threads, int_gemm);
                    for (a, b) in base.iter().zip(&got) {
                        assert_eq!(a.tokens, b.tokens, "lanes={lanes} t={threads} int={int_gemm:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn int_gemm_escape_hatch_serves_both_paths() {
        let model = quant_model();
        let int = run_with_int(&model, KvQuant::Asym4, 2, 2, Some(true));
        let f32_path = run_with_int(&model, KvQuant::Asym4, 2, 2, Some(false));
        assert_eq!(int.len(), 4);
        assert_eq!(f32_path.len(), 4);
        for (a, b) in int.iter().zip(&f32_path) {
            // same requests, same prompt echo, same lengths; the token
            // tails may diverge (documented f32-summation-order delta)
            assert_eq!(a.id, b.id);
            assert_eq!(a.prompt_len, b.prompt_len);
            assert_eq!(a.tokens.len(), b.tokens.len());
            assert_eq!(a.tokens[..a.prompt_len], b.tokens[..b.prompt_len]);
        }
        // fp models ignore the flag entirely: identical streams
        let fp = fp_model();
        let fp_int = run_with_int(&fp, KvQuant::Fp, 2, 2, Some(true));
        let fp_f32 = run_with_int(&fp, KvQuant::Fp, 2, 2, Some(false));
        for (a, b) in fp_int.iter().zip(&fp_f32) {
            assert_eq!(a.tokens, b.tokens, "fp path must not depend on int_gemm");
        }
    }

    #[test]
    fn continuous_batching_admits_and_retires_without_draining() {
        let model = quant_model();
        let cfg = ServeConfig {
            max_lanes: 2,
            block_tokens: 4,
            kv_quant: KvQuant::Asym4,
            threads: Some(2),
            ..ServeConfig::default()
        };
        let mut eng = Engine::new(model, &cfg).unwrap();
        for (toks, n) in requests() {
            eng.submit_tokens(toks, n, 0.0, 7).unwrap();
        }
        let done = eng.run().unwrap();
        assert_eq!(done.len(), 4);
        assert_eq!(eng.stats.admitted, 4);
        assert_eq!(eng.stats.retired, 4);
        assert_eq!(eng.stats.peak_lanes, 2, "both lanes should have been busy");
        // every block returned to the pool
        assert_eq!(eng.pool().free_blocks(), eng.pool().max_blocks);
        // prefill was batched: prompt tokens processed without decode steps
        assert_eq!(eng.stats.prefill_tokens, 3 + 1 + 2 + 4);
        assert_eq!(eng.stats.decode_tokens, 4 + 5 + 3 + 2);
    }

    #[test]
    fn incompatible_act_scheme_falls_back_to_f32_path() {
        // reachable through the public ServeQuantSpec fields: an act
        // grid whose codes don't fit i8 (asymmetric here) must not
        // panic mid-decode — the engine keeps the f32 dequant GEMM
        let meta = fake_llama_meta();
        let mut rng = Rng::new(0);
        let params = Params::init(&meta, &mut rng);
        let spec = ServeQuantSpec {
            act: QuantScheme::kv4(),
            ..ServeQuantSpec::paper_default(
                random_hadamard(meta.d_head, &mut rng),
                random_hadamard(meta.d_head, &mut rng),
                random_hadamard(meta.d_ff, &mut rng),
            )
        };
        let model = ServeModel::from_params(&params, Some(spec)).unwrap();
        let cfg = ServeConfig { int_gemm: Some(true), threads: Some(2), ..ServeConfig::default() };
        let mut eng = Engine::new(model, &cfg).unwrap();
        assert!(!eng.int_gemm(), "asymmetric act grid must fall back to the f32 GEMM");
        eng.submit_tokens(vec![1, 2], 3, 0.0, 7).unwrap();
        assert_eq!(eng.run().unwrap().len(), 1);
    }

    #[test]
    fn sampling_with_temperature_stays_in_vocab() {
        let model = fp_model();
        let cfg = ServeConfig { threads: Some(1), kv_quant: KvQuant::Fp, ..ServeConfig::default() };
        let mut eng = Engine::new(model.clone(), &cfg).unwrap();
        eng.submit_tokens(vec![3, 4], 5, 0.9, 11).unwrap();
        let done = eng.run().unwrap();
        assert_eq!(done[0].tokens.len(), 7);
        assert!(done[0].tokens.iter().all(|&t| (t as usize) < model.meta.vocab));
    }

    #[test]
    fn submit_validation() {
        let model = fp_model();
        let mut eng = Engine::new(model, &ServeConfig::default()).unwrap();
        assert!(eng.submit_tokens(vec![], 2, 0.0, 0).is_err(), "empty prompt");
        assert!(eng.submit_tokens(vec![1], 0, 0.0, 0).is_err(), "zero tokens");
        assert!(eng.submit_tokens(vec![99], 2, 0.0, 0).is_err(), "token out of vocab");
        assert!(eng.submit_tokens(vec![1; 7], 4, 0.0, 0).is_err(), "exceeds cache");
        assert!(eng.submit_tokens(vec![1, 2], 3, 0.0, 0).is_ok());
    }

    #[test]
    fn quant_model_packs_weights() {
        let (fp, q) = (fp_model(), quant_model());
        assert!(q.weight_bytes() * 4 < fp.weight_bytes(), "{} vs {}", q.weight_bytes(), fp.weight_bytes());
        assert_eq!(fp.weight_bytes(), fp.dense_weight_bytes());
        assert_eq!(q.dense_weight_bytes(), fp.dense_weight_bytes());
    }

    #[test]
    fn greedy_sampling_helpers() {
        let logits = vec![0.0, 3.0, 1.0];
        assert_eq!(argmax(&logits), 1);
        let mut rng = Rng::new(0);
        assert_eq!(sample_token(&logits, 0.0, &mut rng), 1);
    }
}
