//! The native INT4 serving engine: continuous-batching autoregressive
//! decode over packed INT4 weights ([`Int4Weight`]) and the paged 4-bit
//! KV pool ([`KvPool`]), entirely on the host kernel layer — no PJRT
//! artifacts at request time.
//!
//! The per-token math mirrors `python/compile/model.py::decode_step`
//! (same op order: norm → act-fake-quant → QKV → RoPE → R3 →
//! KV-quantize-on-append → fused attention → R4 → Wo → FFN with R5),
//! so with 4-bit KV the dequantized cache holds exactly what the quant
//! decode artifact's dense cache holds, and at `temp = 0` the engine
//! reproduces the artifact `Generator` greedy stream modulo f32
//! summation order (pinned by the artifact-parity integration test).
//!
//! **Batching model.** Each decode iteration stacks every live lane's
//! current token into one `(N, d)` activation block so the weight
//! matrices are traversed once per *iteration*, not once per lane —
//! that's the continuous-batching win the serve bench measures. Prompt
//! prefill runs the same forward with one row per prompt position
//! (closing the ROADMAP prefill-batching item). All row-level kernels
//! (norm, fake-quant, GEMM, attention) are per-row independent with
//! fixed accumulation order, so **a lane's token stream is bitwise
//! independent of which other lanes happen to share its batch** — a
//! 1-lane engine and a 16-lane engine produce identical completions
//! (pinned by tests) — and independent of `KURTAIL_THREADS`.
//!
//! **Prefix sharing.** Admission consults a [`PrefixIndex`] over the
//! refcounted KV pool: a request whose prompt shares a prefix with a
//! resident sequence maps its full shared blocks onto the donor's by
//! refcount bump and copies only the partial tail block (copy-on-write)
//! — see `serve/kvcache.rs`. Because the per-token per-head 4-bit
//! scheme makes block content a pure function of the token prefix,
//! shared blocks are bitwise the blocks the lane would have computed,
//! so token streams are identical with `KURTAIL_PREFIX_SHARE=0`
//! (`ServeConfig::prefix_share = Some(false)`). Only the *computed*
//! prompt positions run the prefill forward; `EngineStats::
//! prefix_shared_tokens` counts the skipped ones.
//!
//! **Chunked prefill.** A long prompt no longer runs its whole `(T, d)`
//! activation block through one forward: prefill advances at most
//! `ServeConfig::prefill_chunk` positions (`KURTAIL_PREFILL_CHUNK`,
//! default 32, `0` = unchunked) per engine step, interleaved with the
//! live lanes' decode iterations — a long admission stalls nobody, and
//! the [`DecodeScratch`] peak is bounded by the chunk size instead of
//! the longest prompt. Non-final chunks skip the logits head entirely;
//! the final chunk computes it and samples the first token. Row-level
//! kernels are per-row independent with fixed accumulation order, so
//! chunking is bitwise invisible to every stream (pinned by tests).
//!
//! **Integer GEMM path.** For quantized models the activation
//! fake-quant before each packed GEMM produces int8 *codes* + per-row
//! scales (`serve/qact.rs`) instead of fake-quantized f32 values, and
//! the GEMM accumulates in i32 (`Int4Weight::matmul_i8_into`), folding
//! `act_scale · weight_group_scale` once per (row, group). Codes are
//! identical to the fake-quant grid, so only in-group f32 summation
//! order distinguishes the paths; both keep the batching/threading
//! invariants above. `KURTAIL_INT_GEMM=0` (or
//! `ServeConfig::int_gemm = Some(false)`) restores the f32 dequant GEMM.
//!
//! **Zero-allocation hot path.** Every per-iteration buffer lives in the
//! engine-owned [`DecodeScratch`] arena (`serve/scratch.rs`), rotation
//! matrices and the logits head are pre-packed at model build
//! ([`crate::tensor::matmul::PackedB`]), packed weights optionally carry
//! a cached i8 panel (`Int4Weight::build_panels`, budgeted by
//! `ServeConfig::panel_cache` / `KURTAIL_PANEL_CACHE`), and lane/KV
//! bookkeeping reserves its admission-time worst case — so a
//! steady-state decode `step()` performs zero heap allocations (pinned
//! by `tests/serve_scratch.rs`). All of it is bitwise invisible:
//! `KURTAIL_ARENA=0` re-allocates everything per iteration (the PR-3
//! profile) and produces identical token streams. The arena also decays
//! back to the live-lane peak after an idle window
//! (`ServeConfig::scratch_decay` / `KURTAIL_SCRATCH_DECAY`), so a
//! one-off long prompt no longer pins peak scratch forever.
//!
//! **Fused GEMM epilogues.** The packed GEMMs compute column-major
//! `(n × m)` natively; PR-4 flipped every output into row-major with a
//! single-threaded scalar loop — at 16 lanes × d_ff the longest serial
//! stretch of the decode iteration. The arena path now routes each GEMM
//! by what consumes it: wo/wd feed the **fused column-major residual
//! add**, wg/wu stay column-major through the (elementwise, hence
//! layout-agnostic) silu-gate and cross to row-major with one
//! **parallel blocked transpose** right where the R5 rotation (or wd's
//! lhs) genuinely needs rows, the logits head emits column-major and is
//! consumed by **column-aware argmax/sampling**, and only wq/wk/wv —
//! whose consumers (RoPE, KV append, attention) are inherently
//! row-major — pay a transpose at all, now the parallel blocked one.
//! Every epilogue writes bitwise-identical values per element, so
//! `ServeConfig::fused_epilogue = Some(false)` (or
//! `KURTAIL_FUSED_EPILOGUE=0`), which restores the PR-4 serial-flip
//! path for A/B (`epilogue_fused_speedup` in `BENCH_serve.json`),
//! produces identical token streams.
//!
//! **Parallel runtime.** Every kernel call below pins the
//! `util::par` backend from `ServeConfig::par_backend` (falling back to
//! `KURTAIL_PAR`): the work-stealing default rebalances skewed batches
//! (mixed prefill/decode rows, panel-cached vs uncached layers), the
//! static scoped-thread chunker stays available for A/B. Chunk grids
//! are fixed per backend and kernels are row-independent, so token
//! streams are bitwise identical across backends too.

use std::time::Instant;

use anyhow::Result;

use crate::calib::ByteTokenizer;
use crate::config::{KvQuant, QuantScheme};
use crate::model::Params;
use crate::obs::{
    self, EngineObs, RequestSpan, N_PHASES, PHASE_ACT_QUANT, PHASE_ATTENTION, PHASE_EPILOGUE,
    PHASE_GEMM, PHASE_SAMPLING,
};
use crate::quant::fakequant::{fq_row_sym, row_scale_buf};
use crate::runtime::ConfigMeta;
use crate::tensor::matmul::{matmul_into_threads, transpose_into_on, PackedB};
use crate::tensor::Tensor;
use crate::util::par::{self, num_threads, ParBackend};
use crate::util::Rng;

use super::error::ServeError;
use super::int4::{panel_cache_budget, GemmScratch, Int4Weight};
use super::kvcache::{KvPool, PrefixIndex, SeqKv};
use super::qact::{int_gemm_enabled, quantize_rows_into, quantize_rows_scratch_on, scheme_fits_i8};
use super::scheduler::{LaneSnapshot, Priority, QueuedRequest, Scheduler, DEFAULT_HEAD_SKIPS};
use super::scratch::{arena_enabled, scratch_decay_default, DecodeScratch};

/// `KURTAIL_FUSED_EPILOGUE` escape hatch: the fused column-major /
/// parallel-transpose GEMM epilogues are on by default (arena mode);
/// set `KURTAIL_FUSED_EPILOGUE=0` to restore the PR-4 serial-flip
/// epilogue (A/B debugging, the `epilogue_fused_speedup` bench
/// baseline). Read per engine build, like `KURTAIL_ARENA`.
pub fn fused_epilogue_enabled() -> bool {
    fused_flag(std::env::var("KURTAIL_FUSED_EPILOGUE").ok().as_deref())
}

/// Parse rule behind [`fused_epilogue_enabled`]: unset → on, `0` → off,
/// anything else → on. Split out so the rule itself is testable.
fn fused_flag(var: Option<&str>) -> bool {
    var.map(|v| v.trim() != "0").unwrap_or(true)
}

/// `KURTAIL_PREFIX_SHARE` escape hatch: prefix sharing over the
/// refcounted KV pool is on by default; set `KURTAIL_PREFIX_SHARE=0`
/// to give every lane private blocks (A/B debugging, the bitwise
/// sharing-transparency property tests). Read per engine build.
pub fn prefix_share_enabled() -> bool {
    fused_flag(std::env::var("KURTAIL_PREFIX_SHARE").ok().as_deref())
}

/// `KURTAIL_PREEMPT` escape hatch: KV-pressure lane preemption with
/// transparent resume is on by default; set `KURTAIL_PREEMPT=0` to
/// restore the shed-only behaviour (queued requests wait or shed, live
/// lanes are never disturbed). Read per engine build.
pub fn preempt_enabled() -> bool {
    fused_flag(std::env::var("KURTAIL_PREEMPT").ok().as_deref())
}

/// Default KV-pressure high watermark: preemption may fire only once
/// committed blocks reach this fraction of the (non-withheld) pool.
pub const DEFAULT_KV_HIGH_WATER: f32 = 0.85;

/// `KURTAIL_KV_HIGH_WATER` fallback for [`ServeConfig::kv_high_water`]:
/// unset (or out of `[0, 1]`) → [`DEFAULT_KV_HIGH_WATER`].
pub fn kv_high_water_default() -> f32 {
    water_var(std::env::var("KURTAIL_KV_HIGH_WATER").ok().as_deref())
}

/// Parse rule behind [`kv_high_water_default`], split out for tests.
fn water_var(var: Option<&str>) -> f32 {
    var.and_then(|v| v.trim().parse::<f32>().ok())
        .filter(|w| (0.0..=1.0).contains(w))
        .unwrap_or(DEFAULT_KV_HIGH_WATER)
}

/// Default prefill chunk: positions one admission may push through the
/// forward per engine step before yielding to the decode batch.
pub const DEFAULT_PREFILL_CHUNK: usize = 32;

/// `KURTAIL_PREFILL_CHUNK` fallback for [`ServeConfig::prefill_chunk`]:
/// unset (or unparseable) → [`DEFAULT_PREFILL_CHUNK`], `0` → unchunked
/// (the whole prompt in one forward, the pre-chunking profile).
pub fn prefill_chunk_default() -> usize {
    chunk_var(std::env::var("KURTAIL_PREFILL_CHUNK").ok().as_deref())
}

/// Parse rule behind [`prefill_chunk_default`], split out for tests.
fn chunk_var(var: Option<&str>) -> usize {
    var.and_then(|v| v.trim().parse().ok()).unwrap_or(DEFAULT_PREFILL_CHUNK)
}

/// RoPE base shared by every preset (`ModelConfig.rope_base`); the
/// manifest does not carry it because no config overrides it.
const ROPE_BASE: f32 = 10000.0;

/// Per-forward phase lap accumulator: at each phase boundary in
/// [`Engine::forward`], `lap(phase)` adds the time since the previous
/// boundary to that phase's stack-local bucket; `flush` records each
/// accumulated total into its histogram once per forward. Disabled
/// (`on = false`) it is a no-op — no clock reads, no recording — so the
/// `KURTAIL_OBS=0` A/B run measures the uninstrumented path. All state
/// is on the stack and recording is atomic adds, preserving the
/// zero-alloc decode contract.
struct PhaseClock {
    on: bool,
    last: Instant,
    acc: [u64; N_PHASES],
}

impl PhaseClock {
    #[inline]
    fn start(on: bool) -> Self {
        Self { on, last: Instant::now(), acc: [0; N_PHASES] }
    }

    #[inline]
    fn lap(&mut self, phase: usize) {
        if self.on {
            let now = Instant::now();
            self.acc[phase] += now.duration_since(self.last).as_nanos() as u64;
            self.last = now;
        }
    }

    #[inline]
    fn flush(self, obs: &EngineObs) {
        if self.on {
            for (hist, ns) in obs.phases.iter().zip(self.acc) {
                hist.record_ns(ns);
            }
        }
    }
}

// ------------------------------------------------------------- model

/// Online quantization spec for a quantized serving model: the weight
/// grid used at pack time, the activation fake-quant scheme, and the
/// online rotations (R3/R4/R5) the quant decode graph applies.
#[derive(Clone)]
pub struct ServeQuantSpec {
    pub weight: QuantScheme,
    pub act: QuantScheme,
    pub r3: Tensor,
    pub r4: Tensor,
    pub r5: Tensor,
}

impl ServeQuantSpec {
    /// Paper-default W4/A4 spec with the given online rotations.
    pub fn paper_default(r3: Tensor, r4: Tensor, r5: Tensor) -> Self {
        Self { weight: QuantScheme::weight4(), act: QuantScheme::act4(), r3, r4, r5 }
    }
}

/// One linear's serving-time storage. Dense f32 weights can carry a
/// pre-packed B-panel copy ([`PackedB`]) so the arena path never
/// re-packs (or allocates) inside the decode loop. The copy is 2× the
/// fp weight memory, so it is built lazily by
/// [`ServeModel::prepack`] — only when an engine that will
/// actually read it (arena mode) is constructed.
#[derive(Clone)]
enum LinW {
    F32 { t: Tensor, packed: Option<PackedB> },
    Int4(Int4Weight),
}

impl LinW {
    fn bytes(&self) -> usize {
        match self {
            LinW::F32 { t, .. } => t.numel() * 4,
            LinW::Int4(w) => w.bytes(),
        }
    }

    fn dense_bytes(&self) -> usize {
        match self {
            LinW::F32 { t, .. } => t.numel() * 4,
            LinW::Int4(w) => w.dense_bytes(),
        }
    }
}

/// How one projection's output leaves the GEMM (see the module docs and
/// `rust/README.md` §Output layouts).
#[derive(Clone, Copy, PartialEq, Eq)]
enum Epilogue {
    /// `(n × m)` column-major, no flip — the next op is column-aware.
    ColMajor,
    /// Row-major via the parallel blocked transpose — the next op
    /// (RoPE, KV append) genuinely needs rows.
    RowMajor,
    /// Row-major via the PR-4 single-threaded scalar flip — the
    /// `fused_epilogue = false` A/B baseline.
    SerialFlip,
}

/// One serving projection: the integer path consumes the block's shared
/// int8 codes + per-row scales; the f32 path the (already fake-quantized)
/// dense activations. Split out so every GEMM site in `forward` stays a
/// one-liner per weight. Overwrites `out` in the layout `epi` names.
/// `arena = false` reproduces the PR-3 per-call allocation profile
/// (bench A/B + equality tests; always the serial flip, like PR-3/PR-4);
/// results are bitwise identical for every combination.
#[allow(clippy::too_many_arguments)]
fn project(
    w: &LinW,
    use_int: bool,
    arena: bool,
    epi: Epilogue,
    z: &[f32],
    codes: &[i8],
    scales: &[f32],
    m: usize,
    out: &mut [f32],
    threads: usize,
    backend: ParBackend,
    gemm: &mut GemmScratch,
) {
    match w {
        LinW::Int4(w) => {
            if use_int {
                match (arena, epi) {
                    (true, Epilogue::ColMajor) => {
                        w.matmul_i8_colmajor_scratch(codes, scales, m, out, threads, backend, gemm)
                    }
                    (true, Epilogue::RowMajor) => w.matmul_i8_scratch_on(codes, scales, m, out, threads, backend, gemm),
                    (true, Epilogue::SerialFlip) => {
                        w.matmul_i8_scratch_serial(codes, scales, m, out, threads, backend, gemm)
                    }
                    (false, _) => w.matmul_i8_into(codes, scales, m, out, threads),
                }
            } else {
                match (arena, epi) {
                    (true, Epilogue::ColMajor) => w.matmul_colmajor_scratch(z, m, out, threads, backend, gemm),
                    (true, Epilogue::RowMajor) => w.matmul_into_scratch_on(z, m, out, threads, backend, gemm),
                    (true, Epilogue::SerialFlip) => w.matmul_into_scratch_serial(z, m, out, threads, backend, gemm),
                    (false, _) => w.matmul_into(z, m, out, threads),
                }
            }
        }
        LinW::F32 { t, packed } => {
            // fp models never quantize activations, so the integer path
            // cannot reach a dense weight. Hard assert (all builds): on
            // the int path `z` holds *unquantized* activations, so
            // falling through here would silently compute off-grid.
            assert!(!use_int, "integer GEMM requires packed int4 weights");
            match packed {
                // arena engines pre-pack at construction; the fallback
                // (pack per call) is bitwise identical either way
                Some(p) if arena => match epi {
                    Epilogue::ColMajor => p.matmul_colmajor_on(backend, z, &t.data, out, m, threads),
                    _ => p.matmul_overwrite_on(backend, z, &t.data, out, m, threads),
                },
                _ => {
                    // legacy (non-arena) engines never request a
                    // column-major output; the consumer would misread it
                    assert!(epi != Epilogue::ColMajor, "column-major output needs a pre-packed weight");
                    out.fill(0.0);
                    matmul_into_threads(z, &t.data, out, m, t.shape[0], t.shape[1], threads);
                }
            }
        }
    }
}

/// Activation quantization for one GEMM site: the integer path reads
/// `data` into int8 codes + per-row scales (leaving `data` untouched),
/// the f32 path fake-quantizes `data` in place — the single spot where
/// the two paths' pre-GEMM step lives, so every site in `forward` stays
/// in lockstep. The arena path lends per-chunk selection scratch from
/// `bufs`; the legacy path allocates per call (PR-3 profile).
#[allow(clippy::too_many_arguments)]
fn quantize_site(
    data: &mut [f32],
    width: usize,
    act: &QuantScheme,
    use_int: bool,
    arena: bool,
    codes: &mut [i8],
    scales: &mut [f32],
    threads: usize,
    backend: ParBackend,
    bufs: &mut [Vec<f32>],
) {
    if use_int {
        if arena {
            quantize_rows_scratch_on(backend, data, width, act, codes, scales, threads, bufs);
        } else {
            quantize_rows_into(data, width, act, codes, scales, threads);
        }
    } else if arena {
        fq_rows_scratch(data, width, act, threads, backend, bufs);
    } else {
        fq_rows(data, width, act, threads);
    }
}

#[derive(Clone)]
struct LayerW {
    ln1: Vec<f32>,
    ln2: Vec<f32>,
    wq: LinW,
    wk: LinW,
    wv: LinW,
    wo: LinW,
    /// `None` for the phi arch (single-branch FFN).
    wg: Option<LinW>,
    wu: LinW,
    wd: LinW,
}

impl LayerW {
    /// Every linear of the layer in canonical order
    /// (wq, wk, wv, wo, wg?, wu, wd) — the single definition the byte
    /// accounting and the panel-cache budget walk share.
    fn linears(&self) -> impl Iterator<Item = &LinW> {
        [Some(&self.wq), Some(&self.wk), Some(&self.wv), Some(&self.wo), self.wg.as_ref(), Some(&self.wu), Some(&self.wd)]
            .into_iter()
            .flatten()
    }

    /// [`Self::linears`], mutably (same order).
    fn linears_mut(&mut self) -> impl Iterator<Item = &mut LinW> {
        [&mut self.wq, &mut self.wk, &mut self.wv, &mut self.wo]
            .into_iter()
            .chain(self.wg.as_mut())
            .chain([&mut self.wu, &mut self.wd])
    }
}

/// Pre-packed online rotations (arena path: no per-call B re-pack).
#[derive(Clone)]
struct RotsPacked {
    r3: PackedB,
    r4: PackedB,
    r5: PackedB,
}

/// A model prepared for serving: embedding/head in f32, transformer
/// linears packed INT4 (quant) or dense f32 (fp), RoPE tables
/// precomputed to `max_pos`. The logits head, the online rotations and
/// any dense-f32 linears can additionally carry a [`PackedB`] copy —
/// built lazily by [`Self::prepack`] (arena-mode `Engine::new` calls
/// it) so only engines whose decode loop reads the panels pay the
/// extra memory.
#[derive(Clone)]
pub struct ServeModel {
    pub meta: ConfigMeta,
    embed: Tensor,
    head_t: Tensor,
    head_packed: Option<PackedB>,
    lnf: Vec<f32>,
    layers: Vec<LayerW>,
    quant: Option<ServeQuantSpec>,
    rots_packed: Option<RotsPacked>,
    rope_cos: Vec<f32>,
    rope_sin: Vec<f32>,
    /// Maximum cache position + 1 a request may reach.
    pub max_pos: usize,
}

impl ServeModel {
    /// Build a serving model from a parameter store. `quant = Some(_)`
    /// packs every transformer linear to INT4 on the spec's weight grid
    /// (this *is* the serving-side weight quantizer — hand it the fused,
    /// un-fake-quantized weights; RTN-quantized weights are a fixpoint).
    /// Embedding and head stay f32 (standard practice).
    pub fn from_params(params: &Params, quant: Option<ServeQuantSpec>) -> Result<Self> {
        let meta = params.meta.clone();
        anyhow::ensure!(
            matches!(meta.arch.as_str(), "llama" | "phi"),
            "serve engine supports llama/phi archs, not '{}'",
            meta.arch
        );
        let (d, h, dh) = (meta.d_model, meta.n_heads, meta.d_head);
        anyhow::ensure!(d == h * dh, "d_model {d} != n_heads*d_head");
        anyhow::ensure!(dh % 2 == 0, "RoPE needs an even d_head, got {dh}");
        if let Some(q) = &quant {
            anyhow::ensure!(q.r3.shape == vec![dh, dh], "r3 must be ({dh},{dh})");
            anyhow::ensure!(q.r4.shape == vec![dh, dh], "r4 must be ({dh},{dh})");
            anyhow::ensure!(
                q.r5.shape == vec![meta.d_ff, meta.d_ff],
                "r5 must be ({0},{0})",
                meta.d_ff
            );
        }
        let pack = |w: Tensor| -> LinW {
            match &quant {
                Some(q) => LinW::Int4(Int4Weight::pack(&w, &q.weight)),
                // the PackedB copy is deferred to prepack (2× fp
                // memory — only arena engines pay it)
                None => LinW::F32 { t: w, packed: None },
            }
        };
        let mut layers = Vec::with_capacity(meta.n_layers);
        for l in 0..meta.n_layers {
            layers.push(LayerW {
                ln1: params.get("ln1").index_axis0(l).data,
                ln2: params.get("ln2").index_axis0(l).data,
                wq: pack(params.get("wq").index_axis0(l)),
                wk: pack(params.get("wk").index_axis0(l)),
                wv: pack(params.get("wv").index_axis0(l)),
                wo: pack(params.get("wo").index_axis0(l)),
                wg: if params.has("wg") { Some(pack(params.get("wg").index_axis0(l))) } else { None },
                wu: pack(params.get("wu").index_axis0(l)),
                wd: pack(params.get("wd").index_axis0(l)),
            });
        }
        let max_pos = meta.seq_len;
        // rope_tables(): inv_i = base^(-2i/dh), ang = pos · inv
        let dh2 = dh / 2;
        let inv: Vec<f32> =
            (0..dh2).map(|i2| ROPE_BASE.powf(-((2 * i2) as f32) / dh as f32)).collect();
        let mut rope_cos = vec![0.0f32; max_pos * dh2];
        let mut rope_sin = vec![0.0f32; max_pos * dh2];
        for p in 0..max_pos {
            for (i2, &iv) in inv.iter().enumerate() {
                let ang = p as f32 * iv;
                rope_cos[p * dh2 + i2] = ang.cos();
                rope_sin[p * dh2 + i2] = ang.sin();
            }
        }
        Ok(Self {
            embed: params.get("embed").clone(),
            head_t: params.get("head").t(),
            head_packed: None,
            lnf: params.get("lnf").data.clone(),
            meta,
            layers,
            quant,
            rots_packed: None,
            rope_cos,
            rope_sin,
            max_pos,
        })
    }

    /// Build i8 panel caches over the packed linears, greedy-fit in
    /// layer order (wq, wk, wv, wo, wg, wu, wd per layer): each weight
    /// is cached iff its panel still fits the remaining budget, so a
    /// smaller later weight may be cached after a larger one was
    /// rejected. The budget is a hard cap: panels a previous
    /// (larger-budget) build left on this model are dropped when they
    /// no longer fit, so re-entry with any budget converges to the same
    /// greedy-fit result. Returns the bytes cached. Idempotent at a
    /// fixed budget; no-op for fp models.
    pub fn build_panel_cache(&mut self, budget: usize) -> usize {
        let mut used = 0usize;
        for w in self.layers.iter_mut().flat_map(LayerW::linears_mut) {
            if let LinW::Int4(iw) = w {
                let pb = iw.panel_bytes();
                if used.saturating_add(pb) <= budget {
                    iw.build_panels(); // no-op when already cached
                    used += pb;
                } else {
                    iw.drop_panels(); // enforce the cap on warm models
                }
            }
        }
        used
    }

    /// Bytes currently held by built i8 panels across all linears.
    pub fn panel_cache_bytes(&self) -> usize {
        self.layers
            .iter()
            .flat_map(LayerW::linears)
            .map(|w| match w {
                LinW::Int4(iw) if iw.has_panels() => iw.panel_bytes(),
                _ => 0,
            })
            .sum()
    }

    /// Build the [`PackedB`] copy of every constant GEMM operand the
    /// arena decode path multiplies against — the logits head, the
    /// online rotations (quant models), and any dense-f32 linears —
    /// so that path never re-packs B per call. Idempotent; returns the
    /// packed bytes held afterwards. Arena-mode `Engine::new` invokes
    /// this; legacy-mode engines (`KURTAIL_ARENA=0`) skip it and pay
    /// the per-call re-pack instead, keeping their resident memory at
    /// the PR-3 profile.
    pub fn prepack(&mut self) -> usize {
        let d = self.meta.d_model;
        let head = self
            .head_packed
            .get_or_insert_with(|| PackedB::pack(&self.head_t.data, d, self.meta.vocab));
        let mut bytes = head.bytes();
        if let Some(q) = &self.quant {
            let dh = self.meta.d_head;
            let ff = self.meta.d_ff;
            let rots = self.rots_packed.get_or_insert_with(|| RotsPacked {
                r3: PackedB::pack(&q.r3.data, dh, dh),
                r4: PackedB::pack(&q.r4.data, dh, dh),
                r5: PackedB::pack(&q.r5.data, ff, ff),
            });
            bytes += rots.r3.bytes() + rots.r4.bytes() + rots.r5.bytes();
        }
        for w in self.layers.iter_mut().flat_map(LayerW::linears_mut) {
            if let LinW::F32 { t, packed } = w {
                let p = packed
                    .get_or_insert_with(|| PackedB::pack(&t.data, t.shape[0], t.shape[1]));
                bytes += p.bytes();
            }
        }
        bytes
    }

    pub fn is_quantized(&self) -> bool {
        self.quant.is_some()
    }

    /// Serving-time bytes of the transformer linears (packed or dense).
    pub fn weight_bytes(&self) -> usize {
        self.layers.iter().map(layer_bytes).sum()
    }

    /// Dense-f32 bytes of the same linears (the compression baseline).
    pub fn dense_weight_bytes(&self) -> usize {
        self.layers.iter().flat_map(LayerW::linears).map(LinW::dense_bytes).sum()
    }
}

fn layer_bytes(l: &LayerW) -> usize {
    l.linears().map(LinW::bytes).sum()
}

// ------------------------------------------------------------- engine

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Maximum concurrently decoding sequences.
    pub max_lanes: usize,
    /// Tokens per KV block.
    pub block_tokens: usize,
    /// KV pool capacity in blocks; `0` sizes the pool so `max_lanes`
    /// full-length sequences always fit.
    pub max_blocks: usize,
    pub kv_quant: KvQuant,
    /// Thread budget override (`None` = `KURTAIL_THREADS` / host cores).
    pub threads: Option<usize>,
    /// Integer-accumulator GEMM for quantized models: `None` follows the
    /// `KURTAIL_INT_GEMM` env escape hatch (on unless set to `0`),
    /// `Some(_)` pins it (benches A/B the two paths this way). Ignored
    /// for fp models (which never quantize activations) and for act
    /// schemes whose codes don't fit i8 (asymmetric or > 8 bits — those
    /// fall back to the f32 dequant GEMM).
    pub int_gemm: Option<bool>,
    /// i8 panel-cache byte budget for the packed weights: `None`
    /// follows `KURTAIL_PANEL_CACHE` (unset → unbounded), `Some(0)`
    /// disables the cache, `Some(bytes)` caps it. Panels cost 2× the
    /// packed codes per cached weight and are bitwise transparent.
    pub panel_cache: Option<usize>,
    /// Persistent decode scratch arena: `None` follows `KURTAIL_ARENA`
    /// (unset → on). `Some(false)` re-allocates every per-iteration
    /// buffer — the PR-3 allocation profile, kept for bench A/B and the
    /// fresh-alloc-vs-arena equality tests. Token streams are bitwise
    /// identical either way.
    pub arena: Option<bool>,
    /// Parallel-runtime backend for every kernel the engine invokes:
    /// `None` follows `KURTAIL_PAR` (work-stealing unless `static`).
    /// Token streams are bitwise identical across backends.
    pub par_backend: Option<ParBackend>,
    /// Fused column-major / parallel-transpose GEMM epilogues (arena
    /// mode only): `None` follows `KURTAIL_FUSED_EPILOGUE` (unset → on),
    /// `Some(false)` restores the PR-4 serial-flip epilogue — the
    /// `epilogue_fused_speedup` bench baseline. Bitwise identical
    /// streams either way.
    pub fused_epilogue: Option<bool>,
    /// Scratch-arena high-water decay: idle forwards before the arena
    /// shrinks to the live-lane peak. `None` follows
    /// `KURTAIL_SCRATCH_DECAY` (unset → 64), `Some(0)` disables decay.
    pub scratch_decay: Option<usize>,
    /// Admission-queue bound: submits past `queue_cap` waiting requests
    /// shed with [`ServeError::QueueFull`] (the daemon's backpressure
    /// signal). `0` = unbounded — the in-process/library default, where
    /// the caller owns its own submission loop.
    pub queue_cap: usize,
    /// Head-of-line bypass budget: a queued head whose KV reservation
    /// doesn't fit may be bypassed by smaller requests at most this
    /// many times before admission pauses for it (starvation bound —
    /// see `scheduler.rs`).
    pub max_head_skips: usize,
    /// Telemetry recording (`crate::obs`): `None` follows `KURTAIL_OBS`
    /// (unset → on), `Some(false)` skips every clock read and histogram
    /// record — the bench A/B baseline for the `obs_overhead` gate.
    /// Bitwise invisible to token streams either way.
    pub obs: Option<bool>,
    /// Prefix sharing over the refcounted KV pool: `None` follows
    /// `KURTAIL_PREFIX_SHARE` (unset → on), `Some(false)` gives every
    /// lane private blocks. Shared blocks are bitwise the blocks the
    /// lane would have computed, so streams are identical either way.
    pub prefix_share: Option<bool>,
    /// Prefill chunk: at most this many prompt positions run through
    /// the forward per engine step, interleaved with live decodes.
    /// `None` follows `KURTAIL_PREFILL_CHUNK` (unset →
    /// [`DEFAULT_PREFILL_CHUNK`]), `Some(0)` prefills each prompt in
    /// one forward (the pre-chunking profile). Bitwise invisible.
    pub prefill_chunk: Option<usize>,
    /// KV-pressure lane preemption: when the best queued request's
    /// reservation cannot fit and pool occupancy is past the high
    /// watermark, a live lane of a *strictly lower* priority class
    /// (newest first) is snapshotted, its whole reservation released,
    /// and the snapshot requeued at the front of its class — the stream
    /// resumes byte-identically after re-prefill. `None` follows
    /// `KURTAIL_PREEMPT` (unset → on); `Some(false)` restores the
    /// shed-only behaviour.
    pub preempt: Option<bool>,
    /// Occupancy fraction of the (non-withheld) pool that arms
    /// preemption. `None` follows `KURTAIL_KV_HIGH_WATER` (unset →
    /// [`DEFAULT_KV_HIGH_WATER`]). `1.0` preempts only when the pool is
    /// fully committed; values near `0` preempt as soon as the best
    /// head fails to fit.
    pub kv_high_water: Option<f32>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            max_lanes: 4,
            block_tokens: 16,
            max_blocks: 0,
            kv_quant: KvQuant::Asym4,
            threads: None,
            int_gemm: None,
            panel_cache: None,
            arena: None,
            par_backend: None,
            fused_epilogue: None,
            scratch_decay: None,
            queue_cap: 0,
            max_head_skips: DEFAULT_HEAD_SKIPS,
            obs: None,
            prefix_share: None,
            prefill_chunk: None,
            preempt: None,
            kv_high_water: None,
        }
    }
}

/// A finished request: the full token stream (prompt included) and its
/// byte-decoded text.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: usize,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    pub text: String,
    /// Where the request spent its life (queue wait / prefill / decode);
    /// all-zero timings when the engine runs with `KURTAIL_OBS=0`.
    pub span: RequestSpan,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    pub steps: u64,
    /// Prompt positions actually run through the prefill forward
    /// (prefix-shared positions are skipped, not counted here).
    pub prefill_tokens: u64,
    pub decode_tokens: u64,
    /// Admissions that attached ≥ 1 prefix-shared token.
    pub prefix_hits: u64,
    /// Prompt positions served from shared blocks instead of compute.
    pub prefix_shared_tokens: u64,
    /// Bounded prefill forwards run (≥ 1 per admission; more when a
    /// prompt spans multiple `prefill_chunk` windows).
    pub prefill_chunks: u64,
    pub admitted: u64,
    /// Lanes taken out of flight for any reason — completion, EOS stop,
    /// or cancellation (each one returned its whole block reservation).
    pub retired: u64,
    /// Lanes retired early by their stop token (subset of `retired`).
    pub eos_retired: u64,
    /// Requests rejected by load shedding: queue at capacity, an
    /// impossible-to-fit reservation, or a drain (never admitted).
    pub shed: u64,
    /// Requests canceled after acceptance — client disconnect, explicit
    /// cancel, or deadline expiry (queued or live).
    pub canceled: u64,
    /// Live lanes snapshotted and requeued under KV pressure (each one
    /// released its whole reservation; not a failure — the stream
    /// resumes byte-identically on re-admission).
    pub preempted: u64,
    /// Preempted (or restart-orphaned) lanes re-admitted and continued.
    /// Counted here, *not* in `admitted`, so `admitted` still counts
    /// requests exactly once and balances `retired`.
    pub resumed: u64,
    /// Positions re-run through the prefill forward on resume (prompt +
    /// already-emitted tokens, minus whatever the prefix index still
    /// served) — the compute cost of transparent degradation.
    pub resume_recompute_tokens: u64,
    pub peak_lanes: usize,
}

struct Lane {
    id: usize,
    tokens: Vec<i32>,
    prompt_len: usize,
    n_new: usize,
    produced: usize,
    temp: f32,
    rng: Rng,
    /// EOS-style stop token (see `QueuedRequest::stop`).
    stop: Option<i32>,
    /// The stop token fired — retire at the next sweep.
    stopped: bool,
    /// Admission class — read by the preemption victim scan (strictly
    /// lower classes than the stalled head are preemptible).
    priority: Priority,
    /// Admission tick (monotone per engine): preemption evicts the
    /// *newest* victim within the lowest class, deterministically.
    admit_seq: u64,
    seq: SeqKv,
    /// Tokens already written to the KV cache.
    pos: usize,
    /// Positions the prefill forward must cover before decode:
    /// `prompt_len` for a fresh lane, `prompt_len + produced` for a
    /// resumed one (already-emitted tokens re-prefill too).
    prefill_target: usize,
    /// Positions already cached (prefix-shared at admission or computed
    /// by a prior chunk); prefill resumes here. `== prefill_target`
    /// once the lane has sampled a token this incarnation.
    prefilled: usize,
    reserved_blocks: usize,
    /// Submit time (from `QueuedRequest::enqueued`) — drives the TTFT
    /// histogram and the span's queue-wait component.
    enqueued: Instant,
    /// Admission time: span decode time = retirement − admission −
    /// prefill.
    admitted_at: Instant,
    queue_wait_ns: u64,
    prefill_ns: u64,
}

/// The continuous-batching serving engine.
pub struct Engine {
    model: ServeModel,
    pool: KvPool,
    /// Prompt-prefix trie over the pool's resident blocks (weak: holds
    /// ids, not references — pruned via `freed` on every release).
    prefix: PrefixIndex,
    /// Scratch for the freed-id reports every release feeds into
    /// [`PrefixIndex::invalidate`]; capacity reserved at build so
    /// steady-state retirement allocates nothing.
    freed: Vec<u32>,
    /// Prefix sharing enabled (`ServeConfig::prefix_share`).
    prefix_share: bool,
    /// Prefill chunk size; `0` = unchunked (`ServeConfig::prefill_chunk`).
    prefill_chunk: usize,
    sched: Scheduler,
    lanes: Vec<Option<Lane>>,
    done: Vec<Completion>,
    /// Queued requests evicted by higher-priority arrivals at the
    /// queue bound since the last [`Self::take_preempted`] — the
    /// daemon fails their streams with `QueueFull`. Only ever grows
    /// on the overloaded-push path, never during decode.
    preempted: Vec<usize>,
    next_id: usize,
    committed_blocks: usize,
    /// Blocks temporarily hidden from the admission budget
    /// ([`Self::set_withheld_blocks`] — the deterministic pool-exhaust
    /// fault injection). Never touches live reservations, so the
    /// conservative no-mid-flight-exhaustion invariant holds under it.
    withheld_blocks: usize,
    /// Draining: every submit is rejected; live lanes run to completion.
    draining: bool,
    /// KV-pressure preemption enabled (`ServeConfig::preempt`).
    preempt: bool,
    /// Occupancy fraction arming preemption (`ServeConfig::kv_high_water`).
    high_water: f32,
    /// Monotone admission tick feeding `Lane::admit_seq`.
    admit_ticks: u64,
    threads: usize,
    int_gemm: bool,
    /// Persistent-arena mode (`ServeConfig::arena` / `KURTAIL_ARENA`).
    arena: bool,
    /// Parallel backend every engine kernel call pins.
    backend: ParBackend,
    /// Fused GEMM epilogues (`ServeConfig::fused_epilogue`); implies
    /// `arena` — the legacy profile keeps its PR-4 shape.
    fused: bool,
    scratch: DecodeScratch,
    pub stats: EngineStats,
    /// Telemetry bundle (own registry; the daemon serves it on
    /// `GET /metrics`). `obs.enabled` gates every record call.
    obs: EngineObs,
}

impl Engine {
    pub fn new(model: ServeModel, cfg: &ServeConfig) -> Result<Self> {
        let obs = EngineObs::new(cfg.obs.unwrap_or_else(obs::obs_enabled));
        Self::with_obs(model, cfg, obs)
    }

    /// Build an engine recording into an *existing* telemetry bundle —
    /// the supervisor's rebuild path: counters, histograms, and the
    /// registry behind `GET /metrics` survive an engine restart, so a
    /// scrape across a crash sees monotone counters, not a reset.
    pub fn with_obs(mut model: ServeModel, cfg: &ServeConfig, obs: EngineObs) -> Result<Self> {
        anyhow::ensure!(cfg.max_lanes >= 1, "need at least one lane");
        let meta = &model.meta;
        let threads = cfg.threads.unwrap_or_else(num_threads).max(1);
        let per_seq = meta.n_layers
            * 2
            * ((model.max_pos + cfg.block_tokens - 1) / cfg.block_tokens);
        let max_blocks = if cfg.max_blocks > 0 { cfg.max_blocks } else { cfg.max_lanes * per_seq };
        let pool = KvPool::new(cfg.kv_quant, meta.n_heads, meta.d_head, cfg.block_tokens, max_blocks);
        // the integer path needs i8-representable activation codes
        // (symmetric, ≤ 8 bits); anything else — reachable through the
        // public ServeQuantSpec fields — silently keeps the f32 dequant
        // GEMM, which every spec supports
        let int_gemm = cfg.int_gemm.unwrap_or_else(int_gemm_enabled)
            && model.quant.as_ref().is_none_or(|q| scheme_fits_i8(&q.act));
        let arena = cfg.arena.unwrap_or_else(arena_enabled);
        let backend = cfg.par_backend.unwrap_or_else(par::backend);
        // fused epilogues ride on the arena's colmajor staging and
        // pre-packed weights; the legacy profile keeps its PR-4 shape
        let fused = arena && cfg.fused_epilogue.unwrap_or_else(fused_epilogue_enabled);
        // i8 panel cache, budgeted; bitwise transparent to the GEMMs.
        // The budget is enforced as a hard cap even on a model warmed by
        // an earlier (larger-budget) engine build — excess panels drop.
        let budget = cfg.panel_cache.unwrap_or_else(panel_cache_budget);
        model.build_panel_cache(budget);
        // arena engines read pre-packed B panels (head, rotations,
        // dense linears); legacy-mode engines re-pack per call, so the
        // extra copies are skipped entirely on that profile
        if arena {
            model.prepack();
        }
        // size the arena once for the admission-time peak (max_lanes
        // decode rows); a longer prompt prefill grows it once, and the
        // high-water decay (arena mode) hands the excess back after an
        // idle window
        let mut scratch = DecodeScratch::new(threads);
        {
            let m = &model.meta;
            scratch.ensure(cfg.max_lanes, m.d_model, m.d_ff, m.vocab, model.max_pos);
        }
        if arena {
            scratch.set_decay(cfg.scratch_decay.unwrap_or_else(scratch_decay_default));
        }
        // the decode slot list is mem::taken around each decode batch,
        // so it must carry its full capacity itself (ensure() skips it)
        scratch.slots.reserve(cfg.max_lanes);
        let prefix = PrefixIndex::new(cfg.block_tokens, model.meta.n_layers);
        Ok(Self {
            lanes: (0..cfg.max_lanes).map(|_| None).collect(),
            model,
            pool,
            prefix,
            // one release reports at most one lane's whole block set
            freed: Vec::with_capacity(per_seq),
            prefix_share: cfg.prefix_share.unwrap_or_else(prefix_share_enabled),
            prefill_chunk: cfg.prefill_chunk.unwrap_or_else(prefill_chunk_default),
            sched: Scheduler::bounded(cfg.queue_cap, cfg.max_head_skips),
            done: Vec::new(),
            preempted: Vec::new(),
            next_id: 0,
            committed_blocks: 0,
            withheld_blocks: 0,
            draining: false,
            preempt: cfg.preempt.unwrap_or_else(preempt_enabled),
            high_water: cfg.kv_high_water.unwrap_or_else(kv_high_water_default),
            admit_ticks: 0,
            threads,
            int_gemm,
            arena,
            backend,
            fused,
            scratch,
            stats: EngineStats::default(),
            obs,
        })
    }

    /// The engine's telemetry bundle: histograms, gauges, counters, and
    /// the registry behind `GET /metrics`. All handles are `Arc`s, so a
    /// clone can be read from other threads while the engine records.
    pub fn obs(&self) -> &EngineObs {
        &self.obs
    }

    /// Whether quantized GEMMs run on the integer-accumulator path
    /// (`ServeConfig::int_gemm`, falling back to `KURTAIL_INT_GEMM`).
    pub fn int_gemm(&self) -> bool {
        self.int_gemm
    }

    /// Whether the persistent scratch arena is active
    /// (`ServeConfig::arena`, falling back to `KURTAIL_ARENA`).
    pub fn arena(&self) -> bool {
        self.arena
    }

    /// The parallel backend every engine kernel call pins
    /// (`ServeConfig::par_backend`, falling back to `KURTAIL_PAR`).
    pub fn par_backend(&self) -> ParBackend {
        self.backend
    }

    /// Whether the fused column-major / parallel-transpose GEMM
    /// epilogues are active (`ServeConfig::fused_epilogue`, falling
    /// back to `KURTAIL_FUSED_EPILOGUE`; requires the arena).
    pub fn fused_epilogue(&self) -> bool {
        self.fused
    }

    /// Rows the decode scratch arena currently holds capacity for — the
    /// observable of the high-water decay (tests, ops dashboards).
    pub fn scratch_rows(&self) -> usize {
        self.scratch.sized_rows()
    }

    /// Whether admissions share identical-prefix KV blocks
    /// (`ServeConfig::prefix_share`, falling back to
    /// `KURTAIL_PREFIX_SHARE`).
    pub fn prefix_share(&self) -> bool {
        self.prefix_share
    }

    /// Prompt positions one admission may prefill per engine step
    /// (`ServeConfig::prefill_chunk`, falling back to
    /// `KURTAIL_PREFILL_CHUNK`); `0` = whole-prompt prefill.
    pub fn prefill_chunk(&self) -> usize {
        self.prefill_chunk
    }

    /// The scheduler's head-of-line bypass budget
    /// (`ServeConfig::max_head_skips`) — surfaced in `/stats`.
    pub fn max_head_skips(&self) -> usize {
        self.sched.max_skips()
    }

    /// Pool blocks currently held by more than one lane, counted as
    /// Σ(refs − 1) — each unit is one block of KV memory prefix sharing
    /// avoided recomputing and re-storing.
    pub fn shared_block_refs(&self) -> usize {
        self.pool.shared_block_refs()
    }

    /// Bytes held by the i8 weight panel cache (0 = cache off).
    /// Delegates to the model's live accounting so it always reflects
    /// the panels the GEMMs actually read.
    pub fn panel_cache_bytes(&self) -> usize {
        self.model.panel_cache_bytes()
    }

    /// Queue a text prompt (byte-tokenized). Returns the request id.
    pub fn submit(&mut self, prompt: &str, n_tokens: usize, temp: f32, seed: u64) -> Result<usize, ServeError> {
        self.submit_tokens(ByteTokenizer.encode(prompt), n_tokens, temp, seed)
    }

    /// Queue a pre-tokenized prompt. Returns the request id.
    pub fn submit_tokens(
        &mut self,
        tokens: Vec<i32>,
        n_tokens: usize,
        temp: f32,
        seed: u64,
    ) -> Result<usize, ServeError> {
        self.submit_tokens_stop(tokens, n_tokens, temp, seed, None)
    }

    /// [`Self::submit_tokens`] with an EOS-style stop token: the lane
    /// retires as soon as it emits `stop` (the stop token is included
    /// in the completion), immediately releasing its **whole** block
    /// reservation — unclaimed blocks included — so queued requests can
    /// admit mid-batch without waiting out `n_tokens`.
    ///
    /// Every rejection is a typed, recoverable [`ServeError`] that
    /// leaves the engine untouched — `committed_blocks`, the pool and
    /// the id counter are exactly as before the call, so callers can
    /// shed, retry or report without poisoning later admissions.
    pub fn submit_tokens_stop(
        &mut self,
        tokens: Vec<i32>,
        n_tokens: usize,
        temp: f32,
        seed: u64,
        stop: Option<i32>,
    ) -> Result<usize, ServeError> {
        self.submit_tokens_prio(tokens, n_tokens, temp, seed, stop, Priority::Normal)
    }

    /// [`Self::submit_tokens_stop`] with an explicit admission
    /// [`Priority`] (the daemon maps tenants onto classes; library
    /// callers default to `Normal`, which is exactly the old FCFS).
    /// At the queue bound, an arrival that outranks a queued request
    /// evicts the newest lowest-class one instead of shedding itself —
    /// the victim's id lands in [`Self::take_preempted`].
    pub fn submit_tokens_prio(
        &mut self,
        tokens: Vec<i32>,
        n_tokens: usize,
        temp: f32,
        seed: u64,
        stop: Option<i32>,
        priority: Priority,
    ) -> Result<usize, ServeError> {
        if self.draining {
            self.stats.shed += 1;
            if self.obs.enabled {
                self.obs.requests_shed.inc();
            }
            return Err(ServeError::Draining);
        }
        if tokens.is_empty() {
            return Err(ServeError::Invalid("empty prompt".into()));
        }
        if n_tokens < 1 {
            return Err(ServeError::Invalid("need at least one generated token".into()));
        }
        let vocab = self.model.meta.vocab as i32;
        if let Some(&t) = tokens.iter().find(|&&t| t < 0 || t >= vocab) {
            return Err(ServeError::Invalid(format!("prompt token {t} out of vocab range 0..{vocab}")));
        }
        let total = tokens.len() + n_tokens;
        if total > self.model.max_pos {
            return Err(ServeError::Invalid(format!(
                "prompt+generation ({total}) exceeds cache size {}",
                self.model.max_pos
            )));
        }
        let needed = self.pool.blocks_needed(self.model.meta.n_layers, total);
        if needed > self.pool.max_blocks {
            // the PR-2..5 admission-time hard failure, now recoverable:
            // this request can never fit, but the engine carries on
            self.stats.shed += 1;
            if self.obs.enabled {
                self.obs.requests_shed.inc();
            }
            return Err(ServeError::RequestTooLarge { needed_blocks: needed, pool_blocks: self.pool.max_blocks });
        }
        let id = self.next_id;
        let req = QueuedRequest {
            id,
            tokens,
            n_new: n_tokens,
            temp,
            seed,
            stop,
            priority,
            enqueued: Instant::now(),
            resume: None,
        };
        match self.sched.push(req) {
            Ok(victim) => {
                // ids advance only on acceptance, so a replay of the
                // accepted submissions reproduces the same id sequence
                // (and therefore the same per-request rng streams)
                self.next_id += 1;
                if let Some(v) = victim {
                    // an accepted-but-queued request was evicted to
                    // make room: it held no blocks, so this is pure
                    // bookkeeping — count the shed and surface the id
                    self.stats.shed += 1;
                    if self.obs.enabled {
                        self.obs.requests_shed.inc();
                    }
                    self.preempted.push(v.id);
                }
                Ok(id)
            }
            Err(e) => {
                self.stats.shed += 1;
                if self.obs.enabled {
                    self.obs.requests_shed.inc();
                }
                Err(e)
            }
        }
    }

    /// Ids evicted from the queue by higher-priority arrivals since
    /// the last call (never admitted to a lane; no blocks to reclaim).
    /// The daemon fails their streams with [`ServeError::QueueFull`].
    pub fn take_preempted(&mut self) -> Vec<usize> {
        std::mem::take(&mut self.preempted)
    }

    /// Cancel a request by id, wherever it is: still queued (removed
    /// before admission) or live (the lane is torn down and its whole
    /// block reservation returns to the pool immediately, mid-prefill
    /// or mid-decode). Returns `false` when the id is unknown — already
    /// completed, never accepted, or bogus. Canceled requests emit no
    /// [`Completion`].
    pub fn cancel(&mut self, id: usize) -> bool {
        if self.sched.cancel(id).is_some() {
            self.stats.canceled += 1;
            if self.obs.enabled {
                self.obs.requests_canceled.inc();
            }
            self.refresh_gauges();
            return true;
        }
        for slot in 0..self.lanes.len() {
            if self.lanes[slot].as_ref().is_some_and(|l| l.id == id) {
                let mut lane = self.lanes[slot].take().unwrap();
                self.release_lane_blocks(&mut lane.seq);
                self.committed_blocks -= lane.reserved_blocks;
                self.stats.retired += 1;
                self.stats.canceled += 1;
                if self.obs.enabled {
                    self.obs.requests_retired.inc();
                    self.obs.requests_canceled.inc();
                }
                self.refresh_gauges();
                return true;
            }
        }
        false
    }

    /// Return one lane's blocks to the pool (last reference frees) and
    /// prune every index entry naming a freed id — before any admission
    /// could recycle those ids, so the weak [`PrefixIndex`] never maps a
    /// prefix onto a block that no longer holds it. The freed-id scratch
    /// is engine-owned, so steady-state retirement allocates nothing.
    fn release_lane_blocks(&mut self, seq: &mut SeqKv) {
        let Self { pool, prefix, freed, .. } = self;
        freed.clear();
        pool.release_into(seq, freed);
        if !freed.is_empty() {
            prefix.invalidate(freed);
        }
    }

    /// Re-point the pool/lane/queue gauges at current state. Called at
    /// the end of every step and after out-of-step state changes
    /// (cancel, drain) so a scrape between steps never reads a stale
    /// block count.
    fn refresh_gauges(&self) {
        if self.obs.enabled {
            self.obs.kv_free_blocks.set(self.pool.free_blocks() as u64);
            self.obs.kv_used_blocks.set(self.pool.used_blocks() as u64);
            self.obs.kv_withheld_blocks.set(self.withheld_blocks as u64);
            self.obs.kv_shared_block_refs.set(self.pool.shared_block_refs() as u64);
            self.obs.live_lanes.set(self.live_lanes() as u64);
            self.obs.queued_requests.set(self.sched.len() as u64);
        }
    }

    /// Enter drain: every queued request is shed (their ids are
    /// returned so the caller can notify owners), and every subsequent
    /// submit is rejected with [`ServeError::Draining`]. Live lanes are
    /// untouched — keep stepping until [`Self::step`] returns `false`
    /// for a clean exit. Preempted lanes waiting to resume count as
    /// live, not queued: they stay in the queue and run to completion
    /// like the lanes they were.
    pub fn begin_drain(&mut self) -> Vec<usize> {
        self.draining = true;
        let (resumed, shed): (Vec<_>, Vec<_>) =
            self.sched.drain().into_iter().partition(|r| r.resume.is_some());
        // reverse requeue-front per class reconstructs the drained
        // FCFS order exactly
        for r in resumed.into_iter().rev() {
            self.sched.requeue_front(r);
        }
        self.stats.shed += shed.len() as u64;
        if self.obs.enabled {
            self.obs.requests_shed.add(shed.len() as u64);
        }
        self.refresh_gauges();
        shed.into_iter().map(|r| r.id).collect()
    }

    /// Whether [`Self::begin_drain`] was called.
    pub fn draining(&self) -> bool {
        self.draining
    }

    /// Hide `blocks` from the admission budget (deterministic
    /// pool-exhaustion fault injection: admission starves and sheds,
    /// while live reservations — and the no-mid-flight-exhaustion
    /// invariant — are untouched). `0` restores the full budget.
    pub fn set_withheld_blocks(&mut self, blocks: usize) {
        self.withheld_blocks = blocks;
    }

    pub fn withheld_blocks(&self) -> usize {
        self.withheld_blocks
    }

    /// Blocks currently reserved by live lanes.
    pub fn committed_blocks(&self) -> usize {
        self.committed_blocks
    }

    /// Lanes currently decoding.
    pub fn live_lanes(&self) -> usize {
        self.lanes.iter().filter(|l| l.is_some()).count()
    }

    /// Take every completion finished since the last call (streaming
    /// consumers; [`Self::run`] drains the same buffer at the end).
    pub fn take_completions(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.done)
    }

    /// Blocks the pool can still promise to new admissions.
    fn uncommitted_blocks(&self) -> usize {
        (self.pool.max_blocks - self.committed_blocks).saturating_sub(self.withheld_blocks)
    }

    /// KV-pressure preemption (runs at the top of every step, after
    /// retirement): while the best queued request's reservation cannot
    /// fit the admission budget AND pool occupancy is past the high
    /// watermark, snapshot-and-requeue the newest live lane of the
    /// lowest priority class *strictly below* that head's class. The
    /// strict-class requirement makes single-class workloads (every
    /// pre-preemption test and bench) completely preemption-free, and
    /// rules out two same-class lanes thrashing each other. Victims are
    /// not failures: each one releases its whole reservation through
    /// the refcounted pool (shared-prefix refs simply drop one count)
    /// and rejoins the queue at the front of its class, to resume
    /// byte-identically. Deterministic: depends only on queue contents,
    /// lane state, and block accounting — never wall-clock.
    fn maybe_preempt(&mut self) {
        if !self.preempt {
            return;
        }
        loop {
            let Some(head) = self.sched.peek_best() else { return };
            let needed = self.pool.blocks_needed(self.model.meta.n_layers, head.total_tokens());
            if needed <= self.uncommitted_blocks() {
                return; // the head admits on its own this step
            }
            // occupancy watermark over the non-withheld pool: below it,
            // pressure is transient (retirements will free blocks soon)
            // and preempting would churn lanes for nothing
            let avail = self.pool.max_blocks.saturating_sub(self.withheld_blocks);
            if (self.committed_blocks as f32) < self.high_water * avail as f32 {
                return;
            }
            let head_rank = head.priority.rank();
            // victim: lowest class first (highest rank), newest within
            // the class (largest admit tick) — the lane that lost the
            // least work and outranks the fewest peers
            let victim = (0..self.lanes.len())
                .filter(|&s| {
                    self.lanes[s].as_ref().is_some_and(|l| l.priority.rank() > head_rank)
                })
                .max_by_key(|&s| {
                    let l = self.lanes[s].as_ref().unwrap();
                    (l.priority.rank(), l.admit_seq)
                });
            let Some(slot) = victim else { return };
            self.preempt_lane(slot);
        }
    }

    /// Snapshot one live lane, release its whole KV reservation, and
    /// requeue it at the front of its priority class (see
    /// [`LaneSnapshot`]). The lane's emitted tokens stand — the daemon
    /// keeps its stream open — and on re-admission the chunked-prefill
    /// path recomputes `prompt + emitted` (prefix-index cheap when the
    /// donor blocks survived) before emitting the next token.
    fn preempt_lane(&mut self, slot: usize) {
        let mut lane = self.lanes[slot].take().unwrap();
        self.release_lane_blocks(&mut lane.seq);
        self.committed_blocks -= lane.reserved_blocks;
        self.stats.preempted += 1;
        if self.obs.enabled {
            self.obs.requests_preempted.inc();
        }
        self.sched.requeue_front(QueuedRequest {
            id: lane.id,
            n_new: lane.n_new,
            temp: lane.temp,
            // the snapshot rng supersedes seed-derived sampling state
            seed: 0,
            stop: lane.stop,
            priority: lane.priority,
            enqueued: lane.enqueued,
            resume: Some(LaneSnapshot {
                prompt_len: lane.prompt_len,
                produced: lane.produced,
                rng: lane.rng,
            }),
            tokens: lane.tokens,
        });
    }

    /// Restart support: re-inject a request that was in flight (or
    /// queued) in a previous engine incarnation, resuming after
    /// `tokens.len() - prompt_len` already-delivered tokens. The
    /// sampling rng is reconstructed by replaying the per-request
    /// stream: [`sample_token_buf`] draws exactly one uniform per
    /// emitted token at `temp > 0` and none at `temp <= 0`, so the
    /// replayed state equals the dead lane's — the continuation is
    /// byte-identical to the undisturbed run. Queue-bound- and
    /// drain-exempt like preemption requeues (the request already held
    /// admission once); the id sequence is advanced past `id`.
    #[allow(clippy::too_many_arguments)]
    pub fn resubmit_resumed(
        &mut self,
        id: usize,
        tokens: Vec<i32>,
        prompt_len: usize,
        n_new: usize,
        temp: f32,
        seed: u64,
        stop: Option<i32>,
        priority: Priority,
    ) -> Result<(), ServeError> {
        if prompt_len == 0 || prompt_len > tokens.len() {
            return Err(ServeError::Invalid(format!(
                "resume: prompt_len {prompt_len} out of range for {} tokens",
                tokens.len()
            )));
        }
        let produced = tokens.len() - prompt_len;
        if produced > n_new {
            return Err(ServeError::Invalid(format!(
                "resume: {produced} emitted tokens exceed the n_new budget {n_new}"
            )));
        }
        let needed = self.pool.blocks_needed(self.model.meta.n_layers, prompt_len + n_new);
        if needed > self.pool.max_blocks {
            return Err(ServeError::RequestTooLarge {
                needed_blocks: needed,
                pool_blocks: self.pool.max_blocks,
            });
        }
        let mut rng = Rng::new(seed ^ (id as u64).wrapping_mul(0x9E3779B97F4A7C15));
        if temp > 0.0 {
            for _ in 0..produced {
                rng.uniform();
            }
        }
        self.sched.requeue_front(QueuedRequest {
            id,
            tokens,
            n_new,
            temp,
            seed,
            stop,
            priority,
            enqueued: Instant::now(),
            resume: Some(LaneSnapshot { prompt_len, produced, rng }),
        });
        self.next_id = self.next_id.max(id + 1);
        self.refresh_gauges();
        Ok(())
    }

    /// One engine iteration: retire finished lanes, admit + prefill
    /// queued requests into free lanes, then decode one token on every
    /// other live lane. Returns `false` once no work remains.
    pub fn step(&mut self) -> Result<bool> {
        self.step_with(|_, _| {})
    }

    /// [`Self::step`] with a per-token streaming callback:
    /// `on_token(request_id, token)` fires for every token produced
    /// this iteration, in deterministic order — freshly admitted lanes
    /// first (their prefill-seeded token, in admission slot order),
    /// then the decode batch in slot-ascending order. This is the
    /// SSE-style serving hook; `step()` is this with a no-op callback.
    pub fn step_with(&mut self, mut on_token: impl FnMut(usize, i32)) -> Result<bool> {
        self.retire_finished();
        self.maybe_preempt();

        // admit into free lanes (FCFS, reservation-checked); a freshly
        // admitted lane attaches any shared prompt prefix here and
        // joins the prefill rotation below
        for slot in 0..self.lanes.len() {
            if self.lanes[slot].is_some() {
                continue;
            }
            let budget = self.uncommitted_blocks();
            let (pool, meta) = (&self.pool, &self.model.meta);
            let Some(req) = self
                .sched
                .pop_if(|r| pool.blocks_needed(meta.n_layers, r.total_tokens()) <= budget)
            else {
                break;
            };
            let total = req.total_tokens();
            let reserved = self.pool.blocks_needed(self.model.meta.n_layers, total);
            self.committed_blocks += reserved;
            let rng = req.rng();
            let admitted_at = Instant::now();
            // a resumed lane already paid its queue wait in its first
            // incarnation; recording the gap again would double-count
            let queue_wait_ns = if self.obs.enabled && req.resume.is_none() {
                let ns = admitted_at.duration_since(req.enqueued).as_nanos() as u64;
                self.obs.queue_wait.record_ns(ns);
                ns
            } else {
                0
            };
            let resume = req.resume;
            let (prompt_len, produced) = match &resume {
                Some(s) => (s.prompt_len, s.produced),
                None => (req.tokens.len(), 0),
            };
            // reserve the worst-case token and block capacity up front
            // so the per-step pushes below never reallocate mid-decode
            let mut tokens = req.tokens;
            tokens.reserve(req.n_new - produced);
            let per_list = (total + self.pool.block_tokens - 1) / self.pool.block_tokens;
            let mut lane = Lane {
                id: req.id,
                prompt_len,
                n_new: req.n_new,
                produced,
                temp: req.temp,
                rng,
                stop: req.stop,
                stopped: false,
                priority: req.priority,
                admit_seq: self.admit_ticks,
                seq: SeqKv::with_capacity(self.model.meta.n_layers, per_list),
                pos: 0,
                // resumed lanes re-prefill prompt + already-emitted
                // tokens; the final chunk samples the *next* token with
                // the snapshotted rng, continuing the stream exactly
                prefill_target: tokens.len(),
                prefilled: 0,
                reserved_blocks: reserved,
                enqueued: req.enqueued,
                admitted_at,
                queue_wait_ns,
                prefill_ns: 0,
                tokens,
            };
            self.admit_ticks += 1;
            // map any shared prefix onto resident blocks; the fresh
            // allocations (COW tail + later appends) stay within this
            // lane's conservative reservation, so attach cannot
            // exhaust the pool. For a resumed lane the prefix covers
            // emitted tokens too — cheap resume when the donor survived
            if self.prefix_share {
                let shared = self
                    .prefix
                    .attach(&mut self.pool, &lane.tokens[..lane.prefill_target], &mut lane.seq)?;
                if shared > 0 {
                    lane.prefilled = shared;
                    self.stats.prefix_hits += 1;
                    self.stats.prefix_shared_tokens += shared as u64;
                    if self.obs.enabled {
                        self.obs.prefix_shared_tokens.add(shared as u64);
                    }
                }
            }
            if resume.is_some() {
                let recompute = (lane.prefill_target - lane.prefilled) as u64;
                self.stats.resumed += 1;
                self.stats.resume_recompute_tokens += recompute;
                if self.obs.enabled {
                    self.obs.requests_resumed.inc();
                    self.obs.resume_recompute_tokens.add(recompute);
                }
            } else {
                self.stats.admitted += 1;
                if self.obs.enabled {
                    self.obs.requests_admitted.inc();
                }
            }
            self.lanes[slot] = Some(lane);
        }

        // one bounded prefill chunk per mid-prefill lane, in slot
        // order; a lane whose final chunk ran samples its next token
        // inside prefill_step and sits out this iteration's decode
        let mut finished_prefill: Vec<usize> = Vec::new();
        for slot in 0..self.lanes.len() {
            if self.lanes[slot].as_ref().is_some_and(|l| l.prefilled < l.prefill_target)
                && self.prefill_step(slot, &mut on_token)?
            {
                finished_prefill.push(slot);
            }
        }

        // one decode token for every live lane past prefill (excluding
        // those that finished it this step); the slot list lives in the
        // arena so steady state allocates nothing here
        let mut slots = std::mem::take(&mut self.scratch.slots);
        slots.clear();
        slots.extend((0..self.lanes.len()).filter(|&s| {
            self.lanes[s]
                .as_ref()
                .map_or(false, |l| {
                    l.prefilled >= l.prefill_target && l.produced < l.n_new && !l.stopped
                })
                && !finished_prefill.contains(&s)
        }));
        let step_res = if slots.is_empty() {
            Ok(())
        } else {
            let t_dec = self.obs.enabled.then(Instant::now);
            let r = self.decode_batch(&slots, &mut on_token);
            if let Some(t0) = t_dec {
                self.obs.decode_step.record_duration(t0.elapsed());
            }
            r
        };
        self.scratch.slots = slots;
        step_res?;

        let live = self.lanes.iter().filter(|l| l.is_some()).count();
        self.stats.peak_lanes = self.stats.peak_lanes.max(live);
        self.stats.steps += 1;
        self.retire_finished();
        self.refresh_gauges();
        Ok(self.lanes.iter().any(|l| l.is_some()) || !self.sched.is_empty())
    }

    /// Run to completion; completions are returned in submission order.
    pub fn run(&mut self) -> Result<Vec<Completion>> {
        while self.step()? {}
        let mut out = std::mem::take(&mut self.done);
        out.sort_by_key(|c| c.id);
        Ok(out)
    }

    fn retire_finished(&mut self) {
        for slot in 0..self.lanes.len() {
            let finished = self.lanes[slot]
                .as_ref()
                .map_or(false, |l| l.produced >= l.n_new || l.stopped);
            if !finished {
                continue;
            }
            let mut lane = self.lanes[slot].take().unwrap();
            self.release_lane_blocks(&mut lane.seq);
            // the whole reservation returns — blocks an early-stopped
            // lane never claimed included — so queued requests can
            // admit on the very next step
            self.committed_blocks -= lane.reserved_blocks;
            self.stats.retired += 1;
            // "early" means the stop token fired before the n_new
            // budget ran out — a stop on the final token isn't early
            if lane.stopped && lane.produced < lane.n_new {
                self.stats.eos_retired += 1;
            }
            let span = if self.obs.enabled {
                self.obs.requests_retired.inc();
                RequestSpan {
                    queue_wait_ns: lane.queue_wait_ns,
                    prefill_ns: lane.prefill_ns,
                    decode_ns: (lane.admitted_at.elapsed().as_nanos() as u64)
                        .saturating_sub(lane.prefill_ns),
                    new_tokens: lane.produced as u64,
                }
            } else {
                RequestSpan { new_tokens: lane.produced as u64, ..RequestSpan::default() }
            };
            self.done.push(Completion {
                id: lane.id,
                prompt_len: lane.prompt_len,
                text: ByteTokenizer.decode(&lane.tokens),
                tokens: lane.tokens,
                span,
            });
        }
    }

    /// Grow (or, with the arena disabled, freshly re-allocate) the
    /// scratch to cover an `n`-row forward, running the high-water
    /// decay bookkeeping first (arena mode).
    fn prep_scratch(&mut self, n: usize) {
        let m = &self.model.meta;
        if self.arena {
            self.scratch.maybe_decay(n, m.d_model, m.d_ff, m.vocab);
        } else {
            self.scratch.reset_buffers();
        }
        self.scratch.ensure(n, m.d_model, m.d_ff, m.vocab, self.model.max_pos);
    }

    /// One bounded prefill chunk for a mid-prefill lane: the next
    /// `min(prefill_chunk, remaining)` prompt positions run through the
    /// forward as one `(chunk, d)` block, resuming at `lane.prefilled`
    /// (prefix-shared positions were skipped at admission). Non-final
    /// chunks skip the logits head entirely; the final chunk computes
    /// it, seeds the first generated token from the last position's
    /// logits, and registers the now-resident prompt in the prefix
    /// index. Returns whether prefill completed this call.
    fn prefill_step(&mut self, slot: usize, on_token: &mut impl FnMut(usize, i32)) -> Result<bool> {
        let t_prefill = self.obs.enabled.then(Instant::now);
        let (p, start) = {
            let lane = self.lanes[slot].as_ref().unwrap();
            (lane.prefill_target, lane.prefilled)
        };
        let chunk = if self.prefill_chunk == 0 { p } else { self.prefill_chunk };
        let n = chunk.min(p - start);
        let last = start + n == p;
        self.prep_scratch(n);
        {
            let Self { lanes, scratch, model, .. } = self;
            let lane = lanes[slot].as_ref().unwrap();
            scratch.rows.clear();
            scratch.rows.extend((start..start + n).map(|t| (slot, t)));
            embed_rows_into(&model.embed, &lane.tokens[start..start + n], model.meta.d_model, &mut scratch.x);
        }
        self.forward(n, last)?;
        self.stats.prefill_tokens += n as u64;
        self.stats.prefill_chunks += 1;
        if self.obs.enabled {
            self.obs.prefill_tokens.add(n as u64);
            self.obs.prefill_chunks.inc();
        }
        if !last {
            let lane = self.lanes[slot].as_mut().unwrap();
            lane.prefilled += n;
            if let Some(t0) = t_prefill {
                lane.prefill_ns += t0.elapsed().as_nanos() as u64;
            }
            return Ok(false);
        }
        let vocab = self.model.meta.vocab;
        let fused = self.fused;
        let Self { lanes, scratch, stats, obs, .. } = self;
        let DecodeScratch { logits, exps, lrow, .. } = scratch;
        let lane = lanes[slot].as_mut().unwrap();
        lane.prefilled = p;
        lane.pos = p;
        // fused epilogue: logits are (vocab × n) column-major — gather
        // the last position's column (same values, same order, so the
        // sample is bitwise the row-major one)
        let row: &[f32] = if fused && n > 1 {
            gather_col(logits, n, vocab, n - 1, lrow);
            &lrow[..vocab]
        } else {
            &logits[(n - 1) * vocab..n * vocab]
        };
        let next = sample_token_buf(row, lane.temp, &mut lane.rng, exps);
        lane.tokens.push(next);
        lane.produced += 1;
        if lane.stop == Some(next) {
            lane.stopped = true;
        }
        on_token(lane.id, next);
        stats.decode_tokens += 1;
        if let Some(t0) = t_prefill {
            lane.prefill_ns += t0.elapsed().as_nanos() as u64;
            // a resumed lane (produced > 1 here) already recorded its
            // prefill and TTFT in its first incarnation — per-request
            // histogram counts must keep matching `admitted`
            if lane.produced == 1 {
                obs.prefill.record_ns(lane.prefill_ns);
                // TTFT spans submit → this first sampled token
                obs.ttft.record_ns(lane.enqueued.elapsed().as_nanos() as u64);
            }
            obs.decode_tokens.inc();
        }
        // the full prompt is resident — make its blocks discoverable
        // by later identical-prefix admissions (existing entries win,
        // so racing identical prefills register deterministically)
        if self.prefix_share {
            let Self { lanes, prefix, .. } = self;
            let lane = lanes[slot].as_ref().unwrap();
            prefix.register(&lane.tokens[..p], &lane.seq);
        }
        Ok(true)
    }

    /// One decode token for every slot in `slots`, batched `(N, d)`.
    fn decode_batch(&mut self, slots: &[usize], on_token: &mut impl FnMut(usize, i32)) -> Result<()> {
        let n = slots.len();
        self.prep_scratch(n);
        {
            let Self { lanes, scratch, model, .. } = self;
            scratch.rows.clear();
            scratch.toks.clear();
            for &s in slots {
                let lane = lanes[s].as_ref().unwrap();
                scratch.rows.push((s, lane.pos));
                scratch.toks.push(lane.tokens[lane.pos]);
            }
            let DecodeScratch { toks, x, .. } = scratch;
            embed_rows_into(&model.embed, toks, model.meta.d_model, x);
        }
        self.forward(n, true)?;
        let vocab = self.model.meta.vocab;
        let fused = self.fused;
        let Self { lanes, scratch, stats, obs, .. } = self;
        let t_sample = obs.enabled.then(Instant::now);
        let DecodeScratch { logits, exps, lrow, arg_best, arg_idx, .. } = scratch;
        let any_greedy = slots.iter().any(|&s| lanes[s].as_ref().unwrap().temp <= 0.0);
        if fused && n > 1 && any_greedy {
            // one sequential pass over the column-major logits computes
            // every greedy lane's argmax (the common serving case);
            // temperature lanes gather their column below
            argmax_cols(logits, n, vocab, arg_best, arg_idx);
        }
        for (i, &s) in slots.iter().enumerate() {
            let lane = lanes[s].as_mut().unwrap();
            let next = if fused && n > 1 {
                if lane.temp <= 0.0 {
                    arg_idx[i]
                } else {
                    gather_col(logits, n, vocab, i, lrow);
                    sample_token_buf(&lrow[..vocab], lane.temp, &mut lane.rng, exps)
                }
            } else {
                sample_token_buf(&logits[i * vocab..(i + 1) * vocab], lane.temp, &mut lane.rng, exps)
            };
            lane.pos += 1;
            lane.tokens.push(next);
            lane.produced += 1;
            if lane.stop == Some(next) {
                lane.stopped = true;
            }
            on_token(lane.id, next);
            stats.decode_tokens += 1;
        }
        if let Some(t0) = t_sample {
            obs.phases[PHASE_SAMPLING].record_duration(t0.elapsed());
            obs.decode_tokens.add(n as u64);
        }
        Ok(())
    }

    /// The batched transformer forward for the `scratch.rows` row
    /// descriptors (`(lane_slot, pos)` pairs, `n` of them) with
    /// activations already embedded in `scratch.x` (`n × d`, row i
    /// belongs to `rows[i]`). Appends this token's K/V to each row's
    /// paged cache and — when `with_head` — leaves logits (`n × vocab`)
    /// in `scratch.logits` (non-final prefill chunks sample nothing, so
    /// they skip the final norm and the logits head, the widest GEMM of
    /// the forward).
    /// Mirrors `decode_step` op-for-op. With the arena warm, a call
    /// performs **zero heap allocations** (pinned by
    /// `tests/serve_scratch.rs` under the counting allocator).
    fn forward(&mut self, n: usize, with_head: bool) -> Result<()> {
        // phase attribution (see README §Observability): act_quant =
        // online rotations + activation quantize; gemm = packed linears
        // (+ FFN elementwise activation) and the head; attention =
        // KV append + fused dequant-attention; epilogue = norms, RoPE,
        // residual adds. Sampling is timed by the callers.
        let mut ck = PhaseClock::start(self.obs.enabled);
        let threads = self.threads;
        let arena = self.arena;
        let backend = self.backend;
        let fused = self.fused;
        // per-site epilogues (see the module docs): QKV genuinely needs
        // row-major (RoPE/KV-append) so it pays the parallel blocked
        // transpose; wo/wg/wu/wd and the head go column-major into
        // fused consumers; the non-fused path keeps the PR-4 serial flip
        let row_epi = if fused { Epilogue::RowMajor } else { Epilogue::SerialFlip };
        let col_epi = if fused { Epilogue::ColMajor } else { Epilogue::SerialFlip };
        // integer GEMM path: quantize each activation block to int8
        // codes once and feed every consuming linear; the f32 path
        // fake-quantizes in place instead. Both sit on the same grid
        // (identical codes), so the paths differ only in f32 summation
        // order inside a scale group (see serve/qact.rs).
        let use_int = self.int_gemm && self.model.quant.is_some();
        let model = &self.model;
        let pool = &mut self.pool;
        let lanes = &mut self.lanes;
        let meta = &model.meta;
        let (d, h, dh, ff) = (meta.d_model, meta.n_heads, meta.d_head, meta.d_ff);
        let dh2 = dh / 2;
        let quant = model.quant.as_ref();

        // every per-iteration buffer is re-lent from the arena; exact
        // slices keep the kernels' size assertions as tight as before
        let DecodeScratch {
            x,
            z,
            qx,
            kx,
            vx,
            attn,
            rot,
            mid,
            gate,
            logits,
            qcodes,
            qscales,
            gemm,
            fq_bufs,
            scores,
            rows,
            ..
        } = &mut self.scratch;
        let rows: &[(usize, usize)] = &rows[..];
        assert_eq!(rows.len(), n, "forward: row descriptors not staged");
        let x = &mut x[..n * d];
        let z = &mut z[..n * d];
        let qx = &mut qx[..n * d];
        let kx = &mut kx[..n * d];
        let vx = &mut vx[..n * d];
        let attn = &mut attn[..n * d];
        let mid = &mut mid[..n * ff];
        let gate = &mut gate[..n * ff];
        let logits = &mut logits[..n * meta.vocab];
        let qcodes = &mut qcodes[..n * d.max(ff)];
        let qscales = &mut qscales[..n];
        let fq_bufs = &mut fq_bufs[..];
        let rp = model.rots_packed.as_ref();

        for (l, lw) in model.layers.iter().enumerate() {
            // z = act_fq(rmsnorm(x, ln1)) — shared by wq/wk/wv
            rmsnorm_gamma_rows(x, &lw.ln1, z, d, threads, backend);
            ck.lap(PHASE_EPILOGUE);
            if let Some(q) = quant {
                quantize_site(z, d, &q.act, use_int, arena, qcodes, qscales, threads, backend, fq_bufs);
            }
            ck.lap(PHASE_ACT_QUANT);
            project(&lw.wq, use_int, arena, row_epi, z, qcodes, qscales, n, qx, threads, backend, gemm);
            project(&lw.wk, use_int, arena, row_epi, z, qcodes, qscales, n, kx, threads, backend, gemm);
            project(&lw.wv, use_int, arena, row_epi, z, qcodes, qscales, n, vx, threads, backend, gemm);
            ck.lap(PHASE_GEMM);

            // RoPE at each row's position, per head
            for (i, &(_, pos)) in rows.iter().enumerate() {
                let (cos, sin) =
                    (&model.rope_cos[pos * dh2..(pos + 1) * dh2], &model.rope_sin[pos * dh2..(pos + 1) * dh2]);
                for head in 0..h {
                    let o = i * d + head * dh;
                    apply_rope_row(&mut qx[o..o + dh], cos, sin);
                    apply_rope_row(&mut kx[o..o + dh], cos, sin);
                }
            }
            ck.lap(PHASE_EPILOGUE);
            // online R3 (cancels in QᵀK, shapes the K cache distribution)
            if let Some(q) = quant {
                rotate_rows(qx, rot, rp.map(|r| &r.r3), &q.r3, n * h, dh, threads, backend, arena);
                rotate_rows(kx, rot, rp.map(|r| &r.r3), &q.r3, n * h, dh, threads, backend, arena);
            }
            ck.lap(PHASE_ACT_QUANT);
            // append-quantize this token's K/V into the paged pool
            for (i, &(slot, pos)) in rows.iter().enumerate() {
                let lane = lanes[slot].as_mut().unwrap();
                pool.append(&mut lane.seq, l, pos, &kx[i * d..(i + 1) * d], &vx[i * d..(i + 1) * d])?;
            }
            ck.lap(PHASE_ATTENTION);
            // Q activation quant happens after R3 (decode_step order)
            if let Some(q) = quant {
                if arena {
                    fq_rows_scratch(qx, dh, &q.act, threads, backend, fq_bufs);
                } else {
                    fq_rows(qx, dh, &q.act, threads);
                }
            }
            ck.lap(PHASE_ACT_QUANT);
            // fused dequant-attention per row (rows own disjoint caches
            // or, within a prefill, disjoint causal prefixes); score
            // rows come from the arena, one per worker
            {
                let pool_ref: &KvPool = pool;
                let lanes_ref: &Vec<Option<Lane>> = lanes;
                let qx_ref: &[f32] = qx;
                par::par_row_chunks_scratch_mut_on(backend, attn, d, 1, threads, scores, |r0, chunk, sc| {
                    for (i, orow) in chunk.chunks_exact_mut(d).enumerate() {
                        let (slot, pos) = rows[r0 + i];
                        let seq = &lanes_ref[slot].as_ref().unwrap().seq;
                        pool_ref.attend(seq, l, pos + 1, &qx_ref[(r0 + i) * d..(r0 + i + 1) * d], orow, sc);
                    }
                });
            }
            ck.lap(PHASE_ATTENTION);
            if let Some(q) = quant {
                rotate_rows(attn, rot, rp.map(|r| &r.r4), &q.r4, n * h, dh, threads, backend, arena);
                quantize_site(attn, d, &q.act, use_int, arena, qcodes, qscales, threads, backend, fq_bufs);
            }
            ck.lap(PHASE_ACT_QUANT);
            // wo: column-major straight into the fused residual add —
            // the transpose disappears into x's row-major traversal
            project(&lw.wo, use_int, arena, col_epi, attn, qcodes, qscales, n, z, threads, backend, gemm);
            ck.lap(PHASE_GEMM);
            if fused {
                add_assign_colmajor(x, z, n, d);
            } else {
                add_assign(x, z);
            }

            // FFN
            rmsnorm_gamma_rows(x, &lw.ln2, z, d, threads, backend);
            ck.lap(PHASE_EPILOGUE);
            if let Some(q) = quant {
                quantize_site(z, d, &q.act, use_int, arena, qcodes, qscales, threads, backend, fq_bufs);
            }
            ck.lap(PHASE_ACT_QUANT);
            match &lw.wg {
                Some(wg) => {
                    // llama: silu(z·Wg) ⊙ (z·Wu) — elementwise, so the
                    // fused path runs it directly on the column-major
                    // blocks (same (lane, channel) pairs either way)
                    project(wg, use_int, arena, col_epi, z, qcodes, qscales, n, gate, threads, backend, gemm);
                    project(&lw.wu, use_int, arena, col_epi, z, qcodes, qscales, n, mid, threads, backend, gemm);
                    for (mv, &gv) in mid.iter_mut().zip(gate.iter()) {
                        *mv = silu(gv) * *mv;
                    }
                }
                None => {
                    // phi: gelu(z·Wu)
                    project(&lw.wu, use_int, arena, col_epi, z, qcodes, qscales, n, mid, threads, backend, gemm);
                    for mv in mid.iter_mut() {
                        *mv = gelu(*mv);
                    }
                }
            }
            ck.lap(PHASE_GEMM);
            if fused && n > 1 {
                // the R5 rotation (and wd's lhs) needs row-major rows:
                // one parallel blocked transpose crosses layouts, and
                // the rotation then writes `mid` directly (the legacy
                // path's extra copy-back folds away)
                transpose_into_on(backend, &mid[..n * ff], ff, n, &mut rot[..n * ff], threads);
                if let Some(q) = quant {
                    let r = rp.expect("fused epilogue implies prepacked rotations");
                    r.r5.matmul_overwrite_on(backend, &rot[..n * ff], &q.r5.data, &mut mid[..n * ff], n, threads);
                } else {
                    mid[..n * ff].copy_from_slice(&rot[..n * ff]);
                }
            } else if let Some(q) = quant {
                rotate_rows(mid, rot, rp.map(|r| &r.r5), &q.r5, n, ff, threads, backend, arena);
            }
            if let Some(q) = quant {
                quantize_site(mid, ff, &q.act, use_int, arena, qcodes, qscales, threads, backend, fq_bufs);
            }
            ck.lap(PHASE_ACT_QUANT);
            // wd: column-major into the second fused residual add
            project(&lw.wd, use_int, arena, col_epi, mid, qcodes, qscales, n, z, threads, backend, gemm);
            ck.lap(PHASE_GEMM);
            if fused {
                add_assign_colmajor(x, z, n, d);
            } else {
                add_assign(x, z);
            }
            ck.lap(PHASE_EPILOGUE);
        }

        // final norm + fp head (pre-packed on arena engines; overwrite
        // store — see PackedB::matmul_overwrite for bitwise equality).
        // The fused path emits the logits column-major — at decode batch
        // sizes the head's n (vocab) side is the only one wide enough to
        // parallelize over, and argmax/sampling are column-aware.
        if with_head {
            rmsnorm_gamma_rows(x, &model.lnf, z, d, threads, backend);
            ck.lap(PHASE_EPILOGUE);
            match (&model.head_packed, arena) {
                (Some(p), true) if fused && n > 1 => p.matmul_colmajor_on(backend, z, &model.head_t.data, logits, n, threads),
                (Some(p), true) => p.matmul_overwrite_on(backend, z, &model.head_t.data, logits, n, threads),
                _ => {
                    logits.fill(0.0);
                    matmul_into_threads(z, &model.head_t.data, logits, n, d, meta.vocab, threads);
                }
            }
            ck.lap(PHASE_GEMM);
        }
        ck.flush(&self.obs);
        Ok(())
    }

    /// Pool bytes per stored token across all layers (K+V, scales
    /// included) — the serve-side KV memory/token number.
    pub fn kv_bytes_per_token(&self) -> usize {
        self.model.meta.n_layers * self.pool.bytes_per_token_layer()
    }

    /// Dense f32 cache bytes per stored token (`2·L·h·dh·4`) — what the
    /// artifact decode path keeps per token.
    pub fn dense_kv_bytes_per_token(&self) -> usize {
        let m = &self.model.meta;
        2 * m.n_layers * m.n_heads * m.d_head * 4
    }

    pub fn model(&self) -> &ServeModel {
        &self.model
    }

    pub fn pool(&self) -> &KvPool {
        &self.pool
    }

    pub fn queued(&self) -> usize {
        self.sched.len()
    }

    /// The admission-queue bound this engine was built with
    /// (`ServeConfig::queue_cap`; `0` = unbounded).
    pub fn queue_cap(&self) -> usize {
        self.sched.cap()
    }

    /// The next request id this engine would assign.
    pub fn next_id(&self) -> usize {
        self.next_id
    }

    /// Restart support: continue the request-id sequence of a previous
    /// engine incarnation, so ids stay unique across a supervisor
    /// rebuild and a stale cancel can never hit a stranger's request.
    pub fn resume_ids_from(&mut self, next_id: usize) {
        self.next_id = self.next_id.max(next_id);
    }

    /// Whether KV-pressure preemption is active
    /// (`ServeConfig::preempt`, falling back to `KURTAIL_PREEMPT`).
    pub fn preempt(&self) -> bool {
        self.preempt
    }

    /// The occupancy fraction arming preemption
    /// (`ServeConfig::kv_high_water`, falling back to
    /// `KURTAIL_KV_HIGH_WATER`).
    pub fn kv_high_water(&self) -> f32 {
        self.high_water
    }
}

// ---------------------------------------------------------- primitives

/// Greedy (temp ≤ 0) or temperature sampling over one logit row.
pub fn sample_token(logits: &[f32], temp: f32, rng: &mut Rng) -> i32 {
    sample_token_buf(logits, temp, rng, &mut Vec::new())
}

/// [`sample_token`] with a caller-owned softmax scratch buffer (the
/// engine lends its arena `exps`, so temperature sampling allocates
/// nothing in steady state). Greedy sampling never touches `exps`.
pub fn sample_token_buf(logits: &[f32], temp: f32, rng: &mut Rng, exps: &mut Vec<f32>) -> i32 {
    if temp <= 0.0 {
        return argmax(logits) as i32;
    }
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    exps.clear();
    exps.extend(logits.iter().map(|&l| ((l - max) / temp).exp()));
    let sum: f32 = exps.iter().sum();
    let mut u = rng.uniform() * sum;
    for (i, e) in exps.iter().enumerate() {
        u -= e;
        if u <= 0.0 {
            return i as i32;
        }
    }
    (exps.len() - 1) as i32
}

/// Embed `tokens` into the first `tokens.len() · d` floats of `x`.
fn embed_rows_into(embed: &Tensor, tokens: &[i32], d: usize, x: &mut [f32]) {
    assert!(x.len() >= tokens.len() * d, "embed: x buffer too small");
    for (i, &t) in tokens.iter().enumerate() {
        x[i * d..(i + 1) * d].copy_from_slice(embed.row(t as usize));
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    xs.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).map(|(i, _)| i).unwrap_or(0)
}

/// `out = rmsnorm(x) · γ` per `width`-row (eps 1e-5, matching both
/// `model.py::rmsnorm` and the host `rmsnorm_rows`).
fn rmsnorm_gamma_rows(x: &[f32], gamma: &[f32], out: &mut [f32], width: usize, threads: usize, backend: ParBackend) {
    assert_eq!(gamma.len(), width);
    assert_eq!(x.len(), out.len());
    par::par_row_chunks_mut_on(backend, out, width, 16, threads, |r0, chunk| {
        for (i, orow) in chunk.chunks_exact_mut(width).enumerate() {
            let row = &x[(r0 + i) * width..(r0 + i + 1) * width];
            let ms = row.iter().map(|v| v * v).sum::<f32>() / width as f32;
            let inv = 1.0 / (ms + 1e-5).sqrt();
            for ((o, &v), &g) in orow.iter_mut().zip(row).zip(gamma) {
                *o = v * inv * g;
            }
        }
    });
}

/// RoPE on one head row at a fixed position: each even/odd pair
/// `(x[2i], x[2i+1])` rotates by angle `pos·base^(-2i/dh)` — the exact
/// interleaving of `model.py::apply_rope`.
#[inline]
fn apply_rope_row(row: &mut [f32], cos: &[f32], sin: &[f32]) {
    debug_assert_eq!(row.len(), 2 * cos.len());
    for i2 in 0..cos.len() {
        let (c, s) = (cos[i2], sin[i2]);
        let x1 = row[2 * i2];
        let x2 = row[2 * i2 + 1];
        row[2 * i2] = x1 * c - x2 * s;
        row[2 * i2 + 1] = x1 * s + x2 * c;
    }
}

/// In-place per-row symmetric fake-quant (`fake_quant_rows` math),
/// allocating its selection scratch per chunk — the PR-3 call shape,
/// kept for the `KURTAIL_ARENA=0` path.
fn fq_rows(data: &mut [f32], width: usize, s: &QuantScheme, threads: usize) {
    par::par_row_chunks_mut(data, width, 16, threads, |_r0, chunk| {
        let mut buf = Vec::with_capacity(width);
        for row in chunk.chunks_exact_mut(width) {
            let scale = row_scale_buf(row, s, &mut buf);
            fq_row_sym(row, scale, s);
        }
    });
}

/// [`fq_rows`] with caller-owned per-worker selection scratch (the
/// arena path: zero allocations; identical math, so identical bits).
fn fq_rows_scratch(
    data: &mut [f32],
    width: usize,
    s: &QuantScheme,
    threads: usize,
    backend: ParBackend,
    bufs: &mut [Vec<f32>],
) {
    par::par_row_chunks_scratch_mut_on(backend, data, width, 16, threads, bufs, |_r0, chunk, buf| {
        for row in chunk.chunks_exact_mut(width) {
            let scale = row_scale_buf(row, s, buf);
            fq_row_sym(row, scale, s);
        }
    });
}

/// Rotate `rows` rows of `width` in place: `x ← x · R` via `scratch`.
///
/// The arena path multiplies against the pre-packed rotation with an
/// **overwriting** store — the packed kernel writes every output
/// element exactly once, which is where the old `matmul_into_buf`
/// helper's redundant `scratch.fill(0.0)` went (it only existed to feed
/// the accumulate-contract kernel a zeroed buffer). The legacy path
/// (arena off) keeps the PR-3 call shape — grow, zero-fill, re-pack,
/// accumulate — byte-for-byte; both produce identical results (see
/// `PackedB::matmul_overwrite`).
#[allow(clippy::too_many_arguments)]
fn rotate_rows(
    x: &mut [f32],
    scratch: &mut Vec<f32>,
    packed: Option<&PackedB>,
    dense: &Tensor,
    rows: usize,
    width: usize,
    threads: usize,
    backend: ParBackend,
    arena: bool,
) {
    let len = rows * width;
    match packed {
        // arena engines pre-pack the rotations at construction
        Some(p) if arena => {
            // scratch was pre-sized by DecodeScratch::ensure
            let buf = &mut scratch[..len];
            p.matmul_overwrite_on(backend, &x[..len], &dense.data, buf, rows, threads);
            x[..len].copy_from_slice(buf);
        }
        _ => {
            if scratch.len() < len {
                scratch.resize(len, 0.0);
            }
            scratch[..len].fill(0.0);
            matmul_into_threads(&x[..len], &dense.data, &mut scratch[..len], rows, width, width, threads);
            x[..len].copy_from_slice(&scratch[..len]);
        }
    }
}

fn add_assign(x: &mut [f32], y: &[f32]) {
    for (a, b) in x.iter_mut().zip(y) {
        *a += b;
    }
}

/// Fused residual add over a column-major addend: `x` (`m × n`
/// row-major) `+=` `zt` (`n × m` column-major, a `*_colmajor` GEMM
/// output). The transpose disappears into the add's own traversal —
/// per element one `+=` of the exact value the row-major path adds, so
/// bitwise identical to `add_assign(x, flip(zt))` with no flip run.
/// Column-blocked so the strided `zt` tile stays cache-resident
/// (`m ≤` lanes, so a 64-column block is ≤ 4 KiB at 16 lanes).
fn add_assign_colmajor(x: &mut [f32], zt: &[f32], m: usize, n: usize) {
    debug_assert!(x.len() >= m * n && zt.len() >= m * n);
    const JB: usize = 64;
    for jb in (0..n).step_by(JB) {
        let je = (jb + JB).min(n);
        for i in 0..m {
            let xrow = &mut x[i * n..(i + 1) * n];
            for j in jb..je {
                xrow[j] += zt[j * m + i];
            }
        }
    }
}

/// Column-aware greedy argmax over a column-major logits block
/// (`vocab × n`): one sequential pass computes every lane's argmax —
/// `idx[i]` / `best[i]` for lane `i` — reading each cache line once
/// instead of striding per lane. Tie-breaking keeps [`argmax`]'s
/// last-max semantics (`>=`), so results match the row-major path
/// exactly.
fn argmax_cols(logits_t: &[f32], n: usize, vocab: usize, best: &mut [f32], idx: &mut [i32]) {
    debug_assert!(logits_t.len() >= n * vocab && best.len() >= n && idx.len() >= n);
    best[..n].copy_from_slice(&logits_t[..n]);
    idx[..n].fill(0);
    for j in 1..vocab {
        let row = &logits_t[j * n..(j + 1) * n];
        for i in 0..n {
            if row[i] >= best[i] {
                best[i] = row[i];
                idx[i] = j as i32;
            }
        }
    }
}

/// Gather lane `i`'s logits column from a column-major block into a
/// contiguous scratch row (temperature sampling on the fused path: the
/// gathered values and their order equal the row-major row, so the
/// sample is bitwise unchanged).
fn gather_col(logits_t: &[f32], n: usize, vocab: usize, lane: usize, out: &mut Vec<f32>) {
    debug_assert!(logits_t.len() >= n * vocab && lane < n);
    out.clear();
    out.extend((0..vocab).map(|j| logits_t[j * n + lane]));
}

#[inline]
fn silu(v: f32) -> f32 {
    v / (1.0 + (-v).exp())
}

#[inline]
fn gelu(v: f32) -> f32 {
    // tanh approximation, matching model.py::_gelu
    0.5 * v * (1.0 + (0.7978845608 * (v + 0.044715 * v * v * v)).tanh())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::tests_support::fake_llama_meta;
    use crate::tensor::hadamard::random_hadamard;

    fn fp_model() -> ServeModel {
        let meta = fake_llama_meta();
        let mut rng = Rng::new(0);
        let params = Params::init(&meta, &mut rng);
        ServeModel::from_params(&params, None).unwrap()
    }

    fn quant_model() -> ServeModel {
        let meta = fake_llama_meta();
        let mut rng = Rng::new(0);
        let params = Params::init(&meta, &mut rng);
        let spec = ServeQuantSpec::paper_default(
            random_hadamard(meta.d_head, &mut rng),
            random_hadamard(meta.d_head, &mut rng),
            random_hadamard(meta.d_ff, &mut rng),
        );
        ServeModel::from_params(&params, Some(spec)).unwrap()
    }

    fn requests() -> Vec<(Vec<i32>, usize)> {
        vec![
            (vec![1, 2, 3], 4),
            (vec![7], 5),
            (vec![4, 5], 3),
            (vec![9, 1, 0, 2], 2),
        ]
    }

    fn run_with(model: &ServeModel, kv: KvQuant, lanes: usize, threads: usize) -> Vec<Completion> {
        run_with_int(model, kv, lanes, threads, None)
    }

    fn run_with_int(
        model: &ServeModel,
        kv: KvQuant,
        lanes: usize,
        threads: usize,
        int_gemm: Option<bool>,
    ) -> Vec<Completion> {
        run_full(model, kv, lanes, threads, int_gemm, None, None)
    }

    fn run_full(
        model: &ServeModel,
        kv: KvQuant,
        lanes: usize,
        threads: usize,
        int_gemm: Option<bool>,
        arena: Option<bool>,
        panel_cache: Option<usize>,
    ) -> Vec<Completion> {
        let cfg = ServeConfig {
            max_lanes: lanes,
            block_tokens: 4,
            kv_quant: kv,
            threads: Some(threads),
            int_gemm,
            arena,
            panel_cache,
            ..ServeConfig::default()
        };
        let mut eng = Engine::new(model.clone(), &cfg).unwrap();
        for (toks, n) in requests() {
            eng.submit_tokens(toks, n, 0.0, 7).unwrap();
        }
        eng.run().unwrap()
    }

    fn run_cfg(model: &ServeModel, cfg: &ServeConfig) -> Vec<Completion> {
        let mut eng = Engine::new(model.clone(), cfg).unwrap();
        for (toks, n) in requests() {
            eng.submit_tokens(toks, n, 0.0, 7).unwrap();
        }
        eng.run().unwrap()
    }

    #[test]
    fn fused_flag_parse_rule() {
        assert!(fused_flag(None), "unset must default to the fused epilogues");
        assert!(!fused_flag(Some("0")));
        assert!(!fused_flag(Some(" 0 ")));
        assert!(fused_flag(Some("1")));
        assert!(fused_flag(Some("")));
        assert!(fused_flag(Some("off")), "only literal 0 disables");
    }

    #[test]
    fn fused_epilogue_and_par_backend_are_bitwise_transparent() {
        // the PR-4 serial-flip path on the static backend is the
        // reference; every (fused, backend) combination — and the
        // fp/quant models, both GEMM paths — must reproduce its token
        // streams bitwise at every lane/thread pairing
        for model in [fp_model(), quant_model()] {
            let kv = if model.is_quantized() { KvQuant::Asym4 } else { KvQuant::Fp };
            for int_gemm in [Some(true), Some(false)] {
                let base_cfg = ServeConfig {
                    max_lanes: 1,
                    block_tokens: 4,
                    kv_quant: kv,
                    threads: Some(1),
                    int_gemm,
                    fused_epilogue: Some(false),
                    par_backend: Some(ParBackend::Static),
                    ..ServeConfig::default()
                };
                let base = run_cfg(&model, &base_cfg);
                for fused in [Some(true), Some(false)] {
                    for backend in [ParBackend::Static, ParBackend::Steal] {
                        for (lanes, threads) in [(1usize, 4usize), (4, 1), (4, 8)] {
                            let cfg = ServeConfig {
                                max_lanes: lanes,
                                threads: Some(threads),
                                fused_epilogue: fused,
                                par_backend: Some(backend),
                                ..base_cfg.clone()
                            };
                            let got = run_cfg(&model, &cfg);
                            for (a, b) in base.iter().zip(&got) {
                                assert_eq!(
                                    a.tokens, b.tokens,
                                    "fused={fused:?} {backend:?} lanes={lanes} t={threads} int={int_gemm:?}"
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn fused_epilogue_requires_arena() {
        let model = quant_model();
        let cfg = ServeConfig { arena: Some(false), fused_epilogue: Some(true), ..ServeConfig::default() };
        let eng = Engine::new(model.clone(), &cfg).unwrap();
        assert!(!eng.fused_epilogue(), "legacy profile keeps the PR-4 epilogue");
        let on_cfg = ServeConfig { arena: Some(true), fused_epilogue: Some(true), ..ServeConfig::default() };
        let on = Engine::new(model, &on_cfg).unwrap();
        assert!(on.arena() && on.fused_epilogue());
    }

    #[test]
    fn temperature_sampling_matches_across_epilogues() {
        // the fused path samples from a gathered logits column — the
        // stream must equal the row-major path's bitwise, rng included
        let model = quant_model();
        let mk = |fused: bool| {
            let cfg = ServeConfig {
                max_lanes: 3,
                block_tokens: 4,
                threads: Some(2),
                fused_epilogue: Some(fused),
                ..ServeConfig::default()
            };
            let mut eng = Engine::new(model.clone(), &cfg).unwrap();
            for (i, (toks, n)) in requests().into_iter().enumerate() {
                eng.submit_tokens(toks, n, 0.8, 11 + i as u64).unwrap();
            }
            eng.run().unwrap()
        };
        let (a, b) = (mk(true), mk(false));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens, "temperature stream differs across epilogues");
        }
    }

    #[test]
    fn scratch_decays_to_live_lane_peak() {
        // fake_llama_meta caps prompt+generation at seq_len = 8, so the
        // "long prompt" is 5 tokens against a 2-row steady decode batch
        let model = quant_model();
        let cfg = ServeConfig {
            max_lanes: 2,
            block_tokens: 4,
            threads: Some(1),
            scratch_decay: Some(2),
            ..ServeConfig::default()
        };
        let submit = |eng: &mut Engine| {
            eng.submit_tokens(vec![1; 5], 3, 0.0, 7).unwrap();
            eng.submit_tokens(vec![2], 3, 0.0, 7).unwrap();
        };
        let mut eng = Engine::new(model.clone(), &cfg).unwrap();
        assert_eq!(eng.scratch_rows(), 2, "built at the admission-time peak (max_lanes)");
        submit(&mut eng);
        // step 1 prefills both lanes: the 5-token prompt pins the mark…
        assert!(eng.step().unwrap());
        assert_eq!(eng.scratch_rows(), 5, "prefill grew the arena to the prompt length");
        // …and the second below-peak forward (decode at 2 live lanes,
        // after the 1-token prefill) trips the 2-step decay window
        assert!(eng.step().unwrap());
        assert_eq!(eng.scratch_rows(), 2, "arena decayed to the live-lane peak");
        // streams are unaffected: the decayed engine finishes and matches
        // a no-decay run bitwise
        let done = eng.run().unwrap();
        let mut plain =
            Engine::new(model, &ServeConfig { scratch_decay: Some(0), ..cfg.clone() }).unwrap();
        submit(&mut plain);
        let want = plain.run().unwrap();
        assert_eq!(plain.scratch_rows(), 5, "decay off keeps the peak");
        for (a, b) in done.iter().zip(&want) {
            assert_eq!(a.tokens, b.tokens, "decay must be bitwise invisible");
        }
    }

    #[test]
    fn fp_engine_completes_all_requests() {
        let model = fp_model();
        let done = run_with(&model, KvQuant::Fp, 2, 2);
        assert_eq!(done.len(), 4);
        for (c, (toks, n)) in done.iter().zip(requests()) {
            assert_eq!(c.prompt_len, toks.len());
            assert_eq!(c.tokens.len(), toks.len() + n);
            assert_eq!(&c.tokens[..toks.len()], &toks[..]);
            let vocab = model.meta.vocab as i32;
            assert!(c.tokens.iter().all(|&t| t >= 0 && t < vocab));
        }
    }

    #[test]
    fn streams_invariant_to_lanes_and_threads() {
        for model in [fp_model(), quant_model()] {
            let kv = if model.is_quantized() { KvQuant::Asym4 } else { KvQuant::Fp };
            // both GEMM paths must hold the invariance independently
            for int_gemm in [Some(true), Some(false)] {
                let base = run_with_int(&model, kv, 1, 1, int_gemm);
                for (lanes, threads) in [(2usize, 1usize), (4, 4), (3, 8)] {
                    let got = run_with_int(&model, kv, lanes, threads, int_gemm);
                    for (a, b) in base.iter().zip(&got) {
                        assert_eq!(a.tokens, b.tokens, "lanes={lanes} t={threads} int={int_gemm:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn int_gemm_escape_hatch_serves_both_paths() {
        let model = quant_model();
        let int = run_with_int(&model, KvQuant::Asym4, 2, 2, Some(true));
        let f32_path = run_with_int(&model, KvQuant::Asym4, 2, 2, Some(false));
        assert_eq!(int.len(), 4);
        assert_eq!(f32_path.len(), 4);
        for (a, b) in int.iter().zip(&f32_path) {
            // same requests, same prompt echo, same lengths; the token
            // tails may diverge (documented f32-summation-order delta)
            assert_eq!(a.id, b.id);
            assert_eq!(a.prompt_len, b.prompt_len);
            assert_eq!(a.tokens.len(), b.tokens.len());
            assert_eq!(a.tokens[..a.prompt_len], b.tokens[..b.prompt_len]);
        }
        // fp models ignore the flag entirely: identical streams
        let fp = fp_model();
        let fp_int = run_with_int(&fp, KvQuant::Fp, 2, 2, Some(true));
        let fp_f32 = run_with_int(&fp, KvQuant::Fp, 2, 2, Some(false));
        for (a, b) in fp_int.iter().zip(&fp_f32) {
            assert_eq!(a.tokens, b.tokens, "fp path must not depend on int_gemm");
        }
    }

    #[test]
    fn continuous_batching_admits_and_retires_without_draining() {
        let model = quant_model();
        let cfg = ServeConfig {
            max_lanes: 2,
            block_tokens: 4,
            kv_quant: KvQuant::Asym4,
            threads: Some(2),
            ..ServeConfig::default()
        };
        let mut eng = Engine::new(model, &cfg).unwrap();
        for (toks, n) in requests() {
            eng.submit_tokens(toks, n, 0.0, 7).unwrap();
        }
        let done = eng.run().unwrap();
        assert_eq!(done.len(), 4);
        assert_eq!(eng.stats.admitted, 4);
        assert_eq!(eng.stats.retired, 4);
        assert_eq!(eng.stats.peak_lanes, 2, "both lanes should have been busy");
        // every block returned to the pool
        assert_eq!(eng.pool().free_blocks(), eng.pool().max_blocks);
        // prefill was batched: prompt tokens processed without decode steps
        assert_eq!(eng.stats.prefill_tokens, 3 + 1 + 2 + 4);
        assert_eq!(eng.stats.decode_tokens, 4 + 5 + 3 + 2);
    }

    #[test]
    fn incompatible_act_scheme_falls_back_to_f32_path() {
        // reachable through the public ServeQuantSpec fields: an act
        // grid whose codes don't fit i8 (asymmetric here) must not
        // panic mid-decode — the engine keeps the f32 dequant GEMM
        let meta = fake_llama_meta();
        let mut rng = Rng::new(0);
        let params = Params::init(&meta, &mut rng);
        let spec = ServeQuantSpec {
            act: QuantScheme::kv4(),
            ..ServeQuantSpec::paper_default(
                random_hadamard(meta.d_head, &mut rng),
                random_hadamard(meta.d_head, &mut rng),
                random_hadamard(meta.d_ff, &mut rng),
            )
        };
        let model = ServeModel::from_params(&params, Some(spec)).unwrap();
        let cfg = ServeConfig { int_gemm: Some(true), threads: Some(2), ..ServeConfig::default() };
        let mut eng = Engine::new(model, &cfg).unwrap();
        assert!(!eng.int_gemm(), "asymmetric act grid must fall back to the f32 GEMM");
        eng.submit_tokens(vec![1, 2], 3, 0.0, 7).unwrap();
        assert_eq!(eng.run().unwrap().len(), 1);
    }

    #[test]
    fn arena_and_panel_cache_are_bitwise_transparent() {
        // the PR-3 fresh-alloc profile (arena off, panels off) is the
        // reference; every (arena, panel) combination must reproduce its
        // token streams bitwise at every lane/thread pairing
        for model in [fp_model(), quant_model()] {
            let kv = if model.is_quantized() { KvQuant::Asym4 } else { KvQuant::Fp };
            let base = run_full(&model, kv, 1, 1, Some(true), Some(false), Some(0));
            for (arena, panel) in
                [(Some(true), Some(0)), (Some(true), None), (Some(false), None)]
            {
                for (lanes, threads) in [(1usize, 1usize), (4, 4)] {
                    let got =
                        run_full(&model, kv, lanes, threads, Some(true), arena, panel);
                    for (a, b) in base.iter().zip(&got) {
                        assert_eq!(
                            a.tokens, b.tokens,
                            "arena={arena:?} panel={panel:?} lanes={lanes} t={threads}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn panel_cache_budget_is_greedy_and_reported() {
        let model = quant_model();
        // fake_llama_meta: 2 layers × (4 d·d + wg/wu/wd at d=8, ff=16)
        // → per layer 4·64 + 3·128 = 640 panel bytes, 1280 total
        // explicit budgets keep the test independent of KURTAIL_PANEL_CACHE
        let full = Engine::new(
            model.clone(),
            &ServeConfig { panel_cache: Some(usize::MAX), ..ServeConfig::default() },
        )
        .unwrap();
        assert_eq!(full.panel_cache_bytes(), 1280, "unbounded budget caches every linear");
        let off = Engine::new(
            model.clone(),
            &ServeConfig { panel_cache: Some(0), ..ServeConfig::default() },
        )
        .unwrap();
        assert_eq!(off.panel_cache_bytes(), 0);
        // 700 bytes: all of layer 0 (640) fits, nothing of layer 1 does
        let partial = Engine::new(
            model.clone(),
            &ServeConfig { panel_cache: Some(700), ..ServeConfig::default() },
        )
        .unwrap();
        assert_eq!(partial.panel_cache_bytes(), 640, "greedy fill in layer order");
        // the budget is a hard cap even on a pre-warmed model: a
        // smaller engine budget shrinks the cache, zero clears it — the
        // engine reports (and uses) exactly what is resident
        let mut warm = model.clone();
        warm.build_panel_cache(usize::MAX);
        assert_eq!(warm.panel_cache_bytes(), 1280);
        let shrunk = Engine::new(
            warm.clone(),
            &ServeConfig { panel_cache: Some(700), ..ServeConfig::default() },
        )
        .unwrap();
        assert_eq!(shrunk.panel_cache_bytes(), 640, "warm cache shrinks to the cap");
        let cleared = Engine::new(
            warm,
            &ServeConfig { panel_cache: Some(0), ..ServeConfig::default() },
        )
        .unwrap();
        assert_eq!(cleared.panel_cache_bytes(), 0, "Some(0) clears a warm cache");
        // fp models have nothing to cache
        let fp = Engine::new(fp_model(), &ServeConfig::default()).unwrap();
        assert_eq!(fp.panel_cache_bytes(), 0);
    }

    #[test]
    fn eos_early_retirement_frees_capacity_mid_batch() {
        let model = quant_model();
        // pool sized for exactly one in-flight reservation: total = 2+5
        // = 7 tokens → ceil(7/4) = 2 blocks × 2 layers × (K+V) = 8
        let cfg = ServeConfig {
            max_lanes: 2,
            block_tokens: 4,
            max_blocks: 8,
            kv_quant: KvQuant::Asym4,
            threads: Some(2),
            ..ServeConfig::default()
        };
        // probe run: learn the deterministic first generated token
        let mut probe = Engine::new(model.clone(), &cfg).unwrap();
        probe.submit_tokens(vec![1, 2], 5, 0.0, 7).unwrap();
        let full = probe.run().unwrap();
        assert_eq!(full[0].tokens.len(), 7);
        let first = full[0].tokens[2];

        let mut eng = Engine::new(model, &cfg).unwrap();
        eng.submit_tokens_stop(vec![1, 2], 5, 0.0, 7, Some(first)).unwrap();
        eng.submit_tokens(vec![1, 2], 5, 0.0, 7).unwrap();
        // step 1: only request 0's reservation fits; its stop token
        // fires on the prefill-seeded token, so it retires same-step
        assert!(eng.step().unwrap());
        assert_eq!(eng.stats.admitted, 1, "pool admits a single reservation");
        // step 2: the freed reservation admits the waiting request
        assert!(eng.step().unwrap());
        assert_eq!(eng.stats.admitted, 2, "freed blocks admit mid-batch");
        let done = eng.run().unwrap();
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].tokens.len(), 3, "stopped after its first generated token");
        assert_eq!(*done[0].tokens.last().unwrap(), first, "stop token is included");
        assert_eq!(done[1].tokens.len(), 7, "no stop token → full n_new");
        assert_eq!(eng.stats.eos_retired, 1);
        assert_eq!(eng.stats.retired, 2);
        assert_eq!(eng.pool().free_blocks(), eng.pool().max_blocks);
    }

    #[test]
    fn step_with_streams_every_token_in_order() {
        let model = quant_model();
        let cfg = ServeConfig {
            max_lanes: 2,
            block_tokens: 4,
            threads: Some(2),
            ..ServeConfig::default()
        };
        let mut eng = Engine::new(model, &cfg).unwrap();
        for (toks, n) in requests() {
            eng.submit_tokens(toks, n, 0.0, 7).unwrap();
        }
        let mut streamed: Vec<Vec<i32>> = vec![Vec::new(); 4];
        while eng.step_with(|id, tok| streamed[id].push(tok)).unwrap() {}
        let done = eng.run().unwrap();
        assert_eq!(done.len(), 4);
        for c in &done {
            assert_eq!(
                &c.tokens[c.prompt_len..],
                &streamed[c.id][..],
                "per-token stream equals the completion tail for id {}",
                c.id
            );
        }
        // and step() is literally step_with with a no-op callback: same
        // streams as the plain engine run
        let plain = run_with(&quant_model(), KvQuant::Asym4, 2, 2);
        for (a, b) in done.iter().zip(&plain) {
            assert_eq!(a.tokens, b.tokens);
        }
    }

    #[test]
    fn sampling_with_temperature_stays_in_vocab() {
        let model = fp_model();
        let cfg = ServeConfig { threads: Some(1), kv_quant: KvQuant::Fp, ..ServeConfig::default() };
        let mut eng = Engine::new(model.clone(), &cfg).unwrap();
        eng.submit_tokens(vec![3, 4], 5, 0.9, 11).unwrap();
        let done = eng.run().unwrap();
        assert_eq!(done[0].tokens.len(), 7);
        assert!(done[0].tokens.iter().all(|&t| (t as usize) < model.meta.vocab));
    }

    #[test]
    fn submit_validation() {
        let model = fp_model();
        let mut eng = Engine::new(model, &ServeConfig::default()).unwrap();
        assert!(matches!(eng.submit_tokens(vec![], 2, 0.0, 0), Err(ServeError::Invalid(_))), "empty prompt");
        assert!(matches!(eng.submit_tokens(vec![1], 0, 0.0, 0), Err(ServeError::Invalid(_))), "zero tokens");
        assert!(matches!(eng.submit_tokens(vec![99], 2, 0.0, 0), Err(ServeError::Invalid(_))), "out of vocab");
        assert!(matches!(eng.submit_tokens(vec![1; 7], 4, 0.0, 0), Err(ServeError::Invalid(_))), "exceeds cache");
        assert!(eng.submit_tokens(vec![1, 2], 3, 0.0, 0).is_ok());
    }

    #[test]
    fn rejected_submit_leaves_pool_and_ids_untouched() {
        // the PR-2..5 admission assert (oversized reservation), now a
        // typed recoverable error: pool accounting, committed blocks and
        // the id counter must be exactly as before the rejection
        let model = quant_model();
        // pool of 4 blocks: total=7 tokens needs 2·2·ceil(7/4)=8 > 4
        let cfg = ServeConfig {
            max_lanes: 2,
            block_tokens: 4,
            max_blocks: 4,
            kv_quant: KvQuant::Asym4,
            threads: Some(1),
            ..ServeConfig::default()
        };
        let mut eng = Engine::new(model, &cfg).unwrap();
        let err = eng.submit_tokens(vec![1, 2], 5, 0.0, 7).unwrap_err();
        assert_eq!(err, ServeError::RequestTooLarge { needed_blocks: 8, pool_blocks: 4 });
        assert_eq!(eng.committed_blocks(), 0);
        assert_eq!(eng.pool().free_blocks(), eng.pool().max_blocks);
        assert_eq!(eng.queued(), 0);
        assert_eq!(eng.stats.shed, 1);
        // a small request still fits (1 block pair per layer = 4) and,
        // because rejections don't consume ids, gets id 0 — the same
        // stream a never-rejected engine would produce
        let id = eng.submit_tokens(vec![1], 1, 0.0, 7).unwrap();
        assert_eq!(id, 0, "rejected submits must not consume ids");
        assert_eq!(eng.run().unwrap().len(), 1);
        assert_eq!(eng.pool().free_blocks(), eng.pool().max_blocks);
    }

    #[test]
    fn bounded_queue_sheds_with_queue_full() {
        let model = quant_model();
        let cfg = ServeConfig {
            max_lanes: 1,
            block_tokens: 4,
            threads: Some(1),
            queue_cap: 2,
            ..ServeConfig::default()
        };
        let mut eng = Engine::new(model, &cfg).unwrap();
        eng.submit_tokens(vec![1], 2, 0.0, 7).unwrap();
        eng.submit_tokens(vec![2], 2, 0.0, 7).unwrap();
        let err = eng.submit_tokens(vec![3], 2, 0.0, 7).unwrap_err();
        assert_eq!(err, ServeError::QueueFull { cap: 2 });
        assert_eq!(eng.stats.shed, 1);
        assert_eq!(eng.queued(), 2);
        // the shed request is gone, the accepted ones complete
        let done = eng.run().unwrap();
        assert_eq!(done.len(), 2);
        assert_eq!(eng.pool().free_blocks(), eng.pool().max_blocks);
    }

    #[test]
    fn cancel_returns_blocks_and_is_invisible_to_other_lanes() {
        let model = quant_model();
        let cfg = ServeConfig {
            max_lanes: 2,
            block_tokens: 4,
            kv_quant: KvQuant::Asym4,
            threads: Some(1),
            ..ServeConfig::default()
        };
        // reference: id 1's stream with id 0 running to completion
        let mut plain = Engine::new(model.clone(), &cfg).unwrap();
        plain.submit_tokens(vec![1, 2, 3], 4, 0.0, 7).unwrap();
        plain.submit_tokens(vec![4, 5], 5, 0.0, 7).unwrap();
        let want = plain.run().unwrap();

        let mut eng = Engine::new(model, &cfg).unwrap();
        let a = eng.submit_tokens(vec![1, 2, 3], 4, 0.0, 7).unwrap();
        let b = eng.submit_tokens(vec![4, 5], 5, 0.0, 7).unwrap();
        assert!(eng.step().unwrap());
        assert_eq!(eng.live_lanes(), 2, "both lanes admitted");
        let committed_before = eng.committed_blocks();
        assert!(eng.cancel(a), "live lane cancels");
        assert!(committed_before > eng.committed_blocks(), "reservation returned mid-decode");
        assert!(!eng.cancel(a), "second cancel is a no-op");
        assert!(!eng.cancel(99), "unknown id is a no-op");
        let done = eng.run().unwrap();
        // no completion for the canceled lane; the survivor's stream is
        // bitwise the two-lane reference (cancel is stream-invisible)
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, b);
        assert_eq!(done[0].tokens, want[1].tokens);
        assert_eq!(eng.stats.canceled, 1);
        assert_eq!(eng.pool().free_blocks(), eng.pool().max_blocks);
        assert_eq!(eng.committed_blocks(), 0);
    }

    #[test]
    fn drain_sheds_queue_and_finishes_live_lanes() {
        let model = quant_model();
        let cfg = ServeConfig { max_lanes: 1, block_tokens: 4, threads: Some(1), ..ServeConfig::default() };
        let mut eng = Engine::new(model, &cfg).unwrap();
        let a = eng.submit_tokens(vec![1, 2], 4, 0.0, 7).unwrap();
        let b = eng.submit_tokens(vec![3], 2, 0.0, 7).unwrap();
        assert!(eng.step().unwrap()); // admits a; b still queued
        let shed = eng.begin_drain();
        assert_eq!(shed, vec![b], "queued requests shed on drain");
        assert!(eng.draining());
        assert_eq!(eng.submit_tokens(vec![4], 1, 0.0, 7), Err(ServeError::Draining));
        let done = eng.run().unwrap();
        assert_eq!(done.len(), 1, "live lane ran to completion");
        assert_eq!(done[0].id, a);
        assert_eq!(eng.stats.shed, 2, "one drain shed + one draining reject");
        assert_eq!(eng.pool().free_blocks(), eng.pool().max_blocks);
    }

    #[test]
    fn withheld_blocks_starve_admission_without_touching_live_lanes() {
        let model = quant_model();
        let cfg = ServeConfig { max_lanes: 2, block_tokens: 4, threads: Some(1), ..ServeConfig::default() };
        let mut eng = Engine::new(model, &cfg).unwrap();
        eng.submit_tokens(vec![1, 2], 3, 0.0, 7).unwrap();
        eng.set_withheld_blocks(eng.pool().max_blocks);
        assert!(eng.step().unwrap());
        assert_eq!(eng.stats.admitted, 0, "withheld budget blocks admission");
        assert_eq!(eng.queued(), 1);
        eng.set_withheld_blocks(0);
        assert!(eng.step().unwrap());
        assert_eq!(eng.stats.admitted, 1, "restored budget admits");
        let done = eng.run().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(eng.pool().free_blocks(), eng.pool().max_blocks);
    }

    #[test]
    fn large_request_is_not_starved_by_small_stream() {
        // satellite: pool sized for exactly one large reservation. The
        // large request sits behind a small one; more smalls than the
        // bypass budget wait behind it. Aged bypass admits smalls while
        // the budget lasts, then holds the pool for the large one —
        // everything completes, nothing leaks.
        let model = quant_model();
        // large: 3+5=8 tokens → 2 blocks × 2 layers × 2 = 8 = whole pool
        let cfg = ServeConfig {
            max_lanes: 2,
            block_tokens: 4,
            max_blocks: 8,
            kv_quant: KvQuant::Asym4,
            threads: Some(1),
            max_head_skips: 2,
            ..ServeConfig::default()
        };
        let mut eng = Engine::new(model, &cfg).unwrap();
        eng.submit_tokens(vec![9], 2, 0.0, 7).unwrap(); // small head
        let large = eng.submit_tokens(vec![1, 2, 3], 5, 0.0, 7).unwrap();
        for i in 0..6 {
            eng.submit_tokens(vec![4 + i], 2, 0.0, 7).unwrap(); // smalls
        }
        let done = eng.run().unwrap();
        assert_eq!(done.len(), 8, "aged bypass starves nobody");
        assert!(done.iter().any(|c| c.id == large && c.tokens.len() == 8));
        assert_eq!(eng.stats.admitted, 8);
        assert_eq!(eng.pool().free_blocks(), eng.pool().max_blocks);
    }

    #[test]
    fn quant_model_packs_weights() {
        let (fp, q) = (fp_model(), quant_model());
        assert!(q.weight_bytes() * 4 < fp.weight_bytes(), "{} vs {}", q.weight_bytes(), fp.weight_bytes());
        assert_eq!(fp.weight_bytes(), fp.dense_weight_bytes());
        assert_eq!(q.dense_weight_bytes(), fp.dense_weight_bytes());
    }

    #[test]
    fn greedy_sampling_helpers() {
        let logits = vec![0.0, 3.0, 1.0];
        assert_eq!(argmax(&logits), 1);
        let mut rng = Rng::new(0);
        assert_eq!(sample_token(&logits, 0.0, &mut rng), 1);
    }

    #[test]
    fn chunk_var_parse_rule() {
        assert_eq!(chunk_var(None), DEFAULT_PREFILL_CHUNK, "unset follows the default");
        assert_eq!(chunk_var(Some("0")), 0, "0 = unchunked prefill");
        assert_eq!(chunk_var(Some(" 8 ")), 8);
        assert_eq!(chunk_var(Some("nope")), DEFAULT_PREFILL_CHUNK, "garbage falls back");
    }

    #[test]
    fn chunked_prefill_is_bitwise_invisible_and_bounds_scratch() {
        // fake_llama_meta caps prompt + generation at seq_len = 8
        let model = quant_model();
        let mk = |chunk: usize| {
            let cfg = ServeConfig {
                max_lanes: 2,
                block_tokens: 4,
                threads: Some(1),
                scratch_decay: Some(0), // keep the peak visible
                prefill_chunk: Some(chunk),
                ..ServeConfig::default()
            };
            let mut eng = Engine::new(model.clone(), &cfg).unwrap();
            eng.submit_tokens(vec![1, 2, 3, 4, 5], 3, 0.0, 7).unwrap();
            let done = eng.run().unwrap();
            (done, eng.stats, eng.scratch_rows(), eng.pool().free_blocks() == eng.pool().max_blocks)
        };
        let (chunked, cs, c_rows, c_whole) = mk(2);
        let (whole, ws, w_rows, w_whole) = mk(0);
        assert_eq!(chunked[0].tokens, whole[0].tokens, "chunking must be bitwise invisible");
        assert_eq!(cs.prefill_chunks, 3, "5 prompt positions in chunks of 2");
        assert_eq!(ws.prefill_chunks, 1, "chunk 0 = one forward per prompt");
        assert_eq!(cs.prefill_tokens, 5);
        assert_eq!(ws.prefill_tokens, 5);
        assert_eq!(c_rows, 2, "scratch peak bounded by the chunk, not the prompt");
        assert_eq!(w_rows, 5, "unchunked prefill grows the arena to the prompt length");
        assert!(c_whole && w_whole, "pool whole after both profiles");
    }

    #[test]
    fn chunked_long_admission_leaves_live_lane_streams_unchanged() {
        // satellite: a long admission prefilling one bounded chunk per
        // step must not perturb a lane that is already decoding
        let model = quant_model();
        let cfg = ServeConfig {
            max_lanes: 2,
            block_tokens: 4,
            threads: Some(1),
            prefill_chunk: Some(1),
            ..ServeConfig::default()
        };
        // reference: the short request alone
        let mut solo = Engine::new(model.clone(), &cfg).unwrap();
        solo.submit_tokens(vec![7], 5, 0.0, 7).unwrap();
        let want = solo.run().unwrap();

        let mut eng = Engine::new(model, &cfg).unwrap();
        let a = eng.submit_tokens(vec![7], 5, 0.0, 7).unwrap();
        assert!(eng.step().unwrap()); // lane A live: prefilled + first token
        // the long prompt now prefills one position per step, riding
        // along with A's decode iterations instead of stalling them
        eng.submit_tokens(vec![2, 4, 6, 1, 3], 3, 0.0, 7).unwrap();
        let mut done = eng.run().unwrap();
        done.sort_by_key(|c| c.id);
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].id, a);
        assert_eq!(done[0].tokens, want[0].tokens, "live lane bitwise unaffected");
        assert_eq!(eng.stats.prefill_chunks, 1 + 5, "long prompt ran in 5 single-token chunks");
        assert_eq!(eng.pool().free_blocks(), eng.pool().max_blocks);
    }

    #[test]
    fn prefix_sharing_is_bitwise_invisible_and_counted() {
        // a second identical prompt admitted after the donor's prefill
        // completes maps its full prompt blocks onto the donor's
        // (refcount bump, no compute) and must emit the same stream as a
        // share-off run of the same submission schedule
        let model = quant_model();
        let mk = |share: bool| {
            let cfg = ServeConfig {
                max_lanes: 2,
                block_tokens: 2,
                threads: Some(1),
                prefix_share: Some(share),
                ..ServeConfig::default()
            };
            let mut eng = Engine::new(model.clone(), &cfg).unwrap();
            eng.submit_tokens(vec![1, 2, 3, 4, 5], 3, 0.0, 7).unwrap();
            // the donor must finish prefill (and register its chunks)
            // before the sharer is admitted — sharing is discovered at
            // admission time
            assert!(eng.step().unwrap());
            eng.submit_tokens(vec![1, 2, 3, 4, 5], 3, 0.0, 9).unwrap();
            let mut done = eng.run().unwrap();
            done.sort_by_key(|c| c.id);
            (done, eng.stats, eng.pool().free_blocks() == eng.pool().max_blocks, eng.shared_block_refs())
        };
        let (shared, ss, s_whole, s_refs) = mk(true);
        let (private, ps, p_whole, _) = mk(false);
        assert_eq!(shared.len(), 2);
        for (a, b) in shared.iter().zip(&private) {
            assert_eq!(a.tokens, b.tokens, "sharing must be bitwise invisible");
        }
        // prompt 5, block 2: chunks [1,2] and [3,4] shared (4 positions);
        // the cap at prompt_len − 1 leaves position 4 computed
        assert_eq!(ss.prefix_hits, 1);
        assert_eq!(ss.prefix_shared_tokens, 4);
        assert_eq!(ss.prefill_tokens, 5 + 1, "sharer computes only the final prompt position");
        assert_eq!(ps.prefix_hits, 0);
        assert_eq!(ps.prefill_tokens, 10, "share-off prefills both prompts fully");
        assert!(s_whole && p_whole, "pool whole after the last reference retired");
        assert_eq!(s_refs, 0, "no shared refs outlive the lanes");
    }

    #[test]
    fn prefix_sharing_survives_donor_retirement_and_cancel() {
        // the sharer keeps decoding on blocks whose donor is gone: the
        // refcount (not the donor lane) owns their lifetime, and the
        // index invalidation on release must not free shared blocks
        let model = quant_model();
        let cfg = ServeConfig {
            max_lanes: 2,
            block_tokens: 2,
            threads: Some(1),
            prefix_share: Some(true),
            ..ServeConfig::default()
        };
        let mut reference = Engine::new(model.clone(), &cfg).unwrap();
        reference.submit_tokens(vec![1, 2, 3, 4, 5], 3, 0.0, 7).unwrap();
        assert!(reference.step().unwrap());
        reference.submit_tokens(vec![1, 2, 3, 4, 5], 3, 0.0, 9).unwrap();
        let mut want = reference.run().unwrap();
        want.sort_by_key(|c| c.id);

        let mut eng = Engine::new(model, &cfg).unwrap();
        let donor = eng.submit_tokens(vec![1, 2, 3, 4, 5], 3, 0.0, 7).unwrap();
        assert!(eng.step().unwrap());
        eng.submit_tokens(vec![1, 2, 3, 4, 5], 3, 0.0, 9).unwrap();
        assert!(eng.step().unwrap()); // sharer admitted, attached
        assert!(eng.shared_block_refs() > 0, "live sharing in flight");
        assert!(eng.cancel(donor), "donor cancels mid-share");
        let done = eng.run().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].tokens, want[1].tokens, "sharer stream survives the donor bitwise");
        assert_eq!(eng.pool().free_blocks(), eng.pool().max_blocks, "no leak, no double free");
        assert_eq!(eng.shared_block_refs(), 0);
    }

    // ------------------------------------------- KV-pressure preemption
    //
    // fp model: 2 layers, block_tokens 2 → a lane of `total` tokens
    // reserves 2·2·ceil(total/2) blocks (12 for the 6-token requests
    // below). max_blocks is picked per test so the low lane fits alone
    // *past* the 0.85 watermark while the arriving head cannot.

    fn preempt_cfg(max_lanes: usize, max_blocks: usize) -> ServeConfig {
        ServeConfig {
            max_lanes,
            block_tokens: 2,
            max_blocks,
            threads: Some(1),
            preempt: Some(true),
            ..ServeConfig::default()
        }
    }

    #[test]
    fn preemption_resumes_bitwise_and_leaves_the_pool_whole() {
        let model = fp_model();
        // undisturbed references: the low lane keeps id 0 and seed 5 in
        // both runs, so its rng stream is comparable at temperature;
        // the high lane is greedy (id-independent)
        let mut reference = Engine::new(model.clone(), &preempt_cfg(2, 0)).unwrap();
        reference.submit_tokens(vec![1, 2], 4, 0.8, 5).unwrap();
        let want_low = reference.run().unwrap().remove(0).tokens;
        let mut ref_high = Engine::new(model.clone(), &preempt_cfg(2, 0)).unwrap();
        ref_high.submit_tokens(vec![3, 4], 4, 0.0, 0).unwrap();
        let want_high = ref_high.run().unwrap().remove(0).tokens;

        // 14 blocks: the low lane's 12 sit at 86% occupancy and leave
        // only 2 uncommitted — the high arrival's 12 cannot fit
        let mut eng = Engine::new(model, &preempt_cfg(2, 14)).unwrap();
        let low = eng.submit_tokens_prio(vec![1, 2], 4, 0.8, 5, None, Priority::Low).unwrap();
        assert!(eng.step().unwrap()); // prefill + first token
        assert!(eng.step().unwrap()); // second token
        let high = eng.submit_tokens_prio(vec![3, 4], 4, 0.0, 0, None, Priority::High).unwrap();
        assert!(eng.step().unwrap()); // preempts low, admits high
        assert_eq!(eng.stats.preempted, 1, "the low lane is snapshotted under pressure");
        assert_eq!(eng.queued(), 1, "the victim waits at the front of its class");
        let done = eng.run().unwrap();
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].id, low);
        assert_eq!(done[0].tokens, want_low, "preempted stream must resume byte-identically");
        assert_eq!(done[1].id, high);
        assert_eq!(done[1].tokens, want_high, "the preemptor's stream is undisturbed");
        assert_eq!(eng.stats.admitted, 2, "resume is not a second admission");
        assert_eq!(eng.stats.resumed, 1);
        assert_eq!(eng.stats.retired, 2);
        // at preemption the lane held 2 prompt + 2 emitted tokens; its
        // blocks were freed and no donor matches, so all 4 recompute
        assert_eq!(eng.stats.resume_recompute_tokens, 4);
        assert_eq!(eng.pool().free_blocks(), eng.pool().max_blocks, "pool whole afterward");
        assert_eq!(eng.committed_blocks(), 0);
        assert_eq!(eng.shared_block_refs(), 0);
    }

    #[test]
    fn preemption_evicts_the_newest_lane_of_the_lowest_class() {
        let model = fp_model();
        // two low lanes (12 blocks each, 24/26 committed), high head:
        // exactly one eviction — the newer low lane — lets it fit
        let mut eng = Engine::new(model.clone(), &preempt_cfg(3, 26)).unwrap();
        let l1 = eng.submit_tokens_prio(vec![1, 2], 3, 0.0, 0, None, Priority::Low).unwrap();
        let l2 = eng.submit_tokens_prio(vec![4, 5], 3, 0.0, 0, None, Priority::Low).unwrap();
        assert!(eng.step().unwrap());
        let h = eng.submit_tokens_prio(vec![7, 8], 2, 0.0, 0, None, Priority::High).unwrap();
        assert!(eng.step().unwrap());
        assert_eq!(eng.stats.preempted, 1, "one eviction suffices for the head to fit");
        // cancel the survivors: the parked victim identifies itself by
        // completing alone
        assert!(eng.cancel(l1) && eng.cancel(h));
        let done = eng.run().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, l2, "within a class the newest lane loses");
        let mut r = Engine::new(model, &preempt_cfg(3, 0)).unwrap();
        r.submit_tokens(vec![4, 5], 3, 0.0, 0).unwrap();
        assert_eq!(done[0].tokens, r.run().unwrap().remove(0).tokens);
        assert_eq!(eng.pool().free_blocks(), eng.pool().max_blocks);
    }

    #[test]
    fn preemption_class_order_beats_age() {
        let model = fp_model();
        // the low lane is *older* than the normal one; a high head must
        // still evict the low lane — class outranks admission age
        let mut eng = Engine::new(model.clone(), &preempt_cfg(3, 26)).unwrap();
        let lo = eng.submit_tokens_prio(vec![1, 2], 3, 0.0, 0, None, Priority::Low).unwrap();
        assert!(eng.step().unwrap()); // lo admitted first (admit_seq 0)
        let no = eng.submit_tokens_prio(vec![4, 5], 3, 0.0, 0, None, Priority::Normal).unwrap();
        assert!(eng.step().unwrap()); // no admitted second (admit_seq 1)
        let h = eng.submit_tokens_prio(vec![7, 8], 2, 0.0, 0, None, Priority::High).unwrap();
        assert!(eng.step().unwrap());
        assert_eq!(eng.stats.preempted, 1);
        assert!(eng.cancel(no) && eng.cancel(h));
        let done = eng.run().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, lo, "the lowest class loses even when a lower-ranked lane is newer");
        let mut r = Engine::new(model, &preempt_cfg(3, 0)).unwrap();
        r.submit_tokens(vec![1, 2], 3, 0.0, 0).unwrap();
        assert_eq!(done[0].tokens, r.run().unwrap().remove(0).tokens);
    }

    #[test]
    fn preemption_mid_chunked_prefill_resumes_bitwise() {
        // a victim that has not emitted a single token yet (caught
        // between prefill chunks) snapshots produced = 0 and restarts
        // its prefill from scratch on resume — on the quantized KV path
        let model = quant_model();
        let cfg = ServeConfig {
            kv_quant: KvQuant::Asym4,
            prefill_chunk: Some(1),
            ..preempt_cfg(2, 14)
        };
        let mut reference =
            Engine::new(model.clone(), &ServeConfig { max_blocks: 0, ..cfg.clone() }).unwrap();
        reference.submit_tokens(vec![1, 2, 3, 4], 2, 0.8, 5).unwrap();
        let want = reference.run().unwrap().remove(0).tokens;

        let mut eng = Engine::new(model, &cfg).unwrap();
        let low = eng.submit_tokens_prio(vec![1, 2, 3, 4], 2, 0.8, 5, None, Priority::Low).unwrap();
        assert!(eng.step().unwrap()); // prefill chunk 1 of 4
        assert!(eng.step().unwrap()); // chunk 2 of 4 — nothing emitted yet
        let high = eng.submit_tokens_prio(vec![7, 8], 4, 0.0, 0, None, Priority::High).unwrap();
        assert!(eng.step().unwrap());
        assert_eq!(eng.stats.preempted, 1, "a mid-prefill lane is a valid victim");
        let done = eng.run().unwrap();
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].id, low);
        assert_eq!(done[0].tokens, want, "mid-prefill snapshot resumes bitwise");
        assert_eq!(done[1].id, high);
        assert_eq!(eng.pool().free_blocks(), eng.pool().max_blocks);
        assert_eq!(eng.shared_block_refs(), 0);
    }

    #[test]
    fn preempted_lane_still_cancels_while_queued() {
        // the daemon enforces deadlines by cancel-by-id; a lane parked
        // in the queue between incarnations must stay reachable
        let model = fp_model();
        let mut eng = Engine::new(model, &preempt_cfg(2, 14)).unwrap();
        let low = eng.submit_tokens_prio(vec![1, 2], 4, 0.0, 0, None, Priority::Low).unwrap();
        assert!(eng.step().unwrap());
        assert!(eng.step().unwrap());
        let high = eng.submit_tokens_prio(vec![3, 4], 4, 0.0, 0, None, Priority::High).unwrap();
        assert!(eng.step().unwrap());
        assert_eq!(eng.stats.preempted, 1);
        assert!(eng.cancel(low), "deadline-style cancel reaches the parked snapshot");
        assert_eq!(eng.stats.canceled, 1);
        let done = eng.run().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, high);
        assert_eq!(eng.stats.resumed, 0, "a canceled snapshot never resumes");
        assert_eq!(eng.pool().free_blocks(), eng.pool().max_blocks);
        assert_eq!(eng.committed_blocks(), 0);
    }

    #[test]
    fn drain_keeps_preempted_lanes_and_sheds_fresh_queue() {
        let model = fp_model();
        let mut eng = Engine::new(model, &preempt_cfg(2, 14)).unwrap();
        let low = eng.submit_tokens_prio(vec![1, 2], 4, 0.0, 0, None, Priority::Low).unwrap();
        assert!(eng.step().unwrap());
        assert!(eng.step().unwrap());
        let high = eng.submit_tokens_prio(vec![3, 4], 4, 0.0, 0, None, Priority::High).unwrap();
        assert!(eng.step().unwrap());
        assert_eq!(eng.stats.preempted, 1);
        // a fresh request queued behind the snapshot is shed by drain —
        // the preempted lane is morally in flight and survives it
        let fresh = eng.submit_tokens(vec![6], 2, 0.0, 0).unwrap();
        let shed = eng.begin_drain();
        assert_eq!(shed, vec![fresh]);
        let done = eng.run().unwrap();
        assert_eq!(done.iter().map(|c| c.id).collect::<Vec<_>>(), vec![low, high]);
        assert_eq!(eng.stats.resumed, 1, "the snapshot resumed during the drain");
        assert_eq!(eng.pool().free_blocks(), eng.pool().max_blocks);
    }

    #[test]
    fn preemption_off_keeps_the_head_waiting() {
        let model = fp_model();
        let cfg = ServeConfig { preempt: Some(false), ..preempt_cfg(2, 14) };
        let mut eng = Engine::new(model, &cfg).unwrap();
        assert!(!eng.preempt());
        eng.submit_tokens_prio(vec![1, 2], 4, 0.0, 0, None, Priority::Low).unwrap();
        assert!(eng.step().unwrap());
        eng.submit_tokens_prio(vec![3, 4], 4, 0.0, 0, None, Priority::High).unwrap();
        let done = eng.run().unwrap();
        assert_eq!(done.len(), 2, "the head waits out the low lane instead of evicting it");
        assert_eq!(eng.stats.preempted, 0);
        assert_eq!(eng.stats.resumed, 0);
    }

    #[test]
    fn resubmit_resumed_replays_the_rng_and_continues_bitwise() {
        // the supervisor's restart path: a fresh engine handed only
        // (prompt + delivered tokens, seed) must finish the stream
        // byte-identically — at temperature (rng replay) and greedy
        let model = fp_model();
        let cfg = preempt_cfg(2, 0);
        for temp in [0.8f32, 0.0] {
            let mut reference = Engine::new(model.clone(), &cfg).unwrap();
            let id = reference.submit_tokens(vec![1, 2, 3], 5, temp, 9).unwrap();
            let want = reference.run().unwrap().remove(0).tokens;
            assert_eq!(want.len(), 8);

            let mut eng = Engine::new(model.clone(), &cfg).unwrap();
            // the dead incarnation had delivered the first two tokens
            eng.resubmit_resumed(id, want[..5].to_vec(), 3, 5, temp, 9, None, Priority::Normal)
                .unwrap();
            let done = eng.run().unwrap();
            assert_eq!(done.len(), 1);
            assert_eq!(done[0].id, id);
            assert_eq!(done[0].prompt_len, 3);
            assert_eq!(done[0].tokens, want, "temp={temp}: resumed continuation diverged");
            assert_eq!(eng.stats.resumed, 1);
            assert_eq!(eng.stats.admitted, 0, "a resumed lane is not a fresh admission");
            assert!(eng.next_id() > id, "the id sequence continues past the resumed id");
            assert_eq!(eng.pool().free_blocks(), eng.pool().max_blocks);
        }
        // malformed snapshots are rejected, not admitted
        let mut eng = Engine::new(model, &cfg).unwrap();
        assert!(matches!(
            eng.resubmit_resumed(0, vec![1, 2], 0, 4, 0.0, 0, None, Priority::Normal),
            Err(ServeError::Invalid(_))
        ));
        assert!(matches!(
            eng.resubmit_resumed(0, vec![1, 2, 3, 4], 2, 1, 0.0, 0, None, Priority::Normal),
            Err(ServeError::Invalid(_))
        ));
    }
}
