//! Admission scheduling for the continuous-batching engine.
//!
//! Policy: **FCFS with conservative reservation**. A request is admitted
//! only when (a) a lane slot is free and (b) the KV pool can cover the
//! request's *worst-case* block footprint (`prompt + max_new` tokens
//! across every layer, K and V) on top of what already-admitted lanes
//! may still claim. Admitted sequences therefore never hit pool
//! exhaustion mid-flight, at the cost of admitting slightly fewer lanes
//! than an optimistic scheduler would. The queue never skips the head
//! (no head-of-line bypass): completions retire in bounded time and
//! admission order is deterministic, which the engine's batch-invariance
//! guarantee builds on.

use std::collections::VecDeque;

use crate::util::Rng;

/// A queued generation request (tokenized, ready to admit).
#[derive(Clone, Debug)]
pub struct QueuedRequest {
    pub id: usize,
    pub tokens: Vec<i32>,
    pub n_new: usize,
    pub temp: f32,
    pub seed: u64,
    /// EOS-style stop token: the lane retires as soon as it emits this
    /// token (included in the completion), releasing its whole block
    /// reservation for queued admissions. `None` always runs `n_new`.
    pub stop: Option<i32>,
}

impl QueuedRequest {
    /// Worst-case sequence length (prompt fully cached + every new token).
    pub fn total_tokens(&self) -> usize {
        self.tokens.len() + self.n_new
    }

    /// Per-request sampling stream, independent of admission order and
    /// lane placement (a lane's tokens never depend on its neighbours).
    pub fn rng(&self) -> Rng {
        Rng::new(self.seed ^ (self.id as u64).wrapping_mul(0x9E3779B97F4A7C15))
    }
}

/// FCFS admission queue.
#[derive(Default)]
pub struct Scheduler {
    queue: VecDeque<QueuedRequest>,
}

impl Scheduler {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, r: QueuedRequest) {
        self.queue.push_back(r);
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Pop the head of the queue iff `fits` accepts it. FCFS: when the
    /// head does not fit, nothing is admitted this round even if a later
    /// request would fit.
    pub fn pop_if(&mut self, fits: impl FnOnce(&QueuedRequest) -> bool) -> Option<QueuedRequest> {
        if fits(self.queue.front()?) {
            self.queue.pop_front()
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: usize, len: usize) -> QueuedRequest {
        QueuedRequest { id, tokens: vec![1; len], n_new: 4, temp: 0.0, seed: 0, stop: None }
    }

    #[test]
    fn fcfs_never_skips_the_head() {
        let mut s = Scheduler::new();
        s.push(req(0, 100));
        s.push(req(1, 1));
        // head too big → nothing admitted, even though req 1 would fit
        assert!(s.pop_if(|r| r.total_tokens() <= 10).is_none());
        assert_eq!(s.len(), 2);
        let got = s.pop_if(|r| r.total_tokens() <= 200).unwrap();
        assert_eq!(got.id, 0);
        assert_eq!(s.pop_if(|_| true).unwrap().id, 1);
        assert!(s.is_empty());
    }

    #[test]
    fn request_rngs_are_per_id() {
        let a = req(1, 2).rng().next_u64();
        let b = req(2, 2).rng().next_u64();
        assert_ne!(a, b);
        // and reproducible
        assert_eq!(a, req(1, 2).rng().next_u64());
    }
}
