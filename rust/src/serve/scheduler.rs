//! Admission scheduling for the continuous-batching engine.
//!
//! Policy: **bounded weighted-priority admission with conservative
//! reservation and aged head-of-line bypass**. A request is admitted
//! only when (a) a lane slot is free and (b) the KV pool can cover the
//! request's *worst-case* block footprint (`prompt + max_new` tokens
//! across every layer, K and V) on top of what already-admitted lanes
//! may still claim. Admitted sequences therefore never hit pool
//! exhaustion mid-flight, at the cost of admitting slightly fewer
//! lanes than an optimistic scheduler would.
//!
//! Three robustness amendments over the PR-2 pure-FCFS queue:
//!
//! * **Bounded queue.** `cap > 0` rejects pushes past `cap` requests
//!   with [`ServeError::QueueFull`] — the daemon's backpressure signal
//!   (shed + retry-after) instead of unbounded memory growth under
//!   overload. When the bound is hit by a higher-priority arrival, the
//!   newest request of the lowest class strictly below it is evicted
//!   instead (returned to the caller to shed), so a low-priority flood
//!   cannot lock a full queue against high-priority traffic.
//! * **Priority classes.** Each request carries a [`Priority`]
//!   (`high`/`normal`/`low`); admission scans classes in priority
//!   order, FCFS within a class. Tenant → class mapping lives in the
//!   daemon's runtime config; the in-process/library default is
//!   `Normal`, which reduces exactly to the old FCFS behaviour.
//! * **Aged bypass, generalized.** Pure FCFS never skips the head, so
//!   one large request whose KV reservation doesn't fit blocks every
//!   small request behind it (head-of-line blocking). Pure bypass has
//!   the dual failure: a continuous stream of small requests keeps the
//!   pool fragmented and starves the large head forever. With priority
//!   classes there is a third failure: a high-priority stream starves
//!   every lower class forever. One mechanism bounds all three: each
//!   class head carries a bypass budget (`max_skips` × the class
//!   weight, lower classes getting a larger multiplier); *any*
//!   admission that is not that head spends one unit of it; a head
//!   past its budget gates admission entirely until it fits (live
//!   lanes retire and return their blocks in bounded time, so every
//!   head admits in bounded time, whatever its class). Admission order
//!   remains deterministic — it depends only on the queue contents and
//!   the fits-predicate sequence, never on wall-clock time — which the
//!   engine's batch-invariance guarantee builds on.

use std::collections::VecDeque;
use std::time::Instant;

use crate::util::Rng;

use super::error::ServeError;

/// Default bypass budget before a blocked head pauses admissions
/// (`ServeConfig::max_head_skips`).
pub const DEFAULT_HEAD_SKIPS: usize = 4;

/// Admission priority class. Classes are scanned `High → Normal →
/// Low`; within a class admission is FCFS (plus the aged bypass).
/// `Low` gets a doubled aging budget — it tolerates more bypasses
/// before gating admission — so high-priority bursts ride through,
/// but it still gates eventually: no class can be starved forever.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Priority {
    High,
    #[default]
    Normal,
    Low,
}

impl Priority {
    /// Scan order: 0 is served first.
    pub fn rank(self) -> usize {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Low => 2,
        }
    }

    /// Aging-budget multiplier: a class head gates admission after
    /// `max_skips * weight()` bypasses. `Normal` must stay at 1 so the
    /// single-class behaviour is exactly the pre-priority scheduler.
    pub fn weight(self) -> usize {
        match self {
            Priority::High => 1,
            Priority::Normal => 1,
            Priority::Low => 2,
        }
    }

    /// Config-file / API spelling (`"high"`, `"normal"`, `"low"`).
    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "high" => Some(Priority::High),
            "normal" => Some(Priority::Normal),
            "low" => Some(Priority::Low),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
            Priority::Low => "low",
        }
    }

    fn from_rank(c: usize) -> Priority {
        match c {
            0 => Priority::High,
            1 => Priority::Normal,
            _ => Priority::Low,
        }
    }
}

const CLASSES: usize = 3;

/// Frozen mid-flight state of a preempted (or restart-orphaned) lane,
/// carried on its requeued [`QueuedRequest`]. Because every stream is
/// bitwise-deterministic, `prompt + emitted tokens` plus the sampling
/// rng *as of the last emitted token* fully determine the rest of the
/// stream: on re-admission the engine re-prefills the whole
/// `QueuedRequest::tokens` (prompt and already-emitted tokens alike,
/// cheap again where the prefix index still holds the donor blocks) and
/// the final prefill chunk samples the *next* token with this rng —
/// continuing the stream byte-identically to the undisturbed run.
#[derive(Clone, Debug)]
pub struct LaneSnapshot {
    /// Original prompt length; `tokens[prompt_len..]` are emitted tokens.
    pub prompt_len: usize,
    /// Tokens already emitted (and delivered) before preemption.
    pub produced: usize,
    /// Sampling rng state after `produced` draws.
    pub rng: Rng,
}

/// A queued generation request (tokenized, ready to admit).
#[derive(Clone, Debug)]
pub struct QueuedRequest {
    pub id: usize,
    pub tokens: Vec<i32>,
    pub n_new: usize,
    pub temp: f32,
    pub seed: u64,
    /// EOS-style stop token: the lane retires as soon as it emits this
    /// token (included in the completion), releasing its whole block
    /// reservation for queued admissions. `None` always runs `n_new`.
    pub stop: Option<i32>,
    /// Admission class (tenant policy); `Normal` for library callers.
    pub priority: Priority,
    /// Submit time, for the queue-wait histogram and the request's trace
    /// span. Observability only — admission order never reads the clock
    /// (the batch-invariance guarantee stands).
    pub enqueued: Instant,
    /// Present when this request is a preempted lane coming back:
    /// `tokens` then holds `prompt + emitted` and admission resumes the
    /// stream instead of starting it (see [`LaneSnapshot`]).
    pub resume: Option<LaneSnapshot>,
}

impl QueuedRequest {
    /// Worst-case sequence length (prompt fully cached + every new
    /// token). For a resumed request, already-emitted tokens live in
    /// `tokens`, so they are subtracted from the new-token budget —
    /// the footprint never grows across preempt/resume cycles.
    pub fn total_tokens(&self) -> usize {
        self.tokens.len() + self.n_new - self.resume.as_ref().map_or(0, |s| s.produced)
    }

    /// Per-request sampling stream, independent of admission order and
    /// lane placement (a lane's tokens never depend on its neighbours).
    /// A resumed request continues its snapshotted rng mid-stream.
    pub fn rng(&self) -> Rng {
        match &self.resume {
            Some(s) => s.rng.clone(),
            None => Rng::new(self.seed ^ (self.id as u64).wrapping_mul(0x9E3779B97F4A7C15)),
        }
    }
}

/// Bounded weighted-priority admission queue with aged head-of-line
/// bypass (see the module docs for the policy).
pub struct Scheduler {
    /// One FCFS queue per [`Priority`] class, indexed by `rank()`.
    queues: [VecDeque<QueuedRequest>; CLASSES],
    /// Total bound across classes; `0` = unbounded (the library default).
    cap: usize,
    /// Base bypass budget for a blocked head (scaled per class by
    /// `Priority::weight`).
    max_skips: usize,
    /// Times the *current* head of each class has been bypassed by an
    /// admission from elsewhere; resets whenever that head changes
    /// (pop, cancel of the head, or drain).
    head_skips: [usize; CLASSES],
}

impl Default for Scheduler {
    fn default() -> Self {
        Self::bounded(0, DEFAULT_HEAD_SKIPS)
    }
}

impl Scheduler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Queue bounded at `cap` requests total (`0` = unbounded) with a
    /// `max_skips` base head-of-line bypass budget.
    pub fn bounded(cap: usize, max_skips: usize) -> Self {
        Self {
            queues: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
            cap,
            max_skips,
            head_skips: [0; CLASSES],
        }
    }

    /// Enqueue. At the bound, an arrival outranking some queued request
    /// evicts the newest request of the lowest class strictly below it
    /// and returns the victim (`Ok(Some(..))`) for the caller to shed;
    /// otherwise the push itself is shed with [`ServeError::QueueFull`].
    pub fn push(&mut self, r: QueuedRequest) -> Result<Option<QueuedRequest>, ServeError> {
        if self.cap > 0 && self.len() >= self.cap {
            let victim_class = (r.priority.rank() + 1..CLASSES)
                .rev()
                .find(|&c| !self.queues[c].is_empty());
            let Some(c) = victim_class else {
                return Err(ServeError::QueueFull { cap: self.cap });
            };
            let victim = self.queues[c].pop_back();
            if self.queues[c].is_empty() {
                self.head_skips[c] = 0;
            }
            self.queues[r.priority.rank()].push_back(r);
            return Ok(victim);
        }
        self.queues[r.priority.rank()].push_back(r);
        Ok(None)
    }

    /// Requeue a preempted lane at the *front* of its priority class.
    /// Cap-exempt: the request already held an admission slot, so
    /// putting it back can never be shed — preemption must be lossless.
    /// The class head changes, so its bypass budget resets.
    pub fn requeue_front(&mut self, r: QueuedRequest) {
        let c = r.priority.rank();
        self.queues[c].push_front(r);
        self.head_skips[c] = 0;
    }

    /// The request the next unconstrained `pop_if` would consider first
    /// (the head of the highest-priority non-empty class, or a gating
    /// starved head). Used by the engine's preemption trigger to ask
    /// "what is waiting, and does it fit?" without committing to a pop.
    pub fn peek_best(&self) -> Option<&QueuedRequest> {
        for c in 0..CLASSES {
            if !self.queues[c].is_empty() && self.head_skips[c] >= self.budget(c) {
                return self.queues[c].front();
            }
        }
        self.queues.iter().find_map(|q| q.front())
    }

    pub fn len(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.queues.iter().all(|q| q.is_empty())
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    /// The configured base head-of-line bypass budget
    /// (`ServeConfig::max_head_skips`) — surfaced in `/stats` so
    /// operators can correlate queue-wait tails with the aging policy.
    pub fn max_skips(&self) -> usize {
        self.max_skips
    }

    /// Queued requests in the given class (for `/stats`).
    pub fn len_class(&self, p: Priority) -> usize {
        self.queues[p.rank()].len()
    }

    fn budget(&self, c: usize) -> usize {
        self.max_skips * Priority::from_rank(c).weight()
    }

    /// Charge one bypass against every *other* non-empty class head
    /// after admitting from class `c`.
    fn charge_others(&mut self, c: usize) {
        for k in 0..CLASSES {
            if k != c && !self.queues[k].is_empty() {
                self.head_skips[k] += 1;
            }
        }
    }

    /// Pop the next admissible request. Scan order: classes by
    /// priority; within a class, the head if `fits` accepts it,
    /// otherwise the first later request that fits. Every admission
    /// that is not a given class's head spends one unit of that head's
    /// aging budget (`max_skips × weight`); a head past its budget
    /// *gates* — admission pauses entirely until that head fits, which
    /// bounds its wait by the live lanes' retirement, whatever its
    /// class. Deterministic: depends only on queue contents and the
    /// fits-predicate sequence.
    pub fn pop_if(&mut self, fits: impl Fn(&QueuedRequest) -> bool) -> Option<QueuedRequest> {
        // 1. a starved head gates all admission: pop it if it fits,
        //    else pause. (Highest-priority starved head wins if several
        //    classes starved at once.)
        for c in 0..CLASSES {
            if !self.queues[c].is_empty() && self.head_skips[c] >= self.budget(c) {
                if !fits(self.queues[c].front().expect("non-empty")) {
                    return None;
                }
                self.head_skips[c] = 0;
                let r = self.queues[c].pop_front();
                self.charge_others(c);
                return r;
            }
        }
        // 2. weighted scan — every non-empty class is under budget here
        for c in 0..CLASSES {
            let Some(head) = self.queues[c].front() else { continue };
            if fits(head) {
                self.head_skips[c] = 0;
                let r = self.queues[c].pop_front();
                self.charge_others(c);
                return r;
            }
            // head blocked: aged in-class bypass
            if let Some(pos) = self.queues[c].iter().skip(1).position(&fits) {
                self.head_skips[c] += 1;
                let r = self.queues[c].remove(1 + pos);
                self.charge_others(c);
                return r;
            }
            // nothing in this class fits — falling through to a lower
            // class is itself a bypass of this head, charged on the
            // admitting class's charge_others
        }
        None
    }

    /// Remove a queued request by id (cancellation before admission).
    pub fn cancel(&mut self, id: usize) -> Option<QueuedRequest> {
        for c in 0..CLASSES {
            if let Some(idx) = self.queues[c].iter().position(|r| r.id == id) {
                if idx == 0 {
                    // a new head gets a fresh bypass budget
                    self.head_skips[c] = 0;
                }
                return self.queues[c].remove(idx);
            }
        }
        None
    }

    /// Shed every queued request (graceful drain): the caller notifies
    /// their owners; live lanes are unaffected. Order: by class, FCFS
    /// within a class.
    pub fn drain(&mut self) -> Vec<QueuedRequest> {
        self.head_skips = [0; CLASSES];
        let mut out = Vec::new();
        for q in &mut self.queues {
            out.extend(q.drain(..));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: usize, len: usize) -> QueuedRequest {
        req_prio(id, len, Priority::Normal)
    }

    fn req_prio(id: usize, len: usize, priority: Priority) -> QueuedRequest {
        QueuedRequest {
            id,
            tokens: vec![1; len],
            n_new: 4,
            temp: 0.0,
            seed: 0,
            stop: None,
            priority,
            enqueued: Instant::now(),
            resume: None,
        }
    }

    #[test]
    fn blocked_head_is_bypassed_within_budget() {
        let mut s = Scheduler::bounded(0, 2);
        s.push(req(0, 100)).unwrap();
        s.push(req(1, 1)).unwrap();
        s.push(req(2, 1)).unwrap();
        s.push(req(3, 1)).unwrap();
        let small = |r: &QueuedRequest| r.total_tokens() <= 10;
        // two bypasses spend the head's budget…
        assert_eq!(s.pop_if(small).unwrap().id, 1);
        assert_eq!(s.pop_if(small).unwrap().id, 2);
        // …then admission pauses even though req 3 fits
        assert!(s.pop_if(small).is_none());
        assert_eq!(s.len(), 2);
        // once the head fits it pops (and the budget resets)
        let got = s.pop_if(|r| r.total_tokens() <= 200).unwrap();
        assert_eq!(got.id, 0);
        assert_eq!(s.pop_if(small).unwrap().id, 3);
        assert!(s.is_empty());
    }

    #[test]
    fn large_head_admits_under_endless_small_stream() {
        // the satellite scenario: a pool sized for exactly one large
        // reservation, a large request stuck behind one small one, and
        // an endless supply of small requests arriving behind it. The
        // fits-predicate models the engine's budget check: capacity 8
        // blocks, each live small holds 2 until it retires.
        const CAPACITY: usize = 8;
        let blocks = |r: &QueuedRequest| 2 * r.total_tokens().div_ceil(8);
        let mut s = Scheduler::bounded(0, DEFAULT_HEAD_SKIPS);
        s.push(req(0, 1)).unwrap(); // small (2 blocks)
        s.push(req(1, 28)).unwrap(); // large (8 blocks — the whole pool)
        let mut next_id = 2;
        let mut live: Vec<(usize, usize)> = Vec::new(); // (blocks, steps left)
        let mut large_admitted_at = None;
        for step in 0..64 {
            // an endless stream of small arrivals
            s.push(req(next_id, 1)).unwrap();
            next_id += 1;
            let used: usize = live.iter().map(|&(b, _)| b).sum();
            // one admission attempt per step (single free lane)
            if let Some(r) = s.pop_if(|r| blocks(r) <= CAPACITY - used) {
                if r.id == 1 {
                    large_admitted_at = Some(step);
                }
                live.push((blocks(&r), 3));
            }
            live.retain_mut(|(_, t)| {
                *t -= 1;
                *t > 0
            });
            if large_admitted_at.is_some() {
                break;
            }
        }
        let at = large_admitted_at.expect("aged bypass must admit the large request");
        assert!(at <= 3 * (DEFAULT_HEAD_SKIPS + 2), "admitted late: step {at}");
    }

    #[test]
    fn bounded_queue_sheds_at_cap() {
        let mut s = Scheduler::bounded(2, DEFAULT_HEAD_SKIPS);
        s.push(req(0, 1)).unwrap();
        s.push(req(1, 1)).unwrap();
        // same-class arrival at the bound: shed the push itself
        assert_eq!(s.push(req(2, 1)).unwrap_err(), ServeError::QueueFull { cap: 2 });
        assert_eq!(s.len(), 2);
        // popping frees capacity again
        assert_eq!(s.pop_if(|_| true).unwrap().id, 0);
        s.push(req(2, 1)).unwrap();
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn high_priority_pops_before_earlier_lower_classes() {
        let mut s = Scheduler::bounded(0, DEFAULT_HEAD_SKIPS);
        s.push(req_prio(0, 1, Priority::Low)).unwrap();
        s.push(req_prio(1, 1, Priority::Normal)).unwrap();
        s.push(req_prio(2, 1, Priority::High)).unwrap();
        s.push(req_prio(3, 1, Priority::High)).unwrap();
        let ids: Vec<usize> = std::iter::from_fn(|| s.pop_if(|_| true)).map(|r| r.id).collect();
        // class order first, FCFS within a class
        assert_eq!(ids, vec![2, 3, 1, 0]);
    }

    #[test]
    fn high_arrival_evicts_newest_low_at_the_bound() {
        let mut s = Scheduler::bounded(3, DEFAULT_HEAD_SKIPS);
        s.push(req_prio(0, 1, Priority::Low)).unwrap();
        s.push(req_prio(1, 1, Priority::Normal)).unwrap();
        s.push(req_prio(2, 1, Priority::Low)).unwrap();
        // a high push at the bound evicts the newest Low request…
        let victim = s.push(req_prio(3, 1, Priority::High)).unwrap().unwrap();
        assert_eq!(victim.id, 2);
        assert_eq!(s.len(), 3);
        // …a normal push evicts the remaining Low one…
        let victim = s.push(req_prio(4, 1, Priority::Normal)).unwrap().unwrap();
        assert_eq!(victim.id, 0);
        // …and once nothing outranked remains, the push itself sheds
        assert_eq!(
            s.push(req_prio(5, 1, Priority::Normal)).unwrap_err(),
            ServeError::QueueFull { cap: 3 }
        );
        let ids: Vec<usize> = std::iter::from_fn(|| s.pop_if(|_| true)).map(|r| r.id).collect();
        assert_eq!(ids, vec![3, 1, 4]);
    }

    #[test]
    fn low_class_is_starvation_bounded_under_high_flood() {
        // a Low request with an endless stream of High arrivals in
        // front of it: after max_skips * weight(Low) bypasses its head
        // gates admission, so it must admit in bounded time
        let mut s = Scheduler::bounded(0, 2);
        s.push(req_prio(0, 1, Priority::Low)).unwrap();
        let budget = 2 * Priority::Low.weight();
        let mut next_id = 1;
        let mut admitted_low_at = None;
        for step in 0..32 {
            s.push(req_prio(next_id, 1, Priority::High)).unwrap();
            next_id += 1;
            let got = s.pop_if(|_| true).expect("everything fits");
            if got.priority == Priority::Low {
                admitted_low_at = Some(step);
                break;
            }
        }
        let at = admitted_low_at.expect("low head must not starve");
        // exactly `budget` high admissions ride through, then Low gates
        assert_eq!(at, budget);
    }

    #[test]
    fn cancel_removes_by_id_and_resets_head_budget() {
        let mut s = Scheduler::bounded(0, 1);
        s.push(req(0, 100)).unwrap();
        s.push(req(1, 1)).unwrap();
        s.push(req(2, 1)).unwrap();
        let small = |r: &QueuedRequest| r.total_tokens() <= 10;
        assert_eq!(s.pop_if(small).unwrap().id, 1); // spends head 0's budget
        assert!(s.pop_if(small).is_none());
        assert!(s.cancel(7).is_none());
        assert_eq!(s.cancel(0).unwrap().id, 0);
        // head 2 starts with a fresh budget and fits anyway
        assert_eq!(s.pop_if(small).unwrap().id, 2);
        assert!(s.is_empty());
    }

    #[test]
    fn drain_sheds_everything() {
        let mut s = Scheduler::bounded(4, DEFAULT_HEAD_SKIPS);
        for i in 0..3 {
            s.push(req(i, 1)).unwrap();
        }
        let shed = s.drain();
        assert_eq!(shed.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert!(s.is_empty());
        s.push(req(9, 1)).unwrap(); // queue is reusable after a drain
        assert_eq!(s.pop_if(|_| true).unwrap().id, 9);
    }

    #[test]
    fn requeue_front_jumps_the_class_and_ignores_the_cap() {
        let mut s = Scheduler::bounded(2, DEFAULT_HEAD_SKIPS);
        s.push(req_prio(0, 1, Priority::Low)).unwrap();
        s.push(req_prio(1, 1, Priority::Low)).unwrap();
        // a preempted Low lane comes back at the front of Low even
        // though the queue is at its bound
        let mut back = req_prio(2, 1, Priority::Low);
        back.resume =
            Some(LaneSnapshot { prompt_len: 1, produced: 2, rng: Rng::new(7) });
        s.requeue_front(back);
        assert_eq!(s.len(), 3, "requeue_front is cap-exempt");
        assert_eq!(s.peek_best().unwrap().id, 2);
        // …but a High request still outranks it
        s.push(req_prio(3, 1, Priority::High)).unwrap();
        assert_eq!(s.peek_best().unwrap().id, 3);
        let ids: Vec<usize> = std::iter::from_fn(|| s.pop_if(|_| true)).map(|r| r.id).collect();
        assert_eq!(ids, vec![3, 2, 0, 1]);
    }

    #[test]
    fn resumed_footprint_and_rng_come_from_the_snapshot() {
        // a resumed request's tokens hold prompt + emitted, so its
        // worst-case footprint must not double-count the emitted part
        let mut r = req(0, 3); // prompt 3, n_new 4 → total 7
        assert_eq!(r.total_tokens(), 7);
        r.tokens.extend([5, 6]); // two tokens emitted before preemption
        r.resume = Some(LaneSnapshot { prompt_len: 3, produced: 2, rng: Rng::new(42) });
        assert_eq!(r.total_tokens(), 7, "footprint is stable across preempt/resume");
        assert_eq!(r.rng().next_u64(), Rng::new(42).next_u64(), "rng resumes mid-stream");
    }

    #[test]
    fn priority_parse_roundtrips() {
        for p in [Priority::High, Priority::Normal, Priority::Low] {
            assert_eq!(Priority::parse(p.as_str()), Some(p));
        }
        assert_eq!(Priority::parse("urgent"), None);
        assert_eq!(Priority::default(), Priority::Normal);
    }

    #[test]
    fn request_rngs_are_per_id() {
        let a = req(1, 2).rng().next_u64();
        let b = req(2, 2).rng().next_u64();
        assert_ne!(a, b);
        // and reproducible
        assert_eq!(a, req(1, 2).rng().next_u64());
    }
}
