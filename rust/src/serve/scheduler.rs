//! Admission scheduling for the continuous-batching engine.
//!
//! Policy: **bounded FCFS with conservative reservation and aged
//! head-of-line bypass**. A request is admitted only when (a) a lane
//! slot is free and (b) the KV pool can cover the request's
//! *worst-case* block footprint (`prompt + max_new` tokens across every
//! layer, K and V) on top of what already-admitted lanes may still
//! claim. Admitted sequences therefore never hit pool exhaustion
//! mid-flight, at the cost of admitting slightly fewer lanes than an
//! optimistic scheduler would.
//!
//! Two robustness amendments over the PR-2 pure-FCFS queue:
//!
//! * **Bounded queue.** `cap > 0` rejects pushes past `cap` requests
//!   with [`ServeError::QueueFull`] — the daemon's backpressure signal
//!   (shed + retry-after) instead of unbounded memory growth under
//!   overload.
//! * **Aged bypass.** Pure FCFS never skips the head, so one large
//!   request whose KV reservation doesn't fit blocks every small
//!   request behind it (head-of-line blocking). Pure bypass has the
//!   dual failure: a continuous stream of small requests keeps the pool
//!   fragmented and starves the large head forever. The compromise: a
//!   blocked head may be bypassed at most `max_skips` times; after
//!   that, admission pauses until the head itself fits (live lanes
//!   retire and return their blocks in bounded time, so the head
//!   admits in bounded time). Admission order remains deterministic —
//!   it depends only on the queue contents and the fits-predicate
//!   sequence, never on wall-clock time — which the engine's
//!   batch-invariance guarantee builds on.

use std::collections::VecDeque;
use std::time::Instant;

use crate::util::Rng;

use super::error::ServeError;

/// Default bypass budget before a blocked head pauses admissions
/// (`ServeConfig::max_head_skips`).
pub const DEFAULT_HEAD_SKIPS: usize = 4;

/// A queued generation request (tokenized, ready to admit).
#[derive(Clone, Debug)]
pub struct QueuedRequest {
    pub id: usize,
    pub tokens: Vec<i32>,
    pub n_new: usize,
    pub temp: f32,
    pub seed: u64,
    /// EOS-style stop token: the lane retires as soon as it emits this
    /// token (included in the completion), releasing its whole block
    /// reservation for queued admissions. `None` always runs `n_new`.
    pub stop: Option<i32>,
    /// Submit time, for the queue-wait histogram and the request's trace
    /// span. Observability only — admission order never reads the clock
    /// (the batch-invariance guarantee stands).
    pub enqueued: Instant,
}

impl QueuedRequest {
    /// Worst-case sequence length (prompt fully cached + every new token).
    pub fn total_tokens(&self) -> usize {
        self.tokens.len() + self.n_new
    }

    /// Per-request sampling stream, independent of admission order and
    /// lane placement (a lane's tokens never depend on its neighbours).
    pub fn rng(&self) -> Rng {
        Rng::new(self.seed ^ (self.id as u64).wrapping_mul(0x9E3779B97F4A7C15))
    }
}

/// Bounded FCFS admission queue with aged head-of-line bypass.
pub struct Scheduler {
    queue: VecDeque<QueuedRequest>,
    /// Queue bound; `0` = unbounded (the in-process/library default).
    cap: usize,
    /// Bypass budget for a blocked head (see the module docs).
    max_skips: usize,
    /// Times the *current* head has been bypassed; resets whenever the
    /// head changes (pop, cancel of the head, or drain).
    head_skips: usize,
}

impl Default for Scheduler {
    fn default() -> Self {
        Self::bounded(0, DEFAULT_HEAD_SKIPS)
    }
}

impl Scheduler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Queue bounded at `cap` requests (`0` = unbounded) with a
    /// `max_skips` head-of-line bypass budget.
    pub fn bounded(cap: usize, max_skips: usize) -> Self {
        Self { queue: VecDeque::new(), cap, max_skips, head_skips: 0 }
    }

    /// Enqueue, or shed with [`ServeError::QueueFull`] at the bound.
    pub fn push(&mut self, r: QueuedRequest) -> Result<(), ServeError> {
        if self.cap > 0 && self.queue.len() >= self.cap {
            return Err(ServeError::QueueFull { cap: self.cap });
        }
        self.queue.push_back(r);
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    /// The configured head-of-line bypass budget
    /// (`ServeConfig::max_head_skips`) — surfaced in `/stats` so
    /// operators can correlate queue-wait tails with the aging policy.
    pub fn max_skips(&self) -> usize {
        self.max_skips
    }

    /// Pop the next admissible request: the head if `fits` accepts it;
    /// otherwise — while the head's bypass budget lasts — the first
    /// later request that fits (each such bypass spends one unit of the
    /// budget). A head past its budget pauses admission entirely until
    /// it fits, which bounds its wait by the live lanes' retirement.
    pub fn pop_if(&mut self, fits: impl Fn(&QueuedRequest) -> bool) -> Option<QueuedRequest> {
        if fits(self.queue.front()?) {
            self.head_skips = 0;
            return self.queue.pop_front();
        }
        if self.head_skips >= self.max_skips {
            return None;
        }
        let idx = 1 + self.queue.iter().skip(1).position(fits)?;
        self.head_skips += 1;
        self.queue.remove(idx)
    }

    /// Remove a queued request by id (cancellation before admission).
    pub fn cancel(&mut self, id: usize) -> Option<QueuedRequest> {
        let idx = self.queue.iter().position(|r| r.id == id)?;
        if idx == 0 {
            // a new head gets a fresh bypass budget
            self.head_skips = 0;
        }
        self.queue.remove(idx)
    }

    /// Shed every queued request (graceful drain): the caller notifies
    /// their owners; live lanes are unaffected.
    pub fn drain(&mut self) -> Vec<QueuedRequest> {
        self.head_skips = 0;
        self.queue.drain(..).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: usize, len: usize) -> QueuedRequest {
        QueuedRequest {
            id,
            tokens: vec![1; len],
            n_new: 4,
            temp: 0.0,
            seed: 0,
            stop: None,
            enqueued: Instant::now(),
        }
    }

    #[test]
    fn blocked_head_is_bypassed_within_budget() {
        let mut s = Scheduler::bounded(0, 2);
        s.push(req(0, 100)).unwrap();
        s.push(req(1, 1)).unwrap();
        s.push(req(2, 1)).unwrap();
        s.push(req(3, 1)).unwrap();
        let small = |r: &QueuedRequest| r.total_tokens() <= 10;
        // two bypasses spend the head's budget…
        assert_eq!(s.pop_if(small).unwrap().id, 1);
        assert_eq!(s.pop_if(small).unwrap().id, 2);
        // …then admission pauses even though req 3 fits
        assert!(s.pop_if(small).is_none());
        assert_eq!(s.len(), 2);
        // once the head fits it pops (and the budget resets)
        let got = s.pop_if(|r| r.total_tokens() <= 200).unwrap();
        assert_eq!(got.id, 0);
        assert_eq!(s.pop_if(small).unwrap().id, 3);
        assert!(s.is_empty());
    }

    #[test]
    fn large_head_admits_under_endless_small_stream() {
        // the satellite scenario: a pool sized for exactly one large
        // reservation, a large request stuck behind one small one, and
        // an endless supply of small requests arriving behind it. The
        // fits-predicate models the engine's budget check: capacity 8
        // blocks, each live small holds 2 until it retires.
        const CAPACITY: usize = 8;
        let blocks = |r: &QueuedRequest| 2 * r.total_tokens().div_ceil(8);
        let mut s = Scheduler::bounded(0, DEFAULT_HEAD_SKIPS);
        s.push(req(0, 1)).unwrap(); // small (2 blocks)
        s.push(req(1, 28)).unwrap(); // large (8 blocks — the whole pool)
        let mut next_id = 2;
        let mut live: Vec<(usize, usize)> = Vec::new(); // (blocks, steps left)
        let mut large_admitted_at = None;
        for step in 0..64 {
            // an endless stream of small arrivals
            s.push(req(next_id, 1)).unwrap();
            next_id += 1;
            let used: usize = live.iter().map(|&(b, _)| b).sum();
            // one admission attempt per step (single free lane)
            if let Some(r) = s.pop_if(|r| blocks(r) <= CAPACITY - used) {
                if r.id == 1 {
                    large_admitted_at = Some(step);
                }
                live.push((blocks(&r), 3));
            }
            live.retain_mut(|(_, t)| {
                *t -= 1;
                *t > 0
            });
            if large_admitted_at.is_some() {
                break;
            }
        }
        let at = large_admitted_at.expect("aged bypass must admit the large request");
        assert!(at <= 3 * (DEFAULT_HEAD_SKIPS + 2), "admitted late: step {at}");
    }

    #[test]
    fn bounded_queue_sheds_at_cap() {
        let mut s = Scheduler::bounded(2, DEFAULT_HEAD_SKIPS);
        s.push(req(0, 1)).unwrap();
        s.push(req(1, 1)).unwrap();
        assert_eq!(s.push(req(2, 1)), Err(ServeError::QueueFull { cap: 2 }));
        assert_eq!(s.len(), 2);
        // popping frees capacity again
        assert_eq!(s.pop_if(|_| true).unwrap().id, 0);
        s.push(req(2, 1)).unwrap();
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn cancel_removes_by_id_and_resets_head_budget() {
        let mut s = Scheduler::bounded(0, 1);
        s.push(req(0, 100)).unwrap();
        s.push(req(1, 1)).unwrap();
        s.push(req(2, 1)).unwrap();
        let small = |r: &QueuedRequest| r.total_tokens() <= 10;
        assert_eq!(s.pop_if(small).unwrap().id, 1); // spends head 0's budget
        assert!(s.pop_if(small).is_none());
        assert!(s.cancel(7).is_none());
        assert_eq!(s.cancel(0).unwrap().id, 0);
        // head 2 starts with a fresh budget and fits anyway
        assert_eq!(s.pop_if(small).unwrap().id, 2);
        assert!(s.is_empty());
    }

    #[test]
    fn drain_sheds_everything() {
        let mut s = Scheduler::bounded(4, DEFAULT_HEAD_SKIPS);
        for i in 0..3 {
            s.push(req(i, 1)).unwrap();
        }
        let shed = s.drain();
        assert_eq!(shed.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert!(s.is_empty());
        s.push(req(9, 1)).unwrap(); // queue is reusable after a drain
        assert_eq!(s.pop_if(|_| true).unwrap().id, 9);
    }

    #[test]
    fn request_rngs_are_per_id() {
        let a = req(1, 2).rng().next_u64();
        let b = req(2, 2).rng().next_u64();
        assert_ne!(a, b);
        // and reproducible
        assert_eq!(a, req(1, 2).rng().next_u64());
    }
}
