//! The engine-owned decode scratch arena.
//!
//! PR-3's `Engine::forward` re-allocated ~35 buffers per decode
//! iteration: the eight activation blocks, the integer-path code/scale
//! buffers, a transposed-output staging buffer plus a nibble-unpack
//! tile per packed GEMM, per-chunk fake-quant selection scratch, the
//! attention score rows, the softmax scratch of temperature sampling,
//! and the logits block. [`DecodeScratch`] owns all of them: sized once
//! at engine build for the admission-time peak (`max_lanes` decode
//! rows; a longer prompt prefill grows the arena once and it stays
//! grown), then re-lent to the kernels on every `step()`. In steady
//! state — live lanes decoding, no admission or retirement in flight —
//! a decode iteration performs **zero heap allocations** (pinned by
//! `tests/serve_scratch.rs` under the counting allocator in
//! `util::alloc`; the assertion runs at `threads = 1` because scoped
//! thread *spawns* allocate by design — the kernels themselves never
//! do).
//!
//! Buffer contents never carry information between iterations: every
//! slice is fully overwritten before it is read (the GEMMs overwrite,
//! the norms overwrite, the attention read overwrites), so arena reuse
//! is bitwise invisible. `KURTAIL_ARENA=0` (or
//! `ServeConfig::arena = Some(false)`) drops and re-allocates the whole
//! arena every forward — the PR-3 allocation profile — which is what
//! `benches/serve.rs` measures `arena_speedup` against and what the
//! fresh-alloc-vs-arena equality tests pin bitwise.

use super::int4::GemmScratch;

/// `KURTAIL_ARENA` escape hatch: the persistent scratch arena is on by
/// default; set `KURTAIL_ARENA=0` to re-allocate every per-iteration
/// buffer (A/B debugging, the bench baseline). Read per engine build,
/// like `KURTAIL_INT_GEMM`.
pub fn arena_enabled() -> bool {
    arena_flag(std::env::var("KURTAIL_ARENA").ok().as_deref())
}

/// Parse rule behind [`arena_enabled`]: unset → on, `0` → off,
/// anything else → on. Split out so the rule itself is testable.
fn arena_flag(var: Option<&str>) -> bool {
    var.map(|v| v.trim() != "0").unwrap_or(true)
}

/// Every per-iteration buffer of the serving forward, owned by the
/// engine and reused across `step()` calls. Capacities only grow
/// ([`Self::ensure`]); kernels slice the exact lengths they need.
#[derive(Clone, Debug, Default)]
pub struct DecodeScratch {
    /// Residual stream (`n × d`), filled by token embedding.
    pub x: Vec<f32>,
    /// Post-norm / GEMM-output block (`n × d`).
    pub z: Vec<f32>,
    /// Q projections (`n × d`).
    pub qx: Vec<f32>,
    /// K projections (`n × d`).
    pub kx: Vec<f32>,
    /// V projections (`n × d`).
    pub vx: Vec<f32>,
    /// Attention output (`n × d`).
    pub attn: Vec<f32>,
    /// Rotation staging (`n × max(d, ff)` — R3/R4 use `n·d`, R5 `n·ff`).
    pub rot: Vec<f32>,
    /// FFN mid block (`n × ff`).
    pub mid: Vec<f32>,
    /// FFN gate block (`n × ff`, llama arch).
    pub gate: Vec<f32>,
    /// Output logits (`n × vocab`).
    pub logits: Vec<f32>,
    /// Integer-path activation codes (`n × max(d, ff)`).
    pub qcodes: Vec<i8>,
    /// Integer-path per-row activation scales (`n`).
    pub qscales: Vec<f32>,
    /// Temperature-sampling softmax scratch (`vocab` capacity).
    pub exps: Vec<f32>,
    /// Packed-GEMM staging: transposed output + per-chunk unpack tiles.
    pub gemm: GemmScratch,
    /// Per-chunk `row_scale_buf` clip-quantile selection scratch.
    pub fq_bufs: Vec<Vec<f32>>,
    /// Per-chunk attention score rows (`max_pos` capacity each).
    pub scores: Vec<Vec<f32>>,
    /// Row descriptors `(lane_slot, pos)` of the current forward.
    pub rows: Vec<(usize, usize)>,
    /// Current tokens of the decode batch.
    pub toks: Vec<i32>,
    /// Decode slot list of the current step.
    pub slots: Vec<usize>,
}

fn grow_f32(v: &mut Vec<f32>, need: usize) {
    if v.len() < need {
        v.resize(need, 0.0);
    }
}

impl DecodeScratch {
    /// Empty arena with one per-chunk scratch slot per thread.
    pub fn new(threads: usize) -> Self {
        let t = threads.max(1);
        Self {
            gemm: GemmScratch::with_threads(t),
            fq_bufs: (0..t).map(|_| Vec::new()).collect(),
            scores: (0..t).map(|_| Vec::new()).collect(),
            ..Self::default()
        }
    }

    /// Grow every buffer to cover an `n`-row forward of a
    /// `(d, ff, vocab)` model whose caches reach `max_pos` tokens.
    /// Idempotent and never shrinks; after the first call at the peak
    /// row count, subsequent calls allocate nothing.
    pub fn ensure(&mut self, n: usize, d: usize, ff: usize, vocab: usize, max_pos: usize) {
        let wide = d.max(ff);
        grow_f32(&mut self.x, n * d);
        grow_f32(&mut self.z, n * d);
        grow_f32(&mut self.qx, n * d);
        grow_f32(&mut self.kx, n * d);
        grow_f32(&mut self.vx, n * d);
        grow_f32(&mut self.attn, n * d);
        grow_f32(&mut self.rot, n * wide);
        grow_f32(&mut self.mid, n * ff);
        grow_f32(&mut self.gate, n * ff);
        grow_f32(&mut self.logits, n * vocab);
        grow_f32(&mut self.qscales, n);
        if self.qcodes.len() < n * wide {
            self.qcodes.resize(n * wide, 0);
        }
        self.exps.reserve(vocab.saturating_sub(self.exps.len()));
        self.gemm.reserve(n * wide, wide);
        for buf in &mut self.fq_bufs {
            buf.reserve(wide.saturating_sub(buf.len()));
        }
        for sc in &mut self.scores {
            sc.reserve(max_pos.saturating_sub(sc.len()));
        }
        self.rows.reserve(n.saturating_sub(self.rows.len()));
        self.toks.reserve(n.saturating_sub(self.toks.len()));
        // NOTE: `slots` is deliberately NOT reserved here. The step loop
        // mem::takes it before decode (leaving an empty placeholder) and
        // `ensure` runs while it is taken — reserving the placeholder
        // would allocate fresh capacity every step only to discard it on
        // restore. The engine reserves the real vector once at build.
    }

    /// Drop every buffer (keeping the tiny row-descriptor vectors) so
    /// the next [`Self::ensure`] re-allocates from scratch — the PR-3
    /// per-iteration allocation profile, kept behind `KURTAIL_ARENA=0`
    /// for bench A/B and the fresh-alloc-vs-arena equality tests.
    pub fn reset_buffers(&mut self) {
        let threads = self.fq_bufs.len().max(1);
        let rows = std::mem::take(&mut self.rows);
        let toks = std::mem::take(&mut self.toks);
        let slots = std::mem::take(&mut self.slots);
        *self = Self::new(threads);
        self.rows = rows;
        self.toks = toks;
        self.slots = slots;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arena_flag_parse_rule() {
        assert!(arena_flag(None), "unset must default to the arena");
        assert!(!arena_flag(Some("0")));
        assert!(!arena_flag(Some(" 0 ")));
        assert!(arena_flag(Some("1")));
        assert!(arena_flag(Some("")));
        assert!(arena_flag(Some("off")), "only literal 0 disables");
    }

    #[test]
    fn ensure_grows_once_and_never_shrinks() {
        let mut s = DecodeScratch::new(4);
        s.ensure(4, 8, 16, 32, 64);
        assert_eq!(s.x.len(), 32);
        assert_eq!(s.rot.len(), 4 * 16, "rot covers the wider of d/ff");
        assert_eq!(s.qcodes.len(), 4 * 16);
        assert!(s.exps.capacity() >= 32);
        assert!(s.scores.iter().all(|sc| sc.capacity() >= 64));
        // a wider call grows…
        s.ensure(9, 8, 16, 32, 64);
        assert_eq!(s.x.len(), 72);
        // …a narrower one is a no-op (slicing handles smaller batches)
        let cap = s.x.capacity();
        s.ensure(1, 8, 16, 32, 64);
        assert_eq!(s.x.len(), 72);
        assert_eq!(s.x.capacity(), cap);
    }

    #[test]
    fn reset_drops_buffers_but_keeps_descriptor_vecs() {
        let mut s = DecodeScratch::new(2);
        s.ensure(4, 8, 16, 32, 64);
        s.rows.push((0, 0));
        s.reset_buffers();
        assert!(s.x.is_empty() && s.logits.is_empty() && s.gemm.out_t.is_empty());
        assert_eq!(s.fq_bufs.len(), 2, "per-chunk slot count survives");
        assert_eq!(s.rows.len(), 1, "descriptor inputs survive a reset");
    }
}
