//! The engine-owned decode scratch arena.
//!
//! PR-3's `Engine::forward` re-allocated ~35 buffers per decode
//! iteration: the eight activation blocks, the integer-path code/scale
//! buffers, a transposed-output staging buffer plus a nibble-unpack
//! tile per packed GEMM, per-worker fake-quant selection scratch, the
//! attention score rows, the softmax scratch of temperature sampling,
//! and the logits block. [`DecodeScratch`] owns all of them: sized once
//! at engine build for the admission-time peak (`max_lanes` decode
//! rows; a longer prompt prefill grows the arena once), then re-lent to
//! the kernels on every `step()`. In steady state — live lanes
//! decoding, no admission or retirement in flight — a decode iteration
//! performs **zero heap allocations** (pinned by
//! `tests/serve_scratch.rs` under the counting allocator in
//! `util::alloc`; the assertion runs at `threads = 1` because thread
//! *spawns* (scoped) and pool job injection (work-stealing) allocate by
//! design — the kernels themselves never do, on either backend).
//!
//! **High-water-mark decay.** Grown-only sizing meant a single
//! long-prompt prefill pinned its peak forever. The arena now tracks
//! the rows each forward actually uses: after
//! [`DecodeScratch::set_decay`]`(n)` consecutive forwards that needed
//! fewer rows than the buffers hold (default
//! [`DEFAULT_DECAY_STEPS`], `KURTAIL_SCRATCH_DECAY` /
//! `ServeConfig::scratch_decay` override, `0` disables), the buffers
//! shrink to the **live-lane peak** of that idle window and the freed
//! bytes return to the allocator. Decay never fires while the peak is
//! in use, so steady-state decode at a constant lane count stays
//! allocation-free; after a decay, the next larger forward simply grows
//! the arena again (one allocation burst, off the steady-state path).
//!
//! Buffer contents never carry information between iterations: every
//! slice is fully overwritten before it is read (the GEMMs overwrite,
//! the norms overwrite, the attention read overwrites), so arena reuse
//! is bitwise invisible. `KURTAIL_ARENA=0` (or
//! `ServeConfig::arena = Some(false)`) drops and re-allocates the whole
//! arena every forward — the PR-3 allocation profile — which is what
//! `benches/serve.rs` measures `arena_speedup` against and what the
//! fresh-alloc-vs-arena equality tests pin bitwise.

use super::int4::GemmScratch;

/// `KURTAIL_ARENA` escape hatch: the persistent scratch arena is on by
/// default; set `KURTAIL_ARENA=0` to re-allocate every per-iteration
/// buffer (A/B debugging, the bench baseline). Read per engine build,
/// like `KURTAIL_INT_GEMM`.
pub fn arena_enabled() -> bool {
    arena_flag(std::env::var("KURTAIL_ARENA").ok().as_deref())
}

/// Parse rule behind [`arena_enabled`]: unset → on, `0` → off,
/// anything else → on. Split out so the rule itself is testable.
fn arena_flag(var: Option<&str>) -> bool {
    var.map(|v| v.trim() != "0").unwrap_or(true)
}

/// Default idle-forward count before the arena decays to its live-lane
/// peak (`KURTAIL_SCRATCH_DECAY` / `ServeConfig::scratch_decay`
/// override; `0` disables decay).
pub const DEFAULT_DECAY_STEPS: usize = 64;

/// `KURTAIL_SCRATCH_DECAY` rule: unset or empty → [`DEFAULT_DECAY_STEPS`],
/// `0` → decay off, any other integer → that many idle forwards.
/// An unparseable value falls back to the default (decay is a memory
/// *bound*, so garbage must not silently disable it).
pub fn scratch_decay_default() -> usize {
    decay_flag(std::env::var("KURTAIL_SCRATCH_DECAY").ok().as_deref())
}

/// Parse rule behind [`scratch_decay_default`], split out for tests.
fn decay_flag(var: Option<&str>) -> usize {
    match var {
        None => DEFAULT_DECAY_STEPS,
        Some(v) => {
            let t = v.trim();
            if t.is_empty() {
                DEFAULT_DECAY_STEPS
            } else {
                t.parse::<usize>().unwrap_or(DEFAULT_DECAY_STEPS)
            }
        }
    }
}

/// Every per-iteration buffer of the serving forward, owned by the
/// engine and reused across `step()` calls. Capacities grow on demand
/// ([`Self::ensure`]) and shrink only through the high-water-mark decay
/// ([`Self::maybe_decay`]); kernels slice the exact lengths they need.
#[derive(Clone, Debug, Default)]
pub struct DecodeScratch {
    /// Residual stream (`n × d`), filled by token embedding.
    pub x: Vec<f32>,
    /// Post-norm / GEMM-output block (`n × d`; column-major `(d × n)`
    /// when the fused epilogue routes a GEMM straight into a fused
    /// consumer — the length is the same either way).
    pub z: Vec<f32>,
    /// Q projections (`n × d`).
    pub qx: Vec<f32>,
    /// K projections (`n × d`).
    pub kx: Vec<f32>,
    /// V projections (`n × d`).
    pub vx: Vec<f32>,
    /// Attention output (`n × d`).
    pub attn: Vec<f32>,
    /// Rotation / transpose staging (`n × max(d, ff)` — R3/R4 use `n·d`,
    /// R5 and the fused-epilogue FFN transpose use `n·ff`).
    pub rot: Vec<f32>,
    /// FFN mid block (`n × ff`; `(ff × n)` column-major under the fused
    /// epilogue until the pre-R5 transpose).
    pub mid: Vec<f32>,
    /// FFN gate block (`n × ff`, llama arch; column-major like `mid`).
    pub gate: Vec<f32>,
    /// Output logits (`n × vocab`; `(vocab × n)` column-major under the
    /// fused epilogue).
    pub logits: Vec<f32>,
    /// Integer-path activation codes (`n × max(d, ff)`).
    pub qcodes: Vec<i8>,
    /// Integer-path per-row activation scales (`n`).
    pub qscales: Vec<f32>,
    /// Temperature-sampling softmax scratch (`vocab` capacity).
    pub exps: Vec<f32>,
    /// One gathered logits column (`vocab` floats) for sampling from a
    /// column-major logits block.
    pub lrow: Vec<f32>,
    /// Per-lane running argmax values over a column-major logits block.
    pub arg_best: Vec<f32>,
    /// Per-lane argmax indices (`n`).
    pub arg_idx: Vec<i32>,
    /// Packed-GEMM staging: transposed output + per-worker unpack tiles.
    pub gemm: GemmScratch,
    /// Per-worker `row_scale_buf` clip-quantile selection scratch.
    pub fq_bufs: Vec<Vec<f32>>,
    /// Per-worker attention score rows (`max_pos` capacity each).
    pub scores: Vec<Vec<f32>>,
    /// Row descriptors `(lane_slot, pos)` of the current forward.
    pub rows: Vec<(usize, usize)>,
    /// Current tokens of the decode batch.
    pub toks: Vec<i32>,
    /// Decode slot list of the current step.
    pub slots: Vec<usize>,
    /// Rows the f32 blocks are currently sized for (the high-water mark).
    sized_rows: usize,
    /// Idle forwards before decay (0 = decay off).
    decay_after: usize,
    /// Consecutive forwards that needed fewer rows than `sized_rows`.
    idle_steps: usize,
    /// Largest row count seen inside the current idle window.
    window_rows: usize,
}

fn grow_f32(v: &mut Vec<f32>, need: usize) {
    if v.len() < need {
        v.resize(need, 0.0);
    }
}

fn shrink_f32(v: &mut Vec<f32>, keep: usize) {
    if v.len() > keep {
        v.truncate(keep);
        v.shrink_to_fit();
    }
}

impl DecodeScratch {
    /// Empty arena with one per-worker scratch slot per thread.
    pub fn new(threads: usize) -> Self {
        let t = threads.max(1);
        Self {
            gemm: GemmScratch::with_threads(t),
            fq_bufs: (0..t).map(|_| Vec::new()).collect(),
            scores: (0..t).map(|_| Vec::new()).collect(),
            ..Self::default()
        }
    }

    /// Arm (or disarm, with `0`) the high-water-mark decay.
    pub fn set_decay(&mut self, idle_forwards: usize) {
        self.decay_after = idle_forwards;
        self.idle_steps = 0;
        self.window_rows = 0;
    }

    /// Rows the f32 blocks currently hold capacity for (tests, stats).
    pub fn sized_rows(&self) -> usize {
        self.sized_rows
    }

    /// Grow every buffer to cover an `n`-row forward of a
    /// `(d, ff, vocab)` model whose caches reach `max_pos` tokens.
    /// Idempotent; after a call at the peak row count, subsequent calls
    /// at or below it allocate nothing.
    pub fn ensure(&mut self, n: usize, d: usize, ff: usize, vocab: usize, max_pos: usize) {
        let wide = d.max(ff);
        self.sized_rows = self.sized_rows.max(n);
        grow_f32(&mut self.x, n * d);
        grow_f32(&mut self.z, n * d);
        grow_f32(&mut self.qx, n * d);
        grow_f32(&mut self.kx, n * d);
        grow_f32(&mut self.vx, n * d);
        grow_f32(&mut self.attn, n * d);
        grow_f32(&mut self.rot, n * wide);
        grow_f32(&mut self.mid, n * ff);
        grow_f32(&mut self.gate, n * ff);
        grow_f32(&mut self.logits, n * vocab);
        grow_f32(&mut self.qscales, n);
        grow_f32(&mut self.arg_best, n);
        if self.arg_idx.len() < n {
            self.arg_idx.resize(n, 0);
        }
        if self.qcodes.len() < n * wide {
            self.qcodes.resize(n * wide, 0);
        }
        self.exps.reserve(vocab.saturating_sub(self.exps.len()));
        self.lrow.reserve(vocab.saturating_sub(self.lrow.len()));
        self.gemm.reserve(n * wide, wide);
        for buf in &mut self.fq_bufs {
            buf.reserve(wide.saturating_sub(buf.len()));
        }
        for sc in &mut self.scores {
            sc.reserve(max_pos.saturating_sub(sc.len()));
        }
        self.rows.reserve(n.saturating_sub(self.rows.len()));
        self.toks.reserve(n.saturating_sub(self.toks.len()));
        // NOTE: `slots` is deliberately NOT reserved here. The step loop
        // mem::takes it before decode (leaving an empty placeholder) and
        // `ensure` runs while it is taken — reserving the placeholder
        // would allocate fresh capacity every step only to discard it on
        // restore. The engine reserves the real vector once at build.
    }

    /// High-water-mark decay bookkeeping, called once per forward with
    /// the rows that forward needs (before [`Self::ensure`]). A forward
    /// at the current peak resets the idle window; after `decay_after`
    /// consecutive below-peak forwards the row-proportional buffers
    /// shrink to the window's live-lane peak and release the excess.
    /// Purely a capacity change — every buffer is fully overwritten
    /// before use, so decode streams are bitwise unaffected.
    pub fn maybe_decay(&mut self, rows_needed: usize, d: usize, ff: usize, vocab: usize) {
        if self.decay_after == 0 {
            return;
        }
        if rows_needed >= self.sized_rows {
            self.idle_steps = 0;
            self.window_rows = 0;
            return;
        }
        self.window_rows = self.window_rows.max(rows_needed);
        self.idle_steps += 1;
        if self.idle_steps < self.decay_after {
            return;
        }
        let keep = self.window_rows.max(1);
        let wide = d.max(ff);
        shrink_f32(&mut self.x, keep * d);
        shrink_f32(&mut self.z, keep * d);
        shrink_f32(&mut self.qx, keep * d);
        shrink_f32(&mut self.kx, keep * d);
        shrink_f32(&mut self.vx, keep * d);
        shrink_f32(&mut self.attn, keep * d);
        shrink_f32(&mut self.rot, keep * wide);
        shrink_f32(&mut self.mid, keep * ff);
        shrink_f32(&mut self.gate, keep * ff);
        shrink_f32(&mut self.logits, keep * vocab);
        shrink_f32(&mut self.qscales, keep);
        shrink_f32(&mut self.arg_best, keep);
        if self.arg_idx.len() > keep {
            self.arg_idx.truncate(keep);
            self.arg_idx.shrink_to_fit();
        }
        if self.qcodes.len() > keep * wide {
            self.qcodes.truncate(keep * wide);
            self.qcodes.shrink_to_fit();
        }
        self.gemm.shrink(keep * wide);
        self.sized_rows = keep;
        self.idle_steps = 0;
        self.window_rows = 0;
    }

    /// Drop every buffer (keeping the tiny row-descriptor vectors) so
    /// the next [`Self::ensure`] re-allocates from scratch — the PR-3
    /// per-iteration allocation profile, kept behind `KURTAIL_ARENA=0`
    /// for bench A/B and the fresh-alloc-vs-arena equality tests.
    pub fn reset_buffers(&mut self) {
        let threads = self.fq_bufs.len().max(1);
        let rows = std::mem::take(&mut self.rows);
        let toks = std::mem::take(&mut self.toks);
        let slots = std::mem::take(&mut self.slots);
        let decay = self.decay_after;
        *self = Self::new(threads);
        self.rows = rows;
        self.toks = toks;
        self.slots = slots;
        self.decay_after = decay;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arena_flag_parse_rule() {
        assert!(arena_flag(None), "unset must default to the arena");
        assert!(!arena_flag(Some("0")));
        assert!(!arena_flag(Some(" 0 ")));
        assert!(arena_flag(Some("1")));
        assert!(arena_flag(Some("")));
        assert!(arena_flag(Some("off")), "only literal 0 disables");
    }

    #[test]
    fn decay_flag_parse_rule() {
        assert_eq!(decay_flag(None), DEFAULT_DECAY_STEPS, "unset defaults on");
        assert_eq!(decay_flag(Some("0")), 0, "literal 0 disables");
        assert_eq!(decay_flag(Some(" 8 ")), 8);
        assert_eq!(decay_flag(Some("")), DEFAULT_DECAY_STEPS);
        // a memory *bound* must not silently vanish on garbage
        assert_eq!(decay_flag(Some("lots")), DEFAULT_DECAY_STEPS);
    }

    #[test]
    fn ensure_grows_once_and_never_shrinks_without_decay() {
        let mut s = DecodeScratch::new(4);
        s.ensure(4, 8, 16, 32, 64);
        assert_eq!(s.x.len(), 32);
        assert_eq!(s.rot.len(), 4 * 16, "rot covers the wider of d/ff");
        assert_eq!(s.qcodes.len(), 4 * 16);
        assert!(s.exps.capacity() >= 32);
        assert!(s.scores.iter().all(|sc| sc.capacity() >= 64));
        assert_eq!(s.sized_rows(), 4);
        // a wider call grows…
        s.ensure(9, 8, 16, 32, 64);
        assert_eq!(s.x.len(), 72);
        assert_eq!(s.sized_rows(), 9);
        // …a narrower one is a no-op (slicing handles smaller batches)
        let cap = s.x.capacity();
        s.ensure(1, 8, 16, 32, 64);
        assert_eq!(s.x.len(), 72);
        assert_eq!(s.x.capacity(), cap);
        // decay disarmed by default: idle forwards never shrink
        for _ in 0..200 {
            s.maybe_decay(1, 8, 16, 32);
        }
        assert_eq!(s.x.len(), 72);
    }

    #[test]
    fn decay_shrinks_to_live_lane_peak_after_idle_window() {
        let (d, ff, v) = (8usize, 16usize, 32usize);
        let mut s = DecodeScratch::new(2);
        s.set_decay(3);
        // a long-prompt burst pins the peak…
        s.ensure(40, d, ff, v, 64);
        assert_eq!(s.sized_rows(), 40);
        assert_eq!(s.logits.len(), 40 * v);
        // …steady decode at 2–3 live lanes decays it after 3 idle steps
        for rows in [2usize, 3, 2] {
            s.maybe_decay(rows, d, ff, v);
            s.ensure(rows, d, ff, v, 64);
        }
        assert_eq!(s.sized_rows(), 3, "shrunk to the idle window's live-lane peak");
        assert_eq!(s.x.len(), 3 * d);
        assert_eq!(s.logits.len(), 3 * v);
        assert!(s.x.capacity() < 40 * d, "excess capacity released");
        // a peak-sized forward resets the window instead of decaying
        s.ensure(5, d, ff, v, 64);
        for _ in 0..2 {
            s.maybe_decay(2, d, ff, v);
        }
        s.maybe_decay(5, d, ff, v); // at peak → window resets
        for _ in 0..2 {
            s.maybe_decay(2, d, ff, v);
        }
        assert_eq!(s.sized_rows(), 5, "window reset by a peak forward");
        s.maybe_decay(2, d, ff, v);
        assert_eq!(s.sized_rows(), 2, "third consecutive idle forward decays");
    }

    #[test]
    fn reset_drops_buffers_but_keeps_descriptor_vecs() {
        let mut s = DecodeScratch::new(2);
        s.set_decay(7);
        s.ensure(4, 8, 16, 32, 64);
        s.rows.push((0, 0));
        s.reset_buffers();
        assert!(s.x.is_empty() && s.logits.is_empty() && s.gemm.out_t.is_empty());
        assert_eq!(s.fq_bufs.len(), 2, "per-worker slot count survives");
        assert_eq!(s.rows.len(), 1, "descriptor inputs survive a reset");
        assert_eq!(s.decay_after, 7, "decay config survives a reset");
    }
}
