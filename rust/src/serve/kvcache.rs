//! Paged 4-bit KV cache: a block-pool allocator over fixed-size token
//! blocks, replacing the dense `[l, b, tmax, h, dh]` f32 caches of the
//! artifact decode path on the serving side.
//!
//! * **Blocks.** The pool owns `max_blocks` fixed-size blocks of
//!   `block_tokens` tokens × `h` heads. A sequence holds one block list
//!   per (layer, K|V); blocks are claimed on first write into a fresh
//!   token slot and returned wholesale on retirement, so concurrent
//!   sequences of different lengths share the pool with no copying.
//! * **Scales.** Quantization is per-token per-head asymmetric — one
//!   `(lo, step)` f32 pair per written `dh`-row, the exact semantics of
//!   [`crate::quant::fakequant::fake_quant_rows_asym`] (and of the
//!   `kv_fake_quant` the AOT decode graphs simulate): `step = (hi −
//!   lo).max(1e-8)/15`, codes in `[0, 15]`, dequant `q·step + lo`. The
//!   pool's dequantized reads therefore reproduce bit-for-bit what the
//!   quant decode artifact keeps in its dense f32 cache.
//! * **Append-quantize / fused read.** [`KvPool::append`] quantizes on
//!   write; [`KvPool::attend`] runs the whole attention read
//!   (scores → softmax → weighted V sum) against the packed bytes,
//!   dequantizing on the fly — the dense K/V for a sequence never
//!   exists in memory. Per (head, element) the accumulation order is
//!   fixed ascending over cache positions, so reads are bitwise
//!   deterministic regardless of thread count or lane batching.
//! * **Fp mode.** [`KvQuant::Fp`] stores raw f32 rows in the same block
//!   structure — the apples-to-apples baseline for `BENCH_serve.json`'s
//!   bytes/token comparison and the exactness mode of the serve engine.

use crate::config::KvQuant;

use super::error::ServeError;

/// 4-bit asymmetric grid size (2^4 − 1 levels).
const LEVELS: f32 = 15.0;

/// One sequence's handle into the pool: per-layer block lists for K and
/// V plus the per-layer append cursor (all layers advance in lockstep
/// during a decode step, so the cursors only differ transiently).
#[derive(Clone, Debug, Default)]
pub struct SeqKv {
    k_blocks: Vec<Vec<u32>>,
    v_blocks: Vec<Vec<u32>>,
    appended: Vec<usize>,
}

impl SeqKv {
    pub fn new(n_layers: usize) -> Self {
        Self {
            k_blocks: vec![Vec::new(); n_layers],
            v_blocks: vec![Vec::new(); n_layers],
            appended: vec![0; n_layers],
        }
    }

    /// [`Self::new`] with every per-(layer, side) block list
    /// pre-reserved for `blocks_per_list` entries, so appends up to that
    /// many blocks never reallocate. The engine reserves the
    /// admission-time worst case here, which keeps block-boundary
    /// crossings inside steady-state decode allocation-free.
    pub fn with_capacity(n_layers: usize, blocks_per_list: usize) -> Self {
        // `vec![Vec::with_capacity(..); n]` would clone away the
        // capacity — build each list explicitly
        Self {
            k_blocks: (0..n_layers).map(|_| Vec::with_capacity(blocks_per_list)).collect(),
            v_blocks: (0..n_layers).map(|_| Vec::with_capacity(blocks_per_list)).collect(),
            appended: vec![0; n_layers],
        }
    }

    /// Tokens appended at `layer` so far.
    pub fn len(&self, layer: usize) -> usize {
        self.appended[layer]
    }

    pub fn is_empty(&self) -> bool {
        self.appended.iter().all(|&n| n == 0)
    }

    /// Blocks currently held across all layers (K + V).
    pub fn blocks_held(&self) -> usize {
        self.k_blocks.iter().chain(&self.v_blocks).map(|b| b.len()).sum()
    }
}

/// The shared block pool. One pool serves every layer of every live
/// sequence; block ids index fixed strides into the backing buffers.
pub struct KvPool {
    pub mode: KvQuant,
    pub h: usize,
    pub dh: usize,
    pub block_tokens: usize,
    pub max_blocks: usize,
    /// bytes per packed (token, head) row: ⌈dh/2⌉ (4-bit mode).
    bpr: usize,
    /// packed codes, `max_blocks × block_tokens·h·bpr` (4-bit mode).
    data: Vec<u8>,
    /// `(lo, step)` per (block, token, head) (4-bit mode).
    scales: Vec<f32>,
    /// raw rows, `max_blocks × block_tokens·h·dh` (fp mode).
    fdata: Vec<f32>,
    free: Vec<u32>,
}

impl KvPool {
    pub fn new(mode: KvQuant, h: usize, dh: usize, block_tokens: usize, max_blocks: usize) -> Self {
        assert!(h > 0 && dh > 0 && block_tokens > 0 && max_blocks > 0);
        let bpr = (dh + 1) / 2;
        let (data, scales, fdata) = match mode {
            KvQuant::Asym4 => (
                vec![0u8; max_blocks * block_tokens * h * bpr],
                vec![0.0f32; max_blocks * block_tokens * h * 2],
                Vec::new(),
            ),
            KvQuant::Fp => (Vec::new(), Vec::new(), vec![0.0f32; max_blocks * block_tokens * h * dh]),
        };
        let free = (0..max_blocks as u32).rev().collect();
        Self { mode, h, dh, block_tokens, max_blocks, bpr, data, scales, fdata, free }
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Blocks currently held by live lanes (the occupancy gauge's
    /// complement of [`free_blocks`](Self::free_blocks); no allocation,
    /// safe on the decode hot path).
    pub fn used_blocks(&self) -> usize {
        self.max_blocks - self.free.len()
    }

    /// Blocks a sequence of `total_tokens` will claim across `n_layers`
    /// (K and V) — the scheduler's admission currency.
    pub fn blocks_needed(&self, n_layers: usize, total_tokens: usize) -> usize {
        n_layers * 2 * ((total_tokens + self.block_tokens - 1) / self.block_tokens)
    }

    /// Pool bytes consumed per stored token per layer (K + V, including
    /// scale metadata).
    pub fn bytes_per_token_layer(&self) -> usize {
        match self.mode {
            KvQuant::Asym4 => 2 * (self.h * self.bpr + self.h * 2 * 4),
            KvQuant::Fp => 2 * self.h * self.dh * 4,
        }
    }

    fn alloc(&mut self) -> Result<u32, ServeError> {
        let free = self.free.len();
        self.free.pop().ok_or(ServeError::PoolExhausted { needed: 1, free })
    }

    /// Append-quantize one token's K and V rows (`h·dh` f32s each) for
    /// `layer` at position `pos`. Positions must be appended in order.
    /// Failures are typed and leak-free: [`ServeError::PoolExhausted`]
    /// claims nothing (the K/V block pair is checked before either
    /// allocates), so the caller can release the sequence and retry.
    pub fn append(
        &mut self,
        seq: &mut SeqKv,
        layer: usize,
        pos: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) -> Result<(), ServeError> {
        assert_eq!(k_row.len(), self.h * self.dh);
        assert_eq!(v_row.len(), self.h * self.dh);
        if pos != seq.appended[layer] {
            return Err(ServeError::Internal(format!(
                "kv append out of order: pos {pos} != cursor {}",
                seq.appended[layer]
            )));
        }
        if pos % self.block_tokens == 0 {
            // claim the K/V pair atomically so a failure leaks nothing
            if self.free.len() < 2 {
                return Err(ServeError::PoolExhausted { needed: 2, free: self.free.len() });
            }
            let kb = self.alloc()?;
            let vb = self.alloc()?;
            seq.k_blocks[layer].push(kb);
            seq.v_blocks[layer].push(vb);
        }
        let kb = seq.k_blocks[layer][pos / self.block_tokens];
        let vb = seq.v_blocks[layer][pos / self.block_tokens];
        let tb = pos % self.block_tokens;
        self.write_token(kb, tb, k_row);
        self.write_token(vb, tb, v_row);
        seq.appended[layer] = pos + 1;
        Ok(())
    }

    fn write_token(&mut self, blk: u32, tb: usize, row_heads: &[f32]) {
        let blk = blk as usize;
        match self.mode {
            KvQuant::Fp => {
                let base = (blk * self.block_tokens + tb) * self.h * self.dh;
                self.fdata[base..base + self.h * self.dh].copy_from_slice(row_heads);
            }
            KvQuant::Asym4 => {
                for head in 0..self.h {
                    let row = &row_heads[head * self.dh..(head + 1) * self.dh];
                    // exactly fake_quant_rows_asym's per-row grid
                    let lo = row.iter().cloned().fold(f32::INFINITY, f32::min);
                    let hi = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let step = (hi - lo).max(1e-8) / LEVELS;
                    let sbase = (blk * self.block_tokens + tb) * self.h * 2 + head * 2;
                    self.scales[sbase] = lo;
                    self.scales[sbase + 1] = step;
                    let base = ((blk * self.block_tokens + tb) * self.h + head) * self.bpr;
                    for (e, &v) in row.iter().enumerate() {
                        let q = (((v - lo) / step).round().clamp(0.0, LEVELS)) as u8;
                        let byte = &mut self.data[base + e / 2];
                        if e % 2 == 0 {
                            *byte = (*byte & 0xF0) | q;
                        } else {
                            *byte = (*byte & 0x0F) | (q << 4);
                        }
                    }
                }
            }
        }
    }

    /// Dequantized element `e` of head `head` at cache position `t`.
    #[inline]
    fn read(&self, blocks: &[u32], t: usize, head: usize, e: usize) -> f32 {
        let blk = blocks[t / self.block_tokens] as usize;
        let tb = t % self.block_tokens;
        match self.mode {
            KvQuant::Fp => self.fdata[((blk * self.block_tokens + tb) * self.h + head) * self.dh + e],
            KvQuant::Asym4 => {
                let b = self.data[((blk * self.block_tokens + tb) * self.h + head) * self.bpr + e / 2];
                let q = if e % 2 == 0 { b & 0x0F } else { b >> 4 };
                let sbase = (blk * self.block_tokens + tb) * self.h * 2 + head * 2;
                q as f32 * self.scales[sbase + 1] + self.scales[sbase]
            }
        }
    }

    /// One (token, head) row dequantized (tests / debugging).
    pub fn read_k_row(&self, seq: &SeqKv, layer: usize, t: usize, head: usize) -> Vec<f32> {
        (0..self.dh).map(|e| self.read(&seq.k_blocks[layer], t, head, e)).collect()
    }

    pub fn read_v_row(&self, seq: &SeqKv, layer: usize, t: usize, head: usize) -> Vec<f32> {
        (0..self.dh).map(|e| self.read(&seq.v_blocks[layer], t, head, e)).collect()
    }

    /// Fused dequant-attention over the first `len` cached positions of
    /// `layer`: `out[h·dh] = softmax(q·Kᵀ/√dh)·V`, reading K and V
    /// straight from the packed blocks. `scores` is a caller scratch
    /// buffer (resized to `len`).
    pub fn attend(&self, seq: &SeqKv, layer: usize, len: usize, q: &[f32], out: &mut [f32], scores: &mut Vec<f32>) {
        assert_eq!(q.len(), self.h * self.dh);
        assert_eq!(out.len(), self.h * self.dh);
        assert!(len >= 1 && len <= seq.appended[layer], "attend len {len} vs cached {}", seq.appended[layer]);
        let inv_sqrt = 1.0 / (self.dh as f32).sqrt();
        let kb = &seq.k_blocks[layer];
        let vb = &seq.v_blocks[layer];
        scores.resize(len, 0.0);
        for head in 0..self.h {
            let qh = &q[head * self.dh..(head + 1) * self.dh];
            for (t, s) in scores.iter_mut().enumerate() {
                let mut dot = 0.0f32;
                for (e, &qv) in qh.iter().enumerate() {
                    dot += qv * self.read(kb, t, head, e);
                }
                *s = dot * inv_sqrt;
            }
            let max = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut total = 0.0f32;
            for s in scores.iter_mut() {
                *s = (*s - max).exp();
                total += *s;
            }
            for s in scores.iter_mut() {
                *s /= total;
            }
            let oh = &mut out[head * self.dh..(head + 1) * self.dh];
            oh.fill(0.0);
            for (t, &p) in scores.iter().enumerate() {
                for (e, o) in oh.iter_mut().enumerate() {
                    *o += p * self.read(vb, t, head, e);
                }
            }
        }
    }

    /// Return every block a sequence holds to the free list.
    pub fn release(&mut self, seq: &mut SeqKv) {
        for list in seq.k_blocks.iter_mut().chain(seq.v_blocks.iter_mut()) {
            self.free.extend(list.drain(..));
        }
        for a in &mut seq.appended {
            *a = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::QuantScheme;
    use crate::quant::fakequant::fake_quant_rows_asym;
    use crate::tensor::Tensor;
    use crate::util::Rng;

    fn fill_seq(pool: &mut KvPool, seq: &mut SeqKv, layer: usize, rows: &[(Vec<f32>, Vec<f32>)]) {
        for (t, (k, v)) in rows.iter().enumerate() {
            pool.append(seq, layer, t, k, v).unwrap();
        }
    }

    fn rand_rows(n: usize, w: usize, rng: &mut Rng) -> Vec<(Vec<f32>, Vec<f32>)> {
        (0..n)
            .map(|_| {
                (
                    (0..w).map(|_| rng.normal()).collect(),
                    (0..w).map(|_| rng.normal()).collect(),
                )
            })
            .collect()
    }

    #[test]
    fn roundtrip_matches_fake_quant_asym() {
        let mut rng = Rng::new(0);
        let (h, dh, bt) = (2, 5, 3); // odd dh pads nibbles; bt=3 hits boundaries
        let mut pool = KvPool::new(KvQuant::Asym4, h, dh, bt, 16);
        let mut seq = SeqKv::new(1);
        let rows = rand_rows(8, h * dh, &mut rng);
        fill_seq(&mut pool, &mut seq, 0, &rows);
        assert_eq!(seq.len(0), 8);
        for (t, (k, _)) in rows.iter().enumerate() {
            let want = fake_quant_rows_asym(
                &Tensor::new(k.clone(), vec![h, dh]),
                &QuantScheme::kv4(),
            );
            for head in 0..h {
                assert_eq!(pool.read_k_row(&seq, 0, t, head), want.row(head), "t={t} h={head}");
            }
        }
    }

    #[test]
    fn fp_mode_is_exact() {
        let mut rng = Rng::new(1);
        let (h, dh, bt) = (2, 4, 4);
        let mut pool = KvPool::new(KvQuant::Fp, h, dh, bt, 8);
        let mut seq = SeqKv::new(1);
        let rows = rand_rows(6, h * dh, &mut rng);
        fill_seq(&mut pool, &mut seq, 0, &rows);
        for (t, (_, v)) in rows.iter().enumerate() {
            for head in 0..h {
                assert_eq!(pool.read_v_row(&seq, 0, t, head), v[head * dh..(head + 1) * dh]);
            }
        }
    }

    #[test]
    fn attend_matches_naive_on_dequantized_cache() {
        let mut rng = Rng::new(2);
        let (h, dh, bt) = (2, 6, 3);
        let mut pool = KvPool::new(KvQuant::Asym4, h, dh, bt, 16);
        let mut seq = SeqKv::new(1);
        let rows = rand_rows(7, h * dh, &mut rng);
        fill_seq(&mut pool, &mut seq, 0, &rows);
        let q: Vec<f32> = (0..h * dh).map(|_| rng.normal()).collect();
        let mut out = vec![0.0f32; h * dh];
        let mut scratch = Vec::new();
        pool.attend(&seq, 0, 7, &q, &mut out, &mut scratch);
        for head in 0..h {
            let qh = &q[head * dh..(head + 1) * dh];
            let scores: Vec<f32> = (0..7)
                .map(|t| {
                    let kr = pool.read_k_row(&seq, 0, t, head);
                    qh.iter().zip(&kr).map(|(a, b)| a * b).sum::<f32>() / (dh as f32).sqrt()
                })
                .collect();
            let max = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = scores.iter().map(|s| (s - max).exp()).collect();
            let total: f32 = exps.iter().sum();
            for e in 0..dh {
                let want: f32 = (0..7)
                    .map(|t| exps[t] / total * pool.read_v_row(&seq, 0, t, head)[e])
                    .sum();
                assert!((out[head * dh + e] - want).abs() < 1e-4, "h={head} e={e}");
            }
        }
    }

    #[test]
    fn pool_allocates_and_releases() {
        let (h, dh, bt) = (1, 4, 2);
        let mut pool = KvPool::new(KvQuant::Asym4, h, dh, bt, 6);
        assert_eq!(pool.blocks_needed(1, 5), 2 * 3); // K+V × ceil(5/2)
        let mut seq = SeqKv::new(1);
        let row = vec![0.5f32; h * dh];
        for t in 0..6 {
            pool.append(&mut seq, 0, t, &row, &row).unwrap();
        }
        assert_eq!(seq.blocks_held(), 6);
        assert_eq!(pool.free_blocks(), 0);
        // exhausted: a 7th token needs a fresh block pair — the typed
        // error claims nothing, so release still returns exactly 6
        let err = pool.append(&mut seq, 0, 6, &row, &row).unwrap_err();
        assert_eq!(err, ServeError::PoolExhausted { needed: 2, free: 0 });
        assert_eq!(seq.blocks_held(), 6, "failed append must not claim blocks");
        pool.release(&mut seq);
        assert_eq!(pool.free_blocks(), 6);
        assert_eq!(seq.blocks_held(), 0);
        assert!(seq.is_empty());
    }

    #[test]
    fn bytes_per_token_accounting() {
        let pool4 = KvPool::new(KvQuant::Asym4, 8, 64, 16, 4);
        let poolf = KvPool::new(KvQuant::Fp, 8, 64, 16, 4);
        assert_eq!(pool4.bytes_per_token_layer(), 2 * (8 * 32 + 8 * 8));
        assert_eq!(poolf.bytes_per_token_layer(), 2 * 8 * 64 * 4);
        let ratio = poolf.bytes_per_token_layer() as f64 / pool4.bytes_per_token_layer() as f64;
        assert!(ratio >= 6.0, "dh=64 must give ≥6x reduction, got {ratio}");
    }
}
