//! Paged 4-bit KV cache: a block-pool allocator over fixed-size token
//! blocks, replacing the dense `[l, b, tmax, h, dh]` f32 caches of the
//! artifact decode path on the serving side.
//!
//! * **Blocks.** The pool owns `max_blocks` fixed-size blocks of
//!   `block_tokens` tokens × `h` heads. A sequence holds one block list
//!   per (layer, K|V); blocks are claimed on first write into a fresh
//!   token slot and returned wholesale on retirement, so concurrent
//!   sequences of different lengths share the pool with no copying.
//! * **Scales.** Quantization is per-token per-head asymmetric — one
//!   `(lo, step)` f32 pair per written `dh`-row, the exact semantics of
//!   [`crate::quant::fakequant::fake_quant_rows_asym`] (and of the
//!   `kv_fake_quant` the AOT decode graphs simulate): `step = (hi −
//!   lo).max(1e-8)/15`, codes in `[0, 15]`, dequant `q·step + lo`. The
//!   pool's dequantized reads therefore reproduce bit-for-bit what the
//!   quant decode artifact keeps in its dense f32 cache.
//! * **Append-quantize / fused read.** [`KvPool::append`] quantizes on
//!   write; [`KvPool::attend`] runs the whole attention read
//!   (scores → softmax → weighted V sum) against the packed bytes,
//!   dequantizing on the fly — the dense K/V for a sequence never
//!   exists in memory. Per (head, element) the accumulation order is
//!   fixed ascending over cache positions, so reads are bitwise
//!   deterministic regardless of thread count or lane batching.
//! * **Fp mode.** [`KvQuant::Fp`] stores raw f32 rows in the same block
//!   structure — the apples-to-apples baseline for `BENCH_serve.json`'s
//!   bytes/token comparison and the exactness mode of the serve engine.
//! * **Reference counting + prefix sharing.** Because quantization is
//!   per-token per-head, a block's bytes are a pure function of the
//!   token prefix that produced it (K/V at position *t* depends only on
//!   `tokens[0..=t]` under causal attention) — so two lanes whose
//!   prompts share a prefix can share the *same* physical blocks.
//!   Every block carries a refcount: [`KvPool::alloc`] claims at one
//!   reference, [`KvPool::retain`] bumps it for each additional holder,
//!   and [`KvPool::release_into`] returns a block to the free list only
//!   when the **last** reference retires (reporting the actually-freed
//!   ids so the caller can prune its [`PrefixIndex`]). The PR-6
//!   leak-free invariant — pool whole after any admit/cancel/EOS/drain
//!   interleaving — extends unchanged: when every holder has released,
//!   every refcount is zero and `free_blocks == max_blocks`. KV-pressure
//!   preemption (PR 10) releases a victim lane's *whole* reservation
//!   through this same last-reference path: blocks the victim shared
//!   with surviving lanes stay allocated and prefix-attachable, so
//!   preempting a sharer costs its donors (and future attachers)
//!   nothing.
//! * **Prefix index + COW tails.** [`PrefixIndex`] is a trie keyed on
//!   exact `block_tokens`-sized token chunks; each node records the
//!   per-layer K/V block ids a donor lane wrote for that chunk, plus
//!   any *partial* tail blocks (fewer than `block_tokens` prompt rows).
//!   [`PrefixIndex::attach`] maps a new lane's longest indexed prefix
//!   onto the donor blocks — full chunks by refcount bump, the partial
//!   tail by **copy-on-write**: the donor's tail block bytes are copied
//!   into fresh private blocks, after which the lane appends (and
//!   diverges) without ever touching shared bytes. The index holds *no*
//!   references of its own (weak): [`PrefixIndex::invalidate`] prunes
//!   every entry naming a freed id the moment the pool frees it, so a
//!   reused block id can never alias a stale entry.

use crate::config::KvQuant;

use super::error::ServeError;

/// 4-bit asymmetric grid size (2^4 − 1 levels).
const LEVELS: f32 = 15.0;

/// One sequence's handle into the pool: per-layer block lists for K and
/// V plus the per-layer append cursor (all layers advance in lockstep
/// during a decode step, so the cursors only differ transiently).
#[derive(Clone, Debug, Default)]
pub struct SeqKv {
    k_blocks: Vec<Vec<u32>>,
    v_blocks: Vec<Vec<u32>>,
    appended: Vec<usize>,
}

impl SeqKv {
    pub fn new(n_layers: usize) -> Self {
        Self {
            k_blocks: vec![Vec::new(); n_layers],
            v_blocks: vec![Vec::new(); n_layers],
            appended: vec![0; n_layers],
        }
    }

    /// [`Self::new`] with every per-(layer, side) block list
    /// pre-reserved for `blocks_per_list` entries, so appends up to that
    /// many blocks never reallocate. The engine reserves the
    /// admission-time worst case here, which keeps block-boundary
    /// crossings inside steady-state decode allocation-free.
    pub fn with_capacity(n_layers: usize, blocks_per_list: usize) -> Self {
        // `vec![Vec::with_capacity(..); n]` would clone away the
        // capacity — build each list explicitly
        Self {
            k_blocks: (0..n_layers).map(|_| Vec::with_capacity(blocks_per_list)).collect(),
            v_blocks: (0..n_layers).map(|_| Vec::with_capacity(blocks_per_list)).collect(),
            appended: vec![0; n_layers],
        }
    }

    /// Tokens appended at `layer` so far.
    pub fn len(&self, layer: usize) -> usize {
        self.appended[layer]
    }

    pub fn is_empty(&self) -> bool {
        self.appended.iter().all(|&n| n == 0)
    }

    /// Blocks currently held across all layers (K + V).
    pub fn blocks_held(&self) -> usize {
        self.k_blocks.iter().chain(&self.v_blocks).map(|b| b.len()).sum()
    }
}

/// The shared block pool. One pool serves every layer of every live
/// sequence; block ids index fixed strides into the backing buffers.
pub struct KvPool {
    pub mode: KvQuant,
    pub h: usize,
    pub dh: usize,
    pub block_tokens: usize,
    pub max_blocks: usize,
    /// bytes per packed (token, head) row: ⌈dh/2⌉ (4-bit mode).
    bpr: usize,
    /// packed codes, `max_blocks × block_tokens·h·bpr` (4-bit mode).
    data: Vec<u8>,
    /// `(lo, step)` per (block, token, head) (4-bit mode).
    scales: Vec<f32>,
    /// raw rows, `max_blocks × block_tokens·h·dh` (fp mode).
    fdata: Vec<f32>,
    free: Vec<u32>,
    /// per-block reference count; 0 ⇔ on the free list.
    refs: Vec<u32>,
    /// Σ over blocks of `refs − 1` — each unit is one block some lane
    /// holds without owning physical storage (the sharing win).
    shared_extra: usize,
}

impl KvPool {
    pub fn new(mode: KvQuant, h: usize, dh: usize, block_tokens: usize, max_blocks: usize) -> Self {
        assert!(h > 0 && dh > 0 && block_tokens > 0 && max_blocks > 0);
        let bpr = (dh + 1) / 2;
        let (data, scales, fdata) = match mode {
            KvQuant::Asym4 => (
                vec![0u8; max_blocks * block_tokens * h * bpr],
                vec![0.0f32; max_blocks * block_tokens * h * 2],
                Vec::new(),
            ),
            KvQuant::Fp => (Vec::new(), Vec::new(), vec![0.0f32; max_blocks * block_tokens * h * dh]),
        };
        let free = (0..max_blocks as u32).rev().collect();
        let refs = vec![0u32; max_blocks];
        Self { mode, h, dh, block_tokens, max_blocks, bpr, data, scales, fdata, free, refs, shared_extra: 0 }
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Blocks currently held by live lanes (the occupancy gauge's
    /// complement of [`free_blocks`](Self::free_blocks); no allocation,
    /// safe on the decode hot path).
    pub fn used_blocks(&self) -> usize {
        self.max_blocks - self.free.len()
    }

    /// Block references satisfied by sharing instead of fresh storage:
    /// Σ over blocks of `refs − 1`. Each unit is one physical block the
    /// pool did *not* have to allocate because a lane mapped onto a
    /// donor's prefix. O(1), safe on the decode hot path (the
    /// `kurtail_kv_shared_block_refs` gauge reads it every step).
    pub fn shared_block_refs(&self) -> usize {
        self.shared_extra
    }

    /// Blocks a sequence of `total_tokens` will claim across `n_layers`
    /// (K and V) — the scheduler's admission currency.
    pub fn blocks_needed(&self, n_layers: usize, total_tokens: usize) -> usize {
        n_layers * 2 * ((total_tokens + self.block_tokens - 1) / self.block_tokens)
    }

    /// Pool bytes consumed per stored token per layer (K + V, including
    /// scale metadata).
    pub fn bytes_per_token_layer(&self) -> usize {
        match self.mode {
            KvQuant::Asym4 => 2 * (self.h * self.bpr + self.h * 2 * 4),
            KvQuant::Fp => 2 * self.h * self.dh * 4,
        }
    }

    fn alloc(&mut self) -> Result<u32, ServeError> {
        let free = self.free.len();
        let id = self.free.pop().ok_or(ServeError::PoolExhausted { needed: 1, free })?;
        debug_assert_eq!(self.refs[id as usize], 0, "free block with live refs");
        self.refs[id as usize] = 1;
        Ok(id)
    }

    /// Bump the refcount of a live block — the sharing primitive. The
    /// caller must also push `blk` into its sequence's block list so the
    /// matching [`release_into`](Self::release_into) drops the
    /// reference.
    pub fn retain(&mut self, blk: u32) {
        debug_assert!(self.refs[blk as usize] > 0, "retain of a free block");
        self.refs[blk as usize] += 1;
        self.shared_extra += 1;
    }

    /// Copy one block's stored bytes (codes + scales, or raw f32 rows)
    /// from `src` into `dst` — the copy-on-write step for shared
    /// partial tail blocks. Rows past the donor's filled count carry
    /// stale donor bytes; the receiving lane's append cursor guarantees
    /// they are overwritten before they can be read.
    fn copy_block(&mut self, src: u32, dst: u32) {
        let (s, d) = (src as usize, dst as usize);
        match self.mode {
            KvQuant::Asym4 => {
                let cs = self.block_tokens * self.h * self.bpr;
                self.data.copy_within(s * cs..(s + 1) * cs, d * cs);
                let ss = self.block_tokens * self.h * 2;
                self.scales.copy_within(s * ss..(s + 1) * ss, d * ss);
            }
            KvQuant::Fp => {
                let fs = self.block_tokens * self.h * self.dh;
                self.fdata.copy_within(s * fs..(s + 1) * fs, d * fs);
            }
        }
    }

    /// Append-quantize one token's K and V rows (`h·dh` f32s each) for
    /// `layer` at position `pos`. Positions must be appended in order.
    /// Failures are typed and leak-free: [`ServeError::PoolExhausted`]
    /// claims nothing (the K/V block pair is checked before either
    /// allocates), so the caller can release the sequence and retry.
    pub fn append(
        &mut self,
        seq: &mut SeqKv,
        layer: usize,
        pos: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) -> Result<(), ServeError> {
        assert_eq!(k_row.len(), self.h * self.dh);
        assert_eq!(v_row.len(), self.h * self.dh);
        if pos != seq.appended[layer] {
            return Err(ServeError::Internal(format!(
                "kv append out of order: pos {pos} != cursor {}",
                seq.appended[layer]
            )));
        }
        if pos % self.block_tokens == 0 {
            // claim the K/V pair atomically so a failure leaks nothing
            if self.free.len() < 2 {
                return Err(ServeError::PoolExhausted { needed: 2, free: self.free.len() });
            }
            let kb = self.alloc()?;
            let vb = self.alloc()?;
            seq.k_blocks[layer].push(kb);
            seq.v_blocks[layer].push(vb);
        }
        let kb = seq.k_blocks[layer][pos / self.block_tokens];
        let vb = seq.v_blocks[layer][pos / self.block_tokens];
        let tb = pos % self.block_tokens;
        self.write_token(kb, tb, k_row);
        self.write_token(vb, tb, v_row);
        seq.appended[layer] = pos + 1;
        Ok(())
    }

    fn write_token(&mut self, blk: u32, tb: usize, row_heads: &[f32]) {
        let blk = blk as usize;
        match self.mode {
            KvQuant::Fp => {
                let base = (blk * self.block_tokens + tb) * self.h * self.dh;
                self.fdata[base..base + self.h * self.dh].copy_from_slice(row_heads);
            }
            KvQuant::Asym4 => {
                for head in 0..self.h {
                    let row = &row_heads[head * self.dh..(head + 1) * self.dh];
                    // exactly fake_quant_rows_asym's per-row grid
                    let lo = row.iter().cloned().fold(f32::INFINITY, f32::min);
                    let hi = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let step = (hi - lo).max(1e-8) / LEVELS;
                    let sbase = (blk * self.block_tokens + tb) * self.h * 2 + head * 2;
                    self.scales[sbase] = lo;
                    self.scales[sbase + 1] = step;
                    let base = ((blk * self.block_tokens + tb) * self.h + head) * self.bpr;
                    for (e, &v) in row.iter().enumerate() {
                        let q = (((v - lo) / step).round().clamp(0.0, LEVELS)) as u8;
                        let byte = &mut self.data[base + e / 2];
                        if e % 2 == 0 {
                            *byte = (*byte & 0xF0) | q;
                        } else {
                            *byte = (*byte & 0x0F) | (q << 4);
                        }
                    }
                }
            }
        }
    }

    /// Dequantized element `e` of head `head` at cache position `t`.
    #[inline]
    fn read(&self, blocks: &[u32], t: usize, head: usize, e: usize) -> f32 {
        let blk = blocks[t / self.block_tokens] as usize;
        let tb = t % self.block_tokens;
        match self.mode {
            KvQuant::Fp => self.fdata[((blk * self.block_tokens + tb) * self.h + head) * self.dh + e],
            KvQuant::Asym4 => {
                let b = self.data[((blk * self.block_tokens + tb) * self.h + head) * self.bpr + e / 2];
                let q = if e % 2 == 0 { b & 0x0F } else { b >> 4 };
                let sbase = (blk * self.block_tokens + tb) * self.h * 2 + head * 2;
                q as f32 * self.scales[sbase + 1] + self.scales[sbase]
            }
        }
    }

    /// One (token, head) row dequantized (tests / debugging).
    pub fn read_k_row(&self, seq: &SeqKv, layer: usize, t: usize, head: usize) -> Vec<f32> {
        (0..self.dh).map(|e| self.read(&seq.k_blocks[layer], t, head, e)).collect()
    }

    pub fn read_v_row(&self, seq: &SeqKv, layer: usize, t: usize, head: usize) -> Vec<f32> {
        (0..self.dh).map(|e| self.read(&seq.v_blocks[layer], t, head, e)).collect()
    }

    /// Fused dequant-attention over the first `len` cached positions of
    /// `layer`: `out[h·dh] = softmax(q·Kᵀ/√dh)·V`, reading K and V
    /// straight from the packed blocks. `scores` is a caller scratch
    /// buffer (resized to `len`).
    pub fn attend(&self, seq: &SeqKv, layer: usize, len: usize, q: &[f32], out: &mut [f32], scores: &mut Vec<f32>) {
        assert_eq!(q.len(), self.h * self.dh);
        assert_eq!(out.len(), self.h * self.dh);
        assert!(len >= 1 && len <= seq.appended[layer], "attend len {len} vs cached {}", seq.appended[layer]);
        let inv_sqrt = 1.0 / (self.dh as f32).sqrt();
        let kb = &seq.k_blocks[layer];
        let vb = &seq.v_blocks[layer];
        scores.resize(len, 0.0);
        for head in 0..self.h {
            let qh = &q[head * self.dh..(head + 1) * self.dh];
            for (t, s) in scores.iter_mut().enumerate() {
                let mut dot = 0.0f32;
                for (e, &qv) in qh.iter().enumerate() {
                    dot += qv * self.read(kb, t, head, e);
                }
                *s = dot * inv_sqrt;
            }
            let max = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut total = 0.0f32;
            for s in scores.iter_mut() {
                *s = (*s - max).exp();
                total += *s;
            }
            for s in scores.iter_mut() {
                *s /= total;
            }
            let oh = &mut out[head * self.dh..(head + 1) * self.dh];
            oh.fill(0.0);
            for (t, &p) in scores.iter().enumerate() {
                for (e, o) in oh.iter_mut().enumerate() {
                    *o += p * self.read(vb, t, head, e);
                }
            }
        }
    }

    /// Drop one reference per block the sequence holds; blocks whose
    /// **last** reference this was return to the free list and their ids
    /// are appended to `freed` (the caller feeds them to
    /// [`PrefixIndex::invalidate`] so no index entry outlives the
    /// storage it names). Shared blocks with surviving holders stay
    /// allocated and are *not* reported.
    pub fn release_into(&mut self, seq: &mut SeqKv, freed: &mut Vec<u32>) {
        for list in seq.k_blocks.iter_mut().chain(seq.v_blocks.iter_mut()) {
            for id in list.drain(..) {
                let r = &mut self.refs[id as usize];
                debug_assert!(*r > 0, "release of a free block");
                *r -= 1;
                if *r == 0 {
                    self.free.push(id);
                    freed.push(id);
                } else {
                    self.shared_extra -= 1;
                }
            }
        }
        for a in &mut seq.appended {
            *a = 0;
        }
    }

    /// [`release_into`](Self::release_into) without freed-id reporting —
    /// for callers with no prefix index to prune.
    pub fn release(&mut self, seq: &mut SeqKv) {
        let mut freed = Vec::new();
        self.release_into(seq, &mut freed);
    }
}

/// Cap on partial-tail entries registered per trie node — bounds index
/// growth under adversarial prompt churn; registration past the cap is
/// skipped (sharing is an optimization, never a requirement).
const MAX_PARTIALS_PER_NODE: usize = 8;

/// One registered partial tail: `toks.len() < block_tokens` prompt rows
/// written into one K/V block pair per layer.
#[derive(Debug)]
struct Partial {
    toks: Box<[i32]>,
    k: Box<[u32]>,
    v: Box<[u32]>,
}

/// Trie node for one full `block_tokens`-sized chunk: the per-layer K/V
/// block ids a donor wrote for it, deeper chunks, and partial tails
/// starting right after it.
#[derive(Debug, Default)]
struct Node {
    /// per-layer block ids (empty at the root pseudo-node).
    k: Box<[u32]>,
    v: Box<[u32]>,
    children: Vec<(Box<[i32]>, Node)>,
    partials: Vec<Partial>,
}

impl Node {
    fn holds_any(&self, freed: &[u32]) -> bool {
        self.k.iter().chain(self.v.iter()).any(|b| freed.contains(b))
    }
}

/// Weak radix index from token prefixes to the KV blocks a live lane
/// wrote for them. Keys are exact `block_tokens`-sized chunks of token
/// ids; a node at depth `j` names the `j`-th K/V block pair per layer.
///
/// The index never holds references itself — lanes do. Three operations
/// keep it sound:
///
/// * [`attach`](Self::attach) — at admission, map the longest indexed
///   prefix of a prompt onto donor blocks (full chunks via
///   [`KvPool::retain`], a matching tail via copy-on-write), capped at
///   `prompt_len − 1` so the lane always computes at least the final
///   prompt position (it needs those logits to sample).
/// * [`register`](Self::register) — after a lane's prefill completes,
///   record its prompt chunks. Existing entries win ties (two lanes
///   racing the same prompt produce bitwise-identical blocks, so either
///   id set is valid).
/// * [`invalidate`](Self::invalidate) — prune every entry (and its
///   subtree) naming a block id the pool just freed, called on every
///   release *before* any later alloc can recycle the id.
#[derive(Debug)]
pub struct PrefixIndex {
    block_tokens: usize,
    n_layers: usize,
    root: Node,
}

impl PrefixIndex {
    pub fn new(block_tokens: usize, n_layers: usize) -> Self {
        assert!(block_tokens > 0 && n_layers > 0);
        Self { block_tokens, n_layers, root: Node::default() }
    }

    /// Attach the longest indexed prefix of `tokens` to a fresh
    /// sequence: shared full blocks by refcount bump, then at most one
    /// copy-on-write tail block pair per layer. Returns the number of
    /// prompt positions now covered by the cache (`≤ tokens.len() − 1`);
    /// the caller resumes prefill at that position. Fresh COW blocks
    /// come out of the lane's conservative admission reservation, so
    /// allocation here cannot fail for an admitted lane.
    pub fn attach(
        &self,
        pool: &mut KvPool,
        tokens: &[i32],
        seq: &mut SeqKv,
    ) -> Result<usize, ServeError> {
        debug_assert!(seq.is_empty(), "attach requires a fresh sequence");
        if tokens.len() <= 1 {
            return Ok(0);
        }
        let b = self.block_tokens;
        let limit = tokens.len() - 1; // last prompt position is always computed
        let mut shared = 0usize;
        let mut cur = &self.root;
        while shared + b <= limit && tokens.len() - shared >= b {
            let key = &tokens[shared..shared + b];
            let Some((_, child)) = cur.children.iter().find(|(k, _)| &k[..] == key) else { break };
            for l in 0..self.n_layers {
                pool.retain(child.k[l]);
                pool.retain(child.v[l]);
                seq.k_blocks[l].push(child.k[l]);
                seq.v_blocks[l].push(child.v[l]);
            }
            shared += b;
            cur = child;
        }
        // COW tail: the longest common prefix between the remaining
        // tokens and any tail candidate at this depth — a registered
        // partial, or a full child chunk that no longer fits under
        // `limit`. Rows past the match are stale donor bytes; the
        // receiving lane's append cursor overwrites them before any
        // read (attention never looks past the cursor).
        let rem = &tokens[shared..];
        let cap = limit - shared;
        let common = |cand: &[i32]| cand.iter().zip(rem).take_while(|(a, b)| a == b).count().min(cap);
        let mut best: Option<(&[u32], &[u32], usize)> = None;
        for p in &cur.partials {
            let r = common(&p.toks);
            if r >= 1 && best.map_or(true, |(_, _, br)| r > br) {
                best = Some((&p.k, &p.v, r));
            }
        }
        for (key, child) in &cur.children {
            let r = common(key);
            if r >= 1 && best.map_or(true, |(_, _, br)| r > br) {
                best = Some((&child.k, &child.v, r));
            }
        }
        if let Some((ks, vs, r)) = best {
            for l in 0..self.n_layers {
                let kb = pool.alloc()?;
                pool.copy_block(ks[l], kb);
                seq.k_blocks[l].push(kb);
                let vb = pool.alloc()?;
                pool.copy_block(vs[l], vb);
                seq.v_blocks[l].push(vb);
            }
            shared += r;
        }
        for a in &mut seq.appended {
            *a = shared;
        }
        Ok(shared)
    }

    /// Record a lane's freshly prefilled prompt: one node per full
    /// chunk, plus the partial tail (if any) under the deepest node.
    /// Entries already present are kept — a racing identical prefill
    /// produced bitwise-identical block contents, so either donor is
    /// valid — and partial registration is skipped past
    /// [`MAX_PARTIALS_PER_NODE`].
    pub fn register(&mut self, tokens: &[i32], seq: &SeqKv) {
        let b = self.block_tokens;
        let full = tokens.len() / b;
        let mut cur = &mut self.root;
        for j in 0..full {
            let key = &tokens[j * b..(j + 1) * b];
            let idx = match cur.children.iter().position(|(k, _)| &k[..] == key) {
                Some(i) => i,
                None => {
                    let node = Node {
                        k: (0..self.n_layers).map(|l| seq.k_blocks[l][j]).collect(),
                        v: (0..self.n_layers).map(|l| seq.v_blocks[l][j]).collect(),
                        ..Node::default()
                    };
                    cur.children.push((key.into(), node));
                    cur.children.len() - 1
                }
            };
            cur = &mut cur.children[idx].1;
        }
        let tail = &tokens[full * b..];
        if !tail.is_empty()
            && cur.partials.len() < MAX_PARTIALS_PER_NODE
            && !cur.partials.iter().any(|p| &p.toks[..] == tail)
        {
            cur.partials.push(Partial {
                toks: tail.into(),
                k: (0..self.n_layers).map(|l| seq.k_blocks[l][full]).collect(),
                v: (0..self.n_layers).map(|l| seq.v_blocks[l][full]).collect(),
            });
        }
    }

    /// Prune every entry naming a freed block id (and, for full-chunk
    /// nodes, the whole subtree beneath it — unreachable once its parent
    /// is gone). Must run before the pool can recycle the ids.
    pub fn invalidate(&mut self, freed: &[u32]) {
        fn prune(node: &mut Node, freed: &[u32]) {
            node.partials.retain(|p| !p.k.iter().chain(p.v.iter()).any(|b| freed.contains(b)));
            node.children.retain_mut(|(_, c)| {
                if c.holds_any(freed) {
                    return false;
                }
                prune(c, freed);
                true
            });
        }
        prune(&mut self.root, freed);
    }

    /// Registered full-chunk nodes (tests / debugging).
    pub fn nodes(&self) -> usize {
        fn count(n: &Node) -> usize {
            n.children.iter().map(|(_, c)| 1 + count(c)).sum()
        }
        count(&self.root)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::QuantScheme;
    use crate::quant::fakequant::fake_quant_rows_asym;
    use crate::tensor::Tensor;
    use crate::util::Rng;

    fn fill_seq(pool: &mut KvPool, seq: &mut SeqKv, layer: usize, rows: &[(Vec<f32>, Vec<f32>)]) {
        for (t, (k, v)) in rows.iter().enumerate() {
            pool.append(seq, layer, t, k, v).unwrap();
        }
    }

    fn rand_rows(n: usize, w: usize, rng: &mut Rng) -> Vec<(Vec<f32>, Vec<f32>)> {
        (0..n)
            .map(|_| {
                (
                    (0..w).map(|_| rng.normal()).collect(),
                    (0..w).map(|_| rng.normal()).collect(),
                )
            })
            .collect()
    }

    #[test]
    fn roundtrip_matches_fake_quant_asym() {
        let mut rng = Rng::new(0);
        let (h, dh, bt) = (2, 5, 3); // odd dh pads nibbles; bt=3 hits boundaries
        let mut pool = KvPool::new(KvQuant::Asym4, h, dh, bt, 16);
        let mut seq = SeqKv::new(1);
        let rows = rand_rows(8, h * dh, &mut rng);
        fill_seq(&mut pool, &mut seq, 0, &rows);
        assert_eq!(seq.len(0), 8);
        for (t, (k, _)) in rows.iter().enumerate() {
            let want = fake_quant_rows_asym(
                &Tensor::new(k.clone(), vec![h, dh]),
                &QuantScheme::kv4(),
            );
            for head in 0..h {
                assert_eq!(pool.read_k_row(&seq, 0, t, head), want.row(head), "t={t} h={head}");
            }
        }
    }

    #[test]
    fn fp_mode_is_exact() {
        let mut rng = Rng::new(1);
        let (h, dh, bt) = (2, 4, 4);
        let mut pool = KvPool::new(KvQuant::Fp, h, dh, bt, 8);
        let mut seq = SeqKv::new(1);
        let rows = rand_rows(6, h * dh, &mut rng);
        fill_seq(&mut pool, &mut seq, 0, &rows);
        for (t, (_, v)) in rows.iter().enumerate() {
            for head in 0..h {
                assert_eq!(pool.read_v_row(&seq, 0, t, head), v[head * dh..(head + 1) * dh]);
            }
        }
    }

    #[test]
    fn attend_matches_naive_on_dequantized_cache() {
        let mut rng = Rng::new(2);
        let (h, dh, bt) = (2, 6, 3);
        let mut pool = KvPool::new(KvQuant::Asym4, h, dh, bt, 16);
        let mut seq = SeqKv::new(1);
        let rows = rand_rows(7, h * dh, &mut rng);
        fill_seq(&mut pool, &mut seq, 0, &rows);
        let q: Vec<f32> = (0..h * dh).map(|_| rng.normal()).collect();
        let mut out = vec![0.0f32; h * dh];
        let mut scratch = Vec::new();
        pool.attend(&seq, 0, 7, &q, &mut out, &mut scratch);
        for head in 0..h {
            let qh = &q[head * dh..(head + 1) * dh];
            let scores: Vec<f32> = (0..7)
                .map(|t| {
                    let kr = pool.read_k_row(&seq, 0, t, head);
                    qh.iter().zip(&kr).map(|(a, b)| a * b).sum::<f32>() / (dh as f32).sqrt()
                })
                .collect();
            let max = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = scores.iter().map(|s| (s - max).exp()).collect();
            let total: f32 = exps.iter().sum();
            for e in 0..dh {
                let want: f32 = (0..7)
                    .map(|t| exps[t] / total * pool.read_v_row(&seq, 0, t, head)[e])
                    .sum();
                assert!((out[head * dh + e] - want).abs() < 1e-4, "h={head} e={e}");
            }
        }
    }

    #[test]
    fn pool_allocates_and_releases() {
        let (h, dh, bt) = (1, 4, 2);
        let mut pool = KvPool::new(KvQuant::Asym4, h, dh, bt, 6);
        assert_eq!(pool.blocks_needed(1, 5), 2 * 3); // K+V × ceil(5/2)
        let mut seq = SeqKv::new(1);
        let row = vec![0.5f32; h * dh];
        for t in 0..6 {
            pool.append(&mut seq, 0, t, &row, &row).unwrap();
        }
        assert_eq!(seq.blocks_held(), 6);
        assert_eq!(pool.free_blocks(), 0);
        // exhausted: a 7th token needs a fresh block pair — the typed
        // error claims nothing, so release still returns exactly 6
        let err = pool.append(&mut seq, 0, 6, &row, &row).unwrap_err();
        assert_eq!(err, ServeError::PoolExhausted { needed: 2, free: 0 });
        assert_eq!(seq.blocks_held(), 6, "failed append must not claim blocks");
        pool.release(&mut seq);
        assert_eq!(pool.free_blocks(), 6);
        assert_eq!(seq.blocks_held(), 0);
        assert!(seq.is_empty());
    }

    #[test]
    fn refcounted_blocks_free_only_at_last_release() {
        let (h, dh, bt) = (1, 4, 2);
        let mut pool = KvPool::new(KvQuant::Asym4, h, dh, bt, 8);
        let row = vec![0.25f32; h * dh];
        let mut donor = SeqKv::new(1);
        for t in 0..4 {
            pool.append(&mut donor, 0, t, &row, &row).unwrap();
        }
        assert_eq!(pool.free_blocks(), 4);
        assert_eq!(pool.shared_block_refs(), 0);
        // a sharer maps onto the donor's two full K/V block pairs
        let mut sharer = SeqKv::new(1);
        for j in 0..2 {
            let (kb, vb) = (donor.k_blocks[0][j], donor.v_blocks[0][j]);
            pool.retain(kb);
            pool.retain(vb);
            sharer.k_blocks[0].push(kb);
            sharer.v_blocks[0].push(vb);
        }
        sharer.appended[0] = 4;
        assert_eq!(pool.free_blocks(), 4, "retain claims no storage");
        assert_eq!(pool.shared_block_refs(), 4);
        // donor retires first: shared blocks must survive for the sharer
        let mut freed = Vec::new();
        pool.release_into(&mut donor, &mut freed);
        assert!(freed.is_empty(), "sharer still holds every block");
        assert_eq!(pool.free_blocks(), 4);
        assert_eq!(pool.shared_block_refs(), 0);
        for t in 0..4 {
            // the sharer still reads the donor-written rows
            assert_eq!(pool.read_k_row(&sharer, 0, t, 0).len(), dh);
        }
        // last reference retires → pool whole, freed ids reported
        pool.release_into(&mut sharer, &mut freed);
        assert_eq!(freed.len(), 4);
        assert_eq!(pool.free_blocks(), 8);
        assert_eq!(pool.shared_block_refs(), 0);
    }

    #[test]
    fn preemption_release_keeps_shared_blocks_attachable() {
        // KV-pressure preemption releases a victim's *whole* reservation
        // in one shot. Blocks the victim donated to a surviving sharer
        // must stay allocated, readable, and prefix-attachable — only
        // the victim's private blocks free (and prune the index).
        let mut rng = Rng::new(11);
        let (h, dh, bt) = (1, 4, 3);
        let mut pool = KvPool::new(KvQuant::Asym4, h, dh, bt, 32);
        let mut idx = PrefixIndex::new(bt, 1);
        // victim-to-be prompt: 8 tokens → 2 full chunks + 2-row partial
        let donor_toks: Vec<i32> = (0..8).map(|t| 20 + t as i32).collect();
        let rows = rand_rows(8, h * dh, &mut rng);
        let mut donor = SeqKv::new(1);
        fill_seq(&mut pool, &mut donor, 0, &rows);
        idx.register(&donor_toks, &donor);
        let want_k: Vec<_> = (0..8).map(|t| pool.read_k_row(&donor, 0, t, 0)).collect();

        // a sharer maps both full chunks by refcount and COWs the tail
        let sharer_toks: Vec<i32> = donor_toks.iter().copied().chain([90, 91]).collect();
        let mut sharer = SeqKv::with_capacity(1, 4);
        assert_eq!(idx.attach(&mut pool, &sharer_toks, &mut sharer).unwrap(), 8);
        assert_eq!(pool.shared_block_refs(), 4);

        // preempt the donor: one whole-reservation release
        let mut freed = Vec::new();
        pool.release_into(&mut donor, &mut freed);
        idx.invalidate(&freed);
        assert_eq!(freed.len(), 2, "only the private partial tail pair frees");
        assert_eq!(pool.shared_block_refs(), 0, "sharer is now the sole holder");
        assert_eq!(idx.nodes(), 2, "full-chunk entries survive the preemption");
        // the sharer still reads the victim-written rows bitwise
        for t in 0..8 {
            assert_eq!(pool.read_k_row(&sharer, 0, t, 0), want_k[t], "t={t}");
        }

        // the victim re-admits (resume recomputes from the prompt) and
        // reattaches the surviving shared chunks — only the pruned
        // partial tail is gone, so 2 full chunks still come from cache
        let mut resumed = SeqKv::with_capacity(1, 4);
        assert_eq!(idx.attach(&mut pool, &donor_toks, &mut resumed).unwrap(), 6);
        for t in 0..6 {
            assert_eq!(pool.read_k_row(&resumed, 0, t, 0), want_k[t], "t={t}");
        }

        // last holders release → pool whole, index empty
        pool.release_into(&mut resumed, &mut freed);
        pool.release_into(&mut sharer, &mut freed);
        idx.invalidate(&freed);
        assert_eq!(pool.free_blocks(), 32);
        assert_eq!(pool.shared_block_refs(), 0);
        assert_eq!(idx.nodes(), 0);
    }

    #[test]
    fn prefix_attach_shares_full_blocks_and_cows_the_tail() {
        let mut rng = Rng::new(7);
        let (h, dh, bt) = (2, 5, 3);
        let mut pool = KvPool::new(KvQuant::Asym4, h, dh, bt, 32);
        let mut idx = PrefixIndex::new(bt, 1);
        // donor prompt: 8 tokens → 2 full chunks + a 2-row partial
        let donor_toks: Vec<i32> = (0..8).map(|t| 10 + t as i32).collect();
        let rows = rand_rows(8, h * dh, &mut rng);
        let mut donor = SeqKv::new(1);
        fill_seq(&mut pool, &mut donor, 0, &rows);
        idx.register(&donor_toks, &donor);
        assert_eq!(idx.nodes(), 2);

        // sharer: same 8 tokens + 2 more → shares 2 full chunks by
        // refcount and copies the partial tail block pair
        let sharer_toks: Vec<i32> = donor_toks.iter().copied().chain([90, 91]).collect();
        let mut sharer = SeqKv::with_capacity(1, 4);
        let shared = idx.attach(&mut pool, &sharer_toks, &mut sharer).unwrap();
        assert_eq!(shared, 8, "2 full chunks (6) + 2-row COW tail");
        assert_eq!(pool.shared_block_refs(), 4, "K+V × 2 full chunks");
        // tail blocks are private copies, not the donor's
        assert_ne!(sharer.k_blocks[0][2], donor.k_blocks[0][2]);
        // shared + copied rows read back bitwise identical to the donor
        for t in 0..8 {
            for head in 0..h {
                assert_eq!(
                    pool.read_k_row(&sharer, 0, t, head),
                    pool.read_k_row(&donor, 0, t, head),
                    "t={t} head={head}"
                );
                assert_eq!(
                    pool.read_v_row(&sharer, 0, t, head),
                    pool.read_v_row(&donor, 0, t, head),
                );
            }
        }
        // the sharer appends its divergent suffix into the private tail
        let extra = rand_rows(2, h * dh, &mut rng);
        for (i, (k, v)) in extra.iter().enumerate() {
            pool.append(&mut sharer, 0, 8 + i, k, v).unwrap();
        }
        // ...without disturbing the donor's partial rows
        for t in 6..8 {
            assert_eq!(pool.read_k_row(&donor, 0, t, 0), pool.read_k_row(&sharer, 0, t, 0));
        }

        // identical prompt: attach caps at prompt_len − 1 so the last
        // position is always computed, never fully served from cache
        let mut twin = SeqKv::with_capacity(1, 4);
        let shared = idx.attach(&mut pool, &donor_toks, &mut twin).unwrap();
        assert_eq!(shared, 7, "8-token prompt shares at most 7 positions");
        pool.release(&mut twin);

        // release donor then sharer: pool whole, and freed ids prune
        // the index so nothing stale can ever be attached
        let mut freed = Vec::new();
        pool.release_into(&mut donor, &mut freed);
        pool.release_into(&mut sharer, &mut freed);
        idx.invalidate(&freed);
        assert_eq!(pool.free_blocks(), 32);
        assert_eq!(pool.shared_block_refs(), 0);
        assert_eq!(idx.nodes(), 0, "freed blocks must leave the index");
        let mut fresh = SeqKv::new(1);
        assert_eq!(idx.attach(&mut pool, &sharer_toks, &mut fresh).unwrap(), 0);
    }

    #[test]
    fn prefix_attach_cows_divergent_partial_prefix() {
        // sharer diverges *inside* the donor's partial tail: the common
        // prefix of the tail is still shared via COW
        let mut rng = Rng::new(9);
        let (h, dh, bt) = (1, 4, 4);
        let mut pool = KvPool::new(KvQuant::Asym4, h, dh, bt, 16);
        let mut idx = PrefixIndex::new(bt, 1);
        let donor_toks = vec![1, 2, 3, 4, 5, 6, 7]; // 1 full chunk + 3-row partial
        let rows = rand_rows(7, h * dh, &mut rng);
        let mut donor = SeqKv::new(1);
        fill_seq(&mut pool, &mut donor, 0, &rows);
        idx.register(&donor_toks, &donor);

        // matches the full chunk and 2 of the 3 partial rows
        let sharer_toks = vec![1, 2, 3, 4, 5, 6, 99, 100];
        let mut sharer = SeqKv::new(1);
        let shared = idx.attach(&mut pool, &sharer_toks, &mut sharer).unwrap();
        assert_eq!(shared, 6, "full chunk (4) + 2-row partial prefix");
        for t in 0..6 {
            assert_eq!(pool.read_k_row(&sharer, 0, t, 0), pool.read_k_row(&donor, 0, t, 0));
        }
        pool.release(&mut donor);
        pool.release(&mut sharer);
        assert_eq!(pool.free_blocks(), 16);
    }

    #[test]
    fn bytes_per_token_accounting() {
        let pool4 = KvPool::new(KvQuant::Asym4, 8, 64, 16, 4);
        let poolf = KvPool::new(KvQuant::Fp, 8, 64, 16, 4);
        assert_eq!(pool4.bytes_per_token_layer(), 2 * (8 * 32 + 8 * 8));
        assert_eq!(poolf.bytes_per_token_layer(), 2 * 8 * 64 * 4);
        let ratio = poolf.bytes_per_token_layer() as f64 / pool4.bytes_per_token_layer() as f64;
        assert!(ratio >= 6.0, "dh=64 must give ≥6x reduction, got {ratio}");
    }
}
