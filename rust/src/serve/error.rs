//! Typed, recoverable serving errors.
//!
//! The PR-2..5 engine treated every edge as fatal: oversized requests
//! hit `ensure!`/panics and pool pressure was unrepresentable. The
//! daemon needs to *react* — shed with retry-after, reject with a
//! client error, time out, drain — so every recoverable condition in
//! `engine.rs` / `scheduler.rs` / `kvcache.rs` now surfaces as a
//! [`ServeError`] variant instead of dying. `ServeError` implements
//! `std::error::Error`, so existing `?`-into-`anyhow` call sites keep
//! compiling unchanged; new callers (the daemon's HTTP layer, the
//! admission path) match on the variant to pick a response.

use std::fmt;

/// A recoverable serving-layer failure. Every variant maps to a
/// distinct client-visible outcome (HTTP status, retry hint) in
/// `serve::daemon::http`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The KV block pool cannot supply a claim right now. With the
    /// engine's conservative admission reservation this is unreachable
    /// mid-flight; it survives as the pool's own failure mode (direct
    /// pool users, future optimistic schedulers).
    PoolExhausted { needed: usize, free: usize },
    /// The bounded admission queue is at capacity — shed the request
    /// and tell the client to retry later (backpressure).
    QueueFull { cap: usize },
    /// The request's worst-case KV reservation exceeds the whole pool:
    /// it can never be admitted, no matter how idle the engine is.
    RequestTooLarge { needed_blocks: usize, pool_blocks: usize },
    /// Malformed request (empty prompt, out-of-vocab token, zero
    /// generation budget, over-long sequence, bad JSON field).
    Invalid(String),
    /// The request's deadline expired while queued or mid-stream.
    Deadline,
    /// The request was canceled (client disconnect or explicit cancel).
    Canceled,
    /// The daemon is draining: no new admissions, live lanes finish.
    Draining,
    /// The tenant's token-bucket rate limit is exhausted. Carries the
    /// refill deficit in whole seconds (already clamped to the wire's
    /// [1, 60] `Retry-After` window) so the HTTP layer can echo it
    /// without recomputing bucket state.
    RateLimited { retry_after_s: u64 },
    /// The engine thread panicked and the supervisor is rebuilding it.
    /// In-flight requests are failed with this (retryable) error; a
    /// fresh submit after the restart will succeed.
    EngineRestarting,
    /// An engine-internal invariant broke (out-of-order KV append,
    /// forward failure). Not client-correctable.
    Internal(String),
}

impl ServeError {
    /// Whether retrying the *same* request later can succeed — the
    /// load-shedding/backpressure class (`Retry-After` on the wire).
    pub fn retryable(&self) -> bool {
        matches!(
            self,
            ServeError::PoolExhausted { .. }
                | ServeError::QueueFull { .. }
                | ServeError::Draining
                | ServeError::RateLimited { .. }
                | ServeError::EngineRestarting
        )
    }

    /// Short stable identifier for logs, `/stats` and JSON error bodies.
    pub fn kind(&self) -> &'static str {
        match self {
            ServeError::PoolExhausted { .. } => "pool_exhausted",
            ServeError::QueueFull { .. } => "queue_full",
            ServeError::RequestTooLarge { .. } => "request_too_large",
            ServeError::Invalid(_) => "invalid",
            ServeError::Deadline => "deadline",
            ServeError::Canceled => "canceled",
            ServeError::Draining => "draining",
            ServeError::RateLimited { .. } => "rate_limited",
            ServeError::EngineRestarting => "engine_restarting",
            ServeError::Internal(_) => "internal",
        }
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::PoolExhausted { needed, free } => {
                write!(f, "kv pool exhausted: need {needed} blocks, {free} free")
            }
            ServeError::QueueFull { cap } => write!(f, "admission queue full ({cap} requests)"),
            ServeError::RequestTooLarge { needed_blocks, pool_blocks } => {
                write!(f, "request needs {needed_blocks} KV blocks but the pool only has {pool_blocks}")
            }
            ServeError::Invalid(msg) => write!(f, "invalid request: {msg}"),
            ServeError::Deadline => write!(f, "deadline exceeded"),
            ServeError::Canceled => write!(f, "request canceled"),
            ServeError::Draining => write!(f, "daemon is draining; not accepting work"),
            ServeError::RateLimited { retry_after_s } => {
                write!(f, "tenant rate limit exhausted; retry in {retry_after_s}s")
            }
            ServeError::EngineRestarting => {
                write!(f, "engine restarting after failure; retry shortly")
            }
            ServeError::Internal(msg) => write!(f, "internal serving error: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retryable_classes() {
        assert!(ServeError::QueueFull { cap: 4 }.retryable());
        assert!(ServeError::PoolExhausted { needed: 2, free: 0 }.retryable());
        assert!(ServeError::Draining.retryable());
        assert!(ServeError::RateLimited { retry_after_s: 3 }.retryable());
        assert!(ServeError::EngineRestarting.retryable());
        assert!(!ServeError::RequestTooLarge { needed_blocks: 9, pool_blocks: 8 }.retryable());
        assert!(!ServeError::Invalid("x".into()).retryable());
        assert!(!ServeError::Deadline.retryable());
    }

    #[test]
    fn converts_into_anyhow() {
        // the blanket std::error::Error impl keeps `?`-to-anyhow sites
        // compiling; the message must survive the conversion
        let e: anyhow::Error = ServeError::QueueFull { cap: 7 }.into();
        assert!(e.to_string().contains("queue full (7"), "{e}");
        let d: Option<&ServeError> = e.downcast_ref();
        assert_eq!(d, Some(&ServeError::QueueFull { cap: 7 }));
    }
}
