//! Hand-rolled HTTP/1.1 plumbing for the daemon (the offline build has
//! no HTTP crates). Scope: exactly what the daemon's API needs — a
//! request parser (method + path + headers + `Content-Length` body,
//! with size caps), plain responses, and `Transfer-Encoding: chunked`
//! writers for per-token streaming.
//!
//! Connections are persistent by default (HTTP/1.1 keep-alive): the
//! daemon's per-connection loop keeps parsing requests off the same
//! socket until the client sends `Connection: close`, the configured
//! requests-per-connection bound is reached, the idle window expires,
//! or a drain begins. Two timers guard the read path:
//!
//! * the **idle window** (the socket read timeout set by the caller)
//!   bounds how long a kept-alive connection may sit silent before the
//!   first byte of the next request, and
//! * the **read budget** ([`read_request_within`]) bounds how long a
//!   request may take from its first byte to its last — a slow-loris
//!   client dribbling one header byte per second exhausts the budget
//!   and is disconnected instead of pinning an accept slot.

use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use super::super::error::ServeError;

/// Caps: a request line + headers beyond 16 KiB or a body beyond 1 MiB
/// is rejected (the daemon serves token requests, not uploads).
const MAX_HEAD: usize = 16 * 1024;
const MAX_BODY: usize = 1024 * 1024;

/// One parsed request.
pub struct Request {
    pub method: String,
    pub path: String,
    /// Lower-cased names, trimmed values, in arrival order.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }
}

/// HTTP/1.1 defaults to persistent connections; only an explicit
/// `Connection: close` opts out (the daemon ANDs this with its own
/// keep-alive config, request budget and drain state).
pub fn wants_keep_alive(req: &Request) -> bool {
    !req.header("connection").is_some_and(|v| v.eq_ignore_ascii_case("close"))
}

fn find_blank_line(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

fn invalid(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.to_string())
}

/// One read against the budget clock. The budget only starts ticking
/// when the request's first bytes arrive — before that the socket's
/// own read timeout (the keep-alive idle window) is in charge — and
/// from then on every subsequent read shrinks its timeout to whatever
/// budget remains.
fn read_some(
    stream: &mut TcpStream,
    tmp: &mut [u8],
    deadline: &mut Option<Instant>,
    budget: Duration,
) -> io::Result<usize> {
    if let Some(d) = *deadline {
        let left = d.saturating_duration_since(Instant::now());
        if left.is_zero() {
            return Err(io::Error::new(io::ErrorKind::TimedOut, "request read budget exhausted"));
        }
        stream.set_read_timeout(Some(left))?;
    }
    let n = stream.read(tmp)?;
    if n > 0 && deadline.is_none() {
        *deadline = Some(Instant::now() + budget);
    }
    Ok(n)
}

/// Read and parse one request from the stream (blocking; honours the
/// stream's read timeout for the first byte), requiring the whole
/// head + body to land within `budget` of the first byte.
pub fn read_request_within(stream: &mut TcpStream, budget: Duration) -> io::Result<Request> {
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut tmp = [0u8; 1024];
    let mut deadline: Option<Instant> = None;
    let head_end = loop {
        if let Some(p) = find_blank_line(&buf) {
            break p;
        }
        if buf.len() > MAX_HEAD {
            return Err(invalid("request head too large"));
        }
        let n = read_some(stream, &mut tmp, &mut deadline, budget)?;
        if n == 0 {
            return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "connection closed mid-request"));
        }
        buf.extend_from_slice(&tmp[..n]);
    };
    let head = std::str::from_utf8(&buf[..head_end]).map_err(|_| invalid("non-utf8 request head"))?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().ok_or_else(|| invalid("empty request"))?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().ok_or_else(|| invalid("missing method"))?.to_string();
    let path = parts.next().ok_or_else(|| invalid("missing path"))?.to_string();
    let headers: Vec<(String, String)> = lines
        .filter_map(|l| l.split_once(':'))
        .map(|(k, v)| (k.trim().to_ascii_lowercase(), v.trim().to_string()))
        .collect();
    let content_length: usize = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .and_then(|(_, v)| v.parse().ok())
        .unwrap_or(0);
    if content_length > MAX_BODY {
        return Err(invalid("request body too large"));
    }
    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < content_length {
        let n = read_some(stream, &mut tmp, &mut deadline, budget)?;
        if n == 0 {
            return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "connection closed mid-body"));
        }
        body.extend_from_slice(&tmp[..n]);
    }
    body.truncate(content_length);
    Ok(Request { method, path, headers, body })
}

/// [`read_request_within`] with a generous default budget, for callers
/// (tests, tools) that don't thread a config through.
pub fn read_request(stream: &mut TcpStream) -> io::Result<Request> {
    read_request_within(stream, Duration::from_secs(60))
}

/// `(status, reason)` for a [`ServeError`] — the daemon's single
/// error→wire mapping. Retryable errors carry `Retry-After`.
pub fn status_for(e: &ServeError) -> (u16, &'static str) {
    match e {
        ServeError::QueueFull { .. } => (429, "Too Many Requests"),
        ServeError::RateLimited { .. } => (429, "Too Many Requests"),
        ServeError::PoolExhausted { .. } => (503, "Service Unavailable"),
        ServeError::Draining => (503, "Service Unavailable"),
        ServeError::EngineRestarting => (503, "Service Unavailable"),
        ServeError::RequestTooLarge { .. } => (413, "Payload Too Large"),
        ServeError::Invalid(_) => (400, "Bad Request"),
        ServeError::Deadline => (504, "Gateway Timeout"),
        ServeError::Canceled => (499, "Client Closed Request"),
        ServeError::Internal(_) => (500, "Internal Server Error"),
    }
}

/// Write a complete response and flush. `extra` headers are emitted
/// verbatim after the standard set. `keep` picks the `Connection`
/// header — the caller owns the keep-alive decision (config AND client
/// AND drain state), this just puts it on the wire.
pub fn write_response(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    content_type: &str,
    extra: &[(&str, String)],
    body: &[u8],
    keep: bool,
) -> io::Result<()> {
    let conn = if keep { "keep-alive" } else { "close" };
    let mut head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: {conn}\r\n",
        body.len()
    );
    for (k, v) in extra {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()
}

/// Map a [`ServeError`] onto the wire: status from [`status_for`], a
/// JSON body with the error kind/message, and `Retry-After` on the
/// retryable (backpressure) class. Rate-limit sheds carry their own
/// deficit-derived wait ([`ServeError::RateLimited`]); the rest use
/// `retry_s`, which the daemon derives from the observed queue-wait
/// distribution (p50 drain estimate, clamped to `[1, 60]`) — callers
/// without telemetry pass `1`.
pub fn write_error(stream: &mut TcpStream, e: &ServeError, retry_s: u64, keep: bool) -> io::Result<()> {
    let (status, reason) = status_for(e);
    let retry_after = match e {
        ServeError::RateLimited { retry_after_s } => Some(*retry_after_s),
        _ if e.retryable() => Some(retry_s),
        _ => None,
    };
    let retry: Vec<(&str, String)> =
        retry_after.map(|s| vec![("Retry-After", s.to_string())]).unwrap_or_default();
    let body = format!("{{\"error\": \"{}\", \"message\": \"{}\"}}", e.kind(), e.to_string().replace('"', "'"));
    write_response(stream, status, reason, "application/json", &retry, body.as_bytes(), keep)
}

/// Start a chunked (streaming) response.
pub fn write_chunked_head(stream: &mut TcpStream, content_type: &str, keep: bool) -> io::Result<()> {
    let conn = if keep { "keep-alive" } else { "close" };
    let head = format!(
        "HTTP/1.1 200 OK\r\nContent-Type: {content_type}\r\nTransfer-Encoding: chunked\r\nConnection: {conn}\r\n\r\n"
    );
    stream.write_all(head.as_bytes())?;
    stream.flush()
}

/// One chunk (flushed: per-token streaming wants every token on the
/// wire immediately, not sitting in a buffer).
pub fn write_chunk(stream: &mut TcpStream, data: &[u8]) -> io::Result<()> {
    write!(stream, "{:x}\r\n", data.len())?;
    stream.write_all(data)?;
    stream.write_all(b"\r\n")?;
    stream.flush()
}

/// Terminate a chunked response.
pub fn finish_chunks(stream: &mut TcpStream) -> io::Result<()> {
    stream.write_all(b"0\r\n\r\n")?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::{TcpListener, TcpStream};

    fn roundtrip(raw: &[u8]) -> io::Result<Request> {
        // loop a real socket through the parser (TcpStream has no
        // in-memory stand-in); the writer side closes after the payload
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let raw = raw.to_vec();
        let t = std::thread::spawn(move || {
            let mut c = TcpStream::connect(addr).unwrap();
            c.write_all(&raw).unwrap();
        });
        let (mut s, _) = listener.accept().unwrap();
        let req = read_request(&mut s);
        t.join().unwrap();
        req
    }

    #[test]
    fn parses_request_with_body() {
        let req = roundtrip(b"POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Length: 9\r\n\r\n{\"a\": 1}!").unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/generate");
        assert_eq!(req.header("content-length"), Some("9"));
        assert_eq!(req.body, b"{\"a\": 1}!");
    }

    #[test]
    fn parses_get_without_body() {
        let req = roundtrip(b"GET /stats HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/stats");
        assert!(req.body.is_empty());
    }

    #[test]
    fn rejects_truncated_requests() {
        assert!(roundtrip(b"GET /stats HTTP/1.1\r\nHost: x\r\n").is_err(), "no blank line");
        assert!(roundtrip(b"POST /x HTTP/1.1\r\nContent-Length: 50\r\n\r\nshort").is_err(), "short body");
    }

    #[test]
    fn keep_alive_is_the_default_and_close_opts_out() {
        let keep = roundtrip(b"GET /stats HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        assert!(wants_keep_alive(&keep), "HTTP/1.1 defaults to persistent");
        let close = roundtrip(b"GET /stats HTTP/1.1\r\nConnection: Close\r\n\r\n").unwrap();
        assert!(!wants_keep_alive(&close), "explicit close wins, case-insensitively");
        let ka = roundtrip(b"GET /stats HTTP/1.1\r\nConnection: keep-alive\r\n\r\n").unwrap();
        assert!(wants_keep_alive(&ka));
    }

    #[test]
    fn slow_request_exceeds_read_budget() {
        // slow-loris: the head starts arriving, then stalls past the
        // budget — the parser must give up instead of waiting forever
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let mut c = TcpStream::connect(addr).unwrap();
            c.write_all(b"GET /stats HTTP/1.1\r\n").unwrap();
            std::thread::sleep(Duration::from_millis(400));
            let _ = c.write_all(b"Host: x\r\n\r\n"); // peer may be gone already
        });
        let (mut s, _) = listener.accept().unwrap();
        let err = read_request_within(&mut s, Duration::from_millis(100)).unwrap_err();
        assert!(
            matches!(err.kind(), io::ErrorKind::TimedOut | io::ErrorKind::WouldBlock),
            "budget exhaustion surfaces as a timeout: {err:?}"
        );
        t.join().unwrap();
    }

    #[test]
    fn error_mapping_covers_backpressure_semantics() {
        assert_eq!(status_for(&ServeError::QueueFull { cap: 1 }).0, 429);
        assert_eq!(status_for(&ServeError::RateLimited { retry_after_s: 7 }).0, 429);
        assert_eq!(status_for(&ServeError::Draining).0, 503);
        assert_eq!(status_for(&ServeError::EngineRestarting).0, 503);
        assert_eq!(status_for(&ServeError::RequestTooLarge { needed_blocks: 9, pool_blocks: 8 }).0, 413);
        assert_eq!(status_for(&ServeError::Deadline).0, 504);
        assert_eq!(status_for(&ServeError::Invalid("x".into())).0, 400);
    }
}
