//! Hand-rolled HTTP/1.1 plumbing for the daemon (the offline build has
//! no HTTP crates). Scope: exactly what the daemon's API needs — a
//! request parser (method + path + headers + `Content-Length` body,
//! with size caps), plain responses, and `Transfer-Encoding: chunked`
//! writers for per-token streaming. Connections are one-shot
//! (`Connection: close`), which keeps the server loop trivial and the
//! drain contract obvious: no idle keep-alive sockets to reap.

use std::io::{self, Read, Write};
use std::net::TcpStream;

use super::super::error::ServeError;

/// Caps: a request line + headers beyond 16 KiB or a body beyond 1 MiB
/// is rejected (the daemon serves token requests, not uploads).
const MAX_HEAD: usize = 16 * 1024;
const MAX_BODY: usize = 1024 * 1024;

/// One parsed request.
pub struct Request {
    pub method: String,
    pub path: String,
    /// Lower-cased names, trimmed values, in arrival order.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }
}

fn find_blank_line(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

fn invalid(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.to_string())
}

/// Read and parse one request from the stream (blocking; honours the
/// stream's read timeout).
pub fn read_request(stream: &mut TcpStream) -> io::Result<Request> {
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut tmp = [0u8; 1024];
    let head_end = loop {
        if let Some(p) = find_blank_line(&buf) {
            break p;
        }
        if buf.len() > MAX_HEAD {
            return Err(invalid("request head too large"));
        }
        let n = stream.read(&mut tmp)?;
        if n == 0 {
            return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "connection closed mid-request"));
        }
        buf.extend_from_slice(&tmp[..n]);
    };
    let head = std::str::from_utf8(&buf[..head_end]).map_err(|_| invalid("non-utf8 request head"))?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().ok_or_else(|| invalid("empty request"))?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().ok_or_else(|| invalid("missing method"))?.to_string();
    let path = parts.next().ok_or_else(|| invalid("missing path"))?.to_string();
    let headers: Vec<(String, String)> = lines
        .filter_map(|l| l.split_once(':'))
        .map(|(k, v)| (k.trim().to_ascii_lowercase(), v.trim().to_string()))
        .collect();
    let content_length: usize = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .and_then(|(_, v)| v.parse().ok())
        .unwrap_or(0);
    if content_length > MAX_BODY {
        return Err(invalid("request body too large"));
    }
    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut tmp)?;
        if n == 0 {
            return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "connection closed mid-body"));
        }
        body.extend_from_slice(&tmp[..n]);
    }
    body.truncate(content_length);
    Ok(Request { method, path, headers, body })
}

/// `(status, reason, retryable)` for a [`ServeError`] — the daemon's
/// single error→wire mapping. Retryable errors carry `Retry-After`.
pub fn status_for(e: &ServeError) -> (u16, &'static str) {
    match e {
        ServeError::QueueFull { .. } => (429, "Too Many Requests"),
        ServeError::PoolExhausted { .. } => (503, "Service Unavailable"),
        ServeError::Draining => (503, "Service Unavailable"),
        ServeError::RequestTooLarge { .. } => (413, "Payload Too Large"),
        ServeError::Invalid(_) => (400, "Bad Request"),
        ServeError::Deadline => (504, "Gateway Timeout"),
        ServeError::Canceled => (499, "Client Closed Request"),
        ServeError::Internal(_) => (500, "Internal Server Error"),
    }
}

/// Write a complete response and flush. `extra` headers are emitted
/// verbatim after the standard set.
pub fn write_response(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    content_type: &str,
    extra: &[(&str, String)],
    body: &[u8],
) -> io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n",
        body.len()
    );
    for (k, v) in extra {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()
}

/// Map a [`ServeError`] onto the wire: status from [`status_for`], a
/// JSON body with the error kind/message, and `Retry-After: {retry_s}`
/// on the retryable (backpressure) class. The daemon derives `retry_s`
/// from the observed queue-wait distribution (p50 drain estimate,
/// clamped to `[1, 60]`); callers without telemetry pass `1`.
pub fn write_error(stream: &mut TcpStream, e: &ServeError, retry_s: u64) -> io::Result<()> {
    let (status, reason) = status_for(e);
    let retry: Vec<(&str, String)> =
        if e.retryable() { vec![("Retry-After", retry_s.to_string())] } else { Vec::new() };
    let body = format!("{{\"error\": \"{}\", \"message\": \"{}\"}}", e.kind(), e.to_string().replace('"', "'"));
    write_response(stream, status, reason, "application/json", &retry, body.as_bytes())
}

/// Start a chunked (streaming) response.
pub fn write_chunked_head(stream: &mut TcpStream, content_type: &str) -> io::Result<()> {
    let head = format!(
        "HTTP/1.1 200 OK\r\nContent-Type: {content_type}\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n"
    );
    stream.write_all(head.as_bytes())?;
    stream.flush()
}

/// One chunk (flushed: per-token streaming wants every token on the
/// wire immediately, not sitting in a buffer).
pub fn write_chunk(stream: &mut TcpStream, data: &[u8]) -> io::Result<()> {
    write!(stream, "{:x}\r\n", data.len())?;
    stream.write_all(data)?;
    stream.write_all(b"\r\n")?;
    stream.flush()
}

/// Terminate a chunked response.
pub fn finish_chunks(stream: &mut TcpStream) -> io::Result<()> {
    stream.write_all(b"0\r\n\r\n")?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::{TcpListener, TcpStream};

    fn roundtrip(raw: &[u8]) -> io::Result<Request> {
        // loop a real socket through the parser (TcpStream has no
        // in-memory stand-in); the writer side closes after the payload
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let raw = raw.to_vec();
        let t = std::thread::spawn(move || {
            let mut c = TcpStream::connect(addr).unwrap();
            c.write_all(&raw).unwrap();
        });
        let (mut s, _) = listener.accept().unwrap();
        let req = read_request(&mut s);
        t.join().unwrap();
        req
    }

    #[test]
    fn parses_request_with_body() {
        let req = roundtrip(b"POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Length: 9\r\n\r\n{\"a\": 1}!").unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/generate");
        assert_eq!(req.header("content-length"), Some("9"));
        assert_eq!(req.body, b"{\"a\": 1}!");
    }

    #[test]
    fn parses_get_without_body() {
        let req = roundtrip(b"GET /stats HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/stats");
        assert!(req.body.is_empty());
    }

    #[test]
    fn rejects_truncated_requests() {
        assert!(roundtrip(b"GET /stats HTTP/1.1\r\nHost: x\r\n").is_err(), "no blank line");
        assert!(roundtrip(b"POST /x HTTP/1.1\r\nContent-Length: 50\r\n\r\nshort").is_err(), "short body");
    }

    #[test]
    fn error_mapping_covers_backpressure_semantics() {
        assert_eq!(status_for(&ServeError::QueueFull { cap: 1 }).0, 429);
        assert_eq!(status_for(&ServeError::Draining).0, 503);
        assert_eq!(status_for(&ServeError::RequestTooLarge { needed_blocks: 9, pool_blocks: 8 }).0, 413);
        assert_eq!(status_for(&ServeError::Deadline).0, 504);
        assert_eq!(status_for(&ServeError::Invalid("x".into())).0, 400);
    }
}
