//! File-backed runtime daemon configuration with atomic live reload.
//!
//! Everything an operator may want to change *without restarting* —
//! tenant policies (priority class, in-flight cap, token-bucket rate
//! limit), connection limits, deadlines, fault knobs, log mode — lives
//! in a [`RuntimeConfig`] held by a [`ConfigCell`] (an
//! `Arc`-swapped cell: readers grab a consistent snapshot with one
//! lock-free-ish clone, a reload installs a whole new config at once,
//! never a half-applied one). Reload triggers are SIGHUP and an mtime
//! poll from the accept loop; a config that fails validation is
//! rejected with a structured log and the old config stays live.
//! In-flight streams never observe a reload: admission decisions read
//! the snapshot once, and live lanes keep the reservation they were
//! admitted with.
//!
//! *Not* hot-reloadable (engine-shape knobs, fixed at startup):
//! listen address, queue capacity, lane count, and every
//! `ServeConfig` field — those size the KV pool and scratch arena the
//! engine was built with.
//!
//! Config file format (strict JSON; unknown keys are rejected so a
//! typo cannot silently become a default):
//!
//! ```json
//! {
//!   "per_tenant_cap": 8,
//!   "default_deadline_ms": 30000,
//!   "keep_alive_ms": 10000,
//!   "max_conn_requests": 64,
//!   "read_budget_ms": 10000,
//!   "log": "json",
//!   "fault": "slow_step=5",
//!   "fault_seed": 7,
//!   "resume_on_restart": true,
//!   "tenants": {
//!     "alice": { "priority": "high", "rate_tokens_per_s": 100, "burst_tokens": 200 },
//!     "batch": { "priority": "low", "cap": 2 }
//!   }
//! }
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::SystemTime;

use crate::obs::log::LogFormat;
use crate::util::Json;

use super::super::scheduler::Priority;
use super::fault::FaultSpec;

/// Per-tenant admission policy. Absent tenants get `Default`, which
/// reproduces the pre-policy daemon exactly: normal priority, global
/// cap, no rate limit.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantPolicy {
    /// Admission class (`high`/`normal`/`low`).
    pub priority: Priority,
    /// In-flight request cap override; `0` inherits the global
    /// `per_tenant_cap`.
    pub cap: usize,
    /// Token-bucket refill in *generated* tokens per second; `0` =
    /// unlimited (no bucket at all).
    pub rate_tokens_per_s: f64,
    /// Bucket capacity in tokens; `0` = one second of refill.
    pub burst_tokens: f64,
}

impl Default for TenantPolicy {
    fn default() -> Self {
        Self { priority: Priority::Normal, cap: 0, rate_tokens_per_s: 0.0, burst_tokens: 0.0 }
    }
}

impl TenantPolicy {
    /// Whether this tenant carries a token bucket at all.
    pub fn rate_limited(&self) -> bool {
        self.rate_tokens_per_s > 0.0
    }

    /// Effective bucket capacity (the `0` → one-second-of-refill rule).
    pub fn effective_burst(&self) -> f64 {
        if self.burst_tokens > 0.0 {
            self.burst_tokens
        } else {
            self.rate_tokens_per_s
        }
    }
}

/// The hot-reloadable slice of daemon configuration (see module docs).
#[derive(Clone, Debug, PartialEq)]
pub struct RuntimeConfig {
    /// Global in-flight requests per tenant; `0` = unlimited.
    pub per_tenant_cap: usize,
    /// Deadline applied to requests that don't carry one; `0` = none.
    pub default_deadline_ms: u64,
    /// Keep-alive idle window per connection; `0` disables keep-alive
    /// (every response closes, the pre-PR-9 behaviour).
    pub keep_alive_ms: u64,
    /// Requests served per connection before a graceful close.
    pub max_conn_requests: usize,
    /// Slow-loris guard: once a request's first bytes arrive, the
    /// whole head+body must land within this budget.
    pub read_budget_ms: u64,
    /// Tenant name → policy; absent tenants get `TenantPolicy::default`.
    pub tenants: BTreeMap<String, TenantPolicy>,
    /// Fault injection (same grammar as `KURTAIL_FAULT`).
    pub fault: FaultSpec,
    /// Log mode override; `None` leaves `KURTAIL_LOG` in charge.
    pub log: Option<LogFormat>,
    /// When the supervised engine restarts after a panic, re-submit
    /// in-flight streams from their host-side snapshots (transparent
    /// resume) instead of failing them with 503 `EngineRestarting`.
    pub resume_on_restart: bool,
}

impl Default for RuntimeConfig {
    fn default() -> Self {
        Self {
            per_tenant_cap: 0,
            default_deadline_ms: 0,
            keep_alive_ms: 10_000,
            max_conn_requests: 64,
            read_budget_ms: 10_000,
            tenants: BTreeMap::new(),
            fault: FaultSpec::none(),
            log: None,
            resume_on_restart: true,
        }
    }
}

fn get_usize(obj: &Json, key: &str, into: &mut usize) -> Result<(), String> {
    if let Some(v) = obj.opt(key) {
        *into = v.as_usize().map_err(|e| format!("{key}: {e}"))?;
    }
    Ok(())
}

fn get_u64(obj: &Json, key: &str, into: &mut u64) -> Result<(), String> {
    let mut n = *into as usize;
    get_usize(obj, key, &mut n)?;
    *into = n as u64;
    Ok(())
}

fn get_rate(obj: &Json, key: &str, into: &mut f64) -> Result<(), String> {
    if let Some(v) = obj.opt(key) {
        let x = v.as_f64().map_err(|e| format!("{key}: {e}"))?;
        if !x.is_finite() || x < 0.0 {
            return Err(format!("{key}: must be a finite non-negative number, got {x}"));
        }
        *into = x;
    }
    Ok(())
}

impl RuntimeConfig {
    /// Policy lookup with the global-cap inheritance applied.
    pub fn policy(&self, tenant: &str) -> TenantPolicy {
        let mut p = self.tenants.get(tenant).cloned().unwrap_or_default();
        if p.cap == 0 {
            p.cap = self.per_tenant_cap;
        }
        p
    }

    /// Parse + validate a config document. Every error names the
    /// offending key; nothing is applied on error (the caller keeps
    /// the old config).
    pub fn parse(text: &str) -> Result<RuntimeConfig, String> {
        let doc = Json::parse(text).map_err(|e| format!("config: {e}"))?;
        let top = doc.as_obj().map_err(|e| format!("config: {e}"))?;
        const KNOWN: &[&str] = &[
            "per_tenant_cap",
            "default_deadline_ms",
            "keep_alive_ms",
            "max_conn_requests",
            "read_budget_ms",
            "tenants",
            "fault",
            "fault_seed",
            "log",
            "resume_on_restart",
        ];
        for key in top.keys() {
            if !KNOWN.contains(&key.as_str()) {
                return Err(format!("config: unknown key '{key}'"));
            }
        }
        let mut cfg = RuntimeConfig::default();
        get_usize(&doc, "per_tenant_cap", &mut cfg.per_tenant_cap)?;
        get_u64(&doc, "default_deadline_ms", &mut cfg.default_deadline_ms)?;
        get_u64(&doc, "keep_alive_ms", &mut cfg.keep_alive_ms)?;
        get_usize(&doc, "max_conn_requests", &mut cfg.max_conn_requests)?;
        get_u64(&doc, "read_budget_ms", &mut cfg.read_budget_ms)?;
        if cfg.max_conn_requests == 0 {
            return Err("max_conn_requests: must be >= 1".into());
        }
        if let Some(v) = doc.opt("log") {
            let s = v.as_str().map_err(|e| format!("log: {e}"))?;
            cfg.log = Some(
                LogFormat::parse(s).ok_or_else(|| format!("log: unknown mode '{s}' (text/json/off)"))?,
            );
        }
        if let Some(v) = doc.opt("fault") {
            let spec = v.as_str().map_err(|e| format!("fault: {e}"))?;
            let mut seed = 0usize;
            get_usize(&doc, "fault_seed", &mut seed)?;
            cfg.fault = FaultSpec::parse(spec, seed as u64).map_err(|e| format!("fault: {e}"))?;
        } else if doc.opt("fault_seed").is_some() {
            return Err("fault_seed: set without a fault spec".into());
        }
        if let Some(v) = doc.opt("resume_on_restart") {
            match v {
                Json::Bool(b) => cfg.resume_on_restart = *b,
                _ => return Err("resume_on_restart: expected a boolean".into()),
            }
        }
        if let Some(v) = doc.opt("tenants") {
            let tenants = v.as_obj().map_err(|e| format!("tenants: {e}"))?;
            for (name, spec) in tenants {
                let p = Self::parse_tenant(name, spec)?;
                cfg.tenants.insert(name.clone(), p);
            }
        }
        Ok(cfg)
    }

    fn parse_tenant(name: &str, spec: &Json) -> Result<TenantPolicy, String> {
        let obj = spec.as_obj().map_err(|e| format!("tenant '{name}': {e}"))?;
        const KNOWN: &[&str] = &["priority", "cap", "rate_tokens_per_s", "burst_tokens"];
        for key in obj.keys() {
            if !KNOWN.contains(&key.as_str()) {
                return Err(format!("tenant '{name}': unknown key '{key}'"));
            }
        }
        let mut p = TenantPolicy::default();
        if let Some(v) = spec.opt("priority") {
            let s = v.as_str().map_err(|e| format!("tenant '{name}' priority: {e}"))?;
            p.priority = Priority::parse(s)
                .ok_or_else(|| format!("tenant '{name}' priority: unknown class '{s}' (high/normal/low)"))?;
        }
        get_usize(spec, "cap", &mut p.cap).map_err(|e| format!("tenant '{name}' {e}"))?;
        get_rate(spec, "rate_tokens_per_s", &mut p.rate_tokens_per_s)
            .map_err(|e| format!("tenant '{name}' {e}"))?;
        get_rate(spec, "burst_tokens", &mut p.burst_tokens)
            .map_err(|e| format!("tenant '{name}' {e}"))?;
        if p.burst_tokens > 0.0 && p.rate_tokens_per_s == 0.0 {
            return Err(format!("tenant '{name}': burst_tokens without rate_tokens_per_s"));
        }
        Ok(p)
    }

    /// Load + parse a config file.
    pub fn from_file(path: &Path) -> Result<RuntimeConfig, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("config {}: {e}", path.display()))?;
        Self::parse(&text).map_err(|e| format!("{}: {e}", path.display()))
    }
}

/// Atomically swappable config cell: readers snapshot with
/// [`ConfigCell::current`], a reload installs a whole new
/// [`RuntimeConfig`] at once. The generation counter lets `/stats`
/// (and the smoke test) observe that a reload landed.
pub struct ConfigCell {
    cfg: RwLock<Arc<RuntimeConfig>>,
    generation: AtomicU64,
}

impl ConfigCell {
    pub fn new(initial: RuntimeConfig) -> Self {
        Self { cfg: RwLock::new(Arc::new(initial)), generation: AtomicU64::new(1) }
    }

    /// A consistent snapshot; cheap (one `Arc` clone under a read lock).
    pub fn current(&self) -> Arc<RuntimeConfig> {
        self.cfg.read().expect("config cell poisoned").clone()
    }

    /// Swap in a validated config; returns the new generation.
    pub fn install(&self, cfg: RuntimeConfig) -> u64 {
        let mut slot = self.cfg.write().expect("config cell poisoned");
        *slot = Arc::new(cfg);
        self.generation.fetch_add(1, Ordering::SeqCst) + 1
    }

    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::SeqCst)
    }
}

/// Watches a config file for change by `(mtime, len)` stamp — the pair
/// catches both in-place rewrites and the same-second atomic-rename
/// case a bare mtime misses when the sizes differ.
pub struct ConfigWatcher {
    path: PathBuf,
    seen: Option<(SystemTime, u64)>,
}

impl ConfigWatcher {
    /// Start watching; the current stamp is recorded so only *future*
    /// edits trigger (the caller has already loaded the file once).
    pub fn new(path: PathBuf) -> Self {
        let seen = Self::stamp(&path);
        Self { path, seen }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    fn stamp(path: &Path) -> Option<(SystemTime, u64)> {
        let meta = std::fs::metadata(path).ok()?;
        Some((meta.modified().ok()?, meta.len()))
    }

    /// Mtime poll: `None` when unchanged (or the file is mid-rename),
    /// otherwise the parse result of the new contents. The stamp
    /// advances even on a parse error so a broken file logs once per
    /// edit, not once per poll.
    pub fn poll(&mut self) -> Option<Result<RuntimeConfig, String>> {
        let stamp = Self::stamp(&self.path)?;
        if self.seen == Some(stamp) {
            return None;
        }
        self.seen = Some(stamp);
        Some(RuntimeConfig::from_file(&self.path))
    }

    /// SIGHUP path: reload unconditionally, refreshing the stamp.
    pub fn force(&mut self) -> Result<RuntimeConfig, String> {
        self.seen = Self::stamp(&self.path);
        RuntimeConfig::from_file(&self.path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmp(name: &str, contents: &str) -> PathBuf {
        let path = std::env::temp_dir().join(format!("kurtail_cfg_{}_{name}", std::process::id()));
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(contents.as_bytes()).unwrap();
        f.sync_all().unwrap();
        path
    }

    #[test]
    fn parses_full_config() {
        let cfg = RuntimeConfig::parse(
            r#"{
                "per_tenant_cap": 8,
                "default_deadline_ms": 30000,
                "keep_alive_ms": 5000,
                "max_conn_requests": 16,
                "read_budget_ms": 2000,
                "log": "json",
                "fault": "slow_step=5",
                "fault_seed": 7,
                "resume_on_restart": false,
                "tenants": {
                    "alice": { "priority": "high", "rate_tokens_per_s": 100, "burst_tokens": 200 },
                    "batch": { "priority": "low", "cap": 2 }
                }
            }"#,
        )
        .unwrap();
        assert_eq!(cfg.per_tenant_cap, 8);
        assert_eq!(cfg.keep_alive_ms, 5000);
        assert_eq!(cfg.max_conn_requests, 16);
        assert_eq!(cfg.log, Some(LogFormat::Json));
        assert_eq!(cfg.fault.slow_step_ms, 5);
        assert_eq!(cfg.fault.seed, 7);
        assert!(!cfg.resume_on_restart, "explicit false overrides the on-by-default");
        let alice = cfg.policy("alice");
        assert_eq!(alice.priority, Priority::High);
        assert_eq!(alice.rate_tokens_per_s, 100.0);
        assert_eq!(alice.effective_burst(), 200.0);
        assert!(alice.rate_limited());
        let batch = cfg.policy("batch");
        assert_eq!(batch.priority, Priority::Low);
        assert_eq!(batch.cap, 2, "explicit cap wins over the global");
        assert!(!batch.rate_limited());
        // unknown tenants inherit the global cap and normal class
        let other = cfg.policy("nobody");
        assert_eq!(other.priority, Priority::Normal);
        assert_eq!(other.cap, 8);
    }

    #[test]
    fn empty_object_is_all_defaults() {
        let cfg = RuntimeConfig::parse("{}").unwrap();
        assert_eq!(cfg, RuntimeConfig::default());
        assert_eq!(cfg.policy("x").cap, 0);
    }

    #[test]
    fn rejects_malformed_configs_by_name() {
        let cases = [
            ("{\"per_tenant_capz\": 1}", "unknown key"),
            ("{\"per_tenant_cap\": -1}", "per_tenant_cap"),
            ("{\"max_conn_requests\": 0}", "max_conn_requests"),
            ("{\"log\": \"loud\"}", "log"),
            ("{\"fault\": \"bogus=1\"}", "fault"),
            ("{\"fault_seed\": 3}", "fault_seed"),
            ("{\"resume_on_restart\": 3}", "resume_on_restart"),
            ("{\"tenants\": {\"a\": {\"priority\": \"urgent\"}}}", "priority"),
            ("{\"tenants\": {\"a\": {\"rate_tokens_per_s\": -5}}}", "rate_tokens_per_s"),
            ("{\"tenants\": {\"a\": {\"burst_tokens\": 5}}}", "burst_tokens without"),
            ("{\"tenants\": {\"a\": {\"color\": 1}}}", "unknown key"),
            ("not json", "config"),
        ];
        for (text, needle) in cases {
            let err = RuntimeConfig::parse(text).expect_err(text);
            assert!(err.contains(needle), "error for {text:?} should name '{needle}': {err}");
        }
    }

    #[test]
    fn cell_swaps_atomically_and_bumps_generation() {
        let cell = ConfigCell::new(RuntimeConfig::default());
        assert_eq!(cell.generation(), 1);
        let before = cell.current();
        assert_eq!(before.per_tenant_cap, 0);
        let gen = cell.install(RuntimeConfig { per_tenant_cap: 3, ..RuntimeConfig::default() });
        assert_eq!(gen, 2);
        assert_eq!(cell.generation(), 2);
        assert_eq!(cell.current().per_tenant_cap, 3);
        // old snapshots stay valid (in-flight requests keep their view)
        assert_eq!(before.per_tenant_cap, 0);
    }

    #[test]
    fn watcher_triggers_on_rewrite_and_keeps_old_on_error() {
        let path = tmp("watch", "{\"per_tenant_cap\": 1}");
        let mut w = ConfigWatcher::new(path.clone());
        assert!(w.poll().is_none(), "freshly recorded stamp must not trigger");
        // rewrite with different length → stamp changes even within
        // the same mtime second
        std::fs::write(&path, "{\"per_tenant_cap\": 22}").unwrap();
        let got = w.poll().expect("rewrite triggers").expect("valid config parses");
        assert_eq!(got.per_tenant_cap, 22);
        assert!(w.poll().is_none(), "no re-trigger until the next edit");
        // a broken rewrite surfaces the error exactly once
        std::fs::write(&path, "{\"per_tenant_cap\": }").unwrap();
        assert!(w.poll().expect("edit triggers").is_err());
        assert!(w.poll().is_none(), "broken file logs once per edit, not per poll");
        // force (SIGHUP) reloads even without an edit
        assert!(w.force().is_err());
        std::fs::write(&path, "{}").unwrap();
        assert_eq!(w.force().unwrap(), RuntimeConfig::default());
        let _ = std::fs::remove_file(&path);
    }
}
