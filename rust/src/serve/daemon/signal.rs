//! Minimal SIGTERM/SIGINT/SIGHUP hooks for graceful drain and config
//! reload.
//!
//! The offline build has no `libc`/`signal-hook` crates, so the unix
//! path declares `signal(2)` directly and installs async-signal-safe
//! handlers that only flip static `AtomicBool`s (stores on atomics are
//! on POSIX's async-signal-safe list; nothing else happens in the
//! handlers). The daemon's run loop polls [`requested`] and starts a
//! drain when the shutdown flag flips; its accept loop polls
//! [`take_reload`] and re-reads the config file when the reload flag
//! flips. Non-unix builds compile to no-op installers — the flags then
//! only flip via `/admin/drain` and the mtime poll respectively.

use std::sync::atomic::{AtomicBool, Ordering};

static SHUTDOWN: AtomicBool = AtomicBool::new(false);
static RELOAD: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
mod imp {
    use std::sync::atomic::Ordering;

    const SIGHUP: i32 = 1;
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;

    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }

    extern "C" fn on_signal(_sig: i32) {
        super::SHUTDOWN.store(true, Ordering::SeqCst);
    }

    extern "C" fn on_reload(_sig: i32) {
        super::RELOAD.store(true, Ordering::SeqCst);
    }

    pub fn install() {
        unsafe {
            signal(SIGTERM, on_signal);
            signal(SIGINT, on_signal);
        }
    }

    pub fn install_reload() {
        unsafe {
            signal(SIGHUP, on_reload);
        }
    }
}

#[cfg(not(unix))]
mod imp {
    pub fn install() {}
    pub fn install_reload() {}
}

/// Install the SIGTERM/SIGINT handler (idempotent) and return the
/// shared shutdown flag.
pub fn install() -> &'static AtomicBool {
    imp::install();
    &SHUTDOWN
}

/// Whether a shutdown signal has been received.
pub fn requested() -> bool {
    SHUTDOWN.load(Ordering::SeqCst)
}

/// Install the SIGHUP handler (idempotent). Without it SIGHUP keeps
/// its default disposition (terminate), so the daemon only installs it
/// when it actually has a config file to re-read.
pub fn install_reload() {
    imp::install_reload();
}

/// Consume a pending reload request (SIGHUP since the last call).
pub fn take_reload() -> bool {
    RELOAD.swap(false, Ordering::SeqCst)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_starts_clear_and_is_shared() {
        // (no signal is raised in tests — other tests in this process
        // would see the flag too; just pin the accessor wiring)
        let flag = install();
        assert!(std::ptr::eq(flag, install()), "one shared flag");
        assert_eq!(flag.load(Ordering::SeqCst), requested());
    }

    #[test]
    fn reload_flag_is_consumed_once() {
        install_reload();
        RELOAD.store(true, Ordering::SeqCst);
        assert!(take_reload());
        assert!(!take_reload(), "swap(false) consumes the request");
    }
}
