//! Minimal SIGTERM/SIGINT hook for graceful drain.
//!
//! The offline build has no `libc`/`signal-hook` crates, so the unix
//! path declares `signal(2)` directly and installs an async-signal-safe
//! handler that only flips a static `AtomicBool` (stores on atomics are
//! on POSIX's async-signal-safe list; nothing else happens in the
//! handler). The daemon's run loop polls [`requested`] and starts a
//! drain when it flips. Non-unix builds compile to a no-op installer —
//! the flag then only flips via `/admin/drain`.

use std::sync::atomic::{AtomicBool, Ordering};

static SHUTDOWN: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
mod imp {
    use std::sync::atomic::Ordering;

    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;

    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }

    extern "C" fn on_signal(_sig: i32) {
        super::SHUTDOWN.store(true, Ordering::SeqCst);
    }

    pub fn install() {
        unsafe {
            signal(SIGTERM, on_signal);
            signal(SIGINT, on_signal);
        }
    }
}

#[cfg(not(unix))]
mod imp {
    pub fn install() {}
}

/// Install the SIGTERM/SIGINT handler (idempotent) and return the
/// shared shutdown flag.
pub fn install() -> &'static AtomicBool {
    imp::install();
    &SHUTDOWN
}

/// Whether a shutdown signal has been received.
pub fn requested() -> bool {
    SHUTDOWN.load(Ordering::SeqCst)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_starts_clear_and_is_shared() {
        // (no signal is raised in tests — other tests in this process
        // would see the flag too; just pin the accessor wiring)
        let flag = install();
        assert!(std::ptr::eq(flag, install()), "one shared flag");
        assert_eq!(flag.load(Ordering::SeqCst), requested());
    }
}
