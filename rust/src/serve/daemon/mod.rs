//! Long-running fault-tolerant serving front-end over [`Engine`].
//!
//! Layering (one engine thread, N connection threads):
//!
//! * [`Host`] — a clone-able handle to the engine thread. The engine is
//!   owned by exactly one thread ([`run_host`]); every interaction is a
//!   [`Cmd`] over an mpsc channel, and every accepted request streams
//!   its tokens back over its own [`Event`] channel. Submits are a
//!   rendezvous: the caller blocks until the engine accepted or shed
//!   the request, so backpressure ([`ServeError::QueueFull`] and
//!   friends) reaches the client synchronously.
//! * [`Daemon`] — the TCP front-end: an accept loop that spawns one
//!   detached thread per connection, a hand-rolled HTTP/1.1 layer with
//!   keep-alive (`http.rs`), deterministic fault injection (`fault.rs`)
//!   and SIGTERM-driven graceful drain (`signal.rs`).
//!
//! Overload resilience (PR 9):
//!
//! * **Priorities & rate limits** — every tenant carries a
//!   [`TenantPolicy`] (admission class `high`/`normal`/`low` plus a
//!   token-bucket rate limit over *generated* tokens). The engine's
//!   scheduler admits by weighted priority with a starvation bound;
//!   a high-class arrival at a full queue evicts the newest strictly
//!   lower-class entry (its owner sees a retryable 429). A bucket that
//!   can't cover a request's worst-case generation sheds it with
//!   [`ServeError::RateLimited`] and a `Retry-After` derived from the
//!   bucket deficit.
//! * **Live config reload** — the hot-reloadable knobs live in a
//!   [`RuntimeConfig`] inside a [`ConfigCell`]; SIGHUP or an edit to
//!   the `--config` file swaps a validated snapshot atomically
//!   (invalid files are logged and dropped, the old config stays).
//!   In-flight streams never notice a reload.
//! * **Engine supervision with transparent resume** — the engine
//!   thread runs its serve loop under `catch_unwind`. On a panic (or
//!   step error) the supervisor rebuilds a fresh engine from the dead
//!   one's read-only model, bumps `kurtail_engine_restarts_total`, and
//!   — with `resume_on_restart` (default on) — re-submits every
//!   in-flight stream from its host-side snapshot (prompt + tokens
//!   already streamed, kept in [`Tracked`]). Recompute is bitwise
//!   deterministic, so resumed streams continue exactly where they
//!   paused: clients see a stall, never a 503, and deadlines and
//!   rate-limit charges carry over. `resume_on_restart = false`
//!   restores the old behaviour (fail in-flight with the retryable
//!   [`ServeError::EngineRestarting`]). Request ids keep counting
//!   across incarnations.
//!
//! Graceful degradation (PR 10):
//!
//! * **KV-pressure preemption** — when the pool runs hot
//!   (`ServeConfig::kv_high_water`) and a queued higher-class request
//!   cannot fit, the engine snapshots the newest lowest-class live
//!   lane, releases its whole KV reservation and re-queues it at the
//!   front of its class ([`crate::serve::LaneSnapshot`]). The daemon
//!   holds the owning stream open — the client sees a pause — and the
//!   lane later resumes byte-identically via chunked-prefill
//!   recompute. `/stats` surfaces `preempted` / `resumed` /
//!   `resume_recompute_tokens`; `KURTAIL_FAULT=kv_pressure=N`
//!   synthesizes the pressure deterministically for tests.
//!
//! The daemon adds *no* model math of its own — completed token streams
//! are bitwise identical to an in-process [`Engine::run`] over the same
//! accepted submissions, faults or not (faults only move *admission*
//! timing and client visibility, never sampling).
//!
//! Shutdown contract: [`Daemon::begin_drain`] (or SIGTERM via
//! [`Daemon::run_until`], or `POST /admin/drain`) sheds the queue,
//! rejects every new submit with [`ServeError::Draining`] (HTTP 503 +
//! `Retry-After`), flips `/healthz` to 503, and lets live lanes run to
//! completion. `/stats` stays reachable *during* the drain so an
//! orchestrator can watch it converge; [`Daemon::join`] returns once
//! the last lane retired and every thread exited. The engine thread
//! breaks its loop only when draining *and* idle, so a drain never
//! abandons a live stream.
//!
//! Telemetry ([`crate::obs`]): the daemon renders the engine's metric
//! registry as Prometheus text on `GET /metrics` (engine histograms and
//! counters plus the per-tenant `kurtail_tenant_*_total` series owned
//! here), folds latency quantiles into `/stats`, emits one structured
//! log line per request lifecycle event (`KURTAIL_LOG=json|text|off`),
//! and derives `Retry-After` on backpressure responses from the
//! observed queue-wait p50 — or, before any queue wait was observed,
//! from the expected time until a retirement frees KV blocks (the
//! host loop's retirements/sec EWMA, `kurtail_retire_rate_milli`).

pub mod config;
pub mod fault;
pub mod http;
pub mod ratelimit;
pub mod signal;

use std::collections::{BTreeMap, HashMap};
use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, SyncSender};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::calib::ByteTokenizer;
use crate::model::Params;
use crate::obs::{self, Counter, EngineObs, HistSnapshot, LogValue, Registry, RequestSpan};
use crate::runtime::manifest::{ConfigMeta, ParamSpec};
use crate::tensor::hadamard::random_hadamard;
use crate::util::json::{self, Json};
use crate::util::par::ParBackend;
use crate::util::Rng;

use super::engine::{Completion, Engine, EngineStats, ServeConfig, ServeModel, ServeQuantSpec};
use super::error::ServeError;
use super::scheduler::Priority;
use config::{ConfigCell, ConfigWatcher, RuntimeConfig, TenantPolicy};
use fault::{FaultClock, FaultSpec};
use http::Request;
use ratelimit::TokenBucket;

// ---------------------------------------------------------------- host

/// Per-request notifications from the engine thread to the connection
/// that owns the request.
#[derive(Debug)]
pub enum Event {
    /// One generated token (prefill's first sample included), in order.
    Token(i32),
    /// The request finished normally; carries the full completion
    /// (prompt + generated tokens, decoded text).
    Done(Completion),
    /// The request ended without a completion (cancel, deadline, drain,
    /// engine failure). Terminal.
    Failed(ServeError),
}

/// An admission request handed to the engine thread.
pub struct SubmitReq {
    pub tokens: Vec<i32>,
    pub n_tokens: usize,
    pub temp: f32,
    pub seed: u64,
    pub stop: Option<i32>,
    /// Admission-quota bucket (`HostConfig::per_tenant_cap`).
    pub tenant: String,
    /// Absolute deadline; the engine thread cancels the request (queued
    /// or live) once it passes and emits [`Event::Failed`] `(Deadline)`.
    pub deadline: Option<Instant>,
    /// Where this request's [`Event`]s go.
    pub events: Sender<Event>,
}

enum Cmd {
    Submit(SubmitReq, SyncSender<Result<usize, ServeError>>),
    Cancel(usize),
    Drain,
    Stats(SyncSender<StatsSnapshot>),
}

/// Engine-thread configuration (the non-HTTP half of [`DaemonConfig`]).
#[derive(Clone, Debug, Default)]
pub struct HostConfig {
    /// Max in-flight (queued + live) requests per tenant; `0` = no
    /// per-tenant bound. Rejections count as shed and surface as
    /// [`ServeError::QueueFull`].
    pub per_tenant_cap: usize,
    /// Deterministic fault injection (`KURTAIL_FAULT`).
    pub fault: FaultSpec,
    /// Tenant policies (priority class + rate limits) for hosts spawned
    /// without a daemon/config file (benches, tests). Absent tenants
    /// get [`TenantPolicy::default`].
    pub tenants: BTreeMap<String, TenantPolicy>,
}

/// Clone-able handle to the engine thread.
#[derive(Clone)]
pub struct Host {
    tx: Sender<Cmd>,
}

impl Host {
    /// Submit a request; blocks until the engine thread accepted or
    /// shed it. After the engine thread exits (post-drain) every submit
    /// reports [`ServeError::Draining`].
    pub fn submit(&self, req: SubmitReq) -> Result<usize, ServeError> {
        let (reply, rx) = mpsc::sync_channel(1);
        if self.tx.send(Cmd::Submit(req, reply)).is_err() {
            return Err(ServeError::Draining);
        }
        rx.recv().unwrap_or(Err(ServeError::Draining))
    }

    /// Cancel a request wherever it is; its owner sees
    /// [`Event::Failed`] `(Canceled)` if it was still in flight.
    pub fn cancel(&self, id: usize) {
        let _ = self.tx.send(Cmd::Cancel(id));
    }

    /// Start a drain (shed queue, reject new submits, finish live
    /// lanes).
    pub fn drain(&self) {
        let _ = self.tx.send(Cmd::Drain);
    }

    /// Snapshot the engine counters; [`ServeError::Draining`] once the
    /// engine thread has exited.
    pub fn stats(&self) -> Result<StatsSnapshot, ServeError> {
        let (reply, rx) = mpsc::sync_channel(1);
        if self.tx.send(Cmd::Stats(reply)).is_err() {
            return Err(ServeError::Draining);
        }
        rx.recv().map_err(|_| ServeError::Draining)
    }
}

/// One `/stats` observation of the engine thread.
#[derive(Clone, Debug)]
pub struct StatsSnapshot {
    pub engine: EngineStats,
    pub queued: usize,
    pub live: usize,
    pub free_blocks: usize,
    pub max_blocks: usize,
    pub committed_blocks: usize,
    pub withheld_blocks: usize,
    /// Σ(refs − 1) over pool blocks: blocks lanes hold via prefix
    /// sharing without owning storage.
    pub shared_block_refs: usize,
    /// The scheduler's head-of-line bypass budget
    /// (`ServeConfig::max_head_skips`) — static config surfaced so
    /// operators can correlate queue-wait tails with the aging policy.
    pub max_head_skips: usize,
    pub scratch_rows: usize,
    pub panel_cache_bytes: usize,
    pub draining: bool,
    /// Runtime-config generation from the live-reload cell (starts at
    /// 1; every applied reload bumps it — the smoke test polls this).
    pub config_generation: u64,
    /// Engine incarnations rebuilt by the supervisor after a panic or
    /// step failure.
    pub engine_restarts: u64,
    pub uptime_s: f64,
    pub tok_s: f64,
    pub latency: LatencySnapshot,
}

/// Histogram snapshots folded into `/stats` (quantiles are derived at
/// render time; the engine thread only copies atomics here).
#[derive(Clone, Debug, Default)]
pub struct LatencySnapshot {
    pub queue_wait: HistSnapshot,
    pub ttft: HistSnapshot,
    pub prefill: HistSnapshot,
    pub decode_step: HistSnapshot,
    pub phases: [HistSnapshot; obs::N_PHASES],
}

impl LatencySnapshot {
    fn of(eobs: &EngineObs) -> Self {
        Self {
            queue_wait: eobs.queue_wait.snapshot(),
            ttft: eobs.ttft.snapshot(),
            prefill: eobs.prefill.snapshot(),
            decode_step: eobs.decode_step.snapshot(),
            phases: std::array::from_fn(|i| eobs.phases[i].snapshot()),
        }
    }
}

/// `{count, mean_ms, p50_ms, p90_ms, p99_ms}` for one histogram.
/// Quantiles are bucket upper bounds (within 2× of the true value).
fn hist_ms_json(s: &HistSnapshot) -> Json {
    let q = |p: f64| s.quantile_ns(p).map(|ns| ns as f64 / 1e6).unwrap_or(0.0);
    json::obj(vec![
        ("count", json::num(s.count as f64)),
        ("mean_ms", json::num(s.mean_ns().unwrap_or(0.0) / 1e6)),
        ("p50_ms", json::num(q(0.5))),
        ("p90_ms", json::num(q(0.9))),
        ("p99_ms", json::num(q(0.99))),
    ])
}

impl StatsSnapshot {
    pub fn to_json(&self) -> Json {
        let e = &self.engine;
        let n = |v: u64| json::num(v as f64);
        let u = |v: usize| json::num(v as f64);
        let l = &self.latency;
        json::obj(vec![
            (
                "engine",
                json::obj(vec![
                    ("steps", n(e.steps)),
                    ("prefill_tokens", n(e.prefill_tokens)),
                    ("prefill_chunks", n(e.prefill_chunks)),
                    ("prefix_hits", n(e.prefix_hits)),
                    ("prefix_shared_tokens", n(e.prefix_shared_tokens)),
                    ("decode_tokens", n(e.decode_tokens)),
                    ("admitted", n(e.admitted)),
                    ("retired", n(e.retired)),
                    ("eos_retired", n(e.eos_retired)),
                    ("shed", n(e.shed)),
                    ("canceled", n(e.canceled)),
                    ("preempted", n(e.preempted)),
                    ("resumed", n(e.resumed)),
                    ("resume_recompute_tokens", n(e.resume_recompute_tokens)),
                    ("peak_lanes", u(e.peak_lanes)),
                ]),
            ),
            ("queued", u(self.queued)),
            ("live", u(self.live)),
            ("free_blocks", u(self.free_blocks)),
            ("max_blocks", u(self.max_blocks)),
            ("committed_blocks", u(self.committed_blocks)),
            ("withheld_blocks", u(self.withheld_blocks)),
            ("shared_block_refs", u(self.shared_block_refs)),
            ("max_head_skips", u(self.max_head_skips)),
            ("scratch_rows", u(self.scratch_rows)),
            ("panel_cache_bytes", u(self.panel_cache_bytes)),
            ("draining", Json::Bool(self.draining)),
            ("config_generation", n(self.config_generation)),
            ("engine_restarts", n(self.engine_restarts)),
            ("uptime_s", json::num(self.uptime_s)),
            ("tok_s", json::num(self.tok_s)),
            (
                "latency",
                json::obj(vec![
                    ("queue_wait", hist_ms_json(&l.queue_wait)),
                    ("ttft", hist_ms_json(&l.ttft)),
                    ("prefill", hist_ms_json(&l.prefill)),
                    ("decode_step", hist_ms_json(&l.decode_step)),
                    (
                        "decode_phase",
                        json::obj(
                            obs::PHASE_NAMES
                                .iter()
                                .zip(l.phases.iter())
                                .map(|(name, s)| (*name, hist_ms_json(s)))
                                .collect(),
                        ),
                    ),
                ]),
            ),
        ])
    }
}

fn snapshot(engine: &Engine, started: Instant) -> StatsSnapshot {
    let stats = engine.stats;
    let uptime = started.elapsed().as_secs_f64();
    let toks = (stats.prefill_tokens + stats.decode_tokens) as f64;
    StatsSnapshot {
        engine: stats,
        queued: engine.queued(),
        live: engine.live_lanes(),
        free_blocks: engine.pool().free_blocks(),
        max_blocks: engine.pool().max_blocks,
        committed_blocks: engine.committed_blocks(),
        withheld_blocks: engine.withheld_blocks(),
        shared_block_refs: engine.shared_block_refs(),
        max_head_skips: engine.max_head_skips(),
        scratch_rows: engine.scratch_rows(),
        panel_cache_bytes: engine.panel_cache_bytes(),
        draining: engine.draining(),
        // both owned by the engine thread's supervisor state, patched
        // in by the Cmd::Stats handler
        config_generation: 0,
        engine_restarts: 0,
        uptime_s: uptime,
        tok_s: if uptime > 0.0 { toks / uptime } else { 0.0 },
        latency: LatencySnapshot::of(engine.obs()),
    }
}

/// `Retry-After` from the observed queue drain rate — the p50 queue
/// wait rounded up to whole seconds, clamped to `[1, 60]`. With an
/// empty histogram (cold start, obs off) the hint falls back to the
/// expected time until the next retirement frees KV blocks, from the
/// host loop's retirements/sec EWMA (`kurtail_retire_rate_milli`);
/// with no observed retirements either it stays at `1`, the old
/// constant.
fn retry_after_s(eobs: &EngineObs) -> u64 {
    match eobs.queue_wait.snapshot().quantile_ns(0.5) {
        Some(ns) => ((ns as f64 / 1e9).ceil() as u64).clamp(1, 60),
        None => {
            let rate_milli = eobs.retire_rate_milli.get();
            if rate_milli == 0 {
                1
            } else {
                // ceil(1 / rate) seconds between block-freeing retirements
                ((1000 + rate_milli - 1) / rate_milli).clamp(1, 60)
            }
        }
    }
}

/// Spawn the engine thread and return its [`Host`] handle (public so
/// the serve bench can drive the host without a socket in the path).
/// Hosts spawned this way carry a fixed config (no reload) and no
/// rebuild recipe: an engine failure fails everything and exits, the
/// pre-supervision behaviour.
pub fn spawn_host(engine: Engine, cfg: HostConfig) -> (Host, JoinHandle<()>) {
    let cell = Arc::new(ConfigCell::new(RuntimeConfig {
        per_tenant_cap: cfg.per_tenant_cap,
        tenants: cfg.tenants.clone(),
        fault: cfg.fault.clone(),
        ..RuntimeConfig::default()
    }));
    spawn_host_with(engine, cell, None)
}

/// Spawn a host against a caller-held [`ConfigCell`]: the caller keeps
/// installing new configs and the host picks them up live. Used by the
/// reload property/integration tests; no supervision (like
/// [`spawn_host`], an engine failure fails everything and exits).
pub fn spawn_host_reloadable(engine: Engine, cell: Arc<ConfigCell>) -> (Host, JoinHandle<()>) {
    spawn_host_with(engine, cell, None)
}

/// Spawn a *supervised* host against a caller-held [`ConfigCell`]: an
/// engine panic or step error rebuilds a fresh engine from `scfg` and
/// — per `resume_on_restart` — resumes the in-flight streams. This is
/// the daemon's engine-thread behaviour without the HTTP front-end,
/// for the restart/resume property tests and the serve bench.
pub fn spawn_host_supervised(
    engine: Engine,
    cell: Arc<ConfigCell>,
    scfg: ServeConfig,
) -> (Host, JoinHandle<()>) {
    let restarts = Some(engine.obs().registry.counter(
        "kurtail_engine_restarts_total",
        "Engine rebuilds after a panic or step failure.",
        &[],
    ));
    spawn_host_with(engine, cell, Some(Supervise { scfg, restarts }))
}

/// Rebuild recipe for the supervised path ([`Daemon::spawn`]): with it,
/// an engine panic or step error is survivable — a fresh engine is
/// built from the dead one's (read-only, already-warmed) model and
/// in-flight streams resume from their host-side snapshots
/// (`resume_on_restart`, default on) or fail with the retryable
/// [`ServeError::EngineRestarting`] when resume is disabled.
struct Supervise {
    scfg: ServeConfig,
    /// `kurtail_engine_restarts_total`; `None` with obs off.
    restarts: Option<Arc<Counter>>,
}

fn spawn_host_with(
    engine: Engine,
    cell: Arc<ConfigCell>,
    supervise: Option<Supervise>,
) -> (Host, JoinHandle<()>) {
    let (tx, rx) = mpsc::channel();
    let started = Instant::now();
    let handle = thread::Builder::new()
        .name("kurtail-engine".into())
        .spawn(move || run_supervisor(engine, cell, supervise, rx, started))
        .expect("spawn engine thread");
    (Host { tx }, handle)
}

struct Tracked {
    events: Sender<Event>,
    tenant: String,
    deadline: Option<Instant>,
    /// Tokens charged to the tenant's bucket at admission (`0` when the
    /// tenant has no rate limit); the unused remainder is refunded when
    /// the request finishes.
    charged: f64,
    /// Tokens actually streamed so far — the refund basis when the
    /// request ends without a completion. Stays monotone across a
    /// resume, so recomputed positions are never double-charged.
    sent: usize,
    /// Resume snapshot: the prompt plus every token streamed so far,
    /// appended as the engine emits. On an engine restart the
    /// supervisor re-submits this into the fresh incarnation
    /// ([`resume_tracked`]) so the stream continues byte-identically.
    tokens: Vec<i32>,
    prompt_len: usize,
    /// The remaining submit parameters, kept verbatim so a restart can
    /// reconstruct the request exactly (same id → same rng stream).
    n_tokens: usize,
    temp: f32,
    seed: u64,
    stop: Option<i32>,
    priority: Priority,
}

/// The per-tenant series (`kurtail_tenant_*_total{tenant=...}`).
struct TenantCounters {
    requests: Arc<Counter>,
    shed: Arc<Counter>,
    canceled: Arc<Counter>,
    rate_limited: Arc<Counter>,
}

/// Daemon-side telemetry, owned by the engine thread: per-tenant
/// counters registered against the *engine's* registry (so `/metrics`
/// carries them alongside the engine series) and one structured log
/// line per request lifecycle event. Counter updates honour the
/// engine's obs switch; logging is governed by `KURTAIL_LOG` alone.
struct DaemonObs {
    enabled: bool,
    registry: Arc<Registry>,
    tenants: HashMap<String, TenantCounters>,
}

impl DaemonObs {
    fn new(eobs: &EngineObs) -> Self {
        Self { enabled: eobs.enabled, registry: Arc::clone(&eobs.registry), tenants: HashMap::new() }
    }

    fn tenant(&mut self, tenant: &str) -> &TenantCounters {
        if !self.tenants.contains_key(tenant) {
            let c = TenantCounters {
                requests: self.registry.counter(
                    "kurtail_tenant_requests_total",
                    "Requests received per tenant (accepted and rejected)",
                    &[("tenant", tenant)],
                ),
                shed: self.registry.counter(
                    "kurtail_tenant_shed_total",
                    "Requests shed per tenant (queue full, tenant cap, pool, drain, too large)",
                    &[("tenant", tenant)],
                ),
                canceled: self.registry.counter(
                    "kurtail_tenant_canceled_total",
                    "Requests canceled per tenant (client cancel or deadline)",
                    &[("tenant", tenant)],
                ),
                rate_limited: self.registry.counter(
                    "kurtail_tenant_rate_limited_total",
                    "Requests shed per tenant by the token-bucket rate limit",
                    &[("tenant", tenant)],
                ),
            };
            self.tenants.insert(tenant.to_string(), c);
        }
        &self.tenants[tenant]
    }

    fn accepted(&mut self, id: usize, tenant: &str) {
        if self.enabled {
            self.tenant(tenant).requests.inc();
        }
        obs::log::info(
            "request_accepted",
            &[("id", LogValue::U64(id as u64)), ("tenant", LogValue::Str(tenant))],
        );
    }

    fn rejected(&mut self, tenant: &str, e: &ServeError) {
        // `Invalid` is a client error, not load shedding
        let is_shed = !matches!(e, ServeError::Invalid(_));
        if self.enabled {
            let t = self.tenant(tenant);
            t.requests.inc();
            if is_shed {
                t.shed.inc();
            }
            if matches!(e, ServeError::RateLimited { .. }) {
                t.rate_limited.inc();
            }
        }
        obs::log::warn(
            if is_shed { "request_shed" } else { "request_rejected" },
            &[("tenant", LogValue::Str(tenant)), ("outcome", LogValue::Str(e.kind()))],
        );
    }

    fn finished(&mut self, id: usize, tenant: &str, ev: &Event) {
        match ev {
            Event::Done(c) => {
                let s = &c.span;
                obs::log::info(
                    "request_done",
                    &[
                        ("id", LogValue::U64(id as u64)),
                        ("tenant", LogValue::Str(tenant)),
                        ("outcome", LogValue::Str("ok")),
                        ("queue_wait_ms", LogValue::F64(s.queue_wait_ns as f64 / 1e6)),
                        ("prefill_ms", LogValue::F64(s.prefill_ns as f64 / 1e6)),
                        ("decode_ms", LogValue::F64(s.decode_ns as f64 / 1e6)),
                        ("new_tokens", LogValue::U64(s.new_tokens)),
                    ],
                );
            }
            Event::Failed(e) => {
                if self.enabled && matches!(e, ServeError::Canceled | ServeError::Deadline) {
                    self.tenant(tenant).canceled.inc();
                }
                obs::log::warn(
                    "request_failed",
                    &[
                        ("id", LogValue::U64(id as u64)),
                        ("tenant", LogValue::Str(tenant)),
                        ("outcome", LogValue::Str(e.kind())),
                    ],
                );
            }
            Event::Token(_) => {}
        }
    }

    /// An already-accepted request evicted from the queue by a
    /// higher-class arrival: counts toward the tenant's shed series
    /// (it was counted in `requests` at acceptance).
    fn evicted(&mut self, tenant: &str) {
        if self.enabled {
            self.tenant(tenant).shed.inc();
        }
    }
}

/// Engine-thread bookkeeping that must survive an engine restart: who
/// is in flight, per-tenant in-flight counts and token buckets, the
/// daemon-side telemetry and the restart tally.
struct HostState {
    tracked: HashMap<usize, Tracked>,
    tenants: HashMap<String, usize>,
    buckets: HashMap<String, TokenBucket>,
    dobs: DaemonObs,
    restarts: u64,
}

impl HostState {
    fn new(eobs: &EngineObs) -> Self {
        Self {
            tracked: HashMap::new(),
            tenants: HashMap::new(),
            buckets: HashMap::new(),
            dobs: DaemonObs::new(eobs),
            restarts: 0,
        }
    }

    /// Retire one request: refund the unused bucket charge, update the
    /// telemetry and hand the terminal event to its owner.
    fn finish(&mut self, id: usize, ev: Event) {
        if let Some(t) = self.tracked.remove(&id) {
            if let Some(n) = self.tenants.get_mut(&t.tenant) {
                *n = n.saturating_sub(1);
            }
            if t.charged > 0.0 {
                let used = match &ev {
                    Event::Done(c) => (c.tokens.len() - c.prompt_len) as f64,
                    _ => t.sent as f64,
                };
                if let Some(b) = self.buckets.get_mut(&t.tenant) {
                    b.refund((t.charged - used).max(0.0));
                }
            }
            self.dobs.finished(id, &t.tenant, &ev);
            // the owner may have hung up already; that's its problem
            let _ = t.events.send(ev);
        }
    }

    /// Fail every in-flight request with (a clone of) `e`.
    fn fail_all(&mut self, e: &ServeError) {
        let ids: Vec<usize> = self.tracked.keys().copied().collect();
        for id in ids {
            self.finish(id, Event::Failed(e.clone()));
        }
    }
}

/// Why one engine incarnation's serve loop returned.
enum HostExit {
    /// Drained to idle, or every [`Host`] handle is gone: clean exit.
    Done,
    /// `Engine::step_with` reported an error; the supervisor decides
    /// whether to rebuild or fail out.
    EngineFailed(String),
}

/// The engine thread: a supervisor around [`run_host_once`]. The serve
/// loop runs under `catch_unwind`; on a panic or step error the
/// supervisor fails every in-flight request with the retryable
/// [`ServeError::EngineRestarting`], rebuilds a fresh engine from the
/// dead one's read-only model (when it has a [`Supervise`] recipe) and
/// keeps serving. Request ids continue across incarnations so a stale
/// cancel can never hit a new request.
fn run_supervisor(
    mut engine: Engine,
    cell: Arc<ConfigCell>,
    supervise: Option<Supervise>,
    rx: Receiver<Cmd>,
    started: Instant,
) {
    let mut st = HostState::new(engine.obs());
    let mut clock = FaultClock::new(cell.current().fault.clone());
    let eobs = engine.obs().clone();
    loop {
        let exit = catch_unwind(AssertUnwindSafe(|| {
            run_host_once(&mut engine, &cell, &mut st, &mut clock, &rx, started)
        }));
        let msg = match exit {
            Ok(HostExit::Done) => break,
            Ok(HostExit::EngineFailed(msg)) => msg,
            Err(panic) => {
                let what = panic
                    .downcast_ref::<&'static str>()
                    .map(|s| (*s).to_string())
                    .or_else(|| panic.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "unknown panic".into());
                format!("engine panicked: {what}")
            }
        };
        let Some(sup) = &supervise else {
            // no rebuild recipe (bare spawn_host): fail in-flight and
            // exit; the accept side then reports Draining
            obs::log::error("engine_failed", &[("error", LogValue::Str(&msg))]);
            st.fail_all(&ServeError::Internal(msg));
            return;
        };
        // supervised: rebuild from the dead engine's model and keep
        // serving — in-flight streams resume from their host snapshots
        // (default) or shed with a retryable signal (resume off)
        st.restarts += 1;
        if let Some(c) = &sup.restarts {
            c.inc();
        }
        obs::log::error(
            "engine_restarting",
            &[("error", LogValue::Str(&msg)), ("restarts", LogValue::U64(st.restarts))],
        );
        let resume = cell.current().resume_on_restart;
        if !resume {
            st.fail_all(&ServeError::EngineRestarting);
        }
        let draining = engine.draining();
        let next_id = engine.next_id();
        match Engine::with_obs(engine.model().clone(), &sup.scfg, eobs.clone()) {
            Ok(mut fresh) => {
                fresh.resume_ids_from(next_id);
                if draining {
                    fresh.begin_drain();
                }
                if resume {
                    resume_tracked(&mut fresh, &mut st);
                }
                engine = fresh;
            }
            Err(e) => {
                let err = format!("{e:#}");
                obs::log::error("engine_rebuild_failed", &[("error", LogValue::Str(&err))]);
                st.fail_all(&ServeError::EngineRestarting);
                return;
            }
        }
    }
    // clean exit: whatever is still tracked gets the drain signal
    st.fail_all(&ServeError::Draining);
}

/// Transparent resume across an engine restart: every tracked stream is
/// re-submitted into the fresh incarnation from its host-side snapshot
/// (prompt + tokens already streamed). Bitwise-deterministic recompute
/// makes the restart invisible — each resumed stream continues exactly
/// where it paused, so its owner sees a stall instead of a 503, and
/// deadlines and bucket charges carry over untouched (`Tracked` is
/// host state, not engine state). A snapshot that had already produced
/// its full budget (the crash landed between its last token and its
/// completion event) gets a host-synthesized [`Event::Done`]. Ids are
/// re-queued in descending order: `resubmit_resumed` prepends, so the
/// queue comes out ascending and FCFS order within a class survives.
fn resume_tracked(engine: &mut Engine, st: &mut HostState) {
    let mut ids: Vec<usize> = st.tracked.keys().copied().collect();
    ids.sort_unstable_by(|a, b| b.cmp(a));
    let mut resumed = 0u64;
    for id in ids {
        let (tokens, prompt_len, n_tokens, temp, seed, stop, priority) = {
            let t = &st.tracked[&id];
            (t.tokens.clone(), t.prompt_len, t.n_tokens, t.temp, t.seed, t.stop, t.priority)
        };
        let produced = tokens.len() - prompt_len;
        let hit_stop = produced > 0 && stop.is_some() && tokens.last() == stop.as_ref();
        if produced >= n_tokens || hit_stop {
            let c = Completion {
                id,
                prompt_len,
                text: ByteTokenizer.decode(&tokens),
                tokens,
                span: RequestSpan { new_tokens: produced as u64, ..RequestSpan::default() },
            };
            st.finish(id, Event::Done(c));
            continue;
        }
        match engine.resubmit_resumed(id, tokens, prompt_len, n_tokens, temp, seed, stop, priority)
        {
            Ok(()) => resumed += 1,
            Err(e) => st.finish(id, Event::Failed(e)),
        }
    }
    obs::log::info("engine_resumed", &[("streams", LogValue::U64(resumed))]);
}

/// One engine incarnation's serve loop: single owner of the [`Engine`],
/// processing commands between steps. Returns when draining and idle
/// (the clean path), when every [`Host`] is gone and no work remains,
/// or when a step fails.
fn run_host_once(
    engine: &mut Engine,
    cell: &ConfigCell,
    st: &mut HostState,
    clock: &mut FaultClock,
    rx: &Receiver<Cmd>,
    started: Instant,
) -> HostExit {
    let max_blocks = engine.pool().max_blocks;
    let mut disconnects: Vec<usize> = Vec::new();
    let mut seen_gen = 0u64;
    // retirements/sec EWMA (`kurtail_retire_rate_milli`): the expected
    // block-free time behind the cold-start `Retry-After` fallback
    let obs_on = engine.obs().enabled;
    let mut rate_at = Instant::now();
    let mut rate_retired = engine.stats.retired;
    loop {
        // pick up config reloads: swap the fault timeline only when the
        // spec actually changed (a reload that leaves `fault` alone must
        // not re-seed or re-arm the clock mid-run)
        let gen = cell.generation();
        if gen != seen_gen {
            seen_gen = gen;
            let fault = cell.current().fault.clone();
            if &fault != clock.spec() {
                *clock = FaultClock::new(fault);
            }
        }
        let idle = engine.queued() == 0 && engine.live_lanes() == 0;
        if idle && engine.draining() {
            return HostExit::Done;
        }
        // gather commands: park briefly when idle, never block when
        // lanes are live (steps must keep flowing)
        let mut cmds: Vec<Cmd> = Vec::new();
        if idle {
            match rx.recv_timeout(Duration::from_millis(10)) {
                Ok(c) => cmds.push(c),
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => return HostExit::Done,
            }
        }
        while let Ok(c) = rx.try_recv() {
            cmds.push(c);
        }
        for c in cmds {
            match c {
                Cmd::Submit(req, reply) => {
                    let _ = reply.send(admit(engine, cell, st, req));
                }
                Cmd::Cancel(id) => {
                    if engine.cancel(id) {
                        st.finish(id, Event::Failed(ServeError::Canceled));
                    }
                }
                Cmd::Drain => {
                    for id in engine.begin_drain() {
                        st.finish(id, Event::Failed(ServeError::Draining));
                    }
                }
                Cmd::Stats(reply) => {
                    let mut s = snapshot(engine, started);
                    s.config_generation = cell.generation();
                    s.engine_restarts = st.restarts;
                    let _ = reply.send(s);
                }
            }
        }
        // a higher-class arrival may have evicted queued lower-class
        // requests at the bound: their owners get the shed signal now
        for id in engine.take_preempted() {
            if let Some(t) = st.tracked.get(&id) {
                let tenant = t.tenant.clone();
                st.dobs.evicted(&tenant);
            }
            st.finish(id, Event::Failed(ServeError::QueueFull { cap: engine.queue_cap() }));
        }
        // deadline sweep: cancel overdue requests wherever they are
        let now = Instant::now();
        let overdue: Vec<usize> = st
            .tracked
            .iter()
            .filter(|(_, t)| t.deadline.is_some_and(|d| now >= d))
            .map(|(&id, _)| id)
            .collect();
        for id in overdue {
            engine.cancel(id);
            st.finish(id, Event::Failed(ServeError::Deadline));
        }
        if engine.queued() == 0 && engine.live_lanes() == 0 {
            // idle: park the EWMA window so dead time between bursts
            // doesn't read as a collapsed retirement rate
            rate_at = Instant::now();
            rate_retired = engine.stats.retired;
            continue;
        }
        // fault injection is a per-step decision so a given seed yields
        // one reproducible timeline
        if !clock.spec().is_none() {
            engine.set_withheld_blocks(clock.withhold_blocks(max_blocks));
            if let Some(d) = clock.step_delay() {
                thread::sleep(d);
            }
            if clock.engine_panic() {
                panic!("injected engine_panic fault");
            }
        }
        let tracked = &mut st.tracked;
        let step = engine.step_with(|id, tok| {
            if let Some(t) = tracked.get_mut(&id) {
                // grow the resume snapshot first: a disconnected owner
                // is canceled below, so an extra token is harmless, but
                // a missing one would corrupt a restart resume
                t.tokens.push(tok);
                if t.events.send(Event::Token(tok)).is_err() {
                    disconnects.push(id);
                } else {
                    t.sent += 1;
                }
            }
        });
        if let Err(e) = step {
            return HostExit::EngineFailed(format!("engine step failed: {e:#}"));
        }
        for c in engine.take_completions() {
            let id = c.id;
            st.finish(id, Event::Done(c));
        }
        // fold this window's retirement rate into the EWMA (only while
        // actively stepping: idle time must not decay the estimate)
        let dt = rate_at.elapsed();
        if obs_on && dt >= Duration::from_millis(200) {
            let retired = engine.stats.retired;
            let inst = retired.saturating_sub(rate_retired) as f64 * 1000.0 / dt.as_secs_f64();
            let prev = engine.obs().retire_rate_milli.get() as f64;
            let ewma = if prev == 0.0 { inst } else { 0.8 * prev + 0.2 * inst };
            engine.obs().retire_rate_milli.set(ewma.round() as u64);
            rate_at = Instant::now();
            rate_retired = retired;
        }
        // a dead Event receiver means the client hung up: reclaim the
        // lane's blocks now instead of decoding into the void
        for id in std::mem::take(&mut disconnects) {
            engine.cancel(id);
            st.finish(id, Event::Failed(ServeError::Canceled));
        }
    }
}

/// One admission decision against the current config snapshot: tenant
/// in-flight cap, then the token bucket, then the engine's priority
/// queue. The bucket is charged the full `n_tokens` upfront (worst
/// case, mirroring the engine's conservative KV reservation); the
/// unused remainder comes back when the request finishes.
fn admit(
    engine: &mut Engine,
    cell: &ConfigCell,
    st: &mut HostState,
    req: SubmitReq,
) -> Result<usize, ServeError> {
    let SubmitReq { tokens, n_tokens, temp, seed, stop, tenant, deadline, events } = req;
    let policy = cell.current().policy(&tenant);
    let mut charged = 0.0f64;
    let res = if policy.cap > 0 && st.tenants.get(&tenant).copied().unwrap_or(0) >= policy.cap {
        shed_mirror(engine);
        Err(ServeError::QueueFull { cap: policy.cap })
    } else if let Err(retry_after_s) = charge_bucket(st, &policy, &tenant, n_tokens, &mut charged) {
        shed_mirror(engine);
        Err(ServeError::RateLimited { retry_after_s })
    } else {
        // the engine consumes the tokens; the clone seeds the host-side
        // resume snapshot so a restart can reconstruct the request
        let r =
            engine.submit_tokens_prio(tokens.clone(), n_tokens, temp, seed, stop, policy.priority);
        if r.is_err() && charged > 0.0 {
            if let Some(b) = st.buckets.get_mut(&tenant) {
                b.refund(charged);
            }
        }
        r
    };
    match &res {
        Ok(id) => {
            st.dobs.accepted(*id, &tenant);
            *st.tenants.entry(tenant.clone()).or_insert(0) += 1;
            st.tracked.insert(
                *id,
                Tracked {
                    events,
                    tenant,
                    deadline,
                    charged,
                    sent: 0,
                    prompt_len: tokens.len(),
                    tokens,
                    n_tokens,
                    temp,
                    seed,
                    stop,
                    priority: policy.priority,
                },
            );
        }
        Err(e) => st.dobs.rejected(&tenant, e),
    }
    res
}

/// Mirror an admission-layer shed into the engine's counters (exactly
/// as engine-side sheds do) so `/metrics` reconciles with `/stats`.
fn shed_mirror(engine: &mut Engine) {
    engine.stats.shed += 1;
    if engine.obs().enabled {
        engine.obs().requests_shed.inc();
    }
}

/// Charge the tenant's token bucket for the worst-case generation,
/// creating the bucket on first use and reconfiguring it when a live
/// reload changed the tenant's limit. `Err(retry_after_s)` when the
/// bucket can't cover the request.
fn charge_bucket(
    st: &mut HostState,
    policy: &TenantPolicy,
    tenant: &str,
    n_tokens: usize,
    charged: &mut f64,
) -> Result<(), u64> {
    if !policy.rate_limited() {
        return Ok(());
    }
    let now = Instant::now();
    let bucket = st
        .buckets
        .entry(tenant.to_string())
        .or_insert_with(|| TokenBucket::new(policy.rate_tokens_per_s, policy.effective_burst(), now));
    if bucket.rate() != policy.rate_tokens_per_s || bucket.burst() != policy.effective_burst() {
        bucket.reconfigure(policy.rate_tokens_per_s, policy.effective_burst(), now);
    }
    bucket.try_take(n_tokens as f64, now)?;
    *charged = n_tokens as f64;
    Ok(())
}

// -------------------------------------------------------------- daemon

/// Daemon configuration: the HTTP front-end plus the engine knobs.
#[derive(Clone, Debug)]
pub struct DaemonConfig {
    /// Bind address; port `0` picks a free port (see [`Daemon::addr`]).
    pub addr: String,
    /// Engine admission-queue bound (routed into
    /// [`ServeConfig::queue_cap`]; the backpressure signal).
    pub queue_cap: usize,
    /// Per-tenant in-flight cap ([`HostConfig::per_tenant_cap`]).
    pub per_tenant_cap: usize,
    /// Default request deadline in ms when the body carries none
    /// (`0` = no deadline).
    pub default_deadline_ms: u64,
    pub serve: ServeConfig,
    pub fault: FaultSpec,
    /// Tenant policies for the file-less path (tests/benches construct
    /// these directly); with a config file the file's `tenants` win.
    pub tenants: BTreeMap<String, TenantPolicy>,
    /// Optional runtime-config file (`--config`): loaded at startup —
    /// it then owns the runtime knobs — and live-reloaded on SIGHUP or
    /// file edit.
    pub config_path: Option<PathBuf>,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".into(),
            queue_cap: 64,
            per_tenant_cap: 0,
            default_deadline_ms: 0,
            serve: ServeConfig::default(),
            fault: FaultSpec::none(),
            tenants: BTreeMap::new(),
            config_path: None,
        }
    }
}

/// Build/version identity served on `/healthz`: crate version, git hash
/// (`KURTAIL_GIT_HASH` at *compile* time, "unknown" otherwise) and the
/// engine's resolved feature toggles — enough for an orchestrator to
/// tell which build and configuration answered the probe.
#[derive(Clone, Debug)]
pub struct BuildInfo {
    pub version: &'static str,
    pub git_hash: &'static str,
    pub int_gemm: bool,
    pub arena: bool,
    pub fused_epilogue: bool,
    pub par_backend: &'static str,
}

impl BuildInfo {
    fn from_engine(engine: &Engine) -> Self {
        Self {
            version: env!("CARGO_PKG_VERSION"),
            git_hash: option_env!("KURTAIL_GIT_HASH").unwrap_or("unknown"),
            int_gemm: engine.int_gemm(),
            arena: engine.arena(),
            fused_epilogue: engine.fused_epilogue(),
            par_backend: match engine.par_backend() {
                ParBackend::Steal => "steal",
                ParBackend::Static => "static",
            },
        }
    }

    fn to_json(&self, status: &str) -> Json {
        json::obj(vec![
            ("status", json::s(status)),
            ("version", json::s(self.version)),
            ("git", json::s(self.git_hash)),
            (
                "features",
                json::obj(vec![
                    ("int_gemm", Json::Bool(self.int_gemm)),
                    ("arena", Json::Bool(self.arena)),
                    ("fused_epilogue", Json::Bool(self.fused_epilogue)),
                    ("par_backend", json::s(self.par_backend)),
                ]),
            ),
        ])
    }
}

/// Everything a connection thread needs, cloned per accept.
#[derive(Clone)]
struct ConnShared {
    host: Host,
    draining: Arc<AtomicBool>,
    /// Live runtime config: keep-alive windows, read budgets, default
    /// deadlines and the fault spec are re-read per request so a reload
    /// reaches new work immediately (never in-flight streams).
    config: Arc<ConfigCell>,
    /// Engine telemetry handle: `/metrics` renders its registry, error
    /// responses derive `Retry-After` from its queue-wait histogram.
    obs: EngineObs,
    build: Arc<BuildInfo>,
}

/// Live-reload driver, polled from the accept loop: applies a pending
/// SIGHUP immediately, otherwise checks the config file's (mtime, len)
/// stamp at most every 300 ms. A config that fails validation is
/// logged and dropped — the old config stays live.
struct Reloader {
    cell: Arc<ConfigCell>,
    watcher: Option<ConfigWatcher>,
    reloads: Option<Arc<Counter>>,
    last_poll: Instant,
}

impl Reloader {
    const POLL_EVERY: Duration = Duration::from_millis(300);

    fn tick(&mut self) {
        let Some(w) = self.watcher.as_mut() else { return };
        let result = if signal::take_reload() {
            Some(w.force())
        } else if self.last_poll.elapsed() >= Self::POLL_EVERY {
            self.last_poll = Instant::now();
            w.poll()
        } else {
            None
        };
        match result {
            None => {}
            Some(Ok(cfg)) => {
                obs::log::set_log_format(cfg.log);
                let generation = self.cell.install(cfg);
                if let Some(c) = &self.reloads {
                    c.inc();
                }
                let path = w.path().display().to_string();
                obs::log::info(
                    "config_reloaded",
                    &[("path", LogValue::Str(&path)), ("generation", LogValue::U64(generation))],
                );
            }
            Some(Err(e)) => {
                obs::log::warn("config_reload_failed", &[("error", LogValue::Str(&e))]);
            }
        }
    }
}

/// The running daemon: engine thread + accept thread.
pub struct Daemon {
    addr: SocketAddr,
    host: Host,
    draining: Arc<AtomicBool>,
    stopped: Arc<AtomicBool>,
    engine_thread: JoinHandle<()>,
    accept_thread: JoinHandle<()>,
}

impl Daemon {
    pub fn spawn(model: ServeModel, cfg: &DaemonConfig) -> Result<Self> {
        let mut scfg = cfg.serve.clone();
        scfg.queue_cap = cfg.queue_cap;
        // resolve the initial runtime config: a config file wins
        // wholesale when present (it is the operator's live source of
        // truth), with the CLI/env fault spec backstopping a file that
        // doesn't mention faults; without a file the CLI knobs seed a
        // fixed-but-still-swappable cell
        let mut runtime = RuntimeConfig {
            per_tenant_cap: cfg.per_tenant_cap,
            default_deadline_ms: cfg.default_deadline_ms,
            tenants: cfg.tenants.clone(),
            fault: cfg.fault.clone(),
            ..RuntimeConfig::default()
        };
        let mut watcher = None;
        if let Some(path) = &cfg.config_path {
            runtime = RuntimeConfig::from_file(path).map_err(|e| anyhow::anyhow!(e))?;
            if runtime.fault.is_none() && !cfg.fault.is_none() {
                runtime.fault = cfg.fault.clone();
            }
            watcher = Some(ConfigWatcher::new(path.clone()));
            // SIGHUP keeps its default disposition (terminate) unless
            // there is actually a file to re-read
            signal::install_reload();
        }
        obs::log::set_log_format(runtime.log);
        let cell = Arc::new(ConfigCell::new(runtime));
        let engine = Engine::new(model, &scfg)?;
        let obs = engine.obs().clone();
        let build = Arc::new(BuildInfo::from_engine(&engine));
        let restarts = obs.enabled.then(|| {
            obs.registry.counter(
                "kurtail_engine_restarts_total",
                "Engine incarnations rebuilt by the supervisor after a panic or step failure",
                &[],
            )
        });
        let reloads = obs.enabled.then(|| {
            obs.registry.counter(
                "kurtail_config_reloads_total",
                "Runtime config reloads applied (SIGHUP or file edit)",
                &[],
            )
        });
        let (host, engine_thread) =
            spawn_host_with(engine, Arc::clone(&cell), Some(Supervise { scfg, restarts }));
        let listener = TcpListener::bind(cfg.addr.as_str())?;
        let addr = listener.local_addr()?;
        obs::log::info(
            "daemon_listening",
            &[("addr", LogValue::Str(&addr.to_string())), ("version", LogValue::Str(build.version))],
        );
        // non-blocking accept so the loop can observe the stop flag
        listener.set_nonblocking(true)?;
        let draining = Arc::new(AtomicBool::new(false));
        let stopped = Arc::new(AtomicBool::new(false));
        let accept_thread = {
            let shared = ConnShared {
                host: host.clone(),
                draining: Arc::clone(&draining),
                config: Arc::clone(&cell),
                obs,
                build,
            };
            let stopped = Arc::clone(&stopped);
            let mut reloader =
                Reloader { cell: Arc::clone(&cell), watcher, reloads, last_poll: Instant::now() };
            thread::Builder::new().name("kurtail-accept".into()).spawn(move || {
                while !stopped.load(Ordering::SeqCst) {
                    reloader.tick();
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let shared = shared.clone();
                            // detached: a slow client must not block
                            // accept, and drain never waits on sockets
                            let _ = thread::Builder::new().name("kurtail-conn".into()).spawn(move || {
                                handle_conn(stream, shared);
                            });
                        }
                        Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => {
                            thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => thread::sleep(Duration::from_millis(5)),
                    }
                }
            })?
        };
        Ok(Self { addr, host, draining, stopped, engine_thread, accept_thread })
    }

    /// The bound address (resolves port `0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Direct handle to the engine thread (tests and benches).
    pub fn host(&self) -> &Host {
        &self.host
    }

    /// Stop admissions and shed the queue; live lanes keep running.
    /// `/healthz` flips to 503, `/stats` stays up. Idempotent.
    pub fn begin_drain(&self) {
        self.draining.store(true, Ordering::SeqCst);
        self.host.drain();
    }

    /// Drain (idempotent) and block until the last live lane finished
    /// and both threads exited.
    pub fn join(self) -> Result<()> {
        self.begin_drain();
        self.engine_thread.join().map_err(|_| anyhow::anyhow!("engine thread panicked"))?;
        // only now tear down the front-end: /stats and /healthz stayed
        // reachable for the whole drain
        self.stopped.store(true, Ordering::SeqCst);
        self.accept_thread.join().map_err(|_| anyhow::anyhow!("accept thread panicked"))?;
        Ok(())
    }

    /// Serve until `stop` flips (SIGTERM/SIGINT via [`signal::install`])
    /// or something else started a drain (`POST /admin/drain`), then
    /// drain and join.
    pub fn run_until(self, stop: &AtomicBool) -> Result<()> {
        while !stop.load(Ordering::SeqCst) && !self.draining.load(Ordering::SeqCst) {
            thread::sleep(Duration::from_millis(50));
        }
        self.join()
    }
}

// --------------------------------------------------------- connections

/// Serve one connection: a keep-alive loop with an idle window, a
/// per-request read budget (slow-loris guard) and a bounded request
/// count, all read from the live config. `Connection: close` from the
/// client, keep-alive disabled, the request bound, or a drain all fall
/// back to the one-shot close.
fn handle_conn(mut stream: TcpStream, shared: ConnShared) {
    // accepted sockets inherit non-blocking from the listener on some
    // platforms; request handling wants plain blocking reads
    let _ = stream.set_nonblocking(false);
    let mut served = 0usize;
    loop {
        let rc = shared.config.current();
        // the socket read timeout is the idle window: how long we wait
        // for the *first* byte of the next request
        let idle_ms = if rc.keep_alive_ms > 0 { rc.keep_alive_ms } else { 60_000 };
        let _ = stream.set_read_timeout(Some(Duration::from_millis(idle_ms)));
        let budget = Duration::from_millis(rc.read_budget_ms.max(1));
        let req = match http::read_request_within(&mut stream, budget) {
            Ok(r) => r,
            Err(_) => return, // idle timeout, hang-up, slow-loris or garbage
        };
        served += 1;
        let keep = rc.keep_alive_ms > 0
            && served < rc.max_conn_requests.max(1)
            && !shared.draining.load(Ordering::SeqCst)
            && http::wants_keep_alive(&req);
        if route(&mut stream, &req, &shared, keep).is_err() || !keep {
            return;
        }
    }
}

fn route(stream: &mut TcpStream, req: &Request, sh: &ConnShared, keep: bool) -> io::Result<()> {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            let (status, reason, state) = if sh.draining.load(Ordering::SeqCst) {
                (503, "Service Unavailable", "draining")
            } else {
                (200, "OK", "ok")
            };
            let body = sh.build.to_json(state).to_string_pretty();
            http::write_response(stream, status, reason, "application/json", &[], body.as_bytes(), keep)
        }
        ("GET", "/metrics") => {
            let body = sh.obs.registry.render_prometheus();
            http::write_response(stream, 200, "OK", "text/plain; version=0.0.4", &[], body.as_bytes(), keep)
        }
        ("GET", "/stats") => match sh.host.stats() {
            Ok(s) => {
                let body = s.to_json().to_string_pretty();
                http::write_response(stream, 200, "OK", "application/json", &[], body.as_bytes(), keep)
            }
            Err(e) => http::write_error(stream, &e, retry_after_s(&sh.obs), keep),
        },
        ("POST", "/admin/drain") => {
            sh.draining.store(true, Ordering::SeqCst);
            sh.host.drain();
            obs::log::info("daemon_draining", &[]);
            // this response still closes: the drain flag was set after
            // `keep` was computed, and a draining daemon reaps idle
            // keep-alive sockets by not keeping this one
            http::write_response(stream, 200, "OK", "application/json", &[], b"{\"draining\": true}", false)
                .and(Err(io::ErrorKind::ConnectionAborted.into()))
        }
        ("POST", "/v1/generate") => handle_generate(stream, req, sh, keep),
        _ => http::write_response(stream, 404, "Not Found", "text/plain", &[], b"not found", keep),
    }
}

/// Parse the generate body. Accepts either `"tokens": [..]` (exact
/// control; required when the model's vocab is smaller than the byte
/// tokenizer's 256) or `"prompt": "..."` (byte-tokenized).
fn parse_generate(
    body: &[u8],
    deadline_default_ms: u64,
    events: Sender<Event>,
) -> Result<(SubmitReq, bool), ServeError> {
    let text = std::str::from_utf8(body).map_err(|_| ServeError::Invalid("body must be utf-8".into()))?;
    let j = Json::parse(text).map_err(|e| ServeError::Invalid(format!("bad json: {e:#}")))?;
    let tokens: Vec<i32> = if let Some(t) = j.opt("tokens") {
        let arr = t.as_arr().map_err(|_| ServeError::Invalid("'tokens' must be an array".into()))?;
        arr.iter()
            .map(|v| v.as_f64().map(|f| f as i32))
            .collect::<anyhow::Result<_>>()
            .map_err(|_| ServeError::Invalid("'tokens' must be numbers".into()))?
    } else if let Some(p) = j.opt("prompt") {
        let p = p.as_str().map_err(|_| ServeError::Invalid("'prompt' must be a string".into()))?;
        ByteTokenizer.encode(p)
    } else {
        return Err(ServeError::Invalid("need 'prompt' or 'tokens'".into()));
    };
    let n_tokens = j.opt("max_tokens").and_then(|v| v.as_usize().ok()).unwrap_or(16);
    let temp = j.opt("temp").and_then(|v| v.as_f64().ok()).unwrap_or(0.0) as f32;
    let seed = j.opt("seed").and_then(|v| v.as_f64().ok()).unwrap_or(0.0) as u64;
    let stop = j.opt("stop").and_then(|v| v.as_f64().ok()).map(|v| v as i32);
    let stream_mode = matches!(j.opt("stream"), Some(Json::Bool(true)));
    let ms = j.opt("deadline_ms").and_then(|v| v.as_f64().ok()).map(|v| v as u64).unwrap_or(deadline_default_ms);
    let deadline = (ms > 0).then(|| Instant::now() + Duration::from_millis(ms));
    let tenant = j
        .opt("tenant")
        .and_then(|v| v.as_str().ok())
        .unwrap_or("default")
        .to_string();
    Ok((SubmitReq { tokens, n_tokens, temp, seed, stop, tenant, deadline, events }, stream_mode))
}

fn handle_generate(stream: &mut TcpStream, req: &Request, sh: &ConnShared, keep: bool) -> io::Result<()> {
    let (events, rx) = mpsc::channel();
    let deadline_ms = sh.config.current().default_deadline_ms;
    let (sub, stream_mode) = match parse_generate(&req.body, deadline_ms, events) {
        Ok(v) => v,
        Err(e) => return http::write_error(stream, &e, retry_after_s(&sh.obs), keep),
    };
    let id = match sh.host.submit(sub) {
        Ok(id) => id,
        Err(e) => return http::write_error(stream, &e, retry_after_s(&sh.obs), keep),
    };
    if stream_mode {
        stream_tokens(stream, sh, id, rx, keep)
    } else {
        wait_completion(stream, sh, id, rx, keep)
    }
}

/// The request's trace span in ms, attached to completions (`span`) and
/// the streaming `done` line.
fn span_json(c: &Completion) -> Json {
    json::obj(vec![
        ("queue_wait_ms", json::num(c.span.queue_wait_ns as f64 / 1e6)),
        ("prefill_ms", json::num(c.span.prefill_ns as f64 / 1e6)),
        ("decode_ms", json::num(c.span.decode_ns as f64 / 1e6)),
        ("new_tokens", json::num(c.span.new_tokens as f64)),
    ])
}

fn completion_json(c: &Completion) -> Json {
    json::obj(vec![
        ("id", json::num(c.id as f64)),
        ("prompt_len", json::num(c.prompt_len as f64)),
        ("tokens", json::arr(c.tokens.iter().map(|&t| json::num(t as f64)).collect())),
        ("text", json::s(&c.text)),
        ("span", span_json(c)),
    ])
}

fn wait_completion(
    stream: &mut TcpStream,
    sh: &ConnShared,
    id: usize,
    events: Receiver<Event>,
    keep: bool,
) -> io::Result<()> {
    loop {
        match events.recv() {
            Ok(Event::Token(_)) => {} // the completion carries them all
            Ok(Event::Done(c)) => {
                let body = completion_json(&c).to_string_pretty();
                return http::write_response(stream, 200, "OK", "application/json", &[], body.as_bytes(), keep);
            }
            Ok(Event::Failed(e)) => return http::write_error(stream, &e, retry_after_s(&sh.obs), keep),
            Err(_) => {
                sh.host.cancel(id);
                return http::write_error(
                    stream,
                    &ServeError::Internal("engine exited".into()),
                    retry_after_s(&sh.obs),
                    keep,
                );
            }
        }
    }
}

/// Chunked ndjson stream: one `{"token": t}` line per token, then a
/// `{"done": true, ...}` line carrying the completion. A mid-stream
/// failure becomes an `{"error": ...}` line — the transfer still
/// terminates cleanly so clients can tell "failed" from "cut off".
fn stream_tokens(
    stream: &mut TcpStream,
    sh: &ConnShared,
    id: usize,
    events: Receiver<Event>,
    keep: bool,
) -> io::Result<()> {
    http::write_chunked_head(stream, "application/x-ndjson", keep)?;
    let drop_after = sh.config.current().fault.drop_after(id);
    let mut sent = 0usize;
    loop {
        match events.recv() {
            Ok(Event::Token(t)) => {
                let line = format!("{{\"token\": {t}}}\n");
                if http::write_chunk(stream, line.as_bytes()).is_err() {
                    // client hung up mid-stream: hand the blocks back
                    sh.host.cancel(id);
                    return Ok(());
                }
                sent += 1;
                if drop_after.is_some_and(|k| sent >= k) {
                    // injected drop_conn fault: sever the socket the
                    // way a dying client would, then reclaim
                    let _ = stream.shutdown(std::net::Shutdown::Both);
                    sh.host.cancel(id);
                    return Ok(());
                }
            }
            Ok(Event::Done(c)) => {
                let done = json::obj(vec![
                    ("done", Json::Bool(true)),
                    ("id", json::num(c.id as f64)),
                    ("prompt_len", json::num(c.prompt_len as f64)),
                    ("n_tokens", json::num((c.tokens.len() - c.prompt_len) as f64)),
                    ("text", json::s(&c.text)),
                    ("span", span_json(&c)),
                ]);
                let line = format!("{}\n", done.to_string_compact());
                let _ = http::write_chunk(stream, line.as_bytes());
                return http::finish_chunks(stream);
            }
            Ok(Event::Failed(e)) => {
                let line = format!("{{\"error\": \"{}\"}}\n", e.kind());
                let _ = http::write_chunk(stream, line.as_bytes());
                return http::finish_chunks(stream);
            }
            Err(_) => {
                sh.host.cancel(id);
                let _ = http::write_chunk(stream, b"{\"error\": \"internal\"}\n");
                return http::finish_chunks(stream);
            }
        }
    }
}

// ----------------------------------------------------- synthetic model

/// A small self-contained quantized model (`kurtail daemon
/// --synthetic`): random-init weights on a 2-layer llama config, W4/A4
/// with random-Hadamard online rotations. Deterministic in `seed` —
/// smoke tests and the load generator get reproducible streams without
/// artifacts on disk.
pub fn synthetic_model(seed: u64) -> Result<ServeModel> {
    let (l, d, h, ff, v) = (2usize, 64usize, 2usize, 128usize, 256usize);
    let dh = d / h;
    let spec = |name: &str, shape: Vec<usize>| ParamSpec { name: name.into(), shape };
    let meta = ConfigMeta {
        name: "synthetic-daemon".into(),
        vocab: v,
        d_model: d,
        n_layers: l,
        n_heads: h,
        d_head: dh,
        d_ff: ff,
        seq_len: 64,
        arch: "llama".into(),
        n_experts: 1,
        top_k: 1,
        train_batch: 1,
        eval_batch: 1,
        cap_batch: 1,
        decode_batch: 1,
        spin_batch: 1,
        param_specs: vec![
            spec("embed", vec![v, d]),
            spec("ln1", vec![l, d]),
            spec("wq", vec![l, d, d]),
            spec("wk", vec![l, d, d]),
            spec("wv", vec![l, d, d]),
            spec("wo", vec![l, d, d]),
            spec("ln2", vec![l, d]),
            spec("wg", vec![l, d, ff]),
            spec("wu", vec![l, d, ff]),
            spec("wd", vec![l, ff, d]),
            spec("lnf", vec![d]),
            spec("head", vec![v, d]),
        ],
    };
    let mut rng = Rng::new(seed);
    let params = Params::init(&meta, &mut rng);
    let quant = ServeQuantSpec::paper_default(
        random_hadamard(dh, &mut rng),
        random_hadamard(dh, &mut rng),
        random_hadamard(ff, &mut rng),
    );
    ServeModel::from_params(&params, Some(quant))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::tests_support::fake_llama_meta;
    use crate::serve::scheduler::Priority;

    fn test_engine(cfg: &ServeConfig) -> Engine {
        let mut rng = Rng::new(11);
        let params = Params::init(&fake_llama_meta(), &mut rng);
        let quant = ServeQuantSpec::paper_default(
            random_hadamard(4, &mut rng),
            random_hadamard(4, &mut rng),
            random_hadamard(16, &mut rng),
        );
        let model = ServeModel::from_params(&params, Some(quant)).unwrap();
        Engine::new(model, cfg).unwrap()
    }

    fn collect(rx: &Receiver<Event>) -> (Vec<i32>, Option<Completion>, Option<ServeError>) {
        let mut toks = Vec::new();
        loop {
            match rx.recv_timeout(Duration::from_secs(20)).expect("engine thread answers") {
                Event::Token(t) => toks.push(t),
                Event::Done(c) => return (toks, Some(c), None),
                Event::Failed(e) => return (toks, None, Some(e)),
            }
        }
    }

    #[test]
    fn host_streams_match_in_process_engine() {
        // reference: the same submissions run in-process
        let cfg = ServeConfig { max_lanes: 2, ..ServeConfig::default() };
        let mut reference = test_engine(&cfg);
        reference.submit_tokens(vec![1, 2, 3], 4, 0.0, 7).unwrap();
        reference.submit_tokens(vec![4, 5], 3, 0.8, 9).unwrap();
        let mut want = reference.run().unwrap();
        want.sort_by_key(|c| c.id);

        let (host, handle) = spawn_host(test_engine(&cfg), HostConfig::default());
        let (tx_a, rx_a) = mpsc::channel();
        let (tx_b, rx_b) = mpsc::channel();
        let mk = |tokens: Vec<i32>, n: usize, temp: f32, seed: u64, tx: Sender<Event>| SubmitReq {
            tokens,
            n_tokens: n,
            temp,
            seed,
            stop: None,
            tenant: "t".into(),
            deadline: None,
            events: tx,
        };
        let a = host.submit(mk(vec![1, 2, 3], 4, 0.0, 7, tx_a)).unwrap();
        let b = host.submit(mk(vec![4, 5], 3, 0.8, 9, tx_b)).unwrap();
        assert_eq!((a, b), (0, 1), "ids follow submission order");
        let (toks_a, done_a, _) = collect(&rx_a);
        let (toks_b, done_b, _) = collect(&rx_b);
        let (done_a, done_b) = (done_a.unwrap(), done_b.unwrap());
        assert_eq!(done_a.tokens, want[0].tokens, "bitwise identical to in-process run");
        assert_eq!(done_b.tokens, want[1].tokens);
        assert_eq!(toks_a, want[0].tokens[want[0].prompt_len..], "streamed = completed");
        assert_eq!(toks_b, want[1].tokens[want[1].prompt_len..]);

        let stats = host.stats().unwrap();
        assert_eq!(stats.engine.admitted, 2);
        assert_eq!(stats.free_blocks, stats.max_blocks, "all KV blocks returned");
        host.drain();
        handle.join().unwrap();
        assert!(matches!(host.stats(), Err(ServeError::Draining)), "post-drain host reports draining");
    }

    #[test]
    fn host_enforces_deadlines() {
        let (host, handle) = spawn_host(test_engine(&ServeConfig::default()), HostConfig::default());
        let (tx, rx) = mpsc::channel();
        host.submit(SubmitReq {
            tokens: vec![1, 2],
            n_tokens: 4,
            temp: 0.0,
            seed: 1,
            stop: None,
            tenant: "t".into(),
            deadline: Some(Instant::now()), // already overdue
            events: tx,
        })
        .unwrap();
        let (_, done, err) = collect(&rx);
        assert!(done.is_none());
        assert_eq!(err, Some(ServeError::Deadline));
        let stats = host.stats().unwrap();
        assert_eq!(stats.engine.canceled, 1);
        assert_eq!(stats.free_blocks, stats.max_blocks, "deadline cancel returned every block");
        host.drain();
        handle.join().unwrap();
    }

    #[test]
    fn host_tenant_cap_sheds_excess() {
        // slow steps keep the first request in flight while the second
        // and third arrive
        let cfg = HostConfig {
            per_tenant_cap: 1,
            fault: FaultSpec { slow_step_ms: 20, ..FaultSpec::none() },
            ..HostConfig::default()
        };
        let (host, handle) = spawn_host(test_engine(&ServeConfig::default()), cfg);
        let mk = |tenant: &str, tx: Sender<Event>| SubmitReq {
            tokens: vec![1, 2],
            n_tokens: 6,
            temp: 0.0,
            seed: 1,
            stop: None,
            tenant: tenant.into(),
            deadline: None,
            events: tx,
        };
        let (tx_a, rx_a) = mpsc::channel();
        host.submit(mk("alice", tx_a)).unwrap();
        let (tx_b, _rx_b) = mpsc::channel();
        let err = host.submit(mk("alice", tx_b)).unwrap_err();
        assert_eq!(err, ServeError::QueueFull { cap: 1 }, "tenant over cap sheds");
        let (tx_c, rx_c) = mpsc::channel();
        host.submit(mk("bob", tx_c)).unwrap();
        let (_, done_a, _) = collect(&rx_a);
        let (_, done_c, _) = collect(&rx_c);
        assert!(done_a.is_some() && done_c.is_some(), "other tenants unaffected");
        let stats = host.stats().unwrap();
        assert_eq!(stats.engine.shed, 1);
        host.drain();
        handle.join().unwrap();
    }

    #[test]
    fn retry_after_tracks_queue_wait_p50() {
        let eobs = EngineObs::new(true);
        assert_eq!(retry_after_s(&eobs), 1, "empty histogram falls back to 1s");
        for _ in 0..10 {
            eobs.queue_wait.record_ns(3_500_000_000); // 3.5s observed waits
        }
        // 3.5s lands in the [2^31, 2^32) ns bucket: upper bound ~4.29s
        assert_eq!(retry_after_s(&eobs), 5, "ceil of the p50 bucket bound");
        for _ in 0..100 {
            eobs.queue_wait.record_ns(400 * 1_000_000_000); // pathological waits clamp
        }
        assert_eq!(retry_after_s(&eobs), 60);
    }

    #[test]
    fn retry_after_cold_start_uses_the_retirement_rate() {
        let eobs = EngineObs::new(true);
        assert_eq!(retry_after_s(&eobs), 1, "no queue waits, no retirements: 1s");
        eobs.retire_rate_milli.set(250); // 0.25 retirements/s -> ~4s per freed block
        assert_eq!(retry_after_s(&eobs), 4);
        eobs.retire_rate_milli.set(5); // pathologically slow drain clamps
        assert_eq!(retry_after_s(&eobs), 60);
        eobs.retire_rate_milli.set(4000); // fast drain floors at 1s
        assert_eq!(retry_after_s(&eobs), 1);
        // an observed queue wait beats the block-free-time estimate
        eobs.retire_rate_milli.set(5);
        for _ in 0..10 {
            eobs.queue_wait.record_ns(3_500_000_000);
        }
        assert_eq!(retry_after_s(&eobs), 5, "the p50 path wins once populated");
    }

    #[test]
    fn kv_pressure_fault_preempts_low_and_both_streams_complete() {
        // block math (fake_llama_meta: 2 layers, block_tokens 2): each
        // request reserves 2*2*ceil(6/2) = 12 blocks. kv_pressure=12
        // leaves 14 of 26 usable, so the seated low lane sits at 12/14
        // = 86% (over the 0.85 watermark) and the high arrival (12 > 2
        // free) can only fit by preempting it.
        let scfg = ServeConfig {
            max_lanes: 2,
            block_tokens: 2,
            max_blocks: 26,
            threads: Some(1),
            preempt: Some(true),
            obs: Some(true),
            ..ServeConfig::default()
        };
        // reference: the low stream on an engine without pressure
        let mut reference = test_engine(&scfg);
        reference.submit_tokens(vec![1, 2], 4, 0.0, 7).unwrap();
        let want_low = reference.run().unwrap().remove(0);

        let mut tenants = BTreeMap::new();
        tenants
            .insert("vip".to_string(), TenantPolicy { priority: Priority::High, ..TenantPolicy::default() });
        tenants
            .insert("batch".to_string(), TenantPolicy { priority: Priority::Low, ..TenantPolicy::default() });
        let cfg = HostConfig {
            tenants,
            fault: FaultSpec { kv_pressure: 12, ..FaultSpec::none() },
            ..HostConfig::default()
        };
        let (host, handle) = spawn_host(test_engine(&scfg), cfg);
        let mk = |tokens: Vec<i32>, tenant: &str, tx: Sender<Event>| SubmitReq {
            tokens,
            n_tokens: 4,
            temp: 0.0,
            seed: 7,
            stop: None,
            tenant: tenant.into(),
            deadline: None,
            events: tx,
        };
        let (tx_l, rx_l) = mpsc::channel();
        host.submit(mk(vec![1, 2], "batch", tx_l)).unwrap();
        // wait until low is decoding so the preemption hits a live lane
        match rx_l.recv_timeout(Duration::from_secs(20)).expect("engine thread answers") {
            Event::Token(_) => {}
            other => panic!("expected low's first token, got {other:?}"),
        }
        let (tx_h, rx_h) = mpsc::channel();
        host.submit(mk(vec![3, 4], "vip", tx_h)).unwrap();
        let (_, done_h, err_h) = collect(&rx_h);
        assert_eq!(err_h, None, "the high request admits under pressure");
        assert!(done_h.is_some());
        let (toks_l, done_l, err_l) = collect(&rx_l);
        assert_eq!(err_l, None, "preemption is a pause, never an error");
        let done_l = done_l.unwrap();
        assert_eq!(done_l.tokens, want_low.tokens, "bitwise across preempt + resume");
        assert_eq!(toks_l.len(), 4, "each generated token streamed exactly once");

        let stats = host.stats().unwrap();
        assert_eq!(stats.engine.preempted, 1, "the low lane was snapshotted out");
        assert_eq!(stats.engine.resumed, 1, "and later resumed");
        assert!(stats.engine.resume_recompute_tokens > 0, "resume recomputed the prefix");
        assert_eq!(stats.free_blocks, stats.max_blocks, "the pool came back whole");
        host.drain();
        handle.join().unwrap();
    }

    #[test]
    fn stats_json_carries_latency_quantiles() {
        let cfg = ServeConfig { obs: Some(true), ..ServeConfig::default() };
        let mut engine = test_engine(&cfg);
        engine.submit_tokens(vec![1, 2, 3], 4, 0.0, 7).unwrap();
        engine.run().unwrap();
        let snap = snapshot(&engine, Instant::now());
        assert_eq!(snap.latency.ttft.count, 1);
        assert_eq!(snap.latency.queue_wait.count, 1);
        let j = snap.to_json();
        assert!(
            j.get("max_head_skips").unwrap().as_f64().unwrap() >= 0.0,
            "scheduler aging budget surfaced in /stats"
        );
        assert!(j.get("shared_block_refs").is_some(), "prefix-sharing gauge surfaced in /stats");
        assert!(j.get("engine").unwrap().get("prefix_shared_tokens").is_some());
        for field in ["preempted", "resumed", "resume_recompute_tokens"] {
            assert!(
                j.get("engine").unwrap().get(field).is_some(),
                "{field} surfaced in /stats for preemption dashboards"
            );
        }
        let lat = j.get("latency").unwrap();
        assert_eq!(lat.get("ttft").unwrap().get("count").unwrap().as_f64().unwrap(), 1.0);
        let gemm = lat.get("decode_phase").unwrap().get("gemm").unwrap();
        assert!(gemm.get("p50_ms").unwrap().as_f64().unwrap() >= 0.0);
        // the whole document must round-trip through the parser
        let text = j.to_string_pretty();
        Json::parse(&text).expect("stats json parses");
    }

    #[test]
    fn tenant_counters_reach_the_engine_registry() {
        let cfg = ServeConfig { max_lanes: 2, obs: Some(true), ..ServeConfig::default() };
        let engine = test_engine(&cfg);
        let registry = Arc::clone(&engine.obs().registry);
        let (host, handle) = spawn_host(
            engine,
            HostConfig {
                per_tenant_cap: 1,
                fault: FaultSpec { slow_step_ms: 20, ..FaultSpec::none() },
                ..HostConfig::default()
            },
        );
        let mk = |tenant: &str, tx: Sender<Event>| SubmitReq {
            tokens: vec![1, 2],
            n_tokens: 4,
            temp: 0.0,
            seed: 1,
            stop: None,
            tenant: tenant.into(),
            deadline: None,
            events: tx,
        };
        let (tx_a, rx_a) = mpsc::channel();
        host.submit(mk("alice", tx_a)).unwrap();
        let (tx_b, _rx_b) = mpsc::channel();
        host.submit(mk("alice", tx_b)).unwrap_err(); // over the tenant cap
        collect(&rx_a);
        host.drain();
        handle.join().unwrap();
        let text = registry.render_prometheus();
        assert!(
            text.contains("kurtail_tenant_requests_total{tenant=\"alice\"} 2"),
            "accepted + shed both count as tenant requests:\n{text}"
        );
        assert!(text.contains("kurtail_tenant_shed_total{tenant=\"alice\"} 1"), "{text}");
        assert!(text.contains("kurtail_requests_retired_total 1"), "{text}");
    }

    #[test]
    fn build_info_json_names_the_build() {
        let engine = test_engine(&ServeConfig::default());
        let info = BuildInfo::from_engine(&engine);
        let j = info.to_json("ok");
        assert_eq!(j.get("status").unwrap().as_str().unwrap(), "ok");
        assert_eq!(j.get("version").unwrap().as_str().unwrap(), env!("CARGO_PKG_VERSION"));
        let feats = j.get("features").unwrap();
        assert!(matches!(feats.get("int_gemm").unwrap(), Json::Bool(_)));
        Json::parse(&j.to_string_pretty()).expect("healthz json parses");
    }

    #[test]
    fn synthetic_model_is_deterministic() {
        let m = synthetic_model(3).unwrap();
        assert_eq!(m.meta.vocab, 256, "covers the whole byte tokenizer range");
        let run = |model: ServeModel| {
            let mut eng = Engine::new(model, &ServeConfig::default()).unwrap();
            eng.submit("hi", 4, 0.0, 5).unwrap();
            eng.run().unwrap().remove(0).tokens
        };
        assert_eq!(run(m), run(synthetic_model(3).unwrap()), "same seed, same stream");
    }

    #[test]
    fn supervised_host_resumes_streams_across_engine_restart() {
        let scfg = ServeConfig { obs: Some(true), ..ServeConfig::default() };
        // reference: the same request on an engine that never crashes
        let mut reference = test_engine(&scfg);
        reference.submit_tokens(vec![1, 2, 3], 4, 0.8, 7).unwrap();
        let want = reference.run().unwrap().remove(0);

        let engine = test_engine(&scfg);
        let registry = Arc::clone(&engine.obs().registry);
        let restarts = registry.counter(
            "kurtail_engine_restarts_total",
            "Engine rebuilds after a panic or step failure.",
            &[],
        );
        let cell = Arc::new(ConfigCell::new(RuntimeConfig {
            fault: FaultSpec { engine_panic: 1.0, ..FaultSpec::none() },
            ..RuntimeConfig::default() // resume_on_restart defaults on
        }));
        let (host, handle) = spawn_host_with(
            engine,
            cell,
            Some(Supervise { scfg: scfg.clone(), restarts: Some(Arc::clone(&restarts)) }),
        );
        let (tx0, rx0) = mpsc::channel();
        host.submit(SubmitReq {
            tokens: vec![1, 2, 3],
            n_tokens: 4,
            temp: 0.8,
            seed: 7,
            stop: None,
            tenant: "t".into(),
            deadline: None,
            events: tx0,
        })
        .unwrap();
        // the one-shot panic fires on the first step; the supervisor
        // must re-submit the stream into the rebuilt engine, not 503 it
        let (toks, done, err) = collect(&rx0);
        assert_eq!(err, None, "resume hides the restart from the client");
        let done = done.unwrap();
        assert_eq!(done.tokens, want.tokens, "resumed stream is bitwise the undisturbed run");
        assert_eq!(toks, want.tokens[want.prompt_len..], "every token streamed exactly once");

        let stats = host.stats().unwrap();
        assert_eq!(stats.engine_restarts, 1);
        assert_eq!(stats.engine.resumed, 1, "the replayed stream counts as resumed");
        assert_eq!(stats.free_blocks, stats.max_blocks, "the crash leaked no KV blocks");
        assert_eq!(restarts.get(), 1);
        host.drain();
        handle.join().unwrap();
    }

    #[test]
    fn resume_off_restores_the_retryable_restart_failure() {
        let scfg = ServeConfig { obs: Some(true), ..ServeConfig::default() };
        // reference: what the retried request should stream, bitwise
        let mut reference = test_engine(&scfg);
        reference.submit_tokens(vec![1, 2, 3], 4, 0.0, 7).unwrap();
        let want = reference.run().unwrap().remove(0);

        let engine = test_engine(&scfg);
        let registry = Arc::clone(&engine.obs().registry);
        let restarts = registry.counter(
            "kurtail_engine_restarts_total",
            "Engine rebuilds after a panic or step failure.",
            &[],
        );
        let cell = Arc::new(ConfigCell::new(RuntimeConfig {
            fault: FaultSpec { engine_panic: 1.0, ..FaultSpec::none() },
            resume_on_restart: false,
            ..RuntimeConfig::default()
        }));
        let (host, handle) = spawn_host_with(
            engine,
            cell,
            Some(Supervise { scfg: scfg.clone(), restarts: Some(Arc::clone(&restarts)) }),
        );
        let mk = |tx: Sender<Event>| SubmitReq {
            tokens: vec![1, 2, 3],
            n_tokens: 4,
            temp: 0.0,
            seed: 7,
            stop: None,
            tenant: "t".into(),
            deadline: None,
            events: tx,
        };
        let (tx0, rx0) = mpsc::channel();
        let id0 = host.submit(mk(tx0)).unwrap();
        let (_, done0, err0) = collect(&rx0);
        assert!(done0.is_none());
        assert_eq!(err0, Some(ServeError::EngineRestarting), "in-flight fails retryable");

        // the one-shot fault has fired; the retry runs on the rebuilt
        // engine and must stream exactly the reference tokens
        let (tx1, rx1) = mpsc::channel();
        let id1 = host.submit(mk(tx1)).unwrap();
        assert!(id1 > id0, "request ids continue across engine incarnations");
        let (_, done1, err1) = collect(&rx1);
        assert_eq!(err1, None, "retry succeeds after exactly one restart");
        assert_eq!(done1.unwrap().tokens, want.tokens, "rebuilt engine is bitwise identical");

        let stats = host.stats().unwrap();
        assert_eq!(stats.engine_restarts, 1);
        assert_eq!(stats.engine.resumed, 0, "nothing resumes with the knob off");
        assert_eq!(stats.free_blocks, stats.max_blocks, "the crash leaked no KV blocks");
        assert_eq!(restarts.get(), 1);
        host.drain();
        handle.join().unwrap();
    }

    #[test]
    fn host_rate_limits_by_token_bucket_and_refunds_unused() {
        let mut tenants = BTreeMap::new();
        tenants.insert(
            "metered".to_string(),
            TenantPolicy {
                rate_tokens_per_s: 0.001, // effectively no refill within the test
                burst_tokens: 8.0,
                ..TenantPolicy::default()
            },
        );
        // slow steps keep the first request in flight while the second
        // hits the drained bucket
        let cfg = HostConfig {
            tenants,
            fault: FaultSpec { slow_step_ms: 20, ..FaultSpec::none() },
            ..HostConfig::default()
        };
        let (host, handle) = spawn_host(test_engine(&ServeConfig::default()), cfg);
        let mk = |n: usize, tx: Sender<Event>| SubmitReq {
            tokens: vec![1, 2],
            n_tokens: n,
            temp: 0.0,
            seed: 1,
            stop: None,
            tenant: "metered".into(),
            deadline: None,
            events: tx,
        };
        let (tx_a, rx_a) = mpsc::channel();
        host.submit(mk(6, tx_a)).unwrap(); // bucket 8 -> 2
        let (tx_b, _rx_b) = mpsc::channel();
        let err = host.submit(mk(6, tx_b)).unwrap_err();
        // deficit of 4 tokens at 0.001 tok/s clamps to the 60s ceiling
        assert_eq!(err, ServeError::RateLimited { retry_after_s: 60 });
        let (_, done_a, _) = collect(&rx_a);
        assert!(done_a.is_some(), "in-flight request unaffected by the shed");

        // a request that dies before generating refunds its full charge:
        // the 2-token charge (bucket 2 -> 0) comes back on the deadline
        // failure, so the follow-up 2-token submit still fits
        let (tx_c, rx_c) = mpsc::channel();
        host.submit(SubmitReq { deadline: Some(Instant::now()), ..mk(2, tx_c) }).unwrap();
        let (_, _, err_c) = collect(&rx_c);
        assert_eq!(err_c, Some(ServeError::Deadline));
        let (tx_d, rx_d) = mpsc::channel();
        host.submit(mk(2, tx_d)).unwrap();
        let (_, done_d, _) = collect(&rx_d);
        assert!(done_d.is_some(), "refunded tokens are spendable again");
        host.drain();
        handle.join().unwrap();
    }

    #[test]
    fn host_admits_high_class_before_queued_low_class() {
        // one lane + slow steps: the first low request occupies the
        // lane while the second low and the high queue behind it — the
        // scheduler must seat the high first even though it arrived last
        let mut tenants = BTreeMap::new();
        tenants.insert(
            "vip".to_string(),
            TenantPolicy { priority: Priority::High, ..TenantPolicy::default() },
        );
        tenants.insert(
            "batch".to_string(),
            TenantPolicy { priority: Priority::Low, ..TenantPolicy::default() },
        );
        let cfg = HostConfig {
            tenants,
            fault: FaultSpec { slow_step_ms: 30, ..FaultSpec::none() },
            ..HostConfig::default()
        };
        let scfg = ServeConfig { max_lanes: 1, ..ServeConfig::default() };
        let (host, handle) = spawn_host(test_engine(&scfg), cfg);
        let (tx, rx) = mpsc::channel();
        let mk = |tenant: &str, seed: u64| SubmitReq {
            tokens: vec![1, 2],
            n_tokens: 3,
            temp: 0.0,
            seed,
            stop: None,
            tenant: tenant.into(),
            deadline: None,
            events: tx.clone(),
        };
        let lo1 = host.submit(mk("batch", 1)).unwrap();
        let lo2 = host.submit(mk("batch", 2)).unwrap();
        let hi = host.submit(mk("vip", 3)).unwrap();
        let mut order = Vec::new();
        while order.len() < 3 {
            match rx.recv_timeout(Duration::from_secs(20)).expect("engine thread answers") {
                Event::Done(c) => order.push(c.id),
                Event::Token(_) => {}
                Event::Failed(e) => panic!("unexpected failure: {e:?}"),
            }
        }
        let pos = |id: usize| order.iter().position(|&x| x == id).unwrap();
        assert!(pos(hi) < pos(lo2), "queued high completes before queued low: {order:?}");
        let _ = lo1; // first low may finish before or after hi (already seated)
        host.drain();
        handle.join().unwrap();
    }

    #[test]
    fn high_arrival_evicts_newest_queued_low_and_notifies_owner() {
        let mut tenants = BTreeMap::new();
        tenants.insert(
            "vip".to_string(),
            TenantPolicy { priority: Priority::High, ..TenantPolicy::default() },
        );
        tenants.insert(
            "batch".to_string(),
            TenantPolicy { priority: Priority::Low, ..TenantPolicy::default() },
        );
        let cfg = HostConfig {
            tenants,
            fault: FaultSpec { slow_step_ms: 30, ..FaultSpec::none() },
            ..HostConfig::default()
        };
        let scfg = ServeConfig { max_lanes: 1, queue_cap: 2, ..ServeConfig::default() };
        let (host, handle) = spawn_host(test_engine(&scfg), cfg);
        let mk = |tenant: &str, n: usize, seed: u64, tx: Sender<Event>| SubmitReq {
            tokens: vec![1, 2],
            n_tokens: n,
            temp: 0.0,
            seed,
            stop: None,
            tenant: tenant.into(),
            deadline: None,
            events: tx,
        };
        // seat lo1 in the lane (wait for its first token so it is
        // decoding, not queued), then fill the queue with lo2, lo3
        let (tx1, rx1) = mpsc::channel();
        host.submit(mk("batch", 10, 1, tx1)).unwrap();
        match rx1.recv_timeout(Duration::from_secs(20)).expect("engine thread answers") {
            Event::Token(_) => {}
            other => panic!("expected lo1's first token, got {other:?}"),
        }
        let (tx2, rx2) = mpsc::channel();
        host.submit(mk("batch", 3, 2, tx2)).unwrap();
        let (tx3, rx3) = mpsc::channel();
        host.submit(mk("batch", 3, 3, tx3)).unwrap();
        // hi outranks the queued lows: the newest low (lo3) is evicted
        // and its owner is told, the high is accepted in its place
        let (tx_h, rx_h) = mpsc::channel();
        host.submit(mk("vip", 3, 4, tx_h)).unwrap();
        let (_, done3, err3) = collect(&rx3);
        assert!(done3.is_none());
        assert_eq!(err3, Some(ServeError::QueueFull { cap: 2 }), "victim sheds as queue-full");
        let (_, done_h, _) = collect(&rx_h);
        let (_, done2, _) = collect(&rx2);
        assert!(done_h.is_some() && done2.is_some(), "accepted requests all complete");
        let (_, done1, _) = collect(&rx1);
        assert!(done1.is_some(), "the seated low request rides out the eviction");
        let stats = host.stats().unwrap();
        assert_eq!(stats.free_blocks, stats.max_blocks, "eviction returned every block");
        host.drain();
        handle.join().unwrap();
    }

    #[test]
    fn live_reload_changes_admission_without_dropping_streams() {
        let cell = Arc::new(ConfigCell::new(RuntimeConfig {
            fault: FaultSpec { slow_step_ms: 20, ..FaultSpec::none() },
            ..RuntimeConfig::default()
        }));
        let (host, handle) =
            spawn_host_with(test_engine(&ServeConfig::default()), Arc::clone(&cell), None);
        let mk = |tx: Sender<Event>| SubmitReq {
            tokens: vec![1, 2],
            n_tokens: 8,
            temp: 0.0,
            seed: 1,
            stop: None,
            tenant: "t".into(),
            deadline: None,
            events: tx,
        };
        let (tx_a, rx_a) = mpsc::channel();
        host.submit(mk(tx_a)).unwrap();
        // wait until the stream is live, then swap the config under it
        match rx_a.recv_timeout(Duration::from_secs(20)).expect("engine thread answers") {
            Event::Token(_) => {}
            other => panic!("expected a token, got {other:?}"),
        }
        cell.install(RuntimeConfig { per_tenant_cap: 1, ..RuntimeConfig::default() });
        // new admissions see the new config immediately...
        let (tx_b, _rx_b) = mpsc::channel();
        let err = host.submit(mk(tx_b)).unwrap_err();
        assert_eq!(err, ServeError::QueueFull { cap: 1 }, "reloaded cap applies at once");
        // ...and the in-flight stream is untouched by the swap
        let (toks, done, err_a) = collect(&rx_a);
        assert_eq!(err_a, None, "reload never drops an in-flight stream");
        let done = done.unwrap();
        assert_eq!(
            1 + toks.len(),
            done.tokens.len() - done.prompt_len,
            "every generated token was streamed across the reload"
        );
        let stats = host.stats().unwrap();
        assert_eq!(stats.config_generation, 2, "install bumped the generation");
        host.drain();
        handle.join().unwrap();
    }
}
