//! Deterministic, seeded fault injection for the serving daemon.
//!
//! `KURTAIL_FAULT=pool_exhaust=0.3,slow_step=10,drop_conn=0.5` (any
//! subset, comma-separated) with `KURTAIL_FAULT_SEED=<u64>` arms three
//! failure modes; unset means no faults. Every decision is a pure
//! function of the seed (plus the per-request id or the per-step rng
//! stream), so a fault run replays exactly — the foundation of the
//! fault-suite assertion that completed streams stay bitwise identical
//! to the in-process engine.
//!
//! * `pool_exhaust=P` — each engine step, with probability `P`, the
//!   whole KV block budget is withheld from *admission* for that step
//!   (`Engine::set_withheld_blocks`). Queued requests starve and shed;
//!   live lanes keep their reservations, so the engine's
//!   no-mid-flight-exhaustion invariant survives the fault. `P = 1`
//!   blocks admission permanently — use `P < 1` so progress resumes.
//! * `slow_step=MS` — every engine step sleeps `MS` milliseconds first
//!   (latency fault: deadlines fire, queues back up, TTFT degrades).
//! * `drop_conn=P` — with probability `P` per streaming request, the
//!   daemon severs the client socket after a few tokens, exercising the
//!   disconnect → cancel → block-reclaim path.
//! * `engine_panic=P` — with probability `P` per engine step, the
//!   engine thread panics (once per process: the knob disarms after
//!   firing), exercising the supervisor's catch → rebuild →
//!   resume-in-flight path (or fail-in-flight with
//!   `resume_on_restart: false`). `P = 1` panics on the first step
//!   after arming, so `engine_panic=1` deterministically yields exactly
//!   one restart.
//! * `kv_pressure=N` — every engine step withholds a constant `N`
//!   blocks from the admission budget, shrinking the effective pool so
//!   KV-pressure preemption is exercisable without giant prompts.
//!   Deterministic and rng-free: arming it does not perturb the other
//!   knobs' seeded timelines.

use std::time::Duration;

use crate::util::Rng;

/// Parsed fault configuration (see the module docs). `Default` = no
/// faults.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultSpec {
    pub pool_exhaust: f32,
    pub slow_step_ms: u64,
    pub drop_conn: f32,
    pub engine_panic: f32,
    /// Blocks withheld from the admission budget every step (constant,
    /// rng-free) — the deterministic KV-pressure fault.
    pub kv_pressure: usize,
    pub seed: u64,
}

impl FaultSpec {
    pub fn none() -> Self {
        Self::default()
    }

    pub fn is_none(&self) -> bool {
        self.pool_exhaust <= 0.0
            && self.slow_step_ms == 0
            && self.drop_conn <= 0.0
            && self.engine_panic <= 0.0
            && self.kv_pressure == 0
    }

    /// Parse a `KURTAIL_FAULT`-style spec string.
    pub fn parse(spec: &str, seed: u64) -> Result<Self, String> {
        let mut out = Self { seed, ..Self::default() };
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, val) = part.split_once('=').ok_or_else(|| format!("fault '{part}': expected key=value"))?;
            match key.trim() {
                "pool_exhaust" => {
                    out.pool_exhaust = val.trim().parse().map_err(|e| format!("pool_exhaust: {e}"))?
                }
                "slow_step" => out.slow_step_ms = val.trim().parse().map_err(|e| format!("slow_step: {e}"))?,
                "drop_conn" => out.drop_conn = val.trim().parse().map_err(|e| format!("drop_conn: {e}"))?,
                "engine_panic" => {
                    out.engine_panic = val.trim().parse().map_err(|e| format!("engine_panic: {e}"))?
                }
                "kv_pressure" => {
                    out.kv_pressure = val.trim().parse().map_err(|e| format!("kv_pressure: {e}"))?
                }
                other => {
                    return Err(format!(
                        "unknown fault '{other}' (pool_exhaust/slow_step/drop_conn/engine_panic/kv_pressure)"
                    ))
                }
            }
        }
        if !(0.0..=1.0).contains(&out.pool_exhaust)
            || !(0.0..=1.0).contains(&out.drop_conn)
            || !(0.0..=1.0).contains(&out.engine_panic)
        {
            return Err("fault probabilities must be in [0, 1]".into());
        }
        Ok(out)
    }

    /// Read `KURTAIL_FAULT` / `KURTAIL_FAULT_SEED`; unset → no faults.
    /// A malformed spec is a startup error, not a silent no-op.
    pub fn from_env() -> Result<Self, String> {
        match std::env::var("KURTAIL_FAULT") {
            Ok(spec) if !spec.trim().is_empty() => {
                let seed = std::env::var("KURTAIL_FAULT_SEED")
                    .ok()
                    .and_then(|s| s.trim().parse().ok())
                    .unwrap_or(0);
                Self::parse(&spec, seed)
            }
            _ => Ok(Self::none()),
        }
    }

    /// `drop_conn` decision for one request: `Some(k)` severs the
    /// stream after `k` tokens. A pure function of `(seed, id)`, so a
    /// replay drops the same requests at the same points.
    pub fn drop_after(&self, id: usize) -> Option<usize> {
        if self.drop_conn <= 0.0 {
            return None;
        }
        let mut rng = Rng::new(self.seed ^ 0xD809_C0FF ^ (id as u64).wrapping_mul(0x9E3779B97F4A7C15));
        if rng.uniform() < self.drop_conn {
            Some(1 + rng.below(4))
        } else {
            None
        }
    }
}

/// The engine-thread side: one seeded rng stream drives the per-step
/// decisions, so a given seed yields one reproducible fault timeline.
pub struct FaultClock {
    spec: FaultSpec,
    rng: Rng,
    /// `engine_panic` is one-shot per clock: it disarms after firing,
    /// so the supervisor (which keeps the clock across engine
    /// incarnations) sees exactly one injected crash per arming —
    /// `engine_panic=1` means "one restart", not a crash loop.
    panic_armed: bool,
}

impl FaultClock {
    pub fn new(spec: FaultSpec) -> Self {
        let rng = Rng::new(spec.seed ^ 0xFA_u64.wrapping_mul(0x9E3779B97F4A7C15));
        let panic_armed = spec.engine_panic > 0.0;
        Self { spec, rng, panic_armed }
    }

    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }

    /// Blocks to withhold from admission this step: the whole pool with
    /// probability `pool_exhaust`, plus the constant `kv_pressure`
    /// withhold (rng-free, so arming it never shifts the seeded
    /// `pool_exhaust` timeline). Clamped to the pool size.
    pub fn withhold_blocks(&mut self, max_blocks: usize) -> usize {
        let exhausted = if self.spec.pool_exhaust > 0.0 && self.rng.uniform() < self.spec.pool_exhaust
        {
            max_blocks
        } else {
            0
        };
        exhausted.max(self.spec.kv_pressure).min(max_blocks)
    }

    /// Injected latency per engine step (`slow_step`).
    pub fn step_delay(&self) -> Option<Duration> {
        (self.spec.slow_step_ms > 0).then(|| Duration::from_millis(self.spec.slow_step_ms))
    }

    /// Whether to panic the engine thread this step (`engine_panic`).
    /// Draws from the rng only while armed, so arming it does not
    /// perturb the `pool_exhaust`/`slow_step` timelines of a spec that
    /// leaves it at 0.
    pub fn engine_panic(&mut self) -> bool {
        if !self.panic_armed {
            return false;
        }
        if self.rng.uniform() < self.spec.engine_panic {
            self.panic_armed = false;
            return true;
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_and_partial_specs() {
        let f = FaultSpec::parse("pool_exhaust=0.25, slow_step=10, drop_conn=0.5", 7).unwrap();
        assert_eq!(
            f,
            FaultSpec {
                pool_exhaust: 0.25,
                slow_step_ms: 10,
                drop_conn: 0.5,
                engine_panic: 0.0,
                kv_pressure: 0,
                seed: 7
            }
        );
        let f = FaultSpec::parse("slow_step=3", 0).unwrap();
        assert_eq!(f.slow_step_ms, 3);
        assert!(f.pool_exhaust == 0.0 && f.drop_conn == 0.0 && f.engine_panic == 0.0);
        let f = FaultSpec::parse("engine_panic=1", 0).unwrap();
        assert_eq!(f.engine_panic, 1.0);
        assert!(!f.is_none());
        let f = FaultSpec::parse("kv_pressure=12", 0).unwrap();
        assert_eq!(f.kv_pressure, 12);
        assert!(!f.is_none());
        assert!(FaultSpec::parse("kv_pressure=0.5", 0).is_err());
        assert!(FaultSpec::parse("", 0).unwrap().is_none());
        assert!(FaultSpec::parse("bogus=1", 0).is_err());
        assert!(FaultSpec::parse("drop_conn", 0).is_err());
        assert!(FaultSpec::parse("pool_exhaust=1.5", 0).is_err());
        assert!(FaultSpec::parse("engine_panic=2", 0).is_err());
    }

    #[test]
    fn engine_panic_fires_once_then_disarms() {
        let spec = FaultSpec { engine_panic: 1.0, seed: 5, ..FaultSpec::none() };
        let mut c = FaultClock::new(spec);
        assert!(c.engine_panic(), "p=1 fires on the first armed step");
        for _ in 0..32 {
            assert!(!c.engine_panic(), "one-shot: never fires again");
        }
        // probabilistic arming still fires at most once over a long run
        let spec = FaultSpec { engine_panic: 0.3, seed: 11, ..FaultSpec::none() };
        let mut c = FaultClock::new(spec);
        let fired: usize = (0..256).filter(|_| c.engine_panic()).count();
        assert_eq!(fired, 1, "p=0.3 over 256 steps fires exactly once");
    }

    #[test]
    fn decisions_are_deterministic_in_the_seed() {
        let f = FaultSpec { drop_conn: 0.7, seed: 42, ..FaultSpec::none() };
        let per_id: Vec<Option<usize>> = (0..32).map(|id| f.drop_after(id)).collect();
        assert_eq!(per_id, (0..32).map(|id| f.drop_after(id)).collect::<Vec<_>>());
        assert!(per_id.iter().any(Option::is_some), "p=0.7 over 32 ids must drop some");
        assert!(per_id.iter().any(Option::is_none), "…and keep some");
        let g = FaultSpec { seed: 43, ..f.clone() };
        assert_ne!(per_id, (0..32).map(|id| g.drop_after(id)).collect::<Vec<_>>(), "seed moves the timeline");

        let spec = FaultSpec { pool_exhaust: 0.5, seed: 9, ..FaultSpec::none() };
        let run = |spec: &FaultSpec| {
            let mut c = FaultClock::new(spec.clone());
            (0..64).map(|_| c.withhold_blocks(8)).collect::<Vec<_>>()
        };
        let a = run(&spec);
        assert_eq!(a, run(&spec), "per-step withholding replays exactly");
        assert!(a.iter().any(|&w| w == 8) && a.iter().any(|&w| w == 0));

        // kv_pressure is a constant floor under the same timeline: the
        // pool_exhaust decisions don't shift (rng-free knob), every
        // step withholds at least N, and the result clamps to the pool
        let both = FaultSpec { kv_pressure: 3, ..spec.clone() };
        let b = {
            let mut c = FaultClock::new(both.clone());
            (0..64).map(|_| c.withhold_blocks(8)).collect::<Vec<_>>()
        };
        assert_eq!(
            b,
            a.iter().map(|&w| w.max(3)).collect::<Vec<_>>(),
            "constant pressure floors the pool_exhaust timeline without shifting it"
        );
        let mut c = FaultClock::new(FaultSpec { kv_pressure: 100, ..FaultSpec::none() });
        assert_eq!(c.withhold_blocks(8), 8, "pressure clamps to the pool size");
    }

    #[test]
    fn no_faults_by_default() {
        let f = FaultSpec::none();
        assert!(f.is_none());
        assert_eq!(f.drop_after(3), None);
        let mut c = FaultClock::new(f);
        assert_eq!(c.withhold_blocks(100), 0);
        assert_eq!(c.step_delay(), None);
    }
}
