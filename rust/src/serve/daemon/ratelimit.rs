//! Per-tenant token-bucket rate limiting.
//!
//! The bucket counts *generated* tokens, not requests: a tenant
//! streaming long completions drains its budget proportionally to the
//! load it actually puts on the engine, while short requests stay
//! cheap. Because the daemon must decide admission *before* any token
//! is generated, it charges the request's worst case (`max_tokens`) up
//! front and refunds the unused remainder when the request finishes
//! (early EOS, cancel, failure) — so the bucket level is always a
//! conservative bound and a tenant can never overdraw by racing
//! submissions.
//!
//! Deliberately clock-explicit: every method takes `now: Instant` so
//! the daemon passes real time and tests pass synthetic time. Nothing
//! here reads the wall clock, keeping bucket decisions reproducible
//! under test.

use std::time::Instant;

/// The wire clamp for `Retry-After` seconds, shared with the
/// queue-wait derivation in `daemon/mod.rs` (documented [1, 60] window).
pub const RETRY_AFTER_MIN_S: u64 = 1;
pub const RETRY_AFTER_MAX_S: u64 = 60;

/// A token bucket: `level` refills at `rate` tokens/s up to `burst`.
#[derive(Clone, Debug)]
pub struct TokenBucket {
    /// Refill rate in tokens per second (> 0).
    rate: f64,
    /// Bucket capacity: the largest charge admissible after idleness.
    burst: f64,
    /// Current level in tokens (`0 ..= burst`).
    level: f64,
    /// Last refill instant.
    last: Instant,
}

impl TokenBucket {
    /// A bucket born full. `rate` must be positive; `burst <= 0` falls
    /// back to one second of refill.
    pub fn new(rate: f64, burst: f64, now: Instant) -> Self {
        let rate = if rate > 0.0 { rate } else { 1.0 };
        let burst = if burst > 0.0 { burst } else { rate };
        Self { rate, burst, level: burst, last: now }
    }

    fn refill(&mut self, now: Instant) {
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.level = (self.level + dt * self.rate).min(self.burst);
        self.last = now;
    }

    /// Charge `cost` tokens, or report the refill deficit as whole
    /// `Retry-After` seconds (clamped to the documented [1, 60]
    /// window). A cost above `burst` can never succeed; it reports the
    /// full-bucket wait so the client backs off maximally.
    pub fn try_take(&mut self, cost: f64, now: Instant) -> Result<(), u64> {
        self.refill(now);
        if cost <= self.level {
            self.level -= cost;
            return Ok(());
        }
        let deficit = (cost.min(self.burst) - self.level).max(0.0);
        let secs = (deficit / self.rate).ceil() as u64;
        Err(secs.clamp(RETRY_AFTER_MIN_S, RETRY_AFTER_MAX_S))
    }

    /// Return unused tokens from an up-front charge (early EOS,
    /// cancel, failure). Never lifts the level past `burst`.
    pub fn refund(&mut self, tokens: f64) {
        self.level = (self.level + tokens.max(0.0)).min(self.burst);
    }

    /// Apply a live-reloaded policy without forgetting spent budget:
    /// the level keeps its *deficit* relative to the old burst, so a
    /// reload can tighten or loosen the limit but never mints free
    /// tokens for a tenant that just drained its bucket.
    pub fn reconfigure(&mut self, rate: f64, burst: f64, now: Instant) {
        self.refill(now);
        let spent = self.burst - self.level;
        self.rate = if rate > 0.0 { rate } else { 1.0 };
        self.burst = if burst > 0.0 { burst } else { self.rate };
        self.level = (self.burst - spent).clamp(0.0, self.burst);
    }

    pub fn rate(&self) -> f64 {
        self.rate
    }

    pub fn burst(&self) -> f64 {
        self.burst
    }

    /// Current level after a refill to `now` (stats/tests).
    pub fn level(&mut self, now: Instant) -> f64 {
        self.refill(now);
        self.level
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn t0() -> Instant {
        Instant::now()
    }

    #[test]
    fn charges_until_empty_then_reports_deficit() {
        let now = t0();
        let mut b = TokenBucket::new(10.0, 20.0, now);
        assert_eq!(b.try_take(16.0, now), Ok(()));
        // 4 left; a 16-token charge is 12 short → ceil(12/10) = 2s
        assert_eq!(b.try_take(16.0, now), Err(2));
        // the failed attempt must not have drained anything
        assert!((b.level(now) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn refills_at_rate_up_to_burst() {
        let now = t0();
        let mut b = TokenBucket::new(10.0, 20.0, now);
        assert_eq!(b.try_take(20.0, now), Ok(()));
        let later = now + Duration::from_millis(500);
        // 0.5s * 10 tok/s = 5 tokens back
        assert!((b.level(later) - 5.0).abs() < 1e-6);
        let much_later = now + Duration::from_secs(3600);
        assert!((b.level(much_later) - 20.0).abs() < 1e-9, "capped at burst");
    }

    #[test]
    fn retry_after_clamps_to_wire_window() {
        let now = t0();
        // tiny rate: a full-burst deficit takes 1000s → clamped to 60
        let mut b = TokenBucket::new(0.01, 10.0, now);
        assert_eq!(b.try_take(10.0, now), Ok(()));
        assert_eq!(b.try_take(10.0, now), Err(RETRY_AFTER_MAX_S));
        // sub-second deficit still reports at least 1s
        let mut b = TokenBucket::new(1000.0, 100.0, now);
        assert_eq!(b.try_take(100.0, now), Ok(()));
        assert_eq!(b.try_take(50.0, now), Err(RETRY_AFTER_MIN_S));
    }

    #[test]
    fn oversized_cost_reports_full_bucket_wait() {
        let now = t0();
        let mut b = TokenBucket::new(2.0, 8.0, now);
        assert_eq!(b.try_take(8.0, now), Ok(())); // drain to empty
        // cost 100 > burst 8: can never succeed; deficit capped at the
        // burst so the wait is finite (8/2 = 4s), not absurd
        assert_eq!(b.try_take(100.0, now), Err(4));
    }

    #[test]
    fn refund_restores_unused_charge() {
        let now = t0();
        let mut b = TokenBucket::new(10.0, 32.0, now);
        assert_eq!(b.try_take(32.0, now), Ok(()));
        // request stopped early: 20 of 32 tokens unused
        b.refund(20.0);
        assert_eq!(b.try_take(20.0, now), Ok(()));
        // refunds never overflow the burst
        b.refund(1e9);
        assert!((b.level(now) - 32.0).abs() < 1e-9);
    }

    #[test]
    fn reconfigure_preserves_spent_deficit() {
        let now = t0();
        let mut b = TokenBucket::new(10.0, 20.0, now);
        assert_eq!(b.try_take(15.0, now), Ok(())); // 5 left, 15 spent
        b.reconfigure(5.0, 40.0, now);
        // deficit 15 carries over: 40 - 15 = 25 available
        assert!((b.level(now) - 25.0).abs() < 1e-9);
        b.reconfigure(5.0, 8.0, now);
        // tightened below the spend: clamped to empty, not negative
        assert!(b.level(now).abs() < 1e-9);
        assert_eq!(b.rate(), 5.0);
        assert_eq!(b.burst(), 8.0);
    }
}
