//! QuaRot (Ashkboos et al. 2024b): non-learned random Hadamard rotations.
//! R1 = H_d·diag(±1); R2_l = H_dh·diag(±1) per layer. Zero training cost —
//! the baseline KurTail must beat on quality while staying cheap.

use crate::tensor::{hadamard::random_hadamard, Tensor};
use crate::util::Rng;

/// (R1, per-layer R2) in QuaRot style.
pub fn quarot_rotations(d_model: usize, d_head: usize, n_layers: usize, rng: &mut Rng) -> (Tensor, Vec<Tensor>) {
    let r1 = random_hadamard(d_model, rng);
    let r2 = (0..n_layers).map(|_| random_hadamard(d_head, rng)).collect();
    (r1, r2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::hadamard::orthogonality_error;

    #[test]
    fn rotations_are_orthogonal_and_distinct() {
        let mut rng = Rng::new(0);
        let (r1, r2) = quarot_rotations(64, 16, 4, &mut rng);
        assert!(orthogonality_error(&r1) < 1e-4);
        assert_eq!(r2.len(), 4);
        for r in &r2 {
            assert!(orthogonality_error(r) < 1e-4);
        }
        // per-layer sign patterns differ
        assert!(r2[0].max_abs_diff(&r2[1]) > 0.01);
    }

    #[test]
    fn rotation_reduces_outlier_kurtosis() {
        // the QuaRot mechanism itself: rotating a heavy-tailed matrix
        // drops per-row kurtosis toward gaussian
        let mut rng = Rng::new(1);
        let (r1, _) = quarot_rotations(64, 16, 1, &mut rng);
        let mut x = Tensor::randn(&[512, 64], 1.0, &mut rng);
        for i in 0..512 {
            x.row_mut(i)[3] *= 20.0;
        }
        let before = crate::tensor::stats::kurtail_loss(&x);
        let after = crate::tensor::stats::kurtail_loss(&crate::tensor::matmul::matmul(&x, &r1));
        assert!(after < before / 2.0, "{after} !< {before}/2");
    }
}
