//! QuaRot (Ashkboos et al. 2024b): non-learned random Hadamard rotations.
//! R1 = H_d·diag(±1); R2_l = H_dh·diag(±1) per layer. Zero training cost —
//! the baseline KurTail must beat on quality while staying cheap.

use crate::tensor::{hadamard::hadamard_from_signs, Tensor};
use crate::util::Rng;

/// (R1, per-layer R2) in QuaRot style.
///
/// The ±1 sign vectors are drawn first, in the exact order the
/// all-sequential path consumed the RNG (so rotations are bit-identical
/// to the seed behavior), then the O(d²) matrix constructions run on the
/// row-parallel `hadamard_from_signs` kernel.
pub fn quarot_rotations(d_model: usize, d_head: usize, n_layers: usize, rng: &mut Rng) -> (Tensor, Vec<Tensor>) {
    let s1: Vec<f32> = (0..d_model).map(|_| rng.sign()).collect();
    let s2: Vec<Vec<f32>> = (0..n_layers)
        .map(|_| (0..d_head).map(|_| rng.sign()).collect())
        .collect();
    let r1 = hadamard_from_signs(d_model, &s1);
    let r2 = s2.iter().map(|s| hadamard_from_signs(d_head, s)).collect();
    (r1, r2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::hadamard::orthogonality_error;

    #[test]
    fn rotations_are_orthogonal_and_distinct() {
        let mut rng = Rng::new(0);
        let (r1, r2) = quarot_rotations(64, 16, 4, &mut rng);
        assert!(orthogonality_error(&r1) < 1e-4);
        assert_eq!(r2.len(), 4);
        for r in &r2 {
            assert!(orthogonality_error(r) < 1e-4);
        }
        // per-layer sign patterns differ
        assert!(r2[0].max_abs_diff(&r2[1]) > 0.01);
    }

    #[test]
    fn rotation_reduces_outlier_kurtosis() {
        // the QuaRot mechanism itself: rotating a heavy-tailed matrix
        // drops per-row kurtosis toward gaussian
        let mut rng = Rng::new(1);
        let (r1, _) = quarot_rotations(64, 16, 1, &mut rng);
        let mut x = Tensor::randn(&[512, 64], 1.0, &mut rng);
        for i in 0..512 {
            x.row_mut(i)[3] *= 20.0;
        }
        let before = crate::tensor::stats::kurtail_loss(&x);
        let after = crate::tensor::stats::kurtail_loss(&crate::tensor::matmul::matmul(&x, &r1));
        assert!(after < before / 2.0, "{after} !< {before}/2");
    }
}
