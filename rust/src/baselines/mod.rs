//! Baseline rotation methods the paper compares against (Table 2 rows):
//! QuaRot (random Hadamard) and SpinQuant-lite (end-to-end learned R1).

pub mod quarot;
pub mod spinquant;

pub use quarot::quarot_rotations;
pub use spinquant::{spinquant_learn, SpinQuantReport};
