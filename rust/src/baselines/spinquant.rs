//! SpinQuant-lite (Liu et al. 2024): learn R1 by minimizing end-to-end
//! cross-entropy through the quantized model (STE), via Cayley-Adam.
//!
//! This is the expensive baseline: every step runs a full-model forward
//! AND backward (the `spinquant_step_{cfg}` artifact holds the entire
//! model + autograd graph), which is exactly the memory/compute asymmetry
//! vs. KurTail's layer-wise capture that the paper's §3 "Training Cost"
//! argues (4×H100 vs 1 GPU for 70B). We measure the same asymmetry in
//! wall-clock and peak RSS on this testbed.

use anyhow::Result;

use crate::model::Params;
use crate::runtime::{Runtime, Value};
use crate::tensor::{hadamard::{orthogonality_error, random_hadamard}, IntTensor, Tensor};
use crate::obs::StageTimer;
use crate::util::{timer, Rng};

pub struct SpinQuantReport {
    pub r1: Tensor,
    pub losses: Vec<f32>,
    pub wall_s: f64,
    pub peak_rss_mib: f64,
}

/// Learn R1 on calibration batches (params must be norm-folded, γ = 1).
pub fn spinquant_learn(
    rt: &Runtime,
    params: &Params,
    calib_batches: &[IntTensor],
    iters: usize,
    lr: f32,
    seed: u64,
) -> Result<SpinQuantReport> {
    anyhow::ensure!(!calib_batches.is_empty(), "no calibration batches");
    let meta = params.meta.clone();
    let d = meta.d_model;
    let art = rt.load(&format!("spinquant_step_{}", meta.name))?;
    let sw = StageTimer::start("spinquant");
    let mut rng = Rng::new(seed ^ 0x5917);

    // SpinQuant initializes from a random Hadamard rotation.
    let mut r1 = random_hadamard(d, &mut rng);
    let mut m = Tensor::zeros(&[d, d]);
    let mut v = 0.0f32;
    let mut losses = Vec::with_capacity(iters);
    let spin_b = meta.spin_batch;

    let param_values = params.as_values();
    // spinquant_step takes spin_batch sequences; pad/slice every calib
    // batch once up front instead of rebuilding the same token tensor on
    // each of the `iters` optimizer steps
    let seq = meta.seq_len;
    let padded: Vec<IntTensor> = calib_batches
        .iter()
        .map(|full| {
            let rows = full.shape[0].min(spin_b);
            let mut data = full.data[..rows * seq].to_vec();
            while data.len() < spin_b * seq {
                data.extend_from_slice(&full.data[..seq]);
            }
            IntTensor::new(data, vec![spin_b, seq])
        })
        .collect();
    for t in 1..=iters {
        let tokens = padded[t % padded.len()].clone();

        let mut inputs = param_values.clone();
        inputs.push(Value::F32(r1));
        inputs.push(Value::F32(m));
        inputs.push(Value::from(v));
        inputs.push(Value::I32(tokens));
        inputs.push(Value::from(lr));
        inputs.push(Value::from(t as f32));
        let out = art.run(&inputs)?;
        r1 = out[0].as_f32()?.clone();
        m = out[1].as_f32()?.clone();
        v = out[2].scalar_f32()?;
        losses.push(out[3].scalar_f32()?);
    }
    let orth = orthogonality_error(&r1);
    anyhow::ensure!(orth < 1e-2, "spinquant R1 left the manifold: {orth}");
    Ok(SpinQuantReport {
        r1,
        losses,
        wall_s: sw.stop(),
        peak_rss_mib: timer::peak_rss_mib(),
    })
}

#[cfg(test)]
mod tests {
    // exercised end-to-end in rust/tests/pipeline_integration.rs
}
