//! `artifacts/manifest.json` — the ABI emitted by compile/aot.py.
//!
//! The manifest pins, for every artifact, the exact input/output tensor
//! signatures (names, shapes, dtypes) and, for every model config, the
//! canonical parameter order. The runtime validates every call against it
//! so a stale artifacts/ directory fails loudly instead of mis-executing.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::Json;

#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: usize,
    pub kurtail_rows: usize,
    pub configs: BTreeMap<String, ConfigMeta>,
    pub artifacts: BTreeMap<String, ArtifactSig>,
}

#[derive(Debug, Clone)]
pub struct ConfigMeta {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub arch: String,
    pub n_experts: usize,
    pub top_k: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub cap_batch: usize,
    pub decode_batch: usize,
    pub spin_batch: usize,
    pub param_specs: Vec<ParamSpec>,
}

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct ArtifactSig {
    pub file: String,
    pub group: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

#[derive(Debug, Clone)]
pub struct TensorSig {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

fn tensor_sig(j: &Json) -> Result<TensorSig> {
    Ok(TensorSig {
        name: j.get("name")?.as_str()?.to_string(),
        shape: j.get("shape")?.usize_vec()?,
        dtype: j.get("dtype")?.as_str()?.to_string(),
    })
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let version = j.get("version")?.as_usize()?;
        anyhow::ensure!(version == 1, "unsupported manifest version {version}");

        let mut configs = BTreeMap::new();
        for (name, c) in j.get("configs")?.as_obj()? {
            let param_specs = c
                .get("param_specs")?
                .as_arr()?
                .iter()
                .map(|p| {
                    Ok(ParamSpec {
                        name: p.get("name")?.as_str()?.to_string(),
                        shape: p.get("shape")?.usize_vec()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            configs.insert(
                name.clone(),
                ConfigMeta {
                    name: c.get("name")?.as_str()?.to_string(),
                    vocab: c.get("vocab")?.as_usize()?,
                    d_model: c.get("d_model")?.as_usize()?,
                    n_layers: c.get("n_layers")?.as_usize()?,
                    n_heads: c.get("n_heads")?.as_usize()?,
                    d_head: c.get("d_head")?.as_usize()?,
                    d_ff: c.get("d_ff")?.as_usize()?,
                    seq_len: c.get("seq_len")?.as_usize()?,
                    arch: c.get("arch")?.as_str()?.to_string(),
                    n_experts: c.get("n_experts")?.as_usize()?,
                    top_k: c.get("top_k")?.as_usize()?,
                    train_batch: c.get("train_batch")?.as_usize()?,
                    eval_batch: c.get("eval_batch")?.as_usize()?,
                    cap_batch: c.get("cap_batch")?.as_usize()?,
                    decode_batch: c.get("decode_batch")?.as_usize()?,
                    spin_batch: c.get("spin_batch")?.as_usize()?,
                    param_specs,
                },
            );
        }

        let mut artifacts = BTreeMap::new();
        for (name, a) in j.get("artifacts")?.as_obj()? {
            artifacts.insert(
                name.clone(),
                ArtifactSig {
                    file: a.get("file")?.as_str()?.to_string(),
                    group: a.get("group")?.as_str()?.to_string(),
                    inputs: a.get("inputs")?.as_arr()?.iter().map(tensor_sig).collect::<Result<_>>()?,
                    outputs: a.get("outputs")?.as_arr()?.iter().map(tensor_sig).collect::<Result<_>>()?,
                },
            );
        }

        Ok(Manifest {
            version,
            kurtail_rows: j.get("kurtail_rows")?.as_usize()?,
            configs,
            artifacts,
        })
    }

    pub fn config(&self, name: &str) -> Result<&ConfigMeta> {
        self.configs.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "config '{name}' not in manifest (have: {:?})",
                self.configs.keys().collect::<Vec<_>>()
            )
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSig> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not in manifest"))
    }
}

impl ConfigMeta {
    /// Number of parameter tensors (the leading inputs of most graphs).
    pub fn n_params(&self) -> usize {
        self.param_specs.len()
    }

    /// Index of a named parameter in the canonical order.
    pub fn param_index(&self, name: &str) -> Option<usize> {
        self.param_specs.iter().position(|p| p.name == name)
    }

    /// Names of layer-stacked params (leading axis = n_layers).
    pub fn layer_param_names(&self) -> Vec<&str> {
        self.param_specs
            .iter()
            .map(|p| p.name.as_str())
            .filter(|n| !matches!(*n, "embed" | "lnf" | "head"))
            .collect()
    }

    /// Approximate parameter count (for reports).
    pub fn param_count(&self) -> usize {
        self.param_specs.iter().map(|p| p.shape.iter().product::<usize>()).sum()
    }
}
