//! Host values crossing the PJRT boundary: f32 / i32 tensors.

use anyhow::Result;

use crate::tensor::{IntTensor, Tensor};

#[derive(Clone, Debug)]
pub enum Value {
    F32(Tensor),
    I32(IntTensor),
}

impl Value {
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => &t.shape,
            Value::I32(t) => &t.shape,
        }
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            Value::F32(_) => "f32",
            Value::I32(_) => "i32",
        }
    }

    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            Value::I32(_) => anyhow::bail!("expected f32 value, got i32"),
        }
    }

    pub fn into_f32(self) -> Result<Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            Value::I32(_) => anyhow::bail!("expected f32 value, got i32"),
        }
    }

    pub fn as_i32(&self) -> Result<&IntTensor> {
        match self {
            Value::I32(t) => Ok(t),
            Value::F32(_) => anyhow::bail!("expected i32 value, got f32"),
        }
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        let t = self.as_f32()?;
        anyhow::ensure!(t.numel() == 1, "expected scalar, shape {:?}", t.shape);
        Ok(t.data[0])
    }

    /// Convert to an XLA literal (reshaped to the target dims).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Value::F32(t) => xla::Literal::vec1(&t.data),
            Value::I32(t) => xla::Literal::vec1(&t.data),
        };
        Ok(lit.reshape(&dims).map_err(|e| anyhow::anyhow!("reshape literal: {e}"))?)
    }

    /// Convert an XLA literal back to a host value.
    pub fn from_literal(lit: &xla::Literal) -> Result<Value> {
        let shape = lit.array_shape().map_err(|e| anyhow::anyhow!("literal shape: {e}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                let data = lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec f32: {e}"))?;
                Ok(Value::F32(Tensor::new(data, dims)))
            }
            xla::ElementType::S32 => {
                let data = lit.to_vec::<i32>().map_err(|e| anyhow::anyhow!("to_vec i32: {e}"))?;
                Ok(Value::I32(IntTensor::new(data, dims)))
            }
            other => anyhow::bail!("unsupported output element type {other:?}"),
        }
    }
}

impl From<Tensor> for Value {
    fn from(t: Tensor) -> Self {
        Value::F32(t)
    }
}

impl From<IntTensor> for Value {
    fn from(t: IntTensor) -> Self {
        Value::I32(t)
    }
}

impl From<f32> for Value {
    fn from(v: f32) -> Self {
        Value::F32(Tensor::scalar(v))
    }
}

impl From<i32> for Value {
    fn from(v: i32) -> Self {
        Value::I32(IntTensor::scalar(v))
    }
}
