//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the only module that touches the `xla` crate. Everything above
//! it speaks `Tensor`/`IntTensor` + artifact names. Python never runs at
//! request time — the manifest + HLO text files are the entire contract.

pub mod artifact;
pub mod manifest;
pub mod value;

pub use artifact::{Artifact, Runtime};
pub use manifest::{ArtifactSig, ConfigMeta, Manifest, ParamSpec, TensorSig};
pub use value::Value;
