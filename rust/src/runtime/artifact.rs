//! Artifact loading + execution: HLO text → PJRT executable, with a
//! compile cache (compiling an HLO module costs 10s–100s of ms; every
//! pipeline stage reuses the cached executable).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use super::manifest::{ArtifactSig, Manifest};
use super::value::Value;

/// A compiled artifact bound to its manifest signature.
pub struct Artifact {
    pub name: String,
    pub sig: ArtifactSig,
    exe: xla::PjRtLoadedExecutable,
    /// cumulative execution stats (per-artifact profiling, §Perf)
    stats: Mutex<ExecStats>,
}

#[derive(Default, Debug, Clone, Copy)]
pub struct ExecStats {
    pub calls: u64,
    pub total_s: f64,
}

/// The PJRT runtime: one CPU client + manifest + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub dir: PathBuf,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<Artifact>>>,
    pub compile_s: Mutex<f64>,
}

impl Runtime {
    /// Open the artifacts directory (default: ./artifacts).
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("creating PJRT CPU client: {e}"))?;
        Ok(Self { client, dir, manifest, cache: Mutex::new(HashMap::new()), compile_s: Mutex::new(0.0) })
    }

    /// Load (compile-once) an artifact by manifest name.
    pub fn load(&self, name: &str) -> Result<Arc<Artifact>> {
        if let Some(a) = self.cache.lock().unwrap().get(name) {
            return Ok(a.clone());
        }
        let sig = self.manifest.artifact(name)?.clone();
        let path = self.dir.join(&sig.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling artifact '{name}': {e}"))?;
        *self.compile_s.lock().unwrap() += t0.elapsed().as_secs_f64();
        let artifact =
            Arc::new(Artifact { name: name.to_string(), sig, exe, stats: Mutex::new(ExecStats::default()) });
        self.cache.lock().unwrap().insert(name.to_string(), artifact.clone());
        Ok(artifact)
    }

    /// Drop a cached executable (frees PJRT memory for one-shot artifacts
    /// like spinquant_step once a baseline finishes).
    pub fn evict(&self, name: &str) {
        self.cache.lock().unwrap().remove(name);
    }

    pub fn cached_artifacts(&self) -> Vec<String> {
        self.cache.lock().unwrap().keys().cloned().collect()
    }
}

impl Artifact {
    /// Execute with shape/dtype validation against the manifest signature.
    pub fn run(&self, inputs: &[Value]) -> Result<Vec<Value>> {
        anyhow::ensure!(
            inputs.len() == self.sig.inputs.len(),
            "artifact '{}': {} inputs given, {} expected",
            self.name,
            inputs.len(),
            self.sig.inputs.len()
        );
        for (v, s) in inputs.iter().zip(&self.sig.inputs) {
            anyhow::ensure!(
                v.shape() == s.shape.as_slice() && v.dtype() == s.dtype,
                "artifact '{}' input '{}': got {:?}/{} want {:?}/{}",
                self.name, s.name, v.shape(), v.dtype(), s.shape, s.dtype
            );
        }
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|v| v.to_literal()).collect::<Result<_>>()?;

        let t0 = Instant::now();
        let bufs = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("executing '{}': {e}", self.name))?;
        let result = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result of '{}': {e}", self.name))?;
        // aot.py lowers with return_tuple=True: output is always a tuple.
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling result of '{}': {e}", self.name))?;
        {
            let mut st = self.stats.lock().unwrap();
            st.calls += 1;
            st.total_s += t0.elapsed().as_secs_f64();
        }
        anyhow::ensure!(
            parts.len() == self.sig.outputs.len(),
            "artifact '{}': {} outputs, {} expected",
            self.name, parts.len(), self.sig.outputs.len()
        );
        let mut out = Vec::with_capacity(parts.len());
        for (lit, s) in parts.iter().zip(&self.sig.outputs) {
            let v = Value::from_literal(lit)
                .with_context(|| format!("artifact '{}' output '{}'", self.name, s.name))?;
            anyhow::ensure!(
                v.shape() == s.shape.as_slice(),
                "artifact '{}' output '{}': got {:?} want {:?}",
                self.name, s.name, v.shape(), s.shape
            );
            out.push(v);
        }
        Ok(out)
    }

    pub fn stats(&self) -> ExecStats {
        *self.stats.lock().unwrap()
    }
}
