//! Training-cost comparison (paper §3 "Training Cost" / §4 Setup):
//! SpinQuant needs the full model + autograd in memory every step;
//! KurTail only layer-wise inference + a bounded activation pool.
//! The paper's 4×H100-vs-1-GPU asymmetry shows up here as wall-clock and
//! incremental peak-RSS of the rotation-learning stage.

use anyhow::Result;

use crate::config::{Method, WeightQuantizer};
use crate::pipeline::report::{save_table, Table};

use super::ExpCtx;

pub fn training_cost(ctx: &ExpCtx) -> Result<()> {
    let model = if ctx.fast { "tiny" } else { "base" };
    let pipe = ctx.pipeline(model)?;
    let mut t = Table::new(
        "Training cost — rotation learning stage (paper: SpinQuant 4×H100·2h vs KurTail 1×H100·1h for 70B)",
        &["Method", "capture (s)", "optimize (s)", "total (s)", "peak RSS (MiB)"],
    );
    for method in [Method::QuaRot, Method::SpinQuant, Method::KurTail] {
        let (_, cost) = ctx.run_cell(&pipe, method, WeightQuantizer::Rtn)?;
        println!(
            "  [{}] optimize {:.2}s total {:.2}s rss {:.0}MiB",
            method.label(),
            cost.optimize_s,
            cost.total_s,
            cost.peak_rss_mib
        );
        t.row(vec![
            method.label().to_string(),
            format!("{:.2}", cost.capture_s),
            format!("{:.2}", cost.optimize_s),
            format!("{:.2}", cost.total_s),
            format!("{:.0}", cost.peak_rss_mib),
        ]);
    }
    t.print();
    save_table(&t, "cost")?;
    Ok(())
}
