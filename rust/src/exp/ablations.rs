//! Calibration ablations: Table 6 (dataset) and Table 7 (sample size).

use anyhow::Result;

use crate::config::{Method, PipelineConfig, WeightQuantizer};
use crate::eval::evaluate;
use crate::pipeline::report::{save_table, Table};

use super::ExpCtx;

fn pct(v: f32) -> String {
    format!("{:.1}", v * 100.0)
}

/// Table 6: calibration dataset ablation (wiki / c4 / alpaca / ptb /
/// combined), plus the QuaRot (no-training) reference row.
pub fn table6(ctx: &ExpCtx) -> Result<()> {
    let model = if ctx.fast { "tiny" } else { "small" };
    let pipe = ctx.pipeline(model)?;
    let mut t = Table::new(
        "Table 6 — KurTail calibration-dataset ablation (paper: every dataset beats QuaRot)",
        &["Cal Dataset", "Wiki (↓)", "0-shot (↑)", "MMLU (↑)"],
    );

    // reference row: QuaRot needs no calibration data
    let (s, _) = ctx.run_cell(&pipe, Method::QuaRot, WeightQuantizer::Gptq)?;
    t.row(vec!["Quarot".into(), format!("{:.3}", s.wiki_ppl), pct(s.zero_shot_avg), pct(s.mmlu_avg)]);

    for ds in ["wikitext-2", "c4", "alpaca", "ptb", "combined"] {
        let mut pcfg = PipelineConfig::new(model, Method::KurTail);
        pcfg.seed = ctx.seed;
        pcfg.calib.seed = ctx.seed;
        pcfg.calib.dataset = ds.to_string();
        if ctx.fast {
            pcfg.calib.n_samples = 64;
            pcfg.calib.iters = 30;
        }
        let (pm, _) = pipe.quantize(&pcfg)?;
        let s = evaluate(&pipe, &pm, ctx.n_questions(), ctx.eval_batches())?;
        println!("  [{ds}] ppl {:.3}", s.wiki_ppl);
        t.row(vec![
            ds.to_string(),
            format!("{:.3}", s.wiki_ppl),
            pct(s.zero_shot_avg),
            pct(s.mmlu_avg),
        ]);
    }
    t.print();
    save_table(&t, "table6")?;
    Ok(())
}

/// Table 7: calibration sample-size ablation (128 / 256 / 512 / 1024).
pub fn table7(ctx: &ExpCtx) -> Result<()> {
    let model = if ctx.fast { "tiny" } else { "small" };
    let pipe = ctx.pipeline(model)?;
    let mut t = Table::new(
        "Table 7 — KurTail calibration-size ablation on the combined dataset (saturates ~512)",
        &["Cal Size", "Wiki (↓)", "0-shot (↑)", "MMLU (↑)"],
    );
    let sizes: &[usize] = if ctx.fast { &[32, 128] } else { &[128, 256, 512, 1024] };
    for &n in sizes {
        let mut pcfg = PipelineConfig::new(model, Method::KurTail);
        pcfg.seed = ctx.seed;
        pcfg.calib.seed = ctx.seed;
        pcfg.calib.dataset = "combined".into();
        pcfg.calib.n_samples = n;
        if ctx.fast {
            pcfg.calib.iters = 30;
        }
        let (pm, _) = pipe.quantize(&pcfg)?;
        let s = evaluate(&pipe, &pm, ctx.n_questions(), ctx.eval_batches())?;
        println!("  [{n}] ppl {:.3}", s.wiki_ppl);
        t.row(vec![n.to_string(), format!("{:.3}", s.wiki_ppl), pct(s.zero_shot_avg), pct(s.mmlu_avg)]);
    }
    t.print();
    save_table(&t, "table7")?;
    Ok(())
}
