//! Main result tables: Table 2 (model family × method), Table 3 (Phi),
//! Table 4 (MoE/RTN), Table 5 (MathQA), Tables 8–10 (breakdowns).

use anyhow::Result;

use crate::config::{Method, WeightQuantizer};
use crate::pipeline::report::{save_table, Table};

use super::ExpCtx;

fn pct(v: f32) -> String {
    format!("{:.1}", v * 100.0)
}

/// Table 2: Wiki ppl / 0-shot / MMLU across the model family × methods
/// (weights GPTQ, W4A4KV4 — the paper's headline table).
pub fn table2(ctx: &ExpCtx) -> Result<()> {
    let mut t = Table::new(
        "Table 2 — W4A4KV4 comparison (weights GPTQ). Paper shape: 16-bit ≫ GPTQ-only; KurTail ≥ SpinQuant > QuaRot.",
        &["Model", "Method", "Wiki (↓)", "0-shot (↑)", "MMLU (↑)"],
    );
    for model in ctx.table2_models() {
        let pipe = ctx.pipeline(model)?;
        for method in Method::all() {
            let (s, _) = ctx.run_cell(&pipe, method, WeightQuantizer::Gptq)?;
            println!(
                "  [{model}/{}] ppl {:.3}  0-shot {}  mmlu {}",
                method.label(),
                s.wiki_ppl,
                pct(s.zero_shot_avg),
                pct(s.mmlu_avg)
            );
            t.row(vec![
                model.to_string(),
                method.label().to_string(),
                format!("{:.3}", s.wiki_ppl),
                pct(s.zero_shot_avg),
                pct(s.mmlu_avg),
            ]);
        }
    }
    t.print();
    save_table(&t, "table2")?;
    Ok(())
}

/// Table 3: architecture transfer — the Phi-style (GELU MLP) config.
pub fn table3(ctx: &ExpCtx) -> Result<()> {
    let mut t = Table::new(
        "Table 3 — Phi-style model (GELU MLP), W4A4KV4, weights GPTQ",
        &["Method", "Wiki (↓)", "0-shot (↑)", "MMLU (↑)"],
    );
    let pipe = ctx.pipeline("phi")?;
    for method in [Method::Fp16, Method::QuaRot, Method::KurTail] {
        let (s, _) = ctx.run_cell(&pipe, method, WeightQuantizer::Gptq)?;
        t.row(vec![
            method.label().to_string(),
            format!("{:.3}", s.wiki_ppl),
            pct(s.zero_shot_avg),
            pct(s.mmlu_avg),
        ]);
    }
    t.print();
    save_table(&t, "table3")?;
    Ok(())
}

/// Table 4: Mixtral-style MoE with RTN weights (rotation shared across
/// experts — the paper's §5.1 point).
pub fn table4(ctx: &ExpCtx) -> Result<()> {
    let mut t = Table::new(
        "Table 4 — MoE (4 experts, top-2), W4A4KV4, weights RTN",
        &["Method", "Wiki (↓)", "0-shot (↑)", "MMLU (↑)"],
    );
    let pipe = ctx.pipeline("moe")?;
    for (method, wq) in [
        (Method::Fp16, WeightQuantizer::None),
        (Method::GptqOnly, WeightQuantizer::Rtn), // "RTN" row: no rotations
        (Method::QuaRot, WeightQuantizer::Rtn),
        (Method::KurTail, WeightQuantizer::Rtn),
    ] {
        let (s, _) = ctx.run_cell(&pipe, method, wq)?;
        let label = if method == Method::GptqOnly { "RTN" } else { method.label() };
        t.row(vec![
            label.to_string(),
            format!("{:.3}", s.wiki_ppl),
            pct(s.zero_shot_avg),
            pct(s.mmlu_avg),
        ]);
    }
    t.print();
    save_table(&t, "table4")?;
    Ok(())
}

/// Table 5: MathQA accuracy across the model family.
pub fn table5(ctx: &ExpCtx) -> Result<()> {
    let mut t = Table::new(
        "Table 5 — MathQA-analog accuracy (%), W4A4KV4, weights GPTQ",
        &["Model", "16-bit", "QuaRot", "KurTail"],
    );
    let mut models = ctx.table2_models();
    models.push("phi");
    for model in models {
        let pipe = ctx.pipeline(model)?;
        let mut cells = vec![model.to_string()];
        for method in [Method::Fp16, Method::QuaRot, Method::KurTail] {
            let (s, _) = ctx.run_cell(&pipe, method, WeightQuantizer::Gptq)?;
            cells.push(pct(s.mathqa));
        }
        t.row(cells);
    }
    t.print();
    save_table(&t, "table5")?;
    Ok(())
}

/// Table 8: MMLU-analog per-domain breakdown.
pub fn table8(ctx: &ExpCtx) -> Result<()> {
    let model = if ctx.fast { "tiny" } else { "small" };
    let mut t = Table::new(
        "Table 8 — MMLU-analog per-domain accuracy (%), W4A4KV4 / GPTQ",
        &["Model", "Method", "Human", "Other", "STEM", "S-Sci", "AVG"],
    );
    let pipe = ctx.pipeline(model)?;
    for method in [Method::Fp16, Method::QuaRot, Method::SpinQuant, Method::KurTail] {
        let (s, _) = ctx.run_cell(&pipe, method, WeightQuantizer::Gptq)?;
        let find = |d: &str| {
            s.per_domain
                .iter()
                .find(|(n, _)| n == d)
                .map(|(_, a)| pct(*a))
                .unwrap_or_default()
        };
        t.row(vec![
            model.to_string(),
            if method == Method::Fp16 { "Vanilla".into() } else { method.label().to_string() },
            find("humanities"),
            find("other"),
            find("stem"),
            find("social"),
            pct(s.mmlu_avg),
        ]);
    }
    t.print();
    save_table(&t, "table8")?;
    Ok(())
}

fn per_task_table(ctx: &ExpCtx, wq: WeightQuantizer, caption: &str, file: &str) -> Result<()> {
    let model = if ctx.fast { "tiny" } else { "small" };
    let task_names = ["ARC-C", "ARC-E", "BoolQ", "HellaSwag", "OBQA", "PIQA", "SIQA", "WinoGrande"];
    let mut headers = vec!["Model", "Method"];
    headers.extend(task_names);
    headers.push("AVG");
    let mut t = Table::new(caption, &headers);
    let pipe = ctx.pipeline(model)?;
    let methods: &[Method] = if wq == WeightQuantizer::Rtn {
        &[Method::Fp16, Method::QuaRot, Method::KurTail]
    } else {
        &[Method::Fp16, Method::QuaRot, Method::SpinQuant, Method::KurTail]
    };
    for &method in methods {
        let (s, _) = ctx.run_cell(&pipe, method, wq)?;
        let mut cells = vec![
            model.to_string(),
            if method == Method::Fp16 { "Vanilla".into() } else { method.label().to_string() },
        ];
        for name in task_names {
            let acc = s.per_task.iter().find(|(n, _)| n == name).map(|(_, a)| *a).unwrap_or(0.0);
            cells.push(pct(acc));
        }
        cells.push(pct(s.zero_shot_avg));
        t.row(cells);
    }
    t.print();
    save_table(&t, file)?;
    Ok(())
}

/// Table 9: per-task zero-shot breakdown, GPTQ weights.
pub fn table9(ctx: &ExpCtx) -> Result<()> {
    per_task_table(
        ctx,
        WeightQuantizer::Gptq,
        "Table 9 — zero-shot-analog per-task accuracy (%), W4A4KV4 / GPTQ",
        "table9",
    )
}

/// Table 10: per-task zero-shot breakdown, RTN weights.
pub fn table10(ctx: &ExpCtx) -> Result<()> {
    per_task_table(
        ctx,
        WeightQuantizer::Rtn,
        "Table 10 — zero-shot-analog per-task accuracy (%), W4A4KV4 / RTN",
        "table10",
    )
}
