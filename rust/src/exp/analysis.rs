//! Analysis experiments on captured activations: Fig. 1 (sensitivity),
//! Fig. 2 (outlier distributions), Table 1 (success rates).

use anyhow::Result;

use crate::baselines::quarot_rotations;
use crate::calib::CorpusKind;
use crate::config::CalibConfig;
use crate::eval::outliers::{dist_stats, value_histogram};
use crate::eval::sensitivity::{alpha_grid, sensitivity_curve_rotated};
use crate::eval::success::success_rate;
use crate::kurtail::learn_rotations;
use crate::model::{capture_stream, rmsnorm_rows};
use crate::pipeline::report::{save_csv, save_table, Table};
use crate::quant::fake_quant_rows;
use crate::config::QuantScheme;
use crate::rotation::fold_norms;
use crate::tensor::{matmul::rows_matmul, Tensor};
use crate::util::Rng;

use super::ExpCtx;

/// Captured + normed block inputs for the analysis experiments.
struct AnalysisData {
    /// per-layer MHSA block inputs (normed rows)
    mhsa: Vec<Tensor>,
    /// per-layer FFN block inputs (normed rows)
    ffn: Vec<Tensor>,
    /// rotations
    r1_kurtail: Tensor,
    r1_quarot: Tensor,
}

/// LLM-regime synthetic activations (DESIGN.md §2): Laplace bulk (Banner
/// et al. 2019) + a few ×20 outlier channels (Dettmers et al. 2022). Our
/// from-scratch tiny models develop only mild outliers, so the analysis
/// experiments report both the captured and this stressed source.
struct SyntheticData {
    rows: Tensor,
    r1_kurtail: Tensor,
    r1_quarot: Tensor,
}

fn synthetic_analysis(ctx: &ExpCtx, d: usize) -> Result<SyntheticData> {
    let mut rng = Rng::new(ctx.seed ^ 0x5EED5);
    let n = if ctx.fast { 8_192 } else { 32_768 };
    let mut rows = Tensor::zeros(&[n, d]);
    for v in &mut rows.data {
        *v = rng.laplace(0.08);
    }
    let outlier_channels = [3 % d, (d / 3) % d, (d - 5) % d];
    for i in 0..n {
        for &c in &outlier_channels {
            rows.data[i * d + c] *= 20.0;
        }
    }
    // learn the KurTail rotation on this pool through the artifact
    let mut pool = crate::model::RowReservoir::new(d, n, ctx.seed ^ 0x11);
    pool.offer(&rows);
    let iters = if ctx.fast { 40 } else { 100 };
    let run = crate::kurtail::optimizer::cayley_run(&ctx.rt, d, &mut pool, iters, 0.05)?;
    let (r1_q, _) = quarot_rotations(d, d.min(16), 1, &mut rng);
    Ok(SyntheticData { rows, r1_kurtail: run.rotation, r1_quarot: r1_q })
}

fn capture_analysis(ctx: &ExpCtx, model: &str) -> Result<AnalysisData> {
    let pipe = ctx.pipeline(model)?;
    let mut params = pipe.fp_params.clone();
    fold_norms(&mut params);
    let meta = params.meta.clone();
    let n_cap = if ctx.fast { 4 } else { 16 };
    let batches =
        pipe.bundle.calib_batches(CorpusKind::Wiki, n_cap * meta.cap_batch, meta.cap_batch, ctx.seed);

    let mut mhsa: Vec<Vec<f32>> = vec![Vec::new(); meta.n_layers];
    let mut ffn: Vec<Vec<f32>> = vec![Vec::new(); meta.n_layers];
    capture_stream(&pipe.rt, &params, &batches, |taps| {
        mhsa[taps.layer].extend_from_slice(&rmsnorm_rows(&taps.mhsa_in).data);
        ffn[taps.layer].extend_from_slice(&rmsnorm_rows(&taps.ffn_in).data);
        Ok(())
    })?;
    let d = meta.d_model;
    let to_tensor = |v: Vec<f32>| {
        let rows = v.len() / d;
        Tensor::new(v, vec![rows, d])
    };

    // rotations: KurTail (learned) vs QuaRot (random Hadamard)
    let mut calib = CalibConfig { seed: ctx.seed, ..CalibConfig::default() };
    if ctx.fast {
        calib.iters = 30;
    }
    let rep = learn_rotations(&pipe.rt, &params, &batches, &calib)?;
    let mut rng = Rng::new(ctx.seed ^ 0x9A12);
    let (r1_q, _) = quarot_rotations(meta.d_model, meta.d_head, meta.n_layers, &mut rng);

    Ok(AnalysisData {
        mhsa: mhsa.into_iter().map(to_tensor).collect(),
        ffn: ffn.into_iter().map(to_tensor).collect(),
        r1_kurtail: rep.r1,
        r1_quarot: r1_q,
    })
}

/// Fig. 1: empirical sensitivity of the MHSA input distribution across
/// rotations, first layer vs a deep layer.
pub fn fig1(ctx: &ExpCtx) -> Result<()> {
    let model = if ctx.fast { "tiny" } else { "small" };
    let data = capture_analysis(ctx, model)?;
    let alphas = alpha_grid();
    let scheme = QuantScheme::act4();
    let deep = data.mhsa.len() - 1;

    let mut rows: Vec<Vec<f64>> = alphas.iter().map(|&a| vec![a as f64]).collect();
    let mut t = Table::new(
        "Fig. 1 — sensitivity Γ(α·s̃) of MHSA inputs (lower/flatter = better)",
        &["layer", "rotation", "Γ@α=0.5", "Γ@α=0.75", "Γ@α=1.25", "Γ@α=1.5"],
    );
    let syn = synthetic_analysis(ctx, data.mhsa[0].shape[1])?;
    let sources: [(&str, &Tensor, &Tensor, &Tensor); 3] = [
        ("first", &data.mhsa[0], &data.r1_quarot, &data.r1_kurtail),
        ("deep", &data.mhsa[deep], &data.r1_quarot, &data.r1_kurtail),
        ("LLM-regime", &syn.rows, &syn.r1_quarot, &syn.r1_kurtail),
    ];
    for (lname, x, r_had, r_kt) in sources {
        for (rname, rot) in
            [("vanilla", None), ("hadamard", Some(r_had)), ("kurtail", Some(r_kt))]
        {
            // fused sweep: rotates chunk-at-a-time, never materializes x·R
            let curve = sensitivity_curve_rotated(x, rot, &alphas, &scheme);
            for (k, &v) in curve.iter().enumerate() {
                rows[k].push(v as f64);
            }
            let pick = |a: f32| {
                let i = alphas.iter().position(|&x| (x - a).abs() < 1e-4).unwrap();
                format!("{:.3}", curve[i])
            };
            t.row(vec![
                lname.into(),
                rname.into(),
                pick(0.5),
                pick(0.75),
                pick(1.25),
                pick(1.5),
            ]);
        }
    }
    t.print();
    save_table(&t, "fig1")?;
    save_csv(
        "fig1_curves",
        &[
            "alpha",
            "first_vanilla", "first_hadamard", "first_kurtail",
            "deep_vanilla", "deep_hadamard", "deep_kurtail",
            "llm_vanilla", "llm_hadamard", "llm_kurtail",
        ],
        &rows,
    )?;
    println!("series → results/fig1_curves.csv");
    Ok(())
}

/// Fig. 2: MHSA/FFN input distributions before/after KurTail.
pub fn fig2(ctx: &ExpCtx) -> Result<()> {
    let model = if ctx.fast { "tiny" } else { "small" };
    let data = capture_analysis(ctx, model)?;
    let mid = data.mhsa.len() / 2;

    let mut t = Table::new(
        "Fig. 2 — distribution stats of block inputs before/after KurTail rotation",
        &["block", "variant", "mean tok-max", "p99 tok-max", "outlier ch.", "mean κ", "4b-MSE"],
    );
    let syn = synthetic_analysis(ctx, data.mhsa[0].shape[1])?;
    let mut hist_rows: Vec<Vec<f64>> = Vec::new();
    let blocks: [(&str, &Tensor, &Tensor); 3] = [
        ("MHSA", &data.mhsa[mid], &data.r1_kurtail),
        ("FFN", &data.ffn[mid], &data.r1_kurtail),
        ("LLM-regime", &syn.rows, &syn.r1_kurtail),
    ];
    for (bname, x, r_kt) in blocks {
        for (vname, rot) in [("vanilla", None), ("kurtail", Some(r_kt))] {
            let xr = match rot {
                Some(r) => rows_matmul(x, r),
                None => x.clone(),
            };
            let s = dist_stats(&xr);
            let fq = fake_quant_rows(&xr, &QuantScheme::act4());
            let mse = {
                let d = xr.sub(&fq);
                d.data.iter().map(|v| (v * v) as f64).sum::<f64>() / d.numel() as f64
            };
            t.row(vec![
                bname.into(),
                vname.into(),
                format!("{:.3}", s.mean_token_max),
                format!("{:.3}", s.p99_token_max),
                format!("{}", s.outlier_channels),
                format!("{:.2}", s.mean_token_kurtosis),
                format!("{mse:.2e}"),
            ]);
            let (lo, hi, h) = value_histogram(&xr, 64);
            let mut row = vec![lo as f64, hi as f64];
            row.extend(h.iter().map(|&c| c as f64));
            hist_rows.push(row);
        }
    }
    t.print();
    save_table(&t, "fig2")?;
    let mut headers = vec!["lo".to_string(), "hi".to_string()];
    headers.extend((0..64).map(|i| format!("bin{i}")));
    let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    save_csv("fig2_histograms", &headers_ref, &hist_rows)?;
    println!("histograms → results/fig2_histograms.csv (rows: MHSA-van, MHSA-kt, FFN-van, FFN-kt)");
    Ok(())
}

/// Table 1: success rate of benchmark rotation over baseline.
pub fn table1(ctx: &ExpCtx) -> Result<()> {
    let model = if ctx.fast { "tiny" } else { "small" };
    let data = capture_analysis(ctx, model)?;

    let concat = |per_layer: &[Tensor]| {
        let d = per_layer[0].shape[1];
        let mut all = Vec::new();
        for t in per_layer {
            all.extend_from_slice(&t.data);
        }
        let rows = all.len() / d;
        Tensor::new(all, vec![rows, d])
    };
    let mhsa = concat(&data.mhsa);
    let ffn = concat(&data.ffn);

    let syn = synthetic_analysis(ctx, data.mhsa[0].shape[1])?;

    let mut t = Table::new(
        "Table 1 — success rate of benchmark over baseline (per-token max reduced). \
         'captured' = trained tiny-model block inputs; 'LLM-regime' = outlier-stressed \
         synthetic activations (the paper's setting — see DESIGN.md §2).",
        &["source", "block", "baseline", "benchmark", "success rate (%)"],
    );
    let cases: [(&str, &Tensor, &Tensor, &Tensor); 3] = [
        ("captured", &mhsa, &data.r1_kurtail, &data.r1_quarot),
        ("captured", &ffn, &data.r1_kurtail, &data.r1_quarot),
        ("LLM-regime", &syn.rows, &syn.r1_kurtail, &syn.r1_quarot),
    ];
    for (i, (src, x, kt, qr)) in cases.iter().enumerate() {
        let bname = if *src == "LLM-regime" {
            "MHSA+FFN"
        } else if i == 0 {
            "MHSA"
        } else {
            "FFN"
        };
        for (base, bench, bl, nl) in [
            (None, *kt, "Vanilla", "KurTail"),
            (None, *qr, "Vanilla", "QuaRot"),
            (Some(*qr), *kt, "QuaRot", "KurTail"),
        ] {
            let sr = success_rate(x, base, bench);
            t.row(vec![
                src.to_string(),
                bname.into(),
                bl.into(),
                nl.into(),
                format!("{:.2}", sr * 100.0),
            ]);
        }
    }
    t.print();
    save_table(&t, "table1")?;
    Ok(())
}
