//! Experiment runners — one per paper table/figure (DESIGN.md §5).
//!
//! Each runner regenerates the corresponding paper artifact on the tiny
//! model family and prints a paper-shaped table (plus results/*.{md,json,csv}).
//! `kurtail exp <id>` dispatches here.

pub mod ablations;
pub mod analysis;
pub mod cost;
pub mod main_tables;

use std::sync::Arc;

use anyhow::Result;

use crate::config::{Method, PipelineConfig, WeightQuantizer};
use crate::eval::{evaluate, EvalSummary};
use crate::pipeline::{MethodCost, Pipeline};
use crate::runtime::Runtime;

/// Shared experiment context.
pub struct ExpCtx {
    pub rt: Arc<Runtime>,
    /// Fast mode: fewer questions / batches / training steps (CI-sized).
    pub fast: bool,
    pub seed: u64,
}

impl ExpCtx {
    pub fn new(artifacts_dir: &str, fast: bool, seed: u64) -> Result<Self> {
        Ok(Self { rt: Arc::new(Runtime::new(artifacts_dir)?), fast, seed })
    }

    pub fn n_questions(&self) -> usize {
        if self.fast {
            12
        } else {
            50
        }
    }

    pub fn eval_batches(&self) -> usize {
        if self.fast {
            4
        } else {
            16
        }
    }

    pub fn table2_models(&self) -> Vec<&'static str> {
        if self.fast {
            vec!["tiny"]
        } else {
            vec!["tiny", "small", "base"]
        }
    }

    pub fn pipeline(&self, model: &str) -> Result<Pipeline> {
        Pipeline::new(self.rt.clone(), model, self.seed, self.fast, true)
    }

    /// One (model, method) cell: quantize + evaluate.
    pub fn run_cell(
        &self,
        pipe: &Pipeline,
        method: Method,
        wq: WeightQuantizer,
    ) -> Result<(EvalSummary, MethodCost)> {
        let mut pcfg = PipelineConfig::new(&pipe.cfg_name, method);
        pcfg.weight_quantizer = wq;
        pcfg.seed = self.seed;
        pcfg.calib.seed = self.seed;
        if self.fast {
            pcfg.calib.n_samples = 64;
            pcfg.calib.iters = 30;
        }
        let (pm, cost) = pipe.quantize(&pcfg)?;
        let summary = evaluate(pipe, &pm, self.n_questions(), self.eval_batches())?;
        Ok((summary, cost))
    }
}

/// Dispatch an experiment by id (table1..table10, fig1, fig2, cost, all).
pub fn run(ctx: &ExpCtx, id: &str) -> Result<()> {
    match id {
        "fig1" => analysis::fig1(ctx),
        "fig2" => analysis::fig2(ctx),
        "table1" => analysis::table1(ctx),
        "table2" => main_tables::table2(ctx),
        "table3" => main_tables::table3(ctx),
        "table4" => main_tables::table4(ctx),
        "table5" => main_tables::table5(ctx),
        "table6" => ablations::table6(ctx),
        "table7" => ablations::table7(ctx),
        "table8" => main_tables::table8(ctx),
        "table9" => main_tables::table9(ctx),
        "table10" => main_tables::table10(ctx),
        "cost" => cost::training_cost(ctx),
        "all" => {
            for id in [
                "fig1", "fig2", "table1", "table2", "table3", "table4", "table5", "table6",
                "table7", "table8", "table9", "table10", "cost",
            ] {
                println!("\n================ {id} ================");
                run(ctx, id)?;
            }
            Ok(())
        }
        other => anyhow::bail!(
            "unknown experiment '{other}' (have fig1, fig2, table1..table10, cost, all)"
        ),
    }
}
