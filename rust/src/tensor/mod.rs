//! Dense f32 tensor substrate for the coordinator's offline math.
//!
//! Everything that happens *outside* the PJRT artifacts — rotation fusion,
//! RTN/GPTQ weight quantization, Hessian accumulation, sensitivity sweeps,
//! metric computation — runs on this. Row-major, owned storage, no
//! external BLAS: the hot kernels are packed, register-blocked and
//! multi-threaded in `matmul.rs`/`hadamard.rs` (scoped threads via
//! `util::par`, `KURTAIL_THREADS` override), with fused rotate→consume
//! variants in `fused.rs` that never materialize rotated intermediates.

pub mod fused;
pub mod hadamard;
pub mod linalg;
pub mod matmul;
pub mod stats;

use crate::util::Rng;

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub shape: Vec<usize>,
}

impl Tensor {
    pub fn new(data: Vec<f32>, shape: Vec<usize>) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape {shape:?}");
        Self { data, shape }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Self { data: vec![0.0; shape.iter().product()], shape: shape.to_vec() }
    }

    pub fn ones(shape: &[usize]) -> Self {
        Self { data: vec![1.0; shape.iter().product()], shape: shape.to_vec() }
    }

    pub fn scalar(v: f32) -> Self {
        Self { data: vec![v], shape: vec![] }
    }

    pub fn eye(n: usize) -> Self {
        let mut t = Self::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    pub fn randn(shape: &[usize], std: f32, rng: &mut Rng) -> Self {
        let n: usize = shape.iter().product();
        Self { data: (0..n).map(|_| rng.normal() * std).collect(), shape: shape.to_vec() }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Rows × cols view of the last axis: (prod(shape[..-1]), shape[-1]).
    pub fn as_2d(&self) -> (usize, usize) {
        let cols = *self.shape.last().expect("scalar has no rows");
        (self.numel() / cols, cols)
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(self.numel(), shape.iter().product::<usize>());
        self.shape = shape.to_vec();
        self
    }

    pub fn row(&self, i: usize) -> &[f32] {
        let (r, c) = self.as_2d();
        assert!(i < r);
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let (r, c) = self.as_2d();
        assert!(i < r);
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Copy of sub-tensor at index `i` along axis 0 (layer slicing).
    pub fn index_axis0(&self, i: usize) -> Tensor {
        assert!(self.rank() >= 1 && i < self.shape[0]);
        let stride: usize = self.shape[1..].iter().product();
        Tensor::new(self.data[i * stride..(i + 1) * stride].to_vec(), self.shape[1..].to_vec())
    }

    /// Write `src` into position `i` along axis 0.
    pub fn set_axis0(&mut self, i: usize, src: &Tensor) {
        let stride: usize = self.shape[1..].iter().product();
        assert_eq!(src.shape, &self.shape[1..], "set_axis0 shape mismatch");
        self.data[i * stride..(i + 1) * stride].copy_from_slice(&src.data);
    }

    /// Stack equal-shaped tensors along a new leading axis.
    pub fn stack(parts: &[Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let inner = parts[0].shape.clone();
        let mut data = Vec::with_capacity(parts.len() * parts[0].numel());
        for p in parts {
            assert_eq!(p.shape, inner);
            data.extend_from_slice(&p.data);
        }
        let mut shape = vec![parts.len()];
        shape.extend(inner);
        Tensor::new(data, shape)
    }

    /// 2-D transpose.
    pub fn t(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut out = Tensor::zeros(&[c, r]);
        for i in 0..r {
            for j in 0..c {
                out.data[j * r + i] = self.data[i * c + j];
            }
        }
        out
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor::new(self.data.iter().map(|&x| f(x)).collect(), self.shape.clone())
    }

    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.shape, other.shape);
        Tensor::new(
            self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect(),
            self.shape.clone(),
        )
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a - b)
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    /// Scale row i by g[i] (broadcast over trailing axes).
    pub fn scale_rows(&self, g: &[f32]) -> Tensor {
        let (r, c) = self.as_2d();
        assert_eq!(g.len(), r);
        let mut out = self.clone();
        for i in 0..r {
            for v in &mut out.data[i * c..(i + 1) * c] {
                *v *= g[i];
            }
        }
        out
    }

    /// Scale column j by g[j] for a 2-D tensor.
    pub fn scale_cols(&self, g: &[f32]) -> Tensor {
        let (r, c) = self.as_2d();
        assert_eq!(g.len(), c);
        let mut out = self.clone();
        for i in 0..r {
            for j in 0..c {
                out.data[i * c + j] *= g[j];
            }
        }
        out
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |a, &b| a.max(b.abs()))
    }

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f32>() / self.numel() as f32
    }

    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }

    /// max |A − B|
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |a, (&x, &y)| a.max((x - y).abs()))
    }
}

/// Signed-integer tensor (tokens). Same layout rules as `Tensor`.
#[derive(Clone, Debug, PartialEq)]
pub struct IntTensor {
    pub data: Vec<i32>,
    pub shape: Vec<usize>,
}

impl IntTensor {
    pub fn new(data: Vec<i32>, shape: Vec<usize>) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        Self { data, shape }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Self { data: vec![0; shape.iter().product()], shape: shape.to_vec() }
    }

    pub fn scalar(v: i32) -> Self {
        Self { data: vec![v], shape: vec![] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slicing_roundtrip() {
        let t = Tensor::new((0..24).map(|x| x as f32).collect(), vec![2, 3, 4]);
        let s = t.index_axis0(1);
        assert_eq!(s.shape, vec![3, 4]);
        assert_eq!(s.data[0], 12.0);
        let mut t2 = Tensor::zeros(&[2, 3, 4]);
        t2.set_axis0(1, &s);
        assert_eq!(t2.index_axis0(1), s);
    }

    #[test]
    fn stack_unstack() {
        let a = Tensor::ones(&[2, 2]);
        let b = Tensor::zeros(&[2, 2]);
        let s = Tensor::stack(&[a.clone(), b.clone()]);
        assert_eq!(s.shape, vec![2, 2, 2]);
        assert_eq!(s.index_axis0(0), a);
        assert_eq!(s.index_axis0(1), b);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(0);
        let t = Tensor::randn(&[5, 7], 1.0, &mut rng);
        assert_eq!(t.t().t(), t);
    }

    #[test]
    fn scale_rows_cols() {
        let t = Tensor::ones(&[2, 3]);
        let r = t.scale_rows(&[2.0, 3.0]);
        assert_eq!(r.data, vec![2.0, 2.0, 2.0, 3.0, 3.0, 3.0]);
        let c = t.scale_cols(&[1.0, 2.0, 3.0]);
        assert_eq!(c.data, vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0]);
    }
}
